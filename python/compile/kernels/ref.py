"""Pure-jnp reference oracle for the POBP message update (Layer 1 spec).

This file defines the *mathematical contract* of the belief-propagation
message update of Eq. (1) of the paper, together with the residuals of
Eq. (7) and the masked ("power word / power topic") update gating of
Section 3.1. The Pallas kernel in ``bp_update.py`` and the Rust native
engine are both validated against these functions.

Dense layout over a (padded) mini-batch shard:

  x          (D, W)     word counts x_{w,d} (0 for padding / absent words)
  mu         (D, W, K)  messages mu_{w,d}(k); rows with x>0 sum to 1 over K
  theta      (D, K)     document sufficient statistics  = sum_w x * mu
  phi_wk     (W, K)     GLOBAL topic-word sufficient statistics phi-hat,
                        *including* the current mini-batch's contribution
                        (i.e. phi_prev + dphi_local synchronized), laid out
                        word-major so K is contiguous
  phi_tot    (K,)       sum_w phi_wk
  word_mask  (W,)       1.0 for power words selected this iteration
  topic_mask (W, K)     1.0 for power topics of each power word

The message update with "minus" own-contribution corrections:

  c        = x[d,w] * mu[d,w,k]
  score(k) = (theta[d,k] - c + alpha) * (phi[w,k] - c + beta)
             / (phi_tot[k] - c + W_total*beta)
  mu'      = normalize_k( mask ? score : mu )       (see note below)
  r[d,w,k] = x[d,w] * |mu' - mu|

Masking note: the paper updates only the messages of power (word, topic)
pairs and leaves the rest untouched (Fig. 4 lines 15-20). Partially
updating a normalized vector would break the simplex constraint, so the
update is *mass-preserving within the selection*: the selected entries'
new scores are rescaled to carry exactly the probability mass the selected
entries held before,

    mu'[sel] = score[sel] * (sum(mu[sel]) / sum(score[sel])),   mu'[!sel] = mu[!sel]

which keeps sum_k mu' = sum_k mu (= 1), leaves un-selected messages
bitwise-frozen (so subset-only synchronization of dphi/r is exact), and
with the all-ones mask reduces to the classic normalize-over-K BP update.
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-30


def normalize_k(scores: jnp.ndarray) -> jnp.ndarray:
    """Normalize the trailing (topic) axis to the simplex."""
    return scores / jnp.maximum(scores.sum(axis=-1, keepdims=True), EPS)


def bp_scores(x, mu, theta, phi_wk, phi_tot, alpha, beta, w_total):
    """Un-normalized message scores of Eq. (1), minus-corrected.

    Shapes: x (D,W), mu (D,W,K), theta (D,K), phi_wk (W,K), phi_tot (K,).
    Returns (D,W,K).
    """
    c = x[:, :, None] * mu  # own contribution (D,W,K)
    theta_m = jnp.maximum(theta[:, None, :] - c, 0.0) + alpha
    phi_m = jnp.maximum(phi_wk[None, :, :] - c, 0.0) + beta
    denom = jnp.maximum(phi_tot[None, None, :] - c, 0.0) + w_total * beta
    return theta_m * phi_m / jnp.maximum(denom, EPS)


def bp_update_ref(
    x,
    mu,
    theta,
    phi_wk,
    phi_tot,
    word_mask,
    topic_mask,
    alpha: float,
    beta: float,
    w_total: float,
):
    """Reference masked message update + residuals.

    Returns (mu_new, r) with shapes ((D,W,K), (D,W,K)).
    Entries with x == 0 keep their old message and contribute 0 residual.
    """
    scores = bp_scores(x, mu, theta, phi_wk, phi_tot, alpha, beta, w_total)
    mask = (word_mask[:, None] * topic_mask)[None, :, :] > 0  # (1,W,K)
    sel_mass_old = jnp.where(mask, mu, 0.0).sum(axis=-1, keepdims=True)
    sel_mass_new = jnp.where(mask, scores, 0.0).sum(axis=-1, keepdims=True)
    scale = sel_mass_old / jnp.maximum(sel_mass_new, EPS)
    mu_new = jnp.where(mask, scores * scale, mu)
    active = (x > 0)[:, :, None]
    mu_new = jnp.where(active, mu_new, mu)
    r = x[:, :, None] * jnp.abs(mu_new - mu)
    return mu_new, r


def sweep_ref(
    x,
    mu,
    phi_prev_wk,
    word_mask,
    topic_mask,
    alpha: float,
    beta: float,
    w_total: float,
):
    """One full POBP iteration over a shard (the Layer-2 contract).

    Recomputes local sufficient statistics from (x, mu), applies the message
    update, and returns everything the Rust coordinator needs:

      mu_new    (D,W,K)
      theta_new (D,K)   = sum_w x * mu_new
      dphi_new  (W,K)   = sum_d x * mu_new   (the local gradient to allreduce)
      r_wk      (W,K)   = sum_d x * |mu'-mu| (the residual matrix, Eq. 8)
    """
    theta = jnp.einsum("dw,dwk->dk", x, mu)
    dphi = jnp.einsum("dw,dwk->wk", x, mu)
    phi_wk = phi_prev_wk + dphi
    phi_tot = phi_wk.sum(axis=0)
    mu_new, r = bp_update_ref(
        x, mu, theta, phi_wk, phi_tot, word_mask, topic_mask, alpha, beta, w_total
    )
    theta_new = jnp.einsum("dw,dwk->dk", x, mu_new)
    dphi_new = jnp.einsum("dw,dwk->wk", x, mu_new)
    r_wk = r.sum(axis=0)
    return mu_new, theta_new, dphi_new, r_wk
