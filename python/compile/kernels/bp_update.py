"""Layer-1 Pallas kernel: the POBP message update hot-spot.

The kernel computes, for a (Dblk, Wblk, K) tile of the mini-batch shard,
the minus-corrected BP message update of Eq. (1), the power-mask gating of
Section 3.1, and the residual of Eq. (7):

    c        = x * mu
    score    = (theta - c + alpha) * (phi - c + beta) / (phi_tot - c + W*beta)
    mu'      = mass-preserving masked update (see ref.py): selected entries
               get score rescaled to the mass the selection previously held,
               un-selected entries stay bitwise-frozen; frozen where x == 0
    r        = x * |mu' - mu|

TPU mapping (see DESIGN.md §Hardware-Adaptation): the topic axis K is kept
whole inside every block because the normalization reduces over it; D and W
are tiled so one (Dblk, Wblk, K) message block plus its (Dblk, K) theta
slice and (Wblk, K) phi slice fit VMEM. The kernel is element-wise over
(d, w) with a K-reduction, so the natural layout keeps K innermost
(contiguous lanes). On this image the kernel must run with
``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls — so it lowers into plain HLO that the Rust runtime executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-30


def _bp_update_kernel(
    x_ref,  # (Dblk, Wblk)
    mu_ref,  # (Dblk, Wblk, K)
    theta_ref,  # (Dblk, K)
    phi_ref,  # (Wblk, K)
    phi_tot_ref,  # (K,)
    wmask_ref,  # (Wblk,)
    tmask_ref,  # (Wblk, K)
    mu_out_ref,  # (Dblk, Wblk, K)
    r_out_ref,  # (Dblk, Wblk, K)
    *,
    alpha: float,
    beta: float,
    w_total: float,
):
    x = x_ref[...]
    mu = mu_ref[...]
    c = x[:, :, None] * mu  # own-message contribution

    theta_m = jnp.maximum(theta_ref[...][:, None, :] - c, 0.0) + alpha
    phi_m = jnp.maximum(phi_ref[...][None, :, :] - c, 0.0) + beta
    denom = jnp.maximum(phi_tot_ref[...][None, None, :] - c, 0.0) + w_total * beta
    scores = theta_m * phi_m / jnp.maximum(denom, EPS)

    mask = (wmask_ref[...][:, None] * tmask_ref[...])[None, :, :] > 0
    sel_mass_old = jnp.where(mask, mu, 0.0).sum(axis=-1, keepdims=True)
    sel_mass_new = jnp.where(mask, scores, 0.0).sum(axis=-1, keepdims=True)
    scale = sel_mass_old / jnp.maximum(sel_mass_new, EPS)
    mu_new = jnp.where(mask, scores * scale, mu)

    active = (x > 0)[:, :, None]
    mu_new = jnp.where(active, mu_new, mu)

    mu_out_ref[...] = mu_new
    r_out_ref[...] = x[:, :, None] * jnp.abs(mu_new - mu)


def bp_update_pallas(
    x,
    mu,
    theta,
    phi_wk,
    phi_tot,
    word_mask,
    topic_mask,
    *,
    alpha: float,
    beta: float,
    w_total: float,
    block_d: int = 32,
    block_w: int = 128,
    interpret: bool = True,
):
    """Tiled Pallas launch of the message-update kernel.

    Shapes as in ``ref.py``. D and W must be divisible by the block sizes
    (the Layer-2 model pads shards); K is kept whole per block.
    Returns (mu_new, r), both (D, W, K).
    """
    d, w = x.shape
    k = mu.shape[-1]
    if d % block_d or w % block_w:
        raise ValueError(f"shard ({d},{w}) not divisible by block ({block_d},{block_w})")
    grid = (d // block_d, w // block_w)

    kernel = functools.partial(
        _bp_update_kernel, alpha=alpha, beta=beta, w_total=w_total
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d, block_w), lambda i, j: (i, j)),  # x
            pl.BlockSpec((block_d, block_w, k), lambda i, j: (i, j, 0)),  # mu
            pl.BlockSpec((block_d, k), lambda i, j: (i, 0)),  # theta
            pl.BlockSpec((block_w, k), lambda i, j: (j, 0)),  # phi
            pl.BlockSpec((k,), lambda i, j: (0,)),  # phi_tot
            pl.BlockSpec((block_w,), lambda i, j: (j,)),  # word_mask
            pl.BlockSpec((block_w, k), lambda i, j: (j, 0)),  # topic_mask
        ],
        out_specs=[
            pl.BlockSpec((block_d, block_w, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_d, block_w, k), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, w, k), mu.dtype),
            jax.ShapeDtypeStruct((d, w, k), mu.dtype),
        ],
        interpret=interpret,
    )(x, mu, theta, phi_wk, phi_tot, word_mask, topic_mask)


def vmem_footprint_bytes(block_d: int, block_w: int, k: int, itemsize: int = 4) -> int:
    """Estimated VMEM bytes held live by one kernel instance.

    Inputs (x, mu, theta, phi, phi_tot, masks) + outputs (mu', r) + the c /
    scores temporaries. Used by the perf pass to size blocks under the
    ~16 MiB/core VMEM budget of a TPU.
    """
    per_block = (
        block_d * block_w  # x
        + 4 * block_d * block_w * k  # mu, mu', r, scores temp
        + block_d * k  # theta
        + 2 * block_w * k  # phi, topic_mask
        + k  # phi_tot
        + block_w  # word_mask
    )
    return per_block * itemsize
