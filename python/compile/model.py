"""Layer-2 JAX model: one POBP iteration over a dense mini-batch shard.

This is the computation each (simulated) processor runs between two
synchronization points of the paper's MPA (Fig. 4 lines 15-20):

  inputs  : x (D,W), mu (D,W,K), phi_prev (W,K)  [global phi-hat from the
            previous mini-batches, Eq. 3 / 11], word/topic power masks
  outputs : mu' (D,W,K), theta' (D,K), dphi' (W,K)  [the local gradient the
            coordinator allreduces via Eq. 15], r_wk (W,K)  [the residual
            matrix allreduced via Eq. 9 and used for power selection]

The message update itself is the Layer-1 Pallas kernel; the surrounding
reductions (theta, dphi, residual row-sums) are left to XLA, which fuses
them with the kernel output. ``aot.py`` lowers ``pobp_sweep`` once per
compiled shape and the Rust runtime executes the HLO on its hot path —
Python never runs at serve time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.bp_update import bp_update_pallas
from .kernels import ref


def pobp_sweep(
    x,
    mu,
    phi_prev_wk,
    word_mask,
    topic_mask,
    *,
    alpha: float,
    beta: float,
    w_total: float,
    block_d: int = 32,
    block_w: int = 128,
    use_pallas: bool = True,
):
    """One POBP iteration over a shard. Returns (mu', theta', dphi', r_wk).

    ``phi_prev_wk`` is the accumulated global topic-word sufficient
    statistics EXCLUDING the current mini-batch (Eq. 11's phi^{m-1}); the
    current batch's own contribution is recomputed from ``mu`` so that the
    minus-corrections of Eq. (1) see a self-consistent phi-hat.
    """
    theta = jnp.einsum("dw,dwk->dk", x, mu)
    dphi = jnp.einsum("dw,dwk->wk", x, mu)
    phi_wk = phi_prev_wk + dphi
    phi_tot = phi_wk.sum(axis=0)

    if use_pallas:
        mu_new, r = bp_update_pallas(
            x, mu, theta, phi_wk, phi_tot, word_mask, topic_mask,
            alpha=alpha, beta=beta, w_total=w_total,
            block_d=block_d, block_w=block_w,
        )
    else:
        mu_new, r = ref.bp_update_ref(
            x, mu, theta, phi_wk, phi_tot, word_mask, topic_mask,
            alpha, beta, w_total,
        )

    theta_new = jnp.einsum("dw,dwk->dk", x, mu_new)
    dphi_new = jnp.einsum("dw,dwk->wk", x, mu_new)
    r_wk = r.sum(axis=0)
    return mu_new, theta_new, dphi_new, r_wk


def init_messages(x, key, k: int):
    """Random-initialized normalized messages (Fig. 4 line 3).

    Deterministic given the PRNG key; zero rows (padding) get uniform
    messages so downstream normalizations stay finite.
    """
    d, w = x.shape
    raw = jax.random.uniform(key, (d, w, k), minval=0.1, maxval=1.0)
    return raw / raw.sum(axis=-1, keepdims=True)


def make_sweep_fn(
    d: int,
    w: int,
    k: int,
    *,
    alpha: float,
    beta: float,
    w_total: float | None = None,
    block_d: int = 32,
    block_w: int = 128,
    use_pallas: bool = True,
):
    """A jit-able sweep specialized to a compiled shape (for AOT export).

    Returns ``fn(x, mu, phi_prev, word_mask, topic_mask)`` and its example
    ShapeDtypeStructs, in the exact argument order the Rust runtime uses.
    """
    w_total = float(w if w_total is None else w_total)

    @functools.wraps(pobp_sweep)
    def fn(x, mu, phi_prev_wk, word_mask, topic_mask):
        return pobp_sweep(
            x, mu, phi_prev_wk, word_mask, topic_mask,
            alpha=alpha, beta=beta, w_total=w_total,
            block_d=block_d, block_w=block_w, use_pallas=use_pallas,
        )

    f32 = jnp.float32
    specs = (
        jax.ShapeDtypeStruct((d, w), f32),      # x
        jax.ShapeDtypeStruct((d, w, k), f32),   # mu
        jax.ShapeDtypeStruct((w, k), f32),      # phi_prev
        jax.ShapeDtypeStruct((w,), f32),        # word_mask
        jax.ShapeDtypeStruct((w, k), f32),      # topic_mask
    )
    return fn, specs
