"""AOT bridge: lower the Layer-2 POBP sweep to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Outputs, consumed by ``rust/src/runtime/artifacts.rs``:

  artifacts/pobp_d{D}_w{W}_k{K}.hlo.txt    one module per compiled shape
  artifacts/manifest.json                  shape -> file map + hyperparams

Usage:  python -m compile.aot --out-dir ../artifacts [--shapes d,w,k ...]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import make_sweep_fn

# Default compiled shapes. D and W are the padded shard sizes the Rust
# coordinator buckets mini-batch shards into; K is the topic count.
# (block_d | d, block_w | w must hold — see bp_update_pallas.)
DEFAULT_SHAPES = [
    (32, 256, 16),   # test / CI shape
    (64, 512, 50),   # quickstart: enron-sim scaled, paper's lambda_K*K=50
    (64, 512, 100),  # K sweep point
]
DEFAULT_ALPHA_K = 2.0  # paper: alpha = 2/K
DEFAULT_BETA = 0.01    # paper: beta = 0.01


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def block_sizes(d: int, w: int) -> tuple[int, int]:
    """Largest default-ish blocks that divide the shard shape."""
    bd = next(b for b in (32, 16, 8, 4, 2, 1) if d % b == 0)
    bw = next(b for b in (128, 64, 32, 16, 8, 4, 2, 1) if w % b == 0)
    return bd, bw


def lower_shape(d: int, w: int, k: int, alpha: float, beta: float) -> str:
    bd, bw = block_sizes(d, w)
    fn, specs = make_sweep_fn(
        d, w, k, alpha=alpha, beta=beta, block_d=bd, block_w=bw, use_pallas=True
    )
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--shapes", nargs="*", default=None,
        help="shapes as d,w,k triples, e.g. 64,512,50",
    )
    ap.add_argument("--beta", type=float, default=DEFAULT_BETA)
    args = ap.parse_args()

    shapes = (
        [tuple(int(v) for v in s.split(",")) for s in args.shapes]
        if args.shapes
        else DEFAULT_SHAPES
    )
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "alpha_times_k": DEFAULT_ALPHA_K,
                "beta": args.beta, "entries": []}
    for d, w, k in shapes:
        alpha = DEFAULT_ALPHA_K / k
        name = f"pobp_d{d}_w{w}_k{k}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_shape(d, w, k, alpha, args.beta)
        with open(path, "w") as f:
            f.write(text)
        bd, bw = block_sizes(d, w)
        manifest["entries"].append({
            "file": name, "d": d, "w": w, "k": k,
            "alpha": alpha, "beta": args.beta,
            "block_d": bd, "block_w": bw,
            # arg order the rust runtime must feed:
            "args": ["x[d,w]", "mu[d,w,k]", "phi_prev[w,k]",
                      "word_mask[w]", "topic_mask[w,k]"],
            "outputs": ["mu[d,w,k]", "theta[d,k]", "dphi[w,k]", "r_wk[w,k]"],
        })
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
