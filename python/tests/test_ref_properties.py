"""Property tests of the pure-jnp oracle itself — the invariants every
other layer (Pallas kernel, Rust native engine, AOT artifact) inherits.

hypothesis sweeps shapes, sparsity and mask density; the properties are
the paper's structural facts: message simplex preservation, sufficient-
statistics mass conservation (Eqs. 2-3), bitwise freezing of un-selected
messages (the subset-sync exactness of §3.1), and residual/update
consistency (Eq. 7)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def case(seed, d, w, k, mask_frac):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 4, size=(d, w)).astype(np.float32)
    mu = rng.random((d, w, k)).astype(np.float32) + 0.05
    mu /= mu.sum(-1, keepdims=True)
    phi_prev = (rng.random((w, k)) * 3.0).astype(np.float32)
    wm = (rng.random(w) < mask_frac).astype(np.float32)
    tm = (rng.random((w, k)) < mask_frac).astype(np.float32)
    return x, mu, phi_prev, wm, tm


PARAMS = dict(max_examples=20, deadline=None)
SHAPES = st.tuples(
    st.integers(0, 2**16),          # seed
    st.sampled_from([2, 5]),        # d
    st.sampled_from([6, 11]),       # w
    st.sampled_from([3, 7]),        # k
    st.sampled_from([1.0, 0.5]),    # mask fraction
)


@settings(**PARAMS)
@given(SHAPES)
def test_mass_conservation(shape):
    seed, d, w, k, mf = shape
    x, mu, phi_prev, wm, tm = case(seed, d, w, k, mf)
    _, theta, dphi, _ = ref.sweep_ref(x, mu, phi_prev, wm, tm, 2.0 / k, 0.01, float(w))
    tokens = float(x.sum())
    assert abs(float(theta.sum()) - tokens) < 1e-3 * max(tokens, 1.0)
    assert abs(float(dphi.sum()) - tokens) < 1e-3 * max(tokens, 1.0)


@settings(**PARAMS)
@given(SHAPES)
def test_simplex_preserved(shape):
    seed, d, w, k, mf = shape
    x, mu, phi_prev, wm, tm = case(seed, d, w, k, mf)
    mu2, _, _, _ = ref.sweep_ref(x, mu, phi_prev, wm, tm, 2.0 / k, 0.01, float(w))
    sums = np.asarray(mu2.sum(-1))
    np.testing.assert_allclose(sums, 1.0, atol=2e-5)


@settings(**PARAMS)
@given(SHAPES)
def test_unselected_messages_bitwise_frozen(shape):
    seed, d, w, k, _ = shape
    x, mu, phi_prev, wm, tm = case(seed, d, w, k, 0.4)
    mu2, _, _, r_wk = ref.sweep_ref(x, mu, phi_prev, wm, tm, 2.0 / k, 0.01, float(w))
    sel = (np.asarray(wm)[:, None] * np.asarray(tm)) > 0
    frozen = ~sel
    # un-selected (word, topic) message entries are *bitwise* unchanged
    mu_np, mu2_np = np.asarray(mu), np.asarray(mu2)
    for wi in range(w):
        for t in range(k):
            if frozen[wi, t]:
                np.testing.assert_array_equal(mu2_np[:, wi, t], mu_np[:, wi, t])
    # and contribute exactly zero residual
    assert float(np.asarray(r_wk)[frozen].sum()) == 0.0


@settings(**PARAMS)
@given(SHAPES)
def test_residual_matches_message_movement(shape):
    seed, d, w, k, mf = shape
    x, mu, phi_prev, wm, tm = case(seed, d, w, k, mf)
    mu2, _, _, r_wk = ref.sweep_ref(x, mu, phi_prev, wm, tm, 2.0 / k, 0.01, float(w))
    # Eq. 7/8: r_w(k) = sum_d x |mu' - mu|
    expect = np.einsum("dw,dwk->wk", np.asarray(x), np.abs(np.asarray(mu2) - np.asarray(mu)))
    np.testing.assert_allclose(np.asarray(r_wk), expect, rtol=1e-4, atol=1e-6)


@settings(**PARAMS)
@given(st.integers(0, 2**16))
def test_fixed_point_has_zero_residual(seed):
    """If messages stop moving, residuals vanish (the convergence claim
    behind Fig. 5): iterate to near-convergence and check r ≈ 0 relative
    to the start."""
    d, w, k = 4, 8, 3
    x, mu, phi_prev, wm, tm = case(seed, d, w, k, 1.0)
    r0 = None
    for i in range(60):
        mu, _, _, r = ref.sweep_ref(x, mu, phi_prev, wm, tm, 2.0 / k, 0.01, float(w))
        if i == 0:
            r0 = float(r.sum())
    r_last = float(r.sum())
    assert r_last < max(r0, 1e-9), f"residual did not decay: {r0} -> {r_last}"
