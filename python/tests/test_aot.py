"""AOT pipeline tests: lowering produces valid HLO text + manifest, the
block-size chooser respects divisibility, and the lowered module has the
entry signature the Rust runtime expects."""

import json
import os
import tempfile

import jax
import pytest

from compile import aot

jax.config.update("jax_platform_name", "cpu")


def test_block_sizes_divide():
    for d, w in [(32, 256), (64, 512), (48, 96), (7, 13)]:
        bd, bw = aot.block_sizes(d, w)
        assert d % bd == 0 and w % bw == 0
        assert bd >= 1 and bw >= 1


def test_lower_tiny_shape_produces_hlo_text():
    text = aot.lower_shape(4, 8, 3, alpha=2.0 / 3, beta=0.01)
    assert "HloModule" in text
    # 4 outputs in a tuple
    assert "tuple(" in text.replace(" ", "") or "ROOT" in text


def test_main_writes_manifest(tmp_path=None):
    out = tempfile.mkdtemp(prefix="pobp_aot_test_")
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", out, "--shapes", "4,8,3"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    assert m["format"] == "hlo-text"
    (entry,) = m["entries"]
    assert (entry["d"], entry["w"], entry["k"]) == (4, 8, 3)
    assert abs(entry["alpha"] - 2.0 / 3) < 1e-9
    assert entry["args"][0] == "x[d,w]"
    hlo_path = os.path.join(out, entry["file"])
    assert os.path.exists(hlo_path)
    assert os.path.getsize(hlo_path) > 100


def test_default_shapes_cover_quickstart():
    assert (64, 512, 50) in aot.DEFAULT_SHAPES  # quickstart shape
    assert (32, 256, 16) in aot.DEFAULT_SHAPES  # CI/parity shape


@pytest.mark.parametrize("d,w,k", [(2, 4, 2), (8, 16, 5)])
def test_lowered_module_is_deterministic(d, w, k):
    a = aot.lower_shape(d, w, k, alpha=0.1, beta=0.01)
    b = aot.lower_shape(d, w, k, alpha=0.1, beta=0.01)
    assert a == b
