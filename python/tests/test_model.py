"""L2 semantics: the full POBP sweep (kernel + reductions) and its
invariants — sufficient-statistics mass conservation, SGD phi accumulation
(Eq. 11), masked-update gating, and multi-iteration convergence of the
residual (Fig. 5's co-trend at toy scale).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import pobp_sweep, init_messages, make_sweep_fn
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

D, W, K = 8, 16, 4
ALPHA, BETA = 2.0 / K, 0.01


def toy_shard(seed=0, d=D, w=W):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 4, size=(d, w)).astype(np.float32)
    return jnp.asarray(x)


def ones_masks(w=W, k=K):
    return jnp.ones((w,)), jnp.ones((w, k))


def test_sweep_matches_ref_sweep():
    x = toy_shard()
    mu = init_messages(x, jax.random.PRNGKey(0), K)
    phi_prev = jnp.zeros((W, K))
    wm, tm = ones_masks()
    got = pobp_sweep(x, mu, phi_prev, wm, tm,
                     alpha=ALPHA, beta=BETA, w_total=float(W),
                     block_d=4, block_w=8)
    want = ref.sweep_ref(x, mu, phi_prev, wm, tm, ALPHA, BETA, float(W))
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(g, w_, rtol=1e-5, atol=1e-6)


def test_mass_conservation():
    """sum(theta') = sum(dphi') = total token count of the shard."""
    x = toy_shard(3)
    mu = init_messages(x, jax.random.PRNGKey(1), K)
    wm, tm = ones_masks()
    _, theta, dphi, _ = pobp_sweep(
        x, mu, jnp.zeros((W, K)), wm, tm,
        alpha=ALPHA, beta=BETA, w_total=float(W), block_d=4, block_w=8)
    tokens = float(x.sum())
    np.testing.assert_allclose(float(theta.sum()), tokens, rtol=1e-5)
    np.testing.assert_allclose(float(dphi.sum()), tokens, rtol=1e-5)


def test_residual_decreases_over_iterations():
    """Fig. 5: average residual trends down as messages converge."""
    x = toy_shard(5)
    mu = init_messages(x, jax.random.PRNGKey(2), K)
    wm, tm = ones_masks()
    phi_prev = jnp.zeros((W, K))
    residuals = []
    for _ in range(20):
        mu, _, _, r_wk = pobp_sweep(
            x, mu, phi_prev, wm, tm,
            alpha=ALPHA, beta=BETA, w_total=float(W), block_d=4, block_w=8)
        residuals.append(float(r_wk.sum()) / float(x.sum()))
    assert residuals[-1] < residuals[0] * 0.2
    assert residuals[-1] < 0.1  # paper's convergence threshold (line 26)


def test_phi_accumulation_sgd():
    """Eq. 11: phi^m = phi^{m-1} + dphi^m accumulates across mini-batches
    and the next batch's update sees it via phi_prev."""
    x1, x2 = toy_shard(7), toy_shard(8)
    wm, tm = ones_masks()
    phi = jnp.zeros((W, K))
    for x in (x1, x2):
        mu = init_messages(x, jax.random.PRNGKey(3), K)
        for _ in range(5):
            mu, _, dphi, _ = pobp_sweep(
                x, mu, phi, wm, tm,
                alpha=ALPHA, beta=BETA, w_total=float(W), block_d=4, block_w=8)
        phi = phi + dphi
    np.testing.assert_allclose(
        float(phi.sum()), float(x1.sum() + x2.sum()), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), frac=st.sampled_from([0.25, 0.5]))
def test_masked_sweep_only_moves_selected_words(seed, frac):
    """Un-selected words' messages must be bitwise-frozen (Section 3.1)."""
    x = toy_shard(seed)
    mu = init_messages(x, jax.random.PRNGKey(seed), K)
    rng = np.random.default_rng(seed)
    wm = jnp.asarray((rng.random(W) < frac).astype(np.float32))
    tm = jnp.ones((W, K))
    mu_new, _, _, r_wk = pobp_sweep(
        x, mu, jnp.zeros((W, K)), wm, tm,
        alpha=ALPHA, beta=BETA, w_total=float(W), block_d=4, block_w=8)
    frozen = np.asarray(wm) == 0
    # frozen words are re-normalized (simplex repair), so allow float noise
    np.testing.assert_allclose(
        np.asarray(mu_new)[:, frozen, :], np.asarray(mu)[:, frozen, :],
        atol=1e-6)
    np.testing.assert_allclose(np.asarray(r_wk)[frozen, :], 0.0, atol=1e-5)


def test_make_sweep_fn_specs_roundtrip():
    fn, specs = make_sweep_fn(8, 16, 4, alpha=ALPHA, beta=BETA,
                              block_d=4, block_w=8)
    assert [tuple(s.shape) for s in specs] == [
        (8, 16), (8, 16, 4), (16, 4), (16,), (16, 4)]
    args = [jnp.zeros(s.shape, s.dtype) for s in specs]
    args[0] = toy_shard(1, 8, 16)
    args[1] = init_messages(args[0], jax.random.PRNGKey(0), 4)
    args[3] = jnp.ones(16)
    args[4] = jnp.ones((16, 4))
    out = jax.jit(fn)(*args)
    assert [tuple(o.shape) for o in out] == [
        (8, 16, 4), (8, 4), (16, 4), (16, 4)]
