"""Export golden vectors from the pure-jnp oracle for the Rust engine.

Writes python/tests/golden_sweep.json: a tiny deterministic sweep case
(inputs + expected outputs) that rust/tests/golden.rs replays through the
native sparse engine. This pins the cross-language contract without
needing artifacts or a Python runtime on the Rust side.

Usage: python -m tests.export_golden   (from python/)
"""

import json
import os

import numpy as np

from compile.kernels import ref

D, W, K = 4, 6, 3
ALPHA, BETA = 2.0 / K, 0.01


def main() -> None:
    rng = np.random.default_rng(1234)
    x = rng.integers(0, 4, size=(D, W)).astype(np.float32)
    mu = rng.random((D, W, K)).astype(np.float32) + 0.1
    mu /= mu.sum(-1, keepdims=True)
    phi_prev = (rng.random((W, K)) * 5.0).astype(np.float32)
    word_mask = np.ones(W, np.float32)
    topic_mask = np.ones((W, K), np.float32)

    mu2, theta2, dphi2, r_wk = ref.sweep_ref(
        x, mu, phi_prev, word_mask, topic_mask, ALPHA, BETA, float(W)
    )
    out = {
        "d": D, "w": W, "k": K, "alpha": ALPHA, "beta": BETA,
        "x": np.asarray(x).ravel().tolist(),
        "mu": np.asarray(mu).ravel().tolist(),
        "phi_prev": np.asarray(phi_prev).ravel().tolist(),
        "mu_out": np.asarray(mu2).ravel().tolist(),
        "theta_out": np.asarray(theta2).ravel().tolist(),
        "dphi_out": np.asarray(dphi2).ravel().tolist(),
        "r_wk_out": np.asarray(r_wk).ravel().tolist(),
    }
    path = os.path.join(os.path.dirname(__file__), "golden_sweep.json")
    with open(path, "w") as f:
        json.dump(out, f)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
