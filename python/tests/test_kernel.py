"""L1 correctness: Pallas kernel vs pure-jnp oracle (the CORE signal).

hypothesis sweeps shard shapes, block shapes and mask densities; every case
asserts allclose between ``bp_update_pallas`` (interpret=True) and
``ref.bp_update_ref``, plus the simplex/residual invariants the Rust side
relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bp_update import bp_update_pallas, vmem_footprint_bytes
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_case(seed, d, w, k, zero_frac=0.3, mask_frac=1.0):
    """Random but reproducible kernel inputs with a consistent state."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 5, size=(d, w)).astype(np.float32)
    x[rng.random((d, w)) < zero_frac] = 0.0
    mu = rng.random((d, w, k)).astype(np.float32) + 0.05
    mu /= mu.sum(-1, keepdims=True)
    theta = np.einsum("dw,dwk->dk", x, mu).astype(np.float32)
    phi_prev = rng.random((w, k)).astype(np.float32) * 10.0
    phi = phi_prev + np.einsum("dw,dwk->wk", x, mu).astype(np.float32)
    phi_tot = phi.sum(0)
    wmask = (rng.random(w) < mask_frac).astype(np.float32)
    tmask = (rng.random((w, k)) < mask_frac).astype(np.float32)
    return (jnp.asarray(v) for v in (x, mu, theta, phi, phi_tot, wmask, tmask))


ALPHA, BETA = 2.0 / 16, 0.01


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    d=st.sampled_from([2, 4, 8]),
    w=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([3, 8, 16]),
    mask_frac=st.sampled_from([1.0, 0.5, 0.1]),
)
def test_kernel_matches_ref(seed, d, w, k, mask_frac):
    x, mu, theta, phi, phi_tot, wmask, tmask = make_case(
        seed, d, w, k, mask_frac=mask_frac
    )
    got_mu, got_r = bp_update_pallas(
        x, mu, theta, phi, phi_tot, wmask, tmask,
        alpha=ALPHA, beta=BETA, w_total=float(w), block_d=min(d, 4),
        block_w=min(w, 8),
    )
    want_mu, want_r = ref.bp_update_ref(
        x, mu, theta, phi, phi_tot, wmask, tmask, ALPHA, BETA, float(w)
    )
    np.testing.assert_allclose(got_mu, want_mu, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_messages_stay_normalized(seed):
    d, w, k = 4, 16, 8
    x, mu, theta, phi, phi_tot, wmask, tmask = make_case(seed, d, w, k)
    got_mu, _ = bp_update_pallas(
        x, mu, theta, phi, phi_tot, wmask, tmask,
        alpha=ALPHA, beta=BETA, w_total=float(w), block_d=4, block_w=8,
    )
    sums = np.asarray(got_mu.sum(-1))
    active = np.asarray(x) > 0
    np.testing.assert_allclose(sums[active], 1.0, rtol=1e-5)


def test_zero_count_entries_frozen():
    d, w, k = 4, 8, 4
    x, mu, theta, phi, phi_tot, wmask, tmask = make_case(7, d, w, k, zero_frac=0.6)
    got_mu, got_r = bp_update_pallas(
        x, mu, theta, phi, phi_tot, wmask, tmask,
        alpha=ALPHA, beta=BETA, w_total=float(w), block_d=4, block_w=8,
    )
    inactive = np.asarray(x) == 0
    np.testing.assert_allclose(
        np.asarray(got_mu)[inactive], np.asarray(mu)[inactive]
    )
    np.testing.assert_allclose(np.asarray(got_r)[inactive], 0.0)


def test_empty_mask_is_identity():
    """With no power words selected, messages must not move (Fig. 3)."""
    d, w, k = 4, 8, 4
    x, mu, theta, phi, phi_tot, _, _ = make_case(11, d, w, k)
    zero_w = jnp.zeros(w)
    zero_t = jnp.zeros((w, k))
    got_mu, got_r = bp_update_pallas(
        x, mu, theta, phi, phi_tot, zero_w, zero_t,
        alpha=ALPHA, beta=BETA, w_total=float(w), block_d=4, block_w=8,
    )
    np.testing.assert_allclose(got_mu, mu, rtol=1e-6)
    np.testing.assert_allclose(got_r, 0.0, atol=1e-6)


@pytest.mark.parametrize("block_d,block_w", [(2, 4), (4, 8), (8, 16)])
def test_block_shape_invariance(block_d, block_w):
    """Tiling must not change the numbers."""
    d, w, k = 8, 16, 6
    x, mu, theta, phi, phi_tot, wmask, tmask = make_case(3, d, w, k, mask_frac=0.5)
    got_mu, got_r = bp_update_pallas(
        x, mu, theta, phi, phi_tot, wmask, tmask,
        alpha=ALPHA, beta=BETA, w_total=float(w),
        block_d=block_d, block_w=block_w,
    )
    want_mu, want_r = ref.bp_update_ref(
        x, mu, theta, phi, phi_tot, wmask, tmask, ALPHA, BETA, float(w)
    )
    np.testing.assert_allclose(got_mu, want_mu, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-5, atol=1e-6)


def test_indivisible_block_raises():
    x, mu, theta, phi, phi_tot, wmask, tmask = make_case(0, 4, 8, 4)
    with pytest.raises(ValueError, match="not divisible"):
        bp_update_pallas(
            x, mu, theta, phi, phi_tot, wmask, tmask,
            alpha=ALPHA, beta=BETA, w_total=8.0, block_d=3, block_w=8,
        )


def test_vmem_footprint_under_budget():
    """Default quickstart blocks must fit a 16 MiB TPU VMEM budget."""
    assert vmem_footprint_bytes(32, 128, 100) < 16 * 2**20
    assert vmem_footprint_bytes(32, 128, 1000) > 16 * 2**20  # sanity: scales
