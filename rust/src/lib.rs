//! # POBP — communication-efficient parallel online belief propagation
//!
//! A full-system reproduction of *"Towards Big Topic Modeling"* (Yan,
//! Zeng, Liu & Gao, 2013): latent Dirichlet allocation at scale on a
//! multi-processor architecture that synchronizes only residual-selected
//! *power words* and *power topics*.
//!
//! Three layers (see DESIGN.md):
//! * **L3 (this crate)** — coordinator: mini-batch streaming, N-worker
//!   MPA, power-subset allreduce, convergence control, metrics, CLI.
//! * **L2 (python/compile/model.py)** — the per-shard POBP sweep in JAX,
//!   AOT-lowered to `artifacts/*.hlo.txt`, executed here via PJRT.
//! * **L1 (python/compile/kernels/bp_update.py)** — the Pallas message
//!   update kernel inside the L2 graph.
//!
//! The crate also implements every baseline the paper compares against
//! (PGS/PFGS/PSGS/YLDA/PVB and single-processor BP/OBP) plus the corpus,
//! cluster and evaluation substrates, so all tables and figures of the
//! paper can be regenerated with `cargo bench`.

pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod engine;
pub mod eval;
pub mod fault;
pub mod metrics;
pub mod repro;
pub mod runtime;
pub mod sched;
pub mod storage;
pub mod synth;
pub mod util;
