//! Inference engines: the native POBP worker plus every baseline the
//! paper compares against (collapsed/fast/sparse Gibbs, Yahoo-LDA-style
//! async Gibbs, variational Bayes), each runnable under the same simulated
//! MPA so the paper's figures can be regenerated like-for-like.

pub mod abp;
pub mod bp;
pub mod complexity;
pub mod fgs;
pub mod gibbs;
pub mod mca;
pub mod mpa;
pub mod sgs;
pub mod simd;
pub mod snapshot;
pub mod traits;
pub mod vb;

pub use traits::{IterStat, LdaParams, Model, TrainResult};
