//! SparseLDA sampler (Yao, Mimno & McCallum 2009) — the paper's SGS/PSGS
//! baseline.
//!
//! The collapsed conditional is decomposed into three buckets,
//!
//! ```text
//! p(k) = αβ/(n_k+Wβ)  +  n_dk·β/(n_k+Wβ)  +  (α+n_dk)·n_wk/(n_k+Wβ)
//!          s (smoothing)     r (doc)              q (word)
//! ```
//!
//! `s` is global and maintained incrementally, `r` touches only the
//! topics active in the current document, and `q` only the topics active
//! for the current word — for sparse counts most tokens are drawn from
//! the `q` bucket after O(doc/word non-zero topics) work. Bucket masses
//! are maintained incrementally through the [`Sampler`] hooks, which is
//! exactly the bookkeeping the original SparseLDA implementation does.

use crate::engine::gibbs::{GibbsShard, Sampler};
use crate::engine::traits::LdaParams;
use crate::util::rng::Rng;

pub struct SparseGs {
    k: usize,
    /// s-bucket per-topic contributions and total
    s_contrib: Vec<f64>,
    s_total: f64,
    /// r-bucket (current doc) contributions and total
    r_contrib: Vec<f64>,
    r_total: f64,
    /// q coefficients (α + n_dk)/(n_k + Wβ) for the current doc
    q_coef: Vec<f64>,
    /// topics with n_dk > 0 in the current doc (unsorted) + membership
    doc_topics: Vec<u32>,
    in_doc: Vec<bool>,
    cur_doc: usize,
}

impl SparseGs {
    pub fn new(k: usize) -> SparseGs {
        SparseGs {
            k,
            s_contrib: vec![0.0; k],
            s_total: 0.0,
            r_contrib: vec![0.0; k],
            r_total: 0.0,
            q_coef: vec![0.0; k],
            doc_topics: Vec::with_capacity(k),
            in_doc: vec![false; k],
            cur_doc: usize::MAX,
        }
    }

    /// Refresh the s/r/q terms of a single topic after its counts moved.
    fn refresh_topic(&mut self, s: &GibbsShard, p: &LdaParams, d: usize, t: usize) {
        let wbeta = s.w as f64 * p.beta as f64;
        let denom = s.nk[t] as f64 + wbeta;
        let alpha = p.alpha as f64;
        let beta = p.beta as f64;
        let ndk = s.ndk[d * self.k + t] as f64;

        let s_new = alpha * beta / denom;
        self.s_total += s_new - self.s_contrib[t];
        self.s_contrib[t] = s_new;

        let r_new = ndk * beta / denom;
        self.r_total += r_new - self.r_contrib[t];
        self.r_contrib[t] = r_new;

        self.q_coef[t] = (alpha + ndk) / denom;

        let active = s.ndk[d * self.k + t] > 0;
        if active && !self.in_doc[t] {
            self.in_doc[t] = true;
            self.doc_topics.push(t as u32);
        } else if !active && self.in_doc[t] {
            self.in_doc[t] = false;
            if let Some(pos) = self.doc_topics.iter().position(|&x| x == t as u32) {
                self.doc_topics.swap_remove(pos);
            }
        }
    }
}

impl Sampler for SparseGs {
    fn begin_iteration(&mut self, s: &GibbsShard, p: &LdaParams) {
        let wbeta = s.w as f64 * p.beta as f64;
        let ab = p.alpha as f64 * p.beta as f64;
        self.s_total = 0.0;
        for t in 0..self.k {
            self.s_contrib[t] = ab / (s.nk[t] as f64 + wbeta);
            self.s_total += self.s_contrib[t];
        }
        self.cur_doc = usize::MAX;
    }

    fn begin_doc(&mut self, s: &GibbsShard, p: &LdaParams, d: usize) {
        let wbeta = s.w as f64 * p.beta as f64;
        let (alpha, beta) = (p.alpha as f64, p.beta as f64);
        self.cur_doc = d;
        for t in &self.doc_topics {
            self.in_doc[*t as usize] = false;
        }
        self.doc_topics.clear();
        self.r_total = 0.0;
        for t in 0..self.k {
            let ndk = s.ndk[d * self.k + t];
            let denom = s.nk[t] as f64 + wbeta;
            let r = ndk as f64 * beta / denom;
            self.r_contrib[t] = r;
            self.r_total += r;
            self.q_coef[t] = (alpha + ndk as f64) / denom;
            if ndk > 0 {
                self.in_doc[t] = true;
                self.doc_topics.push(t as u32);
            }
        }
    }

    fn token_removed(&mut self, s: &GibbsShard, p: &LdaParams, d: usize, _w: usize, t: usize) {
        self.refresh_topic(s, p, d, t);
    }

    fn token_added(&mut self, s: &GibbsShard, p: &LdaParams, d: usize, _w: usize, t: usize) {
        self.refresh_topic(s, p, d, t);
    }

    fn sample(&mut self, s: &GibbsShard, _p: &LdaParams, d: usize, w: usize, rng: &mut Rng) -> u32 {
        debug_assert_eq!(self.cur_doc, d);
        let k = self.k;
        // q bucket: scan the word's non-zero topics
        let row = &s.nwk[w * k..(w + 1) * k];
        let mut q_total = 0f64;
        for (t, &c) in row.iter().enumerate() {
            if c > 0 {
                q_total += c as f64 * self.q_coef[t];
            }
        }
        let total = self.s_total + self.r_total + q_total;
        let u = rng.f64() * total;
        if u < q_total {
            // most tokens land here when counts are sparse
            let mut acc = 0f64;
            for (t, &c) in row.iter().enumerate() {
                if c > 0 {
                    acc += c as f64 * self.q_coef[t];
                    if u < acc {
                        return t as u32;
                    }
                }
            }
        } else if u < q_total + self.r_total {
            let target = u - q_total;
            let mut acc = 0f64;
            for &t in &self.doc_topics {
                acc += self.r_contrib[t as usize];
                if target < acc {
                    return t;
                }
            }
        } else {
            let target = u - q_total - self.r_total;
            let mut acc = 0f64;
            for t in 0..k {
                acc += self.s_contrib[t];
                if target < acc {
                    return t as u32;
                }
            }
        }
        (k - 1) as u32 // float fallthrough
    }

    fn name(&self) -> &'static str {
        "sgs"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::gibbs::test_util::*;
    use crate::engine::gibbs::PlainGs;

    #[test]
    fn sgs_matches_exact_conditional() {
        let (mut s, p, mut rng) = burned_in_shard(4, 8);
        let mut sgs = SparseGs::new(8);
        let dev = sampler_deviation(&mut s, &mut sgs, &p, &mut rng, 40_000);
        assert!(dev < 0.02, "deviation {dev}");
    }

    #[test]
    fn sgs_and_gs_reach_similar_state() {
        // run both samplers from the same init; compare topic-word masses
        let (mut s1, p, mut rng1) = burned_in_shard(5, 8);
        let (mut s2, _, mut rng2) = burned_in_shard(5, 8);
        let mut gs = PlainGs::new(8);
        let mut sgs = SparseGs::new(8);
        for _ in 0..10 {
            s1.sweep(&mut gs, &p, &mut rng1);
            s2.sweep(&mut sgs, &p, &mut rng2);
        }
        // both must keep count consistency
        assert_eq!(s1.nk.iter().sum::<u32>(), s2.nk.iter().sum::<u32>());
    }

    #[test]
    fn bucket_masses_stay_positive_and_consistent() {
        let (mut s, p, mut rng) = burned_in_shard(6, 8);
        let mut sgs = SparseGs::new(8);
        s.sweep(&mut sgs, &p, &mut rng);
        // recompute s bucket from scratch and compare with incremental
        let wbeta = s.w as f64 * p.beta as f64;
        let fresh: f64 = (0..8)
            .map(|t| p.alpha as f64 * p.beta as f64 / (s.nk[t] as f64 + wbeta))
            .sum();
        assert!((fresh - sgs.s_total).abs() < 1e-9, "{fresh} vs {}", sgs.s_total);
    }
}
