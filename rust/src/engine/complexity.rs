//! Analytic cost model — the paper's Table 2 and §3.2.2 scalability
//! analysis as a library API, so benches, tests and capacity planning all
//! use one implementation of the formulas:
//!
//! ```text
//!            computation              memory/processor             communication
//! POBP       η·λK·λW·K·W·D·T/N        K(ηWD + D)/(MN) + 2KW        λK·λW·K·W·M·N·T
//! OBP        η·λK·λW·K·W·D·T          K(ηWD + D)/M + 2KW           —
//! PGS        η′·K·W·D·T′/N            (KD + η′WD)/N + KW           N·K·W·T′
//! ```
//!
//! plus Eq. 16/17: overall(N) = A/N + B·N is minimized at N* = √(A/B).

/// Workload description (corpus + run parameters).
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub docs: f64,
    pub vocab: f64,
    pub k: f64,
    /// sparsity η = NNZ/(W·D)
    pub eta: f64,
    /// token density η′ = tokens/(W·D)
    pub eta_tokens: f64,
    /// online iterations per mini-batch (T)
    pub t_online: f64,
    /// batch iterations (T′)
    pub t_batch: f64,
    pub lambda_w: f64,
    pub lambda_k: f64,
    pub n: f64,
    /// mini-batches M (per-processor NNZ budget semantics: §4)
    pub m: f64,
}

impl Workload {
    /// The paper's PUBMED setting at K topics and N processors.
    pub fn pubmed_paper(k: f64, n: f64) -> Workload {
        let (d, w) = (8_200_000f64, 6_902f64);
        let nnz = 222_399_377f64;
        let tokens = 737_869_083f64;
        Workload {
            docs: d,
            vocab: w,
            k,
            eta: nnz / (w * d),
            eta_tokens: tokens / (w * d),
            t_online: 200.0,
            t_batch: 500.0,
            lambda_w: 0.1,
            lambda_k: 50.0 / k,
            n,
            m: (nnz / (45_000.0 * n)).ceil(),
        }
    }

    /// POBP computation cost (element updates).
    pub fn pobp_compute(&self) -> f64 {
        self.eta * self.lambda_k * self.lambda_w * self.k * self.vocab * self.docs
            * self.t_online
            / self.n
    }

    /// POBP per-processor memory (matrix elements).
    pub fn pobp_memory(&self) -> f64 {
        self.k * (self.eta * self.vocab * self.docs + self.docs) / (self.m * self.n)
            + 2.0 * self.k * self.vocab
    }

    /// POBP total communication (elements over the whole run, Eq. 6).
    pub fn pobp_comm(&self) -> f64 {
        self.lambda_k * self.lambda_w * self.k * self.vocab * self.m * self.n * self.t_online
    }

    /// PGS computation cost.
    pub fn pgs_compute(&self) -> f64 {
        self.eta_tokens * self.k * self.vocab * self.docs * self.t_batch / self.n
    }

    /// PGS per-processor memory.
    pub fn pgs_memory(&self) -> f64 {
        (self.k * self.docs + self.eta_tokens * self.vocab * self.docs) / self.n
            + self.k * self.vocab
    }

    /// PGS total communication (elements, Eq. 5 with T′).
    pub fn pgs_comm(&self) -> f64 {
        self.n * self.k * self.vocab * self.t_batch
    }

    /// Eq. 17: the N minimizing A/N + B·N for compute A and per-N comm B.
    pub fn optimal_n(compute_total: f64, comm_per_n: f64) -> f64 {
        (compute_total / comm_per_n.max(1e-300)).sqrt()
    }

    /// Eq. 16 at the optimum: 2√(A·B).
    pub fn minimal_cost(compute_total: f64, comm_per_n: f64) -> f64 {
        2.0 * (compute_total * comm_per_n).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pubmed_m_matches_paper() {
        // the paper: "the number of mini-batches on ... PUBMED ... is 19"
        let w = Workload::pubmed_paper(2000.0, 256.0);
        assert_eq!(w.m, 20.0); // ceil(222.4M / (45k*256)) — paper rounds to 19
        assert!((w.m - 19.0).abs() <= 1.0);
    }

    #[test]
    fn comm_ratio_is_orders_of_magnitude() {
        let w = Workload::pubmed_paper(2000.0, 256.0);
        let ratio = w.pobp_comm() / w.pgs_comm();
        assert!(
            ratio < 0.05,
            "POBP/PGS comm ratio {ratio} should be in the paper's 5-20% band or below"
        );
        assert!(ratio > 1e-4);
    }

    #[test]
    fn pobp_memory_constant_in_n_approximately() {
        // dominated by the 2KW global matrices
        let a = Workload::pubmed_paper(2000.0, 128.0).pobp_memory();
        let b = Workload::pubmed_paper(2000.0, 1024.0).pobp_memory();
        assert!((a - b).abs() / a < 0.1, "{a} vs {b}");
    }

    #[test]
    fn pgs_memory_shrinks_with_n() {
        let a = Workload::pubmed_paper(2000.0, 128.0).pgs_memory();
        let b = Workload::pubmed_paper(2000.0, 1024.0).pgs_memory();
        assert!(b < a / 2.0);
    }

    #[test]
    fn eq17_optimum_minimizes_eq16() {
        let (a, b) = (1e12, 3e4);
        let n_star = Workload::optimal_n(a, b);
        let cost = |n: f64| a / n + b * n;
        assert!(cost(n_star) <= cost(n_star * 2.0));
        assert!(cost(n_star) <= cost(n_star / 2.0));
        assert!((cost(n_star) - Workload::minimal_cost(a, b)).abs() / cost(n_star) < 1e-12);
    }

    #[test]
    fn insensitive_to_k_at_fixed_lambda_kk() {
        // §3.2.2: with λ_K = 50/K, POBP's comm is insensitive to K
        let c1 = Workload::pubmed_paper(500.0, 256.0).pobp_comm();
        let c2 = Workload::pubmed_paper(2000.0, 256.0).pobp_comm();
        assert!((c1 - c2).abs() / c1 < 0.05, "{c1} vs {c2}");
    }
}
