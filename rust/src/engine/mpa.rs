//! The generic synchronous MPA wrapper (Newman et al. 2009) for the Gibbs
//! family: PGS / PFGS / PSGS, plus the asynchronous YLDA mode (Ahmed et
//! al. 2012).
//!
//! Per iteration every (simulated) processor sweeps its document shard
//! against a private copy of the global topic–word counts, then the
//! leader merges the count deltas (Eq. 4),
//!
//! ```text
//! n_wk ← n_wk + Σ_n (n_wk^(n) − n_wk_snapshot)
//! ```
//!
//! and redistributes the merged table — a full K×W synchronization per
//! iteration, which is exactly the communication cost the paper's Eq. (5)
//! charges these baselines with.
//!
//! YLDA mode models the parameter-server pipeline: the same merge, but
//! communication is overlapped with computation, so the simulated
//! iteration time is `max(compute, comm)` instead of their sum. (The
//! tokenwise async staleness of the real YLDA is approximated by the
//! one-iteration-stale tables every worker samples against — the same
//! approximation AD-LDA itself makes.)

use std::sync::Mutex;

use crate::comm::{Cluster, Ledger, NetModel};
use crate::corpus::{shard_ranges, Csr};
use crate::engine::fgs::FastGs;
use crate::engine::gibbs::{GibbsShard, PlainGs, Sampler};
use crate::engine::sgs::SparseGs;
use crate::engine::traits::{IterStat, LdaParams, Model, TrainResult};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Which Gibbs variant each worker runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GsVariant {
    /// plain collapsed Gibbs (PGS)
    Plain,
    /// FastLDA bound-refined sampler (PFGS)
    Fast,
    /// SparseLDA bucket sampler (PSGS)
    Sparse,
    /// SparseLDA sampler + async parameter-server timing (YLDA)
    Ylda,
}

impl GsVariant {
    pub fn name(&self) -> &'static str {
        match self {
            GsVariant::Plain => "pgs",
            GsVariant::Fast => "pfgs",
            GsVariant::Sparse => "psgs",
            GsVariant::Ylda => "ylda",
        }
    }

    fn make_sampler(&self, k: usize) -> Box<dyn Sampler> {
        match self {
            GsVariant::Plain => Box::new(PlainGs::new(k)),
            GsVariant::Fast => Box::new(FastGs::new(k)),
            GsVariant::Sparse | GsVariant::Ylda => Box::new(SparseGs::new(k)),
        }
    }

    fn is_async(&self) -> bool {
        matches!(self, GsVariant::Ylda)
    }
}

/// MPA configuration for the baseline algorithms.
#[derive(Clone, Debug)]
pub struct MpaConfig {
    pub n_workers: usize,
    pub max_threads: usize,
    /// batch iterations T′ (paper: 500)
    pub iters: usize,
    pub net: NetModel,
    pub seed: u64,
    /// record a model snapshot every this many iterations (0 = never)
    pub snapshot_every: usize,
}

impl Default for MpaConfig {
    fn default() -> Self {
        MpaConfig {
            n_workers: 4,
            max_threads: 0,
            iters: 100,
            net: NetModel::infiniband_20gbps(),
            seed: 42,
            snapshot_every: 0,
        }
    }
}

fn model_from_counts(w: usize, k: usize, nwk: &[u32]) -> Model {
    Model { k, w, phi_wk: nwk.iter().map(|&c| c as f32).collect() }
}

/// Train LDA with a parallel Gibbs variant under the synchronous MPA.
pub fn fit_gibbs(
    corpus: &Csr,
    params: &LdaParams,
    cfg: &MpaConfig,
    variant: GsVariant,
) -> TrainResult {
    let wall = Stopwatch::new();
    let (w, k) = (corpus.w, params.k);
    let cluster = Cluster::new(cfg.n_workers, cfg.max_threads);
    let mut ledger = Ledger::new(cfg.net);
    let mut history = Vec::new();
    let mut snapshots = Vec::new();
    let mut rng = Rng::new(cfg.seed);

    let ranges = shard_ranges(corpus.docs(), cfg.n_workers);
    struct WorkerBox {
        shard: GibbsShard,
        sampler: Box<dyn Sampler>,
        rng: Rng,
    }
    let workers: Vec<Mutex<WorkerBox>> = ranges
        .iter()
        .enumerate()
        .map(|(n, rg)| {
            let mut wrng = rng.split(n as u64);
            let shard = GibbsShard::init(
                &corpus.slice_docs(rg.start, rg.end),
                k,
                &mut wrng,
            );
            Mutex::new(WorkerBox { shard, sampler: variant.make_sampler(k), rng: wrng })
        })
        .collect();

    // initial global tables = sum of the random assignments
    let mut global_nwk = vec![0u32; w * k];
    let mut global_nk = vec![0u32; k];
    for wb in &workers {
        let wb = wb.lock().unwrap();
        for (g, &v) in global_nwk.iter_mut().zip(&wb.shard.nwk) {
            *g += v;
        }
        for (g, &v) in global_nk.iter_mut().zip(&wb.shard.nk) {
            *g += v;
        }
    }

    let payload = 4 * w * k; // one u32/f32 matrix per processor per sync

    for it in 1..=cfg.iters {
        let nwk_ref = &global_nwk;
        let nk_ref = &global_nk;
        let (_, secs) = cluster.run(|n| {
            let mut wb = workers[n].lock().unwrap();
            let wb = &mut *wb;
            wb.shard.install_global(nwk_ref, nk_ref);
            wb.shard.sweep(&mut *wb.sampler, params, &mut wb.rng);
        });

        // merge deltas (Eq. 4 over integer counts)
        for wb in &workers {
            let wb = wb.lock().unwrap();
            for i in 0..w * k {
                let delta = wb.shard.nwk[i] as i64 - wb.shard.nwk_snap[i] as i64;
                global_nwk[i] = (global_nwk[i] as i64 + delta) as u32;
            }
        }
        global_nk.fill(0);
        for wi in 0..w {
            for t in 0..k {
                global_nk[t] += global_nwk[wi * k + t];
            }
        }

        if variant.is_async() {
            // parameter-server overlap: the ledger's overlap mode
            // charges max(compute, comm) per iteration while keeping
            // bytes and per-segment attribution exact — the same
            // semantics the POBP coordinator's overlap pipeline uses
            ledger.record_overlapped_iter(0, it, payload, cfg.n_workers, &secs);
        } else {
            ledger.record_compute(&secs);
            ledger.record_sync(0, it, payload, cfg.n_workers);
        }

        if cfg.snapshot_every > 0 && it % cfg.snapshot_every == 0 {
            snapshots.push((ledger.total_secs(), model_from_counts(w, k, &global_nwk)));
        }
        history.push(IterStat {
            batch: 0,
            iter: it,
            residual_per_token: f64::NAN,
            synced_pairs: w * k,
            sim_elapsed: ledger.total_secs(),
            wall_elapsed: wall.total_secs(),
        });
    }

    TrainResult {
        model: model_from_counts(w, k, &global_nwk),
        history,
        ledger,
        wall_secs: wall.total_secs(),
        snapshots,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::{generate, SynthSpec};

    fn tiny() -> Csr {
        generate(&SynthSpec::tiny(21)).corpus
    }

    fn run(variant: GsVariant, n: usize, iters: usize) -> TrainResult {
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = MpaConfig { n_workers: n, iters, ..Default::default() };
        fit_gibbs(&c, &params, &cfg, variant)
    }

    #[test]
    fn global_counts_conserved_all_variants() {
        let c = tiny();
        let tokens = c.tokens() as u32;
        for v in [GsVariant::Plain, GsVariant::Fast, GsVariant::Sparse, GsVariant::Ylda] {
            let r = run(v, 3, 3);
            let total: f64 = r.model.mass();
            assert_eq!(total as u32, tokens, "{} lost tokens", v.name());
        }
    }

    #[test]
    fn gibbs_model_beats_uniform_perplexity() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let r = run(GsVariant::Sparse, 2, 40);
        let p = crate::eval::perplexity::heldin_perplexity(&r.model, &c, &params);
        let uni = crate::eval::perplexity::heldin_perplexity(
            &Model::zeros(c.w, 8),
            &c,
            &params,
        );
        assert!(p < uni * 0.7, "psgs {p} vs uniform {uni}");
    }

    #[test]
    fn sync_payload_is_full_matrix() {
        let r = run(GsVariant::Plain, 4, 5);
        assert_eq!(r.ledger.sync_count(), 5);
        for e in &r.ledger.events {
            assert_eq!(e.payload_bytes, 4 * 200 * 8); // W=200, K=8
        }
    }

    #[test]
    fn ylda_overlaps_communication() {
        // same bytes on the wire; the async mode charges
        // max(compute, comm) per iteration — comm stays *attributed*
        // (segments and bytes exact) but the hidden fraction is
        // subtracted from the serialized total
        let sync = run(GsVariant::Sparse, 4, 5);
        let asy = run(GsVariant::Ylda, 4, 5);
        assert_eq!(
            sync.ledger.payload_bytes_total(),
            asy.ledger.payload_bytes_total()
        );
        assert!(sync.ledger.comm_secs > 0.0);
        assert_eq!(sync.ledger.overlap_saved_secs, 0.0);
        // identical payload schedule => identical modeled comm seconds
        assert!((sync.ledger.comm_secs - asy.ledger.comm_secs).abs() < 1e-15);
        let l = &asy.ledger;
        assert!(l.overlap_saved_secs > 0.0, "ylda must overlap comm");
        assert!(l.total_secs() < l.compute_secs + l.comm_secs);
        assert!(l.total_secs() + 1e-12 >= l.compute_secs.max(l.comm_secs));
        // the figures plot only the comm left exposed on the critical path
        assert!(l.exposed_comm_secs() < l.comm_secs);
        assert_eq!(sync.ledger.exposed_comm_secs(), sync.ledger.comm_secs);
    }

    #[test]
    fn snapshots_recorded_when_requested() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = MpaConfig { n_workers: 2, iters: 6, snapshot_every: 2, ..Default::default() };
        let r = fit_gibbs(&c, &params, &cfg, GsVariant::Plain);
        assert_eq!(r.snapshots.len(), 3);
        assert!(r.snapshots.windows(2).all(|w| w[0].0 <= w[1].0));
    }
}
