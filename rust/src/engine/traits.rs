//! Shared model/parameter/result types for every inference engine.

use crate::comm::Ledger;

/// LDA hyperparameters (the paper fixes α = 2/K, β = 0.01, §4).
#[derive(Clone, Copy, Debug)]
pub struct LdaParams {
    pub k: usize,
    pub alpha: f32,
    pub beta: f32,
}

impl LdaParams {
    /// Paper defaults for a given K.
    pub fn paper(k: usize) -> LdaParams {
        LdaParams { k, alpha: 2.0 / k as f32, beta: 0.01 }
    }
}

/// The learned model: global topic–word sufficient statistics φ̂,
/// stored **word-major** (`phi_wk[w * k + t]`) so the per-word topic
/// vectors the hot loops touch are contiguous.
#[derive(Clone, Debug)]
pub struct Model {
    pub k: usize,
    pub w: usize,
    pub phi_wk: Vec<f32>,
}

impl Model {
    pub fn zeros(w: usize, k: usize) -> Model {
        Model { k, w, phi_wk: vec![0.0; w * k] }
    }

    /// Per-topic totals φ̂_Σ(k) = Σ_w φ̂_w(k).
    pub fn phi_tot(&self) -> Vec<f32> {
        let mut tot = vec![0f32; self.k];
        for wi in 0..self.w {
            for (t, slot) in tot.iter_mut().enumerate() {
                *slot += self.phi_wk[wi * self.k + t];
            }
        }
        tot
    }

    /// Smoothed topic-word probability p(w | t) = (φ̂ + β)/(φ̂_Σ + Wβ).
    pub fn word_prob(&self, wi: usize, t: usize, beta: f32, phi_tot: &[f32]) -> f64 {
        (self.phi_wk[wi * self.k + t] as f64 + beta as f64)
            / (phi_tot[t] as f64 + self.w as f64 * beta as f64)
    }

    /// Top `n` words of topic `t` by φ̂ (for qualitative inspection).
    pub fn top_words(&self, t: usize, n: usize) -> Vec<(u32, f32)> {
        let col: Vec<f32> = (0..self.w).map(|wi| self.phi_wk[wi * self.k + t]).collect();
        crate::util::partial_sort::top_k_desc(&col, n)
            .into_iter()
            .map(|wi| (wi, col[wi as usize]))
            .collect()
    }

    /// Total accumulated mass (≈ tokens seen; conservation invariant).
    pub fn mass(&self) -> f64 {
        self.phi_wk.iter().map(|&v| v as f64).sum()
    }

    /// Save as a small binary file: magic, W, K (u64 LE), then the φ̂
    /// matrix as f32 LE.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"POBPMDL1")?;
        f.write_all(&(self.w as u64).to_le_bytes())?;
        f.write_all(&(self.k as u64).to_le_bytes())?;
        for &v in &self.phi_wk {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Shannon entropy (nats) of topic `t`'s smoothed word distribution —
    /// low entropy = focused topic; K·ln(W) total = uniform garbage.
    pub fn topic_entropy(&self, t: usize, beta: f32) -> f64 {
        let phi_tot = self.phi_tot();
        let mut h = 0f64;
        for wi in 0..self.w {
            let p = self.word_prob(wi, t, beta, &phi_tot);
            if p > 0.0 {
                h -= p * p.ln();
            }
        }
        h
    }

    /// Effective topics per word: exp(entropy) of each word's topic
    /// distribution, averaged over words with mass. The empirical basis
    /// of the paper's "each word may not be allocated to many topics"
    /// (§4.1) — the justification for a fixed λ_K·K.
    pub fn mean_effective_topics_per_word(&self) -> f64 {
        let mut total = 0f64;
        let mut count = 0usize;
        for wi in 0..self.w {
            let row = &self.phi_wk[wi * self.k..(wi + 1) * self.k];
            let mass: f64 = row.iter().map(|&v| v as f64).sum();
            if mass <= 0.0 {
                continue;
            }
            let mut h = 0f64;
            for &v in row {
                let p = v as f64 / mass;
                if p > 0.0 {
                    h -= p * p.ln();
                }
            }
            total += h.exp();
            count += 1;
        }
        if count == 0 {
            0.0
        } else {
            total / count as f64
        }
    }

    /// Load a model written by [`Model::save`].
    pub fn load(path: &std::path::Path) -> std::io::Result<Model> {
        use std::io::Read;
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"POBPMDL1" {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "not a POBP model file",
            ));
        }
        let mut u64buf = [0u8; 8];
        f.read_exact(&mut u64buf)?;
        let w = u64::from_le_bytes(u64buf) as usize;
        f.read_exact(&mut u64buf)?;
        let k = u64::from_le_bytes(u64buf) as usize;
        let mut data = vec![0u8; w * k * 4];
        f.read_exact(&mut data)?;
        let phi_wk = data
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(Model { k, w, phi_wk })
    }
}

/// One recorded iteration (or mini-batch iteration) of training.
#[derive(Clone, Copy, Debug)]
pub struct IterStat {
    /// mini-batch index m (0 for batch algorithms)
    pub batch: usize,
    /// iteration t within the batch / epoch for batch algorithms
    pub iter: usize,
    /// mean residual per token (BP family) or NaN (GS/VB families)
    pub residual_per_token: f64,
    /// (word, topic) pairs synchronized this iteration
    pub synced_pairs: usize,
    /// simulated elapsed seconds so far (compute max + comm)
    pub sim_elapsed: f64,
    /// real wall-clock seconds so far
    pub wall_elapsed: f64,
}

/// The outcome of a training run.
pub struct TrainResult {
    pub model: Model,
    pub history: Vec<IterStat>,
    pub ledger: Ledger,
    /// real wall-clock seconds of the whole fit
    pub wall_secs: f64,
    /// periodic model snapshots (simulated seconds, model) for
    /// perplexity-vs-time curves (Fig. 8); empty unless requested
    pub snapshots: Vec<(f64, Model)>,
}

impl TrainResult {
    /// Simulated training seconds (the Fig. 8/11 time axis).
    pub fn sim_secs(&self) -> f64 {
        self.ledger.total_secs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;

    #[test]
    fn paper_params() {
        let p = LdaParams::paper(2000);
        assert!((p.alpha - 0.001).abs() < 1e-9);
        assert_eq!(p.beta, 0.01);
    }

    #[test]
    fn model_totals_and_probs() {
        let mut m = Model::zeros(3, 2);
        // word-major: w0=[1,2], w1=[3,4], w2=[0,0]
        m.phi_wk = vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0];
        assert_eq!(m.phi_tot(), vec![4.0, 6.0]);
        assert_eq!(m.mass(), 10.0);
        let tot = m.phi_tot();
        let p: f64 = (0..3).map(|w| m.word_prob(w, 0, 0.01, &tot)).sum();
        assert!((p - 1.0).abs() < 1e-6); // smoothed probs normalize
        assert_eq!(m.top_words(1, 2), vec![(1, 4.0), (0, 2.0)]);
    }

    #[test]
    fn entropy_diagnostics() {
        let mut m = Model::zeros(4, 2);
        // topic 0: all mass on word 0; topic 1: spread evenly
        m.phi_wk[0] = 100.0;
        for wi in 0..4 {
            m.phi_wk[wi * 2 + 1] = 25.0;
        }
        let h0 = m.topic_entropy(0, 0.01);
        let h1 = m.topic_entropy(1, 0.01);
        assert!(h0 < h1, "focused topic must have lower entropy: {h0} vs {h1}");
        assert!(h1 <= (4f64).ln() + 1e-6);
        // word 0 uses both topics (but mostly topic 0); words 1-3 one topic
        let eff = m.mean_effective_topics_per_word();
        assert!((1.0..=2.0).contains(&eff), "eff topics {eff}");
    }

    #[test]
    fn train_result_sim_time() {
        let mut ledger = Ledger::new(NetModel::infiniband_20gbps());
        ledger.record_compute(&[0.25]);
        ledger.record_sync(0, 1, 1 << 20, 4);
        let r = TrainResult {
            model: Model::zeros(1, 1),
            history: vec![],
            ledger,
            wall_secs: 0.0,
            snapshots: vec![],
        };
        assert!(r.sim_secs() > 0.25);
    }
}
