//! Variational Bayes for LDA (Blei, Ng & Jordan 2003) and its MapReduce-
//! style parallel form (Mr. LDA, Zhai et al. 2012) — the paper's PVB
//! baseline.
//!
//! Batch VB alternates, per document,
//!
//! ```text
//! φ_dwk ∝ exp(ψ(γ_dk)) · exp(ψ(λ_kw) − ψ(Σ_w λ_kw))
//! γ_dk  = α + Σ_w x_dw φ_dwk
//! ```
//!
//! and globally `λ_kw = β + Σ_d x_dw φ_dwk`. The parallel form shards
//! documents; each worker accumulates its Σ_d x·φ contribution and the
//! leader allreduces the *float* λ statistics every iteration — two K×W
//! float matrices on the wire (push the new statistics, pull the merged
//! exp-digamma table), which is the "PVB communicates ~2× the GS family"
//! observation of the paper's Fig. 10. PVB is exactly batch VB for any N
//! (the paper: "PVB is able to produce exactly the same result with that
//! of batch VB").

use std::sync::Mutex;

use crate::comm::{Cluster, Ledger, NetModel};
use crate::corpus::{shard_ranges, Csr};
use crate::engine::mpa::MpaConfig;
use crate::engine::traits::{IterStat, LdaParams, Model, TrainResult};
use crate::util::math::digamma;
use crate::util::timer::Stopwatch;

/// Per-document inner loops each outer iteration (Blei's fixed-point).
const INNER_ITERS: usize = 8;

struct VbShard {
    data: Csr,
    /// γ, docs × K
    gamma: Vec<f64>,
    /// Σ_d x·φ accumulated this iteration, W × K word-major
    sstats: Vec<f64>,
}

impl VbShard {
    fn new(data: Csr, k: usize, alpha: f64) -> VbShard {
        let docs = data.docs();
        let w = data.w;
        VbShard {
            data,
            gamma: vec![alpha + 1.0; docs * k],
            sstats: vec![0.0; w * k],
        }
    }

    /// One outer iteration over the shard against the fixed global
    /// exp(E[log β]) table (word-major W × K). Fills `sstats`.
    fn sweep(&mut self, exp_elog_beta: &[f64], p: &LdaParams) {
        let k = p.k;
        let alpha = p.alpha as f64;
        self.sstats.fill(0.0);
        let mut exp_elog_theta = vec![0f64; k];
        let mut phi_norm = vec![0f64; 0];
        for d in 0..self.data.docs() {
            let g = &mut self.gamma[d * k..(d + 1) * k];
            let (ws, vs) = self.data.row(d);
            if ws.is_empty() {
                continue;
            }
            for _ in 0..INNER_ITERS {
                let gsum: f64 = g.iter().sum();
                let dig_sum = digamma(gsum);
                for t in 0..k {
                    exp_elog_theta[t] = (digamma(g[t]) - dig_sum).exp();
                }
                // γ = α + Σ_w x · φ with φ ∝ expElogTheta ⊙ expElogBeta
                phi_norm.clear();
                for (&wi, &x) in ws.iter().zip(vs) {
                    let row = &exp_elog_beta[wi as usize * k..(wi as usize + 1) * k];
                    let z: f64 = (0..k).map(|t| exp_elog_theta[t] * row[t]).sum();
                    phi_norm.push(x as f64 / z.max(1e-300));
                }
                for t in 0..k {
                    let mut acc = 0f64;
                    for (j, &wi) in ws.iter().enumerate() {
                        acc += phi_norm[j]
                            * exp_elog_theta[t]
                            * exp_elog_beta[wi as usize * k + t];
                    }
                    g[t] = alpha + acc;
                }
            }
            // final φ accumulated into the topic statistics
            let gsum: f64 = g.iter().sum();
            let dig_sum = digamma(gsum);
            for t in 0..k {
                exp_elog_theta[t] = (digamma(g[t]) - dig_sum).exp();
            }
            for (&wi, &x) in ws.iter().zip(vs) {
                let row = &exp_elog_beta[wi as usize * k..(wi as usize + 1) * k];
                let z: f64 = (0..k).map(|t| exp_elog_theta[t] * row[t]).sum();
                let scale = x as f64 / z.max(1e-300);
                let out = &mut self.sstats[wi as usize * k..(wi as usize + 1) * k];
                for t in 0..k {
                    out[t] += scale * exp_elog_theta[t] * row[t];
                }
            }
        }
    }
}

/// Compute exp(ψ(λ) − ψ(Σ_w λ)) word-major from λ (word-major).
fn exp_elog_beta_from_lambda(lambda_wk: &[f64], w: usize, k: usize) -> Vec<f64> {
    let mut col_sum = vec![0f64; k];
    for row in lambda_wk.chunks_exact(k) {
        for (t, &v) in row.iter().enumerate() {
            col_sum[t] += v;
        }
    }
    let dig_sum: Vec<f64> = col_sum.iter().map(|&s| digamma(s)).collect();
    let mut out = vec![0f64; w * k];
    for wi in 0..w {
        for t in 0..k {
            out[wi * k + t] = (digamma(lambda_wk[wi * k + t]) - dig_sum[t]).exp();
        }
    }
    out
}

/// Train LDA with (parallel) variational Bayes.
pub fn fit_vb(corpus: &Csr, params: &LdaParams, cfg: &MpaConfig) -> TrainResult {
    let wall = Stopwatch::new();
    let (w, k) = (corpus.w, params.k);
    let cluster = Cluster::new(cfg.n_workers, cfg.max_threads);
    let mut ledger = Ledger::new(cfg.net);
    let mut history = Vec::new();
    let mut snapshots = Vec::new();

    let ranges = shard_ranges(corpus.docs(), cfg.n_workers);
    let shards: Vec<Mutex<VbShard>> = ranges
        .iter()
        .map(|rg| {
            Mutex::new(VbShard::new(
                corpus.slice_docs(rg.start, rg.end),
                k,
                params.alpha as f64,
            ))
        })
        .collect();

    // λ init: seeded slightly-off-uniform so topics break symmetry
    // deterministically
    let mut lambda = vec![0f64; w * k];
    let mut rng = crate::util::rng::Rng::new(cfg.seed);
    for v in lambda.iter_mut() {
        *v = params.beta as f64 + 0.01 + 0.1 * rng.f64();
    }

    // PVB ships two K×W float matrices per sync (push sstats, pull the
    // merged table) — the ~2× GS wire cost the paper reports.
    let payload = 2 * 4 * w * k;

    for it in 1..=cfg.iters {
        let eelb = exp_elog_beta_from_lambda(&lambda, w, k);
        let eelb_ref = &eelb;
        let (_, secs) = cluster.run(|n| {
            let mut shard = shards[n].lock().unwrap();
            shard.sweep(eelb_ref, params);
        });
        ledger.record_compute(&secs);

        // allreduce λ = β + Σ_n sstats_n
        for v in lambda.iter_mut() {
            *v = params.beta as f64;
        }
        for shard in &shards {
            let shard = shard.lock().unwrap();
            for (l, &s) in lambda.iter_mut().zip(&shard.sstats) {
                *l += s;
            }
        }
        ledger.record_sync(0, it, payload, cfg.n_workers);

        if cfg.snapshot_every > 0 && it % cfg.snapshot_every == 0 {
            snapshots.push((ledger.total_secs(), model_from_lambda(&lambda, w, k, params)));
        }
        history.push(IterStat {
            batch: 0,
            iter: it,
            residual_per_token: f64::NAN,
            synced_pairs: w * k,
            sim_elapsed: ledger.total_secs(),
            wall_elapsed: wall.total_secs(),
        });
    }

    TrainResult {
        model: model_from_lambda(&lambda, w, k, params),
        history,
        ledger,
        wall_secs: wall.total_secs(),
        snapshots,
    }
}

/// Convert λ to the common sufficient-statistics model (φ̂ = λ − β, so the
/// shared smoothed-probability evaluation path applies unchanged).
fn model_from_lambda(lambda: &[f64], w: usize, k: usize, params: &LdaParams) -> Model {
    Model {
        k,
        w,
        phi_wk: lambda
            .iter()
            .map(|&l| (l - params.beta as f64).max(0.0) as f32)
            .collect(),
    }
}

/// Single-processor batch VB (the PVB N=1 special case).
pub fn fit_vb_single(corpus: &Csr, params: &LdaParams, iters: usize, seed: u64) -> TrainResult {
    fit_vb(
        corpus,
        params,
        &MpaConfig {
            n_workers: 1,
            iters,
            seed,
            net: NetModel::infiniband_20gbps(),
            ..Default::default()
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::{generate, SynthSpec};

    fn tiny() -> Csr {
        generate(&SynthSpec::tiny(23)).corpus
    }

    #[test]
    fn vb_learns_structure() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let r = fit_vb_single(&c, &params, 15, 1);
        let p = crate::eval::perplexity::heldin_perplexity(&r.model, &c, &params);
        let uni = crate::eval::perplexity::heldin_perplexity(
            &Model::zeros(c.w, 8),
            &c,
            &params,
        );
        assert!(p < uni * 0.8, "vb {p} vs uniform {uni}");
    }

    #[test]
    fn pvb_equals_batch_vb_exactly() {
        // the paper's key PVB claim: identical result for any N
        let c = tiny();
        let params = LdaParams::paper(4);
        let r1 = fit_vb(&c, &params, &MpaConfig { n_workers: 1, iters: 5, ..Default::default() });
        let r3 = fit_vb(&c, &params, &MpaConfig { n_workers: 3, iters: 5, ..Default::default() });
        for (a, b) in r1.model.phi_wk.iter().zip(&r3.model.phi_wk) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn pvb_payload_double_of_gs() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = MpaConfig { n_workers: 2, iters: 3, ..Default::default() };
        let vb = fit_vb(&c, &params, &cfg);
        let gs = crate::engine::mpa::fit_gibbs(
            &c, &params, &cfg, crate::engine::mpa::GsVariant::Plain,
        );
        assert_eq!(
            vb.ledger.payload_bytes_total(),
            2 * gs.ledger.payload_bytes_total()
        );
    }

    #[test]
    fn gamma_stays_positive() {
        let c = tiny();
        let params = LdaParams::paper(4);
        let shards = VbShard::new(c.clone(), 4, params.alpha as f64);
        let mut s = shards;
        let lambda = vec![0.5f64; c.w * 4];
        let eelb = exp_elog_beta_from_lambda(&lambda, c.w, 4);
        s.sweep(&eelb, &params);
        assert!(s.gamma.iter().all(|&g| g > 0.0));
        // sstats mass == token mass
        let mass: f64 = s.sstats.iter().sum();
        assert!((mass - c.tokens()).abs() < 1e-6 * c.tokens());
    }
}
