//! The native sparse BP worker: per-shard message passing (Eq. 1–3, 7–8).
//!
//! One `ShardBp` is the state a single (simulated) processor holds for its
//! document shard of the current mini-batch: per-non-zero messages μ, the
//! local document–topic statistics θ̂, the local gradient Δφ̂ (Eq. 15) and
//! the fresh residual matrix r (Eq. 7–8). The sweep consumes the *global*
//! φ̂ synchronized at the previous iteration (frozen during the sweep —
//! synchronous MPA semantics, Fig. 1) and updates only the power
//! (word, topic) pairs of the current [`Selection`].
//!
//! The masked update is mass-preserving within the selection (see
//! `python/compile/kernels/ref.py` for the shared contract): un-selected
//! messages stay bitwise-frozen, so Δφ̂ and r change only on selected
//! pairs and subset-only synchronization is exact.
//!
//! # Doc-parallel sweep engine
//!
//! The sweep is Jacobi: every entry update reads the frozen global φ̂ and
//! the θ̂ snapshot of its *own* document only, so documents are
//! independent except for the accumulate-only Δφ̂/r word rows. That makes
//! the shard sweep doc-parallel: [`ShardBp::sweep_parallel`] partitions
//! the documents into fixed blocks (boundaries derived from NNZ counts at
//! init — *not* from the core count, so block structure is
//! machine-independent), sweeps blocks concurrently on the [`Cluster`]
//! thread pool ([`Cluster::run_on_doc_blocks`]), and routes each block's
//! Δφ̂/r contributions into per-block scratch accumulators (one compact
//! row per distinct word in the block). A deterministic merge then folds
//! the scratch rows into the shard matrices **in ascending block order
//! per word row**, so the floating-point accumulation order is a pure
//! function of the data: results are bitwise reproducible on any machine
//! at any thread count. μ, θ̂ and the per-document f64 residuals are
//! bitwise identical to the serial sweep (documents own their rows; the
//! residual total sums the per-doc partials in doc order); Δφ̂/r differ
//! from the serial path only in summation association, bounded by the
//! equivalence tests (`rust/tests/sweep_equiv.rs`) against the verbatim
//! pre-fusion kernel kept as [`ShardBp::sweep_reference`] — the same
//! oracle pattern the allreduce refactor used (`serial_reference_step`).
//!
//! # Scheduled-parallel sweep (ABP t ≥ 2)
//!
//! Residual-ordered document schedules are non-contiguous, so the fixed
//! block split above does not apply. [`ShardBp::sweep_docs_parallel`]
//! closes that gap: a per-iteration
//! [`DocSchedule`](crate::sched::DocSchedule) permutes the scheduled
//! docs into sorted order and cuts NNZ-balanced, doc-granular blocks
//! (boundaries from *scheduled* NNZ counts only), which makes every
//! block a plain contiguous span of the shard matrices; Δφ̂/r route
//! through per-sweep scratch rows merged in ascending block order, the
//! same deterministic protocol as above. That retires the last serial
//! sweep path in the system — see the method's contract docs.
//!
//! The per-entry kernel itself ([`fused_update`]) is fused and
//! SIMD-friendly: the score, mass and delta phases run as separate
//! contiguous lane loops (pulling the mass reductions out of the score
//! loop lets the divide vectorize), α/β/Wβ are hoisted per sweep into
//! [`SweepCtx`], and the subset path reads packed per-word φ̂/φ̂_Σ gathers
//! built once per sweep instead of strided per-entry gathers.

use std::time::Instant;

use crate::comm::allreduce::ReduceSource;
use crate::comm::Cluster;
use crate::corpus::Csr;
use crate::engine::simd::{self, AlignedF32};
use crate::engine::traits::LdaParams;
use crate::sched::{DocSchedule, PowerSet};
use crate::util::rng::Rng;

/// The iteration schedule in worker-friendly form: a word membership
/// bitmap plus per-word topic lists (empty for un-selected words).
#[derive(Clone, Debug)]
pub struct Selection {
    pub full: bool,
    pub word_sel: Vec<bool>,
    /// offsets into `topic_ids`, len = W + 1
    pub topic_off: Vec<u32>,
    pub topic_ids: Vec<u32>,
}

impl Selection {
    pub fn full(w: usize) -> Selection {
        Selection {
            full: true,
            word_sel: vec![true; w],
            topic_off: vec![0; w + 1],
            topic_ids: Vec::new(),
        }
    }

    pub fn from_power(ps: &PowerSet, w: usize) -> Selection {
        let mut word_sel = vec![false; w];
        let mut per_word: Vec<&[u32]> = vec![&[]; w];
        for (i, &wi) in ps.words.iter().enumerate() {
            word_sel[wi as usize] = true;
            per_word[wi as usize] = &ps.topics[i];
        }
        let mut topic_off = Vec::with_capacity(w + 1);
        let mut topic_ids = Vec::with_capacity(ps.pairs());
        topic_off.push(0u32);
        for wi in 0..w {
            let start = topic_ids.len();
            topic_ids.extend_from_slice(per_word[wi]);
            // ascending topic order: better cache-line reuse in the K-row
            // gathers and the same accumulation order as the L2 masked
            // update (which is element-wise over ascending k)
            topic_ids[start..].sort_unstable();
            topic_off.push(topic_ids.len() as u32);
        }
        Selection { full: false, word_sel, topic_off, topic_ids }
    }

    /// Topic list of word `wi` (empty when un-selected; `None` = all K).
    #[inline]
    pub fn topics_of(&self, wi: usize) -> Option<&[u32]> {
        if self.full {
            None
        } else {
            Some(
                &self.topic_ids
                    [self.topic_off[wi] as usize..self.topic_off[wi + 1] as usize],
            )
        }
    }
}

/// Doc-block partition targets for the parallel sweep: blocks are cut
/// when their NNZ count reaches `max(shard_nnz / DOC_BLOCK_MAX,
/// DOC_BLOCK_MIN_NNZ)`. Both constants are data-only (no core counts), so
/// the block structure — and therefore the merged floating-point order —
/// is identical on every machine.
const DOC_BLOCK_MAX: usize = 32;
const DOC_BLOCK_MIN_NNZ: usize = 1024;

/// Per-phase timing of one [`ShardBp::sweep_parallel`] call.
#[derive(Clone, Debug, Default)]
pub struct SweepTiming {
    /// measured seconds of each doc block, block order
    pub block_secs: Vec<f64>,
    /// measured seconds of the deterministic scratch merge
    pub merge_secs: f64,
}

impl SweepTiming {
    /// Critical-path estimate of the sweep on `budget` dedicated threads:
    /// the LPT lower bound `max(longest block, total / budget)` plus the
    /// merge. The coordinator charges this instead of its own wall clock,
    /// which over-counts queueing when several logical workers contend
    /// for the same OS-thread pool.
    pub fn critical_path_secs(&self, budget: usize) -> f64 {
        let total: f64 = self.block_secs.iter().sum();
        let longest = self.block_secs.iter().cloned().fold(0.0, f64::max);
        longest.max(total / budget.max(1) as f64) + self.merge_secs
    }
}

/// A read-only view of the frozen global φ̂ the sweep kernels consume:
/// either the dense replicated `W·K` matrix, or the sharded storage
/// mode's per-owner row-aligned slices read in place (no worker ever
/// concatenates them). Rows are whole in either representation —
/// `OwnerSlices::row_aligned` guarantees a word's topic row never
/// straddles two slices — so [`PhiView::row`] hands the kernel the
/// identical bits either way.
#[derive(Clone, Copy)]
pub enum PhiView<'a> {
    /// the replicated dense `W·K` matrix, row-major
    Dense(&'a [f32]),
    /// row-aligned owner slices: row `w` lives in
    /// `parts[w / rows_per]` at local row `w % rows_per`
    Slices {
        /// per-owner φ̂ slices, owner order
        parts: &'a [&'a [f32]],
        /// φ̂ rows per owner slice (the partition stride)
        rows_per: usize,
    },
}

impl<'a> PhiView<'a> {
    /// Word `wi`'s topic row (len `k`), identical bits in either mode.
    #[inline]
    pub fn row(&self, wi: usize, k: usize) -> &'a [f32] {
        match *self {
            PhiView::Dense(d) => &d[wi * k..(wi + 1) * k],
            PhiView::Slices { parts, rows_per } => {
                let lo = (wi % rows_per) * k;
                &parts[wi / rows_per][lo..lo + k]
            }
        }
    }
}

/// Per-sweep frozen context shared by every document: the global φ̂ and
/// its topic totals, the selection, hoisted α/β/Wβ, and — for subset
/// sweeps — the packed per-word φ̂/φ̂_Σ gathers at each selected word's
/// topic list (`Selection::topic_off` layout), built once per sweep so
/// the kernel's subset lanes read contiguous memory.
struct SweepCtx<'a> {
    k: usize,
    phi: PhiView<'a>,
    phi_tot: &'a [f32],
    sel: &'a Selection,
    packed_phi: Vec<f32>,
    packed_tot: Vec<f32>,
    alpha: f32,
    beta: f32,
    wbeta: f32,
    update_phi: bool,
    /// run the explicit-SIMD lanes of [`fused_update`]? Resolved once per
    /// sweep from [`simd::active_kernel`] (Contract 7: both kernels are
    /// bitwise equal, so this flag can never change results).
    wide: bool,
}

impl<'a> SweepCtx<'a> {
    fn new(
        w: usize,
        k: usize,
        phi_wk: &'a [f32],
        phi_tot: &'a [f32],
        sel: &'a Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> SweepCtx<'a> {
        debug_assert_eq!(phi_wk.len(), w * k);
        SweepCtx::new_view(w, k, PhiView::Dense(phi_wk), phi_tot, sel, p, update_phi)
    }

    fn new_view(
        w: usize,
        k: usize,
        phi: PhiView<'a>,
        phi_tot: &'a [f32],
        sel: &'a Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> SweepCtx<'a> {
        let (mut packed_phi, mut packed_tot) = (Vec::new(), Vec::new());
        if !sel.full {
            let pairs = sel.topic_ids.len();
            packed_phi = Vec::with_capacity(pairs);
            packed_tot = Vec::with_capacity(pairs);
            for wi in 0..w {
                let lo = sel.topic_off[wi] as usize;
                let hi = sel.topic_off[wi + 1] as usize;
                if lo == hi {
                    continue;
                }
                let row = phi.row(wi, k);
                for &t in &sel.topic_ids[lo..hi] {
                    packed_phi.push(row[t as usize]);
                    packed_tot.push(phi_tot[t as usize]);
                }
            }
        }
        SweepCtx {
            k,
            phi,
            phi_tot,
            sel,
            packed_phi,
            packed_tot,
            alpha: p.alpha,
            beta: p.beta,
            wbeta: w as f32 * p.beta,
            update_phi,
            wide: simd::active_kernel() == simd::KernelKind::Wide,
        }
    }
}

/// Reusable per-sweep tables of the **scheduled**-parallel sweep
/// ([`ShardBp::sweep_docs_parallel`]). Unlike the t = 1 engine's block
/// tables — fixed at init because every sweep covers every doc — the
/// scheduled tables depend on the iteration's [`DocSchedule`], so they
/// are rebuilt per sweep (O(scheduled NNZ), amortized against the K-wide
/// kernel work) into these buffers, which only ever grow: the
/// O(NNZ + W) index storage never reallocates across iterations.
#[derive(Debug, Default)]
struct SchedScratch {
    /// block-local scratch row of each scheduled non-zero entry (global
    /// nnz-indexed; only scheduled, selected entries are written — and
    /// only those are read back — each sweep)
    entry_row: Vec<u32>,
    /// word of each scratch row, block-grouped (len = Σ_b distinct
    /// *selected* words of block b this sweep)
    row_word: Vec<u32>,
    /// per-block scratch-row offsets, len = blocks + 1
    block_row_off: Vec<u32>,
    /// per-word stamp / block-local row for the distinct-word build;
    /// `gen` advances once per block so the stamps never need clearing
    stamp: Vec<u64>,
    local_of: Vec<u32>,
    gen: u64,
    /// scratch rows of word w: `merge_rows[merge_ptr[w]..merge_ptr[w+1]]`,
    /// ascending (= block order) — the deterministic merge order
    merge_ptr: Vec<u32>,
    merge_rows: Vec<u32>,
    merge_cursor: Vec<u32>,
    /// merge-task word-range boundaries, balanced by scratch-row count
    merge_bounds: Vec<u32>,
    /// per-block Δφ̂ / r accumulators (scratch-row-major, `simd::kpad`
    /// padded rows in 64-byte-aligned storage), grown on demand
    sdphi: AlignedF32,
    sr: AlignedF32,
    /// per-doc residuals of the sweep, sorted-schedule order
    resid_sorted: Vec<f64>,
    /// fixed-block reuse path ([`ShardBp::sweep_docs_parallel_fixed`]):
    /// position cuts of the sorted schedule at the init-time block
    /// boundaries (len = fixed blocks + 1)
    fixed_cut: Vec<u32>,
    /// per-sweep liveness of each *fixed* scratch row: rows of fixed
    /// blocks with no scheduled docs stay dirty from earlier sweeps and
    /// must not enter the merge
    row_live: Vec<bool>,
}

/// Per-traversal lane scratch: score lanes plus the packed μ/θ̂ gathers
/// of the subset path. One per serial sweep, one per doc block. The
/// buffers are 64-byte aligned and cache-line padded so two blocks'
/// lane scratch never shares a line (they are written on every entry).
struct LaneBuf {
    scores: AlignedF32,
    gmu: AlignedF32,
    gth: AlignedF32,
}

impl LaneBuf {
    fn new(k: usize) -> LaneBuf {
        let n = simd::kpad(k);
        LaneBuf {
            scores: AlignedF32::zeroed(n),
            gmu: AlignedF32::zeroed(n),
            gth: AlignedF32::zeroed(n),
        }
    }
}

/// The fused Eq. 1/7 kernel for one non-zero entry (d, w), operating on
/// caller-provided rows so the serial, inverted and doc-parallel paths
/// all share it. Per-entry arithmetic is bit-for-bit the reference
/// kernel's ([`ShardBp::sweep_doc_reference`]): every accumulator sees
/// the same operations in the same order, only the loop *structure*
/// changed (mass reductions pulled out of the elementwise lane loops so
/// the divides and deltas vectorize).
#[allow(clippy::too_many_arguments)]
#[inline]
fn fused_update(
    ctx: &SweepCtx<'_>,
    wi: usize,
    x: f32,
    mu: &mut [f32],
    th_old: &[f32],
    th: &mut [f32],
    dphi_row: Option<&mut [f32]>,
    r_row: &mut [f32],
    lanes: &mut LaneBuf,
) -> f64 {
    let k = ctx.k;
    let (alpha, beta, wbeta) = (ctx.alpha, ctx.beta, ctx.wbeta);
    match ctx.sel.topics_of(wi) {
        None => {
            let mu = &mut mu[..k];
            let th = &mut th[..k];
            let th_old = &th_old[..k];
            let phi_row = ctx.phi.row(wi, k);
            let phi_tot = &ctx.phi_tot[..k];
            let scores = &mut lanes.scores[..k];
            // score phase: pure elementwise lanes. The wide kernel
            // (`--features simd`) produces identical bits per lane —
            // Contract 7 — so the dispatch cannot change results.
            if ctx.wide {
                simd::score_phase(x, mu, th_old, phi_row, phi_tot, alpha, beta, wbeta, scores);
            } else {
                for ((((s, &m), &to), &ph), &pt) in scores
                    .iter_mut()
                    .zip(mu.iter())
                    .zip(th_old)
                    .zip(phi_row)
                    .zip(phi_tot)
                {
                    let c = x * m;
                    let th_m = (to - c).max(0.0) + alpha;
                    let ph_m = (ph - c).max(0.0) + beta;
                    let den = (pt - c).max(0.0) + wbeta;
                    *s = th_m * ph_m / den.max(1e-30);
                }
            }
            // the horizontal mass/residual reductions stay scalar
            // sequential left-folds over the stored lane buffers under
            // *both* kernels — the fixed reduction order of Contract 7
            let mass_new: f32 = scores.iter().sum();
            let mass_old: f32 = mu.iter().sum();
            if mass_new <= 0.0 || mass_old <= 0.0 {
                return 0.0; // nothing to redistribute
            }
            let scale = mass_old / mass_new;
            // delta phase: the rr values land back in the score lanes so
            // the residual reduction stays out of the SIMD loop
            if ctx.wide {
                simd::delta_phase(x, scale, scores, mu, th, dphi_row, r_row);
            } else if let Some(dp) = dphi_row {
                let dp = &mut dp[..k];
                for ((((s, m), t_), d_), r_) in scores
                    .iter_mut()
                    .zip(mu.iter_mut())
                    .zip(th.iter_mut())
                    .zip(dp.iter_mut())
                    .zip(r_row.iter_mut())
                {
                    let new = *s * scale;
                    let dm = new - *m;
                    *m = new;
                    *t_ += x * dm;
                    *d_ += x * dm;
                    let rr = x * dm.abs();
                    *r_ += rr;
                    *s = rr;
                }
            } else {
                for (((s, m), t_), r_) in scores
                    .iter_mut()
                    .zip(mu.iter_mut())
                    .zip(th.iter_mut())
                    .zip(r_row.iter_mut())
                {
                    let new = *s * scale;
                    let dm = new - *m;
                    *m = new;
                    *t_ += x * dm;
                    let rr = x * dm.abs();
                    *r_ += rr;
                    *s = rr;
                }
            }
            let rsum: f32 = scores.iter().sum();
            rsum as f64
        }
        Some(ts) => {
            let m_lanes = ts.len();
            if m_lanes == 0 {
                return 0.0;
            }
            let o0 = ctx.sel.topic_off[wi] as usize;
            let o1 = ctx.sel.topic_off[wi + 1] as usize;
            let pph = &ctx.packed_phi[o0..o1];
            let ptot = &ctx.packed_tot[o0..o1];
            let gmu = &mut lanes.gmu[..m_lanes];
            let gth = &mut lanes.gth[..m_lanes];
            for ((g, h), &t) in gmu.iter_mut().zip(gth.iter_mut()).zip(ts) {
                let t = t as usize;
                *g = mu[t];
                *h = th_old[t];
            }
            let scores = &mut lanes.scores[..m_lanes];
            // packed score phase: same wide lanes as the dense arm over
            // the contiguous gathers (Contract 7 — identical bits); the
            // scatter below stays scalar in ascending-`ts` order
            if ctx.wide {
                simd::score_phase(x, gmu, gth, pph, ptot, alpha, beta, wbeta, scores);
            } else {
                for ((((s, &gm), &gt), &ph), &pt) in scores
                    .iter_mut()
                    .zip(gmu.iter())
                    .zip(gth.iter())
                    .zip(pph)
                    .zip(ptot)
                {
                    let c = x * gm;
                    let th_m = (gt - c).max(0.0) + alpha;
                    let ph_m = (ph - c).max(0.0) + beta;
                    let den = (pt - c).max(0.0) + wbeta;
                    *s = th_m * ph_m / den.max(1e-30);
                }
            }
            let mass_new: f32 = scores.iter().sum();
            let mass_old: f32 = gmu.iter().sum();
            if mass_new <= 0.0 || mass_old <= 0.0 {
                return 0.0;
            }
            let scale = mass_old / mass_new;
            let mut resid_sum = 0f64;
            if let Some(dp) = dphi_row {
                for ((&s, &gm), &t) in scores.iter().zip(gmu.iter()).zip(ts) {
                    let t = t as usize;
                    let new = s * scale;
                    let dm = new - gm;
                    mu[t] = new;
                    th[t] += x * dm;
                    dp[t] += x * dm;
                    let rr = x * dm.abs();
                    r_row[t] += rr;
                    resid_sum += rr as f64;
                }
            } else {
                for ((&s, &gm), &t) in scores.iter().zip(gmu.iter()).zip(ts) {
                    let t = t as usize;
                    let new = s * scale;
                    let dm = new - gm;
                    mu[t] = new;
                    th[t] += x * dm;
                    let rr = x * dm.abs();
                    r_row[t] += rr;
                    resid_sum += rr as f64;
                }
            }
            resid_sum
        }
    }
}

/// Sweep one document against a prepared [`SweepCtx`]: snapshot its θ̂
/// row (Jacobi), then run the fused kernel over its selected entries.
/// Free function over explicit matrices so the serial and doc-parallel
/// paths share it.
#[allow(clippy::too_many_arguments)]
fn sweep_doc_ctx(
    data: &Csr,
    ctx: &SweepCtx<'_>,
    d: usize,
    mu: &mut [f32],
    theta: &mut [f32],
    theta_old: &mut [f32],
    dphi: &mut [f32],
    r: &mut [f32],
    lanes: &mut LaneBuf,
) -> f64 {
    let k = ctx.k;
    theta_old[d * k..(d + 1) * k].copy_from_slice(&theta[d * k..(d + 1) * k]);
    let mut resid = 0f64;
    for idx in data.row_range(d) {
        let wi = data.col[idx] as usize;
        if !ctx.sel.word_sel[wi] {
            continue;
        }
        let dphi_row = if ctx.update_phi {
            Some(&mut dphi[wi * k..(wi + 1) * k])
        } else {
            None
        };
        resid += fused_update(
            ctx,
            wi,
            data.val[idx],
            &mut mu[idx * k..(idx + 1) * k],
            &theta_old[d * k..(d + 1) * k],
            &mut theta[d * k..(d + 1) * k],
            dphi_row,
            &mut r[wi * k..(wi + 1) * k],
            lanes,
        );
    }
    resid
}

/// Per-worker BP state over a document shard.
pub struct ShardBp {
    pub k: usize,
    pub data: Csr,
    /// messages, nnz × K (row per non-zero, topic-contiguous)
    pub mu: Vec<f32>,
    /// local θ̂, docs × K
    pub theta: Vec<f32>,
    /// local gradient Δφ̂ = Σ_d x·μ over this shard, W × K word-major
    pub dphi: Vec<f32>,
    /// fresh residuals of the last sweep, W × K word-major
    pub r: Vec<f32>,
    /// scratch score buffer (K) of the reference kernel
    scratch: Vec<f32>,
    /// θ̂ snapshot read during a sweep (Jacobi semantics, see `sweep`)
    theta_old: Vec<f32>,
    /// CSC-style inverted index: non-zero entries grouped by word —
    /// offsets (W+1) into `by_word_idx` (§Perf: lets subset sweeps touch
    /// only the power words' entries instead of scanning all NNZ)
    by_word_ptr: Vec<u32>,
    by_word_idx: Vec<u32>,
    /// document of each non-zero entry (for the inverted traversal)
    nnz_doc: Vec<u32>,
    // --- doc-parallel sweep engine (layout fixed at init; module doc) ---
    /// doc-block boundaries (docs of block b: `off[b]..off[b+1]`);
    /// derived from NNZ counts only, so machine-independent
    block_doc_off: Vec<u32>,
    /// per-block scratch-row offsets (block b owns scratch rows
    /// `off[b]..off[b+1]`; one row per distinct word in the block)
    block_row_off: Vec<u32>,
    /// word of each scratch row (len = Σ_b distinct words of block b)
    row_word: Vec<u32>,
    /// block-local scratch row of each non-zero entry
    nnz_row: Vec<u32>,
    /// scratch rows of word w: `merge_rows[merge_ptr[w]..merge_ptr[w+1]]`,
    /// ascending == block order — the deterministic merge order
    merge_ptr: Vec<u32>,
    merge_rows: Vec<u32>,
    /// merge-task word-range boundaries (≈ one range per block, balanced
    /// by scratch-row count), fixed at init
    merge_bounds: Vec<u32>,
    /// per-block Δφ̂ / r accumulators (scratch-row-major, S × kpad(K) —
    /// rows cache-line padded and 64-byte aligned so concurrent blocks
    /// never share a line; `simd::kpad`), sized on the first parallel
    /// sweep
    scratch_dphi: AlignedF32,
    scratch_r: AlignedF32,
    /// per-doc residuals of the last whole-shard parallel sweep
    resid_doc: Vec<f64>,
    /// reusable tables of the scheduled-parallel sweep (per-sweep build)
    sched: SchedScratch,
}

impl ShardBp {
    /// Random message initialization (Fig. 4 lines 3–5).
    pub fn init(data: Csr, k: usize, rng: &mut Rng) -> ShardBp {
        let nnz = data.nnz();
        let docs = data.docs();
        let w = data.w;
        let mut mu = vec![0f32; nnz * k];
        for row in mu.chunks_exact_mut(k) {
            let mut sum = 0f32;
            for v in row.iter_mut() {
                *v = rng.f32() + 0.1;
                sum += *v;
            }
            let inv = 1.0 / sum;
            row.iter_mut().for_each(|v| *v *= inv);
        }
        // inverted index: counting sort of nnz entries by word
        let mut by_word_ptr = vec![0u32; w + 1];
        for &wid in &data.col {
            by_word_ptr[wid as usize + 1] += 1;
        }
        for i in 0..w {
            by_word_ptr[i + 1] += by_word_ptr[i];
        }
        let mut cursor = by_word_ptr.clone();
        let mut by_word_idx = vec![0u32; nnz];
        let mut nnz_doc = vec![0u32; nnz];
        for d in 0..docs {
            for idx in data.row_range(d) {
                let wid = data.col[idx] as usize;
                by_word_idx[cursor[wid] as usize] = idx as u32;
                cursor[wid] += 1;
                nnz_doc[idx] = d as u32;
            }
        }

        // --- doc-block partition for the parallel sweep: cut blocks on
        //     cumulative NNZ so block structure is machine-independent ---
        let target = (nnz.div_ceil(DOC_BLOCK_MAX)).max(DOC_BLOCK_MIN_NNZ);
        let mut block_doc_off = vec![0u32];
        let mut acc = 0usize;
        for d in 0..docs {
            acc += data.row_range(d).len();
            if acc >= target && d + 1 < docs {
                block_doc_off.push((d + 1) as u32);
                acc = 0;
            }
        }
        if docs > 0 {
            block_doc_off.push(docs as u32);
        }
        let nblocks = block_doc_off.len() - 1;

        // per-block distinct-word tables: one scratch row per (block,
        // word) pair, plus the per-entry local row for O(1) routing
        let mut block_row_off = vec![0u32; nblocks + 1];
        let mut row_word: Vec<u32> = Vec::new();
        let mut nnz_row = vec![0u32; nnz];
        let mut stamp = vec![u32::MAX; w];
        let mut local_of = vec![0u32; w];
        for b in 0..nblocks {
            let d0 = block_doc_off[b] as usize;
            let d1 = block_doc_off[b + 1] as usize;
            let mut count = 0u32;
            for d in d0..d1 {
                for idx in data.row_range(d) {
                    let wi = data.col[idx] as usize;
                    if stamp[wi] != b as u32 {
                        stamp[wi] = b as u32;
                        local_of[wi] = count;
                        row_word.push(wi as u32);
                        count += 1;
                    }
                    nnz_row[idx] = local_of[wi];
                }
            }
            block_row_off[b + 1] = block_row_off[b] + count;
        }
        // merge plan: scratch rows of each word, ascending (= block order)
        let mut merge_ptr = vec![0u32; w + 1];
        for &wi in &row_word {
            merge_ptr[wi as usize + 1] += 1;
        }
        for i in 0..w {
            merge_ptr[i + 1] += merge_ptr[i];
        }
        let mut cur = merge_ptr.clone();
        let mut merge_rows = vec![0u32; row_word.len()];
        for (srow, &wi) in row_word.iter().enumerate() {
            merge_rows[cur[wi as usize] as usize] = srow as u32;
            cur[wi as usize] += 1;
        }
        // merge-task word ranges, balanced by scratch-row count (fixed at
        // init like the blocks — the partition never changes, so the
        // per-sweep merge pays no O(W) setup)
        let srows_total = *block_row_off.last().unwrap() as usize;
        let mut merge_bounds = vec![0u32];
        if nblocks > 0 && w > 0 {
            let per = srows_total.div_ceil(nblocks).max(1);
            let mut racc = 0usize;
            for wi in 0..w {
                racc += (merge_ptr[wi + 1] - merge_ptr[wi]) as usize;
                if racc >= per && wi + 1 < w {
                    merge_bounds.push((wi + 1) as u32);
                    racc = 0;
                }
            }
            merge_bounds.push(w as u32);
        }

        let mut s = ShardBp {
            k,
            data,
            mu,
            theta: vec![0.0; docs * k],
            dphi: vec![0.0; w * k],
            r: vec![0.0; w * k],
            scratch: vec![0.0; k],
            theta_old: vec![0.0; docs * k],
            by_word_ptr,
            by_word_idx,
            nnz_doc,
            block_doc_off,
            block_row_off,
            row_word,
            nnz_row,
            merge_ptr,
            merge_rows,
            merge_bounds,
            scratch_dphi: AlignedF32::default(),
            scratch_r: AlignedF32::default(),
            resid_doc: vec![0.0; docs],
            sched: SchedScratch::default(),
        };
        s.recompute_stats();
        s
    }

    /// Recompute θ̂ and Δφ̂ from scratch (Eq. 2–3 with current μ).
    pub fn recompute_stats(&mut self) {
        self.theta.fill(0.0);
        self.dphi.fill(0.0);
        let k = self.k;
        for d in 0..self.data.docs() {
            for idx in self.data.row_range(d) {
                let wi = self.data.col[idx] as usize;
                let x = self.data.val[idx];
                let mu = &self.mu[idx * k..(idx + 1) * k];
                let th = &mut self.theta[d * k..(d + 1) * k];
                for (t, &m) in mu.iter().enumerate() {
                    th[t] += x * m;
                }
                let dp = &mut self.dphi[wi * k..(wi + 1) * k];
                for (t, &m) in mu.iter().enumerate() {
                    dp[t] += x * m;
                }
            }
        }
    }

    /// Zero the fresh-residual entries of the selected pairs (before a
    /// sweep) so `r` holds exactly this iteration's Eq. (8) values there.
    /// [`ShardBp::sweep_parallel`] folds this into its merge — do not
    /// pre-clear on that path (it is harmless, just redundant).
    pub fn clear_selected_residuals(&mut self, sel: &Selection) {
        if sel.full {
            self.r.fill(0.0);
            return;
        }
        let k = self.k;
        for (wi, &is_sel) in sel.word_sel.iter().enumerate() {
            if !is_sel {
                continue;
            }
            match sel.topics_of(wi) {
                None => self.r[wi * k..(wi + 1) * k].fill(0.0),
                Some(ts) => {
                    for &t in ts {
                        self.r[wi * k + t as usize] = 0.0;
                    }
                }
            }
        }
    }

    /// One serial message-passing sweep over the shard (Fig. 4 lines
    /// 6–8 / 15–20), reading the frozen global φ̂ (`phi_wk`, word-major)
    /// and its topic totals. Returns the summed residual of the sweep.
    ///
    /// The sweep is **Jacobi** (synchronous): every message update reads
    /// the θ̂ of the *previous* iteration, matching the AOT-compiled L2
    /// dense graph bit-for-bit in structure (see rust/tests/golden.rs and
    /// rust/tests/xla_parity.rs) and the per-iteration synchronization
    /// semantics of the paper's Fig. 4. Runs the fused kernel; results
    /// are bitwise identical to [`ShardBp::sweep_reference`].
    ///
    /// `update_phi = false` freezes Δφ̂ (used for θ fold-in at evaluation
    /// time, where the heldout documents must not move the model).
    pub fn sweep(
        &mut self,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> f64 {
        // §Perf note: a word-inverted traversal (`sweep_selected`) was
        // measured 1.5x SLOWER than this doc-order scan for power
        // selections — the selected words are the Zipf head carrying most
        // of the NNZ, so the skip savings are small while the inverted
        // walk loses θ̂ locality. Doc-order + bitmap skip is the winner;
        // the inverted path is kept for tail-heavy selections and tests.
        let ctx =
            SweepCtx::new(self.data.w, self.k, phi_wk, phi_tot, sel, p, update_phi);
        let mut lanes = LaneBuf::new(self.k);
        let data = &self.data;
        let mut resid_sum = 0f64;
        for d in 0..data.docs() {
            resid_sum += sweep_doc_ctx(
                data,
                &ctx,
                d,
                &mut self.mu,
                &mut self.theta,
                &mut self.theta_old,
                &mut self.dphi,
                &mut self.r,
                &mut lanes,
            );
        }
        resid_sum
    }

    /// Doc-parallel sweep: the whole-shard sweep fanned over the fixed
    /// doc blocks on up to `budget` OS threads of `pool` (0 = the full
    /// pool; values above the pool are honored so tests can pin thread
    /// counts). See the module doc for the determinism contract: μ, θ̂
    /// and the returned residual are bitwise equal to [`ShardBp::sweep`];
    /// Δφ̂/r rows are merged per word in ascending block order, so they
    /// are bitwise reproducible at any thread count on any machine, and
    /// equal to the serial path up to summation association.
    ///
    /// Folds `clear_selected_residuals` into the merge — callers must
    /// *not* rely on pre-cleared residuals, and per-doc residuals of the
    /// sweep are available afterwards via [`ShardBp::doc_residuals`].
    #[allow(clippy::too_many_arguments)]
    pub fn sweep_parallel(
        &mut self,
        pool: &Cluster,
        budget: usize,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> (f64, SweepTiming) {
        debug_assert_eq!(phi_wk.len(), self.data.w * self.k);
        self.sweep_parallel_view(
            pool,
            budget,
            PhiView::Dense(phi_wk),
            phi_tot,
            sel,
            p,
            update_phi,
        )
    }

    /// [`ShardBp::sweep_parallel`] generalized over the φ̂ representation:
    /// the sharded storage mode's sweep entry point, reading φ̂ rows
    /// through a [`PhiView`] (dense replica or row-aligned owner slices)
    /// — identical bits either way, so results are bitwise equal to the
    /// dense path on the same φ̂ contents.
    #[allow(clippy::too_many_arguments)]
    pub fn sweep_parallel_view(
        &mut self,
        pool: &Cluster,
        budget: usize,
        view: PhiView<'_>,
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> (f64, SweepTiming) {
        let k = self.k;
        let nblocks = self.block_doc_off.len().saturating_sub(1);
        if nblocks == 0 {
            return (0.0, SweepTiming::default());
        }
        // scratch rows are strided to kpad(K) — each row starts on its
        // own 64-byte line, so concurrent blocks never false-share
        let kp = simd::kpad(k);
        let srows = *self.block_row_off.last().unwrap() as usize;
        if self.scratch_dphi.len() != srows * kp {
            self.scratch_dphi = AlignedF32::zeroed(srows * kp);
            self.scratch_r = AlignedF32::zeroed(srows * kp);
        }
        let ctx = SweepCtx::new_view(self.data.w, k, view, phi_tot, sel, p, update_phi);

        struct BlockTask<'a> {
            d0: usize,
            nnz0: usize,
            mu: &'a mut [f32],
            theta: &'a mut [f32],
            theta_old: &'a mut [f32],
            resid: &'a mut [f64],
            sdphi: &'a mut [f32],
            sr: &'a mut [f32],
            /// words of this block's scratch rows, local-row order
            words: &'a [u32],
            lanes: LaneBuf,
        }

        // disjoint &mut views per block: docs (and their nnz rows) are
        // contiguous, scratch rows are grouped by block
        let data = &self.data;
        let nnz_row = &self.nnz_row;
        let mut tasks: Vec<BlockTask<'_>> = Vec::with_capacity(nblocks);
        {
            let mut mu_rest = &mut self.mu[..];
            let mut th_rest = &mut self.theta[..];
            let mut tho_rest = &mut self.theta_old[..];
            let mut rd_rest = &mut self.resid_doc[..];
            let mut sd_rest = &mut self.scratch_dphi[..];
            let mut sr_rest = &mut self.scratch_r[..];
            let mut words_rest = &self.row_word[..];
            for b in 0..nblocks {
                let d0 = self.block_doc_off[b] as usize;
                let d1 = self.block_doc_off[b + 1] as usize;
                let nnz0 = data.row_ptr[d0] as usize;
                let nnz1 = data.row_ptr[d1] as usize;
                let rows =
                    (self.block_row_off[b + 1] - self.block_row_off[b]) as usize;
                let (mu_b, rest) = mu_rest.split_at_mut((nnz1 - nnz0) * k);
                mu_rest = rest;
                let (th_b, rest) = th_rest.split_at_mut((d1 - d0) * k);
                th_rest = rest;
                let (tho_b, rest) = tho_rest.split_at_mut((d1 - d0) * k);
                tho_rest = rest;
                let (rd_b, rest) = rd_rest.split_at_mut(d1 - d0);
                rd_rest = rest;
                let (sd_b, rest) = sd_rest.split_at_mut(rows * kp);
                sd_rest = rest;
                let (sr_b, rest) = sr_rest.split_at_mut(rows * kp);
                sr_rest = rest;
                let (w_b, rest) = words_rest.split_at(rows);
                words_rest = rest;
                tasks.push(BlockTask {
                    d0,
                    nnz0,
                    mu: mu_b,
                    theta: th_b,
                    theta_old: tho_b,
                    resid: rd_b,
                    sdphi: sd_b,
                    sr: sr_b,
                    words: w_b,
                    lanes: LaneBuf::new(k),
                });
            }
        }

        // Small shards degenerate gracefully: one block (or budget 1)
        // takes run_on_doc_blocks' serial path — no threads, no mutexes.
        let block_secs = pool.run_on_doc_blocks(budget, &mut tasks, |_b, t| {
            // zero this sweep's selected scratch rows (zero-at-start
            // protocol: rows stay dirty between sweeps; every sweep
            // cleans exactly the lanes it will write and merge)
            for (lr, &wr) in t.words.iter().enumerate() {
                let wi = wr as usize;
                if !ctx.sel.word_sel[wi] {
                    continue;
                }
                match ctx.sel.topics_of(wi) {
                    None => {
                        if ctx.update_phi {
                            t.sdphi[lr * kp..lr * kp + k].fill(0.0);
                        }
                        t.sr[lr * kp..lr * kp + k].fill(0.0);
                    }
                    Some(ts) => {
                        for &tt in ts {
                            if ctx.update_phi {
                                t.sdphi[lr * kp + tt as usize] = 0.0;
                            }
                            t.sr[lr * kp + tt as usize] = 0.0;
                        }
                    }
                }
            }
            // NOTE: this is sweep_doc_ctx's traversal with block-local
            // rows (mu/θ̂ offset by the block base, Δφ̂/r routed to scratch
            // rows) — a protocol change there must be mirrored here, and
            // sweep_equiv's bitwise tests will catch a mismatch.
            let ndocs = t.resid.len();
            for ld in 0..ndocs {
                let d = t.d0 + ld;
                t.theta_old[ld * k..(ld + 1) * k]
                    .copy_from_slice(&t.theta[ld * k..(ld + 1) * k]);
                let mut resid = 0f64;
                for idx in data.row_range(d) {
                    let wi = data.col[idx] as usize;
                    if !ctx.sel.word_sel[wi] {
                        continue;
                    }
                    let lr = nnz_row[idx] as usize;
                    let li = idx - t.nnz0;
                    let dphi_row = if ctx.update_phi {
                        Some(&mut t.sdphi[lr * kp..lr * kp + k])
                    } else {
                        None
                    };
                    resid += fused_update(
                        &ctx,
                        wi,
                        data.val[idx],
                        &mut t.mu[li * k..(li + 1) * k],
                        &t.theta_old[ld * k..(ld + 1) * k],
                        &mut t.theta[ld * k..(ld + 1) * k],
                        dphi_row,
                        &mut t.sr[lr * kp..lr * kp + k],
                        &mut t.lanes,
                    );
                }
                t.resid[ld] = resid;
            }
        });
        drop(tasks);

        // --- deterministic merge: per word row, fold scratch rows in
        //     ascending block order; parallel over word ranges (safe:
        //     each output row depends only on its own word's rows) ---
        let t0 = Instant::now();
        struct MergeTask<'a> {
            w0: usize,
            dphi: &'a mut [f32],
            r: &'a mut [f32],
        }
        let mut mtasks: Vec<MergeTask<'_>> =
            Vec::with_capacity(self.merge_bounds.len());
        {
            let mut dp_rest = &mut self.dphi[..];
            let mut r_rest = &mut self.r[..];
            let mut prev = 0usize;
            for &b in &self.merge_bounds[1..] {
                let b = b as usize;
                let (dp_b, rest) = dp_rest.split_at_mut((b - prev) * k);
                dp_rest = rest;
                let (r_b, rest) = r_rest.split_at_mut((b - prev) * k);
                r_rest = rest;
                mtasks.push(MergeTask { w0: prev, dphi: dp_b, r: r_b });
                prev = b;
            }
        }
        let merge_ptr = &self.merge_ptr;
        let merge_rows = &self.merge_rows;
        let sdphi = &self.scratch_dphi;
        let sr = &self.scratch_r;
        pool.run_on_doc_blocks(budget, &mut mtasks, |_i, mt| {
            let nw = mt.r.len() / k;
            for ww in 0..nw {
                let wi = mt.w0 + ww;
                if !ctx.sel.word_sel[wi] {
                    continue;
                }
                let rows = &merge_rows
                    [merge_ptr[wi] as usize..merge_ptr[wi + 1] as usize];
                match ctx.sel.topics_of(wi) {
                    None => {
                        let rrow = &mut mt.r[ww * k..(ww + 1) * k];
                        rrow.fill(0.0);
                        for &srow in rows {
                            let base = srow as usize * kp;
                            let src = &sr[base..base + k];
                            for (o, &v) in rrow.iter_mut().zip(src) {
                                *o += v;
                            }
                        }
                        if ctx.update_phi {
                            let drow = &mut mt.dphi[ww * k..(ww + 1) * k];
                            for &srow in rows {
                                let base = srow as usize * kp;
                                let src = &sdphi[base..base + k];
                                for (o, &v) in drow.iter_mut().zip(src) {
                                    *o += v;
                                }
                            }
                        }
                    }
                    Some(ts) => {
                        let rrow = &mut mt.r[ww * k..(ww + 1) * k];
                        for &tt in ts {
                            rrow[tt as usize] = 0.0;
                        }
                        for &srow in rows {
                            let base = srow as usize * kp;
                            for &tt in ts {
                                rrow[tt as usize] += sr[base + tt as usize];
                            }
                        }
                        if ctx.update_phi {
                            let drow = &mut mt.dphi[ww * k..(ww + 1) * k];
                            for &srow in rows {
                                let base = srow as usize * kp;
                                for &tt in ts {
                                    drow[tt as usize] += sdphi[base + tt as usize];
                                }
                            }
                        }
                    }
                }
            }
        });
        let merge_secs = t0.elapsed().as_secs_f64();

        // per-doc f64 partials summed in doc order: bitwise equal to the
        // serial doc loop's accumulation
        let resid: f64 = self.resid_doc.iter().sum();
        (resid, SweepTiming { block_secs, merge_secs })
    }

    /// Per-doc residuals of the last [`ShardBp::sweep_parallel`] call,
    /// indexed by shard-local document id — the ABP scheduling signal
    /// without a second pass.
    pub fn doc_residuals(&self) -> &[f64] {
        &self.resid_doc
    }

    /// Non-zero entries of word `wi` in this shard, from the inverted
    /// index (O(1); the microbench work-item accounting uses this instead
    /// of a per-doc binary-search scan).
    pub fn word_entries(&self, wi: usize) -> usize {
        (self.by_word_ptr[wi + 1] - self.by_word_ptr[wi]) as usize
    }

    /// Subset sweep through the inverted index: touches only the selected
    /// words' non-zero entries (O(active NNZ) instead of O(NNZ)).
    /// Jacobi-equivalent to the doc-order path: entries are visited once,
    /// scores read the θ̂ snapshot, and per-row float accumulation order
    /// is identical (CSR rows are word-sorted; the index is doc-sorted
    /// within each word), so the state it leaves is bitwise equal to
    /// [`ShardBp::sweep`]'s — only the f64 residual *total* differs in
    /// association. Runs the fused kernel; the packed φ̂ gathers pay off
    /// here because each word's lanes are reused across all its entries.
    /// Beneficial only when the selection misses the Zipf head — see the
    /// §Perf note in [`ShardBp::sweep`].
    pub fn sweep_selected(
        &mut self,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> f64 {
        debug_assert!(!sel.full);
        self.theta_old.copy_from_slice(&self.theta);
        let ctx =
            SweepCtx::new(self.data.w, self.k, phi_wk, phi_tot, sel, p, update_phi);
        let mut lanes = LaneBuf::new(self.k);
        let k = self.k;
        let data = &self.data;
        let mut resid_sum = 0f64;
        for wi in 0..data.w {
            if !sel.word_sel[wi] {
                continue;
            }
            let lo = self.by_word_ptr[wi] as usize;
            let hi = self.by_word_ptr[wi + 1] as usize;
            for pos in lo..hi {
                let idx = self.by_word_idx[pos] as usize;
                let d = self.nnz_doc[idx] as usize;
                let dphi_row = if ctx.update_phi {
                    Some(&mut self.dphi[wi * k..(wi + 1) * k])
                } else {
                    None
                };
                resid_sum += fused_update(
                    &ctx,
                    wi,
                    data.val[idx],
                    &mut self.mu[idx * k..(idx + 1) * k],
                    &self.theta_old[d * k..(d + 1) * k],
                    &mut self.theta[d * k..(d + 1) * k],
                    dphi_row,
                    &mut self.r[wi * k..(wi + 1) * k],
                    &mut lanes,
                );
            }
        }
        resid_sum
    }

    /// Sweep a single document (the ABP active-scheduling granule; also
    /// the unit `sweep` iterates). Takes this doc's own Jacobi θ̂
    /// snapshot — documents only read their own θ̂ row, so per-doc
    /// snapshots are equivalent to a whole-shard snapshot. Builds the
    /// sweep context per call; schedulers sweeping many docs against one
    /// frozen φ̂ should prefer [`ShardBp::sweep_docs`].
    pub fn sweep_doc(
        &mut self,
        d: usize,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> f64 {
        let ctx =
            SweepCtx::new(self.data.w, self.k, phi_wk, phi_tot, sel, p, update_phi);
        let mut lanes = LaneBuf::new(self.k);
        sweep_doc_ctx(
            &self.data,
            &ctx,
            d,
            &mut self.mu,
            &mut self.theta,
            &mut self.theta_old,
            &mut self.dphi,
            &mut self.r,
            &mut lanes,
        )
    }

    /// Sweep a scheduled document list against one frozen φ̂, returning
    /// each document's residual (aligned with `docs`). One context build
    /// for the whole list — the ABP inner loop.
    pub fn sweep_docs(
        &mut self,
        docs: &[u32],
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> Vec<f64> {
        let ctx =
            SweepCtx::new(self.data.w, self.k, phi_wk, phi_tot, sel, p, update_phi);
        let mut lanes = LaneBuf::new(self.k);
        let data = &self.data;
        let mut out = Vec::with_capacity(docs.len());
        for &d in docs {
            out.push(sweep_doc_ctx(
                data,
                &ctx,
                d as usize,
                &mut self.mu,
                &mut self.theta,
                &mut self.theta_old,
                &mut self.dphi,
                &mut self.r,
                &mut lanes,
            ));
        }
        out
    }

    /// Scheduled-parallel sweep — [`ShardBp::sweep_docs`] fanned over the
    /// NNZ-balanced permuted blocks of a [`DocSchedule`] on up to
    /// `budget` OS threads of `pool` (0 = the full pool budget), via
    /// [`Cluster::run_on_permuted_blocks`]. This retires the last serial
    /// sweep on the compute side: ABP's residual-ordered t ≥ 2
    /// iterations now scale with the machine like the t = 1 path.
    ///
    /// Returns per-doc residuals **in the caller's original schedule
    /// order** (via the schedule's inverse permutation), plus the sweep
    /// timing; `merge_secs` includes the per-sweep index build (serial
    /// leader work) on top of the deterministic merge.
    ///
    /// # Determinism contract (mirrors [`ShardBp::sweep_parallel`])
    ///
    /// * Blocks own disjoint whole documents — sorted ascending, so each
    ///   block's μ/θ̂ rows live in one contiguous shard span. μ, θ̂ and
    ///   the per-doc f64 residuals are **bitwise identical** to the
    ///   serial [`ShardBp::sweep_docs`] over the same schedule (each doc
    ///   appears once, reads only the frozen φ̂ and its own θ̂ snapshot).
    /// * Δφ̂/r contributions route through per-block scratch rows (one
    ///   per distinct selected word per block, built per sweep into the
    ///   reused [`SchedScratch`]) and merge **in ascending block order
    ///   per word row**. Block boundaries derive from scheduled-NNZ
    ///   counts only, so the accumulation order is a pure function of
    ///   the schedule and the data: bitwise reproducible at any thread
    ///   count on any machine, equal to the serial path up to summation
    ///   association (`rust/tests/sweep_equiv.rs` pins both).
    /// * Un-selected (word, topic) pairs and un-scheduled documents stay
    ///   bitwise frozen.
    ///
    /// Unlike [`ShardBp::sweep_parallel`], residual clearing is **not**
    /// folded in: callers clear selected residuals first, exactly as
    /// with the serial [`ShardBp::sweep_docs`] (the merge *adds* block
    /// sums onto the cleared lanes, preserving the serial contract).
    #[allow(clippy::too_many_arguments)]
    pub fn sweep_docs_parallel(
        &mut self,
        pool: &Cluster,
        budget: usize,
        sched: &DocSchedule,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> (Vec<f64>, SweepTiming) {
        let k = self.k;
        // cache-line-padded scratch stride (see sweep_parallel_view)
        let kp = simd::kpad(k);
        let nblocks = sched.blocks();
        if nblocks == 0 {
            return (Vec::new(), SweepTiming::default());
        }
        let ctx =
            SweepCtx::new(self.data.w, k, phi_wk, phi_tot, sel, p, update_phi);
        let mut scr = std::mem::take(&mut self.sched);
        let data = &self.data;
        let w = data.w;
        let t_setup = Instant::now();

        // --- per-sweep index build: one scratch row per (block, selected
        //     word) pair, O(scheduled NNZ); the stamp generation advances
        //     per block so the W-sized tables never need clearing ---
        if scr.stamp.len() != w {
            scr.stamp = vec![0; w];
            scr.local_of = vec![0; w];
            scr.gen = 0;
        }
        scr.entry_row.resize(data.nnz(), 0);
        scr.row_word.clear();
        scr.block_row_off.clear();
        scr.block_row_off.push(0);
        for b in 0..nblocks {
            scr.gen += 1;
            let g = scr.gen;
            let mut count = 0u32;
            for &d in sched.block(b) {
                for idx in data.row_range(d as usize) {
                    let wi = data.col[idx] as usize;
                    if !ctx.sel.word_sel[wi] {
                        continue;
                    }
                    if scr.stamp[wi] != g {
                        scr.stamp[wi] = g;
                        scr.local_of[wi] = count;
                        scr.row_word.push(wi as u32);
                        count += 1;
                    }
                    scr.entry_row[idx] = scr.local_of[wi];
                }
            }
            let prev = *scr.block_row_off.last().unwrap();
            scr.block_row_off.push(prev + count);
        }
        let srows = *scr.block_row_off.last().unwrap() as usize;
        if scr.sdphi.len() < srows * kp {
            scr.sdphi.resize_zeroed(srows * kp);
            scr.sr.resize_zeroed(srows * kp);
        }
        // merge plan: counting sort of the scratch rows by word — per
        // word, ascending rows == ascending block order
        scr.merge_ptr.clear();
        scr.merge_ptr.resize(w + 1, 0);
        for &wi in &scr.row_word {
            scr.merge_ptr[wi as usize + 1] += 1;
        }
        for i in 0..w {
            scr.merge_ptr[i + 1] += scr.merge_ptr[i];
        }
        scr.merge_cursor.clear();
        scr.merge_cursor.extend_from_slice(&scr.merge_ptr[..w]);
        scr.merge_rows.clear();
        scr.merge_rows.resize(srows, 0);
        for (srow, &wi) in scr.row_word.iter().enumerate() {
            let c = &mut scr.merge_cursor[wi as usize];
            scr.merge_rows[*c as usize] = srow as u32;
            *c += 1;
        }
        // merge-task word ranges, balanced by scratch-row count
        scr.merge_bounds.clear();
        scr.merge_bounds.push(0);
        let per = srows.div_ceil(nblocks).max(1);
        let mut racc = 0usize;
        for wi in 0..w {
            racc += (scr.merge_ptr[wi + 1] - scr.merge_ptr[wi]) as usize;
            if racc >= per && wi + 1 < w {
                scr.merge_bounds.push((wi + 1) as u32);
                racc = 0;
            }
        }
        scr.merge_bounds.push(w as u32);
        scr.resid_sorted.clear();
        scr.resid_sorted.resize(sched.len(), 0.0);
        let setup_secs = t_setup.elapsed().as_secs_f64();

        struct SchedBlockTask<'a> {
            /// first doc of the block's contiguous shard span
            d0: usize,
            /// nnz base of the span
            nnz0: usize,
            /// scheduled docs of the block, ascending
            docs: &'a [u32],
            mu: &'a mut [f32],
            theta: &'a mut [f32],
            theta_old: &'a mut [f32],
            /// residual outputs, block-local sorted-schedule order
            resid: &'a mut [f64],
            sdphi: &'a mut [f32],
            sr: &'a mut [f32],
            /// words of this block's scratch rows, local-row order
            words: &'a [u32],
            lanes: LaneBuf,
        }

        // Disjoint &mut views per block: docs are sorted ascending and
        // blocks are contiguous ranges of the sorted schedule, so each
        // block's μ/θ̂ rows fall inside one global span [d0, d1) that
        // never overlaps the next block's — the split skips the
        // unscheduled gap before each span. (This is what the
        // DocSchedule permutation buys: data-dependent schedules become
        // plain split_at_mut work sets.)
        let mut tasks: Vec<SchedBlockTask<'_>> = Vec::with_capacity(nblocks);
        {
            let mut mu_rest = &mut self.mu[..];
            let mut th_rest = &mut self.theta[..];
            let mut tho_rest = &mut self.theta_old[..];
            let mut rd_rest = &mut scr.resid_sorted[..];
            let mut sd_rest = &mut scr.sdphi[..srows * kp];
            let mut sr_rest = &mut scr.sr[..srows * kp];
            let mut words_rest = &scr.row_word[..];
            let mut doc_cut = 0usize;
            let mut nnz_cut = 0usize;
            for b in 0..nblocks {
                let docs_b = sched.block(b);
                let d0 = docs_b[0] as usize;
                let d1 = *docs_b.last().unwrap() as usize + 1;
                let nnz0 = data.row_ptr[d0] as usize;
                let nnz1 = data.row_ptr[d1] as usize;
                let rows =
                    (scr.block_row_off[b + 1] - scr.block_row_off[b]) as usize;
                let (_, rest) = mu_rest.split_at_mut((nnz0 - nnz_cut) * k);
                let (mu_b, rest) = rest.split_at_mut((nnz1 - nnz0) * k);
                mu_rest = rest;
                let (_, rest) = th_rest.split_at_mut((d0 - doc_cut) * k);
                let (th_b, rest) = rest.split_at_mut((d1 - d0) * k);
                th_rest = rest;
                let (_, rest) = tho_rest.split_at_mut((d0 - doc_cut) * k);
                let (tho_b, rest) = rest.split_at_mut((d1 - d0) * k);
                tho_rest = rest;
                let (rd_b, rest) = rd_rest.split_at_mut(docs_b.len());
                rd_rest = rest;
                let (sd_b, rest) = sd_rest.split_at_mut(rows * kp);
                sd_rest = rest;
                let (sr_b, rest) = sr_rest.split_at_mut(rows * kp);
                sr_rest = rest;
                let (w_b, rest) = words_rest.split_at(rows);
                words_rest = rest;
                doc_cut = d1;
                nnz_cut = nnz1;
                tasks.push(SchedBlockTask {
                    d0,
                    nnz0,
                    docs: docs_b,
                    mu: mu_b,
                    theta: th_b,
                    theta_old: tho_b,
                    resid: rd_b,
                    sdphi: sd_b,
                    sr: sr_b,
                    words: w_b,
                    lanes: LaneBuf::new(k),
                });
            }
        }

        let entry_row = &scr.entry_row;
        let block_secs = pool.run_on_permuted_blocks(budget, &mut tasks, |_b, t| {
            // zero this sweep's selected scratch lanes (rows are freshly
            // assigned per sweep, but the buffers persist dirty)
            for (lr, &wr) in t.words.iter().enumerate() {
                let wi = wr as usize;
                match ctx.sel.topics_of(wi) {
                    None => {
                        if ctx.update_phi {
                            t.sdphi[lr * kp..lr * kp + k].fill(0.0);
                        }
                        t.sr[lr * kp..lr * kp + k].fill(0.0);
                    }
                    Some(ts) => {
                        for &tt in ts {
                            if ctx.update_phi {
                                t.sdphi[lr * kp + tt as usize] = 0.0;
                            }
                            t.sr[lr * kp + tt as usize] = 0.0;
                        }
                    }
                }
            }
            // sweep_docs' traversal with span-local rows (μ/θ̂ offset by
            // the span base, Δφ̂/r routed to the block's scratch rows)
            for (i, &d) in t.docs.iter().enumerate() {
                let d = d as usize;
                let ld = d - t.d0;
                t.theta_old[ld * k..(ld + 1) * k]
                    .copy_from_slice(&t.theta[ld * k..(ld + 1) * k]);
                let mut resid = 0f64;
                for idx in data.row_range(d) {
                    let wi = data.col[idx] as usize;
                    if !ctx.sel.word_sel[wi] {
                        continue;
                    }
                    let lr = entry_row[idx] as usize;
                    let li = idx - t.nnz0;
                    let dphi_row = if ctx.update_phi {
                        Some(&mut t.sdphi[lr * kp..lr * kp + k])
                    } else {
                        None
                    };
                    resid += fused_update(
                        &ctx,
                        wi,
                        data.val[idx],
                        &mut t.mu[li * k..(li + 1) * k],
                        &t.theta_old[ld * k..(ld + 1) * k],
                        &mut t.theta[ld * k..(ld + 1) * k],
                        dphi_row,
                        &mut t.sr[lr * kp..lr * kp + k],
                        &mut t.lanes,
                    );
                }
                t.resid[i] = resid;
            }
        });
        drop(tasks);

        // --- deterministic merge: per touched word row, *add* the block
        //     sums in ascending block order onto the caller-cleared
        //     lanes (serial sweep_docs contract); parallel over the
        //     per-sweep word-range tasks ---
        let t0 = Instant::now();
        struct MergeTask<'a> {
            w0: usize,
            dphi: &'a mut [f32],
            r: &'a mut [f32],
        }
        let mut mtasks: Vec<MergeTask<'_>> =
            Vec::with_capacity(scr.merge_bounds.len());
        {
            let mut dp_rest = &mut self.dphi[..];
            let mut r_rest = &mut self.r[..];
            let mut prev = 0usize;
            for &b in &scr.merge_bounds[1..] {
                let b = b as usize;
                let (dp_b, rest) = dp_rest.split_at_mut((b - prev) * k);
                dp_rest = rest;
                let (r_b, rest) = r_rest.split_at_mut((b - prev) * k);
                r_rest = rest;
                mtasks.push(MergeTask { w0: prev, dphi: dp_b, r: r_b });
                prev = b;
            }
        }
        let merge_ptr = &scr.merge_ptr;
        let merge_rows = &scr.merge_rows;
        let sdphi = &scr.sdphi;
        let sr = &scr.sr;
        pool.run_on_permuted_blocks(budget, &mut mtasks, |_i, mt| {
            let nw = mt.r.len() / k;
            for ww in 0..nw {
                let wi = mt.w0 + ww;
                let rows = &merge_rows
                    [merge_ptr[wi] as usize..merge_ptr[wi + 1] as usize];
                if rows.is_empty() {
                    continue; // word untouched by this schedule
                }
                match ctx.sel.topics_of(wi) {
                    None => {
                        let rrow = &mut mt.r[ww * k..(ww + 1) * k];
                        for &srow in rows {
                            let base = srow as usize * kp;
                            let src = &sr[base..base + k];
                            for (o, &v) in rrow.iter_mut().zip(src) {
                                *o += v;
                            }
                        }
                        if ctx.update_phi {
                            let drow = &mut mt.dphi[ww * k..(ww + 1) * k];
                            for &srow in rows {
                                let base = srow as usize * kp;
                                let src = &sdphi[base..base + k];
                                for (o, &v) in drow.iter_mut().zip(src) {
                                    *o += v;
                                }
                            }
                        }
                    }
                    Some(ts) => {
                        let rrow = &mut mt.r[ww * k..(ww + 1) * k];
                        for &srow in rows {
                            let base = srow as usize * kp;
                            for &tt in ts {
                                rrow[tt as usize] += sr[base + tt as usize];
                            }
                        }
                        if ctx.update_phi {
                            let drow = &mut mt.dphi[ww * k..(ww + 1) * k];
                            for &srow in rows {
                                let base = srow as usize * kp;
                                for &tt in ts {
                                    drow[tt as usize] += sdphi[base + tt as usize];
                                }
                            }
                        }
                    }
                }
            }
        });
        let merge_secs = t0.elapsed().as_secs_f64() + setup_secs;

        // per-doc residuals back in the caller's schedule order
        let mut out = vec![0f64; sched.len()];
        for (i, &pos) in sched.sched_pos().iter().enumerate() {
            out[pos as usize] = scr.resid_sorted[i];
        }
        self.sched = scr;
        (out, SweepTiming { block_secs, merge_secs })
    }

    /// Fixed-block scheduled sweep — the high-coverage fast path of the
    /// ABP t ≥ 2 iteration: sweep the documents of `sched` over the
    /// **init-time** block tables of the t = 1 engine instead of
    /// rebuilding the per-sweep permutation tables. The O(scheduled NNZ)
    /// index build of [`ShardBp::sweep_docs_parallel`] disappears; the
    /// trade is that the zero/merge phases walk every fixed scratch row
    /// of the blocks that contain scheduled docs, which pays off exactly
    /// when the schedule covers most of the shard — the caller gates on
    /// [`DocSchedule::coverage`] against `AbpConfig::sched_reuse_coverage`.
    ///
    /// # Contract (mirrors [`ShardBp::sweep_docs_parallel`])
    ///
    /// * μ, θ̂ and the per-doc residuals (returned in the caller's
    ///   schedule order) are **bitwise identical** to the serial
    ///   [`ShardBp::sweep_docs`] over the same schedule.
    /// * Δφ̂/r route through the fixed per-block scratch rows and merge
    ///   per word in ascending fixed-block order — a different (coarser)
    ///   partition than the per-sweep permutation blocks, so results
    ///   equal the serial path (and the rebuild path) up to summation
    ///   association, and are bitwise reproducible at any thread budget.
    ///   Scratch rows whose block holds scheduled docs but whose word has
    ///   no scheduled entry contribute exact `+0.0` lanes (zeroed in the
    ///   zero phase, never written): `x + 0.0` is a bitwise identity for
    ///   every reachable `x` — Δφ̂/r lanes are never `-0.0` (r
    ///   accumulates absolute values from a `+0.0` clear; Δφ̂ descends
    ///   from `+0.0`-seeded sums, and f32 addition yields `-0.0` only
    ///   from two `-0.0` operands). Rows of fixed blocks with **no**
    ///   scheduled docs stay dirty and are skipped via a per-sweep
    ///   liveness table.
    /// * Residual clearing is **not** folded in — callers
    ///   [`ShardBp::clear_selected_residuals`] first, exactly as with
    ///   the serial path (the merge *adds*).
    #[allow(clippy::too_many_arguments)]
    pub fn sweep_docs_parallel_fixed(
        &mut self,
        pool: &Cluster,
        budget: usize,
        sched: &DocSchedule,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> (Vec<f64>, SweepTiming) {
        let k = self.k;
        // cache-line-padded scratch stride (see sweep_parallel_view)
        let kp = simd::kpad(k);
        let nblocks = self.block_doc_off.len().saturating_sub(1);
        if nblocks == 0 || sched.is_empty() {
            return (vec![0.0; sched.len()], SweepTiming::default());
        }
        let srows = *self.block_row_off.last().unwrap() as usize;
        if self.scratch_dphi.len() != srows * kp {
            self.scratch_dphi = AlignedF32::zeroed(srows * kp);
            self.scratch_r = AlignedF32::zeroed(srows * kp);
        }
        let ctx = SweepCtx::new(self.data.w, k, phi_wk, phi_tot, sel, p, update_phi);
        let mut scr = std::mem::take(&mut self.sched);
        let t_setup = Instant::now();

        // cut the sorted schedule at the fixed block boundaries: block b
        // owns sorted-schedule positions fixed_cut[b]..fixed_cut[b+1]
        let docs_sorted = sched.docs_sorted();
        scr.fixed_cut.clear();
        scr.fixed_cut.push(0);
        {
            let mut pos = 0usize;
            for b in 0..nblocks {
                let d1 = self.block_doc_off[b + 1];
                while pos < docs_sorted.len() && docs_sorted[pos] < d1 {
                    pos += 1;
                }
                scr.fixed_cut.push(pos as u32);
            }
        }
        // scratch-row liveness: only rows of blocks with scheduled docs
        // are zeroed this sweep; the rest must not enter the merge
        scr.row_live.clear();
        scr.row_live.resize(srows, false);
        for b in 0..nblocks {
            if scr.fixed_cut[b + 1] > scr.fixed_cut[b] {
                let lo = self.block_row_off[b] as usize;
                let hi = self.block_row_off[b + 1] as usize;
                for lv in &mut scr.row_live[lo..hi] {
                    *lv = true;
                }
            }
        }
        scr.resid_sorted.clear();
        scr.resid_sorted.resize(sched.len(), 0.0);
        let setup_secs = t_setup.elapsed().as_secs_f64();

        struct FixedBlockTask<'a> {
            /// first doc of the fixed block (the μ/θ̂ span base)
            d0: usize,
            /// nnz base of the block's span
            nnz0: usize,
            /// scheduled docs inside the block, ascending
            docs: &'a [u32],
            mu: &'a mut [f32],
            theta: &'a mut [f32],
            theta_old: &'a mut [f32],
            /// residual outputs, block-local sorted-schedule order
            resid: &'a mut [f64],
            sdphi: &'a mut [f32],
            sr: &'a mut [f32],
            /// words of this block's fixed scratch rows, local-row order
            words: &'a [u32],
            lanes: LaneBuf,
        }

        // disjoint &mut views per ACTIVE fixed block (blocks without
        // scheduled docs are skipped; the cursors hop their spans)
        let data = &self.data;
        let nnz_row = &self.nnz_row;
        let mut tasks: Vec<FixedBlockTask<'_>> = Vec::with_capacity(nblocks);
        {
            let mut mu_rest = &mut self.mu[..];
            let mut th_rest = &mut self.theta[..];
            let mut tho_rest = &mut self.theta_old[..];
            let mut rd_rest = &mut scr.resid_sorted[..];
            let mut sd_rest = &mut self.scratch_dphi[..];
            let mut sr_rest = &mut self.scratch_r[..];
            let mut words_rest = &self.row_word[..];
            let mut doc_cut = 0usize;
            let mut nnz_cut = 0usize;
            let mut row_cut = 0usize;
            for b in 0..nblocks {
                let lo = scr.fixed_cut[b] as usize;
                let hi = scr.fixed_cut[b + 1] as usize;
                if lo == hi {
                    continue; // no scheduled docs in this fixed block
                }
                let d0 = self.block_doc_off[b] as usize;
                let d1 = self.block_doc_off[b + 1] as usize;
                let nnz0 = data.row_ptr[d0] as usize;
                let nnz1 = data.row_ptr[d1] as usize;
                let row0 = self.block_row_off[b] as usize;
                let rows = self.block_row_off[b + 1] as usize - row0;
                let (_, rest) = mu_rest.split_at_mut((nnz0 - nnz_cut) * k);
                let (mu_b, rest) = rest.split_at_mut((nnz1 - nnz0) * k);
                mu_rest = rest;
                let (_, rest) = th_rest.split_at_mut((d0 - doc_cut) * k);
                let (th_b, rest) = rest.split_at_mut((d1 - d0) * k);
                th_rest = rest;
                let (_, rest) = tho_rest.split_at_mut((d0 - doc_cut) * k);
                let (tho_b, rest) = rest.split_at_mut((d1 - d0) * k);
                tho_rest = rest;
                let (rd_b, rest) = rd_rest.split_at_mut(hi - lo);
                rd_rest = rest;
                let (_, rest) = sd_rest.split_at_mut((row0 - row_cut) * kp);
                let (sd_b, rest) = rest.split_at_mut(rows * kp);
                sd_rest = rest;
                let (_, rest) = sr_rest.split_at_mut((row0 - row_cut) * kp);
                let (sr_b, rest) = rest.split_at_mut(rows * kp);
                sr_rest = rest;
                let (_, rest) = words_rest.split_at(row0 - row_cut);
                let (w_b, rest) = rest.split_at(rows);
                words_rest = rest;
                doc_cut = d1;
                nnz_cut = nnz1;
                row_cut = row0 + rows;
                tasks.push(FixedBlockTask {
                    d0,
                    nnz0,
                    docs: &docs_sorted[lo..hi],
                    mu: mu_b,
                    theta: th_b,
                    theta_old: tho_b,
                    resid: rd_b,
                    sdphi: sd_b,
                    sr: sr_b,
                    words: w_b,
                    lanes: LaneBuf::new(k),
                });
            }
        }

        let block_secs = pool.run_on_permuted_blocks(budget, &mut tasks, |_b, t| {
            // zero the selected lanes of every fixed row of this block
            // (rows without scheduled entries contribute exact +0.0)
            for (lr, &wr) in t.words.iter().enumerate() {
                let wi = wr as usize;
                if !ctx.sel.word_sel[wi] {
                    continue;
                }
                match ctx.sel.topics_of(wi) {
                    None => {
                        if ctx.update_phi {
                            t.sdphi[lr * kp..lr * kp + k].fill(0.0);
                        }
                        t.sr[lr * kp..lr * kp + k].fill(0.0);
                    }
                    Some(ts) => {
                        for &tt in ts {
                            if ctx.update_phi {
                                t.sdphi[lr * kp + tt as usize] = 0.0;
                            }
                            t.sr[lr * kp + tt as usize] = 0.0;
                        }
                    }
                }
            }
            // sweep_docs' traversal over the block's scheduled docs, with
            // block-local rows (μ/θ̂ offset by the span base, Δφ̂/r routed
            // to the init-time scratch rows via the fixed nnz_row table)
            for (i, &d) in t.docs.iter().enumerate() {
                let d = d as usize;
                let ld = d - t.d0;
                t.theta_old[ld * k..(ld + 1) * k]
                    .copy_from_slice(&t.theta[ld * k..(ld + 1) * k]);
                let mut resid = 0f64;
                for idx in data.row_range(d) {
                    let wi = data.col[idx] as usize;
                    if !ctx.sel.word_sel[wi] {
                        continue;
                    }
                    let lr = nnz_row[idx] as usize;
                    let li = idx - t.nnz0;
                    let dphi_row = if ctx.update_phi {
                        Some(&mut t.sdphi[lr * kp..lr * kp + k])
                    } else {
                        None
                    };
                    resid += fused_update(
                        &ctx,
                        wi,
                        data.val[idx],
                        &mut t.mu[li * k..(li + 1) * k],
                        &t.theta_old[ld * k..(ld + 1) * k],
                        &mut t.theta[ld * k..(ld + 1) * k],
                        dphi_row,
                        &mut t.sr[lr * kp..lr * kp + k],
                        &mut t.lanes,
                    );
                }
                t.resid[i] = resid;
            }
        });
        drop(tasks);

        // deterministic merge over the init-time plan: per selected word,
        // ADD the live rows' sums in ascending fixed-block order onto the
        // caller-cleared lanes (serial sweep_docs contract — no fill)
        let t0 = Instant::now();
        struct MergeTask<'a> {
            w0: usize,
            dphi: &'a mut [f32],
            r: &'a mut [f32],
        }
        let mut mtasks: Vec<MergeTask<'_>> =
            Vec::with_capacity(self.merge_bounds.len());
        {
            let mut dp_rest = &mut self.dphi[..];
            let mut r_rest = &mut self.r[..];
            let mut prev = 0usize;
            for &b in &self.merge_bounds[1..] {
                let b = b as usize;
                let (dp_b, rest) = dp_rest.split_at_mut((b - prev) * k);
                dp_rest = rest;
                let (r_b, rest) = r_rest.split_at_mut((b - prev) * k);
                r_rest = rest;
                mtasks.push(MergeTask { w0: prev, dphi: dp_b, r: r_b });
                prev = b;
            }
        }
        let merge_ptr = &self.merge_ptr;
        let merge_rows = &self.merge_rows;
        let sdphi = &self.scratch_dphi;
        let sr = &self.scratch_r;
        let row_live = &scr.row_live;
        pool.run_on_permuted_blocks(budget, &mut mtasks, |_i, mt| {
            let nw = mt.r.len() / k;
            for ww in 0..nw {
                let wi = mt.w0 + ww;
                if !ctx.sel.word_sel[wi] {
                    continue;
                }
                let rows = &merge_rows
                    [merge_ptr[wi] as usize..merge_ptr[wi + 1] as usize];
                match ctx.sel.topics_of(wi) {
                    None => {
                        let rrow = &mut mt.r[ww * k..(ww + 1) * k];
                        for &srow in rows {
                            if !row_live[srow as usize] {
                                continue;
                            }
                            let base = srow as usize * kp;
                            let src = &sr[base..base + k];
                            for (o, &v) in rrow.iter_mut().zip(src) {
                                *o += v;
                            }
                        }
                        if ctx.update_phi {
                            let drow = &mut mt.dphi[ww * k..(ww + 1) * k];
                            for &srow in rows {
                                if !row_live[srow as usize] {
                                    continue;
                                }
                                let base = srow as usize * kp;
                                let src = &sdphi[base..base + k];
                                for (o, &v) in drow.iter_mut().zip(src) {
                                    *o += v;
                                }
                            }
                        }
                    }
                    Some(ts) => {
                        let rrow = &mut mt.r[ww * k..(ww + 1) * k];
                        for &srow in rows {
                            if !row_live[srow as usize] {
                                continue;
                            }
                            let base = srow as usize * kp;
                            for &tt in ts {
                                rrow[tt as usize] += sr[base + tt as usize];
                            }
                        }
                        if ctx.update_phi {
                            let drow = &mut mt.dphi[ww * k..(ww + 1) * k];
                            for &srow in rows {
                                if !row_live[srow as usize] {
                                    continue;
                                }
                                let base = srow as usize * kp;
                                for &tt in ts {
                                    drow[tt as usize] += sdphi[base + tt as usize];
                                }
                            }
                        }
                    }
                }
            }
        });
        let merge_secs = t0.elapsed().as_secs_f64() + setup_secs;

        // per-doc residuals back in the caller's schedule order
        let mut out = vec![0f64; sched.len()];
        for (i, &pos) in sched.sched_pos().iter().enumerate() {
            out[pos as usize] = scr.resid_sorted[i];
        }
        self.sched = scr;
        (out, SweepTiming { block_secs, merge_secs })
    }

    /// The pre-fusion serial sweep, kept verbatim as the equivalence-test
    /// oracle (the `serial_reference_step` pattern of the allreduce
    /// subsystem): doc loop over [`ShardBp::sweep_doc_reference`].
    pub fn sweep_reference(
        &mut self,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> f64 {
        let mut resid_sum = 0f64;
        for d in 0..self.data.docs() {
            resid_sum +=
                self.sweep_doc_reference(d, phi_wk, phi_tot, sel, p, update_phi);
        }
        resid_sum
    }

    /// Pre-fusion single-document sweep (reference kernel, verbatim).
    pub fn sweep_doc_reference(
        &mut self,
        d: usize,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> f64 {
        let k = self.k;
        let mut resid_sum = 0f64;
        self.theta_old[d * k..(d + 1) * k]
            .copy_from_slice(&self.theta[d * k..(d + 1) * k]);
        for idx in self.data.row_range(d) {
            let wi = self.data.col[idx] as usize;
            if !sel.word_sel[wi] {
                continue;
            }
            resid_sum += self.update_entry(d, idx, wi, phi_wk, phi_tot, sel, p, update_phi);
        }
        resid_sum
    }

    /// The Eq. 1/7 update of one non-zero entry (d, w): minus-corrected
    /// scores over the selected topics, mass-preserving renormalization,
    /// θ̂/Δφ̂/r delta propagation. Reads the `theta_old` Jacobi snapshot —
    /// callers must have snapshotted the row (or the whole matrix) first.
    /// This is the pre-fusion reference kernel; the hot paths run
    /// [`fused_update`], which reproduces it bit-for-bit.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn update_entry(
        &mut self,
        d: usize,
        idx: usize,
        wi: usize,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> f64 {
        debug_assert_eq!(phi_wk.len(), self.data.w * self.k);
        let k = self.k;
        let (alpha, beta) = (p.alpha, p.beta);
        let wbeta = self.data.w as f32 * beta;
        let mut resid_sum = 0f64;

        let x = self.data.val[idx];
        let mu = &mut self.mu[idx * k..(idx + 1) * k];
        let th_old = &self.theta_old[d * k..(d + 1) * k];
        let th = &mut self.theta[d * k..(d + 1) * k];
        let phi_row = &phi_wk[wi * k..(wi + 1) * k];

        let topics = sel.topics_of(wi);
        let scores = &mut self.scratch;
        let (mut mass_old, mut mass_new) = (0f32, 0f32);
        match topics {
            None => {
                // zipped iteration: no bounds checks, auto-vectorizable
                for ((((&m, &to), &ph), &pt), s) in mu
                    .iter()
                    .zip(th_old)
                    .zip(phi_row)
                    .zip(phi_tot)
                    .zip(scores.iter_mut())
                {
                    let c = x * m;
                    let th_m = (to - c).max(0.0) + alpha;
                    let ph_m = (ph - c).max(0.0) + beta;
                    let den = (pt - c).max(0.0) + wbeta;
                    let sv = th_m * ph_m / den.max(1e-30);
                    *s = sv;
                    mass_new += sv;
                    mass_old += m;
                }
            }
            Some(ts) => {
                for (j, &t) in ts.iter().enumerate() {
                    let t = t as usize;
                    let c = x * mu[t];
                    let th_m = (th_old[t] - c).max(0.0) + alpha;
                    let ph_m = (phi_row[t] - c).max(0.0) + beta;
                    let den = (phi_tot[t] - c).max(0.0) + wbeta;
                    let s = th_m * ph_m / den.max(1e-30);
                    scores[j] = s;
                    mass_new += s;
                    mass_old += mu[t];
                }
            }
        }
        if mass_new <= 0.0 || mass_old <= 0.0 {
            return 0.0; // nothing to redistribute
        }
        let scale = mass_old / mass_new;

        let dphi_row = if update_phi {
            Some(&mut self.dphi[wi * k..(wi + 1) * k])
        } else {
            None
        };
        let r_row = &mut self.r[wi * k..(wi + 1) * k];
        match topics {
            None => {
                let mut rsum = 0f32;
                if let Some(dp) = dphi_row {
                    for ((((m, &s), t_), d_), r_) in mu
                        .iter_mut()
                        .zip(scores.iter())
                        .zip(th.iter_mut())
                        .zip(dp.iter_mut())
                        .zip(r_row.iter_mut())
                    {
                        let new = s * scale;
                        let dm = new - *m;
                        *m = new;
                        *t_ += x * dm;
                        *d_ += x * dm;
                        let rr = x * dm.abs();
                        *r_ += rr;
                        rsum += rr;
                    }
                } else {
                    for (((m, &s), t_), r_) in mu
                        .iter_mut()
                        .zip(scores.iter())
                        .zip(th.iter_mut())
                        .zip(r_row.iter_mut())
                    {
                        let new = s * scale;
                        let dm = new - *m;
                        *m = new;
                        *t_ += x * dm;
                        let rr = x * dm.abs();
                        *r_ += rr;
                        rsum += rr;
                    }
                }
                resid_sum += rsum as f64;
            }
            Some(ts) => {
                if let Some(dp) = dphi_row {
                    for (j, &t) in ts.iter().enumerate() {
                        let t = t as usize;
                        let new = scores[j] * scale;
                        let dm = new - mu[t];
                        mu[t] = new;
                        th[t] += x * dm;
                        dp[t] += x * dm;
                        let rr = x * dm.abs();
                        r_row[t] += rr;
                        resid_sum += rr as f64;
                    }
                } else {
                    for (j, &t) in ts.iter().enumerate() {
                        let t = t as usize;
                        let new = scores[j] * scale;
                        let dm = new - mu[t];
                        mu[t] = new;
                        th[t] += x * dm;
                        let rr = x * dm.abs();
                        r_row[t] += rr;
                        resid_sum += rr as f64;
                    }
                }
            }
        }
        resid_sum
    }

    /// Per-document residual totals of the last sweep’s fresh residuals —
    /// the ABP document-scheduling signal (r_d = Σ_{w∈d} r_{w,d}).
    /// Computed from messages vs a recomputation is expensive, so ABP
    /// tracks it via [`ShardBp::sweep_docs`] return values instead; this
    /// helper exists for invariants/tests.
    pub fn doc_tokens(&self, d: usize) -> f64 {
        let (_, vs) = self.data.row(d);
        vs.iter().map(|&v| v as f64).sum()
    }
}

/// Gives `ShardBp` the worker side of the owner-sliced sparse allreduce:
/// the trait's `export_selected_into` default packs Δφ̂ and r at the
/// plan's flat indices (`w·K + k`, plan order) into the coordinator's
/// *reused* [`GatherBuf`](crate::comm::allreduce::GatherBuf) pool
/// (`comm::allreduce::SyncScratch`), per worker, in parallel on the
/// cluster — no per-sync allocation. In the coordinator's overlap mode
/// this export runs pipelined: worker n+1 packs while worker n's buffer
/// is folded into the owner slices.
impl ReduceSource for ShardBp {
    fn dense_parts(&self) -> (&[f32], &[f32]) {
        (&self.dphi, &self.r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{select_power, PowerParams};
    use crate::synth::SynthSpec;

    fn small_shard(seed: u64) -> (ShardBp, LdaParams) {
        let sc = crate::synth::generate(&SynthSpec::tiny(seed));
        let p = LdaParams::paper(8);
        let mut rng = Rng::new(seed);
        (ShardBp::init(sc.corpus, 8, &mut rng), p)
    }

    fn phi_of(shard: &ShardBp) -> (Vec<f32>, Vec<f32>) {
        // single-worker "global" phi = own gradient
        let phi = shard.dphi.clone();
        let k = shard.k;
        let mut tot = vec![0f32; k];
        for row in phi.chunks_exact(k) {
            for (t, &v) in row.iter().enumerate() {
                tot[t] += v;
            }
        }
        (phi, tot)
    }

    #[test]
    fn init_messages_normalized_and_mass_conserved() {
        let (s, _) = small_shard(1);
        for row in s.mu.chunks_exact(s.k) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        let tokens = s.data.tokens();
        let th_sum: f64 = s.theta.iter().map(|&v| v as f64).sum();
        let dp_sum: f64 = s.dphi.iter().map(|&v| v as f64).sum();
        assert!((th_sum - tokens).abs() < tokens * 1e-5);
        assert!((dp_sum - tokens).abs() < tokens * 1e-5);
    }

    #[test]
    fn full_sweep_preserves_mass_and_decreases_residual() {
        let (mut s, p) = small_shard(2);
        let sel = Selection::full(s.data.w);
        let tokens = s.data.tokens();
        // BP from random init dips, humps while topics differentiate,
        // then decays (see coordinator::PobpConfig::min_iters) — so check
        // mass conservation every sweep but convergence only at the end.
        let mut last = f64::INFINITY;
        for it in 0..40 {
            let (phi, tot) = phi_of(&s);
            s.clear_selected_residuals(&sel);
            last = s.sweep(&phi, &tot, &sel, &p, true);
            let dp_sum: f64 = s.dphi.iter().map(|&v| v as f64).sum();
            assert!((dp_sum - tokens).abs() < tokens * 1e-4, "iter {it}");
            assert!(last.is_finite() && last / tokens < 4.0, "exploded at {it}: {last}");
        }
        assert!(last / tokens < 0.1, "did not converge: {}", last / tokens);
    }

    #[test]
    fn subset_sweep_freezes_unselected() {
        let (mut s, p) = small_shard(3);
        let w = s.data.w;
        // one full sweep to get non-trivial residuals
        let sel_f = Selection::full(w);
        let (phi, tot) = phi_of(&s);
        s.clear_selected_residuals(&sel_f);
        s.sweep(&phi, &tot, &sel_f, &p, true);

        let ps = select_power(&s.r, w, s.k, &PowerParams { lambda_w: 0.2, lambda_k_times_k: 3 });
        let sel = Selection::from_power(&ps, w);
        let mu_before = s.mu.clone();
        let dphi_before = s.dphi.clone();
        let (phi, tot) = phi_of(&s);
        s.clear_selected_residuals(&sel);
        s.sweep(&phi, &tot, &sel, &p, true);

        // messages of un-selected words are bitwise frozen
        let k = s.k;
        for d in 0..s.data.docs() {
            for idx in s.data.row_range(d) {
                let wi = s.data.col[idx] as usize;
                if !sel.word_sel[wi] {
                    assert_eq!(
                        &s.mu[idx * k..(idx + 1) * k],
                        &mu_before[idx * k..(idx + 1) * k]
                    );
                }
            }
        }
        // dphi of un-selected pairs is bitwise frozen
        let sel_pairs: std::collections::HashSet<usize> =
            ps.flat_indices(k).iter().map(|&i| i as usize).collect();
        for i in 0..w * k {
            if !sel_pairs.contains(&i) {
                assert_eq!(s.dphi[i], dphi_before[i], "pair {i} moved");
            }
        }
        // mass still conserved (mass-preserving subset renorm)
        let tokens = s.data.tokens();
        let dp_sum: f64 = s.dphi.iter().map(|&v| v as f64).sum();
        assert!((dp_sum - tokens).abs() < tokens * 1e-4);
    }

    #[test]
    fn messages_stay_on_simplex_after_subset_updates() {
        let (mut s, p) = small_shard(4);
        let w = s.data.w;
        for i in 0..8 {
            let (phi, tot) = phi_of(&s);
            let sel = if i == 0 {
                Selection::full(w)
            } else {
                let ps = select_power(
                    &s.r, w, s.k,
                    &PowerParams { lambda_w: 0.3, lambda_k_times_k: 4 },
                );
                Selection::from_power(&ps, w)
            };
            s.clear_selected_residuals(&sel);
            s.sweep(&phi, &tot, &sel, &p, true);
        }
        for row in s.mu.chunks_exact(s.k) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "mu drifted off simplex: {sum}");
        }
    }

    #[test]
    fn update_phi_false_freezes_gradient() {
        let (mut s, p) = small_shard(5);
        let sel = Selection::full(s.data.w);
        let (phi, tot) = phi_of(&s);
        let dphi_before = s.dphi.clone();
        s.clear_selected_residuals(&sel);
        s.sweep(&phi, &tot, &sel, &p, false);
        assert_eq!(s.dphi, dphi_before);
    }

    #[test]
    fn export_selected_follows_plan_order() {
        let (mut s, p) = small_shard(6);
        let w = s.data.w;
        let sel = Selection::full(w);
        let (phi, tot) = phi_of(&s);
        s.clear_selected_residuals(&sel);
        s.sweep(&phi, &tot, &sel, &p, true);

        let ps = select_power(
            &s.r,
            w,
            s.k,
            &PowerParams { lambda_w: 0.2, lambda_k_times_k: 3 },
        );
        let flat = ps.flat_indices(s.k);
        let buf = s.export_selected(&flat);
        assert_eq!(buf.dphi.len(), flat.len());
        assert_eq!(buf.r.len(), flat.len());
        for (slot, &ix) in flat.iter().enumerate() {
            assert_eq!(buf.dphi[slot], s.dphi[ix as usize]);
            assert_eq!(buf.r[slot], s.r[ix as usize]);
        }
        // the reusing export (the coordinator's hot path) packs the same
        // bytes into a recycled buffer without growing it
        let mut reused = buf.clone();
        s.export_selected_into(&flat, &mut reused);
        assert_eq!(reused, buf);
    }

    #[test]
    fn selection_from_power_roundtrip() {
        let ps = PowerSet { words: vec![2, 0], topics: vec![vec![1, 3], vec![0]] };
        let sel = Selection::from_power(&ps, 4);
        assert!(sel.word_sel[0] && sel.word_sel[2]);
        assert!(!sel.word_sel[1] && !sel.word_sel[3]);
        assert_eq!(sel.topics_of(2).unwrap(), &[1, 3]);
        assert_eq!(sel.topics_of(0).unwrap(), &[0]);
        assert!(sel.topics_of(1).unwrap().is_empty());
    }

    #[test]
    fn doc_blocks_partition_and_merge_plan_consistent() {
        let (s, _) = small_shard(7);
        let nblocks = s.block_doc_off.len() - 1;
        assert!(nblocks >= 1);
        assert_eq!(s.block_doc_off[0], 0);
        assert_eq!(*s.block_doc_off.last().unwrap() as usize, s.data.docs());
        for b in 0..nblocks {
            assert!(s.block_doc_off[b] < s.block_doc_off[b + 1], "empty block {b}");
        }
        // every entry's scratch row names the entry's own word
        for b in 0..nblocks {
            let (d0, d1) = (s.block_doc_off[b] as usize, s.block_doc_off[b + 1] as usize);
            let base = s.block_row_off[b] as usize;
            for d in d0..d1 {
                for idx in s.data.row_range(d) {
                    let srow = base + s.nnz_row[idx] as usize;
                    assert_eq!(s.row_word[srow], s.data.col[idx]);
                }
            }
        }
        // merge lists: ascending scratch rows (= block order), word-consistent
        for wi in 0..s.data.w {
            let rows =
                &s.merge_rows[s.merge_ptr[wi] as usize..s.merge_ptr[wi + 1] as usize];
            for pair in rows.windows(2) {
                assert!(pair[0] < pair[1]);
            }
            for &srow in rows {
                assert_eq!(s.row_word[srow as usize] as usize, wi);
            }
        }
        // scratch rows partition exactly across blocks
        assert_eq!(
            *s.block_row_off.last().unwrap() as usize,
            s.row_word.len()
        );
        assert_eq!(s.merge_rows.len(), s.row_word.len());
        // merge-task word ranges cover the vocabulary exactly once
        assert_eq!(s.merge_bounds[0], 0);
        assert_eq!(*s.merge_bounds.last().unwrap() as usize, s.data.w);
        for pair in s.merge_bounds.windows(2) {
            assert!(pair[0] < pair[1]);
        }
    }
}
