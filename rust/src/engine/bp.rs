//! The native sparse BP worker: per-shard message passing (Eq. 1–3, 7–8).
//!
//! One `ShardBp` is the state a single (simulated) processor holds for its
//! document shard of the current mini-batch: per-non-zero messages μ, the
//! local document–topic statistics θ̂, the local gradient Δφ̂ (Eq. 15) and
//! the fresh residual matrix r (Eq. 7–8). The sweep consumes the *global*
//! φ̂ synchronized at the previous iteration (frozen during the sweep —
//! synchronous MPA semantics, Fig. 1) and updates only the power
//! (word, topic) pairs of the current [`Selection`].
//!
//! The masked update is mass-preserving within the selection (see
//! `python/compile/kernels/ref.py` for the shared contract): un-selected
//! messages stay bitwise-frozen, so Δφ̂ and r change only on selected
//! pairs and subset-only synchronization is exact.

use crate::comm::allreduce::ReduceSource;
use crate::corpus::Csr;
use crate::engine::traits::LdaParams;
use crate::sched::PowerSet;
use crate::util::rng::Rng;

/// The iteration schedule in worker-friendly form: a word membership
/// bitmap plus per-word topic lists (empty for un-selected words).
#[derive(Clone, Debug)]
pub struct Selection {
    pub full: bool,
    pub word_sel: Vec<bool>,
    /// offsets into `topic_ids`, len = W + 1
    pub topic_off: Vec<u32>,
    pub topic_ids: Vec<u32>,
}

impl Selection {
    pub fn full(w: usize) -> Selection {
        Selection {
            full: true,
            word_sel: vec![true; w],
            topic_off: vec![0; w + 1],
            topic_ids: Vec::new(),
        }
    }

    pub fn from_power(ps: &PowerSet, w: usize) -> Selection {
        let mut word_sel = vec![false; w];
        let mut per_word: Vec<&[u32]> = vec![&[]; w];
        for (i, &wi) in ps.words.iter().enumerate() {
            word_sel[wi as usize] = true;
            per_word[wi as usize] = &ps.topics[i];
        }
        let mut topic_off = Vec::with_capacity(w + 1);
        let mut topic_ids = Vec::with_capacity(ps.pairs());
        topic_off.push(0u32);
        for wi in 0..w {
            let start = topic_ids.len();
            topic_ids.extend_from_slice(per_word[wi]);
            // ascending topic order: better cache-line reuse in the K-row
            // gathers and the same accumulation order as the L2 masked
            // update (which is element-wise over ascending k)
            topic_ids[start..].sort_unstable();
            topic_off.push(topic_ids.len() as u32);
        }
        Selection { full: false, word_sel, topic_off, topic_ids }
    }

    /// Topic list of word `wi` (empty when un-selected; `None` = all K).
    #[inline]
    pub fn topics_of(&self, wi: usize) -> Option<&[u32]> {
        if self.full {
            None
        } else {
            Some(
                &self.topic_ids
                    [self.topic_off[wi] as usize..self.topic_off[wi + 1] as usize],
            )
        }
    }
}

/// Per-worker BP state over a document shard.
pub struct ShardBp {
    pub k: usize,
    pub data: Csr,
    /// messages, nnz × K (row per non-zero, topic-contiguous)
    pub mu: Vec<f32>,
    /// local θ̂, docs × K
    pub theta: Vec<f32>,
    /// local gradient Δφ̂ = Σ_d x·μ over this shard, W × K word-major
    pub dphi: Vec<f32>,
    /// fresh residuals of the last sweep, W × K word-major
    pub r: Vec<f32>,
    /// scratch score buffer (K)
    scratch: Vec<f32>,
    /// θ̂ snapshot read during a sweep (Jacobi semantics, see `sweep`)
    theta_old: Vec<f32>,
    /// CSC-style inverted index: non-zero entries grouped by word —
    /// offsets (W+1) into `by_word_idx` (§Perf: lets subset sweeps touch
    /// only the power words' entries instead of scanning all NNZ)
    by_word_ptr: Vec<u32>,
    by_word_idx: Vec<u32>,
    /// document of each non-zero entry (for the inverted traversal)
    nnz_doc: Vec<u32>,
}

impl ShardBp {
    /// Random message initialization (Fig. 4 lines 3–5).
    pub fn init(data: Csr, k: usize, rng: &mut Rng) -> ShardBp {
        let nnz = data.nnz();
        let docs = data.docs();
        let w = data.w;
        let mut mu = vec![0f32; nnz * k];
        for row in mu.chunks_exact_mut(k) {
            let mut sum = 0f32;
            for v in row.iter_mut() {
                *v = rng.f32() + 0.1;
                sum += *v;
            }
            let inv = 1.0 / sum;
            row.iter_mut().for_each(|v| *v *= inv);
        }
        // inverted index: counting sort of nnz entries by word
        let mut by_word_ptr = vec![0u32; w + 1];
        for &wid in &data.col {
            by_word_ptr[wid as usize + 1] += 1;
        }
        for i in 0..w {
            by_word_ptr[i + 1] += by_word_ptr[i];
        }
        let mut cursor = by_word_ptr.clone();
        let mut by_word_idx = vec![0u32; nnz];
        let mut nnz_doc = vec![0u32; nnz];
        for d in 0..docs {
            for idx in data.row_range(d) {
                let wid = data.col[idx] as usize;
                by_word_idx[cursor[wid] as usize] = idx as u32;
                cursor[wid] += 1;
                nnz_doc[idx] = d as u32;
            }
        }

        let mut s = ShardBp {
            k,
            data,
            mu,
            theta: vec![0.0; docs * k],
            dphi: vec![0.0; w * k],
            r: vec![0.0; w * k],
            scratch: vec![0.0; k],
            theta_old: vec![0.0; docs * k],
            by_word_ptr,
            by_word_idx,
            nnz_doc,
        };
        s.recompute_stats();
        s
    }

    /// Recompute θ̂ and Δφ̂ from scratch (Eq. 2–3 with current μ).
    pub fn recompute_stats(&mut self) {
        self.theta.fill(0.0);
        self.dphi.fill(0.0);
        let k = self.k;
        for d in 0..self.data.docs() {
            for idx in self.data.row_range(d) {
                let wi = self.data.col[idx] as usize;
                let x = self.data.val[idx];
                let mu = &self.mu[idx * k..(idx + 1) * k];
                let th = &mut self.theta[d * k..(d + 1) * k];
                for (t, &m) in mu.iter().enumerate() {
                    th[t] += x * m;
                }
                let dp = &mut self.dphi[wi * k..(wi + 1) * k];
                for (t, &m) in mu.iter().enumerate() {
                    dp[t] += x * m;
                }
            }
        }
    }

    /// Zero the fresh-residual entries of the selected pairs (before a
    /// sweep) so `r` holds exactly this iteration's Eq. (8) values there.
    pub fn clear_selected_residuals(&mut self, sel: &Selection) {
        if sel.full {
            self.r.fill(0.0);
            return;
        }
        let k = self.k;
        for (wi, &is_sel) in sel.word_sel.iter().enumerate() {
            if !is_sel {
                continue;
            }
            match sel.topics_of(wi) {
                None => self.r[wi * k..(wi + 1) * k].fill(0.0),
                Some(ts) => {
                    for &t in ts {
                        self.r[wi * k + t as usize] = 0.0;
                    }
                }
            }
        }
    }

    /// One message-passing sweep over the shard (Fig. 4 lines 6–8 /
    /// 15–20), reading the frozen global φ̂ (`phi_wk`, word-major) and its
    /// topic totals. Returns the summed residual of the sweep.
    ///
    /// The sweep is **Jacobi** (synchronous): every message update reads
    /// the θ̂ of the *previous* iteration, matching the AOT-compiled L2
    /// dense graph bit-for-bit in structure (see rust/tests/golden.rs and
    /// rust/tests/xla_parity.rs) and the per-iteration synchronization
    /// semantics of the paper's Fig. 4.
    ///
    /// `update_phi = false` freezes Δφ̂ (used for θ fold-in at evaluation
    /// time, where the heldout documents must not move the model).
    pub fn sweep(
        &mut self,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> f64 {
        // §Perf note: a word-inverted traversal (`sweep_selected`) was
        // measured 1.5x SLOWER than this doc-order scan for power
        // selections — the selected words are the Zipf head carrying most
        // of the NNZ, so the skip savings are small while the inverted
        // walk loses θ̂ locality. Doc-order + bitmap skip is the winner;
        // the inverted path is kept for tail-heavy selections and tests.
        let mut resid_sum = 0f64;
        for d in 0..self.data.docs() {
            resid_sum += self.sweep_doc(d, phi_wk, phi_tot, sel, p, update_phi);
        }
        resid_sum
    }

    /// Subset sweep through the inverted index: touches only the selected
    /// words' non-zero entries (O(active NNZ) instead of O(NNZ)).
    /// Jacobi-equivalent to the doc-order path: entries are visited once,
    /// scores read the θ̂ snapshot, and per-row float accumulation order
    /// is identical (CSR rows are word-sorted; the index is doc-sorted
    /// within each word). Beneficial only when the selection misses the
    /// Zipf head — see the §Perf note in [`ShardBp::sweep`].
    pub fn sweep_selected(
        &mut self,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> f64 {
        debug_assert!(!sel.full);
        self.theta_old.copy_from_slice(&self.theta);
        let k = self.k;
        let mut resid_sum = 0f64;
        for wi in 0..self.data.w {
            if !sel.word_sel[wi] {
                continue;
            }
            let lo = self.by_word_ptr[wi] as usize;
            let hi = self.by_word_ptr[wi + 1] as usize;
            for pos in lo..hi {
                let idx = self.by_word_idx[pos] as usize;
                let d = self.nnz_doc[idx] as usize;
                resid_sum += self.update_entry(d, idx, wi, phi_wk, phi_tot, sel, p, update_phi);
            }
        }
        let _ = k;
        resid_sum
    }

    /// Sweep a single document (the ABP active-scheduling granule; also
    /// the unit `sweep` iterates). Takes this doc's own Jacobi θ̂
    /// snapshot — documents only read their own θ̂ row, so per-doc
    /// snapshots are equivalent to a whole-shard snapshot.
    pub fn sweep_doc(
        &mut self,
        d: usize,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> f64 {
        let k = self.k;
        let mut resid_sum = 0f64;
        self.theta_old[d * k..(d + 1) * k]
            .copy_from_slice(&self.theta[d * k..(d + 1) * k]);
        for idx in self.data.row_range(d) {
            let wi = self.data.col[idx] as usize;
            if !sel.word_sel[wi] {
                continue;
            }
            resid_sum += self.update_entry(d, idx, wi, phi_wk, phi_tot, sel, p, update_phi);
        }
        resid_sum
    }

    /// The Eq. 1/7 update of one non-zero entry (d, w): minus-corrected
    /// scores over the selected topics, mass-preserving renormalization,
    /// θ̂/Δφ̂/r delta propagation. Reads the `theta_old` Jacobi snapshot —
    /// callers must have snapshotted the row (or the whole matrix) first.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn update_entry(
        &mut self,
        d: usize,
        idx: usize,
        wi: usize,
        phi_wk: &[f32],
        phi_tot: &[f32],
        sel: &Selection,
        p: &LdaParams,
        update_phi: bool,
    ) -> f64 {
        debug_assert_eq!(phi_wk.len(), self.data.w * self.k);
        let k = self.k;
        let (alpha, beta) = (p.alpha, p.beta);
        let wbeta = self.data.w as f32 * beta;
        let mut resid_sum = 0f64;

        let x = self.data.val[idx];
        let mu = &mut self.mu[idx * k..(idx + 1) * k];
        let th_old = &self.theta_old[d * k..(d + 1) * k];
        let th = &mut self.theta[d * k..(d + 1) * k];
        let phi_row = &phi_wk[wi * k..(wi + 1) * k];

        let topics = sel.topics_of(wi);
        let scores = &mut self.scratch;
        let (mut mass_old, mut mass_new) = (0f32, 0f32);
        match topics {
            None => {
                // zipped iteration: no bounds checks, auto-vectorizable
                for ((((&m, &to), &ph), &pt), s) in mu
                    .iter()
                    .zip(th_old)
                    .zip(phi_row)
                    .zip(phi_tot)
                    .zip(scores.iter_mut())
                {
                    let c = x * m;
                    let th_m = (to - c).max(0.0) + alpha;
                    let ph_m = (ph - c).max(0.0) + beta;
                    let den = (pt - c).max(0.0) + wbeta;
                    let sv = th_m * ph_m / den.max(1e-30);
                    *s = sv;
                    mass_new += sv;
                    mass_old += m;
                }
            }
            Some(ts) => {
                for (j, &t) in ts.iter().enumerate() {
                    let t = t as usize;
                    let c = x * mu[t];
                    let th_m = (th_old[t] - c).max(0.0) + alpha;
                    let ph_m = (phi_row[t] - c).max(0.0) + beta;
                    let den = (phi_tot[t] - c).max(0.0) + wbeta;
                    let s = th_m * ph_m / den.max(1e-30);
                    scores[j] = s;
                    mass_new += s;
                    mass_old += mu[t];
                }
            }
        }
        if mass_new <= 0.0 || mass_old <= 0.0 {
            return 0.0; // nothing to redistribute
        }
        let scale = mass_old / mass_new;

        let dphi_row = if update_phi {
            Some(&mut self.dphi[wi * k..(wi + 1) * k])
        } else {
            None
        };
        let r_row = &mut self.r[wi * k..(wi + 1) * k];
        match topics {
            None => {
                let mut rsum = 0f32;
                if let Some(dp) = dphi_row {
                    for ((((m, &s), t_), d_), r_) in mu
                        .iter_mut()
                        .zip(scores.iter())
                        .zip(th.iter_mut())
                        .zip(dp.iter_mut())
                        .zip(r_row.iter_mut())
                    {
                        let new = s * scale;
                        let dm = new - *m;
                        *m = new;
                        *t_ += x * dm;
                        *d_ += x * dm;
                        let rr = x * dm.abs();
                        *r_ += rr;
                        rsum += rr;
                    }
                } else {
                    for (((m, &s), t_), r_) in mu
                        .iter_mut()
                        .zip(scores.iter())
                        .zip(th.iter_mut())
                        .zip(r_row.iter_mut())
                    {
                        let new = s * scale;
                        let dm = new - *m;
                        *m = new;
                        *t_ += x * dm;
                        let rr = x * dm.abs();
                        *r_ += rr;
                        rsum += rr;
                    }
                }
                resid_sum += rsum as f64;
            }
            Some(ts) => {
                if let Some(dp) = dphi_row {
                    for (j, &t) in ts.iter().enumerate() {
                        let t = t as usize;
                        let new = scores[j] * scale;
                        let dm = new - mu[t];
                        mu[t] = new;
                        th[t] += x * dm;
                        dp[t] += x * dm;
                        let rr = x * dm.abs();
                        r_row[t] += rr;
                        resid_sum += rr as f64;
                    }
                } else {
                    for (j, &t) in ts.iter().enumerate() {
                        let t = t as usize;
                        let new = scores[j] * scale;
                        let dm = new - mu[t];
                        mu[t] = new;
                        th[t] += x * dm;
                        let rr = x * dm.abs();
                        r_row[t] += rr;
                        resid_sum += rr as f64;
                    }
                }
            }
        }
        resid_sum
    }

    /// Per-document residual totals of the last sweep’s fresh residuals —
    /// the ABP document-scheduling signal (r_d = Σ_{w∈d} r_{w,d}).
    /// Computed from messages vs a recomputation is expensive, so ABP
    /// tracks it via [`ShardBp::sweep_doc`] return values instead; this
    /// helper exists for invariants/tests.
    pub fn doc_tokens(&self, d: usize) -> f64 {
        let (_, vs) = self.data.row(d);
        vs.iter().map(|&v| v as f64).sum()
    }
}

/// Gives `ShardBp` the worker side of the sparse allreduce: the trait's
/// `export_selected` default packs Δφ̂ and r at the plan's flat indices
/// (`w·K + k`, plan order) into a
/// [`GatherBuf`](crate::comm::allreduce::GatherBuf), per worker, in
/// parallel on the cluster (comm::allreduce).
impl ReduceSource for ShardBp {
    fn dense_parts(&self) -> (&[f32], &[f32]) {
        (&self.dphi, &self.r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{select_power, PowerParams};
    use crate::synth::SynthSpec;

    fn small_shard(seed: u64) -> (ShardBp, LdaParams) {
        let sc = crate::synth::generate(&SynthSpec::tiny(seed));
        let p = LdaParams::paper(8);
        let mut rng = Rng::new(seed);
        (ShardBp::init(sc.corpus, 8, &mut rng), p)
    }

    fn phi_of(shard: &ShardBp) -> (Vec<f32>, Vec<f32>) {
        // single-worker "global" phi = own gradient
        let phi = shard.dphi.clone();
        let k = shard.k;
        let mut tot = vec![0f32; k];
        for row in phi.chunks_exact(k) {
            for (t, &v) in row.iter().enumerate() {
                tot[t] += v;
            }
        }
        (phi, tot)
    }

    #[test]
    fn init_messages_normalized_and_mass_conserved() {
        let (s, _) = small_shard(1);
        for row in s.mu.chunks_exact(s.k) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        let tokens = s.data.tokens();
        let th_sum: f64 = s.theta.iter().map(|&v| v as f64).sum();
        let dp_sum: f64 = s.dphi.iter().map(|&v| v as f64).sum();
        assert!((th_sum - tokens).abs() < tokens * 1e-5);
        assert!((dp_sum - tokens).abs() < tokens * 1e-5);
    }

    #[test]
    fn full_sweep_preserves_mass_and_decreases_residual() {
        let (mut s, p) = small_shard(2);
        let sel = Selection::full(s.data.w);
        let tokens = s.data.tokens();
        // BP from random init dips, humps while topics differentiate,
        // then decays (see coordinator::PobpConfig::min_iters) — so check
        // mass conservation every sweep but convergence only at the end.
        let mut last = f64::INFINITY;
        for it in 0..40 {
            let (phi, tot) = phi_of(&s);
            s.clear_selected_residuals(&sel);
            last = s.sweep(&phi, &tot, &sel, &p, true);
            let dp_sum: f64 = s.dphi.iter().map(|&v| v as f64).sum();
            assert!((dp_sum - tokens).abs() < tokens * 1e-4, "iter {it}");
            assert!(last.is_finite() && last / tokens < 4.0, "exploded at {it}: {last}");
        }
        assert!(last / tokens < 0.1, "did not converge: {}", last / tokens);
    }

    #[test]
    fn subset_sweep_freezes_unselected() {
        let (mut s, p) = small_shard(3);
        let w = s.data.w;
        // one full sweep to get non-trivial residuals
        let sel_f = Selection::full(w);
        let (phi, tot) = phi_of(&s);
        s.clear_selected_residuals(&sel_f);
        s.sweep(&phi, &tot, &sel_f, &p, true);

        let ps = select_power(&s.r, w, s.k, &PowerParams { lambda_w: 0.2, lambda_k_times_k: 3 });
        let sel = Selection::from_power(&ps, w);
        let mu_before = s.mu.clone();
        let dphi_before = s.dphi.clone();
        let (phi, tot) = phi_of(&s);
        s.clear_selected_residuals(&sel);
        s.sweep(&phi, &tot, &sel, &p, true);

        // messages of un-selected words are bitwise frozen
        let k = s.k;
        for d in 0..s.data.docs() {
            for idx in s.data.row_range(d) {
                let wi = s.data.col[idx] as usize;
                if !sel.word_sel[wi] {
                    assert_eq!(
                        &s.mu[idx * k..(idx + 1) * k],
                        &mu_before[idx * k..(idx + 1) * k]
                    );
                }
            }
        }
        // dphi of un-selected pairs is bitwise frozen
        let sel_pairs: std::collections::HashSet<usize> =
            ps.flat_indices(k).iter().map(|&i| i as usize).collect();
        for i in 0..w * k {
            if !sel_pairs.contains(&i) {
                assert_eq!(s.dphi[i], dphi_before[i], "pair {i} moved");
            }
        }
        // mass still conserved (mass-preserving subset renorm)
        let tokens = s.data.tokens();
        let dp_sum: f64 = s.dphi.iter().map(|&v| v as f64).sum();
        assert!((dp_sum - tokens).abs() < tokens * 1e-4);
    }

    #[test]
    fn messages_stay_on_simplex_after_subset_updates() {
        let (mut s, p) = small_shard(4);
        let w = s.data.w;
        for i in 0..8 {
            let (phi, tot) = phi_of(&s);
            let sel = if i == 0 {
                Selection::full(w)
            } else {
                let ps = select_power(
                    &s.r, w, s.k,
                    &PowerParams { lambda_w: 0.3, lambda_k_times_k: 4 },
                );
                Selection::from_power(&ps, w)
            };
            s.clear_selected_residuals(&sel);
            s.sweep(&phi, &tot, &sel, &p, true);
        }
        for row in s.mu.chunks_exact(s.k) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "mu drifted off simplex: {sum}");
        }
    }

    #[test]
    fn update_phi_false_freezes_gradient() {
        let (mut s, p) = small_shard(5);
        let sel = Selection::full(s.data.w);
        let (phi, tot) = phi_of(&s);
        let dphi_before = s.dphi.clone();
        s.clear_selected_residuals(&sel);
        s.sweep(&phi, &tot, &sel, &p, false);
        assert_eq!(s.dphi, dphi_before);
    }

    #[test]
    fn export_selected_follows_plan_order() {
        let (mut s, p) = small_shard(6);
        let w = s.data.w;
        let sel = Selection::full(w);
        let (phi, tot) = phi_of(&s);
        s.clear_selected_residuals(&sel);
        s.sweep(&phi, &tot, &sel, &p, true);

        let ps = select_power(
            &s.r,
            w,
            s.k,
            &PowerParams { lambda_w: 0.2, lambda_k_times_k: 3 },
        );
        let flat = ps.flat_indices(s.k);
        let buf = s.export_selected(&flat);
        assert_eq!(buf.dphi.len(), flat.len());
        assert_eq!(buf.r.len(), flat.len());
        for (slot, &ix) in flat.iter().enumerate() {
            assert_eq!(buf.dphi[slot], s.dphi[ix as usize]);
            assert_eq!(buf.r[slot], s.r[ix as usize]);
        }
    }

    #[test]
    fn selection_from_power_roundtrip() {
        let ps = PowerSet { words: vec![2, 0], topics: vec![vec![1, 3], vec![0]] };
        let sel = Selection::from_power(&ps, 4);
        assert!(sel.word_sel[0] && sel.word_sel[2]);
        assert!(!sel.word_sel[1] && !sel.word_sel[3]);
        assert_eq!(sel.topics_of(2).unwrap(), &[1, 3]);
        assert_eq!(sel.topics_of(0).unwrap(), &[0]);
        assert!(sel.topics_of(1).unwrap().is_empty());
    }
}
