//! Multi-core architecture (MCA) mode — the paper's future work (§5):
//! "We may avoid serious race conditions by dynamical scheduling of
//! non-conflict subsets of vocabulary words and topics."
//!
//! Threads share one φ̂ matrix in memory instead of keeping private copies
//! (zero communication, the MCA premise), and race-freedom comes from a
//! **streaming vocabulary partition** in the style of Yan, Xu & Qi's GPU
//! LDA (the paper's [13]): the vocabulary is split into N word-streams;
//! round r has thread n process only stream (n + r) mod N of its document
//! shard. Streams are word-disjoint, so concurrent φ̂ row updates never
//! collide; a barrier separates rounds, making the whole iteration
//! deterministic. φ̂_Σ (per-topic totals) is refreshed at round barriers —
//! the intra-round staleness is the standard MCA relaxation.
//!
//! The paper's [13] also notes the partition causes *load imbalance*;
//! [`McaResult::imbalance`] measures exactly that, and the stream builder
//! balances by non-zero count (greedy LPT) rather than word id to keep it
//! small.

use crate::corpus::{shard_ranges, Csr};
use crate::engine::bp::{Selection, ShardBp};
use crate::engine::traits::{IterStat, LdaParams, Model, TrainResult};
use crate::util::partial_sort::top_k_desc;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// MCA configuration.
#[derive(Clone, Debug)]
pub struct McaConfig {
    /// threads = streams
    pub n_threads: usize,
    pub max_iters: usize,
    pub min_iters: usize,
    pub converge_thresh: f64,
    pub converge_rel: f64,
    pub seed: u64,
}

impl Default for McaConfig {
    fn default() -> Self {
        McaConfig {
            n_threads: 4,
            max_iters: 60,
            min_iters: 5,
            converge_thresh: 0.1,
            converge_rel: 0.01,
            seed: 42,
        }
    }
}

/// Greedy LPT assignment of words to `n` streams balancing per-stream
/// non-zero counts. Returns (stream id per word, per-stream nnz).
pub fn build_streams(corpus: &Csr, n: usize) -> (Vec<u32>, Vec<u64>) {
    let mut wt: Vec<f32> = vec![0.0; corpus.w];
    for &wid in &corpus.col {
        wt[wid as usize] += 1.0;
    }
    let order = top_k_desc(&wt, corpus.w);
    let mut stream_of = vec![0u32; corpus.w];
    let mut load = vec![0u64; n];
    for &wid in &order {
        // place the heaviest remaining word on the lightest stream
        let (s, _) = load.iter().enumerate().min_by_key(|&(_, &l)| l).unwrap();
        stream_of[wid as usize] = s as u32;
        load[s] += wt[wid as usize] as u64;
    }
    (stream_of, load)
}

/// Load imbalance = max stream nnz / mean stream nnz (1.0 = perfect).
pub fn imbalance(load: &[u64]) -> f64 {
    let max = *load.iter().max().unwrap_or(&0) as f64;
    let mean = load.iter().sum::<u64>() as f64 / load.len().max(1) as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

/// Shared-memory training result (TrainResult + MCA diagnostics).
pub struct McaResult {
    pub result: TrainResult,
    /// max/mean per-stream nnz — the paper's [13] load-imbalance concern
    pub imbalance: f64,
}

/// Train batch LDA with shared-φ̂ multi-core BP.
pub fn fit_mca(corpus: &Csr, params: &LdaParams, cfg: &McaConfig) -> McaResult {
    let wall = Stopwatch::new();
    let (w, k) = (corpus.w, params.k);
    let n = cfg.n_threads.max(1);
    let tokens = corpus.tokens().max(1.0);

    let (stream_of, load) = build_streams(corpus, n);
    let ranges = shard_ranges(corpus.docs(), n);
    let mut rng = Rng::new(cfg.seed);
    let mut shards: Vec<ShardBp> = ranges
        .iter()
        .map(|rg| {
            let mut wrng = rng.split(rg.start as u64);
            ShardBp::init(corpus.slice_docs(rg.start, rg.end), k, &mut wrng)
        })
        .collect();

    // the SHARED global φ̂ = Σ shards' gradients, plus its topic totals
    let mut phi = vec![0f32; w * k];
    for s in &shards {
        for (g, &v) in phi.iter_mut().zip(&s.dphi) {
            *g += v;
        }
    }
    let mut phi_tot = vec![0f32; k];
    for row in phi.chunks_exact(k) {
        for (t, &v) in row.iter().enumerate() {
            phi_tot[t] += v;
        }
    }

    // per-stream word Selections: stream s == the words of that stream
    let stream_sel: Vec<Selection> = (0..n)
        .map(|s| {
            let mut sel = Selection::full(w);
            sel.word_sel = stream_of.iter().map(|&x| x == s as u32).collect();
            sel
        })
        .collect();

    let mut ledger = crate::comm::Ledger::new(crate::comm::NetModel::infiniband_20gbps());
    let mut history = Vec::new();
    let mut prev_resid = f64::INFINITY;
    let mut first_resid = f64::INFINITY;

    for t in 1..=cfg.max_iters {
        let t0 = std::time::Instant::now();
        let mut resid_total = 0f64;

        // Each round: thread i sweeps (shard i, stream (i + round) % n)
        // against the SHARED φ̂. Word-disjoint streams make the row
        // updates race-free; φ̂ rows the sweep *reads* for other words are
        // stable because only the owning thread may write them this round.
        //
        // To keep the reproduction strictly deterministic, threads read a
        // per-round shared snapshot and their word-disjoint row deltas
        // are folded in at the round barrier (an equivalent, unsafe-free
        // rendering of "write the shared rows you own").
        for round in 0..n {
            let phi_snapshot = phi.clone();
            // collect each thread's (stream) sweep results in parallel
            let results: Vec<(usize, f64)> = std::thread::scope(|scope| {
                let phi_ref = &phi_snapshot;
                let tot_ref = &phi_tot;
                let sels = &stream_sel;
                let handles: Vec<_> = shards
                    .iter_mut()
                    .enumerate()
                    .map(|(i, shard)| {
                        let stream = (i + round) % n;
                        scope.spawn(move || {
                            let sel = &sels[stream];
                            shard.clear_selected_residuals(sel);
                            let r = shard.sweep(phi_ref, tot_ref, sel, params, true);
                            (stream, r)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (_, r) in results {
                resid_total += r;
            }
            // barrier: rebuild the shared φ̂ rows from the shard gradients
            // (cheap: only this round's streams changed, but a full
            // rebuild keeps the code obviously correct; the perf pass
            // showed it is not the bottleneck at bench scale)
            phi.fill(0.0);
            for s in &shards {
                for (g, &v) in phi.iter_mut().zip(&s.dphi) {
                    *g += v;
                }
            }
            phi_tot.fill(0.0);
            for row in phi.chunks_exact(k) {
                for (tt, &v) in row.iter().enumerate() {
                    phi_tot[tt] += v;
                }
            }
        }
        ledger.record_compute(&[t0.elapsed().as_secs_f64()]);

        let resid_per_token = resid_total / tokens;
        history.push(IterStat {
            batch: 0,
            iter: t,
            residual_per_token: resid_per_token,
            synced_pairs: 0, // shared memory: nothing on the wire
            sim_elapsed: ledger.total_secs(),
            wall_elapsed: wall.total_secs(),
        });
        if t == 1 {
            first_resid = resid_per_token.max(1e-12);
        }
        if t >= cfg.min_iters
            && resid_per_token <= cfg.converge_thresh
            && resid_per_token <= cfg.converge_rel * first_resid
            && resid_per_token <= prev_resid
        {
            break;
        }
        prev_resid = resid_per_token;
    }

    McaResult {
        result: TrainResult {
            model: Model { k, w, phi_wk: phi },
            history,
            ledger,
            wall_secs: wall.total_secs(),
            snapshots: vec![],
        },
        imbalance: imbalance(&load),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::{generate, SynthSpec};

    fn tiny() -> Csr {
        generate(&SynthSpec::tiny(51)).corpus
    }

    #[test]
    fn streams_partition_vocabulary() {
        let c = tiny();
        let (stream_of, load) = build_streams(&c, 4);
        assert_eq!(stream_of.len(), c.w);
        assert!(stream_of.iter().all(|&s| s < 4));
        assert_eq!(load.iter().sum::<u64>(), c.nnz() as u64);
    }

    #[test]
    fn lpt_balances_zipf_vocabulary() {
        // Zipf word loads are exactly the adversarial case [13] worries
        // about; LPT should keep imbalance under ~1.3 at bench scale
        let c = tiny();
        let (_, load) = build_streams(&c, 4);
        let imb = imbalance(&load);
        assert!(imb < 1.3, "imbalance {imb}");
    }

    #[test]
    fn mca_conserves_mass_and_converges() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let r = fit_mca(&c, &params, &McaConfig { n_threads: 4, ..Default::default() });
        assert!((r.result.model.mass() - c.tokens()).abs() < c.tokens() * 1e-3);
        assert!(r.imbalance >= 1.0);
        let last = r.result.history.last().unwrap().residual_per_token;
        assert!(last.is_finite());
    }

    #[test]
    fn mca_is_deterministic() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = McaConfig { n_threads: 3, max_iters: 10, ..Default::default() };
        let a = fit_mca(&c, &params, &cfg);
        let b = fit_mca(&c, &params, &cfg);
        assert_eq!(a.result.model.phi_wk, b.result.model.phi_wk);
    }

    #[test]
    fn mca_quality_matches_mpa() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let mca = fit_mca(&c, &params, &McaConfig { n_threads: 4, max_iters: 40, ..Default::default() });
        let mpa = crate::coordinator::fit(&c, &params, &crate::coordinator::PobpConfig {
            n_workers: 4,
            nnz_budget: usize::MAX,
            power: crate::sched::PowerParams::full(),
            max_iters: 40,
            ..Default::default()
        });
        let p_mca = crate::eval::perplexity::heldin_perplexity(&mca.result.model, &c, &params);
        let p_mpa = crate::eval::perplexity::heldin_perplexity(&mpa.model, &c, &params);
        assert!(
            (p_mca.ln() - p_mpa.ln()).abs() < 0.2,
            "MCA {p_mca} vs MPA {p_mpa}"
        );
    }

    #[test]
    fn mca_pays_no_communication() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let r = fit_mca(&c, &params, &McaConfig { n_threads: 4, max_iters: 5, ..Default::default() });
        assert_eq!(r.result.ledger.comm_secs, 0.0);
        assert_eq!(r.result.ledger.wire_bytes, 0);
    }
}
