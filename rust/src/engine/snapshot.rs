//! Incremental φ̂ snapshot engine — retires the last O(W·K)-per-iteration
//! leader cost in ABP.
//!
//! The ABP loop needs a *frozen* global φ̂ (and its topic totals) for each
//! sweep: the sweep mutates `ShardBp::dphi` in place, so it cannot read
//! the matrix it is writing (Jacobi semantics). Before this engine the
//! loop cloned the full `W × K` matrix and rebuilt the totals from
//! scratch every iteration — O(W·K) leader work even when the power
//! selection touched only a few percent of the pairs. The selection
//! structure makes incremental maintenance exact (Zeng et al.,
//! "Memory-Efficient Topic Modeling"): a sweep changes Δφ̂ *only on the
//! selected (word, topic) pairs* (the freeze contract pinned by
//! `engine::bp`'s tests), so publishing the sweep into the frozen view
//! is O(selected pairs + W), not O(W·K) — the O(W) term is a flat scan
//! of the selection's word bitmap, the same cost ABP's per-iteration
//! selection build (`select_power` / `Selection::from_power`) already
//! pays; it is the K-wide per-word work that is retired.
//!
//! # Invariants (the snapshot contract)
//!
//! * **Frozen view is exact**: after every [`PhiSnapshot::apply`], the
//!   view is **bitwise equal** to the source matrix — the clone the old
//!   loop made. Selected pairs are copied verbatim; un-selected pairs
//!   were bitwise frozen by the sweep, so the stale copies are already
//!   the right bits. `rust/tests/snapshot_equiv.rs` pins this against
//!   the retained [`clone_rebuild`] oracle across full and power-subset
//!   selections at thread budgets 1/2/8.
//! * **Totals live in f64**: subset publishes move the topic totals by
//!   *exact* deltas (`new as f64 − old as f64`; both promotions are
//!   exact, so each step adds precisely the value change), the same
//!   protocol that fixed the coordinator's drift
//!   (`comm::allreduce::GlobalState`). The kernels read the f32 render
//!   via [`PhiSnapshot::phi_tot`].
//! * **Dense resync knob**: f64 accumulation still rounds, so repeated
//!   subset deltas can drift from a from-scratch rebuild at the 1e-13
//!   relative level. [`PhiSnapshot`] rebuilds the totals from scratch
//!   (f64, word-ascending — the oracle's op order, so the result is
//!   bitwise equal to the oracle's) every `resync_every` subset applies,
//!   and on every dense (full-selection) apply. With `resync_every = 1`
//!   the whole trajectory is bitwise identical to the clone-and-rebuild
//!   oracle; larger cadences trade that for O(selected) publishes, with
//!   the drift bounded by [`PhiSnapshot::totals_drift`] (pinned by the
//!   drift test).

use crate::engine::bp::Selection;
use crate::sched::PowerSet;

/// Persistent frozen φ̂ view + f64-backed topic totals (module doc).
#[derive(Clone, Debug)]
pub struct PhiSnapshot {
    k: usize,
    /// the frozen `W × K` view the sweeps read — bitwise equal to the
    /// source matrix after every publish
    phi: Vec<f32>,
    /// f64 topic totals (exact deltas on subset publishes, from-scratch
    /// rebuild on dense publishes/resyncs)
    tot64: Vec<f64>,
    /// f32 render of `tot64` — what the sweep kernels consume
    tot32: Vec<f32>,
    /// subset publishes since the last dense totals rebuild
    since_resync: usize,
    /// dense totals-resync cadence: rebuild from scratch every this many
    /// subset publishes (0 = only on dense publishes; 1 = every publish,
    /// i.e. bitwise the clone-and-rebuild oracle)
    pub resync_every: usize,
}

impl PhiSnapshot {
    /// Freeze `src` (full copy + from-scratch f64 totals).
    pub fn new(src: &[f32], k: usize, resync_every: usize) -> PhiSnapshot {
        let mut s = PhiSnapshot {
            k,
            phi: src.to_vec(),
            tot64: vec![0.0; k],
            tot32: vec![0.0; k],
            since_resync: 0,
            resync_every,
        };
        s.resync_totals();
        s
    }

    /// The frozen φ̂ view (word-major `W × K`).
    pub fn phi(&self) -> &[f32] {
        &self.phi
    }

    /// Topic totals φ̂_Σ as the f32 render the sweep kernels read.
    pub fn phi_tot(&self) -> &[f32] {
        &self.tot32
    }

    /// Publish a sweep's changes from `src`: dense copy for full
    /// selections, O(selected pairs + W) delta application otherwise
    /// (module doc). `src` must differ from the last published state
    /// only on `sel`'s pairs — exactly what the sweep freeze contract
    /// guarantees.
    pub fn apply(&mut self, src: &[f32], sel: &Selection) {
        if sel.full {
            self.apply_dense(src);
        } else {
            self.apply_selected(src, sel);
        }
    }

    /// Dense publish: full copy + from-scratch f64 totals (the
    /// unavoidable O(W·K) case — everything may have changed).
    pub fn apply_dense(&mut self, src: &[f32]) {
        debug_assert_eq!(src.len(), self.phi.len());
        self.phi.copy_from_slice(src);
        self.resync_totals();
    }

    /// Dense publish from a **sliced** source — the sharded storage
    /// mode's snapshot path: the per-owner row-aligned φ̂ slices are
    /// copied consecutively (owner order = dense row order) and the f64
    /// totals rebuilt from scratch. Bitwise identical to
    /// [`PhiSnapshot::apply_dense`] on the concatenation, without the
    /// caller ever materializing it.
    pub fn apply_dense_parts(&mut self, parts: &[&[f32]]) {
        debug_assert_eq!(
            parts.iter().map(|p| p.len()).sum::<usize>(),
            self.phi.len()
        );
        let mut off = 0;
        for p in parts {
            self.phi[off..off + p.len()].copy_from_slice(p);
            off += p.len();
        }
        self.resync_totals();
    }

    /// Subset publish: copy `src` at the selected pairs and move the f64
    /// totals by the exact per-pair deltas. O(selected pairs + W) — the
    /// word-bitmap scan; no K-wide work on un-selected words.
    pub fn apply_selected(&mut self, src: &[f32], sel: &Selection) {
        debug_assert_eq!(src.len(), self.phi.len());
        let k = self.k;
        for (wi, &is_sel) in sel.word_sel.iter().enumerate() {
            if !is_sel {
                continue;
            }
            // a full per-word topic list (K distinct ids in [0, K)) is
            // the whole row: take the zipped lane path — bounds-check
            // free and vectorizable, the same per-pair f64 op order as
            // the indexed path (t ascending). The paper-default
            // λ_K·K = K selection hits this on every selected word.
            let full_row = match sel.topics_of(wi) {
                None => true,
                Some(ts) => ts.len() == k,
            };
            if full_row {
                let row_src = &src[wi * k..(wi + 1) * k];
                let row = &mut self.phi[wi * k..(wi + 1) * k];
                for ((slot, d), &s) in
                    self.tot64.iter_mut().zip(row.iter_mut()).zip(row_src)
                {
                    *slot += s as f64 - *d as f64;
                    *d = s;
                }
            } else if let Some(ts) = sel.topics_of(wi) {
                for &t in ts {
                    let t = t as usize;
                    let i = wi * k + t;
                    let new = src[i];
                    let old = self.phi[i];
                    self.tot64[t] += new as f64 - old as f64;
                    self.phi[i] = new;
                }
            }
        }
        self.finish_subset_publish();
    }

    /// Subset publish straight off a [`PowerSet`] — ABP's hot path. The
    /// explicit selected-word list makes this truly **O(selected
    /// pairs)**: no scan of the W-wide word bitmap at all. Copies the
    /// same pairs as [`PhiSnapshot::apply_selected`] on the
    /// corresponding `Selection` (the view bits are identical — copies
    /// are order-independent); the f64 totals deltas accumulate in
    /// selection order instead of word-ascending order, which is a pure
    /// function of the `PowerSet` (deterministic) and bounded by the
    /// same drift/resync contract.
    pub fn apply_power(&mut self, src: &[f32], ps: &PowerSet) {
        debug_assert_eq!(src.len(), self.phi.len());
        let k = self.k;
        for (ts, &wi) in ps.topics.iter().zip(&ps.words) {
            let wi = wi as usize;
            if ts.len() == k {
                let row_src = &src[wi * k..(wi + 1) * k];
                let row = &mut self.phi[wi * k..(wi + 1) * k];
                for ((slot, d), &s) in
                    self.tot64.iter_mut().zip(row.iter_mut()).zip(row_src)
                {
                    *slot += s as f64 - *d as f64;
                    *d = s;
                }
            } else {
                for &t in ts {
                    let t = t as usize;
                    let i = wi * k + t;
                    let new = src[i];
                    let old = self.phi[i];
                    self.tot64[t] += new as f64 - old as f64;
                    self.phi[i] = new;
                }
            }
        }
        self.finish_subset_publish();
    }

    /// Shared tail of the subset publishes: advance the resync counter
    /// and either rebuild the totals from scratch (cadence reached) or
    /// re-render the f32 view.
    fn finish_subset_publish(&mut self) {
        self.since_resync += 1;
        if self.resync_every > 0 && self.since_resync >= self.resync_every {
            self.resync_totals();
        } else {
            self.render_tot32();
        }
    }

    /// Rebuild the f64 totals from the frozen view (word-ascending — the
    /// same op order as [`clone_rebuild`], so the result is bitwise equal
    /// to the oracle's) and reset the resync counter.
    ///
    /// NOTE: this is deliberately the same accumulation protocol as
    /// `comm::allreduce::GlobalState::recompute_totals` (φ̂ half) — the
    /// two live in different layers (worker-local engine vs coordinator
    /// replica, with different state shapes), so the protocol is
    /// duplicated rather than shared; a change to the op order or the
    /// f32 render rule must land in both, and the drift/equivalence
    /// tests on each side pin it.
    pub fn resync_totals(&mut self) {
        self.tot64.fill(0.0);
        for row in self.phi.chunks_exact(self.k) {
            for (t, &v) in row.iter().enumerate() {
                self.tot64[t] += v as f64;
            }
        }
        self.since_resync = 0;
        self.render_tot32();
    }

    fn render_tot32(&mut self) {
        for (o, &v) in self.tot32.iter_mut().zip(&self.tot64) {
            *o = v as f32;
        }
    }

    /// Drift diagnostics: max |running − recomputed| over the f64 topic
    /// totals. Bounded by f64 rounding between resyncs; exactly zero
    /// right after one.
    pub fn totals_drift(&self) -> f64 {
        let mut fresh = vec![0f64; self.k];
        for row in self.phi.chunks_exact(self.k) {
            for (t, &v) in row.iter().enumerate() {
                fresh[t] += v as f64;
            }
        }
        fresh
            .iter()
            .zip(&self.tot64)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// The retained clone-and-rebuild oracle — the per-iteration publish
/// shape the snapshot engine replaces: clone the full matrix, rebuild
/// the topic totals from scratch in f64 (word-ascending), render to
/// f32. Kept as the equivalence-test oracle
/// (`rust/tests/snapshot_equiv.rs`) and the microbench baseline, the
/// same pattern as `serial_reference_step` / `ShardBp::sweep_reference`.
///
/// Note: this is *not* bit-for-bit the pre-snapshot ABP loop — that
/// code accumulated the totals in **f32**. The totals here are
/// deliberately upgraded to the f64 protocol the coordinator's
/// `GlobalState` adopted in PR 1 (the f32 render usually agrees, but
/// ABP trajectories shift at the f32-rounding level across the
/// upgrade; recorded in CHANGES.md). What the oracle pins is the
/// clone-and-rebuild *publish semantics* the incremental engine must
/// reproduce exactly.
pub fn clone_rebuild(src: &[f32], k: usize) -> (Vec<f32>, Vec<f32>) {
    let phi = src.to_vec();
    let mut tot64 = vec![0f64; k];
    for row in phi.chunks_exact(k) {
        for (t, &v) in row.iter().enumerate() {
            tot64[t] += v as f64;
        }
    }
    let tot32: Vec<f32> = tot64.iter().map(|&v| v as f32).collect();
    (phi, tot32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn selection_every_third(w: usize, k: usize) -> Selection {
        // sparse selection: every 3rd word, topics {0, 2, 4, ...}
        let mut word_sel = vec![false; w];
        let mut topic_off = Vec::with_capacity(w + 1);
        let mut topic_ids = Vec::new();
        topic_off.push(0u32);
        for wi in 0..w {
            if wi % 3 == 0 {
                word_sel[wi] = true;
                for t in (0..k as u32).step_by(2) {
                    topic_ids.push(t);
                }
            }
            topic_off.push(topic_ids.len() as u32);
        }
        Selection { full: false, word_sel, topic_off, topic_ids }
    }

    #[test]
    fn fresh_snapshot_matches_oracle_bitwise() {
        let (w, k) = (40, 8);
        let mut rng = Rng::new(3);
        let src: Vec<f32> = (0..w * k).map(|_| rng.f32() * 5.0).collect();
        let snap = PhiSnapshot::new(&src, k, 0);
        let (phi_o, tot_o) = clone_rebuild(&src, k);
        assert_eq!(snap.phi(), &phi_o[..]);
        assert_eq!(snap.phi_tot(), &tot_o[..]);
    }

    #[test]
    fn dense_parts_publish_matches_concatenated_apply() {
        let (w, k) = (40, 8);
        let mut rng = Rng::new(17);
        let src: Vec<f32> = (0..w * k).map(|_| rng.f32() * 5.0).collect();
        // row-aligned slices like the sharded coordinator's state
        let os = crate::comm::OwnerSlices::row_aligned(w * k, k, 3);
        let parts: Vec<&[f32]> = (0..os.owners()).map(|n| &src[os.range(n)]).collect();

        let zeros = vec![0.0; w * k];
        let mut from_parts = PhiSnapshot::new(&zeros, k, 0);
        from_parts.apply_dense_parts(&parts);
        let mut from_dense = PhiSnapshot::new(&zeros, k, 0);
        from_dense.apply_dense(&src);
        assert_eq!(from_parts.phi(), from_dense.phi());
        assert_eq!(from_parts.phi_tot(), from_dense.phi_tot());
    }

    #[test]
    fn selected_apply_tracks_source_exactly() {
        let (w, k) = (30, 8);
        let mut rng = Rng::new(5);
        let mut src: Vec<f32> = (0..w * k).map(|_| rng.f32() * 2.0).collect();
        let sel = selection_every_third(w, k);
        let mut snap = PhiSnapshot::new(&src, k, 0);
        for _ in 0..50 {
            // mutate only the selected pairs (the sweep freeze contract)
            for (wi, &is_sel) in sel.word_sel.iter().enumerate() {
                if !is_sel {
                    continue;
                }
                for &t in sel.topics_of(wi).unwrap() {
                    src[wi * k + t as usize] += rng.f32() - 0.5;
                }
            }
            snap.apply_selected(&src, &sel);
            // the frozen view is the clone the old loop made, bit for bit
            assert_eq!(snap.phi(), &src[..]);
            // f64 deltas keep the totals within f64-rounding distance of
            // a from-scratch rebuild (no resync configured here)
            assert!(snap.totals_drift() < 1e-8, "drift {}", snap.totals_drift());
        }
    }

    #[test]
    fn resync_every_one_is_bitwise_the_oracle() {
        let (w, k) = (25, 6);
        let mut rng = Rng::new(7);
        let mut src: Vec<f32> = (0..w * k).map(|_| rng.f32()).collect();
        let sel = selection_every_third(w, k);
        let mut snap = PhiSnapshot::new(&src, k, 1);
        for _ in 0..20 {
            for (wi, &is_sel) in sel.word_sel.iter().enumerate() {
                if !is_sel {
                    continue;
                }
                for &t in sel.topics_of(wi).unwrap() {
                    src[wi * k + t as usize] += rng.f32() - 0.4;
                }
            }
            snap.apply_selected(&src, &sel);
            let (phi_o, tot_o) = clone_rebuild(&src, k);
            assert_eq!(snap.phi(), &phi_o[..]);
            assert_eq!(snap.phi_tot(), &tot_o[..]);
        }
    }

    #[test]
    fn dense_apply_resets_to_oracle() {
        let (w, k) = (20, 4);
        let mut rng = Rng::new(9);
        let src: Vec<f32> = (0..w * k).map(|_| rng.f32()).collect();
        let mut snap = PhiSnapshot::new(&src, k, 0);
        let src2: Vec<f32> = (0..w * k).map(|_| rng.f32() * 3.0).collect();
        let sel = Selection::full(w);
        snap.apply(&src2, &sel);
        let (phi_o, tot_o) = clone_rebuild(&src2, k);
        assert_eq!(snap.phi(), &phi_o[..]);
        assert_eq!(snap.phi_tot(), &tot_o[..]);
        assert_eq!(snap.totals_drift(), 0.0);
    }

    #[test]
    fn apply_power_matches_apply_selected() {
        let (w, k) = (30, 8);
        let mut rng = Rng::new(13);
        let mut src: Vec<f32> = (0..w * k).map(|_| rng.f32()).collect();
        // a power set with mixed full and partial topic lists (words in
        // selection — residual-descending-like — order, not ascending)
        let ps = PowerSet {
            words: vec![7, 2, 19, 11],
            topics: vec![
                (0..k as u32).collect(),
                vec![1, 3, 5],
                (0..k as u32).collect(),
                vec![0, 6],
            ],
        };
        let sel = Selection::from_power(&ps, w);
        let mut a = PhiSnapshot::new(&src, k, 0);
        let mut b = a.clone();
        for _ in 0..10 {
            for (ts, &wi) in ps.topics.iter().zip(&ps.words) {
                for &t in ts {
                    src[wi as usize * k + t as usize] += rng.f32() - 0.5;
                }
            }
            a.apply_selected(&src, &sel);
            b.apply_power(&src, &ps);
            // identical view bits (copies are order-independent); totals
            // differ only in f64 add order — drift-bounded
            assert_eq!(a.phi(), b.phi());
            assert_eq!(a.phi(), &src[..]);
            assert!(b.totals_drift() < 1e-8, "drift {}", b.totals_drift());
        }
        // after a resync both are bitwise the from-scratch totals
        a.resync_totals();
        b.resync_totals();
        assert_eq!(a.phi_tot(), b.phi_tot());
    }

    #[test]
    fn resync_cadence_restores_exactness() {
        let (w, k) = (30, 8);
        let mut rng = Rng::new(11);
        let mut src: Vec<f32> = (0..w * k).map(|_| rng.f32() * 4.0).collect();
        let sel = selection_every_third(w, k);
        let cadence = 4;
        let mut snap = PhiSnapshot::new(&src, k, cadence);
        for i in 0..32 {
            for (wi, &is_sel) in sel.word_sel.iter().enumerate() {
                if !is_sel {
                    continue;
                }
                for &t in sel.topics_of(wi).unwrap() {
                    src[wi * k + t as usize] += rng.f32() - 0.5;
                }
            }
            snap.apply_selected(&src, &sel);
            if (i + 1) % cadence == 0 {
                // the resync just fired: totals from scratch, zero drift
                assert_eq!(snap.totals_drift(), 0.0, "apply {i}");
                let (_, tot_o) = clone_rebuild(&src, k);
                assert_eq!(snap.phi_tot(), &tot_o[..], "apply {i}");
            }
        }
    }
}
