//! Active belief propagation (Zeng, Liu & Cao 2012) — the sublinear batch
//! BP the paper builds OBP on (its reference [8]/[22]).
//!
//! ABP schedules *documents* as well as words/topics by residual: each
//! iteration sweeps only the λ_D fraction of documents with the largest
//! accumulated residuals (plus the word/topic power selection of §3.1,
//! which ABP pioneered). Residuals of unswept documents stay frozen, so —
//! exactly like Fig. 3's dynamic schedule — every document keeps its
//! chance to be selected until its residual is driven down.
//!
//! This engine is single-processor batch (the paper's usage); POBP embeds
//! the same word/topic scheduling in its MPA coordinator.
//!
//! # Scheduling invariants
//!
//! * **Epoch coverage**: t = 1 sweeps *every* document (the batch
//!   epoch's full pass), so each doc enters the residual table with a
//!   fresh value before any selection happens; t ≥ 2 sweeps the top-λ_D
//!   docs by residual. Residuals of unswept docs stay frozen, so every
//!   doc keeps its chance to be selected (the Fig. 3 "no information
//!   gets lost" invariant at document granularity).
//! * **Determinism**: the schedule is a pure function of the residual
//!   table (`top_k_desc`, index-tie-broken) and the sweep itself is the
//!   bitwise-reproducible scheduled-parallel path below — two runs with
//!   the same seed produce bitwise-identical histories and models at
//!   any thread count.
//! * **Parallelism**: both sweep forms fan over the `Cluster` pool — the
//!   t = 1 full pass over the fixed doc blocks
//!   ([`ShardBp::sweep_parallel`]), the t ≥ 2 scheduled pass over a
//!   per-iteration [`DocSchedule`] permutation
//!   ([`ShardBp::sweep_docs_parallel`]), which returns the per-doc
//!   residuals in schedule order. No sweep in the engine is serial
//!   anymore; the ledger charges the critical-path estimate of each
//!   sweep on the configured thread budget.
//! * **Snapshot publish**: the frozen φ̂ each sweep reads lives in a
//!   persistent [`PhiSnapshot`] — after the sweep, only the Δ at the
//!   selected (word, topic) pairs is published (exact f32→f64 totals
//!   deltas, dense resync every [`AbpConfig::resync_every`] subset
//!   publishes). The old per-iteration `dphi.clone()` + totals rebuild
//!   — O(W·K) leader work regardless of the selection — is retired to
//!   [`clone_rebuild`](crate::engine::snapshot::clone_rebuild), the
//!   equivalence-test oracle.
//! * **Block-table reuse**: when a t ≥ 2 schedule covers at least
//!   [`AbpConfig::sched_reuse_coverage`] of the documents, the sweep
//!   reuses the t = 1 fixed block tables
//!   ([`ShardBp::sweep_docs_parallel_fixed`]) instead of rebuilding the
//!   per-sweep permutation tables — the per-iteration O(scheduled NNZ)
//!   index build disappears exactly when it is largest.

use crate::comm::Cluster;
use crate::corpus::Csr;
use crate::engine::bp::{Selection, ShardBp};
use crate::engine::snapshot::PhiSnapshot;
use crate::engine::traits::{IterStat, LdaParams, Model, TrainResult};
use crate::sched::{select_power, DocSchedule, PowerParams, PowerSet};
use crate::util::partial_sort::top_k_desc;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// ABP configuration.
#[derive(Clone, Debug)]
pub struct AbpConfig {
    /// fraction of documents swept per iteration
    pub lambda_d: f64,
    /// word/topic selection (λ_W, λ_K·K); `PowerParams::full()` for
    /// doc-scheduling only
    pub power: PowerParams,
    pub max_iters: usize,
    pub min_iters: usize,
    pub converge_thresh: f64,
    pub converge_rel: f64,
    pub seed: u64,
    /// OS threads for the doc-parallel sweeps (0 = all cores): ABP is
    /// single-processor, but both sweep forms fan over idle cores — the
    /// t = 1 full pass over the fixed doc blocks
    /// (`ShardBp::sweep_parallel`, which also hands back the per-doc
    /// residuals the scheduler needs) and the t ≥ 2 residual-scheduled
    /// pass over the per-iteration `DocSchedule` permutation
    /// (`ShardBp::sweep_docs_parallel`).
    pub threads: usize,
    /// Dense totals-resync cadence of the φ̂ snapshot: rebuild the f64
    /// topic totals from scratch every this many subset publishes
    /// (0 = only on full-selection publishes; 1 = every publish, i.e.
    /// bitwise the clone-and-rebuild oracle). Drift between resyncs is
    /// bounded at the f64-rounding level
    /// ([`PhiSnapshot::totals_drift`]).
    pub resync_every: usize,
    /// Scheduled-path block-table reuse threshold: when a t ≥ 2 schedule
    /// covers at least this fraction of the documents, sweep over the
    /// t = 1 fixed block tables ([`ShardBp::sweep_docs_parallel_fixed`])
    /// instead of building the per-sweep permutation tables. Values
    /// above 1.0 disable the reuse path; 0.0 forces it. The choice is a
    /// pure function of the schedule length, so runs stay bitwise
    /// deterministic; the two sweep forms differ only in Δφ̂/r summation
    /// association (different block partitions) — which means the
    /// default (0.9) shifts high-coverage trajectories vs the
    /// rebuild-only path of earlier releases; set > 1.0 to keep the
    /// per-sweep permutation on every iteration.
    pub sched_reuse_coverage: f64,
}

impl Default for AbpConfig {
    fn default() -> Self {
        AbpConfig {
            lambda_d: 0.5,
            power: PowerParams::full(),
            max_iters: 100,
            min_iters: 5,
            converge_thresh: 0.1,
            converge_rel: 0.01,
            seed: 42,
            threads: 0,
            resync_every: 16,
            sched_reuse_coverage: 0.9,
        }
    }
}

/// Train batch LDA with active BP.
pub fn fit_abp(corpus: &Csr, params: &LdaParams, cfg: &AbpConfig) -> TrainResult {
    let wall = Stopwatch::new();
    let (w, k) = (corpus.w, params.k);
    let tokens = corpus.tokens().max(1.0);
    let mut rng = Rng::new(cfg.seed);
    let mut shard = ShardBp::init(corpus.clone(), k, &mut rng);
    let docs = corpus.docs();
    let pool = Cluster::new(1, cfg.threads);
    let mut ledger = crate::comm::Ledger::new(crate::comm::NetModel::infiniband_20gbps());
    let mut history = Vec::new();

    // per-doc residuals (stale-until-swept, like the word/topic residuals)
    let mut r_doc = vec![f32::MAX; docs]; // everything active at t=1
    let mut selection = Selection::full(w);
    let mut prev_resid = f64::INFINITY;
    let mut first_resid = f64::INFINITY;
    let active_docs = ((cfg.lambda_d * docs as f64).ceil() as usize).clamp(1, docs.max(1));
    // N = 1 "global" φ̂ is the shard's own gradient, frozen behind the
    // incremental snapshot: each iteration publishes only the selected
    // pairs' Δ instead of cloning + rebuilding the whole matrix
    let mut snap = PhiSnapshot::new(&shard.dphi, k, cfg.resync_every);
    // the PowerSet behind `selection` (None while the selection is
    // full): the snapshot publish walks its explicit word list
    let mut power: Option<PowerSet> = None;

    for t in 1..=cfg.max_iters {
        // doc schedule: top-λ_D docs by residual (all docs at t = 1)
        let scheduled: Vec<u32> = if t == 1 {
            (0..docs as u32).collect()
        } else {
            top_k_desc(&r_doc, active_docs)
        };

        // same budget split as the POBP coordinator: N = 1, so the whole
        // pool goes to the single shard's doc blocks
        let budget = pool.doc_threads_per_worker();
        if t == 1 {
            // whole-corpus sweep: doc-parallel over the fixed blocks; the
            // per-doc residuals come back from the same pass (residual
            // clearing is folded into the sweep's merge)
            let (_, timing) = shard.sweep_parallel(
                &pool, budget, snap.phi(), snap.phi_tot(), &selection, params, true,
            );
            for (rd, &v) in r_doc.iter_mut().zip(shard.doc_residuals()) {
                *rd = v as f32;
            }
            ledger.record_compute(&[timing.critical_path_secs(budget)]);
        } else {
            // scheduled sweep: permute the residual-ordered doc list into
            // blocks and fan them over the same pool; above the coverage
            // threshold the permutation reuses the t = 1 fixed block
            // tables (no per-sweep index build). The per-doc residuals
            // come back in schedule order either way.
            shard.clear_selected_residuals(&selection);
            let ds = DocSchedule::build(&scheduled, |d| shard.data.row_range(d).len());
            let reuse_fixed = ds.coverage(docs) >= cfg.sched_reuse_coverage;
            let (rds, timing) = if reuse_fixed {
                shard.sweep_docs_parallel_fixed(
                    &pool, budget, &ds, snap.phi(), snap.phi_tot(), &selection, params, true,
                )
            } else {
                shard.sweep_docs_parallel(
                    &pool, budget, &ds, snap.phi(), snap.phi_tot(), &selection, params, true,
                )
            };
            for (&d, &rd) in scheduled.iter().zip(&rds) {
                r_doc[d as usize] = rd as f32;
            }
            ledger.record_compute(&[timing.critical_path_secs(budget)]);
        }
        // publish this sweep's Δ into the frozen snapshot — O(selected
        // pairs) under power selection (the PowerSet's explicit word
        // list, no W-wide bitmap scan), dense only when the selection is
        // full (the sweep touched nothing outside `selection`)
        match &power {
            Some(ps) => snap.apply_power(&shard.dphi, ps),
            None => snap.apply(&shard.dphi, &selection),
        }

        let resid_total: f64 = r_doc
            .iter()
            .map(|&v| if v == f32::MAX { 0.0 } else { v as f64 })
            .sum();
        let resid_per_token = resid_total / tokens;
        history.push(IterStat {
            batch: 0,
            iter: t,
            residual_per_token: resid_per_token,
            synced_pairs: 0, // single processor: nothing on the wire
            sim_elapsed: ledger.total_secs(),
            wall_elapsed: wall.total_secs(),
        });

        if t == 1 {
            first_resid = resid_per_token.max(1e-12);
        }
        if t >= cfg.min_iters
            && resid_per_token <= cfg.converge_thresh
            && resid_per_token <= cfg.converge_rel * first_resid
            && resid_per_token <= prev_resid
        {
            break;
        }
        prev_resid = resid_per_token;

        // word/topic schedule for the next iteration
        if cfg.power.lambda_w < 1.0 || cfg.power.lambda_k_times_k < k {
            let ps = select_power(&shard.r, w, k, &cfg.power);
            selection = Selection::from_power(&ps, w);
            power = Some(ps);
        }
    }

    TrainResult {
        model: Model { k, w, phi_wk: shard.dphi.clone() },
        history,
        ledger,
        wall_secs: wall.total_secs(),
        snapshots: vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::{generate, SynthSpec};

    fn tiny() -> Csr {
        generate(&SynthSpec::tiny(41)).corpus
    }

    #[test]
    fn abp_converges_and_conserves_mass() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let r = fit_abp(&c, &params, &AbpConfig { max_iters: 60, ..Default::default() });
        assert!((r.model.mass() - c.tokens()).abs() < c.tokens() * 1e-3);
        let last = r.history.last().unwrap().residual_per_token;
        assert!(last < 0.2, "residual {last}");
    }

    #[test]
    fn abp_quality_close_to_full_bp() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let abp = fit_abp(&c, &params, &AbpConfig { lambda_d: 0.3, max_iters: 80, ..Default::default() });
        let full = fit_abp(&c, &params, &AbpConfig { lambda_d: 1.0, max_iters: 80, ..Default::default() });
        let p_abp = crate::eval::perplexity::heldin_perplexity(&abp.model, &c, &params);
        let p_full = crate::eval::perplexity::heldin_perplexity(&full.model, &c, &params);
        assert!(
            p_abp < p_full * 1.25,
            "active scheduling degraded too much: {p_abp} vs {p_full}"
        );
    }

    #[test]
    fn every_doc_eventually_swept() {
        // the Fig. 3 invariant at document granularity
        let c = tiny();
        let params = LdaParams::paper(8);
        let r = fit_abp(&c, &params, &AbpConfig { lambda_d: 0.2, max_iters: 60, converge_thresh: 0.0, ..Default::default() });
        // after the run, no document still has the t=1 sentinel residual
        // (fit_abp sweeps all docs at t=1, so this checks scheduling ran)
        assert!(r.history.len() > 2);
    }

    #[test]
    fn every_doc_swept_once_per_batch_epoch() {
        // Epoch-coverage invariant: the t = 1 pass schedules *every*
        // document, so a 1-iteration run already has a meaningful
        // residual for each doc — the per-token residual equals the sum
        // over all docs (no sentinel/frozen docs left out).
        let c = tiny();
        let params = LdaParams::paper(8);
        let r = fit_abp(
            &c,
            &params,
            &AbpConfig { lambda_d: 0.1, max_iters: 1, converge_thresh: 0.0, ..Default::default() },
        );
        assert_eq!(r.history.len(), 1);
        let first = r.history[0].residual_per_token;
        assert!(first.is_finite() && first > 0.0, "t=1 must sweep all docs: {first}");
    }

    #[test]
    fn doc_schedule_deterministic_and_distinct() {
        // the t >= 2 schedule is a pure function of the residual table:
        // repeated selection is identical, docs are distinct, and ties
        // break by index
        let mut rng = crate::util::rng::Rng::new(29);
        let r_doc: Vec<f32> = (0..500).map(|_| rng.f32()).collect();
        let a = top_k_desc(&r_doc, 120);
        let b = top_k_desc(&r_doc, 120);
        assert_eq!(a, b);
        let mut seen = std::collections::HashSet::new();
        assert!(a.iter().all(|d| seen.insert(*d)), "schedule repeated a doc");
        // and the derived permutation is deterministic too
        let ds1 = DocSchedule::build(&a, |d| 1 + d % 7);
        let ds2 = DocSchedule::build(&b, |d| 1 + d % 7);
        assert_eq!(ds1.docs_sorted(), ds2.docs_sorted());
        assert_eq!(ds1.sched_pos(), ds2.sched_pos());
    }

    #[test]
    fn doc_scheduling_eventually_selects_every_doc() {
        // Fig. 3 at doc granularity, mechanism-level: as residuals of
        // swept docs decay, every doc is eventually scheduled.
        let mut rng = crate::util::rng::Rng::new(31);
        let n = 200usize;
        let mut r: Vec<f32> = (0..n).map(|_| rng.f32() + 0.01).collect();
        let mut seen = vec![false; n];
        for _ in 0..100 {
            let sched = top_k_desc(&r, n / 5);
            for &d in &sched {
                seen[d as usize] = true;
                r[d as usize] *= 0.2; // sweeping shrinks the residual
            }
            if seen.iter().all(|&s| s) {
                return;
            }
        }
        panic!("some documents were never scheduled");
    }

    #[test]
    fn abp_bitwise_deterministic_across_runs() {
        // scheduled sweeps run block-parallel; the determinism contract
        // says two identical runs agree bitwise on history and model
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = AbpConfig {
            lambda_d: 0.3,
            max_iters: 12,
            converge_thresh: 0.0,
            ..Default::default()
        };
        let a = fit_abp(&c, &params, &cfg);
        let b = fit_abp(&c, &params, &cfg);
        assert_eq!(a.history.len(), b.history.len());
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(
                x.residual_per_token.to_bits(),
                y.residual_per_token.to_bits(),
                "iter {} residual diverged",
                x.iter
            );
        }
        assert_eq!(a.model.phi_wk, b.model.phi_wk);
    }

    #[test]
    fn abp_snapshot_path_bitwise_deterministic_under_power_selection() {
        // the incremental-snapshot publish (sparse deltas + periodic
        // resync) is a pure function of the sweep outputs: two identical
        // runs on the power-subset path agree bitwise
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = AbpConfig {
            lambda_d: 0.4,
            power: PowerParams { lambda_w: 0.3, lambda_k_times_k: 4 },
            max_iters: 15,
            converge_thresh: 0.0,
            resync_every: 4,
            ..Default::default()
        };
        let a = fit_abp(&c, &params, &cfg);
        let b = fit_abp(&c, &params, &cfg);
        assert_eq!(a.history.len(), b.history.len());
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(
                x.residual_per_token.to_bits(),
                y.residual_per_token.to_bits(),
                "iter {} residual diverged",
                x.iter
            );
        }
        assert_eq!(a.model.phi_wk, b.model.phi_wk);
    }

    #[test]
    fn block_reuse_path_matches_rebuild_path_at_t2() {
        // With λ_D = 1.0 the t = 2 schedule covers every doc, so the
        // coverage threshold routes it onto the fixed block tables.
        // μ/θ̂/per-doc residuals are bitwise equal between the two sweep
        // forms (both equal the serial sweep_docs oracle), so the t = 2
        // residual agrees bitwise; Δφ̂/r differ only in block-merge
        // association from t = 2 on.
        let c = tiny();
        let params = LdaParams::paper(8);
        let base = AbpConfig {
            lambda_d: 1.0,
            max_iters: 2,
            converge_thresh: 0.0,
            ..Default::default()
        };
        let reuse =
            fit_abp(&c, &params, &AbpConfig { sched_reuse_coverage: 0.9, ..base.clone() });
        let rebuild =
            fit_abp(&c, &params, &AbpConfig { sched_reuse_coverage: 2.0, ..base });
        assert_eq!(reuse.history.len(), rebuild.history.len());
        for (x, y) in reuse.history.iter().zip(&rebuild.history) {
            assert_eq!(
                x.residual_per_token.to_bits(),
                y.residual_per_token.to_bits(),
                "iter {} residual diverged between reuse and rebuild",
                x.iter
            );
        }
    }

    #[test]
    fn block_reuse_path_converges_and_is_deterministic() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = AbpConfig {
            lambda_d: 0.95,
            sched_reuse_coverage: 0.9, // every t >= 2 sweep reuses
            max_iters: 40,
            ..Default::default()
        };
        let a = fit_abp(&c, &params, &cfg);
        let b = fit_abp(&c, &params, &cfg);
        assert_eq!(a.model.phi_wk, b.model.phi_wk);
        assert!((a.model.mass() - c.tokens()).abs() < c.tokens() * 1e-3);
        let last = a.history.last().unwrap().residual_per_token;
        assert!(last < 0.3, "reuse path did not converge: {last}");
    }

    #[test]
    fn smaller_lambda_d_does_less_work_per_iter() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let fast = fit_abp(&c, &params, &AbpConfig { lambda_d: 0.1, max_iters: 20, converge_thresh: 0.0, ..Default::default() });
        let slow = fit_abp(&c, &params, &AbpConfig { lambda_d: 1.0, max_iters: 20, converge_thresh: 0.0, ..Default::default() });
        assert!(fast.ledger.compute_secs < slow.ledger.compute_secs);
    }
}
