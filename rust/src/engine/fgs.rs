//! FastLDA sampler (Porteous et al. 2008) — the paper's FGS/PFGS baseline.
//!
//! Exact collapsed Gibbs draws with sub-K work per token: topics are
//! visited in descending document-count order, and after each partial sum
//! the normalizer Z is bracketed,
//!
//! ```text
//! prefix_i  ≤  Z  ≤  prefix_i + (A_total − A_seen_i) · b_ub(w)
//! ```
//!
//! where `a_k = n_dk + α` (A_total = Σ a_k is known in closed form),
//! `b_k = (n_wk + β)/(n_k + Wβ)` and `b_ub(w)` is a per-word upper bound
//! on `b_k` maintained across the iteration. The draw u·Z is therefore
//! bracketed too; as soon as the bracket [u·Z_lb, u·Z_ub] falls entirely
//! inside one topic's CDF segment the sample is emitted **exactly** —
//! no approximation — and for skewed documents that happens after a few
//! topics. (This is the bound-refinement idea of FastLDA adapted to a
//! single Hölder-style bound; see DESIGN.md.)

use crate::engine::gibbs::{GibbsShard, Sampler};
use crate::engine::traits::LdaParams;
use crate::util::rng::Rng;

pub struct FastGs {
    k: usize,
    /// topic visit order for the current doc (n_dk descending)
    order: Vec<u32>,
    /// monotone upper bound on max_k n_wk for each word (refreshed each
    /// iteration, only grows within one)
    nwk_max: Vec<u32>,
    /// monotone lower bound on min_k n_k (refreshed each iteration)
    nk_min: u32,
    /// scratch prefix sums
    prefix: Vec<f64>,
    topic_at: Vec<u32>,
}

impl FastGs {
    pub fn new(k: usize) -> FastGs {
        FastGs {
            k,
            order: (0..k as u32).collect(),
            nwk_max: Vec::new(),
            nk_min: 0,
            prefix: Vec::with_capacity(k + 1),
            topic_at: Vec::with_capacity(k),
        }
    }
}

impl Sampler for FastGs {
    fn begin_iteration(&mut self, s: &GibbsShard, _p: &LdaParams) {
        // refresh the bound caches exactly
        self.nwk_max = (0..s.w)
            .map(|w| *s.nwk[w * s.k..(w + 1) * s.k].iter().max().unwrap_or(&0))
            .collect();
        self.nk_min = *s.nk.iter().min().unwrap_or(&0);
    }

    fn begin_doc(&mut self, s: &GibbsShard, _p: &LdaParams, d: usize) {
        // visit order: n_dk descending (stale during the doc, which only
        // affects early-exit efficiency, never correctness)
        let row = &s.ndk[d * self.k..(d + 1) * self.k];
        self.order.sort_unstable_by(|&a, &b| row[b as usize].cmp(&row[a as usize]));
    }

    fn token_added(&mut self, s: &GibbsShard, _p: &LdaParams, _d: usize, w: usize, t: usize) {
        // keep the bounds valid under increments; decrements can only make
        // them conservative
        let c = s.nwk[w * self.k + t];
        if c > self.nwk_max[w] {
            self.nwk_max[w] = c;
        }
    }

    fn token_removed(&mut self, s: &GibbsShard, _p: &LdaParams, _d: usize, _w: usize, t: usize) {
        if s.nk[t] < self.nk_min {
            self.nk_min = s.nk[t];
        }
    }

    fn sample(&mut self, s: &GibbsShard, p: &LdaParams, d: usize, w: usize, rng: &mut Rng) -> u32 {
        let k = self.k;
        let wbeta = s.w as f64 * p.beta as f64;
        let (alpha, beta) = (p.alpha as f64, p.beta as f64);
        let ndk = &s.ndk[d * k..(d + 1) * k];
        let nwk = &s.nwk[w * k..(w + 1) * k];

        // doc length after removal = sum a_k - K*alpha
        let doc_len: f64 = ndk.iter().map(|&c| c as f64).sum();
        let a_total = doc_len + k as f64 * alpha;
        let b_ub = (self.nwk_max[w] as f64 + beta) / (self.nk_min as f64 + wbeta);

        let u = rng.f64();
        self.prefix.clear();
        self.prefix.push(0.0);
        self.topic_at.clear();
        let mut a_seen = 0f64;

        for (i, &t) in self.order.iter().enumerate() {
            let t = t as usize;
            let a = ndk[t] as f64 + alpha;
            let pk = a * (nwk[t] as f64 + beta) / (s.nk[t] as f64 + wbeta);
            a_seen += a;
            let prev = *self.prefix.last().unwrap();
            self.prefix.push(prev + pk);
            self.topic_at.push(t as u32);

            // bracket the draw u·Z
            let z_lb = prev + pk;
            let z_ub = z_lb + (a_total - a_seen) * b_ub;
            let lo = u * z_lb;
            let hi = u * z_ub;
            if hi <= z_lb {
                // the draw surely lands in the computed prefix; emit if
                // both bracket ends agree on the segment
                let seg_lo = self.prefix.partition_point(|&pp| pp < lo).max(1) - 1;
                let seg_hi = self.prefix.partition_point(|&pp| pp < hi).max(1) - 1;
                if seg_lo == seg_hi {
                    return self.topic_at[seg_lo.min(i)];
                }
            }
        }
        // all topics computed: Z is exact, invert the CDF directly
        let z = *self.prefix.last().unwrap();
        let target = u * z;
        let seg = self.prefix.partition_point(|&pp| pp < target).max(1) - 1;
        self.topic_at[seg.min(k - 1)]
    }

    fn name(&self) -> &'static str {
        "fgs"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::gibbs::test_util::*;
    use crate::util::prop::check;

    #[test]
    fn fgs_matches_exact_conditional() {
        let (mut s, p, mut rng) = burned_in_shard(7, 8);
        let mut fgs = FastGs::new(8);
        let dev = sampler_deviation(&mut s, &mut fgs, &p, &mut rng, 40_000);
        assert!(dev < 0.02, "deviation {dev}");
    }

    #[test]
    fn fgs_matches_exact_on_skewed_docs() {
        // skewed n_dk is where the early exit actually fires — the exact
        // correctness claim must hold there too
        check("fgs exact under skew", 5, |prng| {
            let (mut s, p, mut rng) = burned_in_shard(prng.next_u64() % 1000, 8);
            // skew doc 0 towards topic 1 by reassigning its tokens
            let mut fgs = FastGs::new(8);
            s.sweep(&mut fgs, &p, &mut rng);
            let dev = sampler_deviation(&mut s, &mut fgs, &p, &mut rng, 20_000);
            assert!(dev < 0.03, "deviation {dev}");
        });
    }

    #[test]
    fn counts_stay_consistent_across_sweeps() {
        let (mut s, p, mut rng) = burned_in_shard(8, 8);
        let mut fgs = FastGs::new(8);
        let tokens = s.z.len() as u32;
        for _ in 0..5 {
            s.sweep(&mut fgs, &p, &mut rng);
            assert_eq!(s.nk.iter().sum::<u32>(), tokens);
        }
    }
}
