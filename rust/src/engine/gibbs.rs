//! Collapsed Gibbs sampling substrate (Griffiths & Steyvers 2004) and the
//! shared machinery for its fast variants (FGS, SGS) and their parallel
//! forms (PGS = AD-LDA, Newman et al. 2009).
//!
//! State per (simulated) processor: one topic label per token, the local
//! document–topic counts n_dk, and a private copy of the global
//! topic–word counts n_wk / n_k — the AD-LDA memory layout the paper's
//! Table 2 charges PGS with. The conditional for token (d, w) is
//!
//! ```text
//! p(z = k | rest) ∝ (n_dk + α) (n_wk + β) / (n_k + Wβ)
//! ```
//!
//! with the token's own count removed. Variant samplers ([`Sampler`])
//! differ only in *how* they draw from this discrete distribution; the
//! count bookkeeping is shared, so every variant targets the identical
//! posterior and the speed comparison is like-for-like (the paper's
//! Figs. 8/11).

use crate::corpus::Csr;
use crate::engine::traits::LdaParams;
use crate::util::rng::Rng;

/// Token-level Gibbs state for one shard.
pub struct GibbsShard {
    pub k: usize,
    pub w: usize,
    /// one entry per token
    pub doc_of: Vec<u32>,
    pub word_of: Vec<u32>,
    pub z: Vec<u32>,
    /// local docs × K
    pub ndk: Vec<u32>,
    /// private copy of global W × K (word-major)
    pub nwk: Vec<u32>,
    /// private copy of global per-topic totals
    pub nk: Vec<u32>,
    /// snapshot of nwk at the last synchronization (for delta computation)
    pub nwk_snap: Vec<u32>,
}

impl GibbsShard {
    /// Expand a document shard into tokens with random topic assignments.
    pub fn init(data: &Csr, k: usize, rng: &mut Rng) -> GibbsShard {
        let w = data.w;
        let mut doc_of = Vec::new();
        let mut word_of = Vec::new();
        for d in 0..data.docs() {
            let (ws, vs) = data.row(d);
            for (&wi, &c) in ws.iter().zip(vs) {
                for _ in 0..c.round() as usize {
                    doc_of.push(d as u32);
                    word_of.push(wi);
                }
            }
        }
        let n_tokens = doc_of.len();
        let mut s = GibbsShard {
            k,
            w,
            doc_of,
            word_of,
            z: vec![0; n_tokens],
            ndk: vec![0; data.docs() * k],
            nwk: vec![0; w * k],
            nk: vec![0; k],
            nwk_snap: vec![0; w * k],
        };
        for i in 0..n_tokens {
            let t = rng.below(k) as u32;
            s.z[i] = t;
            s.inc(s.doc_of[i] as usize, s.word_of[i] as usize, t as usize);
        }
        s
    }

    #[inline]
    fn inc(&mut self, d: usize, w: usize, t: usize) {
        self.ndk[d * self.k + t] += 1;
        self.nwk[w * self.k + t] += 1;
        self.nk[t] += 1;
    }

    #[inline]
    fn dec(&mut self, d: usize, w: usize, t: usize) {
        self.ndk[d * self.k + t] -= 1;
        self.nwk[w * self.k + t] -= 1;
        self.nk[t] -= 1;
    }

    /// Overwrite the private global tables with the synchronized ones and
    /// snapshot them (start of an iteration in AD-LDA).
    pub fn install_global(&mut self, nwk: &[u32], nk: &[u32]) {
        self.nwk.copy_from_slice(nwk);
        self.nk.copy_from_slice(nk);
        self.nwk_snap.copy_from_slice(nwk);
    }

    /// One full sweep over the shard's tokens with the given sampler.
    pub fn sweep<S: Sampler + ?Sized>(
        &mut self,
        sampler: &mut S,
        p: &LdaParams,
        rng: &mut Rng,
    ) {
        sampler.begin_iteration(self, p);
        let n = self.z.len();
        let mut cur_doc = u32::MAX;
        for i in 0..n {
            let (d, w) = (self.doc_of[i] as usize, self.word_of[i] as usize);
            if self.doc_of[i] != cur_doc {
                cur_doc = self.doc_of[i];
                sampler.begin_doc(self, p, d);
            }
            let old = self.z[i] as usize;
            self.dec(d, w, old);
            sampler.token_removed(self, p, d, w, old);
            let new = sampler.sample(self, p, d, w, rng) as usize;
            debug_assert!(new < self.k);
            self.inc(d, w, new);
            sampler.token_added(self, p, d, w, new);
            self.z[i] = new as u32;
        }
    }
}

/// A strategy for drawing from the collapsed conditional. All variants
/// must sample the *same* distribution; they differ in work per draw.
pub trait Sampler: Send {
    fn begin_iteration(&mut self, shard: &GibbsShard, p: &LdaParams);
    fn begin_doc(&mut self, shard: &GibbsShard, p: &LdaParams, d: usize);
    /// called after the current token's count was removed
    fn token_removed(&mut self, _s: &GibbsShard, _p: &LdaParams, _d: usize, _w: usize, _t: usize) {}
    /// called after the new topic's count was added
    fn token_added(&mut self, _s: &GibbsShard, _p: &LdaParams, _d: usize, _w: usize, _t: usize) {}
    fn sample(&mut self, shard: &GibbsShard, p: &LdaParams, d: usize, w: usize, rng: &mut Rng) -> u32;
    /// relative bytes-per-element this variant synchronizes (the paper:
    /// GS-family ships integer counts, VB ships floats at ~2×)
    fn name(&self) -> &'static str;
}

/// Plain collapsed Gibbs: full O(K) scan per token.
pub struct PlainGs {
    probs: Vec<f64>,
}

impl PlainGs {
    pub fn new(k: usize) -> PlainGs {
        PlainGs { probs: vec![0.0; k] }
    }
}

impl Sampler for PlainGs {
    fn begin_iteration(&mut self, _s: &GibbsShard, _p: &LdaParams) {}
    fn begin_doc(&mut self, _s: &GibbsShard, _p: &LdaParams, _d: usize) {}

    fn sample(&mut self, s: &GibbsShard, p: &LdaParams, d: usize, w: usize, rng: &mut Rng) -> u32 {
        let k = s.k;
        let wbeta = s.w as f64 * p.beta as f64;
        let (alpha, beta) = (p.alpha as f64, p.beta as f64);
        let mut total = 0f64;
        for t in 0..k {
            let pr = (s.ndk[d * k + t] as f64 + alpha)
                * (s.nwk[w * k + t] as f64 + beta)
                / (s.nk[t] as f64 + wbeta);
            self.probs[t] = pr;
            total += pr;
        }
        let mut u = rng.f64() * total;
        for (t, &pr) in self.probs.iter().enumerate() {
            u -= pr;
            if u <= 0.0 {
                return t as u32;
            }
        }
        (k - 1) as u32
    }

    fn name(&self) -> &'static str {
        "gs"
    }
}

/// Exact conditional probabilities for a (d, w) context — shared by the
/// correctness tests of every sampler variant.
pub fn exact_conditional(s: &GibbsShard, p: &LdaParams, d: usize, w: usize) -> Vec<f64> {
    let k = s.k;
    let wbeta = s.w as f64 * p.beta as f64;
    let mut probs: Vec<f64> = (0..k)
        .map(|t| {
            (s.ndk[d * k + t] as f64 + p.alpha as f64)
                * (s.nwk[w * k + t] as f64 + p.beta as f64)
                / (s.nk[t] as f64 + wbeta)
        })
        .collect();
    let z: f64 = probs.iter().sum();
    probs.iter_mut().for_each(|x| *x /= z);
    probs
}

#[cfg(test)]
pub mod test_util {
    use super::*;
    use crate::synth::{generate, SynthSpec};

    /// A small burned-in shard for sampler distribution tests.
    pub fn burned_in_shard(seed: u64, k: usize) -> (GibbsShard, LdaParams, Rng) {
        let sc = generate(&SynthSpec::tiny(seed));
        let p = LdaParams::paper(k);
        let mut rng = Rng::new(seed);
        let mut s = GibbsShard::init(&sc.corpus, k, &mut rng);
        let mut gs = PlainGs::new(k);
        for _ in 0..3 {
            s.sweep(&mut gs, &p, &mut rng);
        }
        (s, p, rng)
    }

    /// Empirical frequencies of `sampler` on a fixed (d, w) context vs the
    /// exact conditional; returns max absolute deviation.
    pub fn sampler_deviation<S: Sampler>(
        s: &mut GibbsShard,
        sampler: &mut S,
        p: &LdaParams,
        rng: &mut Rng,
        draws: usize,
    ) -> f64 {
        let (d, w) = (0usize, s.word_of[0] as usize);
        // remove one token's worth of context like the sweep does
        let old = s.z[0] as usize;
        s.dec(d, w, old);
        let exact = exact_conditional(s, p, d, w);
        sampler.begin_iteration(s, p);
        sampler.begin_doc(s, p, d);
        sampler.token_removed(s, p, d, w, old);
        let mut counts = vec![0usize; s.k];
        for _ in 0..draws {
            counts[sampler.sample(s, p, d, w, rng) as usize] += 1;
        }
        s.inc(d, w, old);
        exact
            .iter()
            .zip(&counts)
            .map(|(&e, &c)| (e - c as f64 / draws as f64).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use test_util::*;

    #[test]
    fn counts_are_consistent_after_sweeps() {
        let (s, _, _) = burned_in_shard(1, 8);
        let tokens = s.z.len() as u32;
        assert_eq!(s.ndk.iter().sum::<u32>(), tokens);
        assert_eq!(s.nwk.iter().sum::<u32>(), tokens);
        assert_eq!(s.nk.iter().sum::<u32>(), tokens);
        // per-topic totals agree between tables
        for t in 0..s.k {
            let from_nwk: u32 = (0..s.w).map(|w| s.nwk[w * s.k + t]).sum();
            assert_eq!(from_nwk, s.nk[t]);
        }
    }

    #[test]
    fn plain_gs_matches_exact_conditional() {
        let (mut s, p, mut rng) = burned_in_shard(2, 8);
        let mut gs = PlainGs::new(8);
        let dev = sampler_deviation(&mut s, &mut gs, &p, &mut rng, 40_000);
        assert!(dev < 0.02, "deviation {dev}");
    }

    #[test]
    fn gibbs_finds_structure_in_separable_corpus() {
        // two disjoint word blocks -> after sweeps, topics should separate
        let docs: Vec<Vec<(u32, f32)>> = (0..40)
            .map(|i| {
                let base = if i % 2 == 0 { 0u32 } else { 4 };
                (0..4).map(|j| (base + j, 3.0)).collect()
            })
            .collect();
        let c = Csr::from_docs(8, &docs);
        let p = LdaParams::paper(2);
        let mut rng = Rng::new(3);
        let mut s = GibbsShard::init(&c, 2, &mut rng);
        let mut gs = PlainGs::new(2);
        for _ in 0..30 {
            s.sweep(&mut gs, &p, &mut rng);
        }
        // purity: each word block should be dominated by one topic
        let block_topic = |lo: usize| -> f64 {
            let t0: u32 = (lo..lo + 4).map(|w| s.nwk[w * 2]).sum();
            let t1: u32 = (lo..lo + 4).map(|w| s.nwk[w * 2 + 1]).sum();
            t0.max(t1) as f64 / (t0 + t1).max(1) as f64
        };
        assert!(block_topic(0) > 0.9, "block 0 purity {}", block_topic(0));
        assert!(block_topic(4) > 0.9, "block 1 purity {}", block_topic(4));
    }
}
