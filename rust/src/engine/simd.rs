//! Explicit-SIMD lanes of the fused BP kernel + cache-aligned scratch
//! (Contract 7, `docs/ARCHITECTURE.md`).
//!
//! The per-entry kernel ([`fused_update`](super::bp)) runs three phases:
//! an elementwise *score* phase, two horizontal *mass* reductions, and an
//! elementwise *delta* phase. Only the elementwise phases are widened
//! here (SSE2 on x86_64, NEON on aarch64 — both baseline features of
//! their targets, so there is no runtime CPU detection to get wrong);
//! the mass reductions and the per-entry residual stay **scalar
//! sequential left-folds over the stored lane buffers**, which is both
//! the fixed, documented horizontal-reduction order and the exact order
//! of the scalar oracle. Per lane, the wide phases perform the same IEEE
//! single-precision mul/sub/add/div in the same order as the scalar
//! kernel — those operations are correctly rounded, so each lane's bits
//! are identical — and the `K mod 4` tail runs the verbatim scalar
//! expressions. Net: μ, θ̂, the per-doc residuals and the scratch Δφ̂/r
//! rows produced under the wide kernel are **bitwise equal** to the
//! scalar kernel's (pinned by `rust/tests/kernel_equiv.rs`).
//!
//! `max` lanes: the kernel only computes `v.max(c)` against constants
//! (`0.0`, `1e-30`) and the constant rides in the second operand of
//! `maxps`/`fmax`, matching `f32::max`'s NaN-returns-other semantics;
//! a `-0.0` winner differs from `+0.0` only in the sign bit, which the
//! immediately following `+ α`/`+ β`/`+ Wβ` add erases (`-0.0 + c ==
//! +0.0 + c` bitwise). The kernel's statistics are finite and
//! non-negative, so no NaN reaches the `max` lanes on any path.
//!
//! Without `--features simd` (the default build) the scalar kernel in
//! `bp.rs` runs unchanged and nothing here is dispatched to; the
//! fallbacks below keep the API compiling on every target.
//!
//! [`AlignedF32`] is the other half of the hardware-floor pass: per-block
//! scratch rows (`LaneBuf`, the Δφ̂/r scratch tables) are padded to a
//! 64-byte stride ([`kpad`]) inside 64-byte-aligned storage, so two pool
//! threads never write the same cache line (false sharing).

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU8, Ordering};

/// f32 lanes per 64-byte cache line — the scratch-row alignment quantum.
pub const LANE_F32: usize = 16;

/// Scratch-row stride for `k` topic lanes: `k` rounded up to a whole
/// cache line. The padding lanes are never zeroed, never written by the
/// kernel and never read by the merge — they exist only so adjacent
/// rows land on distinct lines.
#[inline]
pub fn kpad(k: usize) -> usize {
    k.next_multiple_of(LANE_F32)
}

#[derive(Clone, Copy)]
#[repr(C, align(64))]
struct CacheLine([f32; LANE_F32]);

/// Growable `f32` buffer whose storage is 64-byte aligned (backed by
/// whole [`CacheLine`]s). Derefs to `[f32]`, so call sites index it like
/// the `Vec<f32>` it replaced; combined with a [`kpad`] stride every row
/// starts on its own cache line.
#[derive(Clone, Default)]
pub struct AlignedF32 {
    buf: Vec<CacheLine>,
    len: usize,
}

impl AlignedF32 {
    pub fn zeroed(len: usize) -> AlignedF32 {
        AlignedF32 {
            buf: vec![CacheLine([0.0; LANE_F32]); len.div_ceil(LANE_F32)],
            len,
        }
    }

    /// Grow (or shrink) to `len` elements; any newly exposed elements
    /// read as `0.0`, matching `Vec::resize(len, 0.0)`.
    pub fn resize_zeroed(&mut self, len: usize) {
        self.buf.resize(len.div_ceil(LANE_F32), CacheLine([0.0; LANE_F32]));
        let old = self.len.min(len);
        self.len = len;
        let s: &mut [f32] = self;
        s[old..].fill(0.0);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Deref for AlignedF32 {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        // SAFETY: `buf` is a contiguous `repr(C)` array of `[f32; 16]`
        // lines holding at least `len` floats (zeroed at allocation).
        unsafe { std::slice::from_raw_parts(self.buf.as_ptr().cast::<f32>(), self.len) }
    }
}

impl DerefMut for AlignedF32 {
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: as in `deref`; `&mut self` gives exclusive access.
        unsafe {
            std::slice::from_raw_parts_mut(self.buf.as_mut_ptr().cast::<f32>(), self.len)
        }
    }
}

impl fmt::Debug for AlignedF32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("AlignedF32").field("len", &self.len).finish()
    }
}

/// Which `fused_update` lane implementation a sweep runs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KernelKind {
    /// The verbatim scalar kernel — the default build and the oracle.
    Scalar,
    /// The explicit-SIMD lanes (`--features simd`, x86_64/aarch64 only).
    Wide,
}

/// Whether a wide kernel is compiled into this binary at all.
pub fn wide_compiled() -> bool {
    cfg!(all(
        feature = "simd",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))
}

// 0 = auto (wide when compiled), 1 = force scalar, 2 = force wide
static KERNEL_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Test/bench hook: force the kernel choice (`None` = back to auto).
/// Forcing [`KernelKind::Wide`] in a build without a wide kernel is a
/// no-op — [`active_kernel`] still reports `Scalar`, so scalar-only
/// builds run equivalence tests as scalar-vs-scalar (vacuously green).
pub fn force_kernel(kind: Option<KernelKind>) {
    let v = match kind {
        None => 0,
        Some(KernelKind::Scalar) => 1,
        Some(KernelKind::Wide) => 2,
    };
    KERNEL_OVERRIDE.store(v, Ordering::SeqCst);
}

/// The kernel the next sweep will run. Resolved once per sweep into
/// `SweepCtx` (not per entry), so a mid-sweep `force_kernel` cannot mix
/// kernels within one sweep.
pub fn active_kernel() -> KernelKind {
    match KERNEL_OVERRIDE.load(Ordering::SeqCst) {
        1 => KernelKind::Scalar,
        2 | 0 if wide_compiled() => KernelKind::Wide,
        _ => KernelKind::Scalar,
    }
}

/// The scalar score phase — the oracle expressions, shared verbatim by
/// the non-SIMD fallback and the wide kernels' `K mod 4` tails.
#[allow(clippy::too_many_arguments)]
#[inline]
fn score_scalar(
    x: f32,
    mu: &[f32],
    th_old: &[f32],
    phi_row: &[f32],
    phi_tot: &[f32],
    alpha: f32,
    beta: f32,
    wbeta: f32,
    scores: &mut [f32],
) {
    for ((((s, &m), &to), &ph), &pt) in scores
        .iter_mut()
        .zip(mu.iter())
        .zip(th_old.iter())
        .zip(phi_row.iter())
        .zip(phi_tot.iter())
    {
        let c = x * m;
        let th_m = (to - c).max(0.0) + alpha;
        let ph_m = (ph - c).max(0.0) + beta;
        let den = (pt - c).max(0.0) + wbeta;
        *s = th_m * ph_m / den.max(1e-30);
    }
}

/// The scalar delta phase (oracle expressions; see [`score_scalar`]).
#[inline]
fn delta_scalar(
    x: f32,
    scale: f32,
    scores: &mut [f32],
    mu: &mut [f32],
    th: &mut [f32],
    dphi: Option<&mut [f32]>,
    r: &mut [f32],
) {
    if let Some(dp) = dphi {
        for ((((s, m), t_), d_), r_) in scores
            .iter_mut()
            .zip(mu.iter_mut())
            .zip(th.iter_mut())
            .zip(dp.iter_mut())
            .zip(r.iter_mut())
        {
            let new = *s * scale;
            let dm = new - *m;
            *m = new;
            *t_ += x * dm;
            *d_ += x * dm;
            let rr = x * dm.abs();
            *r_ += rr;
            *s = rr;
        }
    } else {
        for (((s, m), t_), r_) in scores
            .iter_mut()
            .zip(mu.iter_mut())
            .zip(th.iter_mut())
            .zip(r.iter_mut())
        {
            let new = *s * scale;
            let dm = new - *m;
            *m = new;
            *t_ += x * dm;
            let rr = x * dm.abs();
            *r_ += rr;
            *s = rr;
        }
    }
}

/// Wide score phase: `scores[i] = ((th_old[i]-x·mu[i])⁺+α) ·
/// ((phi_row[i]-x·mu[i])⁺+β) / max((phi_tot[i]-x·mu[i])⁺+Wβ, 1e-30)`,
/// bitwise per lane equal to the scalar kernel. `scores.len()` governs;
/// the input slices must be at least that long. Serves both the dense
/// arm (μ/θ̂/φ̂ rows) and the packed subset arm (gathered gmu/gθ̂ and
/// packed φ̂/φ̂_Σ) of `fused_update`.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn score_phase(
    x: f32,
    mu: &[f32],
    th_old: &[f32],
    phi_row: &[f32],
    phi_tot: &[f32],
    alpha: f32,
    beta: f32,
    wbeta: f32,
    scores: &mut [f32],
) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    return sse::score_phase(x, mu, th_old, phi_row, phi_tot, alpha, beta, wbeta, scores);
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    return neon::score_phase(x, mu, th_old, phi_row, phi_tot, alpha, beta, wbeta, scores);
    #[cfg(not(all(
        feature = "simd",
        any(target_arch = "x86_64", target_arch = "aarch64")
    )))]
    score_scalar(x, mu, th_old, phi_row, phi_tot, alpha, beta, wbeta, scores)
}

/// Wide delta phase of the dense arm: rescale the score lanes into the
/// new μ, accumulate `x·Δμ` into θ̂ (and Δφ̂ when given), and park the
/// per-lane residual `x·|Δμ|` back in the score buffer (the caller's
/// sequential `rsum` fold reads it from there — the fixed horizontal
/// order). Bitwise per lane equal to the scalar kernel.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn delta_phase(
    x: f32,
    scale: f32,
    scores: &mut [f32],
    mu: &mut [f32],
    th: &mut [f32],
    dphi: Option<&mut [f32]>,
    r: &mut [f32],
) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    return sse::delta_phase(x, scale, scores, mu, th, dphi, r);
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    return neon::delta_phase(x, scale, scores, mu, th, dphi, r);
    #[cfg(not(all(
        feature = "simd",
        any(target_arch = "x86_64", target_arch = "aarch64")
    )))]
    delta_scalar(x, scale, scores, mu, th, dphi, r)
}

/// SSE2 lanes (baseline on every x86_64 target — no runtime detection).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod sse {
    use std::arch::x86_64::*;

    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub(super) fn score_phase(
        x: f32,
        mu: &[f32],
        th_old: &[f32],
        phi_row: &[f32],
        phi_tot: &[f32],
        alpha: f32,
        beta: f32,
        wbeta: f32,
        scores: &mut [f32],
    ) {
        let n = scores.len();
        debug_assert!(
            mu.len() >= n && th_old.len() >= n && phi_row.len() >= n && phi_tot.len() >= n
        );
        let wide = n - n % 4;
        // SAFETY: SSE2 is an x86_64 baseline feature; all loads/stores
        // stay below `wide <= n` and every input slice holds >= n floats.
        unsafe {
            let xv = _mm_set1_ps(x);
            let av = _mm_set1_ps(alpha);
            let bv = _mm_set1_ps(beta);
            let wv = _mm_set1_ps(wbeta);
            let zero = _mm_setzero_ps();
            let floor = _mm_set1_ps(1e-30);
            let mut i = 0;
            while i < wide {
                let m = _mm_loadu_ps(mu.as_ptr().add(i));
                let to = _mm_loadu_ps(th_old.as_ptr().add(i));
                let ph = _mm_loadu_ps(phi_row.as_ptr().add(i));
                let pt = _mm_loadu_ps(phi_tot.as_ptr().add(i));
                let c = _mm_mul_ps(xv, m);
                // constants ride in maxps's second operand — f32::max
                // semantics for every kernel-reachable input (module doc)
                let th_m = _mm_add_ps(_mm_max_ps(_mm_sub_ps(to, c), zero), av);
                let ph_m = _mm_add_ps(_mm_max_ps(_mm_sub_ps(ph, c), zero), bv);
                let den = _mm_add_ps(_mm_max_ps(_mm_sub_ps(pt, c), zero), wv);
                let s = _mm_div_ps(_mm_mul_ps(th_m, ph_m), _mm_max_ps(den, floor));
                _mm_storeu_ps(scores.as_mut_ptr().add(i), s);
                i += 4;
            }
        }
        super::score_scalar(
            x,
            &mu[wide..n],
            &th_old[wide..n],
            &phi_row[wide..n],
            &phi_tot[wide..n],
            alpha,
            beta,
            wbeta,
            &mut scores[wide..n],
        );
    }

    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub(super) fn delta_phase(
        x: f32,
        scale: f32,
        scores: &mut [f32],
        mu: &mut [f32],
        th: &mut [f32],
        mut dphi: Option<&mut [f32]>,
        r: &mut [f32],
    ) {
        let n = scores.len();
        debug_assert!(mu.len() >= n && th.len() >= n && r.len() >= n);
        debug_assert!(dphi.as_ref().map_or(true, |d| d.len() >= n));
        let wide = n - n % 4;
        let dp_ptr: Option<*mut f32> = dphi.as_mut().map(|d| d.as_mut_ptr());
        // SAFETY: as in `score_phase`; `scores`/`mu`/`th`/`dphi`/`r` are
        // distinct `&mut` slices, so the raw-pointer read/modify/write
        // per array never aliases another.
        unsafe {
            let xv = _mm_set1_ps(x);
            let sv = _mm_set1_ps(scale);
            let abs_mask = _mm_castsi128_ps(_mm_set1_epi32(0x7fff_ffff));
            let mut i = 0;
            while i < wide {
                let s = _mm_loadu_ps(scores.as_ptr().add(i));
                let m = _mm_loadu_ps(mu.as_ptr().add(i));
                let new = _mm_mul_ps(s, sv);
                let dm = _mm_sub_ps(new, m);
                _mm_storeu_ps(mu.as_mut_ptr().add(i), new);
                let xdm = _mm_mul_ps(xv, dm);
                let t = _mm_loadu_ps(th.as_ptr().add(i));
                _mm_storeu_ps(th.as_mut_ptr().add(i), _mm_add_ps(t, xdm));
                if let Some(dp) = dp_ptr {
                    let d = _mm_loadu_ps(dp.add(i));
                    _mm_storeu_ps(dp.add(i), _mm_add_ps(d, xdm));
                }
                // |dm| by clearing the sign bit — exactly f32::abs
                let rr = _mm_mul_ps(xv, _mm_and_ps(dm, abs_mask));
                let rv = _mm_loadu_ps(r.as_ptr().add(i));
                _mm_storeu_ps(r.as_mut_ptr().add(i), _mm_add_ps(rv, rr));
                _mm_storeu_ps(scores.as_mut_ptr().add(i), rr);
                i += 4;
            }
        }
        super::delta_scalar(
            x,
            scale,
            &mut scores[wide..n],
            &mut mu[wide..n],
            &mut th[wide..n],
            dphi.map(|d| &mut d[wide..n]),
            &mut r[wide..n],
        );
    }
}

/// NEON lanes (baseline on every aarch64 target). `fmax`/`fabs`/`fdiv`
/// are IEEE-exact on aarch64; the kernel's operands are finite (module
/// doc), so `vmaxq_f32` agrees with `f32::max` on every reachable lane.
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    use std::arch::aarch64::*;

    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub(super) fn score_phase(
        x: f32,
        mu: &[f32],
        th_old: &[f32],
        phi_row: &[f32],
        phi_tot: &[f32],
        alpha: f32,
        beta: f32,
        wbeta: f32,
        scores: &mut [f32],
    ) {
        let n = scores.len();
        debug_assert!(
            mu.len() >= n && th_old.len() >= n && phi_row.len() >= n && phi_tot.len() >= n
        );
        let wide = n - n % 4;
        // SAFETY: NEON is an aarch64 baseline feature; bounds as in the
        // SSE2 arm.
        unsafe {
            let xv = vdupq_n_f32(x);
            let av = vdupq_n_f32(alpha);
            let bv = vdupq_n_f32(beta);
            let wv = vdupq_n_f32(wbeta);
            let zero = vdupq_n_f32(0.0);
            let floor = vdupq_n_f32(1e-30);
            let mut i = 0;
            while i < wide {
                let m = vld1q_f32(mu.as_ptr().add(i));
                let to = vld1q_f32(th_old.as_ptr().add(i));
                let ph = vld1q_f32(phi_row.as_ptr().add(i));
                let pt = vld1q_f32(phi_tot.as_ptr().add(i));
                let c = vmulq_f32(xv, m);
                let th_m = vaddq_f32(vmaxq_f32(vsubq_f32(to, c), zero), av);
                let ph_m = vaddq_f32(vmaxq_f32(vsubq_f32(ph, c), zero), bv);
                let den = vaddq_f32(vmaxq_f32(vsubq_f32(pt, c), zero), wv);
                let s = vdivq_f32(vmulq_f32(th_m, ph_m), vmaxq_f32(den, floor));
                vst1q_f32(scores.as_mut_ptr().add(i), s);
                i += 4;
            }
        }
        super::score_scalar(
            x,
            &mu[wide..n],
            &th_old[wide..n],
            &phi_row[wide..n],
            &phi_tot[wide..n],
            alpha,
            beta,
            wbeta,
            &mut scores[wide..n],
        );
    }

    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub(super) fn delta_phase(
        x: f32,
        scale: f32,
        scores: &mut [f32],
        mu: &mut [f32],
        th: &mut [f32],
        mut dphi: Option<&mut [f32]>,
        r: &mut [f32],
    ) {
        let n = scores.len();
        debug_assert!(mu.len() >= n && th.len() >= n && r.len() >= n);
        debug_assert!(dphi.as_ref().map_or(true, |d| d.len() >= n));
        let wide = n - n % 4;
        let dp_ptr: Option<*mut f32> = dphi.as_mut().map(|d| d.as_mut_ptr());
        // SAFETY: as in `score_phase`; the `&mut` slices are disjoint.
        unsafe {
            let xv = vdupq_n_f32(x);
            let sv = vdupq_n_f32(scale);
            let mut i = 0;
            while i < wide {
                let s = vld1q_f32(scores.as_ptr().add(i));
                let m = vld1q_f32(mu.as_ptr().add(i));
                let new = vmulq_f32(s, sv);
                let dm = vsubq_f32(new, m);
                vst1q_f32(mu.as_mut_ptr().add(i), new);
                let xdm = vmulq_f32(xv, dm);
                let t = vld1q_f32(th.as_ptr().add(i));
                vst1q_f32(th.as_mut_ptr().add(i), vaddq_f32(t, xdm));
                if let Some(dp) = dp_ptr {
                    let d = vld1q_f32(dp.add(i));
                    vst1q_f32(dp.add(i), vaddq_f32(d, xdm));
                }
                let rr = vmulq_f32(xv, vabsq_f32(dm));
                let rv = vld1q_f32(r.as_ptr().add(i));
                vst1q_f32(r.as_mut_ptr().add(i), vaddq_f32(rv, rr));
                vst1q_f32(scores.as_mut_ptr().add(i), rr);
                i += 4;
            }
        }
        super::delta_scalar(
            x,
            scale,
            &mut scores[wide..n],
            &mut mu[wide..n],
            &mut th[wide..n],
            dphi.map(|d| &mut d[wide..n]),
            &mut r[wide..n],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kpad_rounds_to_cache_lines() {
        assert_eq!(kpad(1), 16);
        assert_eq!(kpad(16), 16);
        assert_eq!(kpad(17), 32);
        assert_eq!(kpad(50), 64);
    }

    #[test]
    fn aligned_buffer_is_64b_aligned_and_zeroed() {
        let mut a = AlignedF32::zeroed(50);
        assert_eq!(a.len(), 50);
        assert_eq!(a.as_ptr() as usize % 64, 0);
        assert!(a.iter().all(|&v| v == 0.0));
        a[49] = 1.5;
        a.resize_zeroed(130);
        assert_eq!(a.len(), 130);
        assert_eq!(a[49], 1.5);
        assert!(a[50..].iter().all(|&v| v == 0.0));
        a.resize_zeroed(8);
        a.resize_zeroed(50);
        assert!(a[8..].iter().all(|&v| v == 0.0), "shrink-grow must re-zero");
    }

    #[test]
    fn kernel_override_round_trips() {
        assert_eq!(
            active_kernel(),
            if wide_compiled() { KernelKind::Wide } else { KernelKind::Scalar }
        );
        force_kernel(Some(KernelKind::Scalar));
        assert_eq!(active_kernel(), KernelKind::Scalar);
        force_kernel(Some(KernelKind::Wide));
        assert_eq!(
            active_kernel(),
            if wide_compiled() { KernelKind::Wide } else { KernelKind::Scalar }
        );
        force_kernel(None);
    }

    /// The public phases must match the scalar oracle bitwise on every
    /// build (scalar builds trivially; SIMD builds because the lanes are
    /// bit-exact) — including lengths that exercise the `n mod 4` tail.
    #[test]
    fn wide_phases_match_scalar_bitwise() {
        for n in [1usize, 3, 4, 7, 8, 13, 50] {
            let x = 3.0f32;
            let mu: Vec<f32> = (0..n).map(|i| 0.01 + i as f32 * 0.37).collect();
            let th: Vec<f32> = (0..n).map(|i| 0.5 + i as f32 * 1.13).collect();
            let ph: Vec<f32> = (0..n).map(|i| 0.2 + i as f32 * 0.71).collect();
            let pt: Vec<f32> = (0..n).map(|i| 40.0 + i as f32 * 2.9).collect();
            let mut s_ref = vec![0f32; n];
            let mut s_got = vec![0f32; n];
            score_scalar(x, &mu, &th, &ph, &pt, 1.0, 0.01, 20.0, &mut s_ref);
            score_phase(x, &mu, &th, &ph, &pt, 1.0, 0.01, 20.0, &mut s_got);
            assert_eq!(
                s_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                s_got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "score lanes diverged at n={n}"
            );
            let scale = 0.731f32;
            let (mut mu_a, mut mu_b) = (mu.clone(), mu.clone());
            let (mut th_a, mut th_b) = (th.clone(), th.clone());
            let (mut dp_a, mut dp_b) = (ph.clone(), ph.clone());
            let (mut r_a, mut r_b) = (pt.clone(), pt.clone());
            let (mut sa, mut sb) = (s_ref.clone(), s_got.clone());
            delta_scalar(x, scale, &mut sa, &mut mu_a, &mut th_a, Some(&mut dp_a), &mut r_a);
            delta_phase(x, scale, &mut sb, &mut mu_b, &mut th_b, Some(&mut dp_b), &mut r_b);
            for (name, a, b) in [
                ("scores", &sa, &sb),
                ("mu", &mu_a, &mu_b),
                ("theta", &th_a, &th_b),
                ("dphi", &dp_a, &dp_b),
                ("r", &r_a, &r_b),
            ] {
                assert_eq!(
                    a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "delta {name} lanes diverged at n={n}"
                );
            }
        }
    }
}
