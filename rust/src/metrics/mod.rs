//! Run records and result writers: every bench/experiment writes CSV rows
//! under `results/` (plus a JSON sidecar with the full configuration) so
//! figures can be regenerated and diffed against the paper.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// A CSV table under construction.
pub struct Table {
    pub name: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(name: &str, header: &[&str]) -> Table {
        Table {
            name: name.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Push a row of displayable values.
    pub fn push<T: std::fmt::Display>(&mut self, cells: &[T]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }

    /// Write `results/<name>.csv`; returns the path.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        let mut f = std::fs::File::create(&path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(path)
    }

    /// Render as an aligned text table (for bench stdout).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

/// Write a JSON sidecar describing a run configuration.
pub fn save_sidecar(dir: &Path, name: &str, config: Json) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, config.to_string())?;
    Ok(path)
}

/// Default results directory (repo-root/results).
pub fn results_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results")
}

/// Format a float with a sensible number of digits for tables.
pub fn sig(v: f64) -> String {
    if !v.is_finite() {
        return "nan".into();
    }
    if v == 0.0 {
        return "0".into();
    }
    let a = v.abs();
    if a >= 100.0 {
        format!("{v:.1}")
    } else if a >= 1.0 {
        format!("{v:.3}")
    } else {
        format!("{v:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_and_render() {
        let mut t = Table::new("demo", &["algo", "perplexity"]);
        t.push(&["pobp".to_string(), "123.4".to_string()]);
        t.push(&["pvb".to_string(), "456.7".to_string()]);
        let csv = t.to_csv();
        assert_eq!(csv, "algo,perplexity\npobp,123.4\npvb,456.7\n");
        let rendered = t.render();
        assert!(rendered.contains("pobp"));
        assert!(rendered.lines().count() == 4);
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join("pobp_metrics_test");
        let mut t = Table::new("x", &["a"]);
        t.push(&[1.5]);
        let p = t.save(&dir).unwrap();
        assert!(p.exists());
        let sc = save_sidecar(&dir, "x", Json::obj(vec![("k", Json::from(5usize))])).unwrap();
        assert!(sc.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sig_formats() {
        assert_eq!(sig(1234.5678), "1234.6");
        assert_eq!(sig(12.3456), "12.346");
        assert_eq!(sig(0.00123), "1.230e-3");
        assert_eq!(sig(f64::NAN), "nan");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push(&[1]);
    }
}
