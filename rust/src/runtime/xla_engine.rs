//! OBP/POBP through the AOT-compiled XLA sweep — the three-layer request
//! path: Rust coordinator (L3) → compiled JAX graph (L2) → Pallas kernel
//! (L1), with Python long gone.
//!
//! Each mini-batch shard is padded to the artifact's compiled (D, W)
//! shape; messages live as a dense (D, W, K) buffer between iterations.
//! The dense path is the demonstration/parity engine — the native sparse
//! engine in `engine::bp` is the throughput path — and the two are
//! validated against each other in `rust/tests/xla_parity.rs`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::comm::{Ledger, NetModel};
use crate::corpus::Csr;
use crate::engine::traits::{IterStat, LdaParams, Model, TrainResult};
use crate::runtime::pjrt::{SweepArgs, SweepExecutable};
use crate::sched::{select_power, PowerParams};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Configuration of the XLA-backed online engine (single processor; the
/// multi-worker POBP protocol is exercised by the native engine, which is
/// numerically the same contract — see the parity test).
#[derive(Clone, Debug)]
pub struct XlaObpConfig {
    pub max_iters: usize,
    pub min_iters: usize,
    pub converge_thresh: f64,
    /// relative residual-decay condition (see coordinator::PobpConfig)
    pub converge_rel: f64,
    pub power: PowerParams,
    pub seed: u64,
}

impl Default for XlaObpConfig {
    fn default() -> Self {
        XlaObpConfig {
            max_iters: 30,
            min_iters: 5,
            converge_thresh: 0.1,
            converge_rel: 0.01,
            power: PowerParams::full(),
            seed: 42,
        }
    }
}

/// Densify a doc-range of a corpus into a padded (D, W) count matrix.
pub fn densify(data: &Csr, d_pad: usize, w_pad: usize) -> Vec<f32> {
    assert!(data.docs() <= d_pad && data.w <= w_pad);
    let mut x = vec![0f32; d_pad * w_pad];
    for d in 0..data.docs() {
        let (ws, vs) = data.row(d);
        for (&wi, &c) in ws.iter().zip(vs) {
            x[d * w_pad + wi as usize] = c;
        }
    }
    x
}

/// Random normalized messages for a dense padded shard (matches the
/// Fig. 4 line-3 init of the native engine).
pub fn init_dense_messages(d_pad: usize, w_pad: usize, k: usize, rng: &mut Rng) -> Vec<f32> {
    let mut mu = vec![0f32; d_pad * w_pad * k];
    for row in mu.chunks_exact_mut(k) {
        let mut sum = 0f32;
        for v in row.iter_mut() {
            *v = rng.f32() + 0.1;
            sum += *v;
        }
        row.iter_mut().for_each(|v| *v /= sum);
    }
    mu
}

/// Train online BP over `corpus` executing every sweep through the AOT
/// artifact in `artifact_dir`. The artifact's K must equal `params.k` and
/// its compiled W must be ≥ the corpus vocabulary.
pub fn fit_obp_xla(
    corpus: &Csr,
    params: &LdaParams,
    artifact_dir: &Path,
    cfg: &XlaObpConfig,
) -> Result<TrainResult> {
    let wall = Stopwatch::new();
    let (w, k) = (corpus.w, params.k);
    let mut rng = Rng::new(cfg.seed);

    // pick an artifact that fits the vocabulary; batch docs to its D
    let manifest = crate::runtime::artifacts::Manifest::load(artifact_dir)?;
    let entry = manifest
        .fit(1, w, k)
        .with_context(|| format!("no artifact with k={k}, w>={w}"))?
        .clone();
    let exe = SweepExecutable::load(&entry)?;
    let (d_pad, w_pad) = (entry.d, entry.w);

    let mut ledger = Ledger::new(NetModel::infiniband_20gbps());
    let mut history = Vec::new();
    let mut phi_acc = vec![0f32; w_pad * k]; // padded vocab; tail stays 0

    // batch by document count ≤ compiled D (and the CSR nnz budget is
    // irrelevant here: the dense buffer is the limit)
    let mut doc_lo = 0usize;
    let mut batch_index = 0usize;
    while doc_lo < corpus.docs() {
        let doc_hi = (doc_lo + d_pad).min(corpus.docs());
        let slice = corpus.slice_docs(doc_lo, doc_hi);
        let tokens = slice.tokens().max(1.0);
        let x = densify(&slice, d_pad, w_pad);
        let mut mu = init_dense_messages(d_pad, w_pad, k, &mut rng);
        let mut word_mask = vec![1f32; w_pad];
        let mut topic_mask = vec![1f32; w_pad * k];
        let mut r_global = vec![0f32; w_pad * k];
        let mut r_total: f64;
        let mut prev_resid = f64::INFINITY;
        let mut first_resid = f64::INFINITY;
        let mut dphi_last = vec![0f32; w_pad * k];

        for t in 1..=cfg.max_iters {
            let (out, secs) = {
                let t0 = std::time::Instant::now();
                let out = exe.run(&SweepArgs {
                    x: &x,
                    mu: &mu,
                    phi_prev: &phi_acc,
                    word_mask: &word_mask,
                    topic_mask: &topic_mask,
                })?;
                (out, t0.elapsed().as_secs_f64())
            };
            ledger.record_compute(&[secs]);
            mu = out.mu;
            dphi_last = out.dphi;

            // residual bookkeeping mirrors the native coordinator: fresh
            // values on selected pairs, stale elsewhere
            let mut pairs = 0usize;
            for i in 0..w_pad * k {
                let selected =
                    word_mask[i / k] > 0.0 && topic_mask[i] > 0.0;
                if selected {
                    r_global[i] = out.r_wk[i];
                    pairs += 1;
                }
            }
            r_total = r_global.iter().map(|&v| v as f64).sum();
            // N = 1: no communication, but the sync payload is what a
            // multi-worker run would ship — record it with n = 1 (free)
            ledger.record_sync(batch_index, t, 2 * 4 * pairs, 1);

            let resid_per_token = r_total / tokens;
            history.push(IterStat {
                batch: batch_index,
                iter: t,
                residual_per_token: resid_per_token,
                synced_pairs: pairs,
                sim_elapsed: ledger.total_secs(),
                wall_elapsed: wall.total_secs(),
            });
            if t == 1 {
                first_resid = resid_per_token.max(1e-12);
            }
            if t >= cfg.min_iters
                && resid_per_token <= cfg.converge_thresh
                && resid_per_token <= cfg.converge_rel * first_resid
                && resid_per_token <= prev_resid
            {
                break;
            }
            prev_resid = resid_per_token;

            // dynamic power selection on the padded (W, K) residuals
            if cfg.power.lambda_w < 1.0 || cfg.power.lambda_k_times_k < k {
                let ps = select_power(&r_global, w_pad, k, &cfg.power);
                word_mask.fill(0.0);
                topic_mask.fill(0.0);
                for (i, &wi) in ps.words.iter().enumerate() {
                    word_mask[wi as usize] = 1.0;
                    for &tt in &ps.topics[i] {
                        topic_mask[wi as usize * k + tt as usize] = 1.0;
                    }
                }
            }
        }

        // fold the batch gradient into the accumulated statistics (Eq. 11)
        for (acc, &g) in phi_acc.iter_mut().zip(&dphi_last) {
            *acc += g;
        }
        doc_lo = doc_hi;
        batch_index += 1;
    }

    // un-pad the vocabulary back to the corpus W
    let mut phi_wk = vec![0f32; w * k];
    for wi in 0..w {
        phi_wk[wi * k..(wi + 1) * k]
            .copy_from_slice(&phi_acc[wi * k..(wi + 1) * k]);
    }
    Ok(TrainResult {
        model: Model { k, w, phi_wk },
        history,
        ledger,
        wall_secs: wall.total_secs(),
        snapshots: vec![],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn densify_pads_correctly() {
        let c = Csr::from_docs(3, &[vec![(0, 2.0), (2, 1.0)], vec![(1, 5.0)]]);
        let x = densify(&c, 4, 5);
        assert_eq!(x.len(), 20);
        assert_eq!(x[0], 2.0);
        assert_eq!(x[2], 1.0);
        assert_eq!(x[5 + 1], 5.0);
        assert_eq!(x.iter().sum::<f32>(), 8.0);
    }

    #[test]
    fn dense_messages_normalized() {
        let mut rng = Rng::new(1);
        let mu = init_dense_messages(2, 3, 4, &mut rng);
        for row in mu.chunks_exact(4) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
    }
}
