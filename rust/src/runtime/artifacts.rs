//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `make artifacts` lowers the Layer-2 POBP sweep once per compiled shape
//! and writes `artifacts/manifest.json`; this module parses it and picks
//! the artifact a shard fits into (shards are padded up to the compiled
//! (D, W) — K must match exactly since it changes the model).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One AOT-compiled sweep shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub file: PathBuf,
    pub d: usize,
    pub w: usize,
    pub k: usize,
    pub alpha: f64,
    pub beta: f64,
    pub block_d: usize,
    pub block_w: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        if v.get("format").and_then(|f| f.as_str()) != Some("hlo-text") {
            bail!("manifest format is not hlo-text");
        }
        let mut entries = Vec::new();
        for e in v
            .get("entries")
            .and_then(|e| e.as_arr())
            .context("manifest missing entries")?
        {
            let get = |k: &str| -> Result<usize> {
                e.get(k).and_then(|x| x.as_usize()).with_context(|| format!("entry missing {k}"))
            };
            let getf = |k: &str| -> Result<f64> {
                e.get(k).and_then(|x| x.as_f64()).with_context(|| format!("entry missing {k}"))
            };
            entries.push(ArtifactEntry {
                file: dir.join(
                    e.get("file").and_then(|f| f.as_str()).context("entry missing file")?,
                ),
                d: get("d")?,
                w: get("w")?,
                k: get("k")?,
                alpha: getf("alpha")?,
                beta: getf("beta")?,
                block_d: get("block_d")?,
                block_w: get("block_w")?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Smallest compiled shape that fits a (docs, vocab) shard for topic
    /// count `k`.
    pub fn fit(&self, docs: usize, vocab: usize, k: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.k == k && e.d >= docs && e.w >= vocab)
            .min_by_key(|e| e.d * e.w)
    }

    /// Exact-K entries (any padding), largest first — used to report what
    /// is available when `fit` fails.
    pub fn for_k(&self, k: usize) -> Vec<&ArtifactEntry> {
        self.entries.iter().filter(|e| e.k == k).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "alpha_times_k": 2.0, "beta": 0.01,
      "entries": [
        {"file": "a.hlo.txt", "d": 32, "w": 256, "k": 16,
         "alpha": 0.125, "beta": 0.01, "block_d": 32, "block_w": 128},
        {"file": "b.hlo.txt", "d": 64, "w": 512, "k": 50,
         "alpha": 0.04, "beta": 0.01, "block_d": 32, "block_w": 128}
      ]}"#;

    #[test]
    fn parses_and_fits() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.fit(20, 200, 16).unwrap();
        assert_eq!(e.d, 32);
        assert!(m.fit(100, 200, 16).is_none(), "too many docs must not fit");
        assert!(m.fit(10, 10, 99).is_none(), "unknown K must not fit");
        assert_eq!(m.for_k(50).len(), 1);
    }

    #[test]
    fn rejects_wrong_format() {
        assert!(Manifest::parse(Path::new("."), r#"{"format":"proto","entries":[]}"#).is_err());
        assert!(Manifest::parse(Path::new("."), "not json").is_err());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        // integration: if `make artifacts` has run, the real manifest
        // must parse and contain the quickstart shape
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.fit(64, 512, 50).is_some());
        }
    }
}
