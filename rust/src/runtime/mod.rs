//! Runtime: PJRT loading/execution of the AOT artifacts (L2+L1) from the
//! Rust hot path. `artifacts` parses the manifest, `pjrt` wraps the xla
//! crate, `xla_engine` drives online BP through the compiled sweep.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(feature = "xla")]
pub mod xla_engine;

pub use artifacts::Manifest;
#[cfg(feature = "xla")]
pub use pjrt::{SweepArgs, SweepExecutable, SweepOut};
