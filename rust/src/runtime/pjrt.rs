//! PJRT execution of the AOT-compiled Layer-2 sweep.
//!
//! Wraps the `xla` crate: load HLO **text** (`HloModuleProto::from_text_file`
//! — the id-safe interchange format, see python/compile/aot.py), compile on
//! the CPU PJRT client once, then execute from the L3 hot path with plain
//! `f32` buffers. Python is never involved at run time.

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::artifacts::ArtifactEntry;

/// A compiled POBP sweep executable for one (D, W, K) shape.
pub struct SweepExecutable {
    pub entry: ArtifactEntry,
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

/// Inputs of one sweep call, shapes per the artifact entry:
/// x (D,W), mu (D,W,K), phi_prev (W,K), word_mask (W), topic_mask (W,K).
pub struct SweepArgs<'a> {
    pub x: &'a [f32],
    pub mu: &'a [f32],
    pub phi_prev: &'a [f32],
    pub word_mask: &'a [f32],
    pub topic_mask: &'a [f32],
}

/// Outputs of one sweep call: mu' (D,W,K), theta' (D,K), dphi' (W,K),
/// r_wk (W,K).
pub struct SweepOut {
    pub mu: Vec<f32>,
    pub theta: Vec<f32>,
    pub dphi: Vec<f32>,
    pub r_wk: Vec<f32>,
}

impl SweepExecutable {
    /// Load + compile the artifact (expensive; do once per shape).
    pub fn load(entry: &ArtifactEntry) -> Result<SweepExecutable> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .with_context(|| format!("parse {}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compile sweep HLO")?;
        Ok(SweepExecutable { entry: entry.clone(), client, exe })
    }

    /// Convenience: load the best-fitting artifact from a directory.
    pub fn load_fitting(dir: &Path, docs: usize, vocab: usize, k: usize) -> Result<SweepExecutable> {
        let manifest = crate::runtime::artifacts::Manifest::load(dir)?;
        let entry = manifest.fit(docs, vocab, k).with_context(|| {
            format!(
                "no artifact fits shard d={docs} w={vocab} k={k}; available: {:?}",
                manifest.entries.iter().map(|e| (e.d, e.w, e.k)).collect::<Vec<_>>()
            )
        })?;
        Self::load(entry)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute one sweep. Buffers must match the compiled shape exactly
    /// (callers pad — see [`crate::runtime::xla_engine`]).
    pub fn run(&self, args: &SweepArgs<'_>) -> Result<SweepOut> {
        let (d, w, k) = (
            self.entry.d as i64,
            self.entry.w as i64,
            self.entry.k as i64,
        );
        anyhow::ensure!(args.x.len() == (d * w) as usize, "x shape");
        anyhow::ensure!(args.mu.len() == (d * w * k) as usize, "mu shape");
        anyhow::ensure!(args.phi_prev.len() == (w * k) as usize, "phi shape");
        anyhow::ensure!(args.word_mask.len() == w as usize, "word_mask shape");
        anyhow::ensure!(args.topic_mask.len() == (w * k) as usize, "topic_mask shape");

        let lit = |data: &[f32], dims: &[i64]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(data).reshape(dims)?)
        };
        let inputs = [
            lit(args.x, &[d, w])?,
            lit(args.mu, &[d, w, k])?,
            lit(args.phi_prev, &[w, k])?,
            lit(args.word_mask, &[w])?,
            lit(args.topic_mask, &[w, k])?,
        ];
        let result = self.exe.execute::<xla::Literal>(&inputs)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 4, "expected 4 outputs, got {}", outs.len());
        Ok(SweepOut {
            mu: outs[0].to_vec::<f32>()?,
            theta: outs[1].to_vec::<f32>()?,
            dphi: outs[2].to_vec::<f32>()?,
            r_wk: outs[3].to_vec::<f32>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Manifest;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// End-to-end smoke: load the CI-shape artifact and run one sweep.
    /// Skipped (not failed) when artifacts have not been built.
    #[test]
    fn executes_ci_artifact() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let e = m.fit(32, 256, 16).expect("ci shape present");
        let exe = SweepExecutable::load(e).unwrap();
        let (d, w, k) = (e.d, e.w, e.k);

        // uniform messages over 1-count x on the first 8 words
        let mut x = vec![0f32; d * w];
        for dd in 0..d {
            for ww in 0..8 {
                x[dd * w + ww] = 1.0;
            }
        }
        let mu = vec![1.0 / k as f32; d * w * k];
        let phi_prev = vec![0f32; w * k];
        let ones_w = vec![1f32; w];
        let ones_wk = vec![1f32; w * k];
        let out = exe
            .run(&SweepArgs {
                x: &x,
                mu: &mu,
                phi_prev: &phi_prev,
                word_mask: &ones_w,
                topic_mask: &ones_wk,
            })
            .unwrap();

        // mass conservation: theta and dphi sum to token count
        let tokens: f32 = x.iter().sum();
        let th: f32 = out.theta.iter().sum();
        let dp: f32 = out.dphi.iter().sum();
        assert!((th - tokens).abs() < tokens * 1e-4, "theta {th} vs {tokens}");
        assert!((dp - tokens).abs() < tokens * 1e-4, "dphi {dp} vs {tokens}");
        // messages on active entries stay normalized
        for dd in 0..d {
            let row = &out.mu[(dd * w) * k..(dd * w + 1) * k];
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }
}
