//! Scheduled-document permutation for the block-parallel ABP sweep.
//!
//! ABP's t ≥ 2 iterations sweep a residual-ordered *subset* of the
//! documents, so the fixed doc-block partition of the t = 1 engine
//! (`engine::bp`) does not apply: the scheduled docs are non-contiguous
//! in the shard, and residual order changes every iteration. The
//! standard fix ("Model-Parallel Inference for Big Topic Models", Zheng
//! et al.) is to turn the data-dependent schedule into **disjoint work
//! sets via an index permutation** — that permutation is what
//! [`DocSchedule`] derives, once per scheduled sweep:
//!
//! 1. **Sort** the scheduled doc ids ascending. Documents are Jacobi-
//!    independent within a sweep (each reads only the frozen global φ̂
//!    and its own θ̂ row), so the processing order is free — and sorted
//!    order makes every block's μ/θ̂ rows live inside one *contiguous*
//!    span of the shard matrices, which is what lets the engine hand
//!    plain disjoint `&mut` slices to the thread pool.
//! 2. **Cut blocks** on cumulative *scheduled* NNZ only (never the core
//!    count), exactly like the t = 1 block partition's contract: the
//!    block structure — and therefore every merge-order-keyed float
//!    accumulation downstream — is identical on every machine at every
//!    thread budget. A document is never split across blocks.
//! 3. **Remember the inverse permutation** ([`DocSchedule::sched_pos`])
//!    so per-doc residuals can be handed back in the caller's original
//!    schedule (residual-descending) order.
//!
//! The consumer is [`ShardBp::sweep_docs_parallel`], which drives the
//! blocks over `Cluster::run_on_permuted_blocks` and merges per-block
//! Δφ̂/r scratch rows in ascending block order (the same deterministic
//! merge protocol as the t = 1 engine).
//!
//! [`ShardBp::sweep_docs_parallel`]: crate::engine::bp::ShardBp::sweep_docs_parallel
//!
//! # Example
//!
//! ```
//! use pobp::sched::DocSchedule;
//!
//! // residual-descending schedule over a 6-doc shard; per-doc NNZ below
//! let scheduled = [4u32, 1, 5, 2];
//! let doc_nnz = [3usize, 2, 4, 1, 5, 2];
//! let ds = DocSchedule::build(&scheduled, |d| doc_nnz[d]);
//! assert_eq!(ds.docs_sorted(), &[1, 2, 4, 5]);     // the permutation
//! assert_eq!(ds.len(), 4);
//! assert_eq!(ds.nnz(), 2 + 4 + 5 + 2);             // scheduled NNZ only
//! // blocks partition the sorted list; no doc is ever split
//! let total: usize = (0..ds.blocks()).map(|b| ds.block(b).len()).sum();
//! assert_eq!(total, ds.len());
//! // the inverse permutation recovers schedule order
//! for (i, &d) in ds.docs_sorted().iter().enumerate() {
//!     assert_eq!(scheduled[ds.sched_pos()[i] as usize], d);
//! }
//! ```

/// Block-partition targets for the scheduled sweep: blocks are cut when
/// their scheduled-NNZ count reaches `max(sched_nnz / SCHED_BLOCK_MAX,
/// SCHED_BLOCK_MIN_NNZ)`. Both constants are data-only (no core counts),
/// mirroring the t = 1 engine's `DOC_BLOCK_MAX` / `DOC_BLOCK_MIN_NNZ`, so
/// the block structure is machine-independent.
const SCHED_BLOCK_MAX: usize = 32;
const SCHED_BLOCK_MIN_NNZ: usize = 1024;

/// A machine-independent permutation of one iteration's scheduled
/// documents into NNZ-balanced, doc-granular blocks (module doc).
#[derive(Clone, Debug, Default)]
pub struct DocSchedule {
    /// scheduled doc ids, ascending — the index permutation
    docs_sorted: Vec<u32>,
    /// inverse permutation: `sched_pos[i]` is the position of
    /// `docs_sorted[i]` in the caller's original schedule order
    sched_pos: Vec<u32>,
    /// block boundaries into `docs_sorted`, len = blocks + 1
    block_off: Vec<u32>,
    /// total NNZ of the scheduled documents
    nnz: usize,
}

impl DocSchedule {
    /// Derive the permutation and block partition from a schedule of
    /// **distinct** doc ids (`top_k_desc` order in ABP) and a per-doc
    /// NNZ accessor. Boundaries come from scheduled-NNZ counts only.
    pub fn build<F: Fn(usize) -> usize>(scheduled: &[u32], doc_nnz: F) -> DocSchedule {
        let mut order: Vec<(u32, u32)> = scheduled
            .iter()
            .enumerate()
            .map(|(pos, &d)| (d, pos as u32))
            .collect();
        order.sort_unstable();
        let docs_sorted: Vec<u32> = order.iter().map(|&(d, _)| d).collect();
        let sched_pos: Vec<u32> = order.iter().map(|&(_, p)| p).collect();
        debug_assert!(
            docs_sorted.windows(2).all(|w| w[0] < w[1]),
            "schedule must hold distinct doc ids"
        );
        let nnz: usize = docs_sorted.iter().map(|&d| doc_nnz(d as usize)).sum();

        let mut block_off = vec![0u32];
        if !docs_sorted.is_empty() {
            let target = nnz.div_ceil(SCHED_BLOCK_MAX).max(SCHED_BLOCK_MIN_NNZ);
            let mut acc = 0usize;
            for (i, &d) in docs_sorted.iter().enumerate() {
                acc += doc_nnz(d as usize);
                if acc >= target && i + 1 < docs_sorted.len() {
                    block_off.push((i + 1) as u32);
                    acc = 0;
                }
            }
            block_off.push(docs_sorted.len() as u32);
        }
        DocSchedule { docs_sorted, sched_pos, block_off, nnz }
    }

    /// Scheduled docs in ascending (permuted) order.
    pub fn docs_sorted(&self) -> &[u32] {
        &self.docs_sorted
    }

    /// Inverse permutation back to the caller's schedule order.
    pub fn sched_pos(&self) -> &[u32] {
        &self.sched_pos
    }

    /// Number of scheduled documents.
    pub fn len(&self) -> usize {
        self.docs_sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.docs_sorted.is_empty()
    }

    /// Total NNZ of the scheduled documents.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Fraction of a `total_docs`-document shard this schedule covers —
    /// the quantity the fixed-block reuse threshold compares against
    /// (`AbpConfig::sched_reuse_coverage`): above the threshold the
    /// consumer sweeps over the t = 1 fixed block tables instead of
    /// building the per-sweep permutation tables.
    pub fn coverage(&self, total_docs: usize) -> f64 {
        if total_docs == 0 {
            0.0
        } else {
            self.docs_sorted.len() as f64 / total_docs as f64
        }
    }

    /// Number of blocks (0 for an empty schedule).
    pub fn blocks(&self) -> usize {
        self.block_off.len().saturating_sub(1)
    }

    /// Ascending doc ids of block `b` — a whole-document slice of the
    /// sorted schedule (a doc is never split across blocks).
    pub fn block(&self, b: usize) -> &[u32] {
        &self.docs_sorted[self.block_off[b] as usize..self.block_off[b + 1] as usize]
    }

    /// Half-open range of sorted-schedule positions covered by block `b`.
    pub fn block_range(&self, b: usize) -> std::ops::Range<usize> {
        self.block_off[b] as usize..self.block_off[b + 1] as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn nnz_table(docs: usize, rng: &mut Rng) -> Vec<usize> {
        (0..docs).map(|_| 1 + rng.below(400)).collect()
    }

    #[test]
    fn permutation_roundtrips_and_blocks_partition() {
        let mut rng = Rng::new(11);
        for trial in 0..20 {
            let docs = 1 + rng.below(3000);
            let nnz = nnz_table(docs, &mut rng);
            // distinct random subset in shuffled (schedule-like) order
            let mut scheduled: Vec<u32> =
                (0..docs as u32).filter(|_| rng.f32() < 0.4).collect();
            if scheduled.is_empty() {
                scheduled.push(rng.below(docs) as u32);
            }
            rng.shuffle(&mut scheduled);
            let ds = DocSchedule::build(&scheduled, |d| nnz[d]);

            assert_eq!(ds.len(), scheduled.len(), "trial {trial}");
            assert_eq!(
                ds.nnz(),
                scheduled.iter().map(|&d| nnz[d as usize]).sum::<usize>()
            );
            // sorted ascending, distinct
            assert!(ds.docs_sorted().windows(2).all(|w| w[0] < w[1]));
            // inverse permutation recovers the original schedule
            for (i, &d) in ds.docs_sorted().iter().enumerate() {
                assert_eq!(scheduled[ds.sched_pos()[i] as usize], d);
            }
            // blocks partition the sorted list exactly once, no empty
            // blocks, no doc split across blocks
            let mut covered = 0usize;
            for b in 0..ds.blocks() {
                let rg = ds.block_range(b);
                assert_eq!(rg.start, covered);
                assert!(rg.end > rg.start, "empty block {b}");
                assert_eq!(ds.block(b).len(), rg.len());
                covered = rg.end;
            }
            assert_eq!(covered, ds.len());
        }
    }

    #[test]
    fn deterministic_for_a_given_schedule() {
        let mut rng = Rng::new(13);
        let nnz = nnz_table(500, &mut rng);
        let mut scheduled: Vec<u32> = (0..500u32).step_by(3).collect();
        rng.shuffle(&mut scheduled);
        let a = DocSchedule::build(&scheduled, |d| nnz[d]);
        let b = DocSchedule::build(&scheduled, |d| nnz[d]);
        assert_eq!(a.docs_sorted(), b.docs_sorted());
        assert_eq!(a.sched_pos(), b.sched_pos());
        assert_eq!(a.block_off, b.block_off);
        // and independent of the schedule's order (the permutation
        // depends only on the *set*)
        let mut reordered = scheduled.clone();
        reordered.reverse();
        let c = DocSchedule::build(&reordered, |d| nnz[d]);
        assert_eq!(a.docs_sorted(), c.docs_sorted());
        assert_eq!(a.block_off, c.block_off);
    }

    #[test]
    fn block_boundaries_balance_scheduled_nnz() {
        // heavy uniform docs: every block except the last must carry at
        // least the target NNZ, so no block is pathologically small
        let nnz_per = 100usize;
        let scheduled: Vec<u32> = (0..2000u32).collect();
        let ds = DocSchedule::build(&scheduled, |_| nnz_per);
        assert!(ds.blocks() > 1, "want a multi-block partition");
        let target = (ds.nnz().div_ceil(SCHED_BLOCK_MAX)).max(SCHED_BLOCK_MIN_NNZ);
        for b in 0..ds.blocks() - 1 {
            let bn: usize = ds.block(b).len() * nnz_per;
            assert!(bn >= target, "block {b} under target: {bn} < {target}");
            assert!(bn < target + nnz_per, "block {b} overshot: {bn}");
        }
    }

    #[test]
    fn coverage_is_schedule_fraction() {
        let ds = DocSchedule::build(&[0, 2, 4, 6], |_| 3);
        assert!((ds.coverage(8) - 0.5).abs() < 1e-12);
        assert_eq!(ds.coverage(0), 0.0);
        assert_eq!(DocSchedule::build(&[], |_| 1).coverage(10), 0.0);
    }

    #[test]
    fn empty_and_singleton_schedules() {
        let ds = DocSchedule::build(&[], |_| 7);
        assert!(ds.is_empty());
        assert_eq!(ds.blocks(), 0);
        assert_eq!(ds.nnz(), 0);
        let ds = DocSchedule::build(&[42], |_| 7);
        assert_eq!(ds.len(), 1);
        assert_eq!(ds.blocks(), 1);
        assert_eq!(ds.block(0), &[42]);
        assert_eq!(ds.sched_pos(), &[0]);
    }
}
