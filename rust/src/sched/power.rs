//! Two-step power word / power topic selection (§3.1, Figs. 2–3).
//!
//! Given the synchronized residual matrix r_w(k) (row-major `(W, K)`) and
//! its word marginal r_w, select:
//!
//!   1. the `λ_W·W` words with largest total residual (*power words*),
//!   2. for each power word, the `λ_K·K` topics with largest residual
//!      (*power topics*),
//!
//! both with a partial sort (util::partial_sort). The selection is the
//! synchronization *and* computation schedule for the next iteration: only
//! the selected (word, topic) pairs are updated and allreduced.

use crate::util::partial_sort::{top_k_desc, top_k_desc_strided};

/// A power selection: the dynamic schedule for one iteration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PowerSet {
    /// selected word ids, residual-descending
    pub words: Vec<u32>,
    /// topics per selected word: `topics[i]` belongs to `words[i]`,
    /// each residual-descending
    pub topics: Vec<Vec<u32>>,
}

impl PowerSet {
    /// Number of (word, topic) pairs selected — the per-processor payload
    /// element count of Eq. (6).
    pub fn pairs(&self) -> usize {
        self.topics.iter().map(|t| t.len()).sum()
    }

    /// Flat row-major indices (w·K + k) of the selected pairs, in
    /// selection order, written into `out` (cleared first, capacity
    /// reused) — the coordinator's per-iteration plan build without the
    /// per-sync allocation. `k_total` is K.
    pub fn flat_indices_into(&self, k_total: usize, out: &mut Vec<u32>) {
        out.clear();
        out.reserve(self.pairs());
        for (wi, &w) in self.words.iter().enumerate() {
            for &k in &self.topics[wi] {
                out.push(w * k_total as u32 + k);
            }
        }
    }

    /// Allocating wrapper over [`PowerSet::flat_indices_into`].
    pub fn flat_indices(&self, k_total: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.flat_indices_into(k_total, &mut out);
        out
    }

    /// Bytes per processor to synchronize one f32 matrix restricted to
    /// this selection (the paper syncs both φ̂ and r, so callers double it).
    pub fn payload_bytes(&self) -> usize {
        4 * self.pairs()
    }

    // NOTE: there is deliberately no `PowerSet::full(w, k)` constructor.
    // It used to materialize `W` separate `Vec<u32>` of length `K` — an
    // O(W·K) heap bill for "everything" (PUBMED scale: W ≈ 141k ×
    // K = 2000 ≈ 3·10⁸ u32s). The full schedule is implicit: the
    // coordinator's `Option<PowerSet>` is `None`, and the allreduce runs
    // a dense plan (`comm::allreduce::ReducePlan::Dense`).
}

/// Ratios λ_W, λ_K of §3.1. `lambda_k_times_k` follows the paper's
/// practical parameterization: "each word may not be allocated to many
/// topics, and thus λ_K·K is often a fixed value" (§4.1, default 50).
#[derive(Clone, Copy, Debug)]
pub struct PowerParams {
    pub lambda_w: f64,
    /// absolute number of power topics per power word (λ_K·K)
    pub lambda_k_times_k: usize,
}

impl PowerParams {
    /// The paper's recommended setting: λ_W = 0.1, λ_K·K = 50 (§4.1).
    pub fn paper_default() -> PowerParams {
        PowerParams { lambda_w: 0.1, lambda_k_times_k: 50 }
    }

    /// Disable selection: scan everything (reduces POBP to plain parallel
    /// OBP; used by ablations).
    pub fn full() -> PowerParams {
        PowerParams { lambda_w: 1.0, lambda_k_times_k: usize::MAX }
    }

    pub fn words_of(&self, w: usize) -> usize {
        ((self.lambda_w * w as f64).ceil() as usize).clamp(1, w)
    }

    pub fn topics_of(&self, k: usize) -> usize {
        self.lambda_k_times_k.clamp(1, k)
    }
}

/// Two-step selection from the synchronized residual matrix
/// (`r_wk`: row-major `(W, K)`).
pub fn select_power(r_wk: &[f32], w: usize, k: usize, params: &PowerParams) -> PowerSet {
    debug_assert_eq!(r_wk.len(), w * k);
    // Step 1: word marginals r_w = sum_k r_w(k)  (Eq. 10)
    let r_w: Vec<f32> = (0..w)
        .map(|wi| r_wk[wi * k..(wi + 1) * k].iter().sum())
        .collect();
    let words = top_k_desc(&r_w, params.words_of(w));
    // Step 2: per selected word, top topics (Eq. 9 sorted along K)
    let kk = params.topics_of(k);
    let topics = words
        .iter()
        .map(|&wi| top_k_desc_strided(r_wk, wi as usize * k, 1, k, kk))
        .collect();
    PowerSet { words, topics }
}

/// [`select_power`] over a **sharded** residual matrix: the per-owner
/// row-aligned r slices of the sharded storage mode (`r_parts`, owner
/// order; word `wi`'s row lives in `r_parts[wi / rows_per]` at local row
/// `wi % rows_per`). Per-row sums, the word partial sort and the
/// per-word topic partial sorts all see the identical values in the
/// identical order as the dense path, so the selection is **bitwise
/// equal** to [`select_power`] on the concatenation — the schedule, and
/// with it the whole sharded training trajectory, cannot drift from the
/// replicated oracle's.
pub fn select_power_sharded(
    r_parts: &[&[f32]],
    rows_per: usize,
    w: usize,
    k: usize,
    params: &PowerParams,
) -> PowerSet {
    debug_assert_eq!(r_parts.iter().map(|p| p.len()).sum::<usize>(), w * k);
    // Step 1: word marginals, rows read in place from the owner slices
    let r_w: Vec<f32> = (0..w)
        .map(|wi| {
            let lo = (wi % rows_per) * k;
            r_parts[wi / rows_per][lo..lo + k].iter().sum()
        })
        .collect();
    let words = top_k_desc(&r_w, params.words_of(w));
    // Step 2: per selected word, top topics within its slice-local row
    // (window-relative indices = topic ids, same as the dense stride)
    let kk = params.topics_of(k);
    let topics = words
        .iter()
        .map(|&wi| {
            let wi = wi as usize;
            top_k_desc_strided(
                r_parts[wi / rows_per],
                (wi % rows_per) * k,
                1,
                k,
                kk,
            )
        })
        .collect();
    PowerSet { words, topics }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// The paper's Fig. 2 worked example: K = 4, W = 6, λ_K = λ_W = 0.5.
    #[test]
    fn fig2_worked_example_shape() {
        let (w, k) = (6, 4);
        let mut rng = Rng::new(0);
        let r: Vec<f32> = (0..w * k).map(|_| rng.f32()).collect();
        let params = PowerParams { lambda_w: 0.5, lambda_k_times_k: 2 };
        let ps = select_power(&r, w, k, &params);
        assert_eq!(ps.words.len(), 3); // 0.5 * 6
        assert!(ps.topics.iter().all(|t| t.len() == 2)); // 0.5 * 4
        assert_eq!(ps.pairs(), 6);
        assert_eq!(ps.payload_bytes(), 24);
    }

    #[test]
    fn selects_highest_residual_words_and_topics() {
        let (w, k) = (4, 3);
        let mut r = vec![0f32; w * k];
        // word 2 is hot, topics 2 > 0 > 1 within it; word 0 mildly warm
        r[2 * k + 2] = 10.0;
        r[2 * k] = 5.0;
        r[1] = 1.0;
        let ps = select_power(&r, w, k, &PowerParams { lambda_w: 0.5, lambda_k_times_k: 2 });
        assert_eq!(ps.words, vec![2, 0]);
        assert_eq!(ps.topics[0], vec![2, 0]);
        assert_eq!(ps.topics[1], vec![1, 0]);
    }

    #[test]
    fn pairs_and_payload_follow_selection() {
        let ps = PowerSet { words: vec![2, 0], topics: vec![vec![1, 3], vec![0]] };
        assert_eq!(ps.pairs(), 3);
        assert_eq!(ps.payload_bytes(), 12);
    }

    #[test]
    fn flat_indices_row_major() {
        let ps = PowerSet { words: vec![3, 1], topics: vec![vec![0, 2], vec![1]] };
        assert_eq!(ps.flat_indices(4), vec![12, 14, 5]);
        // the reusing variant clears stale contents
        let mut buf = vec![99u32; 7];
        ps.flat_indices_into(4, &mut buf);
        assert_eq!(buf, vec![12, 14, 5]);
    }

    #[test]
    fn sharded_selection_bitwise_equals_dense() {
        // ties included: coarse quantization forces equal residuals, so
        // this also pins the tie-breaking (lower index wins) across the
        // two layouts
        let mut rng = Rng::new(9);
        for &(w, k, owners) in &[(6usize, 4usize, 2usize), (50, 6, 4), (37, 5, 8)] {
            let r: Vec<f32> =
                (0..w * k).map(|_| (rng.f32() * 4.0).floor() / 4.0).collect();
            let os = crate::comm::OwnerSlices::row_aligned(w * k, k, owners);
            let parts: Vec<&[f32]> =
                (0..os.owners()).map(|n| &r[os.range(n)]).collect();
            let rows_per = os.per() / k;
            for params in [
                PowerParams { lambda_w: 0.5, lambda_k_times_k: 2 },
                PowerParams::paper_default(),
            ] {
                let dense = select_power(&r, w, k, &params);
                let sharded = select_power_sharded(&parts, rows_per, w, k, &params);
                assert_eq!(dense, sharded, "w={w} k={k} owners={owners}");
            }
        }
    }

    #[test]
    fn paper_default_params() {
        let p = PowerParams::paper_default();
        assert_eq!(p.words_of(7000), 700);
        assert_eq!(p.topics_of(2000), 50);
        assert_eq!(p.topics_of(30), 30); // clamped to K
        assert_eq!(PowerParams::full().words_of(7000), 7000);
    }

    #[test]
    fn dynamic_scheduling_eventually_selects_everything() {
        // Fig. 3 invariant: as residuals of selected elements decay, every
        // element is eventually selected ("no information gets lost").
        check("power selection coverage", 20, |rng| {
            let (w, k) = (12, 6);
            let mut r: Vec<f32> = (0..w * k).map(|_| rng.f32() + 0.01).collect();
            let params = PowerParams { lambda_w: 0.25, lambda_k_times_k: 2 };
            let mut seen = vec![false; w * k];
            for _ in 0..200 {
                let ps = select_power(&r, w, k, &params);
                for &ix in &ps.flat_indices(k) {
                    seen[ix as usize] = true;
                    r[ix as usize] *= 0.2; // message passing shrinks residual
                }
                if seen.iter().all(|&s| s) {
                    return;
                }
            }
            panic!("some elements never selected");
        });
    }
}
