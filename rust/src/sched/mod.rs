//! Dynamic scheduling: residual-driven power word/topic selection — the
//! communication-efficient heart of the paper (§3.1) — plus the
//! document-schedule permutation that makes ABP's residual-ordered doc
//! sweeps block-parallel ([`DocSchedule`]).

pub mod doc_schedule;
pub mod power;

pub use doc_schedule::DocSchedule;
pub use power::{select_power, select_power_sharded, PowerParams, PowerSet};
