//! Dynamic scheduling: residual-driven power word/topic selection — the
//! communication-efficient heart of the paper (§3.1).

pub mod power;

pub use power::{select_power, PowerParams, PowerSet};
