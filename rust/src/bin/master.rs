//! `pobp-master` — the distributed training leader (Contract 8).
//!
//! ```text
//! pobp-master --dataset enron --scale 40 --k 8 --workers 2 --spawn
//! pobp-master --dataset enron --scale 40 --k 8 --workers 2 \
//!             --listen 0.0.0.0:7070   # then start pobp-worker processes
//! ```
//!
//! Runs [`pobp::coordinator::fit_dist`] over a TCP
//! [`TcpTransport`]: `--spawn` launches loopback `pobp-worker`
//! processes next to this executable; `--listen` waits for externally
//! started workers to join. `--assert-oracle` re-runs the same
//! configuration in-process afterwards and exits non-zero unless the
//! distributed result is bitwise identical — the CI smoke leg.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use pobp::cli::Args;
use pobp::comm::transport::{TcpSpawnSpec, TcpTransport, Transport};
use pobp::coordinator::{fit_checked, fit_dist, PobpConfig};
use pobp::engine::traits::LdaParams;
use pobp::fault::ChaosPlan;
use pobp::repro::dataset;
use pobp::sched::PowerParams;
use pobp::storage::PhiStorageMode;
use pobp::util::timer::fmt_secs;

const USAGE: &str = "\
pobp-master — POBP distributed training leader
  pobp-master --dataset D --scale S --k K --workers N (--spawn | --listen ADDR)
              [--storage replicated|sharded] [--iters T] [--nnz-budget B]
              [--lambda-w R] [--lambda-kk KK] [--seed S] [--threads T]
              [--timeout SECS] [--assert-oracle]
              [--chaos-permille P] [--chaos-seed S] [--frame-retries R]

  --spawn           launch N loopback pobp-worker processes (sibling binary)
  --listen ADDR     bind ADDR and wait for N externally started workers
  --storage         phi storage layout (default replicated)
  --threads         sweep threads per worker (default 1)
  --timeout         socket deadline in seconds (default 120)
  --assert-oracle   re-run in-process and demand bitwise equality
  --chaos-permille  per-frame wire-fault probability out of 1000
                    (default 0 = chaos off; Contract 9)
  --chaos-seed      seed of the chaos schedule (default 42)
  --frame-retries   supervised retry budget per frame exchange (default 5)
";

fn main() -> Result<()> {
    // Args::parse treats the first token as a subcommand; inject a
    // synthetic one ahead of the real flags (same trick as pobp-worker).
    let args = Args::parse(
        std::iter::once("master".to_string()).chain(std::env::args().skip(1)),
    )?;
    if args.switch("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let name = args.get_str("dataset", "enron");
    let scale = args.get::<usize>("scale", 40)?;
    let k = args.get::<usize>("k", 8)?;
    let workers = args.get::<usize>("workers", 2)?;
    let storage_s = args.get_str("storage", "replicated");
    let storage = match storage_s.as_str() {
        "replicated" => PhiStorageMode::Replicated,
        "sharded" => PhiStorageMode::Sharded,
        other => bail!("unknown --storage {other} (replicated|sharded)"),
    };
    let max_iters = args.get::<usize>("iters", 10)?;
    let nnz_budget = args.get::<usize>("nnz-budget", 2_000)?;
    let lambda_w = args.get::<f64>("lambda-w", 0.1)?;
    let lambda_kk = args.get::<usize>("lambda-kk", 50)?;
    let seed = args.get::<u64>("seed", 42)?;
    let threads = args.get::<usize>("threads", 1)?;
    let listen = args.get_str("listen", "");
    let spawn = args.switch("spawn");
    let timeout = args.get::<u64>("timeout", 120)?;
    let assert_oracle = args.switch("assert-oracle");
    let chaos_permille = args.get::<u32>("chaos-permille", 0)?;
    let chaos_seed = args.get::<u64>("chaos-seed", 42)?;
    let frame_retries = args.get::<usize>("frame-retries", 5)?;
    args.reject_unknown()?;
    if chaos_permille > 1000 {
        bail!("--chaos-permille {chaos_permille} out of range (0..=1000)");
    }

    let corpus = dataset(&name, scale, k, seed);
    let params = LdaParams::paper(k);
    let cfg = PobpConfig {
        n_workers: workers,
        max_threads: threads,
        nnz_budget,
        power: PowerParams { lambda_w, lambda_k_times_k: lambda_kk },
        max_iters,
        seed,
        storage,
        ..Default::default()
    };
    println!(
        "corpus: D={} W={} NNZ={} tokens={}",
        corpus.docs(),
        corpus.w,
        corpus.nnz(),
        corpus.tokens()
    );

    let mut tp = if spawn {
        let exe = std::env::current_exe().context("locating pobp-master")?;
        let worker = exe.with_file_name(if cfg!(windows) {
            "pobp-worker.exe"
        } else {
            "pobp-worker"
        });
        TcpTransport::spawn(workers, TcpSpawnSpec { exe: worker, threads })?
            .with_io_timeout(Duration::from_secs(timeout))
    } else if !listen.is_empty() {
        let mut t = TcpTransport::listen(listen.as_str(), workers)?
            .with_io_timeout(Duration::from_secs(timeout));
        println!(
            "listening on {}; waiting for {workers} workers to join",
            t.local_addr()?
        );
        t.accept_workers()?;
        t
    } else {
        bail!("pass --spawn (loopback workers) or --listen HOST:PORT (external workers)");
    };
    tp = tp.with_frame_retries(frame_retries);
    if chaos_permille > 0 {
        tp = tp.with_chaos(ChaosPlan::seeded(chaos_seed, chaos_permille));
        println!(
            "chaos on: permille {chaos_permille}, seed {chaos_seed}, \
             frame retry budget {frame_retries}"
        );
    }
    println!("cluster up: {workers} tcp workers, {threads} sweep threads each");

    let result = fit_dist(&corpus, &params, &cfg, &mut tp)?;
    let l = &result.ledger;
    println!(
        "pobp-dist [tcp/{storage_s}]: wall {}, simulated {} (compute {} + comm {}), \
         syncs {}, wire {} MB",
        fmt_secs(result.wall_secs),
        fmt_secs(result.sim_secs()),
        fmt_secs(l.compute_secs),
        fmt_secs(l.comm_secs),
        l.sync_count(),
        l.wire_bytes / 1_000_000,
    );
    // measured wire seconds beside the α–β estimate (Contract 8: the
    // model is calibrated against the real interconnect, not trusted)
    println!(
        "measured wire: reduce {} + gather {} over {} segments (modeled comm {})",
        fmt_secs(l.measured_reduce_secs),
        fmt_secs(l.measured_gather_secs),
        l.measured.len(),
        fmt_secs(l.comm_secs),
    );
    // Contract 9 side accumulators: recovery effort, never in total_secs
    println!(
        "wire supervision: {} faults injected, {} frames retransmitted \
         ({} bytes), {} reconnects, backoff wait {}",
        l.chaos_faults,
        l.retrans_frames,
        l.retrans_bytes,
        l.reconnects,
        fmt_secs(l.backoff_wait_secs),
    );

    if assert_oracle {
        let oracle = fit_checked(&corpus, &params, &cfg)?;
        let history_ok = result.history.len() == oracle.history.len()
            && result.history.iter().zip(&oracle.history).all(|(a, b)| {
                a.batch == b.batch
                    && a.iter == b.iter
                    && a.residual_per_token.to_bits() == b.residual_per_token.to_bits()
                    && a.synced_pairs == b.synced_pairs
            });
        let ok = result.model.phi_wk == oracle.model.phi_wk
            && history_ok
            && l.sync_count() == oracle.ledger.sync_count()
            && l.payload_bytes_total() == oracle.ledger.payload_bytes_total()
            && l.wire_bytes == oracle.ledger.wire_bytes;
        if !ok {
            let _ = tp.shutdown();
            bail!("distributed run diverged from the in-process oracle");
        }
        println!("oracle check: distributed run bitwise-equal to in-process fit");
    }
    tp.shutdown()?;
    Ok(())
}
