//! `pobp-worker` — one distributed worker process (Contract 8).
//!
//! ```text
//! pobp-worker --connect HOST:PORT --slot N [--threads T] [--timeout SECS]
//!             [--connect-retries R] [--connect-backoff-ms MS]
//! ```
//!
//! Connects back to a `pobp-master` listener, handshakes its slot, and
//! serves Batch/Sweep/Fold frames until the master sends Shutdown (or
//! the socket deadline expires — `--timeout 0` waits forever). All
//! training state arrives over the wire; the worker needs no corpus,
//! config file, or checkpoint directory of its own. Startup races the
//! master's listener safely: the initial connect retries with capped
//! exponential backoff (Contract 9), so spawn order does not matter.

use std::time::Duration;

use anyhow::{Context, Result};

use pobp::cli::Args;
use pobp::comm::transport::{serve_worker, ConnectCfg};

const USAGE: &str = "\
pobp-worker — POBP distributed worker process
  pobp-worker --connect HOST:PORT --slot N [--threads T] [--timeout SECS]
              [--connect-retries R] [--connect-backoff-ms MS]

  --connect             the pobp-master listen address to join
  --slot                this worker's slot index (0-based, < n_workers)
  --threads             OS threads for the shard sweep (default 1)
  --timeout             socket deadline in seconds, 0 = wait forever (default 600)
  --connect-retries     extra connect attempts after the first (default 10)
  --connect-backoff-ms  initial retry backoff, doubling per attempt,
                        capped at 2 s (default 50)
";

fn main() -> Result<()> {
    // Args::parse treats the first token as a subcommand; this binary
    // has none, so inject a synthetic one ahead of the real flags.
    let args = Args::parse(
        std::iter::once("worker".to_string()).chain(std::env::args().skip(1)),
    )?;
    if args.switch("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let connect: String = args.require("connect")?;
    let slot = args.require::<usize>("slot")?;
    let threads = args.get::<usize>("threads", 1)?;
    let timeout = args.get::<u64>("timeout", 600)?;
    let retries = args.get::<usize>("connect-retries", 10)?;
    let backoff_ms = args.get::<u64>("connect-backoff-ms", 50)?;
    args.reject_unknown()?;

    let deadline =
        if timeout == 0 { None } else { Some(Duration::from_secs(timeout)) };
    let connect_cfg = ConnectCfg { retries, backoff_ms };
    serve_worker(connect.as_str(), slot, threads, deadline, connect_cfg)
        .with_context(|| format!("worker slot {slot} serving {connect}"))?;
    Ok(())
}
