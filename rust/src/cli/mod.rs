//! Minimal argument parser (offline substitute for `clap`).
//!
//! Grammar: `pobp <subcommand> [positional...] [--flag value | --switch]`.
//! Flags may appear in any order; unknown flags are collected so the
//! subcommands can reject them with a helpful message. A token following
//! `--name` that does not start with `--` is taken as that flag's value,
//! so positionals must precede switches (or use `--flag=value`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `std::env::args()`-style input (without the binary name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut args = Args { subcommand, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.consumed.borrow_mut().push(name.to_string());
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {v}: {e}")),
        }
    }

    /// Required flag.
    pub fn require<T: std::str::FromStr>(&self, name: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.consumed.borrow_mut().push(name.to_string());
        let v = self
            .flags
            .get(name)
            .with_context(|| format!("missing required --{name}"))?;
        v.parse().map_err(|e| anyhow::anyhow!("--{name} {v}: {e}"))
    }

    /// String flag with default.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Boolean switch (`--verbose`).
    pub fn switch(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.switches.iter().any(|s| s == name)
    }

    /// Error on any flag that no `get`/`require`/`switch` call touched.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        for k in self.flags.keys() {
            if !seen.contains(k) {
                bail!("unknown flag --{k}");
            }
        }
        for s in &self.switches {
            if !seen.contains(s) {
                bail!("unknown switch --{s}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_flags_switches_positionals() {
        let a = parse("train file.txt --k 50 --dataset=enron --verbose");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get::<usize>("k", 0).unwrap(), 50);
        assert_eq!(a.get_str("dataset", "x"), "enron");
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["file.txt"]);
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("train --k 10");
        assert_eq!(a.get::<usize>("workers", 4).unwrap(), 4);
        assert!(a.require::<usize>("missing").is_err());
        assert!(a.get::<usize>("k", 0).is_ok());
    }

    #[test]
    fn bad_values_error() {
        let a = parse("train --k notanumber");
        assert!(a.get::<usize>("k", 0).is_err());
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = parse("train --k 10 --bogus 3");
        let _ = a.get::<usize>("k", 0);
        assert!(a.reject_unknown().is_err());
        let b = parse("train --k 10");
        let _ = b.get::<usize>("k", 0);
        assert!(b.reject_unknown().is_ok());
    }

    #[test]
    fn switch_followed_by_flag() {
        let a = parse("x --fast --k 3");
        assert!(a.switch("fast"));
        assert_eq!(a.get::<usize>("k", 0).unwrap(), 3);
    }
}
