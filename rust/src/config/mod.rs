//! Experiment configuration files: a small INI-style format
//! (`key = value`, `#` comments, one `[section]` per concern) so runs are
//! reproducible from checked-in files rather than long command lines.
//!
//! ```text
//! [corpus]
//! dataset = pubmed        # Table-3 preset or "tiny"
//! scale   = 20000
//! seed    = 42
//!
//! [model]
//! k = 100
//!
//! [run]
//! algo      = pobp
//! workers   = 256
//! iters     = 60
//! lambda_w  = 0.1
//! lambda_kk = 12
//! ```
//!
//! Every key has the `RunOpts`/`LdaParams` default, so configs only state
//! what they change.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::comm::{NetModel, TransportKind};
use crate::engine::traits::LdaParams;
use crate::repro::{Algo, RunOpts};
use crate::sched::PowerParams;
use crate::storage::PhiStorageMode;

/// Parsed `[section] key = value` file.
#[derive(Clone, Debug, Default)]
pub struct ConfigFile {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl ConfigFile {
    pub fn parse(text: &str) -> Result<ConfigFile> {
        let mut cf = ConfigFile::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", ln + 1))?;
                section = name.trim().to_string();
                cf.sections.entry(section.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                if section.is_empty() {
                    bail!("line {}: key before any [section]", ln + 1);
                }
                cf.sections
                    .get_mut(&section)
                    .unwrap()
                    .insert(k.trim().to_string(), v.trim().to_string());
            } else {
                bail!("line {}: expected 'key = value', got '{line}'", ln + 1);
            }
        }
        Ok(cf)
    }

    pub fn load(path: &Path) -> Result<ConfigFile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text).with_context(|| path.display().to_string())
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    fn typed<T: std::str::FromStr>(&self, section: &str, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("[{section}] {key} = {v}: {e}")),
        }
    }
}

/// Everything an experiment run needs, resolved from a config file.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub dataset: String,
    pub scale: usize,
    pub seed: u64,
    pub params: LdaParams,
    pub algo: Algo,
    pub opts: RunOpts,
}

impl Experiment {
    /// Resolve a config file against the library defaults.
    pub fn from_config(cf: &ConfigFile) -> Result<Experiment> {
        let dataset = cf.get("corpus", "dataset").unwrap_or("enron").to_string();
        let scale = cf.typed("corpus", "scale", 400usize)?;
        let seed = cf.typed("corpus", "seed", 42u64)?;
        let k = cf.typed("model", "k", 50usize)?;
        let mut params = LdaParams::paper(k);
        params.alpha = cf.typed("model", "alpha", params.alpha)?;
        params.beta = cf.typed("model", "beta", params.beta)?;

        let algo_name = cf.get("run", "algo").unwrap_or("pobp");
        let algo = Algo::parse(algo_name)
            .with_context(|| format!("[run] algo = {algo_name}: unknown algorithm"))?;
        let defaults = RunOpts::default();
        let opts = RunOpts {
            n_workers: cf.typed("run", "workers", defaults.n_workers)?,
            max_threads: cf.typed("run", "threads", defaults.max_threads)?,
            // `pin_cores = true` pins pool threads to cores — a pure
            // performance hint; where the OS refuses affinity the run
            // logs once and continues with floating threads
            pin_cores: cf.typed("run", "pin_cores", defaults.pin_cores)?,
            iters: cf.typed("run", "iters", defaults.iters)?,
            max_batch_iters: cf.typed("run", "batch_iters", defaults.max_batch_iters)?,
            nnz_budget: cf.typed("run", "nnz_budget", defaults.nnz_budget)?,
            power: PowerParams {
                lambda_w: cf.typed("run", "lambda_w", 0.1)?,
                lambda_k_times_k: cf.typed("run", "lambda_kk", 50usize)?,
            },
            net: match cf.get("run", "network").unwrap_or("infiniband") {
                "infiniband" => NetModel::infiniband_20gbps(),
                "gige" => NetModel::gige(),
                "scaled" => NetModel::infiniband_for_scale(k, 2000),
                other => bail!("[run] network = {other}: infiniband|gige|scaled"),
            },
            seed,
            snapshot_every: cf.typed("run", "snapshot_every", 0usize)?,
            // `overlap = true` runs POBP through the pipelined
            // synchronization stack (bitwise-identical results,
            // max(compute, comm) time accounting)
            overlap: cf.typed("run", "overlap", defaults.overlap)?,
            // `storage = sharded` trains the POBP family with φ̂ held as
            // row-aligned owner slices (O(W·K/N) per-worker model
            // memory, bitwise-identical results)
            storage: match cf.get("run", "storage").unwrap_or("replicated") {
                "replicated" => PhiStorageMode::Replicated,
                "sharded" => PhiStorageMode::Sharded,
                other => bail!("[run] storage = {other}: replicated|sharded"),
            },
            // fault tolerance (Contract 6): `checkpoint_every > 0` or
            // `resume = true` routes the POBP family through
            // `coordinator::fit_resilient`
            checkpoint_every: cf.typed("run", "checkpoint_every", defaults.checkpoint_every)?,
            checkpoint_dir: cf
                .get("run", "checkpoint_dir")
                .unwrap_or(&defaults.checkpoint_dir)
                .to_string(),
            max_retries: cf.typed("run", "max_retries", defaults.max_retries)?,
            straggler_timeout_factor: cf.typed(
                "run",
                "straggler_timeout",
                defaults.straggler_timeout_factor,
            )?,
            resume: cf.typed("run", "resume", defaults.resume)?,
            // `transport = tcp` marks the config for the real
            // master/worker cluster (Contract 8); `pobp run` itself only
            // drives the in-process carrier, so the CLI rejects the tcp
            // value with a pointer at pobp-master / pobp-worker
            transport: {
                let s = cf.get("run", "transport").unwrap_or("inprocess");
                TransportKind::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("[run] transport = {s}: inprocess|tcp"))?
            },
            // wire supervision / chaos (Contract 9): worker startup
            // connect retries with capped exponential backoff, and the
            // deterministic seeded wire-fault schedule
            connect_retries: cf.typed("run", "connect_retries", defaults.connect_retries)?,
            connect_backoff_ms: cf.typed(
                "run",
                "connect_backoff_ms",
                defaults.connect_backoff_ms,
            )?,
            chaos_seed: cf.typed("run", "chaos_seed", defaults.chaos_seed)?,
            chaos_permille: cf.typed("run", "chaos_permille", defaults.chaos_permille)?,
        };
        if opts.chaos_permille > 1000 {
            bail!(
                "[run] chaos_permille = {}: at most 1000 (a probability out of 1000)",
                opts.chaos_permille
            );
        }
        // invalid [run] combinations fail here with the typed message,
        // not as a panic mid-run (e.g. overlap + sharded storage)
        if matches!(algo, Algo::Pobp | Algo::PobpFull | Algo::Obp | Algo::BatchBp) {
            crate::repro::pobp_config(algo, &params, &opts)
                .validate()
                .map_err(|e| anyhow::anyhow!("[run] {e}"))?;
            if opts.wants_resilience() {
                opts.resilience()
                    .validate()
                    .map_err(|e| anyhow::anyhow!("[run] {e}"))?;
            }
        }
        Ok(Experiment { dataset, scale, seed, params, algo, opts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# demo config
[corpus]
dataset = pubmed
scale = 20000        # divisor of Table-3 D

[model]
k = 100

[run]
algo = psgs
workers = 32
network = gige
";

    #[test]
    fn parses_and_resolves() {
        let cf = ConfigFile::parse(SAMPLE).unwrap();
        let e = Experiment::from_config(&cf).unwrap();
        assert_eq!(e.dataset, "pubmed");
        assert_eq!(e.scale, 20000);
        assert_eq!(e.params.k, 100);
        assert!((e.params.alpha - 0.02).abs() < 1e-6); // 2/K default
        assert_eq!(e.algo, Algo::Psgs);
        assert_eq!(e.opts.n_workers, 32);
        assert!(e.opts.net.bandwidth_bps < 1e9); // gige
    }

    #[test]
    fn defaults_fill_missing_sections() {
        let cf = ConfigFile::parse("[corpus]\ndataset = tiny\n").unwrap();
        let e = Experiment::from_config(&cf).unwrap();
        assert_eq!(e.algo, Algo::Pobp);
        assert_eq!(e.opts.n_workers, RunOpts::default().n_workers);
    }

    #[test]
    fn rejects_malformed() {
        assert!(ConfigFile::parse("key = 1\n").is_err()); // before section
        assert!(ConfigFile::parse("[run\nalgo = pobp\n").is_err());
        assert!(ConfigFile::parse("[run]\njust a line\n").is_err());
        let cf = ConfigFile::parse("[run]\nalgo = nope\n").unwrap();
        assert!(Experiment::from_config(&cf).is_err());
        let cf = ConfigFile::parse("[run]\nworkers = many\n").unwrap();
        assert!(Experiment::from_config(&cf).is_err());
    }

    #[test]
    fn rejects_overlap_with_sharded_storage() {
        // the invalid combination fails at config-resolution time with
        // the typed coordinator message, not as a panic mid-run
        let cf =
            ConfigFile::parse("[run]\noverlap = true\nstorage = sharded\n").unwrap();
        let err = Experiment::from_config(&cf).unwrap_err();
        assert!(err.to_string().contains("overlap"), "{err}");
    }

    #[test]
    fn resilience_keys_resolve() {
        let cf = ConfigFile::parse(
            "[run]\ncheckpoint_every = 2\ncheckpoint_dir = ckpts\n\
             max_retries = 5\nstraggler_timeout = 6.5\nresume = true\n",
        )
        .unwrap();
        let e = Experiment::from_config(&cf).unwrap();
        assert_eq!(e.opts.checkpoint_every, 2);
        assert_eq!(e.opts.checkpoint_dir, "ckpts");
        assert_eq!(e.opts.max_retries, 5);
        assert!((e.opts.straggler_timeout_factor - 6.5).abs() < 1e-12);
        assert!(e.opts.resume);
        assert!(e.opts.wants_resilience());
        // degenerate resilience knobs are rejected the same way
        let cf = ConfigFile::parse("[run]\ncheckpoint_every = 1\nstraggler_timeout = 0\n")
            .unwrap();
        assert!(Experiment::from_config(&cf).is_err());
    }

    #[test]
    fn transport_key_resolves() {
        let e = Experiment::from_config(&ConfigFile::parse("[run]\n").unwrap()).unwrap();
        assert_eq!(e.opts.transport, TransportKind::InProcess);
        let cf = ConfigFile::parse("[run]\ntransport = tcp\n").unwrap();
        let e = Experiment::from_config(&cf).unwrap();
        assert_eq!(e.opts.transport, TransportKind::Tcp);
        let cf = ConfigFile::parse("[run]\ntransport = rdma\n").unwrap();
        let err = Experiment::from_config(&cf).unwrap_err();
        assert!(err.to_string().contains("transport"), "{err}");
    }

    #[test]
    fn chaos_and_connect_keys_resolve() {
        let e = Experiment::from_config(&ConfigFile::parse("[run]\n").unwrap()).unwrap();
        assert_eq!(e.opts.connect_retries, 10);
        assert_eq!(e.opts.connect_backoff_ms, 50);
        assert_eq!(e.opts.chaos_permille, 0);
        let cf = ConfigFile::parse(
            "[run]\nconnect_retries = 4\nconnect_backoff_ms = 25\n\
             chaos_seed = 7\nchaos_permille = 300\n",
        )
        .unwrap();
        let e = Experiment::from_config(&cf).unwrap();
        assert_eq!(e.opts.connect_retries, 4);
        assert_eq!(e.opts.connect_backoff_ms, 25);
        assert_eq!(e.opts.chaos_seed, 7);
        assert_eq!(e.opts.chaos_permille, 300);
        // permille is a probability out of 1000
        let cf = ConfigFile::parse("[run]\nchaos_permille = 1001\n").unwrap();
        let err = Experiment::from_config(&cf).unwrap_err();
        assert!(err.to_string().contains("chaos_permille"), "{err}");
    }

    #[test]
    fn comments_and_whitespace_tolerated() {
        let cf = ConfigFile::parse("  [model]  \n  k = 25  # topics\n\n").unwrap();
        assert_eq!(cf.get("model", "k"), Some("25"));
    }
}
