//! `pobp` — the leader binary.
//!
//! ```text
//! pobp train      --dataset enron --scale 400 --algo pobp --k 50
//!                 [--workers N] [--iters T] [--lambda-w 0.1]
//!                 [--lambda-kk 50] [--nnz-budget 45000] [--seed S]
//!                 [--engine native|xla] [--save model.bin] [--topics 5]
//!                 [--checkpoint-every M] [--checkpoint-dir DIR]
//!                 [--retries R] [--resume] [--pin-cores]
//! pobp gen-data   --dataset pubmed --scale 2000 --out data/
//! pobp topics     --model model.bin [--top 10]
//! pobp perplexity --model model.bin --dataset enron --scale 400 --k 50
//! pobp info       # artifact + environment report
//! ```
//!
//! The `repro` bench harness lives under `benches/` (one target per paper
//! table/figure; run `cargo bench`).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use pobp::cli::Args;
use pobp::corpus::{bow, Vocab};
use pobp::engine::traits::{LdaParams, Model};
use pobp::metrics::sig;
use pobp::repro::{dataset, eval_model, run_algo, Algo, RunOpts};
use pobp::sched::PowerParams;
use pobp::util::timer::fmt_secs;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "run" => cmd_run(&args),
        "gen-data" => cmd_gen_data(&args),
        "topics" => cmd_topics(&args),
        "perplexity" => cmd_perplexity(&args),
        "info" => cmd_info(),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try `pobp help`)"),
    }
}

const HELP: &str = "\
pobp — communication-efficient parallel online belief propagation for LDA
  (reproduction of Yan, Zeng, Liu & Gao, 'Towards Big Topic Modeling', 2013)

subcommands:
  train       train a model on a (synthetic Table-3) dataset
              (--checkpoint-every M --checkpoint-dir DIR for fault-tolerant
               runs; --resume continues from the newest good checkpoint;
               --pin-cores pins pool threads to cores, best-effort)
  run         train from a config file (see configs/*.conf); configs with
              [run] transport = tcp belong to the pobp-master/pobp-worker
              cluster binaries instead
  gen-data    write a synthetic corpus in UCI bag-of-words format
  topics      print top words per topic of a saved model
  perplexity  evaluate a saved model (Eq. 20 protocol)
  info        artifact + environment report
run `cargo bench` for the per-figure/table reproduction harness.
";

fn corpus_args(args: &Args) -> Result<(pobp::corpus::Csr, usize)> {
    let name = args.get_str("dataset", "enron");
    let scale = args.get::<usize>("scale", 400)?;
    let k = args.get::<usize>("k", 50)?;
    let seed = args.get::<u64>("seed", 42)?;
    Ok((dataset(&name, scale, k, seed), k))
}

fn cmd_train(args: &Args) -> Result<()> {
    let (corpus, k) = corpus_args(args)?;
    let algo = Algo::parse(&args.get_str("algo", "pobp"))
        .context("unknown --algo (pobp|pobp-full|obp|bp|pgs|pfgs|psgs|ylda|pvb)")?;
    let params = LdaParams::paper(k);
    let opts = RunOpts {
        n_workers: args.get("workers", 4)?,
        iters: args.get("iters", 100)?,
        max_batch_iters: args.get("batch-iters", 50)?,
        nnz_budget: args.get("nnz-budget", 45_000)?,
        power: PowerParams {
            lambda_w: args.get("lambda-w", 0.1)?,
            lambda_k_times_k: args.get("lambda-kk", 50)?,
        },
        seed: args.get("seed", 42)?,
        // fault tolerance (Contract 6): checkpoint cadence + resume
        checkpoint_every: args.get("checkpoint-every", 0)?,
        checkpoint_dir: args.get_str("checkpoint-dir", ""),
        max_retries: args.get("retries", 3)?,
        resume: args.switch("resume"),
        // best-effort core pinning of pool threads; where the OS refuses
        // affinity the run logs once and continues floating
        pin_cores: args.switch("pin-cores"),
        ..Default::default()
    };
    let engine = args.get_str("engine", "native");
    let save: String = args.get_str("save", "");
    let show_topics = args.get::<usize>("topics", 0)?;
    args.reject_unknown()?;

    println!(
        "corpus: D={} W={} NNZ={} tokens={}",
        corpus.docs(),
        corpus.w,
        corpus.nnz(),
        corpus.tokens()
    );
    let result = match engine.as_str() {
        "native" => run_algo(algo, &corpus, &params, &opts),
        "xla" => {
            if algo != Algo::Obp && algo != Algo::Pobp {
                bail!("--engine xla supports the BP-family algorithms only");
            }
            run_xla(&corpus, &params, &opts)?
        }
        other => bail!("unknown --engine {other} (native|xla)"),
    };

    println!(
        "{} [{}]: wall {}, simulated {} (compute {} + comm {}), syncs {}, wire {} MB",
        algo.name(),
        engine,
        fmt_secs(result.wall_secs),
        fmt_secs(result.sim_secs()),
        fmt_secs(result.ledger.compute_secs),
        fmt_secs(result.ledger.comm_secs),
        result.ledger.sync_count(),
        result.ledger.wire_bytes / 1_000_000,
    );
    if opts.checkpoint_every > 0 || opts.resume {
        println!(
            "resilience: checkpoints {} ({} MB, {}), recoveries {} (replay {})",
            result.ledger.checkpoint_count,
            result.ledger.checkpoint_bytes / 1_000_000,
            fmt_secs(result.ledger.checkpoint_secs),
            result.ledger.recovery_count,
            fmt_secs(result.ledger.recovery_replay_secs),
        );
    }
    let perp = eval_model(&result.model, &corpus, &params, opts.seed);
    println!("predictive perplexity (Eq. 20): {}", sig(perp));

    if show_topics > 0 {
        print_topics(&result.model, show_topics, 8);
    }
    if !save.is_empty() {
        result.model.save(&PathBuf::from(&save))?;
        println!("model saved to {save}");
    }
    Ok(())
}

/// PJRT-backed training, available only in `--features xla` builds (the
/// xla crate needs the XLA C++ runtime; see Cargo.toml).
#[cfg(feature = "xla")]
fn run_xla(
    corpus: &pobp::corpus::Csr,
    params: &LdaParams,
    opts: &RunOpts,
) -> Result<pobp::engine::traits::TrainResult> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    pobp::runtime::xla_engine::fit_obp_xla(
        corpus,
        params,
        &dir,
        &pobp::runtime::xla_engine::XlaObpConfig {
            max_iters: opts.max_batch_iters,
            power: opts.power,
            seed: opts.seed,
            ..Default::default()
        },
    )
}

#[cfg(not(feature = "xla"))]
fn run_xla(
    _corpus: &pobp::corpus::Csr,
    _params: &LdaParams,
    _opts: &RunOpts,
) -> Result<pobp::engine::traits::TrainResult> {
    bail!("--engine xla requires a build with `--features xla` (see Cargo.toml)")
}

fn cmd_run(args: &Args) -> Result<()> {
    let path: String = if args.positional.is_empty() {
        args.require("config")?
    } else {
        args.positional[0].clone()
    };
    let save: String = args.get_str("save", "");
    args.reject_unknown()?;
    let cf = pobp::config::ConfigFile::load(&PathBuf::from(&path))?;
    let exp = pobp::config::Experiment::from_config(&cf)?;
    if exp.opts.transport == pobp::comm::TransportKind::Tcp {
        // `pobp run` is single-process by design; the real cluster has
        // its own leader binary so worker lifecycle stays out of here
        bail!(
            "[run] transport = tcp runs under the cluster binaries: start \
             `pobp-master --spawn` (loopback) or `pobp-master --listen HOST:PORT` \
             plus `pobp-worker --connect HOST:PORT --slot I` processes \
             (`pobp run` drives the in-process transport only)"
        );
    }
    println!(
        "experiment: dataset={} scale={} K={} algo={} N={}",
        exp.dataset, exp.scale, exp.params.k, exp.algo.name(), exp.opts.n_workers
    );
    let corpus = dataset(&exp.dataset, exp.scale, exp.params.k, exp.seed);
    println!(
        "corpus: D={} W={} NNZ={} tokens={}",
        corpus.docs(), corpus.w, corpus.nnz(), corpus.tokens()
    );
    let result = run_algo(exp.algo, &corpus, &exp.params, &exp.opts);
    println!(
        "{}: wall {}, simulated {} (comm {}), syncs {}",
        exp.algo.name(),
        fmt_secs(result.wall_secs),
        fmt_secs(result.sim_secs()),
        fmt_secs(result.ledger.comm_secs),
        result.ledger.sync_count(),
    );
    println!(
        "predictive perplexity (Eq. 20): {}",
        sig(eval_model(&result.model, &corpus, &exp.params, exp.seed))
    );
    if !save.is_empty() {
        result.model.save(&PathBuf::from(&save))?;
        println!("model saved to {save}");
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let name = args.get_str("dataset", "enron");
    let scale = args.get::<usize>("scale", 400)?;
    let seed = args.get::<u64>("seed", 42)?;
    let out = PathBuf::from(args.get_str("out", "data"));
    args.reject_unknown()?;
    let corpus = dataset(&name, scale, 50, seed);
    let vocab = Vocab::synthetic(corpus.w);
    bow::write_uci_pair(&out, &format!("{name}-sim"), &corpus, &vocab)?;
    println!(
        "wrote {}/docword.{name}-sim.txt (D={} W={} NNZ={})",
        out.display(),
        corpus.docs(),
        corpus.w,
        corpus.nnz()
    );
    Ok(())
}

fn cmd_topics(args: &Args) -> Result<()> {
    let model_path: String = args.require("model")?;
    let top = args.get::<usize>("top", 10)?;
    args.reject_unknown()?;
    let model = Model::load(&PathBuf::from(&model_path))?;
    print_topics(&model, model.k, top);
    Ok(())
}

fn print_topics(model: &Model, n_topics: usize, top: usize) {
    for t in 0..n_topics.min(model.k) {
        let words: Vec<String> = model
            .top_words(t, top)
            .into_iter()
            .map(|(w, v)| format!("w{w:04}({v:.0})"))
            .collect();
        println!("topic {t:>3}: {}", words.join(" "));
    }
}

fn cmd_perplexity(args: &Args) -> Result<()> {
    let model_path: String = args.require("model")?;
    let (corpus, k) = corpus_args(args)?;
    args.reject_unknown()?;
    let model = Model::load(&PathBuf::from(&model_path))?;
    anyhow::ensure!(model.k == k && model.w == corpus.w, "model/corpus shape mismatch");
    let params = LdaParams::paper(k);
    println!("perplexity: {}", sig(eval_model(&model, &corpus, &params, 42)));
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("pobp {} — three-layer rust+jax+pallas build", env!("CARGO_PKG_VERSION"));
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match pobp::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({}):", dir.display());
            for e in &m.entries {
                println!(
                    "  d={} w={} k={} blocks=({}, {})  {}",
                    e.d, e.w, e.k, e.block_d, e.block_w,
                    e.file.file_name().unwrap().to_string_lossy()
                );
            }
            #[cfg(feature = "xla")]
            {
                let client = xla::PjRtClient::cpu()?;
                println!(
                    "pjrt: platform={} devices={}",
                    client.platform_name(),
                    client.device_count()
                );
            }
            #[cfg(not(feature = "xla"))]
            println!("pjrt: disabled (build with --features xla)");
        }
        Err(e) => println!("artifacts not built ({e}); run `make artifacts`"),
    }
    println!(
        "cores: {}",
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
    );
    Ok(())
}
