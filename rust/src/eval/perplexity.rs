//! Predictive perplexity (Eq. 20) — the paper's accuracy metric.
//!
//! Protocol (§4): each document is split 80/20 at token level. With the
//! trained φ̂ *fixed*, θ is estimated on the 80% side by iterating the BP
//! fold-in update from the same random initialization; perplexity is then
//! computed on the 20% side:
//!
//! ```text
//! P = exp( − Σ_{w,d} x20 · log Σ_k θ_d(k) φ_w(k)  /  Σ_{w,d} x20 )
//! ```
//!
//! Lower is better.

use crate::corpus::{Csr, Split};
use crate::engine::traits::{LdaParams, Model};
use crate::util::rng::Rng;

/// Fold in θ for `docs` with φ̂ frozen: per-token EM (the BP update of
/// Eq. 1 without the φ minus-correction, since held-out tokens are not
/// part of φ̂). Returns θ̂, docs × K.
pub fn fold_in_theta(
    model: &Model,
    docs: &Csr,
    params: &LdaParams,
    iters: usize,
    seed: u64,
) -> Vec<f32> {
    assert_eq!(model.w, docs.w, "vocab mismatch");
    let k = model.k;
    let phi_tot = model.phi_tot();
    let wbeta = model.w as f32 * params.beta;
    // Pre-normalized topic-word probabilities, word-major.
    let mut phi_prob = vec![0f32; model.w * k];
    for wi in 0..model.w {
        for t in 0..k {
            phi_prob[wi * k + t] = (model.phi_wk[wi * k + t] + params.beta)
                / (phi_tot[t] + wbeta);
        }
    }

    let mut rng = Rng::new(seed);
    let mut theta = vec![0f32; docs.docs() * k];
    let mut mu = vec![0f32; docs.nnz() * k];
    // random init (same protocol as training, Fig. 4 line 3)
    for row in mu.chunks_exact_mut(k) {
        let mut sum = 0f32;
        for v in row.iter_mut() {
            *v = rng.f32() + 0.1;
            sum += *v;
        }
        row.iter_mut().for_each(|v| *v /= sum);
    }
    for d in 0..docs.docs() {
        for idx in docs.row_range(d) {
            let x = docs.val[idx];
            for t in 0..k {
                theta[d * k + t] += x * mu[idx * k + t];
            }
        }
    }

    let mut scores = vec![0f32; k];
    for _ in 0..iters {
        for d in 0..docs.docs() {
            for idx in docs.row_range(d) {
                let wi = docs.col[idx] as usize;
                let x = docs.val[idx];
                let mu_row = &mut mu[idx * k..(idx + 1) * k];
                let th = &mut theta[d * k..(d + 1) * k];
                let ph = &phi_prob[wi * k..(wi + 1) * k];
                let mut sum = 0f32;
                for t in 0..k {
                    let c = x * mu_row[t];
                    let s = ((th[t] - c).max(0.0) + params.alpha) * ph[t];
                    scores[t] = s;
                    sum += s;
                }
                if sum <= 0.0 {
                    continue;
                }
                let inv = 1.0 / sum;
                for t in 0..k {
                    let new = scores[t] * inv;
                    th[t] += x * (new - mu_row[t]);
                    mu_row[t] = new;
                }
            }
        }
    }
    theta
}

/// Perplexity of `heldout` under (θ̂, φ̂) with Dirichlet smoothing (Eq. 20).
pub fn perplexity(
    model: &Model,
    theta: &[f32],
    heldout: &Csr,
    params: &LdaParams,
) -> f64 {
    let k = model.k;
    let phi_tot = model.phi_tot();
    let wbeta = model.w as f64 * params.beta as f64;
    let kalpha = k as f64 * params.alpha as f64;
    let mut ll = 0f64;
    let mut tokens = 0f64;
    for d in 0..heldout.docs() {
        let th = &theta[d * k..(d + 1) * k];
        let th_sum: f64 = th.iter().map(|&v| v as f64).sum();
        for idx in heldout.row_range(d) {
            let wi = heldout.col[idx] as usize;
            let x = heldout.val[idx] as f64;
            let mut p = 0f64;
            for t in 0..k {
                let theta_p = (th[t] as f64 + params.alpha as f64)
                    / (th_sum + kalpha);
                let phi_p = (model.phi_wk[wi * k + t] as f64
                    + params.beta as f64)
                    / (phi_tot[t] as f64 + wbeta);
                p += theta_p * phi_p;
            }
            ll += x * p.max(1e-300).ln();
            tokens += x;
        }
    }
    if tokens == 0.0 {
        return f64::NAN;
    }
    (-ll / tokens).exp()
}

/// The full Eq. 20 protocol on a pre-computed split.
pub fn predictive_perplexity(
    model: &Model,
    split: &Split,
    params: &LdaParams,
    fold_iters: usize,
    seed: u64,
) -> f64 {
    let theta = fold_in_theta(model, &split.train, params, fold_iters, seed);
    perplexity(model, &theta, &split.heldout, params)
}

/// Perplexity of the training data itself (fold-in on the same docs);
/// a cheap train-quality signal used by unit tests.
pub fn heldin_perplexity(model: &Model, corpus: &Csr, params: &LdaParams) -> f64 {
    let theta = fold_in_theta(model, corpus, params, 20, 7);
    perplexity(model, &theta, corpus, params)
}

/// Perplexity gap of Eq. 21: (P_base − P_ours) / P_base × 100%.
pub fn gap_percent(p_base: f64, p_ours: f64) -> f64 {
    (p_base - p_ours) / p_base * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::split_tokens;
    use crate::synth::{generate, SynthSpec};

    fn toy_model() -> (Model, Csr, LdaParams) {
        let sc = generate(&SynthSpec::tiny(3));
        let params = LdaParams::paper(8);
        let cfg = crate::coordinator::PobpConfig {
            n_workers: 1,
            nnz_budget: usize::MAX,
            max_iters: 25,
            ..Default::default()
        };
        let r = crate::coordinator::fit(&sc.corpus, &params, &cfg);
        (r.model, sc.corpus, params)
    }

    #[test]
    fn trained_model_beats_uniform() {
        let (model, corpus, params) = toy_model();
        let split = split_tokens(&corpus, 0.2, 1);
        let p_trained = predictive_perplexity(&model, &split, &params, 20, 2);
        let uniform = Model::zeros(model.w, model.k);
        let p_uniform = predictive_perplexity(&uniform, &split, &params, 20, 2);
        assert!(p_trained.is_finite() && p_trained > 1.0);
        assert!(
            p_trained < p_uniform * 0.9,
            "trained {p_trained} vs uniform {p_uniform}"
        );
        // uniform model perplexity ≈ W (every word equally likely)
        assert!((p_uniform - model.w as f64).abs() < model.w as f64 * 0.2);
    }

    #[test]
    fn more_fold_iters_do_not_hurt() {
        let (model, corpus, params) = toy_model();
        let split = split_tokens(&corpus, 0.2, 5);
        let p5 = predictive_perplexity(&model, &split, &params, 5, 3);
        let p40 = predictive_perplexity(&model, &split, &params, 40, 3);
        assert!(p40 < p5 * 1.05, "fold-in diverged: {p5} -> {p40}");
    }

    #[test]
    fn gap_formula() {
        assert!((gap_percent(200.0, 150.0) - 25.0).abs() < 1e-12);
        assert!(gap_percent(100.0, 120.0) < 0.0);
    }

    #[test]
    fn theta_mass_tracks_tokens() {
        let (model, corpus, params) = toy_model();
        let theta = fold_in_theta(&model, &corpus, &params, 10, 4);
        let sum: f64 = theta.iter().map(|&v| v as f64).sum();
        assert!((sum - corpus.tokens()).abs() < corpus.tokens() * 1e-3);
    }

    #[test]
    fn empty_heldout_is_nan() {
        let (model, _, params) = toy_model();
        let empty = Csr::from_docs(model.w, &[vec![]]);
        let theta = vec![0f32; model.k];
        assert!(perplexity(&model, &theta, &empty, &params).is_nan());
    }
}
