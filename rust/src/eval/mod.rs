//! Evaluation: predictive perplexity (Eq. 20), perplexity gap (Eq. 21)
//! and topic-quality diagnostics.

pub mod coherence;
pub mod perplexity;

pub use perplexity::{gap_percent, predictive_perplexity};
