//! UMass topic coherence (Mimno et al. 2011) — a qualitative complement
//! to perplexity used by the examples to sanity-check learned topics.
//!
//! ```text
//! C(t) = Σ_{i<j} log ( (D(w_i, w_j) + 1) / D(w_j) )
//! ```
//!
//! over the top-n words of topic t, where D(w) is the document frequency
//! and D(w_i, w_j) the co-document frequency. Higher (closer to 0) is
//! better.

use std::collections::HashMap;

use crate::corpus::Csr;
use crate::engine::traits::Model;

/// Document frequency and pairwise co-document frequency for a word set.
fn co_doc_freq(corpus: &Csr, words: &[u32]) -> (HashMap<u32, u32>, HashMap<(u32, u32), u32>) {
    let set: std::collections::HashSet<u32> = words.iter().copied().collect();
    let mut df: HashMap<u32, u32> = HashMap::new();
    let mut co: HashMap<(u32, u32), u32> = HashMap::new();
    let mut present: Vec<u32> = Vec::new();
    for d in 0..corpus.docs() {
        present.clear();
        let (ws, _) = corpus.row(d);
        for &w in ws {
            if set.contains(&w) {
                present.push(w);
            }
        }
        for (i, &a) in present.iter().enumerate() {
            *df.entry(a).or_insert(0) += 1;
            for &b in &present[i + 1..] {
                let key = if a < b { (a, b) } else { (b, a) };
                *co.entry(key).or_insert(0) += 1;
            }
        }
    }
    (df, co)
}

/// UMass coherence of topic `t` using its `top_n` words.
pub fn umass_coherence(model: &Model, corpus: &Csr, t: usize, top_n: usize) -> f64 {
    let top: Vec<u32> = model.top_words(t, top_n).into_iter().map(|(w, _)| w).collect();
    let (df, co) = co_doc_freq(corpus, &top);
    let mut c = 0f64;
    for i in 1..top.len() {
        for j in 0..i {
            let (a, b) = (top[i], top[j]);
            let key = if a < b { (a, b) } else { (b, a) };
            let co_ab = *co.get(&key).unwrap_or(&0) as f64;
            let d_b = *df.get(&b).unwrap_or(&0) as f64;
            if d_b > 0.0 {
                c += ((co_ab + 1.0) / d_b).ln();
            }
        }
    }
    c
}

/// Mean coherence over all topics.
pub fn mean_coherence(model: &Model, corpus: &Csr, top_n: usize) -> f64 {
    (0..model.k)
        .map(|t| umass_coherence(model, corpus, t, top_n))
        .sum::<f64>()
        / model.k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two perfectly separated word communities: coherent topics must
    /// score higher than a topic mixing the communities.
    #[test]
    fn separated_communities_score_higher() {
        // words 0-2 always co-occur; words 3-5 always co-occur; never mix
        let docs: Vec<Vec<(u32, f32)>> = (0..20)
            .map(|i| {
                if i % 2 == 0 {
                    vec![(0, 1.0), (1, 1.0), (2, 1.0)]
                } else {
                    vec![(3, 1.0), (4, 1.0), (5, 1.0)]
                }
            })
            .collect();
        let corpus = Csr::from_docs(6, &docs);
        let mut good = Model::zeros(6, 2);
        // topic 0 = {0,1,2}, topic 1 = {3,4,5}
        for w in 0..3 {
            good.phi_wk[w * 2] = 10.0;
        }
        for w in 3..6 {
            good.phi_wk[w * 2 + 1] = 10.0;
        }
        let mut bad = Model::zeros(6, 2);
        // topic 0 = {0,3,1}: mixes communities
        bad.phi_wk[0] = 10.0;
        bad.phi_wk[3 * 2] = 9.0;
        bad.phi_wk[2] = 8.0;
        bad.phi_wk[1 * 2 + 1] = 10.0;
        bad.phi_wk[4 * 2 + 1] = 9.0;
        bad.phi_wk[5 * 2 + 1] = 8.0;

        let cg = umass_coherence(&good, &corpus, 0, 3);
        let cb = umass_coherence(&bad, &corpus, 0, 3);
        assert!(cg > cb, "coherent {cg} should beat mixed {cb}");
    }

    #[test]
    fn mean_over_topics_is_finite() {
        let corpus = Csr::from_docs(3, &[vec![(0, 1.0), (1, 2.0)], vec![(2, 1.0)]]);
        let mut m = Model::zeros(3, 2);
        m.phi_wk = vec![1.0, 0.5, 2.0, 0.1, 0.0, 3.0];
        assert!(mean_coherence(&m, &corpus, 2).is_finite());
    }
}
