//! Multi-processor architecture substrate: the simulated cluster, the
//! parallel sparse allreduce, the network cost model, and the
//! communication ledger. See DESIGN.md §Substitutions for why simulation
//! preserves the paper's measured quantities (bytes moved and sync counts
//! are exact; time follows the published link parameters).
//!
//! Since the transport PR the cluster is no longer necessarily
//! simulated: [`transport`] puts real worker processes behind the same
//! coordinator loop ([`wire`] frames over TCP, Contract 8), with the
//! in-process pool as the degenerate single-host backend — and the
//! ledger records *measured* wire seconds next to the α–β estimate so
//! the model is calibrated rather than trusted.
//!
//! The subsystem's standing contracts — written down per module
//! and cross-referenced in `docs/ARCHITECTURE.md`:
//!
//! * **Determinism** ([`cluster`]): every dispatch executes
//!   caller-fixed partitions whose boundaries derive from data counts
//!   only, so float results are machine- and thread-count-independent
//!   whenever accumulation order is keyed on the partition.
//! * **Owner slicing** ([`allreduce`]): the reduce-scatter's
//!   [`OwnerSlices`] partition of the flat index space — row-aligned to
//!   whole φ̂ rows so it doubles as the *storage* partition of the
//!   sharded mode ([`allreduce::ShardedState`]) — the per-element serial
//!   left folds, and the per-owner f64 totals merged in owner order:
//!   bitwise equal to [`allreduce::serial_reference_step`] on every
//!   path, pipelined and sharded included.
//! * **Ledger/overlap accounting** ([`ledger`]): exact bytes, sync
//!   counts and per-segment attribution always; serialized iterations
//!   charge `compute + comm`, overlapped iterations `max(compute,
//!   comm)` with the hidden share tracked in
//!   [`Ledger::overlap_saved_secs`].

pub mod affinity;
pub mod allreduce;
pub mod cluster;
pub mod ledger;
pub mod net;
pub mod transport;
pub mod wire;

pub use allreduce::{
    allreduce_step, allreduce_step_overlap, allreduce_step_overlap_rounds,
    allreduce_step_pool, allreduce_step_sharded, reduce_chunked, reduce_sum_into,
    reduce_sum_subset_into, GatherBuf, GlobalState, OwnerSlices, ReducePlan,
    ReduceSource, ShardedState, SyncScratch,
};
pub use cluster::Cluster;
pub use ledger::{Ledger, MeasuredSeg, SyncEvent};
pub use net::NetModel;
pub use transport::{
    classify, ConnectCfg, FaultClass, FrameCtx, InProcessTransport, TcpSpawnSpec, TcpTransport,
    Transport, TransportError, TransportKind, WireStats,
};
pub use wire::WireError;
