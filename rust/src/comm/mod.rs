//! Multi-processor architecture substrate: the simulated cluster, the
//! parallel sparse allreduce, the network cost model, and the
//! communication ledger. See DESIGN.md §Substitutions for why simulation
//! preserves the paper's measured quantities (bytes moved and sync counts
//! are exact; time follows the published link parameters).

pub mod allreduce;
pub mod cluster;
pub mod ledger;
pub mod net;

pub use allreduce::{
    allreduce_step, allreduce_step_overlap, allreduce_step_pool, reduce_chunked,
    reduce_sum_into, reduce_sum_subset_into, GatherBuf, GlobalState, OwnerSlices,
    ReducePlan, ReduceSource, SyncScratch,
};
pub use cluster::Cluster;
pub use ledger::{Ledger, SyncEvent};
pub use net::NetModel;
