//! The parallel sparse allreduce subsystem — the leader-side realization
//! of the paper's synchronization step (Fig. 4 lines 9–10 / 23–24,
//! Eqs. 6, 9, 15), organized as a true **owner-sliced reduce-scatter**.
//! (`docs/ARCHITECTURE.md` places these contracts in the whole
//! mini-batch lifecycle; the equivalence tests that pin them live in
//! `rust/tests/allreduce_equiv.rs`.)
//!
//! # Ownership model
//!
//! The flat reduce index space (row-major `w·K + k` over the `W × K`
//! matrices) is partitioned into N static **owner slices**
//! ([`OwnerSlices`]) — one per logical worker, boundaries derived from
//! the index count and worker count only, never from the machine's core
//! count. Each worker reduces and scatters *only the (word, topic) pairs
//! that fall inside its slice*, in a single fused pass: Δφ̂, r and the
//! f64 totals deltas move together, with no intermediate `red_dphi` /
//! `red_r` buffers and no barrier between the two matrices.
//!
//! # Storage modes
//!
//! The same owner partition now has two storage realizations:
//!
//! * **Replicated** ([`GlobalState`]): every worker (and the leader)
//!   holds the full `W·K` φ̂/r replica. The "allgather" half of the
//!   allreduce — every processor republishing its owned slice — is free
//!   in this leader-memory simulation (the merged state *is* the shared
//!   replica), and the ledger charges it per segment exactly as before.
//! * **Sharded** ([`ShardedState`]): owner `n` *persistently stores only
//!   its row-aligned slice* of φ̂_eff, r and φ̂_acc
//!   ([`OwnerSlices::row_aligned`] — slice boundaries snapped to whole
//!   φ̂ rows), so per-worker φ̂ memory is O(W·K/N). Sweeps read rows
//!   through a sliced view; the allgather back to the workers ships only
//!   the *next working set's* slices and is charged separately from the
//!   reduce-scatter (`Ledger::record_sync_split`). Every step is bitwise
//!   identical to the replicated oracle — same row-aligned partition,
//!   same per-element left folds, same per-owner f64 totals merge — so
//!   the two modes are interchangeable (pinned by
//!   `rust/tests/shard_equiv.rs`).
//!
//! # Gather-buffer layout
//!
//! * **Dense plan** (t = 1 full sync): plan order is row-major `w·K + k`.
//!   Workers export nothing; the owner tasks borrow their Δφ̂ / r
//!   matrices in place (a real deployment ships the matrix verbatim, so
//!   there is no packing step to model).
//! * **Subset plan** (power iterations): plan order is
//!   `PowerSet::flat_indices` order — selection order, words by
//!   descending residual. Each worker packs its own [`GatherBuf`]
//!   ([`ReduceSource::export_selected_into`]) in parallel on the
//!   cluster, into buffers **reused across syncs** (the [`SyncScratch`]
//!   pool — the old path allocated fresh buffers every iteration).
//!
//! # Determinism
//!
//! Every output element's accumulation chain is the serial leader loop's
//! left fold (seed, then worker 0, worker 1, …) regardless of which
//! thread runs its owner slice, so the result is **bitwise identical**
//! to [`serial_reference_step`], the oracle the equivalence tests
//! compare against. The f64 totals accumulate per owner (slot order
//! within the owner) and merge in ascending owner order — a pure
//! function of the data, identical between [`allreduce_step`] and the
//! pipelined [`allreduce_step_overlap`].
//!
//! # Overlap pipeline (slice-granular)
//!
//! [`allreduce_step_overlap`] is the pipelined variant the coordinator's
//! overlap mode runs, at **slice granularity**: each worker's gather
//! export is split into per-owner-slice chunks, and an owner starts
//! folding its slice as soon as *every worker has packed that slice* —
//! tracked by per-slice ready counters — instead of waiting for whole
//! workers. The per-worker double-buffered rounds pipeline this replaces
//! is retained as [`allreduce_step_overlap_rounds`] (the second pipeline
//! oracle and microbench baseline). Ordering rules that keep all paths
//! bitwise interchangeable:
//!
//! * a slice's fold runs only after all N workers packed *that slice*
//!   (`ready[s] == N`, acquire/release on the counter);
//! * within a slice, every element folds the worker buffers in worker
//!   order — the serial reference's left fold — and the owner's f64
//!   totals deltas accumulate in plan order within the owner;
//! * the per-owner totals merge in ascending owner order, the identical
//!   f64 op sequence as the fused and per-worker-pipelined paths.
//!
//! Results are therefore bitwise identical to [`allreduce_step`] —
//! totals included — only wall-clock scheduling differs; simulated
//! *time* always comes from the byte-exact ledger and the network
//! model's per-segment accounting.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::comm::Cluster;
use crate::fault::{FaultEvent, FaultPlan, SyncPhase};

/// One worker's contribution to a sparse allreduce: Δφ̂ and r values at
/// the plan's flat indices, in plan order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GatherBuf {
    pub dphi: Vec<f32>,
    pub r: Vec<f32>,
}

/// A worker-local source of partial matrices for the allreduce.
/// Implemented by `engine::bp::ShardBp`; test doubles implement only
/// [`ReduceSource::dense_parts`].
pub trait ReduceSource {
    /// Borrow the dense per-worker partials (Δφ̂, r), both `W·K` long,
    /// row-major.
    fn dense_parts(&self) -> (&[f32], &[f32]);

    /// Pack the partials at `indices` (flat `w·K + k`, plan order) into
    /// `buf`, reusing its capacity — the worker side of the sparse
    /// allreduce, called once per sync per worker on the cluster pool.
    fn export_selected_into(&self, indices: &[u32], buf: &mut GatherBuf) {
        let (dphi, r) = self.dense_parts();
        buf.dphi.clear();
        buf.r.clear();
        buf.dphi.extend(indices.iter().map(|&i| dphi[i as usize]));
        buf.r.extend(indices.iter().map(|&i| r[i as usize]));
    }

    /// Allocating convenience wrapper over
    /// [`ReduceSource::export_selected_into`].
    fn export_selected(&self, indices: &[u32]) -> GatherBuf {
        let mut buf = GatherBuf::default();
        self.export_selected_into(indices, &mut buf);
        buf
    }

    /// Pack the partials at the plan slots `slots` (positions into
    /// `indices`) into `buf` — the per-owner-slice gather export of the
    /// slice-granular pipeline ([`allreduce_step_overlap`]): one chunk
    /// per (worker, owner slice), holding the owned slots' values in
    /// plan order within the owner.
    fn export_slice_into(&self, indices: &[u32], slots: &[u32], buf: &mut GatherBuf) {
        let (dphi, r) = self.dense_parts();
        buf.dphi.clear();
        buf.r.clear();
        buf.dphi
            .extend(slots.iter().map(|&s| dphi[indices[s as usize] as usize]));
        buf.r
            .extend(slots.iter().map(|&s| r[indices[s as usize] as usize]));
    }
}

/// Which (word, topic) pairs a synchronization reduces.
#[derive(Clone, Copy, Debug)]
pub enum ReducePlan<'a> {
    /// every pair of the `W × K` matrices, row-major
    Dense { len: usize },
    /// the pairs at these flat indices, in this (plan) order. The plan
    /// is a *set* of pairs: indices must be **distinct**
    /// (`PowerSet::flat_indices` guarantees it — distinct words,
    /// distinct topics per word). The serial and fused steps happen to
    /// tolerate duplicates (each slot refolds from scratch), but the
    /// pipelined step's in-place accumulator does not; distinctness is
    /// the contract.
    Subset { indices: &'a [u32] },
}

impl ReducePlan<'_> {
    /// Number of (word, topic) pairs reduced — the per-processor payload
    /// element count of Eq. (6).
    pub fn pairs(&self) -> usize {
        match self {
            ReducePlan::Dense { len } => *len,
            ReducePlan::Subset { indices } => indices.len(),
        }
    }
}

/// Static ownership partition of the flat reduce index space over the N
/// logical workers — the model-slice assignment of a real reduce-scatter
/// (each processor reduces 1/N of the matrix, then allgathers it back).
/// Boundaries derive from the index count and worker count only (never
/// from the machine's core count), so the partition — and every
/// floating-point accumulation order keyed on it — is machine-independent.
#[derive(Clone, Copy, Debug)]
pub struct OwnerSlices {
    len: usize,
    per: usize,
    owners: usize,
}

impl OwnerSlices {
    pub fn new(len: usize, owners: usize) -> OwnerSlices {
        assert!(owners > 0);
        OwnerSlices { len, per: len.div_ceil(owners).max(1), owners }
    }

    /// Row-aligned partition for a flat `W·K` index space: slice
    /// boundaries are snapped to multiples of `k`, so no word's topic row
    /// straddles two owners — the alignment storage sharding requires
    /// (an owner must hold whole φ̂ rows to serve sweep row reads).
    /// Still derived from the index count and worker count only, hence
    /// machine-independent like [`OwnerSlices::new`]. This is the
    /// partition **both** storage modes use for reductions, so the
    /// per-owner f64 totals grouping — and with it every bitwise
    /// equivalence between the modes — lines up.
    pub fn row_aligned(len: usize, k: usize, owners: usize) -> OwnerSlices {
        assert!(owners > 0);
        assert!(k > 0);
        assert_eq!(len % k, 0, "flat space must be whole φ̂ rows");
        let per = (len / k).div_ceil(owners).max(1) * k;
        OwnerSlices { len, per, owners }
    }

    pub fn owners(&self) -> usize {
        self.owners
    }

    /// Slice width in flat indices (the last owner's slice may be
    /// shorter; trailing owners may be empty).
    pub fn per(&self) -> usize {
        self.per
    }

    /// Flat-index range owned by worker `n` (possibly empty for trailing
    /// workers when the space is smaller than the worker count).
    pub fn range(&self, n: usize) -> std::ops::Range<usize> {
        let lo = (n * self.per).min(self.len);
        let hi = ((n + 1) * self.per).min(self.len);
        lo..hi
    }

    /// The worker owning flat index `i`.
    pub fn owner_of(&self, i: usize) -> usize {
        debug_assert!(i < self.len);
        (i / self.per).min(self.owners - 1)
    }
}

/// Coordinator-owned buffer pool for the owner-sliced allreduce: the
/// per-worker gather buffers, the owner-grouped slot permutation, the
/// per-owner f64 totals deltas and the pipelined path's pre-overwrite
/// snapshots. Reused across syncs and mini-batches — the retired
/// leader-pool path ([`allreduce_step_pool`]) allocates fresh
/// `GatherBuf`s and reduction vectors on every iteration, which showed
/// up as allocator churn on the coordinator's critical path.
#[derive(Debug, Default)]
pub struct SyncScratch {
    /// per-worker plan-order gather buffers ([`allreduce_step`]) /
    /// double buffer ([`allreduce_step_overlap_rounds`])
    gather: Vec<GatherBuf>,
    /// owner n reduces plan slots `owner_slots[owner_off[n]..owner_off[n+1]]`
    owner_off: Vec<u32>,
    /// plan slot ids grouped by owner, plan order within each owner
    owner_slots: Vec<u32>,
    cursor: Vec<u32>,
    /// per-owner totals deltas: owner n owns lanes `n·(k+1) .. (n+1)·(k+1)`
    /// (k φ̂-topic lanes + 1 residual lane), merged in ascending owner order
    tot_delta: Vec<f64>,
    /// pre-overwrite `phi_eff` / `r_global` values at the owned slots
    /// (per-worker rounds pipeline only; aligned with `owner_slots`)
    old_phi: Vec<f32>,
    old_r: Vec<f32>,
    /// slice-granular pipeline: per-(owner slice, worker) gather chunks,
    /// slice-major (`slice_bufs[s·N + w]`), reused across syncs. The
    /// mutexes hand chunk ownership from the pack task that fills a
    /// chunk to the fold task that reads it; each lock is uncontended
    /// once the slice's ready counter has been observed.
    slice_bufs: Vec<Mutex<GatherBuf>>,
    /// slice-granular pipeline: per-slice pack-completion counters — a
    /// slice's fold spins until its counter reaches the worker count
    ready: Vec<AtomicUsize>,
}

impl SyncScratch {
    /// Group the plan slots by owning worker (counting sort, reused
    /// storage): after the call, owner `n`'s slots are
    /// `owner_slots[owner_off[n]..owner_off[n+1]]`, in plan order — the
    /// deterministic per-owner scatter order.
    fn group_by_owner(&mut self, indices: &[u32], slices: &OwnerSlices) {
        let owners = slices.owners();
        self.owner_off.clear();
        self.owner_off.resize(owners + 1, 0);
        for &ix in indices {
            self.owner_off[slices.owner_of(ix as usize) + 1] += 1;
        }
        for n in 0..owners {
            self.owner_off[n + 1] += self.owner_off[n];
        }
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.owner_off[..owners]);
        self.owner_slots.clear();
        self.owner_slots.resize(indices.len(), 0);
        for (slot, &ix) in indices.iter().enumerate() {
            let o = slices.owner_of(ix as usize);
            self.owner_slots[self.cursor[o] as usize] = slot as u32;
            self.cursor[o] += 1;
        }
    }
}

/// The replicated state every processor holds after an allreduce:
/// effective φ̂ (= φ̂_acc + Σ_n Δφ̂_n on synchronized pairs), the
/// synchronized residual matrix, and their running totals.
///
/// The totals are f64-backed: dense syncs recompute them from scratch,
/// subset syncs accumulate exact f32→f64 deltas (per owner slice, merged
/// in owner order), so the drift of the old incremental-f32 bookkeeping
/// is gone (see `totals_drift`). The sweep kernels consume the f32
/// render via [`GlobalState::phi_tot`].
#[derive(Clone, Debug)]
pub struct GlobalState {
    pub phi_eff: Vec<f32>,
    pub r_global: Vec<f32>,
    phi_tot64: Vec<f64>,
    phi_tot32: Vec<f32>,
    r_total: f64,
    k: usize,
}

impl GlobalState {
    /// Fresh per-batch state: φ_eff = φ̂_acc, no residuals yet.
    pub fn new(phi_acc: &[f32], k: usize) -> GlobalState {
        let mut s = GlobalState {
            phi_eff: phi_acc.to_vec(),
            r_global: vec![0.0; phi_acc.len()],
            phi_tot64: vec![0.0; k],
            phi_tot32: vec![0.0; k],
            r_total: 0.0,
            k,
        };
        s.recompute_totals();
        s
    }

    /// Topic totals φ̂_Σ(k) as the f32 view the sweep kernels read.
    pub fn phi_tot(&self) -> &[f32] {
        &self.phi_tot32
    }

    /// Total synchronized residual Σ r (line 26's convergence quantity).
    pub fn r_total(&self) -> f64 {
        self.r_total
    }

    /// Rebuild both totals from the matrices, in f64.
    pub fn recompute_totals(&mut self) {
        self.phi_tot64.fill(0.0);
        for row in self.phi_eff.chunks_exact(self.k) {
            for (t, &v) in row.iter().enumerate() {
                self.phi_tot64[t] += v as f64;
            }
        }
        self.r_total = self.r_global.iter().map(|&v| v as f64).sum();
        self.render_tot32();
    }

    fn render_tot32(&mut self) {
        for (o, &v) in self.phi_tot32.iter_mut().zip(&self.phi_tot64) {
            *o = v as f32;
        }
    }

    /// Fold the per-owner totals deltas in ascending owner order — the
    /// deterministic second half of a subset reduce-scatter, shared by
    /// the fused and pipelined paths (identical f64 op sequence, so the
    /// two are bitwise interchangeable).
    fn merge_owner_totals(&mut self, tot_delta: &[f64]) {
        let k = self.k;
        debug_assert_eq!(tot_delta.len() % (k + 1), 0);
        for td in tot_delta.chunks_exact(k + 1) {
            for (t, slot) in self.phi_tot64.iter_mut().enumerate() {
                *slot += td[t];
            }
            self.r_total += td[k];
        }
        self.render_tot32();
    }

    /// Drift diagnostics: (max |running − recomputed| over topic totals,
    /// |running − recomputed| residual total). Bounded by f64 rounding —
    /// the long-run drift test pins it near zero.
    pub fn totals_drift(&self) -> (f64, f64) {
        let mut fresh = vec![0f64; self.k];
        for row in self.phi_eff.chunks_exact(self.k) {
            for (t, &v) in row.iter().enumerate() {
                fresh[t] += v as f64;
            }
        }
        let phi_drift = fresh
            .iter()
            .zip(&self.phi_tot64)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        let r_fresh: f64 = self.r_global.iter().map(|&v| v as f64).sum();
        (phi_drift, (r_fresh - self.r_total).abs())
    }

    /// Apply reduced plan-order sub-vectors at `indices`: the scatter
    /// half of a subset allreduce (retained for the leader-pool baseline
    /// [`allreduce_step_pool`]). Matches the pre-refactor per-element
    /// arithmetic on `phi_eff`/`r_global` bitwise; totals move by exact
    /// f32→f64 deltas.
    fn scatter_subset(
        &mut self,
        indices: &[u32],
        phi_acc: &[f32],
        red_dphi: &[f32],
        red_r: &[f32],
    ) {
        let k = self.k;
        for ((&ix, &d), &r) in indices.iter().zip(red_dphi).zip(red_r) {
            let i = ix as usize;
            let new_phi = phi_acc[i] + d;
            self.phi_tot64[i % k] += new_phi as f64 - self.phi_eff[i] as f64;
            self.phi_eff[i] = new_phi;
            self.r_total += r as f64 - self.r_global[i] as f64;
            self.r_global[i] = r;
        }
        self.render_tot32();
    }
}

// ---------------------------------------------------------------------
// owner-slice task types (module-level: inner items cannot name a
// function's generic parameters)
// ---------------------------------------------------------------------

/// One owner's disjoint view of the replicated state for a dense fold.
struct DenseSlice<'a> {
    base: usize,
    phi: &'a mut [f32],
    r: &'a mut [f32],
}

/// One owner's disjoint view for a subset fold: the owned contiguous
/// `phi_eff`/`r_global` windows, the plan slots that scatter into them,
/// the owner's f64 totals lanes, and (pipelined path) the pre-overwrite
/// value snapshots aligned with `slots`.
struct FoldSlice<'a> {
    base: usize,
    phi: &'a mut [f32],
    r: &'a mut [f32],
    slots: &'a [u32],
    td: &'a mut [f64],
    old_phi: &'a mut [f32],
    old_r: &'a mut [f32],
}

/// A pipelined dispatch round's task: fold one worker's buffer into an
/// owner slice, or pack the *next* worker's buffer (the double-buffered
/// gather export that overlaps with the fold).
enum PipeTask<'a, S> {
    Fold(FoldSlice<'a>),
    Pack { worker: &'a Mutex<S>, dst: &'a mut GatherBuf },
}

/// A slice-granular dispatch task ([`allreduce_step_overlap`]): pack one
/// worker's chunk of one owner slice, or fold one owner slice once its
/// ready counter shows every worker has packed it.
enum SliceTask<'a, S> {
    Pack {
        worker: &'a Mutex<S>,
        chunk: &'a Mutex<GatherBuf>,
        slots: &'a [u32],
        ready: &'a AtomicUsize,
    },
    Fold {
        t: FoldSlice<'a>,
        chunks: &'a [Mutex<GatherBuf>],
        ready: &'a AtomicUsize,
    },
}

/// Split the replicated state (and the owner-grouped scratch lanes) into
/// per-owner disjoint fold tasks. `old` additionally hands each owner
/// its aligned pre-overwrite snapshot windows (pipelined path).
#[allow(clippy::too_many_arguments)]
fn make_fold_slices<'a>(
    slices: &OwnerSlices,
    k: usize,
    phi_eff: &'a mut [f32],
    r_global: &'a mut [f32],
    owner_off: &[u32],
    owner_slots: &'a [u32],
    tot_delta: &'a mut [f64],
    old: Option<(&'a mut [f32], &'a mut [f32])>,
) -> Vec<FoldSlice<'a>> {
    let owners = slices.owners();
    let mut out = Vec::with_capacity(owners);
    let mut phi_rest = phi_eff;
    let mut r_rest = r_global;
    let mut slots_rest = owner_slots;
    let mut td_rest = tot_delta;
    let has_old = old.is_some();
    let (mut op_rest, mut or_rest): (&'a mut [f32], &'a mut [f32]) = match old {
        Some((p, r)) => (p, r),
        None => (&mut [], &mut []),
    };
    for n in 0..owners {
        let rg = slices.range(n);
        let (phi_n, rest) = phi_rest.split_at_mut(rg.len());
        phi_rest = rest;
        let (r_n, rest) = r_rest.split_at_mut(rg.len());
        r_rest = rest;
        let cnt = (owner_off[n + 1] - owner_off[n]) as usize;
        let (sl_n, rest) = slots_rest.split_at(cnt);
        slots_rest = rest;
        let (td_n, rest) = td_rest.split_at_mut(k + 1);
        td_rest = rest;
        let (op_n, or_n): (&'a mut [f32], &'a mut [f32]) = if has_old {
            // the snapshot windows partition exactly like the slot lists
            let (a, rest) = op_rest.split_at_mut(cnt);
            op_rest = rest;
            let (b, rest) = or_rest.split_at_mut(cnt);
            or_rest = rest;
            (a, b)
        } else {
            (&mut [], &mut [])
        };
        out.push(FoldSlice {
            base: rg.start,
            phi: phi_n,
            r: r_n,
            slots: sl_n,
            td: td_n,
            old_phi: op_n,
            old_r: or_n,
        });
    }
    out
}

/// Dense owner-sliced reduce-scatter: every owner folds its contiguous
/// slice of both matrices in one fused pass over the worker partials —
/// the per-element left fold of the serial reference (seed φ̂_acc / 0,
/// then one add per worker in worker order), both matrices collected
/// from each lock guard **once** (the old path walked `dense_parts`
/// twice per guard).
fn dense_owner_step<S: ReduceSource + Send>(
    cluster: &Cluster,
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    state: &mut GlobalState,
) -> usize {
    let len = state.phi_eff.len();
    debug_assert_eq!(phi_acc.len(), len);
    let guards: Vec<_> = workers.iter().map(|m| m.lock().unwrap()).collect();
    // one pass over the guards: Δφ̂ and r slices collected together
    let parts: Vec<(&[f32], &[f32])> = guards.iter().map(|g| g.dense_parts()).collect();
    let slices = OwnerSlices::row_aligned(len, state.k, workers.len());
    let mut tasks: Vec<DenseSlice<'_>> = Vec::with_capacity(slices.owners());
    {
        let mut phi_rest = &mut state.phi_eff[..];
        let mut r_rest = &mut state.r_global[..];
        for n in 0..slices.owners() {
            let rg = slices.range(n);
            let (phi_n, rest) = phi_rest.split_at_mut(rg.len());
            phi_rest = rest;
            let (r_n, rest) = r_rest.split_at_mut(rg.len());
            r_rest = rest;
            tasks.push(DenseSlice { base: rg.start, phi: phi_n, r: r_n });
        }
    }
    cluster.run_on_owner_slices(&mut tasks, |_n, t| {
        for (j, (po, ro)) in t.phi.iter_mut().zip(t.r.iter_mut()).enumerate() {
            let i = t.base + j;
            let mut acc = phi_acc[i];
            let mut racc = 0f32;
            for (dp, rp) in &parts {
                acc += dp[i];
                racc += rp[i];
            }
            *po = acc;
            *ro = racc;
        }
    });
    drop(tasks);
    drop(guards);
    state.recompute_totals();
    len
}

/// Subset owner-sliced reduce-scatter, single dispatch: gather every
/// worker's plan-order buffer in parallel (reused scratch), then one
/// owner dispatch where each owner folds **all** workers over its slots
/// — Δφ̂ sum, r sum, scatter and f64 totals deltas fused per slot.
fn subset_owner_step<S: ReduceSource + Send>(
    cluster: &Cluster,
    indices: &[u32],
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    state: &mut GlobalState,
    scratch: &mut SyncScratch,
) -> usize {
    let nw = workers.len();
    let k = state.k;
    // parallel gather: each worker packs its own plan-order buffer into
    // the reused pool — dispatched directly over the pooled buffers (the
    // old per-sync `Vec<&mut GatherBuf>` task list is gone)
    scratch.gather.resize_with(nw, GatherBuf::default);
    cluster.run_on_owner_slices(&mut scratch.gather[..nw], |n, buf| {
        workers[n].lock().unwrap().export_selected_into(indices, buf);
    });
    let slices = OwnerSlices::row_aligned(state.phi_eff.len(), k, nw);
    scratch.group_by_owner(indices, &slices);
    scratch.tot_delta.clear();
    scratch.tot_delta.resize(slices.owners() * (k + 1), 0.0);
    let bufs = &scratch.gather;
    let mut tasks = make_fold_slices(
        &slices,
        k,
        &mut state.phi_eff,
        &mut state.r_global,
        &scratch.owner_off,
        &scratch.owner_slots,
        &mut scratch.tot_delta,
        None,
    );
    cluster.run_on_owner_slices(&mut tasks, |_n, t| {
        for &s in t.slots {
            let s = s as usize;
            let i = indices[s] as usize;
            let j = i - t.base;
            // the serial reference's left folds, worker order, both
            // matrices in one pass (0-seeded like the serial loop)
            let mut dsum = 0f32;
            let mut rsum = 0f32;
            for b in bufs {
                dsum += b.dphi[s];
                rsum += b.r[s];
            }
            let new_phi = phi_acc[i] + dsum;
            t.td[i % k] += new_phi as f64 - t.phi[j] as f64;
            t.phi[j] = new_phi;
            t.td[k] += rsum as f64 - t.r[j] as f64;
            t.r[j] = rsum;
        }
    });
    drop(tasks);
    state.merge_owner_totals(&scratch.tot_delta);
    indices.len()
}

/// Subset owner-sliced reduce-scatter, per-worker double-buffered rounds
/// pipeline (retained behind [`allreduce_step_overlap_rounds`]): round n
/// folds worker n's buffer into every owner slice while worker n+1 packs
/// its export into the alternate buffer on the same dispatch. The fold
/// accumulates directly in `phi_eff`/`r_global` (same f32 op sequence as
/// the single-dispatch path's register accumulators), snapshots
/// pre-overwrite values in round 0, and finalizes scatter + totals in
/// the last round — bitwise identical to [`subset_owner_step`].
///
/// Relies on the [`ReducePlan::Subset`] distinctness contract: a
/// duplicated flat index would re-seed the in-place accumulator mid-fold
/// (the slot-local refold of the serial/fused paths has no such hazard).
fn subset_owner_step_pipelined<S: ReduceSource + Send>(
    cluster: &Cluster,
    indices: &[u32],
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    state: &mut GlobalState,
    scratch: &mut SyncScratch,
) -> usize {
    let nw = workers.len();
    let k = state.k;
    let m = indices.len();
    let slices = OwnerSlices::row_aligned(state.phi_eff.len(), k, nw);
    scratch.group_by_owner(indices, &slices);
    scratch.tot_delta.clear();
    scratch.tot_delta.resize(slices.owners() * (k + 1), 0.0);
    scratch.old_phi.clear();
    scratch.old_phi.resize(m.max(1), 0.0);
    scratch.old_r.clear();
    scratch.old_r.resize(m.max(1), 0.0);
    if scratch.gather.len() < 2 {
        scratch.gather.resize_with(2, GatherBuf::default);
    }
    // prime the pipeline: worker 0 packs on the leader thread
    workers[0].lock().unwrap().export_selected_into(indices, &mut scratch.gather[0]);

    for wn in 0..nw {
        let first = wn == 0;
        let last = wn + 1 == nw;
        let (g0, g1) = scratch.gather.split_at_mut(1);
        let (cur, next): (&GatherBuf, &mut GatherBuf) = if wn % 2 == 0 {
            (&g0[0], &mut g1[0])
        } else {
            (&g1[0], &mut g0[0])
        };
        let fold = make_fold_slices(
            &slices,
            k,
            &mut state.phi_eff,
            &mut state.r_global,
            &scratch.owner_off,
            &scratch.owner_slots,
            &mut scratch.tot_delta,
            Some((&mut scratch.old_phi[..m], &mut scratch.old_r[..m])),
        );
        // Pack goes FIRST: tasks are claimed in index order, so on pools
        // narrower than owners+1 a trailing pack would only start after
        // every fold finished — the overlap this pipeline exists for.
        let mut tasks: Vec<PipeTask<'_, S>> = Vec::with_capacity(fold.len() + 1);
        if !last {
            tasks.push(PipeTask::Pack { worker: &workers[wn + 1], dst: next });
        }
        tasks.extend(fold.into_iter().map(PipeTask::Fold));
        cluster.run_on_owner_slices(&mut tasks, |_i, task| match task {
            PipeTask::Pack { worker, dst } => {
                worker.lock().unwrap().export_selected_into(indices, dst);
            }
            PipeTask::Fold(t) => {
                for (p, &s) in t.slots.iter().enumerate() {
                    let s = s as usize;
                    let i = indices[s] as usize;
                    let j = i - t.base;
                    if first {
                        t.old_phi[p] = t.phi[j];
                        t.old_r[p] = t.r[j];
                        // explicit 0 + x: the serial fold seeds each
                        // accumulator with literal 0.0 (preserves the
                        // -0.0 edge case bit-for-bit)
                        t.phi[j] = 0f32 + cur.dphi[s];
                        t.r[j] = 0f32 + cur.r[s];
                    } else {
                        t.phi[j] += cur.dphi[s];
                        t.r[j] += cur.r[s];
                    }
                    if last {
                        let new_phi = phi_acc[i] + t.phi[j];
                        t.td[i % k] += new_phi as f64 - t.old_phi[p] as f64;
                        t.phi[j] = new_phi;
                        t.td[k] += t.r[j] as f64 - t.old_r[p] as f64;
                    }
                }
            }
        });
    }
    state.merge_owner_totals(&scratch.tot_delta);
    m
}

/// Subset owner-sliced reduce-scatter, **slice-granular** pipeline: every
/// worker's gather export is split into per-owner-slice chunks, and the
/// fold of slice `s` starts as soon as all `N` workers have packed *that
/// slice* (per-slice ready counters) — no per-worker rounds, no barrier
/// between packing and folding. One dispatch carries `N·S` pack tasks
/// and `S` fold tasks, interleaved slice-major so early slices fold
/// while later slices still pack.
///
/// Deadlock-freedom: tasks are claimed in index order, so a thread
/// spinning in fold `s` implies every pack of slice `s` is claimed; the
/// still-running ones execute on *other* threads (a spinning fold never
/// holds a pack), so the counter always reaches `N`. On a single thread
/// the tasks simply run in order (packs of `s`, then fold `s`).
///
/// Bitwise identical to [`subset_owner_step`]: per element the fold is
/// the same worker-order left fold, the owner's f64 totals deltas
/// accumulate in plan order within the owner, and the owners merge in
/// ascending order — the identical f64 op sequence.
fn subset_owner_step_sliced<S: ReduceSource + Send>(
    cluster: &Cluster,
    indices: &[u32],
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    state: &mut GlobalState,
    scratch: &mut SyncScratch,
) -> usize {
    let nw = workers.len();
    let k = state.k;
    let slices = OwnerSlices::row_aligned(state.phi_eff.len(), k, nw);
    let owners = slices.owners();
    scratch.group_by_owner(indices, &slices);
    scratch.tot_delta.clear();
    scratch.tot_delta.resize(owners * (k + 1), 0.0);
    if scratch.slice_bufs.len() < nw * owners {
        scratch
            .slice_bufs
            .resize_with(nw * owners, || Mutex::new(GatherBuf::default()));
    }
    if scratch.ready.len() < owners {
        scratch.ready.resize_with(owners, || AtomicUsize::new(0));
    }
    for rd in &scratch.ready[..owners] {
        rd.store(0, Ordering::Relaxed);
    }

    let fold = make_fold_slices(
        &slices,
        k,
        &mut state.phi_eff,
        &mut state.r_global,
        &scratch.owner_off,
        &scratch.owner_slots,
        &mut scratch.tot_delta,
        None,
    );

    let owner_off = &scratch.owner_off;
    let owner_slots = &scratch.owner_slots;
    let slice_bufs = &scratch.slice_bufs;
    let ready = &scratch.ready;
    let mut tasks: Vec<SliceTask<'_, S>> = Vec::with_capacity(owners * (nw + 1));
    for (s, fold_s) in fold.into_iter().enumerate() {
        let slots =
            &owner_slots[owner_off[s] as usize..owner_off[s + 1] as usize];
        for (w, worker) in workers.iter().enumerate() {
            tasks.push(SliceTask::Pack {
                worker,
                chunk: &slice_bufs[s * nw + w],
                slots,
                ready: &ready[s],
            });
        }
        tasks.push(SliceTask::Fold {
            t: fold_s,
            chunks: &slice_bufs[s * nw..(s + 1) * nw],
            ready: &ready[s],
        });
    }
    cluster.run_on_owner_slices(&mut tasks, |_i, task| match task {
        SliceTask::Pack { worker, chunk, slots, ready } => {
            {
                let mut buf = chunk.lock().unwrap();
                worker.lock().unwrap().export_slice_into(indices, slots, &mut buf);
            }
            ready.fetch_add(1, Ordering::Release);
        }
        SliceTask::Fold { t, chunks, ready } => {
            // slice-granular readiness: the other pool threads are
            // running this slice's remaining packs
            while ready.load(Ordering::Acquire) < nw {
                std::thread::yield_now();
            }
            // one uncontended lock per worker chunk; the guard list is
            // an O(N) per-fold allocation (guards are lifetime-bound and
            // cannot live in the pool) — see the ROADMAP scratch note
            let guards: Vec<_> =
                chunks.iter().map(|c| c.lock().unwrap()).collect();
            for (p, &slot) in t.slots.iter().enumerate() {
                let i = indices[slot as usize] as usize;
                let j = i - t.base;
                // the serial reference's left folds, worker order
                let mut dsum = 0f32;
                let mut rsum = 0f32;
                for g in &guards {
                    dsum += g.dphi[p];
                    rsum += g.r[p];
                }
                let new_phi = phi_acc[i] + dsum;
                t.td[i % k] += new_phi as f64 - t.phi[j] as f64;
                t.phi[j] = new_phi;
                t.td[k] += rsum as f64 - t.r[j] as f64;
                t.r[j] = rsum;
            }
        }
    });
    drop(tasks);
    state.merge_owner_totals(&scratch.tot_delta);
    indices.len()
}

/// One full synchronization as an owner-sliced reduce-scatter: gather
/// worker partials per `plan` (subset plans pack into `scratch`'s reused
/// buffers), then each owner reduces + scatters its slice in a single
/// fused pass. Returns the number of (word, topic) pairs reduced; the
/// caller charges `2 · 4 · pairs` payload bytes (φ̂ and r) to the ledger.
///
/// Equivalent — bitwise, on `phi_eff`/`r_global` — to
/// [`serial_reference_step`] on the same inputs, at any thread budget.
pub fn allreduce_step<S: ReduceSource + Send>(
    cluster: &Cluster,
    plan: &ReducePlan,
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    state: &mut GlobalState,
    scratch: &mut SyncScratch,
) -> usize {
    assert_eq!(
        workers.len(),
        cluster.workers(),
        "one shard per logical worker"
    );
    match plan {
        ReducePlan::Dense { len } => {
            debug_assert_eq!(*len, state.phi_eff.len());
            dense_owner_step(cluster, phi_acc, workers, state)
        }
        ReducePlan::Subset { indices } => {
            subset_owner_step(cluster, indices, phi_acc, workers, state, scratch)
        }
    }
}

/// The pipelined synchronization (coordinator overlap mode), at **slice
/// granularity**: an owner folds its slice as soon as every worker has
/// packed *that slice* (per-slice ready counters), so packing and
/// folding interleave freely instead of alternating per-worker rounds.
/// Dense plans have no packing phase (matrices are borrowed in place),
/// so they degenerate to the fused dense dispatch — their overlap shows
/// up only in the ledger's `max(compute, comm)` accounting. Results are
/// **bitwise identical** to [`allreduce_step`] and to the retained
/// per-worker rounds pipeline [`allreduce_step_overlap_rounds`], totals
/// included.
pub fn allreduce_step_overlap<S: ReduceSource + Send>(
    cluster: &Cluster,
    plan: &ReducePlan,
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    state: &mut GlobalState,
    scratch: &mut SyncScratch,
) -> usize {
    assert_eq!(
        workers.len(),
        cluster.workers(),
        "one shard per logical worker"
    );
    match plan {
        ReducePlan::Dense { len } => {
            debug_assert_eq!(*len, state.phi_eff.len());
            dense_owner_step(cluster, phi_acc, workers, state)
        }
        ReducePlan::Subset { indices } => {
            subset_owner_step_sliced(cluster, indices, phi_acc, workers, state, scratch)
        }
    }
}

/// The retained per-worker double-buffered rounds pipeline (the PR-3
/// overlap path the slice-granular [`allreduce_step_overlap`] replaced):
/// round n folds worker n's whole buffer into every owner slice while
/// worker n+1 packs into the alternate buffer. Kept as the second
/// pipeline oracle and the microbench baseline — bitwise identical to
/// [`allreduce_step`] and [`allreduce_step_overlap`], totals included.
pub fn allreduce_step_overlap_rounds<S: ReduceSource + Send>(
    cluster: &Cluster,
    plan: &ReducePlan,
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    state: &mut GlobalState,
    scratch: &mut SyncScratch,
) -> usize {
    assert_eq!(
        workers.len(),
        cluster.workers(),
        "one shard per logical worker"
    );
    match plan {
        ReducePlan::Dense { len } => {
            debug_assert_eq!(*len, state.phi_eff.len());
            dense_owner_step(cluster, phi_acc, workers, state)
        }
        ReducePlan::Subset { indices } => {
            subset_owner_step_pipelined(cluster, indices, phi_acc, workers, state, scratch)
        }
    }
}

/// The retired PR-1 leader-pool synchronization, kept as the microbench
/// baseline and a second equivalence oracle: the whole pool reduces
/// *every* slice in two chunk-parallel passes (`red_dphi`, then `red_r`)
/// with freshly allocated gather/reduction buffers, followed by a serial
/// scatter. Bitwise-equal to [`allreduce_step`] on `phi_eff`/`r_global`;
/// slower (double pass, allocator churn, serial scatter).
pub fn allreduce_step_pool<S: ReduceSource + Send>(
    cluster: &Cluster,
    plan: &ReducePlan,
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    state: &mut GlobalState,
) -> usize {
    assert_eq!(
        workers.len(),
        cluster.workers(),
        "one shard per logical worker"
    );
    match plan {
        ReducePlan::Dense { len } => {
            debug_assert_eq!(*len, state.phi_eff.len());
            let guards: Vec<_> = workers.iter().map(|m| m.lock().unwrap()).collect();
            let parts: Vec<(&[f32], &[f32])> =
                guards.iter().map(|g| g.dense_parts()).collect();
            let dphi_parts: Vec<&[f32]> = parts.iter().map(|p| p.0).collect();
            let r_parts: Vec<&[f32]> = parts.iter().map(|p| p.1).collect();
            reduce_chunked(cluster, Some(phi_acc), &dphi_parts, &mut state.phi_eff);
            reduce_chunked(cluster, None, &r_parts, &mut state.r_global);
            drop(guards);
            state.recompute_totals();
            *len
        }
        ReducePlan::Subset { indices } => {
            let (bufs, _) =
                cluster.run(|n| workers[n].lock().unwrap().export_selected(indices));
            let m = indices.len();
            let mut red_dphi = vec![0f32; m];
            let mut red_r = vec![0f32; m];
            let dphi_parts: Vec<&[f32]> = bufs.iter().map(|b| b.dphi.as_slice()).collect();
            let r_parts: Vec<&[f32]> = bufs.iter().map(|b| b.r.as_slice()).collect();
            reduce_chunked(cluster, None, &dphi_parts, &mut red_dphi);
            reduce_chunked(cluster, None, &r_parts, &mut red_r);
            state.scatter_subset(indices, phi_acc, &red_dphi, &red_r);
            m
        }
    }
}

// ---------------------------------------------------------------------
// sharded storage mode: φ̂ partitioned by owner slice
// ---------------------------------------------------------------------

/// One owner's fold task in sharded dense mode: the owner's *stored*
/// slices (φ̂_eff, r) plus its φ̂_acc slice, all row-aligned.
struct ShardDenseTask<'a> {
    base: usize,
    acc: &'a [f32],
    phi: &'a mut [f32],
    r: &'a mut [f32],
}

/// One owner's fold task in sharded subset mode: stored slices, φ̂_acc
/// slice, the plan slots scattering into them and the owner's f64 totals
/// lanes.
struct ShardFoldTask<'a> {
    base: usize,
    acc: &'a [f32],
    phi: &'a mut [f32],
    r: &'a mut [f32],
    slots: &'a [u32],
    td: &'a mut [f64],
}

/// One owner's end-of-batch accumulator fold: φ̂_acc slice += Σ Δφ̂.
struct ShardAccTask<'a> {
    base: usize,
    phi: &'a mut [f32],
    acc: &'a mut [f32],
}

/// The **sharded** realization of the post-allreduce state: owner `n`
/// persistently stores only its row-aligned slice of φ̂_eff and r
/// (`phi_slices[n]` / `r_slices[n]` covering `OwnerSlices::range(n)` of
/// the flat row-major space), plus the shared f64 totals. This is the
/// model-parallel big-K storage mode: no processor ever materializes the
/// dense `W·K` replica, so per-worker φ̂ memory is O(W·K/N).
///
/// Bitwise contract (Contract 5): with the same row-aligned partition,
/// every fold is the serial reference's per-element left fold and the
/// totals accumulate per owner and merge in ascending owner order —
/// exactly [`GlobalState`]'s op sequence — so
/// `concat(phi_slices) == GlobalState::phi_eff` bitwise after each sync,
/// totals included. [`GlobalState`] stays the oracle.
#[derive(Clone, Debug)]
pub struct ShardedState {
    os: OwnerSlices,
    k: usize,
    /// per-owner φ̂_eff slices, owner order; `phi_slices[n]` covers the
    /// flat range `os.range(n)`
    pub phi_slices: Vec<Vec<f32>>,
    /// per-owner synchronized-residual slices, aligned with `phi_slices`
    pub r_slices: Vec<Vec<f32>>,
    phi_tot64: Vec<f64>,
    phi_tot32: Vec<f32>,
    r_total: f64,
}

impl ShardedState {
    /// Fresh per-batch state from the sharded accumulator: φ_eff slice =
    /// φ̂_acc slice, no residuals yet — the sharded mirror of
    /// [`GlobalState::new`].
    pub fn new(phi_acc_parts: &[Vec<f32>], k: usize, os: OwnerSlices) -> ShardedState {
        assert_eq!(phi_acc_parts.len(), os.owners());
        for (n, p) in phi_acc_parts.iter().enumerate() {
            assert_eq!(p.len(), os.range(n).len(), "acc slice {n} misaligned");
        }
        let mut s = ShardedState {
            os,
            k,
            phi_slices: phi_acc_parts.to_vec(),
            r_slices: phi_acc_parts.iter().map(|p| vec![0.0; p.len()]).collect(),
            phi_tot64: vec![0.0; k],
            phi_tot32: vec![0.0; k],
            r_total: 0.0,
        };
        s.recompute_totals();
        s
    }

    /// The row-aligned owner partition this state stores φ̂ under.
    pub fn owner_slices(&self) -> OwnerSlices {
        self.os
    }

    /// φ̂ rows (words) per owner slice — the stride of the sliced row
    /// view (`row w lives in slice w / rows_per, local row w % rows_per`).
    pub fn rows_per(&self) -> usize {
        self.os.per / self.k
    }

    /// Topic totals φ̂_Σ(k) as the f32 view the sweep kernels read.
    pub fn phi_tot(&self) -> &[f32] {
        &self.phi_tot32
    }

    /// Total synchronized residual Σ r (line 26's convergence quantity).
    pub fn r_total(&self) -> f64 {
        self.r_total
    }

    /// Borrowed per-owner φ̂_eff slices, owner order (the sliced sweep
    /// view / snapshot publish source).
    pub fn phi_parts(&self) -> Vec<&[f32]> {
        self.phi_slices.iter().map(|p| p.as_slice()).collect()
    }

    /// Borrowed per-owner r slices, owner order (sharded power selection).
    pub fn r_parts(&self) -> Vec<&[f32]> {
        self.r_slices.iter().map(|p| p.as_slice()).collect()
    }

    /// Largest per-worker resident φ̂ footprint in bytes (φ̂_eff + r
    /// slices) — what one processor actually stores in sharded mode.
    pub fn resident_bytes_per_worker(&self) -> usize {
        self.phi_slices
            .iter()
            .zip(&self.r_slices)
            .map(|(p, r)| 4 * (p.len() + r.len()))
            .max()
            .unwrap_or(0)
    }

    /// Materialize the dense φ̂_eff (evaluation / oracle comparison only
    /// — the training path never calls this).
    pub fn render_dense(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.os.len);
        for p in &self.phi_slices {
            out.extend_from_slice(p);
        }
        out
    }

    /// Rebuild both totals from the stored slices, in f64. Slices are
    /// walked in owner order, rows in order within each slice — the
    /// concatenation is the dense row order, so the f64 op sequence is
    /// identical to [`GlobalState::recompute_totals`].
    pub fn recompute_totals(&mut self) {
        self.phi_tot64.fill(0.0);
        for part in &self.phi_slices {
            for row in part.chunks_exact(self.k) {
                for (t, &v) in row.iter().enumerate() {
                    self.phi_tot64[t] += v as f64;
                }
            }
        }
        self.r_total = 0.0;
        for part in &self.r_slices {
            for &v in part {
                self.r_total += v as f64;
            }
        }
        self.render_tot32();
    }

    fn render_tot32(&mut self) {
        for (o, &v) in self.phi_tot32.iter_mut().zip(&self.phi_tot64) {
            *o = v as f32;
        }
    }

    /// Ascending-owner-order totals merge — [`GlobalState`]'s identical
    /// f64 op sequence (Contract 5's totals interchangeability).
    fn merge_owner_totals(&mut self, tot_delta: &[f64]) {
        let k = self.k;
        debug_assert_eq!(tot_delta.len() % (k + 1), 0);
        for td in tot_delta.chunks_exact(k + 1) {
            for (t, slot) in self.phi_tot64.iter_mut().enumerate() {
                *slot += td[t];
            }
            self.r_total += td[k];
        }
        self.render_tot32();
    }

    /// End-of-batch accumulator fold, sharded: each owner folds every
    /// worker's dense Δφ̂ over its slice —
    /// `acc[j] ← acc[j] + Σ_n Δφ̂_n[base + j]`, the element-local left
    /// fold [`reduce_chunked`] performs — writing both the accumulator
    /// slice and the φ̂_eff slice (the replicated path's fold + copy-back,
    /// fused). Totals are left stale, matching the replicated path: the
    /// state is rebuilt fresh at the next batch.
    pub fn fold_batch(
        &mut self,
        cluster: &Cluster,
        phi_acc_parts: &mut [Vec<f32>],
        dphi_parts: &[&[f32]],
    ) {
        let os = self.os;
        assert_eq!(phi_acc_parts.len(), os.owners());
        let mut tasks: Vec<ShardAccTask<'_>> = Vec::with_capacity(os.owners());
        for (n, (phi, acc)) in self
            .phi_slices
            .iter_mut()
            .zip(phi_acc_parts.iter_mut())
            .enumerate()
        {
            tasks.push(ShardAccTask { base: os.range(n).start, phi, acc });
        }
        cluster.run_on_owner_slices(&mut tasks, |_n, t| {
            for j in 0..t.acc.len() {
                let i = t.base + j;
                let mut v = t.acc[j];
                for dp in dphi_parts {
                    v += dp[i];
                }
                t.phi[j] = v;
                t.acc[j] = v;
            }
        });
    }
}

/// Dense reduce-scatter in sharded storage mode: identical per-element
/// arithmetic to [`dense_owner_step`] (seed φ̂_acc, left fold in worker
/// order, fused Δφ̂/r pass), but each owner reads its φ̂_acc slice and
/// writes its *stored* slices — no dense replica anywhere.
fn sharded_dense_step<S: ReduceSource + Send>(
    cluster: &Cluster,
    phi_acc_parts: &[Vec<f32>],
    workers: &[Mutex<S>],
    state: &mut ShardedState,
) -> usize {
    let os = state.os;
    let guards: Vec<_> = workers.iter().map(|m| m.lock().unwrap()).collect();
    let parts: Vec<(&[f32], &[f32])> = guards.iter().map(|g| g.dense_parts()).collect();
    let mut tasks: Vec<ShardDenseTask<'_>> = Vec::with_capacity(os.owners());
    for (n, ((phi, r), acc)) in state
        .phi_slices
        .iter_mut()
        .zip(state.r_slices.iter_mut())
        .zip(phi_acc_parts)
        .enumerate()
    {
        tasks.push(ShardDenseTask { base: os.range(n).start, acc, phi, r });
    }
    cluster.run_on_owner_slices(&mut tasks, |_n, t| {
        for (j, (po, ro)) in t.phi.iter_mut().zip(t.r.iter_mut()).enumerate() {
            let i = t.base + j;
            // the serial reference's left fold, worker order, both
            // matrices in one pass — dense_owner_step's exact body with
            // the seed read from the owner's acc slice
            let mut acc = t.acc[j];
            let mut racc = 0f32;
            for (dp, rp) in &parts {
                acc += dp[i];
                racc += rp[i];
            }
            *po = acc;
            *ro = racc;
        }
    });
    drop(tasks);
    drop(guards);
    state.recompute_totals();
    os.len
}

/// Subset reduce-scatter in sharded storage mode: same parallel gather
/// into the reused [`SyncScratch`] pool and same per-slot fold as
/// [`subset_owner_step`], with the seed read from the owner's φ̂_acc
/// slice and the scatter landing in the owner's stored slices.
fn sharded_subset_step<S: ReduceSource + Send>(
    cluster: &Cluster,
    indices: &[u32],
    phi_acc_parts: &[Vec<f32>],
    workers: &[Mutex<S>],
    state: &mut ShardedState,
    scratch: &mut SyncScratch,
) -> usize {
    let nw = workers.len();
    let k = state.k;
    let os = state.os;
    scratch.gather.resize_with(nw, GatherBuf::default);
    cluster.run_on_owner_slices(&mut scratch.gather[..nw], |n, buf| {
        workers[n].lock().unwrap().export_selected_into(indices, buf);
    });
    scratch.group_by_owner(indices, &os);
    scratch.tot_delta.clear();
    scratch.tot_delta.resize(os.owners() * (k + 1), 0.0);
    let bufs = &scratch.gather;
    let owner_off = &scratch.owner_off;
    let owner_slots = &scratch.owner_slots;
    let mut tasks: Vec<ShardFoldTask<'_>> = Vec::with_capacity(os.owners());
    {
        let mut td_rest = &mut scratch.tot_delta[..];
        for (n, ((phi, r), acc)) in state
            .phi_slices
            .iter_mut()
            .zip(state.r_slices.iter_mut())
            .zip(phi_acc_parts)
            .enumerate()
        {
            let slots = &owner_slots[owner_off[n] as usize..owner_off[n + 1] as usize];
            let (td, rest) = td_rest.split_at_mut(k + 1);
            td_rest = rest;
            tasks.push(ShardFoldTask { base: os.range(n).start, acc, phi, r, slots, td });
        }
    }
    cluster.run_on_owner_slices(&mut tasks, |_n, t| {
        for &s in t.slots {
            let s = s as usize;
            let i = indices[s] as usize;
            let j = i - t.base;
            // subset_owner_step's exact per-slot body, seed from the
            // owner's acc slice
            let mut dsum = 0f32;
            let mut rsum = 0f32;
            for b in bufs {
                dsum += b.dphi[s];
                rsum += b.r[s];
            }
            let new_phi = t.acc[j] + dsum;
            t.td[i % k] += new_phi as f64 - t.phi[j] as f64;
            t.phi[j] = new_phi;
            t.td[k] += rsum as f64 - t.r[j] as f64;
            t.r[j] = rsum;
        }
    });
    drop(tasks);
    state.merge_owner_totals(&scratch.tot_delta);
    indices.len()
}

/// One full synchronization in **sharded storage mode**: the same
/// owner-sliced reduce-scatter as [`allreduce_step`], folding into the
/// per-owner *stored* slices of [`ShardedState`] instead of a dense
/// replica. Returns the number of (word, topic) pairs reduced; the
/// caller charges the reduce and the (working-set) allgather halves
/// separately via `Ledger::record_sync_split`.
///
/// Bitwise contract: with `phi_acc_parts` the row-aligned sharding of
/// the replicated path's `phi_acc`, `concat(state.phi_slices)` /
/// `concat(state.r_slices)` equal [`GlobalState`]'s `phi_eff` /
/// `r_global` after [`allreduce_step`] on the same inputs, totals
/// bitwise included, at any thread budget.
pub fn allreduce_step_sharded<S: ReduceSource + Send>(
    cluster: &Cluster,
    plan: &ReducePlan,
    phi_acc_parts: &[Vec<f32>],
    workers: &[Mutex<S>],
    state: &mut ShardedState,
    scratch: &mut SyncScratch,
) -> usize {
    assert_eq!(
        workers.len(),
        cluster.workers(),
        "one shard per logical worker"
    );
    assert_eq!(workers.len(), state.os.owners(), "one owner slice per worker");
    match plan {
        ReducePlan::Dense { len } => {
            debug_assert_eq!(*len, state.os.len);
            sharded_dense_step(cluster, phi_acc_parts, workers, state)
        }
        ReducePlan::Subset { indices } => {
            sharded_subset_step(cluster, indices, phi_acc_parts, workers, state, scratch)
        }
    }
}

/// [`allreduce_step`] with the Contract 6 fault-injection hook *inside*
/// the collective's boundary: the reduce-scatter half has run (the
/// owners folded their slices into `state` — the working state is
/// mid-sync) when the plan is consulted, so a tripped
/// [`SyncPhase::MidReduce`] kill leaves the batch state unusable and
/// recovery must replay the batch from the last checkpoint. The
/// arithmetic is the unfaulted step's, bitwise — the hook only decides
/// whether the result is allowed to reach the coordinator.
#[allow(clippy::too_many_arguments)]
pub fn allreduce_step_injected<S: ReduceSource + Send>(
    cluster: &Cluster,
    plan: &ReducePlan,
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    state: &mut GlobalState,
    scratch: &mut SyncScratch,
    faults: &FaultPlan,
    batch: usize,
    iter: usize,
) -> Result<usize, FaultEvent> {
    let pairs = allreduce_step(cluster, plan, phi_acc, workers, state, scratch);
    faults.trip(batch, iter, SyncPhase::MidReduce)?;
    Ok(pairs)
}

/// [`allreduce_step_overlap`] with the mid-reduce fault hook — see
/// [`allreduce_step_injected`].
#[allow(clippy::too_many_arguments)]
pub fn allreduce_step_overlap_injected<S: ReduceSource + Send>(
    cluster: &Cluster,
    plan: &ReducePlan,
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    state: &mut GlobalState,
    scratch: &mut SyncScratch,
    faults: &FaultPlan,
    batch: usize,
    iter: usize,
) -> Result<usize, FaultEvent> {
    let pairs = allreduce_step_overlap(cluster, plan, phi_acc, workers, state, scratch);
    faults.trip(batch, iter, SyncPhase::MidReduce)?;
    Ok(pairs)
}

/// [`allreduce_step_sharded`] with the mid-reduce fault hook — see
/// [`allreduce_step_injected`]. In sharded storage a mid-reduce kill is
/// the interesting case: the owner slices (the *persistent* φ̂ working
/// state) are partially synchronized when the worker dies, and only the
/// checkpoint's copy is trustworthy.
#[allow(clippy::too_many_arguments)]
pub fn allreduce_step_sharded_injected<S: ReduceSource + Send>(
    cluster: &Cluster,
    plan: &ReducePlan,
    phi_acc_parts: &[Vec<f32>],
    workers: &[Mutex<S>],
    state: &mut ShardedState,
    scratch: &mut SyncScratch,
    faults: &FaultPlan,
    batch: usize,
    iter: usize,
) -> Result<usize, FaultEvent> {
    let pairs =
        allreduce_step_sharded(cluster, plan, phi_acc_parts, workers, state, scratch);
    faults.trip(batch, iter, SyncPhase::MidReduce)?;
    Ok(pairs)
}

/// Chunk-parallel element-wise sum on the cluster's OS threads:
/// `out[i] = seed[i] + Σ_n parts[n][i]` (seed = 0 when `None`). Each
/// element's accumulation chain is the same left fold the serial loop
/// performs, so the result is bitwise independent of the chunking. Used
/// by the coordinator's end-of-batch fold and the leader-pool baseline.
pub fn reduce_chunked(
    cluster: &Cluster,
    seed: Option<&[f32]>,
    parts: &[&[f32]],
    out: &mut [f32],
) {
    debug_assert!(parts.iter().all(|p| p.len() == out.len()));
    if let Some(s) = seed {
        debug_assert_eq!(s.len(), out.len());
    }
    cluster.run_on_chunks(out, |start, chunk| {
        match seed {
            Some(s) => chunk.copy_from_slice(&s[start..start + chunk.len()]),
            None => chunk.fill(0.0),
        }
        for p in parts {
            for (o, &v) in chunk.iter_mut().zip(&p[start..start + chunk.len()]) {
                *o += v;
            }
        }
    });
}

/// The pre-refactor serial leader reduction, kept verbatim (modulo
/// naming) as the oracle for the equivalence tests: single-threaded,
/// f32 incremental totals and all.
#[derive(Clone, Debug)]
pub struct SerialState {
    pub phi_eff: Vec<f32>,
    pub r_global: Vec<f32>,
    pub phi_tot: Vec<f32>,
    pub r_total: f64,
}

impl SerialState {
    pub fn new(phi_acc: &[f32], k: usize) -> SerialState {
        let mut phi_tot = vec![0f32; k];
        for row in phi_acc.chunks_exact(k) {
            for (t, &v) in row.iter().enumerate() {
                phi_tot[t] += v;
            }
        }
        SerialState {
            phi_eff: phi_acc.to_vec(),
            r_global: vec![0.0; phi_acc.len()],
            phi_tot,
            r_total: 0.0,
        }
    }
}

/// Serial reference synchronization — the old coordinator leader loop.
pub fn serial_reference_step<S: ReduceSource + Send>(
    plan: &ReducePlan,
    k: usize,
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    st: &mut SerialState,
) {
    let guards: Vec<_> = workers.iter().map(|m| m.lock().unwrap()).collect();
    match plan {
        ReducePlan::Dense { .. } => {
            st.phi_eff.copy_from_slice(phi_acc);
            st.r_global.fill(0.0);
            for g in &guards {
                let (dphi, r) = g.dense_parts();
                for i in 0..st.phi_eff.len() {
                    st.phi_eff[i] += dphi[i];
                    st.r_global[i] += r[i];
                }
            }
            st.phi_tot.fill(0.0);
            for row in st.phi_eff.chunks_exact(k) {
                for (t, &v) in row.iter().enumerate() {
                    st.phi_tot[t] += v;
                }
            }
            st.r_total = st.r_global.iter().map(|&v| v as f64).sum();
        }
        ReducePlan::Subset { indices } => {
            for &ix in *indices {
                let i = ix as usize;
                let mut dphi_sum = 0f32;
                let mut r_sum = 0f32;
                for g in &guards {
                    let (dphi, r) = g.dense_parts();
                    dphi_sum += dphi[i];
                    r_sum += r[i];
                }
                let new_phi = phi_acc[i] + dphi_sum;
                st.phi_tot[i % k] += new_phi - st.phi_eff[i];
                st.phi_eff[i] = new_phi;
                st.r_total += r_sum as f64 - st.r_global[i] as f64;
                st.r_global[i] = r_sum;
            }
        }
    }
}

/// Element-wise serial sum of worker partial vectors into `global` — the
/// single-threaded baseline the microbench compares [`reduce_chunked`]
/// against (absorbed from `comm::cluster`).
pub fn reduce_sum_into(global: &mut [f32], partials: &[Vec<f32>]) {
    for p in partials {
        debug_assert_eq!(p.len(), global.len());
        for (g, &v) in global.iter_mut().zip(p) {
            *g += v;
        }
    }
}

/// Sparse serial variant: sums plan-order sub-vectors into `global` at
/// the listed flat indices (the power-subset synchronization of §3.1).
/// Indices must be in-bounds; `partials[n][slot]` pairs with
/// `indices[slot]`.
pub fn reduce_sum_subset_into(
    global: &mut [f32],
    indices: &[u32],
    partials: &[Vec<f32>],
) {
    for (slot, &ix) in indices.iter().enumerate() {
        let mut acc = 0f32;
        for p in partials {
            acc += p[slot];
        }
        global[ix as usize] += acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Cluster;
    use crate::util::rng::Rng;

    struct VecSource {
        dphi: Vec<f32>,
        r: Vec<f32>,
    }

    impl ReduceSource for VecSource {
        fn dense_parts(&self) -> (&[f32], &[f32]) {
            (&self.dphi, &self.r)
        }
    }

    fn random_workers(n: usize, len: usize, rng: &mut Rng) -> Vec<Mutex<VecSource>> {
        (0..n)
            .map(|_| {
                Mutex::new(VecSource {
                    dphi: (0..len).map(|_| rng.f32() * 2.0 - 0.5).collect(),
                    r: (0..len).map(|_| rng.f32()).collect(),
                })
            })
            .collect()
    }

    #[test]
    fn owner_slices_partition_exactly() {
        for &(len, owners) in &[(1usize, 1usize), (10, 3), (100, 7), (5, 8), (8192, 4)] {
            let s = OwnerSlices::new(len, owners);
            let mut covered = 0usize;
            for n in 0..owners {
                let rg = s.range(n);
                assert_eq!(rg.start, covered, "len={len} owners={owners} n={n}");
                covered = rg.end;
                for i in rg {
                    assert_eq!(s.owner_of(i), n, "len={len} owners={owners} i={i}");
                }
            }
            assert_eq!(covered, len);
        }
    }

    #[test]
    fn row_aligned_slices_never_split_a_row() {
        for &(w, k, owners) in &[
            (1usize, 1usize, 1usize),
            (40, 8, 3),
            (100, 7, 7),
            (5, 6, 8),
            (2000, 50, 8),
            (997, 3, 5),
        ] {
            let len = w * k;
            let s = OwnerSlices::row_aligned(len, k, owners);
            let mut covered = 0usize;
            for n in 0..owners {
                let rg = s.range(n);
                assert_eq!(rg.start, covered, "w={w} k={k} owners={owners} n={n}");
                assert_eq!(rg.start % k, 0, "slice start off row boundary");
                assert!(rg.len() % k == 0, "slice holds partial rows");
                covered = rg.end;
                for i in rg {
                    assert_eq!(s.owner_of(i), n, "w={w} k={k} owners={owners} i={i}");
                }
            }
            assert_eq!(covered, len);
            // all of a word's topics land on one owner
            for wi in 0..w {
                let o = s.owner_of(wi * k);
                for t in 0..k {
                    assert_eq!(s.owner_of(wi * k + t), o, "row {wi} straddles owners");
                }
            }
            // row count per slice is the index-count-derived ceil split
            assert_eq!(s.per() % k, 0);
            assert_eq!(s.per() / k, w.div_ceil(owners).max(1));
        }
    }

    #[test]
    fn reduce_sum_matches_sequential() {
        let partials = vec![vec![1.0f32, 2.0, 3.0], vec![10.0, 20.0, 30.0]];
        let mut g = vec![0.5f32, 0.5, 0.5];
        reduce_sum_into(&mut g, &partials);
        assert_eq!(g, vec![11.5, 22.5, 33.5]);
    }

    #[test]
    fn reduce_subset_touches_only_indices() {
        // global has 6 slots; sync only flat indices [1, 4]
        let mut g = vec![0f32; 6];
        let partials = vec![vec![5.0f32, 7.0], vec![1.0, 2.0]];
        reduce_sum_subset_into(&mut g, &[1, 4], &partials);
        assert_eq!(g, vec![0.0, 6.0, 0.0, 0.0, 9.0, 0.0]);
    }

    #[test]
    fn chunked_reduce_bitwise_equals_serial() {
        let mut rng = Rng::new(3);
        // len chosen to force multiple chunks on any multi-core host
        let len = (1 << 13) * 5 + 331;
        let partials: Vec<Vec<f32>> =
            (0..7).map(|_| (0..len).map(|_| rng.f32() * 3.0 - 1.0).collect()).collect();
        let parts: Vec<&[f32]> = partials.iter().map(|p| p.as_slice()).collect();
        let seed: Vec<f32> = (0..len).map(|_| rng.f32()).collect();

        let mut serial = seed.clone();
        reduce_sum_into(&mut serial, &partials);

        let cluster = Cluster::new(8, 0);
        let mut par = vec![0f32; len];
        reduce_chunked(&cluster, Some(&seed), &parts, &mut par);
        assert_eq!(par, serial);

        // seedless variant
        let mut serial0 = vec![0f32; len];
        reduce_sum_into(&mut serial0, &partials);
        reduce_chunked(&cluster, None, &parts, &mut par);
        assert_eq!(par, serial0);
    }

    #[test]
    fn dense_step_matches_serial_reference() {
        let (w, k) = (40, 8);
        let mut rng = Rng::new(5);
        let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32() * 4.0).collect();
        let workers = random_workers(3, w * k, &mut rng);
        let cluster = Cluster::new(3, 0);

        let mut own = GlobalState::new(&phi_acc, k);
        let mut pipe = GlobalState::new(&phi_acc, k);
        let mut pool = GlobalState::new(&phi_acc, k);
        let mut ser = SerialState::new(&phi_acc, k);
        let mut scr_own = SyncScratch::default();
        let mut scr_pipe = SyncScratch::default();
        let plan = ReducePlan::Dense { len: w * k };
        let pairs = allreduce_step(&cluster, &plan, &phi_acc, &workers, &mut own, &mut scr_own);
        allreduce_step_overlap(&cluster, &plan, &phi_acc, &workers, &mut pipe, &mut scr_pipe);
        allreduce_step_pool(&cluster, &plan, &phi_acc, &workers, &mut pool);
        serial_reference_step(&plan, k, &phi_acc, &workers, &mut ser);
        assert_eq!(pairs, w * k);
        assert_eq!(own.phi_eff, ser.phi_eff);
        assert_eq!(own.r_global, ser.r_global);
        assert_eq!(pipe.phi_eff, ser.phi_eff);
        assert_eq!(pipe.r_global, ser.r_global);
        assert_eq!(pool.phi_eff, ser.phi_eff);
        assert_eq!(pool.r_global, ser.r_global);
        // fused and pipelined agree on the f64 totals bitwise
        assert_eq!(own.phi_tot(), pipe.phi_tot());
        assert_eq!(own.r_total().to_bits(), pipe.r_total().to_bits());
    }

    #[test]
    fn subset_step_matches_serial_reference() {
        let (w, k) = (50, 6);
        let mut rng = Rng::new(6);
        let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32() * 4.0).collect();
        let workers = random_workers(4, w * k, &mut rng);
        let cluster = Cluster::new(4, 0);

        let mut own = GlobalState::new(&phi_acc, k);
        let mut pipe = GlobalState::new(&phi_acc, k);
        let mut rounds = GlobalState::new(&phi_acc, k);
        let mut pool = GlobalState::new(&phi_acc, k);
        let mut ser = SerialState::new(&phi_acc, k);
        let mut scr_own = SyncScratch::default();
        let mut scr_pipe = SyncScratch::default();
        let mut scr_rounds = SyncScratch::default();
        for round in 0..5 {
            // a fresh random subset each round, deliberately unsorted
            let mut indices: Vec<u32> =
                (0..(w * k) as u32).filter(|_| rng.f32() < 0.2).collect();
            rng.shuffle(&mut indices);
            if indices.is_empty() {
                indices.push(rng.below(w * k) as u32);
            }
            let plan = ReducePlan::Subset { indices: &indices };
            let pairs =
                allreduce_step(&cluster, &plan, &phi_acc, &workers, &mut own, &mut scr_own);
            allreduce_step_overlap(
                &cluster, &plan, &phi_acc, &workers, &mut pipe, &mut scr_pipe,
            );
            allreduce_step_overlap_rounds(
                &cluster, &plan, &phi_acc, &workers, &mut rounds, &mut scr_rounds,
            );
            allreduce_step_pool(&cluster, &plan, &phi_acc, &workers, &mut pool);
            serial_reference_step(&plan, k, &phi_acc, &workers, &mut ser);
            assert_eq!(pairs, indices.len());
            assert_eq!(own.phi_eff, ser.phi_eff, "round {round}");
            assert_eq!(own.r_global, ser.r_global, "round {round}");
            assert_eq!(pipe.phi_eff, ser.phi_eff, "sliced round {round}");
            assert_eq!(pipe.r_global, ser.r_global, "sliced round {round}");
            assert_eq!(rounds.phi_eff, ser.phi_eff, "rounds round {round}");
            assert_eq!(rounds.r_global, ser.r_global, "rounds round {round}");
            assert_eq!(pool.phi_eff, ser.phi_eff, "pool round {round}");
            assert_eq!(pool.r_global, ser.r_global, "pool round {round}");
            // fused vs both pipelines: totals bitwise (the coordinator's
            // overlap-equivalence contract hinges on this)
            assert_eq!(own.phi_tot(), pipe.phi_tot(), "round {round}");
            assert_eq!(own.r_total().to_bits(), pipe.r_total().to_bits(), "round {round}");
            assert_eq!(own.phi_tot(), rounds.phi_tot(), "round {round}");
            assert_eq!(own.r_total().to_bits(), rounds.r_total().to_bits(), "round {round}");
            // mutate worker partials between rounds
            for m in &workers {
                let mut g = m.lock().unwrap();
                for v in g.dphi.iter_mut() {
                    *v += rng.f32() - 0.5;
                }
                for v in g.r.iter_mut() {
                    *v = rng.f32();
                }
            }
        }
    }

    /// Shard a dense vector by the given owner partition.
    fn shard_vec(dense: &[f32], os: &OwnerSlices) -> Vec<Vec<f32>> {
        (0..os.owners()).map(|n| dense[os.range(n)].to_vec()).collect()
    }

    #[test]
    fn sharded_steps_bitwise_equal_replicated_oracle() {
        let (w, k) = (50, 6);
        let mut rng = Rng::new(21);
        let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32() * 4.0).collect();
        let nw = 4;
        let workers = random_workers(nw, w * k, &mut rng);
        let cluster = Cluster::new(nw, 0);
        let os = OwnerSlices::row_aligned(w * k, k, nw);
        let acc_parts = shard_vec(&phi_acc, &os);

        let mut rep = GlobalState::new(&phi_acc, k);
        let mut shd = ShardedState::new(&acc_parts, k, os);
        let mut scr_rep = SyncScratch::default();
        let mut scr_shd = SyncScratch::default();

        // fresh-state totals agree bitwise
        assert_eq!(rep.phi_tot(), shd.phi_tot());
        assert_eq!(rep.r_total().to_bits(), shd.r_total().to_bits());

        // dense sync
        let plan = ReducePlan::Dense { len: w * k };
        let p1 = allreduce_step(&cluster, &plan, &phi_acc, &workers, &mut rep, &mut scr_rep);
        let p2 = allreduce_step_sharded(
            &cluster, &plan, &acc_parts, &workers, &mut shd, &mut scr_shd,
        );
        assert_eq!(p1, p2);
        assert_eq!(shd.render_dense(), rep.phi_eff);
        assert_eq!(rep.phi_tot(), shd.phi_tot());
        assert_eq!(rep.r_total().to_bits(), shd.r_total().to_bits());

        // subset rounds with mutating worker partials
        for round in 0..5 {
            let mut indices: Vec<u32> =
                (0..(w * k) as u32).filter(|_| rng.f32() < 0.2).collect();
            rng.shuffle(&mut indices);
            if indices.is_empty() {
                indices.push(rng.below(w * k) as u32);
            }
            let plan = ReducePlan::Subset { indices: &indices };
            allreduce_step(&cluster, &plan, &phi_acc, &workers, &mut rep, &mut scr_rep);
            allreduce_step_sharded(
                &cluster, &plan, &acc_parts, &workers, &mut shd, &mut scr_shd,
            );
            assert_eq!(shd.render_dense(), rep.phi_eff, "round {round}");
            let r_dense: Vec<f32> = shd.r_parts().concat();
            assert_eq!(r_dense, rep.r_global, "round {round}");
            assert_eq!(rep.phi_tot(), shd.phi_tot(), "round {round}");
            assert_eq!(
                rep.r_total().to_bits(),
                shd.r_total().to_bits(),
                "round {round}"
            );
            for m in &workers {
                let mut g = m.lock().unwrap();
                for v in g.dphi.iter_mut() {
                    *v += rng.f32() - 0.5;
                }
                for v in g.r.iter_mut() {
                    *v = rng.f32();
                }
            }
        }

        // per-worker resident bytes: one slice of each matrix, not W·K
        let full = 2 * 4 * w * k;
        assert_eq!(shd.resident_bytes_per_worker(), 2 * 4 * os.per());
        assert!(shd.resident_bytes_per_worker() < full);
    }

    #[test]
    fn sharded_fold_batch_matches_reduce_chunked() {
        let (w, k, nw) = (37, 5, 3);
        let mut rng = Rng::new(22);
        let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32() * 2.0).collect();
        let dphi: Vec<Vec<f32>> = (0..nw)
            .map(|_| (0..w * k).map(|_| rng.f32() - 0.3).collect())
            .collect();
        let dphi_parts: Vec<&[f32]> = dphi.iter().map(|p| p.as_slice()).collect();
        let cluster = Cluster::new(nw, 0);
        let os = OwnerSlices::row_aligned(w * k, k, nw);

        // replicated oracle: fold into phi_eff, copy back to acc
        let mut rep_acc = phi_acc.clone();
        let mut folded = vec![0f32; w * k];
        reduce_chunked(&cluster, Some(&rep_acc), &dphi_parts, &mut folded);
        rep_acc.copy_from_slice(&folded);

        // sharded path
        let mut acc_parts = shard_vec(&phi_acc, &os);
        let mut shd = ShardedState::new(&acc_parts, k, os);
        shd.fold_batch(&cluster, &mut acc_parts, &dphi_parts);
        assert_eq!(acc_parts.concat(), rep_acc);
        assert_eq!(shd.render_dense(), rep_acc);
    }

    #[test]
    fn single_worker_owner_step_degenerates() {
        // N = 1: one owner slice covering everything, no pipeline rounds
        let (w, k) = (30, 4);
        let mut rng = Rng::new(8);
        let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32()).collect();
        let workers = random_workers(1, w * k, &mut rng);
        let cluster = Cluster::new(1, 0);
        let mut own = GlobalState::new(&phi_acc, k);
        let mut pipe = GlobalState::new(&phi_acc, k);
        let mut rounds = GlobalState::new(&phi_acc, k);
        let mut ser = SerialState::new(&phi_acc, k);
        let mut scr = SyncScratch::default();
        let mut scr2 = SyncScratch::default();
        let mut scr3 = SyncScratch::default();
        let indices: Vec<u32> = (0..(w * k) as u32).step_by(3).collect();
        let plan = ReducePlan::Subset { indices: &indices };
        allreduce_step(&cluster, &plan, &phi_acc, &workers, &mut own, &mut scr);
        allreduce_step_overlap(&cluster, &plan, &phi_acc, &workers, &mut pipe, &mut scr2);
        allreduce_step_overlap_rounds(
            &cluster, &plan, &phi_acc, &workers, &mut rounds, &mut scr3,
        );
        serial_reference_step(&plan, k, &phi_acc, &workers, &mut ser);
        assert_eq!(own.phi_eff, ser.phi_eff);
        assert_eq!(pipe.phi_eff, ser.phi_eff);
        assert_eq!(rounds.phi_eff, ser.phi_eff);
        assert_eq!(own.r_global, ser.r_global);
        assert_eq!(pipe.r_global, ser.r_global);
        assert_eq!(rounds.r_global, ser.r_global);
    }

    #[test]
    fn export_selected_into_reuses_buffer() {
        let src = VecSource {
            dphi: vec![10.0, 11.0, 12.0, 13.0],
            r: vec![0.1, 0.2, 0.3, 0.4],
        };
        let mut buf = GatherBuf::default();
        src.export_selected_into(&[3, 0, 2], &mut buf);
        assert_eq!(buf.dphi, vec![13.0, 10.0, 12.0]);
        assert_eq!(buf.r, vec![0.4, 0.1, 0.3]);
        // second export into the same buffer replaces, never appends
        src.export_selected_into(&[1], &mut buf);
        assert_eq!(buf.dphi, vec![11.0]);
        assert_eq!(buf.r, vec![0.2]);
        // the allocating wrapper agrees
        let owned = src.export_selected(&[3, 0, 2]);
        assert_eq!(owned.dphi, vec![13.0, 10.0, 12.0]);
        assert_eq!(owned.r, vec![0.4, 0.1, 0.3]);
    }

    #[test]
    fn group_by_owner_covers_each_slot_once() {
        let mut rng = Rng::new(13);
        let len = 997;
        let slices = OwnerSlices::new(len, 5);
        let mut indices: Vec<u32> =
            (0..len as u32).filter(|_| rng.f32() < 0.3).collect();
        rng.shuffle(&mut indices);
        let mut scr = SyncScratch::default();
        scr.group_by_owner(&indices, &slices);
        assert_eq!(scr.owner_off.len(), 6);
        assert_eq!(*scr.owner_off.last().unwrap() as usize, indices.len());
        let mut seen = vec![false; indices.len()];
        for n in 0..5 {
            let lo = scr.owner_off[n] as usize;
            let hi = scr.owner_off[n + 1] as usize;
            let mut prev_slot = None;
            for &s in &scr.owner_slots[lo..hi] {
                let s = s as usize;
                assert!(!seen[s], "slot {s} grouped twice");
                seen[s] = true;
                assert_eq!(slices.owner_of(indices[s] as usize), n);
                // plan order preserved within each owner
                if let Some(p) = prev_slot {
                    assert!(s > p, "owner {n}: slot order violated");
                }
                prev_slot = Some(s);
            }
        }
        assert!(seen.iter().all(|&x| x));
    }
}
