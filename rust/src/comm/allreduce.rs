//! The parallel sparse allreduce subsystem — the leader-side realization
//! of the paper's synchronization step (Fig. 4 lines 9–10 / 23–24,
//! Eqs. 6, 9, 15).
//!
//! # Gather-buffer layout
//!
//! Every worker contributes two flat `f32` buffers per synchronization —
//! one for Δφ̂ and one for r — sharing a single index order, the *plan
//! order*:
//!
//! * **Dense plan** (t = 1 full sync): plan order is row-major `w·K + k`
//!   over the whole `W × K` matrix. Workers export nothing; the
//!   reduction borrows their Δφ̂ / r matrices in place (a real deployment
//!   would ship the matrix verbatim, so there is no packing step to
//!   model).
//! * **Subset plan** (power iterations): plan order is
//!   `PowerSet::flat_indices` order — selection order, words by
//!   descending residual. Each worker packs its own [`GatherBuf`]
//!   ([`ReduceSource::export_selected`]) in parallel on the cluster.
//!
//! The reduction itself runs *in parallel over contiguous index chunks*
//! on the [`Cluster`] thread pool. Because every output element's
//! accumulation chain (seed, then worker 0, worker 1, …) is independent
//! of the chunking, the result is **bitwise identical** to the serial
//! leader loop it replaced — [`serial_reference_step`] keeps that loop
//! verbatim as the oracle the equivalence tests compare against.
//!
//! The scatter back into the replicated [`GlobalState`] accumulates the
//! φ̂ topic totals and the residual total in **f64**: the pre-refactor
//! coordinator updated them incrementally in f32, which drifts over the
//! hundreds of small power-subset scatters a long run performs.
//!
//! Simulated communication *time* is unchanged by any of this — it comes
//! from the byte-exact ledger and the network model's per-segment
//! (reduce-scatter + allgather) accounting; parallelizing the reduction
//! buys leader wall-clock, which `benches/microbench.rs` measures.

use std::sync::Mutex;

use crate::comm::Cluster;

/// One worker's contribution to a sparse allreduce: Δφ̂ and r values at
/// the plan's flat indices, in plan order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GatherBuf {
    pub dphi: Vec<f32>,
    pub r: Vec<f32>,
}

/// A worker-local source of partial matrices for the allreduce.
/// Implemented by `engine::bp::ShardBp`; test doubles implement only
/// [`ReduceSource::dense_parts`].
pub trait ReduceSource {
    /// Borrow the dense per-worker partials (Δφ̂, r), both `W·K` long,
    /// row-major.
    fn dense_parts(&self) -> (&[f32], &[f32]);

    /// Pack the partials at `indices` (flat `w·K + k`, plan order) into a
    /// fresh gather buffer — the worker side of the sparse allreduce.
    fn export_selected(&self, indices: &[u32]) -> GatherBuf {
        let (dphi, r) = self.dense_parts();
        GatherBuf {
            dphi: indices.iter().map(|&i| dphi[i as usize]).collect(),
            r: indices.iter().map(|&i| r[i as usize]).collect(),
        }
    }
}

/// Which (word, topic) pairs a synchronization reduces.
#[derive(Clone, Copy, Debug)]
pub enum ReducePlan<'a> {
    /// every pair of the `W × K` matrices, row-major
    Dense { len: usize },
    /// the pairs at these flat indices, in this (plan) order
    Subset { indices: &'a [u32] },
}

impl ReducePlan<'_> {
    /// Number of (word, topic) pairs reduced — the per-processor payload
    /// element count of Eq. (6).
    pub fn pairs(&self) -> usize {
        match self {
            ReducePlan::Dense { len } => *len,
            ReducePlan::Subset { indices } => indices.len(),
        }
    }
}

/// The replicated state every processor holds after an allreduce:
/// effective φ̂ (= φ̂_acc + Σ_n Δφ̂_n on synchronized pairs), the
/// synchronized residual matrix, and their running totals.
///
/// The totals are f64-backed: dense syncs recompute them from scratch,
/// subset syncs accumulate exact f32→f64 deltas, so the drift of the old
/// incremental-f32 bookkeeping is gone (see `totals_drift`). The sweep
/// kernels consume the f32 render via [`GlobalState::phi_tot`].
#[derive(Clone, Debug)]
pub struct GlobalState {
    pub phi_eff: Vec<f32>,
    pub r_global: Vec<f32>,
    phi_tot64: Vec<f64>,
    phi_tot32: Vec<f32>,
    r_total: f64,
    k: usize,
}

impl GlobalState {
    /// Fresh per-batch state: φ_eff = φ̂_acc, no residuals yet.
    pub fn new(phi_acc: &[f32], k: usize) -> GlobalState {
        let mut s = GlobalState {
            phi_eff: phi_acc.to_vec(),
            r_global: vec![0.0; phi_acc.len()],
            phi_tot64: vec![0.0; k],
            phi_tot32: vec![0.0; k],
            r_total: 0.0,
            k,
        };
        s.recompute_totals();
        s
    }

    /// Topic totals φ̂_Σ(k) as the f32 view the sweep kernels read.
    pub fn phi_tot(&self) -> &[f32] {
        &self.phi_tot32
    }

    /// Total synchronized residual Σ r (line 26's convergence quantity).
    pub fn r_total(&self) -> f64 {
        self.r_total
    }

    /// Rebuild both totals from the matrices, in f64.
    pub fn recompute_totals(&mut self) {
        self.phi_tot64.fill(0.0);
        for row in self.phi_eff.chunks_exact(self.k) {
            for (t, &v) in row.iter().enumerate() {
                self.phi_tot64[t] += v as f64;
            }
        }
        self.r_total = self.r_global.iter().map(|&v| v as f64).sum();
        self.render_tot32();
    }

    fn render_tot32(&mut self) {
        for (o, &v) in self.phi_tot32.iter_mut().zip(&self.phi_tot64) {
            *o = v as f32;
        }
    }

    /// Drift diagnostics: (max |running − recomputed| over topic totals,
    /// |running − recomputed| residual total). Bounded by f64 rounding —
    /// the long-run drift test pins it near zero.
    pub fn totals_drift(&self) -> (f64, f64) {
        let mut fresh = vec![0f64; self.k];
        for row in self.phi_eff.chunks_exact(self.k) {
            for (t, &v) in row.iter().enumerate() {
                fresh[t] += v as f64;
            }
        }
        let phi_drift = fresh
            .iter()
            .zip(&self.phi_tot64)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        let r_fresh: f64 = self.r_global.iter().map(|&v| v as f64).sum();
        (phi_drift, (r_fresh - self.r_total).abs())
    }

    /// Apply reduced plan-order sub-vectors at `indices`: the scatter
    /// half of a subset allreduce. Matches the pre-refactor per-element
    /// arithmetic on `phi_eff`/`r_global` bitwise; totals move by exact
    /// f32→f64 deltas.
    fn scatter_subset(
        &mut self,
        indices: &[u32],
        phi_acc: &[f32],
        red_dphi: &[f32],
        red_r: &[f32],
    ) {
        let k = self.k;
        for ((&ix, &d), &r) in indices.iter().zip(red_dphi).zip(red_r) {
            let i = ix as usize;
            let new_phi = phi_acc[i] + d;
            self.phi_tot64[i % k] += new_phi as f64 - self.phi_eff[i] as f64;
            self.phi_eff[i] = new_phi;
            self.r_total += r as f64 - self.r_global[i] as f64;
            self.r_global[i] = r;
        }
        self.render_tot32();
    }
}

/// Chunk-parallel element-wise sum on the cluster's OS threads:
/// `out[i] = seed[i] + Σ_n parts[n][i]` (seed = 0 when `None`). Each
/// element's accumulation chain is the same left fold the serial loop
/// performs, so the result is bitwise independent of the chunking.
pub fn reduce_chunked(
    cluster: &Cluster,
    seed: Option<&[f32]>,
    parts: &[&[f32]],
    out: &mut [f32],
) {
    debug_assert!(parts.iter().all(|p| p.len() == out.len()));
    if let Some(s) = seed {
        debug_assert_eq!(s.len(), out.len());
    }
    cluster.run_on_chunks(out, |start, chunk| {
        match seed {
            Some(s) => chunk.copy_from_slice(&s[start..start + chunk.len()]),
            None => chunk.fill(0.0),
        }
        for p in parts {
            for (o, &v) in chunk.iter_mut().zip(&p[start..start + chunk.len()]) {
                *o += v;
            }
        }
    });
}

/// One full synchronization: gather worker partials per `plan`, reduce
/// them in parallel over index chunks, scatter into `state`. Returns the
/// number of (word, topic) pairs reduced; the caller charges
/// `2 · 4 · pairs` payload bytes (φ̂ and r) to the ledger.
///
/// Equivalent — bitwise, on `phi_eff`/`r_global` — to
/// [`serial_reference_step`] on the same inputs.
pub fn allreduce_step<S: ReduceSource + Send>(
    cluster: &Cluster,
    plan: &ReducePlan,
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    state: &mut GlobalState,
) -> usize {
    assert_eq!(
        workers.len(),
        cluster.workers(),
        "one shard per logical worker"
    );
    match plan {
        ReducePlan::Dense { len } => {
            debug_assert_eq!(*len, state.phi_eff.len());
            let guards: Vec<_> = workers.iter().map(|m| m.lock().unwrap()).collect();
            let dphi_parts: Vec<&[f32]> =
                guards.iter().map(|g| g.dense_parts().0).collect();
            let r_parts: Vec<&[f32]> =
                guards.iter().map(|g| g.dense_parts().1).collect();
            reduce_chunked(cluster, Some(phi_acc), &dphi_parts, &mut state.phi_eff);
            reduce_chunked(cluster, None, &r_parts, &mut state.r_global);
            drop(guards);
            state.recompute_totals();
            *len
        }
        ReducePlan::Subset { indices } => {
            // parallel gather: each worker packs its own plan-order buffer
            let (bufs, _) =
                cluster.run(|n| workers[n].lock().unwrap().export_selected(indices));
            let m = indices.len();
            let mut red_dphi = vec![0f32; m];
            let mut red_r = vec![0f32; m];
            let dphi_parts: Vec<&[f32]> = bufs.iter().map(|b| b.dphi.as_slice()).collect();
            let r_parts: Vec<&[f32]> = bufs.iter().map(|b| b.r.as_slice()).collect();
            reduce_chunked(cluster, None, &dphi_parts, &mut red_dphi);
            reduce_chunked(cluster, None, &r_parts, &mut red_r);
            state.scatter_subset(indices, phi_acc, &red_dphi, &red_r);
            m
        }
    }
}

/// The pre-refactor serial leader reduction, kept verbatim (modulo
/// naming) as the oracle for the equivalence tests: single-threaded,
/// f32 incremental totals and all.
#[derive(Clone, Debug)]
pub struct SerialState {
    pub phi_eff: Vec<f32>,
    pub r_global: Vec<f32>,
    pub phi_tot: Vec<f32>,
    pub r_total: f64,
}

impl SerialState {
    pub fn new(phi_acc: &[f32], k: usize) -> SerialState {
        let mut phi_tot = vec![0f32; k];
        for row in phi_acc.chunks_exact(k) {
            for (t, &v) in row.iter().enumerate() {
                phi_tot[t] += v;
            }
        }
        SerialState {
            phi_eff: phi_acc.to_vec(),
            r_global: vec![0.0; phi_acc.len()],
            phi_tot,
            r_total: 0.0,
        }
    }
}

/// Serial reference synchronization — the old coordinator leader loop.
pub fn serial_reference_step<S: ReduceSource + Send>(
    plan: &ReducePlan,
    k: usize,
    phi_acc: &[f32],
    workers: &[Mutex<S>],
    st: &mut SerialState,
) {
    let guards: Vec<_> = workers.iter().map(|m| m.lock().unwrap()).collect();
    match plan {
        ReducePlan::Dense { .. } => {
            st.phi_eff.copy_from_slice(phi_acc);
            st.r_global.fill(0.0);
            for g in &guards {
                let (dphi, r) = g.dense_parts();
                for i in 0..st.phi_eff.len() {
                    st.phi_eff[i] += dphi[i];
                    st.r_global[i] += r[i];
                }
            }
            st.phi_tot.fill(0.0);
            for row in st.phi_eff.chunks_exact(k) {
                for (t, &v) in row.iter().enumerate() {
                    st.phi_tot[t] += v;
                }
            }
            st.r_total = st.r_global.iter().map(|&v| v as f64).sum();
        }
        ReducePlan::Subset { indices } => {
            for &ix in *indices {
                let i = ix as usize;
                let mut dphi_sum = 0f32;
                let mut r_sum = 0f32;
                for g in &guards {
                    let (dphi, r) = g.dense_parts();
                    dphi_sum += dphi[i];
                    r_sum += r[i];
                }
                let new_phi = phi_acc[i] + dphi_sum;
                st.phi_tot[i % k] += new_phi - st.phi_eff[i];
                st.phi_eff[i] = new_phi;
                st.r_total += r_sum as f64 - st.r_global[i] as f64;
                st.r_global[i] = r_sum;
            }
        }
    }
}

/// Element-wise serial sum of worker partial vectors into `global` — the
/// single-threaded baseline the microbench compares [`reduce_chunked`]
/// against (absorbed from `comm::cluster`).
pub fn reduce_sum_into(global: &mut [f32], partials: &[Vec<f32>]) {
    for p in partials {
        debug_assert_eq!(p.len(), global.len());
        for (g, &v) in global.iter_mut().zip(p) {
            *g += v;
        }
    }
}

/// Sparse serial variant: sums plan-order sub-vectors into `global` at
/// the listed flat indices (the power-subset synchronization of §3.1).
/// Indices must be in-bounds; `partials[n][slot]` pairs with
/// `indices[slot]`.
pub fn reduce_sum_subset_into(
    global: &mut [f32],
    indices: &[u32],
    partials: &[Vec<f32>],
) {
    for (slot, &ix) in indices.iter().enumerate() {
        let mut acc = 0f32;
        for p in partials {
            acc += p[slot];
        }
        global[ix as usize] += acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Cluster;
    use crate::util::rng::Rng;

    struct VecSource {
        dphi: Vec<f32>,
        r: Vec<f32>,
    }

    impl ReduceSource for VecSource {
        fn dense_parts(&self) -> (&[f32], &[f32]) {
            (&self.dphi, &self.r)
        }
    }

    fn random_workers(n: usize, len: usize, rng: &mut Rng) -> Vec<Mutex<VecSource>> {
        (0..n)
            .map(|_| {
                Mutex::new(VecSource {
                    dphi: (0..len).map(|_| rng.f32() * 2.0 - 0.5).collect(),
                    r: (0..len).map(|_| rng.f32()).collect(),
                })
            })
            .collect()
    }

    #[test]
    fn reduce_sum_matches_sequential() {
        let partials = vec![vec![1.0f32, 2.0, 3.0], vec![10.0, 20.0, 30.0]];
        let mut g = vec![0.5f32, 0.5, 0.5];
        reduce_sum_into(&mut g, &partials);
        assert_eq!(g, vec![11.5, 22.5, 33.5]);
    }

    #[test]
    fn reduce_subset_touches_only_indices() {
        // global has 6 slots; sync only flat indices [1, 4]
        let mut g = vec![0f32; 6];
        let partials = vec![vec![5.0f32, 7.0], vec![1.0, 2.0]];
        reduce_sum_subset_into(&mut g, &[1, 4], &partials);
        assert_eq!(g, vec![0.0, 6.0, 0.0, 0.0, 9.0, 0.0]);
    }

    #[test]
    fn chunked_reduce_bitwise_equals_serial() {
        let mut rng = Rng::new(3);
        // len chosen to force multiple chunks on any multi-core host
        let len = (1 << 13) * 5 + 331;
        let partials: Vec<Vec<f32>> =
            (0..7).map(|_| (0..len).map(|_| rng.f32() * 3.0 - 1.0).collect()).collect();
        let parts: Vec<&[f32]> = partials.iter().map(|p| p.as_slice()).collect();
        let seed: Vec<f32> = (0..len).map(|_| rng.f32()).collect();

        let mut serial = seed.clone();
        reduce_sum_into(&mut serial, &partials);

        let cluster = Cluster::new(8, 0);
        let mut par = vec![0f32; len];
        reduce_chunked(&cluster, Some(&seed), &parts, &mut par);
        assert_eq!(par, serial);

        // seedless variant
        let mut serial0 = vec![0f32; len];
        reduce_sum_into(&mut serial0, &partials);
        reduce_chunked(&cluster, None, &parts, &mut par);
        assert_eq!(par, serial0);
    }

    #[test]
    fn dense_step_matches_serial_reference() {
        let (w, k) = (40, 8);
        let mut rng = Rng::new(5);
        let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32() * 4.0).collect();
        let workers = random_workers(3, w * k, &mut rng);
        let cluster = Cluster::new(3, 0);

        let mut par = GlobalState::new(&phi_acc, k);
        let mut ser = SerialState::new(&phi_acc, k);
        let plan = ReducePlan::Dense { len: w * k };
        let pairs = allreduce_step(&cluster, &plan, &phi_acc, &workers, &mut par);
        serial_reference_step(&plan, k, &phi_acc, &workers, &mut ser);
        assert_eq!(pairs, w * k);
        assert_eq!(par.phi_eff, ser.phi_eff);
        assert_eq!(par.r_global, ser.r_global);
    }

    #[test]
    fn subset_step_matches_serial_reference() {
        let (w, k) = (50, 6);
        let mut rng = Rng::new(6);
        let phi_acc: Vec<f32> = (0..w * k).map(|_| rng.f32() * 4.0).collect();
        let workers = random_workers(4, w * k, &mut rng);
        let cluster = Cluster::new(4, 0);

        let mut par = GlobalState::new(&phi_acc, k);
        let mut ser = SerialState::new(&phi_acc, k);
        for round in 0..5 {
            // a fresh random subset each round, deliberately unsorted
            let mut indices: Vec<u32> =
                (0..(w * k) as u32).filter(|_| rng.f32() < 0.2).collect();
            rng.shuffle(&mut indices);
            if indices.is_empty() {
                indices.push(rng.below(w * k) as u32);
            }
            let plan = ReducePlan::Subset { indices: &indices };
            let pairs = allreduce_step(&cluster, &plan, &phi_acc, &workers, &mut par);
            serial_reference_step(&plan, k, &phi_acc, &workers, &mut ser);
            assert_eq!(pairs, indices.len());
            assert_eq!(par.phi_eff, ser.phi_eff, "round {round}");
            assert_eq!(par.r_global, ser.r_global, "round {round}");
            // mutate worker partials between rounds
            for m in &workers {
                let mut g = m.lock().unwrap();
                for v in g.dphi.iter_mut() {
                    *v += rng.f32() - 0.5;
                }
                for v in g.r.iter_mut() {
                    *v = rng.f32();
                }
            }
        }
    }

    #[test]
    fn export_selected_default_packs_plan_order() {
        let src = VecSource {
            dphi: vec![10.0, 11.0, 12.0, 13.0],
            r: vec![0.1, 0.2, 0.3, 0.4],
        };
        let buf = src.export_selected(&[3, 0, 2]);
        assert_eq!(buf.dphi, vec![13.0, 10.0, 12.0]);
        assert_eq!(buf.r, vec![0.4, 0.1, 0.3]);
    }
}
