//! Best-effort core pinning for pool threads (`--pin-cores` /
//! `[run] pin_cores`).
//!
//! Pinning is a pure performance hint: the [`Cluster`](super::Cluster)
//! dispatches are deterministic by partition (module doc there), so
//! where a thread runs can never change results — only how often it
//! migrates between cores and re-warms its caches. Accordingly this
//! module **never fails**: where the OS refuses affinity (restricted
//! cgroups, non-Linux targets, seccomp), it logs one warning and the
//! pool keeps running with floating threads.
//!
//! Implementation: raw `sched_getaffinity`/`sched_setaffinity` FFI on
//! Linux (no crates; a 1024-bit CPU mask like glibc's `cpu_set_t`).
//! The process's allowed-CPU list is read once and cached; thread slot
//! `i` pins to `allowed[i % allowed.len()]`, so the mapping also works
//! inside containers whose cgroup exposes a sparse CPU subset.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

#[cfg(target_os = "linux")]
mod imp {
    /// 1024-bit mask = 16 × u64: the glibc `cpu_set_t` default width.
    const MASK_U64: usize = 16;

    extern "C" {
        fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }

    /// CPUs the process may run on, ascending; empty when unreadable.
    pub fn allowed_cpus() -> Vec<usize> {
        let mut mask = [0u64; MASK_U64];
        // SAFETY: pid 0 = calling thread; the mask buffer is MASK_U64*8
        // bytes, exactly the cpusetsize passed.
        let rc = unsafe { sched_getaffinity(0, MASK_U64 * 8, mask.as_mut_ptr()) };
        if rc != 0 {
            return Vec::new();
        }
        let mut cpus = Vec::new();
        for (word, &bits) in mask.iter().enumerate() {
            for bit in 0..64 {
                if bits & (1u64 << bit) != 0 {
                    cpus.push(word * 64 + bit);
                }
            }
        }
        cpus
    }

    /// Pin the calling thread to one CPU; false when the OS refuses.
    pub fn pin_to(cpu: usize) -> bool {
        if cpu >= MASK_U64 * 64 {
            return false;
        }
        let mut mask = [0u64; MASK_U64];
        mask[cpu / 64] = 1u64 << (cpu % 64);
        // SAFETY: as above; a single-bit mask of the right width.
        (unsafe { sched_setaffinity(0, MASK_U64 * 8, mask.as_ptr()) }) == 0
    }
}

#[cfg(not(target_os = "linux"))]
mod imp {
    /// Non-Linux: no affinity API wired up — pinning degrades to a no-op.
    pub fn allowed_cpus() -> Vec<usize> {
        Vec::new()
    }

    pub fn pin_to(_cpu: usize) -> bool {
        false
    }
}

static ALLOWED: OnceLock<Vec<usize>> = OnceLock::new();
static WARNED: AtomicBool = AtomicBool::new(false);

/// The process's allowed-CPU list (affinity mask at first call), cached.
pub fn allowed_cpus() -> &'static [usize] {
    ALLOWED.get_or_init(imp::allowed_cpus)
}

/// Pin the calling thread to the `slot`-th allowed CPU (round-robin over
/// the mask). Returns whether the pin took; on the first failure one
/// warning is logged (log, don't fail — satellite contract) and later
/// failures stay silent.
pub fn pin_current_thread(slot: usize) -> bool {
    let cpus = allowed_cpus();
    if cpus.is_empty() {
        warn_once("no readable CPU affinity mask on this platform");
        return false;
    }
    let ok = imp::pin_to(cpus[slot % cpus.len()]);
    if !ok {
        warn_once("sched_setaffinity refused");
    }
    ok
}

fn warn_once(why: &str) {
    if !WARNED.swap(true, Ordering::Relaxed) {
        eprintln!("pobp: core pinning unavailable ({why}); pool threads stay floating");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pinning must never panic and must report consistently with the
    /// visible mask: with allowed CPUs the Linux pin should take; with
    /// none it must return false (and only warn).
    #[test]
    fn pin_is_best_effort_everywhere() {
        let cpus = allowed_cpus();
        for slot in 0..4 {
            let ok = pin_current_thread(slot);
            if cpus.is_empty() {
                assert!(!ok);
            } else if cfg!(target_os = "linux") {
                assert!(ok, "pin to slot {slot} of {} allowed CPUs failed", cpus.len());
            }
        }
        // restore: leave the test thread free to float over the full mask
        if cfg!(target_os = "linux") && !cpus.is_empty() {
            for &c in cpus {
                // re-pinning to each allowed CPU keeps the thread valid;
                // the harness does not depend on a particular final CPU
                let _ = imp_pin(c);
            }
        }
    }

    fn imp_pin(cpu: usize) -> bool {
        super::imp::pin_to(cpu)
    }
}
