//! Network cost model (α–β model) for the simulated multi-processor
//! architecture.
//!
//! The paper's testbed: up to 1024 processors on 20 GB/s Infiniband. We do
//! not have that cluster, so communication *time* is derived from exact
//! byte counts (ledger.rs) through this model, while computation time is
//! measured for real per worker shard. The paper itself reasons the same
//! way: Eq. (5)/(6) express communication cost as matrix-elements moved
//! per synchronization, and §3.2.2 notes per-processor cost B grows with N
//! under bandwidth limits — captured here by the latency term of the
//! ring/tree allreduce.

/// α–β link model: time = α (latency) + bytes / β (bandwidth).
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// per-message latency, seconds
    pub latency_s: f64,
    /// link bandwidth, bytes/second
    pub bandwidth_bps: f64,
}

impl NetModel {
    /// The paper's interconnect: 20 GB/s Infiniband, ~2 µs MPI latency.
    pub fn infiniband_20gbps() -> NetModel {
        NetModel { latency_s: 2e-6, bandwidth_bps: 20e9 }
    }

    /// A slower 1 GbE model (used by ablation benches to show where the
    /// communication wall moves).
    pub fn gige() -> NetModel {
        NetModel { latency_s: 50e-6, bandwidth_bps: 125e6 }
    }

    /// Bandwidth scaled down by `factor` (latency unchanged). The benches
    /// run corpora ~100× smaller than the paper's, which would shift the
    /// allreduce from the paper's bandwidth-dominated regime into a
    /// latency-dominated one and distort every comm-time ratio; scaling
    /// the link by the payload ratio keeps per-sync times in the paper's
    /// regime (DESIGN.md §Substitutions).
    pub fn scaled_down(&self, factor: f64) -> NetModel {
        NetModel {
            latency_s: self.latency_s,
            bandwidth_bps: self.bandwidth_bps / factor.max(1.0),
        }
    }

    /// The paper's regime for a bench-scale (K, W): Infiniband with
    /// bandwidth scaled by the K·W payload ratio against the paper's
    /// K = 2000, W ≈ 7000 setting.
    ///
    /// IMPORTANT: pass the *reference* (K, W) of the whole experiment
    /// (e.g. the middle of a K sweep), not each run's own K — scaling by
    /// each run's payload would make every sync cost the same seconds and
    /// erase the K-dependence the paper's Figs. 10–11 measure.
    pub fn infiniband_for_scale(k_ref: usize, w_ref: usize) -> NetModel {
        let factor = (2000.0 * 7000.0) / (k_ref as f64 * w_ref as f64);
        Self::infiniband_20gbps().scaled_down(factor)
    }

    /// Point-to-point transfer time for `bytes`.
    pub fn p2p_secs(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Reduce-scatter half of the Rabenseifner allreduce: `log2(n)`
    /// halving steps, each processor ending with one reduced 1/n-slice,
    /// for `log2(n)` latency charges plus `bytes·(n−1)/n` through the
    /// link. For n = 1 the cost is zero.
    pub fn reduce_scatter_secs(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n as f64).log2().ceil() * self.latency_s
            + bytes as f64 * (n as f64 - 1.0) / n as f64 / self.bandwidth_bps
    }

    /// Allgather half of the Rabenseifner allreduce — doubling steps that
    /// redistribute the reduced slices, cost-symmetric to the
    /// reduce-scatter.
    pub fn allgather_secs(&self, bytes: usize, n: usize) -> f64 {
        self.reduce_scatter_secs(bytes, n)
    }

    /// Allreduce of a `bytes`-sized payload across `n` processors,
    /// Rabenseifner's reduce-scatter + allgather (what MPI uses for
    /// anything non-tiny): 2·log2(n) latency steps and 2·bytes·(n−1)/n
    /// per-processor wire traffic, the sum of the two segment costs
    /// above. The log-N latency term matters: the paper's POBP performs
    /// many *small* synchronizations, which a 2(n−1)-step ring model
    /// would penalize unrealistically at n = 256+. For n = 1 the cost is
    /// zero.
    pub fn allreduce_secs(&self, bytes: usize, n: usize) -> f64 {
        self.reduce_scatter_secs(bytes, n) + self.allgather_secs(bytes, n)
    }

    /// Iteration time when an allreduce of `bytes` across `n` — plus any
    /// `deferred_comm_secs` carried over from a deferred sync (the
    /// overlap-mode end-of-batch fold) — overlaps `compute_secs` of
    /// computation (the pipelined / parameter-server semantics the
    /// ledger's overlap mode charges): `max(compute, comm + deferred)` —
    /// communication hides behind computation and vice versa, never
    /// both. This is the single home of the overlap charging rule;
    /// [`Ledger::record_overlapped_iter`](crate::comm::Ledger::record_overlapped_iter)
    /// delegates here.
    pub fn overlapped_iter_secs(
        &self,
        compute_secs: f64,
        bytes: usize,
        n: usize,
        deferred_comm_secs: f64,
    ) -> f64 {
        compute_secs.max(self.allreduce_secs(bytes, n) + deferred_comm_secs)
    }

    /// Straggler timeout for a sync of `bytes` across `n`: `factor`
    /// times the α–β allreduce time — a worker that has not reached the
    /// barrier within `factor` healthy sync windows is presumed slow
    /// and the leader starts its backoff polling
    /// ([`Ledger::record_straggler`](crate::comm::Ledger::record_straggler)).
    /// Floored at one message latency so an n = 1 or zero-byte sync
    /// still yields a usable (non-zero) timeout.
    pub fn straggler_timeout_secs(&self, bytes: usize, n: usize, factor: f64) -> f64 {
        (factor * self.allreduce_secs(bytes, n)).max(self.latency_s)
    }

    /// Total wire bytes an `n`-processor allreduce of `bytes` moves
    /// (all links summed) — the quantity the paper's Eq. (5) counts
    /// as N·K·W elements.
    pub fn allreduce_wire_bytes(&self, bytes: usize, n: usize) -> usize {
        if n <= 1 {
            0
        } else {
            2 * bytes * (n - 1)
        }
    }

    /// Signed calibration error of one allreduce segment: measured
    /// minus modeled seconds for a `bytes`-payload segment across `n`
    /// (positive = the α–β model is optimistic for this link). The
    /// distributed transport records measured wire seconds next to
    /// every estimate
    /// ([`Ledger::record_measured`](crate::comm::Ledger::record_measured));
    /// this is the scoring rule that turns those pairs into a model
    /// correction, so the α–β parameters can be *calibrated* against
    /// the real interconnect instead of trusted.
    pub fn calibration_error_secs(&self, bytes: usize, n: usize, measured_secs: f64) -> f64 {
        measured_secs - self.reduce_scatter_secs(bytes, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_processor_is_free() {
        let m = NetModel::infiniband_20gbps();
        assert_eq!(m.allreduce_secs(1 << 20, 1), 0.0);
        assert_eq!(m.allreduce_wire_bytes(1 << 20, 1), 0);
    }

    #[test]
    fn cost_grows_with_n_and_bytes() {
        let m = NetModel::infiniband_20gbps();
        assert!(m.allreduce_secs(1 << 20, 4) < m.allreduce_secs(1 << 20, 64));
        assert!(m.allreduce_secs(1 << 10, 8) < m.allreduce_secs(1 << 20, 8));
    }

    #[test]
    fn bandwidth_term_dominates_large_payloads() {
        let m = NetModel::infiniband_20gbps();
        let bytes = 1usize << 30; // 1 GiB
        let t = m.allreduce_secs(bytes, 16);
        let bw_term = 2.0 * bytes as f64 * 15.0 / 16.0 / 20e9;
        assert!((t - bw_term) / t < 0.01);
    }

    #[test]
    fn latency_term_dominates_small_payloads() {
        let m = NetModel::infiniband_20gbps();
        let t = m.allreduce_secs(64, 1024);
        // 2·log2(1024) = 20 latency steps dominate a 64-byte payload
        let lat = 20.0 * 2e-6;
        assert!(t >= lat && t < lat * 1.5, "t = {t}");
    }

    #[test]
    fn segments_sum_to_allreduce() {
        let m = NetModel::infiniband_20gbps();
        for &(bytes, n) in &[(64usize, 4usize), (1 << 20, 16), (1 << 10, 256)] {
            let rs = m.reduce_scatter_secs(bytes, n);
            let ag = m.allgather_secs(bytes, n);
            assert!(rs > 0.0 && ag > 0.0);
            assert!((rs + ag - m.allreduce_secs(bytes, n)).abs() < 1e-18);
        }
        assert_eq!(m.reduce_scatter_secs(1 << 20, 1), 0.0);
        assert_eq!(m.allgather_secs(1 << 20, 1), 0.0);
    }

    #[test]
    fn overlapped_iter_is_max_of_segments() {
        let m = NetModel::infiniband_20gbps();
        let comm = m.allreduce_secs(1 << 20, 8);
        // compute-bound: compute dominates; comm-bound: comm dominates
        assert_eq!(m.overlapped_iter_secs(10.0 * comm, 1 << 20, 8, 0.0), 10.0 * comm);
        assert_eq!(m.overlapped_iter_secs(comm * 0.1, 1 << 20, 8, 0.0), comm);
        // n = 1 has no comm to hide
        assert_eq!(m.overlapped_iter_secs(0.25, 1 << 20, 1, 0.0), 0.25);
        // deferred fold comm joins the window's comm side (comm + comm
        // = 2·comm is exact in binary floating point)
        assert_eq!(
            m.overlapped_iter_secs(comm * 0.1, 1 << 20, 8, comm),
            2.0 * comm
        );
        assert_eq!(
            m.overlapped_iter_secs(10.0 * comm, 1 << 20, 8, comm),
            10.0 * comm
        );
    }

    #[test]
    fn straggler_timeout_scales_with_sync_and_floors_at_latency() {
        let m = NetModel::infiniband_20gbps();
        let t = m.straggler_timeout_secs(1 << 20, 8, 4.0);
        assert_eq!(t, 4.0 * m.allreduce_secs(1 << 20, 8));
        assert!(
            m.straggler_timeout_secs(1 << 20, 8, 8.0)
                > m.straggler_timeout_secs(1 << 20, 8, 4.0)
        );
        // n = 1 has a free allreduce; the timeout floors at one latency
        assert_eq!(m.straggler_timeout_secs(1 << 20, 1, 4.0), m.latency_s);
    }

    #[test]
    fn calibration_error_is_signed_measured_minus_modeled() {
        let m = NetModel::infiniband_20gbps();
        let modeled = m.reduce_scatter_secs(1 << 20, 8);
        assert_eq!(m.calibration_error_secs(1 << 20, 8, modeled), 0.0);
        assert!(m.calibration_error_secs(1 << 20, 8, 2.0 * modeled) > 0.0);
        assert!(m.calibration_error_secs(1 << 20, 8, 0.5 * modeled) < 0.0);
    }

    #[test]
    fn gige_slower_than_ib() {
        let bytes = 10 << 20;
        assert!(
            NetModel::gige().allreduce_secs(bytes, 8)
                > NetModel::infiniband_20gbps().allreduce_secs(bytes, 8)
        );
    }
}
