//! The simulated multi-processor cluster: N logical workers executed on
//! the machine's physical cores with *per-worker* timing.
//!
//! The MPA of the paper is bulk-synchronous (Fig. 1): every worker sweeps
//! its shard, then all workers allreduce. We reproduce that with scoped
//! std threads; when N exceeds the physical core count, logical workers
//! are multiplexed over cores and their shard times are still measured
//! individually, so the barrier cost max_n(compute_n) used by the ledger
//! stays meaningful for N up to the paper's 1024.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// A pool of `n` logical workers.
#[derive(Clone, Copy, Debug)]
pub struct Cluster {
    n: usize,
    threads: usize,
}

impl Cluster {
    /// `n` logical workers on up to `max_threads` OS threads
    /// (0 = available parallelism).
    pub fn new(n: usize, max_threads: usize) -> Cluster {
        assert!(n > 0);
        let cores = std::thread::available_parallelism()
            .map(|c| c.get())
            .unwrap_or(1);
        let cap = if max_threads == 0 { cores } else { max_threads.min(cores) };
        Cluster { n, threads: cap.min(n) }
    }

    pub fn workers(&self) -> usize {
        self.n
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(worker_id)` for every logical worker; returns the results
    /// and each worker's individually measured seconds.
    ///
    /// `f` must be `Sync` because multiple OS threads call it; per-worker
    /// mutable state should live in the closure's return value or behind
    /// the worker-indexed slices the engines pass in.
    pub fn run<T, F>(&self, f: F) -> (Vec<T>, Vec<f64>)
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let n = self.n;
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut secs = vec![0f64; n];
        if self.threads <= 1 {
            for (i, (slot, sec)) in results.iter_mut().zip(&mut secs).enumerate() {
                let t0 = Instant::now();
                *slot = Some(f(i));
                *sec = t0.elapsed().as_secs_f64();
            }
        } else {
            let counter = AtomicUsize::new(0);
            // Disjoint &mut views for the threads, claimed by work-stealing
            // on the atomic counter. SAFETY-free version: give each OS
            // thread its own result buffer and stitch after the join.
            let fref = &f;
            let counter_ref = &counter;
            let mut collected: Vec<Vec<(usize, T, f64)>> =
                std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..self.threads)
                        .map(|_| {
                            scope.spawn(move || {
                                let mut local = Vec::new();
                                loop {
                                    let i = counter_ref.fetch_add(1, Ordering::Relaxed);
                                    if i >= n {
                                        break;
                                    }
                                    let t0 = Instant::now();
                                    let r = fref(i);
                                    local.push((i, r, t0.elapsed().as_secs_f64()));
                                }
                                local
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
            for chunk in collected.drain(..) {
                for (i, r, s) in chunk {
                    results[i] = Some(r);
                    secs[i] = s;
                }
            }
        }
        (
            results.into_iter().map(|r| r.expect("worker missing")).collect(),
            secs,
        )
    }
}

/// Element-wise sum of worker partial vectors into `global` — the leader
/// side of the synchronous allreduce of Eq. (4)/(15): the result every
/// processor holds afterwards.
pub fn reduce_sum_into(global: &mut [f32], partials: &[Vec<f32>]) {
    for p in partials {
        debug_assert_eq!(p.len(), global.len());
        for (g, &v) in global.iter_mut().zip(p) {
            *g += v;
        }
    }
}

/// Sparse variant: sums only the listed flat indices (the power-subset
/// synchronization of §3.1). Indices must be in-bounds.
pub fn reduce_sum_subset_into(
    global: &mut [f32],
    indices: &[u32],
    partials: &[Vec<f32>],
) {
    for (slot, &ix) in indices.iter().enumerate() {
        let mut acc = 0f32;
        for p in partials {
            acc += p[slot];
        }
        global[ix as usize] += acc;
        let _ = slot;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_workers_any_topology() {
        for &(n, threads) in &[(1usize, 1usize), (4, 2), (16, 0), (33, 4)] {
            let c = Cluster::new(n, threads);
            let (res, secs) = c.run(|i| i * i);
            assert_eq!(res, (0..n).map(|i| i * i).collect::<Vec<_>>());
            assert_eq!(secs.len(), n);
            assert!(secs.iter().all(|&s| s >= 0.0));
        }
    }

    #[test]
    fn more_logical_workers_than_threads() {
        let c = Cluster::new(64, 2);
        assert_eq!(c.workers(), 64);
        assert!(c.threads() <= 2);
        let (res, _) = c.run(|i| i);
        assert_eq!(res.len(), 64);
    }

    #[test]
    fn reduce_sum_matches_sequential() {
        let partials = vec![vec![1.0f32, 2.0, 3.0], vec![10.0, 20.0, 30.0]];
        let mut g = vec![0.5f32, 0.5, 0.5];
        reduce_sum_into(&mut g, &partials);
        assert_eq!(g, vec![11.5, 22.5, 33.5]);
    }

    #[test]
    fn reduce_subset_touches_only_indices() {
        // global has 6 slots; sync only flat indices [1, 4]
        let mut g = vec![0f32; 6];
        let partials = vec![vec![5.0f32, 7.0], vec![1.0, 2.0]];
        reduce_sum_subset_into(&mut g, &[1, 4], &partials);
        assert_eq!(g, vec![0.0, 6.0, 0.0, 0.0, 9.0, 0.0]);
    }

    #[test]
    fn deterministic_results_under_parallelism() {
        let c = Cluster::new(32, 0);
        let (a, _) = c.run(|i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let (b, _) = c.run(|i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        assert_eq!(a, b);
    }
}
