//! The simulated multi-processor cluster: N logical workers executed on
//! the machine's physical cores with *per-worker* timing.
//!
//! The MPA of the paper is bulk-synchronous (Fig. 1): every worker sweeps
//! its shard, then all workers allreduce. We reproduce that with scoped
//! std threads; when N exceeds the physical core count, logical workers
//! are multiplexed over cores and their shard times are still measured
//! individually, so the barrier cost max_n(compute_n) used by the ledger
//! stays meaningful for N up to the paper's 1024.
//!
//! # Determinism contract of the dispatches
//!
//! The pool is a pure executor: results must never depend on how many
//! OS threads ran a dispatch or which thread claimed which task. The
//! split of responsibility that guarantees it:
//!
//! * **Caller-fixed partitions** ([`Cluster::run`],
//!   [`Cluster::run_on_doc_blocks`], [`Cluster::run_on_permuted_blocks`],
//!   [`Cluster::run_on_owner_slices`]): the caller pre-builds the task
//!   list from data counts only (doc blocks from NNZ, schedule blocks
//!   from scheduled NNZ, owner slices from index counts); tasks are
//!   mutually independent `&mut` views, claimed by work-stealing on an
//!   atomic counter. Whatever the claim order, each task's work — and
//!   therefore every float accumulation keyed on the partition — is
//!   identical on every machine at every thread budget.
//! * **Pool-derived chunks** ([`Cluster::run_on_chunks`]): boundaries
//!   *do* depend on the core count, so the closure must be
//!   element-local (each output element computed from that element's
//!   inputs only) — the chunked allreduce reduction qualifies because
//!   each element's fold chain is chunking-independent.
//!
//! Per-task seconds are measured individually and returned in task
//! order, so the ledger's barrier/critical-path accounting is
//! deterministic in *shape* (which tasks existed) even though the
//! measured times themselves vary run to run.
//!
//! Two construction-time performance knobs ride on top of the contract
//! without touching it: [`Cluster::with_pinning`] pins spawned pool
//! threads to cores (best-effort, see [`affinity`](super::affinity)) and
//! [`Cluster::with_spawn_threshold`] tunes the serial/parallel cutover
//! of [`Cluster::run_on_chunks`]. Both affect only *where* and *whether*
//! threads run — never the partition — so results stay bitwise identical
//! with them on, off, or refused by the OS.
//!
//! ```
//! use pobp::comm::Cluster;
//! let pool = Cluster::new(2, 0);
//! let (squares, secs) = pool.run(|i| i * i);
//! assert_eq!(squares, vec![0, 1]);
//! assert_eq!(secs.len(), 2);
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// A pool of `n` logical workers.
#[derive(Clone, Copy, Debug)]
pub struct Cluster {
    n: usize,
    threads: usize,
    /// OS-thread budget for leader-side data-parallel helpers
    /// ([`Cluster::run_on_chunks`]): the machine/`max_threads` cap, *not*
    /// limited by the logical worker count — an N = 2 simulation on a
    /// 16-core host still reduces on 16 threads.
    pool_threads: usize,
    /// When set, every spawned pool thread pins itself to an allowed CPU
    /// (slot-round-robin over the process affinity mask) before claiming
    /// work — see [`affinity`](super::affinity). Best-effort: where the
    /// OS refuses, threads stay floating and results are unchanged
    /// (pinning is purely a cache-warmth hint under the determinism
    /// contract above).
    pin_cores: bool,
    /// Minimum elements per parallel chunk in [`Cluster::run_on_chunks`]
    /// — below this the scoped-thread spawn overhead exceeds the work and
    /// the call degenerates to a serial pass. Defaults to
    /// [`MIN_PAR_CHUNK`]; construction-time tunable via
    /// [`Cluster::with_spawn_threshold`] (benchmarked in
    /// `benches/microbench.rs`).
    min_par_chunk: usize,
}

impl Cluster {
    /// `n` logical workers on up to `max_threads` OS threads
    /// (0 = available parallelism).
    pub fn new(n: usize, max_threads: usize) -> Cluster {
        assert!(n > 0);
        let cores = std::thread::available_parallelism()
            .map(|c| c.get())
            .unwrap_or(1);
        let cap = if max_threads == 0 { cores } else { max_threads.min(cores) };
        Cluster {
            n,
            threads: cap.min(n),
            pool_threads: cap,
            pin_cores: false,
            min_par_chunk: MIN_PAR_CHUNK,
        }
    }

    /// Builder: enable (or disable) best-effort core pinning of pool
    /// threads. Off by default; a refused pin logs once and the pool
    /// keeps running floating.
    pub fn with_pinning(mut self, pin: bool) -> Cluster {
        self.pin_cores = pin;
        self
    }

    /// Builder: override the [`Cluster::run_on_chunks`] spawn threshold
    /// (minimum elements per parallel chunk; clamped to ≥ 1).
    pub fn with_spawn_threshold(mut self, nnz: usize) -> Cluster {
        self.min_par_chunk = nnz.max(1);
        self
    }

    /// Whether pool threads pin themselves to cores.
    pub fn pinned(&self) -> bool {
        self.pin_cores
    }

    /// The active [`Cluster::run_on_chunks`] spawn threshold.
    pub fn spawn_threshold(&self) -> usize {
        self.min_par_chunk
    }

    pub fn workers(&self) -> usize {
        self.n
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The OS-thread budget of the leader-side pool (machine cores capped
    /// by `max_threads`), independent of the logical worker count.
    pub fn pool_threads(&self) -> usize {
        self.pool_threads
    }

    /// Even split of the OS-thread pool across the logical workers a
    /// bulk-synchronous [`Cluster::run`] executes concurrently (≥ 1): the
    /// per-shard doc-block budget the coordinator hands to
    /// `ShardBp::sweep_parallel`, so an N = 1 OBP run gets the whole
    /// machine while an N = cores run stays one thread per worker.
    pub fn doc_threads_per_worker(&self) -> usize {
        (self.pool_threads / self.threads.max(1)).max(1)
    }

    /// Run `f(worker_id)` for every logical worker; returns the results
    /// and each worker's individually measured seconds.
    ///
    /// `f` must be `Sync` because multiple OS threads call it; per-worker
    /// mutable state should live in the closure's return value or behind
    /// the worker-indexed slices the engines pass in.
    pub fn run<T, F>(&self, f: F) -> (Vec<T>, Vec<f64>)
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let n = self.n;
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut secs = vec![0f64; n];
        if self.threads <= 1 {
            for (i, (slot, sec)) in results.iter_mut().zip(&mut secs).enumerate() {
                let t0 = Instant::now();
                *slot = Some(f(i));
                *sec = t0.elapsed().as_secs_f64();
            }
        } else {
            let counter = AtomicUsize::new(0);
            // Disjoint &mut views for the threads, claimed by work-stealing
            // on the atomic counter: per-slot mutexes hand each claiming
            // thread its (result, seconds) pair directly. Each lock is
            // uncontended (every index is claimed exactly once), and no
            // per-thread collection buffers are allocated per dispatch.
            let cells: Vec<Mutex<(&mut Option<T>, &mut f64)>> = results
                .iter_mut()
                .zip(secs.iter_mut())
                .map(Mutex::new)
                .collect();
            let fref = &f;
            let cells_ref = &cells;
            let counter_ref = &counter;
            let pin = self.pin_cores;
            std::thread::scope(|scope| {
                for ti in 0..self.threads {
                    scope.spawn(move || {
                        if pin {
                            super::affinity::pin_current_thread(ti);
                        }
                        loop {
                            let i = counter_ref.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            let t0 = Instant::now();
                            let r = fref(i);
                            let mut guard = cells_ref[i].lock().unwrap();
                            *guard.0 = Some(r);
                            *guard.1 = t0.elapsed().as_secs_f64();
                        }
                    });
                }
            });
            drop(cells);
        }
        (
            results.into_iter().map(|r| r.expect("worker missing")).collect(),
            secs,
        )
    }
}

/// Default minimum elements per parallel chunk in
/// [`Cluster::run_on_chunks`]: below this the scoped-thread spawn
/// overhead exceeds the work, so the call degenerates to a serial pass.
/// Per-pool override: [`Cluster::with_spawn_threshold`].
pub const MIN_PAR_CHUNK: usize = 1 << 13;

impl Cluster {
    /// Split `data` into chunks (up to the full OS-thread budget — the
    /// leader's reduction is not bound by the logical worker count) and
    /// run `f(chunk_start, chunk)` concurrently on scoped OS threads —
    /// the data-parallel primitive behind the chunked allreduce
    /// reduction (comm::allreduce).
    ///
    /// Chunk boundaries depend on the machine's core count, so `f` must
    /// be element-local (each output element computed from that element's
    /// inputs only) for results to be machine-independent.
    pub fn run_on_chunks<F>(&self, data: &mut [f32], f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let len = data.len();
        let nchunks = self.pool_threads.min(len.div_ceil(self.min_par_chunk)).max(1);
        if nchunks <= 1 {
            f(0, data);
            return;
        }
        let chunk_len = len.div_ceil(nchunks);
        let pin = self.pin_cores;
        std::thread::scope(|scope| {
            for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
                let fref = &f;
                scope.spawn(move || {
                    if pin {
                        super::affinity::pin_current_thread(ci);
                    }
                    fref(ci * chunk_len, chunk)
                });
            }
        });
    }

    /// Doc-block sibling of [`Cluster::run_on_chunks`]: run
    /// `f(i, &mut blocks[i])` for every pre-built block task concurrently
    /// on up to `budget` OS threads (0 = the full pool budget; values
    /// above the pool are honored so tests can pin thread counts), with
    /// work-stealing over the block list. Returns each block's measured
    /// seconds, block order.
    ///
    /// Unlike `run_on_chunks`, the *caller* fixes the block boundaries
    /// (the sweep engine derives them from NNZ counts), so `f` may carry
    /// per-block mutable state and results stay machine-independent as
    /// long as blocks are mutually independent.
    pub fn run_on_doc_blocks<T, F>(
        &self,
        budget: usize,
        blocks: &mut [T],
        f: F,
    ) -> Vec<f64>
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = blocks.len();
        let cap = if budget == 0 { self.pool_threads } else { budget };
        let threads = cap.min(n).max(1);
        let mut secs = vec![0f64; n];
        if threads <= 1 {
            for (i, (b, s)) in blocks.iter_mut().zip(secs.iter_mut()).enumerate() {
                let t0 = Instant::now();
                f(i, b);
                *s = t0.elapsed().as_secs_f64();
            }
            return secs;
        }
        // per-block mutexes hand out the disjoint (&mut block, &mut
        // seconds-slot) views to whichever thread claims the block on the
        // shared counter; each lock is uncontended (every index is
        // claimed exactly once). Threads write their measurements through
        // the cells, so the dispatch allocates no per-thread collection
        // buffers (the last per-dispatch allocations besides the cell
        // list itself and the returned seconds).
        let cells: Vec<Mutex<(&mut T, &mut f64)>> = blocks
            .iter_mut()
            .zip(secs.iter_mut())
            .map(Mutex::new)
            .collect();
        let counter = AtomicUsize::new(0);
        let fref = &f;
        let cells_ref = &cells;
        let counter_ref = &counter;
        let pin = self.pin_cores;
        std::thread::scope(|scope| {
            for ti in 0..threads {
                scope.spawn(move || {
                    if pin {
                        super::affinity::pin_current_thread(ti);
                    }
                    loop {
                        let i = counter_ref.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let mut guard = cells_ref[i].lock().unwrap();
                        let t0 = Instant::now();
                        fref(i, &mut *guard.0);
                        *guard.1 = t0.elapsed().as_secs_f64();
                    }
                });
            }
        });
        drop(cells);
        secs
    }

    /// Permuted-block dispatch of the scheduled-parallel doc sweep
    /// (`engine::bp::ShardBp::sweep_docs_parallel`): run
    /// `f(i, &mut blocks[i])` for every pre-built schedule block
    /// concurrently on up to `budget` OS threads. Semantically the blocks
    /// are *whole-document* slices of a per-iteration
    /// [`DocSchedule`](crate::sched::DocSchedule) permutation — their
    /// boundaries derive from scheduled-NNZ counts only, never from the
    /// machine, so any float-accumulation order keyed on the block
    /// structure is machine-independent however the pool schedules the
    /// tasks. This is [`Cluster::run_on_doc_blocks`] with the permuted
    /// (sorted-subset) ownership contract, named so the scheduling stack
    /// has its own dispatch point. Returns each block's measured seconds,
    /// block order.
    pub fn run_on_permuted_blocks<T, F>(
        &self,
        budget: usize,
        blocks: &mut [T],
        f: F,
    ) -> Vec<f64>
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        self.run_on_doc_blocks(budget, blocks, f)
    }

    /// Slice-owning dispatch of the owner-sliced reduce-scatter
    /// (comm::allreduce): run `f(i, &mut tasks[i])` for every owner task
    /// concurrently on the full OS-thread pool. Semantically task `i`
    /// belongs to logical worker `i` — its slice boundaries derive from
    /// index counts only, never from the machine — so results are
    /// machine-independent however the pool schedules the tasks. This is
    /// [`Cluster::run_on_doc_blocks`] under the pool-wide budget, named
    /// so the synchronization stack has a single dispatch point. Returns
    /// each task's measured seconds, task order.
    pub fn run_on_owner_slices<T, F>(&self, tasks: &mut [T], f: F) -> Vec<f64>
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        self.run_on_doc_blocks(0, tasks, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_workers_any_topology() {
        for &(n, threads) in &[(1usize, 1usize), (4, 2), (16, 0), (33, 4)] {
            let c = Cluster::new(n, threads);
            let (res, secs) = c.run(|i| i * i);
            assert_eq!(res, (0..n).map(|i| i * i).collect::<Vec<_>>());
            assert_eq!(secs.len(), n);
            assert!(secs.iter().all(|&s| s >= 0.0));
        }
    }

    #[test]
    fn more_logical_workers_than_threads() {
        let c = Cluster::new(64, 2);
        assert_eq!(c.workers(), 64);
        assert!(c.threads() <= 2);
        let (res, _) = c.run(|i| i);
        assert_eq!(res.len(), 64);
    }

    #[test]
    fn chunked_run_covers_every_element_exactly_once() {
        // sizes straddling the MIN_PAR_CHUNK threshold, plus empty input
        for &(n, len) in &[(1usize, 10usize), (4, 100_000), (8, (1 << 13) * 3 + 17), (2, 0)] {
            let c = Cluster::new(n, 0);
            let mut data = vec![0f32; len];
            c.run_on_chunks(&mut data, |start, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v += (start + j) as f32;
                }
            });
            for (i, &v) in data.iter().enumerate() {
                assert_eq!(v, i as f32, "n={n} len={len} slot {i}");
            }
        }
    }

    #[test]
    fn doc_blocks_run_each_task_exactly_once_any_budget() {
        for &budget in &[0usize, 1, 2, 8] {
            let c = Cluster::new(1, 0);
            let mut tasks: Vec<(usize, usize)> = (0..13).map(|i| (i, 0usize)).collect();
            let secs = c.run_on_doc_blocks(budget, &mut tasks, |i, t| {
                assert_eq!(t.0, i);
                t.1 += 1;
            });
            assert_eq!(secs.len(), 13);
            assert!(secs.iter().all(|&s| s >= 0.0));
            assert!(tasks.iter().all(|t| t.1 == 1), "budget {budget}");
        }
    }

    #[test]
    fn permuted_block_dispatch_runs_each_block_once_any_budget() {
        for &budget in &[0usize, 1, 2, 8] {
            let c = Cluster::new(1, 0);
            let mut blocks: Vec<(usize, usize)> = (0..9).map(|i| (i, 0usize)).collect();
            let secs = c.run_on_permuted_blocks(budget, &mut blocks, |i, b| {
                assert_eq!(b.0, i);
                b.1 += 1;
            });
            assert_eq!(secs.len(), 9);
            assert!(blocks.iter().all(|b| b.1 == 1), "budget {budget}");
        }
    }

    #[test]
    fn owner_slice_dispatch_runs_each_task_once() {
        for &(n, threads) in &[(1usize, 1usize), (4, 2), (8, 0)] {
            let c = Cluster::new(n, threads);
            let mut tasks: Vec<usize> = vec![0; n];
            let secs = c.run_on_owner_slices(&mut tasks, |i, t| {
                assert!(i < n);
                *t += 1;
            });
            assert_eq!(secs.len(), n);
            assert!(tasks.iter().all(|&t| t == 1), "n={n} threads={threads}");
        }
    }

    #[test]
    fn thread_budget_splits_pool_across_workers() {
        let c = Cluster::new(1, 4);
        assert_eq!(c.doc_threads_per_worker(), c.pool_threads());
        let c = Cluster::new(64, 2);
        assert_eq!(c.doc_threads_per_worker(), 1);
    }

    #[test]
    fn spawn_threshold_is_tunable_and_preserves_coverage() {
        let c = Cluster::new(4, 0);
        assert_eq!(c.spawn_threshold(), MIN_PAR_CHUNK);
        // a tiny threshold forces the parallel path even on small data; a
        // huge one forces the serial path — coverage must be identical
        for &thr in &[1usize, 64, usize::MAX] {
            let c = c.with_spawn_threshold(thr);
            assert_eq!(c.spawn_threshold(), thr.max(1));
            let mut data = vec![0f32; 1000];
            c.run_on_chunks(&mut data, |start, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v += (start + j) as f32;
                }
            });
            for (i, &v) in data.iter().enumerate() {
                assert_eq!(v, i as f32, "thr={thr} slot {i}");
            }
        }
        assert_eq!(c.with_spawn_threshold(0).spawn_threshold(), 1);
    }

    #[test]
    fn pinned_pool_matches_floating_pool_bitwise() {
        let floating = Cluster::new(8, 0);
        let pinned = floating.with_pinning(true);
        assert!(pinned.pinned() && !floating.pinned());
        let work = |i: usize| ((i as u64).wrapping_mul(0x9E3779B97F4A7C15), i * i);
        let (a, _) = floating.run(work);
        let (b, _) = pinned.run(work);
        assert_eq!(a, b);
        let mut x = vec![1f32; (1 << 13) * 2 + 5];
        let mut y = x.clone();
        let scale = |start: usize, chunk: &mut [f32]| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v *= ((start + j) % 7) as f32 + 0.5;
            }
        };
        floating.run_on_chunks(&mut x, scale);
        pinned.run_on_chunks(&mut y, scale);
        assert_eq!(x, y);
    }

    #[test]
    fn deterministic_results_under_parallelism() {
        let c = Cluster::new(32, 0);
        let (a, _) = c.run(|i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let (b, _) = c.run(|i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        assert_eq!(a, b);
    }
}
