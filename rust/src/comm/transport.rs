//! The transport boundary (Contract 8): one worker-side protocol
//! implementation behind two carriers — the in-process pool (the
//! degenerate single-host case) and real TCP worker processes
//! (`bin/master` + `bin/worker`).
//!
//! # Protocol
//!
//! Every message is one `comm::wire` frame. Per mini-batch:
//!
//! ```text
//! master                                  worker n (of N)
//!   Batch  ── checkpoint + doc shard ──▶    ShardBp::init(shard, k, rng_n)
//!   per iteration t:
//!   Sweep  ── φ̂_eff, totals, power ────▶    sweep_parallel(...)
//!          ◀── Gather: plan-order Δφ̂/r ──   (+ measured sweep seconds)
//!   at the batch boundary:
//!   Fold   ─────────────────────────────▶
//!          ◀── FoldPart: dense Δφ̂ ──────
//! ```
//!
//! The [`FrameKind::Batch`] payload *is* a `POBPCKP1` checkpoint (plus
//! the worker's document shard and the LDA params): the worker-join and
//! the state-transfer message are the same bytes a resumed run loads
//! from disk, checksummed and totals-verified by [`Checkpoint::decode`].
//! A worker therefore rejoins after a crash exactly the way a killed
//! run resumes.
//!
//! # Distributed determinism
//!
//! The master draws the same per-worker RNG splits, document ranges and
//! reduce plans as the in-process coordinator and performs the
//! owner-sliced reduction itself over [`PartSource`] mirrors of the
//! workers' gather buffers; workers contribute only [`ShardBp`] sweep
//! results, which are thread-budget-independent (Contract 1). A
//! loopback distributed run is therefore bitwise identical to the
//! in-process run in both storage modes — `rust/tests/dist_equiv.rs`
//! pins it. Wall-clock quantities (sweep seconds, measured wire
//! seconds) are measured, recorded, and never compared.

use std::fmt;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use crate::comm::allreduce::{GatherBuf, ReduceSource};
use crate::comm::wire::{
    self, read_frame, write_frame, FrameKind, PayloadRd, WireError, PROTO_VERSION,
};
use crate::comm::Cluster;
use crate::corpus::Csr;
use crate::engine::bp::{Selection, ShardBp};
use crate::engine::traits::LdaParams;
use crate::sched::PowerSet;
use crate::storage::Checkpoint;
use crate::util::rng::Rng;

/// Which transport a run uses (`[run] transport = inprocess|tcp`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// logical workers on the in-process pool (the historical behavior)
    #[default]
    InProcess,
    /// real worker processes over TCP (`bin/master` + `bin/worker`)
    Tcp,
}

impl TransportKind {
    /// Parse a config/CLI value.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "inprocess" | "in-process" => Some(TransportKind::InProcess),
            "tcp" => Some(TransportKind::Tcp),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProcess => "inprocess",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Why a transport operation failed.
#[derive(Debug)]
pub enum TransportError {
    /// a frame was refused (corrupt, truncated, wrong layout)
    Wire(WireError),
    Io(io::Error),
    /// the peer spoke wrongly (unexpected frame kind, bad slot, shape
    /// mismatch, protocol-version mismatch)
    Protocol(String),
    /// a socket deadline expired — the hung-socket guard
    Timeout(&'static str),
    /// a specific worker's connection or process is gone
    WorkerDead { slot: usize, msg: String },
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Wire(e) => write!(f, "transport wire error: {e}"),
            TransportError::Io(e) => write!(f, "transport I/O: {e}"),
            TransportError::Protocol(s) => write!(f, "transport protocol violation: {s}"),
            TransportError::Timeout(what) => write!(f, "transport timeout ({what})"),
            TransportError::WorkerDead { slot, msg } => {
                write!(f, "worker {slot} unreachable: {msg}")
            }
        }
    }
}

impl std::error::Error for TransportError {}

impl From<WireError> for TransportError {
    fn from(e: WireError) -> TransportError {
        TransportError::Wire(e)
    }
}

impl From<io::Error> for TransportError {
    fn from(e: io::Error) -> TransportError {
        TransportError::Io(e)
    }
}

// ---- protocol payloads (wire-format conventions of the checkpoint) ----

fn hello_payload(slot: usize) -> Vec<u8> {
    let mut p = Vec::new();
    wire::put_u32(&mut p, PROTO_VERSION);
    wire::put_u64(&mut p, slot as u64);
    wire::put_u64(&mut p, std::process::id() as u64);
    p
}

fn decode_hello(payload: &[u8]) -> Result<(u32, usize, u32), WireError> {
    let mut rd = PayloadRd::new(payload, "hello");
    let version = rd.u32()?;
    let slot = rd.usize()?;
    let pid = rd.u64()? as u32;
    rd.done()?;
    Ok((version, slot, pid))
}

fn welcome_payload(slot: usize, n_workers: usize) -> Vec<u8> {
    let mut p = Vec::new();
    wire::put_u64(&mut p, slot as u64);
    wire::put_u64(&mut p, n_workers as u64);
    p
}

fn decode_welcome(payload: &[u8]) -> Result<(usize, usize), WireError> {
    let mut rd = PayloadRd::new(payload, "welcome");
    let slot = rd.usize()?;
    let n = rd.usize()?;
    rd.done()?;
    Ok((slot, n))
}

/// Build a [`FrameKind::Batch`] payload: the `POBPCKP1` join/state
/// checkpoint, the LDA smoothing params, and the worker's document
/// shard (a re-based CSR slice).
pub fn batch_payload(ck: &Checkpoint, shard: &Csr, params: &LdaParams) -> Vec<u8> {
    let ck_bytes = ck.encode();
    let mut p = Vec::with_capacity(ck_bytes.len() + 64 + 4 * (shard.row_ptr.len() + 2 * shard.col.len()));
    wire::put_u64(&mut p, ck_bytes.len() as u64);
    p.extend_from_slice(&ck_bytes);
    wire::put_u32(&mut p, params.alpha.to_bits());
    wire::put_u32(&mut p, params.beta.to_bits());
    wire::put_u64(&mut p, shard.w as u64);
    wire::put_u64(&mut p, shard.row_ptr.len() as u64);
    wire::put_u32s(&mut p, &shard.row_ptr);
    wire::put_u64(&mut p, shard.col.len() as u64);
    wire::put_u32s(&mut p, &shard.col);
    wire::put_f32s(&mut p, &shard.val);
    p
}

/// Decode a Batch payload. The embedded checkpoint goes through
/// [`Checkpoint::decode`] — per-section checksums plus the bitwise
/// totals check — so a worker refuses a torn state transfer the same
/// way a resuming run refuses a torn checkpoint file.
pub fn decode_batch(payload: &[u8]) -> Result<(Checkpoint, Csr, LdaParams), WireError> {
    let mut rd = PayloadRd::new(payload, "batch");
    let ck_len = rd.usize()?;
    let ck = Checkpoint::decode(rd.bytes(ck_len)?)
        .map_err(|e| WireError::Malformed(format!("join checkpoint refused: {e}")))?;
    let alpha = f32::from_bits(rd.u32()?);
    let beta = f32::from_bits(rd.u32()?);
    let w = rd.usize()?;
    let rows = rd.usize()?;
    if rows == 0 {
        return Err(WireError::Malformed("empty CSR row table".into()));
    }
    let row_ptr = rd.u32s(rows)?;
    let nnz = rd.usize()?;
    let col = rd.u32s(nnz)?;
    let val = rd.f32s(nnz)?;
    rd.done()?;
    if w != ck.w {
        return Err(WireError::Malformed(format!(
            "shard vocabulary {w} != checkpoint vocabulary {}",
            ck.w
        )));
    }
    if row_ptr.first() != Some(&0) || *row_ptr.last().unwrap() as usize != nnz {
        return Err(WireError::Malformed("inconsistent CSR row pointers".into()));
    }
    let params = LdaParams { k: ck.k, alpha, beta };
    Ok((ck, Csr { w, row_ptr, col, val }, params))
}

/// Build a [`FrameKind::Sweep`] payload: iteration index, the dense
/// φ̂_eff working set, the k per-topic totals, and the power set (absent
/// on full-schedule iterations).
pub fn sweep_payload(iter: usize, phi: &[f32], tot: &[f32], power: Option<&PowerSet>) -> Vec<u8> {
    let mut p = Vec::with_capacity(32 + 4 * (phi.len() + tot.len()));
    wire::put_u64(&mut p, iter as u64);
    wire::put_u64(&mut p, phi.len() as u64);
    wire::put_f32s(&mut p, phi);
    wire::put_u64(&mut p, tot.len() as u64);
    wire::put_f32s(&mut p, tot);
    match power {
        None => wire::put_u32(&mut p, 0),
        Some(ps) => {
            wire::put_u32(&mut p, 1);
            wire::put_u64(&mut p, ps.words.len() as u64);
            wire::put_u32s(&mut p, &ps.words);
            for topics in &ps.topics {
                wire::put_u64(&mut p, topics.len() as u64);
                wire::put_u32s(&mut p, topics);
            }
        }
    }
    p
}

/// Decode a Sweep payload into `(iter, φ̂, totals, power set)`.
pub fn decode_sweep(
    payload: &[u8],
) -> Result<(usize, Vec<f32>, Vec<f32>, Option<PowerSet>), WireError> {
    let mut rd = PayloadRd::new(payload, "sweep");
    let iter = rd.usize()?;
    let phi_len = rd.usize()?;
    let phi = rd.f32s(phi_len)?;
    let k = rd.usize()?;
    let tot = rd.f32s(k)?;
    let power = match rd.u32()? {
        0 => None,
        1 => {
            let n_words = rd.usize()?;
            let words = rd.u32s(n_words)?;
            let mut topics = Vec::with_capacity(n_words);
            for _ in 0..n_words {
                let len = rd.usize()?;
                topics.push(rd.u32s(len)?);
            }
            Some(PowerSet { words, topics })
        }
        other => {
            return Err(WireError::Malformed(format!("bad power-set tag {other}")));
        }
    };
    rd.done()?;
    Ok((iter, phi, tot, power))
}

/// A worker's reply to one Sweep: the plan-order gather buffer plus the
/// measured sweep seconds (used for the ledger's compute attribution,
/// never for bits).
#[derive(Clone, Debug)]
pub struct GatherReply {
    pub iter: usize,
    pub dphi: Vec<f32>,
    pub r: Vec<f32>,
    pub sweep_secs: f64,
}

fn gather_payload(iter: usize, dphi: &[f32], r: &[f32], sweep_secs: f64) -> Vec<u8> {
    debug_assert_eq!(dphi.len(), r.len());
    let mut p = Vec::with_capacity(24 + 8 * dphi.len());
    wire::put_u64(&mut p, iter as u64);
    wire::put_u64(&mut p, dphi.len() as u64);
    wire::put_f32s(&mut p, dphi);
    wire::put_f32s(&mut p, r);
    wire::put_f64(&mut p, sweep_secs);
    p
}

fn decode_gather(payload: &[u8]) -> Result<GatherReply, WireError> {
    let mut rd = PayloadRd::new(payload, "gather");
    let iter = rd.usize()?;
    let pairs = rd.usize()?;
    let dphi = rd.f32s(pairs)?;
    let r = rd.f32s(pairs)?;
    let sweep_secs = rd.f64()?;
    rd.done()?;
    Ok(GatherReply { iter, dphi, r, sweep_secs })
}

fn fold_part_payload(dphi: &[f32]) -> Vec<u8> {
    let mut p = Vec::with_capacity(8 + 4 * dphi.len());
    wire::put_u64(&mut p, dphi.len() as u64);
    wire::put_f32s(&mut p, dphi);
    p
}

fn decode_fold_part(payload: &[u8]) -> Result<Vec<f32>, WireError> {
    let mut rd = PayloadRd::new(payload, "fold part");
    let len = rd.usize()?;
    let dphi = rd.f32s(len)?;
    rd.done()?;
    Ok(dphi)
}

// ---- the worker-side protocol (one implementation, two carriers) ----

/// A worker's whole protocol state: its document shard's [`ShardBp`]
/// plus the decode/sweep/export handlers. The TCP worker binary wraps
/// this in a socket loop ([`serve_worker`]); [`InProcessTransport`]
/// calls it directly with the *same encoded payloads*, so the two
/// carriers cannot diverge semantically.
pub struct WorkerState {
    cluster: Cluster,
    w: usize,
    k: usize,
    params: LdaParams,
    shard: Option<ShardBp>,
    flat_buf: Vec<u32>,
    gather: GatherBuf,
}

impl WorkerState {
    /// A fresh worker with a local `max_threads`-thread sweep pool
    /// (thread budgets never change bits — Contract 1).
    pub fn new(max_threads: usize) -> WorkerState {
        WorkerState {
            cluster: Cluster::new(1, max_threads),
            w: 0,
            k: 0,
            params: LdaParams::paper(1),
            shard: None,
            flat_buf: Vec::new(),
            gather: GatherBuf::default(),
        }
    }

    /// Handle a Batch frame: adopt the join/state checkpoint and build
    /// this worker's shard from its document slice, seeding from the
    /// master-drawn RNG split carried in the checkpoint — the same
    /// `ShardBp::init` call, on the same bits, the in-process
    /// coordinator makes.
    pub fn on_batch(&mut self, payload: &[u8]) -> Result<(), TransportError> {
        let (ck, shard_csr, params) = decode_batch(payload)?;
        self.w = ck.w;
        self.k = ck.k;
        self.params = params;
        let mut rng = Rng::from_state(ck.rng_state);
        self.shard = Some(ShardBp::init(shard_csr, ck.k, &mut rng));
        Ok(())
    }

    /// Handle a Sweep frame: run the doc-parallel sweep against the
    /// published φ̂/totals under the published power schedule, and
    /// return the Gather payload — the plan-order gather buffer plus
    /// measured sweep seconds.
    pub fn on_sweep(&mut self, payload: &[u8]) -> Result<Vec<u8>, TransportError> {
        let (iter, phi, tot, power) = decode_sweep(payload)?;
        let shard = self
            .shard
            .as_mut()
            .ok_or_else(|| TransportError::Protocol("sweep before batch".into()))?;
        if phi.len() != self.w * self.k || tot.len() != self.k {
            return Err(TransportError::Protocol(format!(
                "sweep shapes {}/{} do not match W·K = {}·{}",
                phi.len(),
                tot.len(),
                self.w,
                self.k
            )));
        }
        let selection = match &power {
            Some(ps) => Selection::from_power(ps, self.w),
            None => Selection::full(self.w),
        };
        let budget = self.cluster.doc_threads_per_worker();
        let (_resid, timing) = shard.sweep_parallel(
            &self.cluster,
            budget,
            &phi,
            &tot,
            &selection,
            &self.params,
            true,
        );
        // the same critical-path attribution the in-process coordinator
        // records — measured, never compared bitwise
        let sweep_secs = timing.critical_path_secs(budget);
        let payload = match &power {
            None => {
                let (dphi, r) = shard.dense_parts();
                gather_payload(iter, dphi, r, sweep_secs)
            }
            Some(ps) => {
                ps.flat_indices_into(self.k, &mut self.flat_buf);
                shard.export_selected_into(&self.flat_buf, &mut self.gather);
                gather_payload(iter, &self.gather.dphi, &self.gather.r, sweep_secs)
            }
        };
        Ok(payload)
    }

    /// Handle a Fold frame: export the dense end-of-batch Δφ̂.
    pub fn on_fold(&mut self) -> Result<Vec<u8>, TransportError> {
        let shard = self
            .shard
            .as_ref()
            .ok_or_else(|| TransportError::Protocol("fold before batch".into()))?;
        let (dphi, _r) = shard.dense_parts();
        Ok(fold_part_payload(dphi))
    }
}

// ---- the master-side stand-in for a remote shard ----

/// A dense W·K mirror of a remote worker's gather buffers. The master
/// scatters each [`GatherReply`] into it and passes it — through the
/// *unchanged* `allreduce_step`/`allreduce_step_sharded` — wherever the
/// in-process coordinator passes the worker's [`ShardBp`]: the reduce
/// plan only ever reads the plan positions, and those carry exactly the
/// bits the remote shard exported, so the reduction is bitwise
/// identical to the in-process one.
pub struct PartSource {
    dphi: Vec<f32>,
    r: Vec<f32>,
}

impl PartSource {
    pub fn new(len: usize) -> PartSource {
        PartSource { dphi: vec![0.0; len], r: vec![0.0; len] }
    }

    /// Scatter a plan-order reply: dense replies replace the mirrors,
    /// subset replies land at the plan indices. Length mismatches are
    /// protocol violations, not panics.
    pub fn load(
        &mut self,
        indices: Option<&[u32]>,
        reply: &GatherReply,
    ) -> Result<(), TransportError> {
        let expect = indices.map_or(self.dphi.len(), |idx| idx.len());
        if reply.dphi.len() != expect || reply.r.len() != expect {
            return Err(TransportError::Protocol(format!(
                "gather reply carries {} pairs, plan has {expect}",
                reply.dphi.len()
            )));
        }
        match indices {
            None => {
                self.dphi.copy_from_slice(&reply.dphi);
                self.r.copy_from_slice(&reply.r);
            }
            Some(idx) => {
                for (s, &i) in idx.iter().enumerate() {
                    let i = i as usize;
                    if i >= self.dphi.len() {
                        return Err(TransportError::Protocol(format!(
                            "plan index {i} outside W·K = {}",
                            self.dphi.len()
                        )));
                    }
                    self.dphi[i] = reply.dphi[s];
                    self.r[i] = reply.r[s];
                }
            }
        }
        Ok(())
    }
}

impl ReduceSource for PartSource {
    fn dense_parts(&self) -> (&[f32], &[f32]) {
        (&self.dphi, &self.r)
    }
}

// ---- the transport trait and its two backends ----

/// One sweep round-trip across all workers: the replies in slot order
/// plus the measured publish/collect wall seconds (the real allgather /
/// reduce-scatter wire segments).
pub struct SweepExchange {
    pub replies: Vec<GatherReply>,
    pub publish_secs: f64,
    pub collect_secs: f64,
}

/// One end-of-batch fold collection: dense Δφ̂ parts in slot order plus
/// the measured collect wall seconds.
pub struct FoldExchange {
    pub parts: Vec<Vec<f32>>,
    pub collect_secs: f64,
}

/// What the distributed coordinator (`coordinator::dist`) needs from a
/// cluster of workers. Object-safe so backends are runtime-selectable.
pub trait Transport {
    fn n_workers(&self) -> usize;

    /// Ship each worker its batch/state-transfer frame (slot order).
    fn start_batch(&mut self, payloads: &[Vec<u8>]) -> Result<(), TransportError>;

    /// Publish per-worker Sweep frames and collect the Gather replies.
    fn sweep_exchange(&mut self, payloads: &[Vec<u8>]) -> Result<SweepExchange, TransportError>;

    /// Collect every worker's dense end-of-batch Δφ̂.
    fn collect_fold(&mut self) -> Result<FoldExchange, TransportError>;

    /// Hard-kill worker `slot`'s process (real SIGKILL on the TCP
    /// backend; a no-op for in-process logical workers, whose "death"
    /// is the fault plan's simulation).
    fn kill_worker(&mut self, slot: usize) -> Result<(), TransportError>;

    /// Tear down and re-establish every worker — the crash-recovery
    /// path between a kill and a checkpoint resume.
    fn reset(&mut self) -> Result<(), TransportError>;

    /// Clean shutdown of all workers.
    fn shutdown(&mut self) -> Result<(), TransportError>;
}

/// The degenerate single-host backend: [`WorkerState`]s called
/// directly, but through the frame codec — every payload is encoded and
/// decoded exactly as it would be on a socket, so the in-process path
/// exercises the wire format on every exchange.
pub struct InProcessTransport {
    workers: Vec<WorkerState>,
}

impl InProcessTransport {
    pub fn new(n_workers: usize, max_threads: usize) -> InProcessTransport {
        InProcessTransport {
            workers: (0..n_workers).map(|_| WorkerState::new(max_threads)).collect(),
        }
    }

    fn through_codec(kind: FrameKind, payload: &[u8]) -> Result<Vec<u8>, TransportError> {
        let frame = wire::decode_frame(&wire::encode_frame(kind, payload))?;
        Ok(frame.payload)
    }
}

impl Transport for InProcessTransport {
    fn n_workers(&self) -> usize {
        self.workers.len()
    }

    fn start_batch(&mut self, payloads: &[Vec<u8>]) -> Result<(), TransportError> {
        debug_assert_eq!(payloads.len(), self.workers.len());
        for (ws, p) in self.workers.iter_mut().zip(payloads) {
            let p = Self::through_codec(FrameKind::Batch, p)?;
            ws.on_batch(&p)?;
        }
        Ok(())
    }

    fn sweep_exchange(&mut self, payloads: &[Vec<u8>]) -> Result<SweepExchange, TransportError> {
        debug_assert_eq!(payloads.len(), self.workers.len());
        let t0 = Instant::now();
        let mut replies = Vec::with_capacity(self.workers.len());
        for (ws, p) in self.workers.iter_mut().zip(payloads) {
            let p = Self::through_codec(FrameKind::Sweep, p)?;
            let reply = ws.on_sweep(&p)?;
            let reply = Self::through_codec(FrameKind::Gather, &reply)?;
            replies.push(decode_gather(&reply)?);
        }
        // in-process, publish and collect are the same synchronous pass;
        // charge it all to the collect side
        Ok(SweepExchange { replies, publish_secs: 0.0, collect_secs: t0.elapsed().as_secs_f64() })
    }

    fn collect_fold(&mut self) -> Result<FoldExchange, TransportError> {
        let t0 = Instant::now();
        let mut parts = Vec::with_capacity(self.workers.len());
        for ws in &mut self.workers {
            let p = ws.on_fold()?;
            let p = Self::through_codec(FrameKind::FoldPart, &p)?;
            parts.push(decode_fold_part(&p)?);
        }
        Ok(FoldExchange { parts, collect_secs: t0.elapsed().as_secs_f64() })
    }

    fn kill_worker(&mut self, _slot: usize) -> Result<(), TransportError> {
        Ok(())
    }

    fn reset(&mut self) -> Result<(), TransportError> {
        // nothing to rebuild: the next start_batch re-ships full state
        Ok(())
    }

    fn shutdown(&mut self) -> Result<(), TransportError> {
        Ok(())
    }
}

/// How a [`TcpTransport`] (re)spawns its worker processes.
#[derive(Clone, Debug)]
pub struct TcpSpawnSpec {
    /// the `pobp-worker` executable
    pub exe: PathBuf,
    /// sweep threads per worker (`--threads`)
    pub threads: usize,
}

/// The real-process backend: slot-ordered TCP connections to `pobp-worker`
/// processes, every exchange length-prefixed and checksummed, every
/// socket under a read/write deadline so a hung peer fails fast with
/// [`TransportError::Timeout`] instead of wedging the run.
pub struct TcpTransport {
    listener: TcpListener,
    conns: Vec<Option<TcpStream>>,
    children: Vec<Option<Child>>,
    spawn: Option<TcpSpawnSpec>,
    n: usize,
    io_timeout: Duration,
}

impl TcpTransport {
    /// Default socket deadline (join, reply and write waits).
    pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(120);

    /// Bind a listener and spawn `n` loopback `pobp-worker` processes
    /// that connect back to it (the `--spawn` path and the test-suite
    /// path).
    pub fn spawn(n: usize, spec: TcpSpawnSpec) -> Result<TcpTransport, TransportError> {
        let listener = TcpListener::bind(("127.0.0.1", 0))?;
        let mut t = TcpTransport {
            listener,
            conns: (0..n).map(|_| None).collect(),
            children: (0..n).map(|_| None).collect(),
            spawn: Some(spec),
            n,
            io_timeout: Self::DEFAULT_IO_TIMEOUT,
        };
        t.spawn_children()?;
        t.accept_workers()?;
        Ok(t)
    }

    /// Bind `addr` and wait for `n` externally launched workers to
    /// join (the `bin/master` path without `--spawn`). Call
    /// [`TcpTransport::accept_workers`] once they are started.
    pub fn listen(addr: impl ToSocketAddrs, n: usize) -> Result<TcpTransport, TransportError> {
        Ok(TcpTransport {
            listener: TcpListener::bind(addr)?,
            conns: (0..n).map(|_| None).collect(),
            children: (0..n).map(|_| None).collect(),
            spawn: None,
            n,
            io_timeout: Self::DEFAULT_IO_TIMEOUT,
        })
    }

    /// Override the hung-socket deadline.
    pub fn with_io_timeout(mut self, t: Duration) -> TcpTransport {
        self.io_timeout = t;
        self
    }

    /// The bound listen address (what workers `--connect` to).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    fn spawn_children(&mut self) -> Result<(), TransportError> {
        let spec = self
            .spawn
            .clone()
            .ok_or_else(|| TransportError::Protocol("no spawn spec for this transport".into()))?;
        let addr = self.listener.local_addr()?;
        for slot in 0..self.n {
            let child = Command::new(&spec.exe)
                .arg("--connect")
                .arg(addr.to_string())
                .arg("--slot")
                .arg(slot.to_string())
                .arg("--threads")
                .arg(spec.threads.to_string())
                .stdin(Stdio::null())
                .spawn()
                .map_err(|e| TransportError::WorkerDead {
                    slot,
                    msg: format!("spawn {}: {e}", spec.exe.display()),
                })?;
            self.children[slot] = Some(child);
        }
        Ok(())
    }

    /// Accept and handshake all `n` workers: each sends Hello
    /// (version, slot, pid), the master validates and replies Welcome.
    /// Connections are stored slot-ordered, so arrival order never
    /// matters. Deadlined end to end.
    pub fn accept_workers(&mut self) -> Result<(), TransportError> {
        let deadline = Instant::now() + self.io_timeout;
        let mut joined = 0usize;
        while joined < self.n {
            let stream = self.accept_one(deadline)?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(self.io_timeout))?;
            stream.set_write_timeout(Some(self.io_timeout))?;
            let mut stream = stream;
            let hello = read_frame(&mut stream).map_err(io_to_timeout("worker hello"))?;
            if hello.kind != FrameKind::Hello {
                return Err(TransportError::Protocol(format!(
                    "expected Hello, got {:?}",
                    hello.kind
                )));
            }
            let (version, slot, _pid) = decode_hello(&hello.payload)?;
            if version != PROTO_VERSION {
                return Err(TransportError::Protocol(format!(
                    "worker speaks protocol v{version}, master v{PROTO_VERSION}"
                )));
            }
            if slot >= self.n {
                return Err(TransportError::Protocol(format!(
                    "worker slot {slot} outside 0..{}",
                    self.n
                )));
            }
            if self.conns[slot].is_some() {
                return Err(TransportError::Protocol(format!("duplicate worker slot {slot}")));
            }
            write_frame(&mut stream, FrameKind::Welcome, &welcome_payload(slot, self.n))
                .map_err(io_to_timeout("worker welcome"))?;
            self.conns[slot] = Some(stream);
            joined += 1;
        }
        Ok(())
    }

    fn accept_one(&self, deadline: Instant) -> Result<TcpStream, TransportError> {
        self.listener.set_nonblocking(true)?;
        let out = loop {
            match self.listener.accept() {
                Ok((s, _)) => break Ok(s),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        break Err(TransportError::Timeout("worker join"));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => break Err(e.into()),
            }
        };
        self.listener.set_nonblocking(false)?;
        let s = out?;
        s.set_nonblocking(false)?;
        Ok(s)
    }

    fn conn(&mut self, slot: usize) -> Result<&mut TcpStream, TransportError> {
        self.conns[slot].as_mut().ok_or(TransportError::WorkerDead {
            slot,
            msg: "no connection".into(),
        })
    }

    fn send(&mut self, slot: usize, kind: FrameKind, payload: &[u8]) -> Result<(), TransportError> {
        let stream = self.conn(slot)?;
        write_frame(stream, kind, payload).map_err(|e| wire_to_dead(slot, "send", e))
    }

    fn recv_expect(&mut self, slot: usize, kind: FrameKind) -> Result<Vec<u8>, TransportError> {
        let stream = self.conn(slot)?;
        let frame = read_frame(stream).map_err(|e| wire_to_dead(slot, "reply", e))?;
        if frame.kind != kind {
            return Err(TransportError::Protocol(format!(
                "worker {slot}: expected {kind:?}, got {:?}",
                frame.kind
            )));
        }
        Ok(frame.payload)
    }
}

fn io_to_timeout(what: &'static str) -> impl Fn(WireError) -> TransportError {
    move |e| match e {
        WireError::Io(ref io) if is_timeout(io) => TransportError::Timeout(what),
        other => TransportError::Wire(other),
    }
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

fn wire_to_dead(slot: usize, what: &str, e: WireError) -> TransportError {
    match e {
        WireError::Io(ref io) if is_timeout(io) => TransportError::WorkerDead {
            slot,
            msg: format!("{what} timed out (hung socket)"),
        },
        WireError::Io(io) => TransportError::WorkerDead { slot, msg: format!("{what}: {io}") },
        WireError::Truncated(t) => TransportError::WorkerDead {
            slot,
            msg: format!("{what}: connection closed ({t})"),
        },
        other => TransportError::Wire(other),
    }
}

impl Transport for TcpTransport {
    fn n_workers(&self) -> usize {
        self.n
    }

    fn start_batch(&mut self, payloads: &[Vec<u8>]) -> Result<(), TransportError> {
        debug_assert_eq!(payloads.len(), self.n);
        for (slot, p) in payloads.iter().enumerate() {
            self.send(slot, FrameKind::Batch, p)?;
        }
        Ok(())
    }

    fn sweep_exchange(&mut self, payloads: &[Vec<u8>]) -> Result<SweepExchange, TransportError> {
        debug_assert_eq!(payloads.len(), self.n);
        let t0 = Instant::now();
        for (slot, p) in payloads.iter().enumerate() {
            self.send(slot, FrameKind::Sweep, p)?;
        }
        let publish_secs = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let mut replies = Vec::with_capacity(self.n);
        for slot in 0..self.n {
            let payload = self.recv_expect(slot, FrameKind::Gather)?;
            replies.push(decode_gather(&payload)?);
        }
        Ok(SweepExchange { replies, publish_secs, collect_secs: t1.elapsed().as_secs_f64() })
    }

    fn collect_fold(&mut self) -> Result<FoldExchange, TransportError> {
        let t0 = Instant::now();
        for slot in 0..self.n {
            self.send(slot, FrameKind::Fold, &[])?;
        }
        let mut parts = Vec::with_capacity(self.n);
        for slot in 0..self.n {
            let payload = self.recv_expect(slot, FrameKind::FoldPart)?;
            parts.push(decode_fold_part(&payload)?);
        }
        Ok(FoldExchange { parts, collect_secs: t0.elapsed().as_secs_f64() })
    }

    fn kill_worker(&mut self, slot: usize) -> Result<(), TransportError> {
        self.conns[slot] = None;
        match self.children[slot].as_mut() {
            Some(child) => {
                crate::fault::sigkill(child).map_err(|e| TransportError::WorkerDead {
                    slot,
                    msg: format!("sigkill: {e}"),
                })?;
                self.children[slot] = None;
                Ok(())
            }
            None => Err(TransportError::Protocol(format!(
                "worker {slot} was not spawned by this master; cannot kill it"
            ))),
        }
    }

    fn reset(&mut self) -> Result<(), TransportError> {
        for slot in 0..self.n {
            self.conns[slot] = None;
            if let Some(child) = self.children[slot].as_mut() {
                let _ = crate::fault::sigkill(child);
            }
            self.children[slot] = None;
        }
        self.spawn_children()?;
        self.accept_workers()
    }

    fn shutdown(&mut self) -> Result<(), TransportError> {
        for slot in 0..self.n {
            if self.conns[slot].is_some() {
                let _ = self.send(slot, FrameKind::Shutdown, &[]);
            }
            self.conns[slot] = None;
            if let Some(child) = self.children[slot].as_mut() {
                // workers exit on Shutdown (or on the socket closing);
                // wait() reaps them either way
                let _ = child.wait();
            }
            self.children[slot] = None;
        }
        Ok(())
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        for child in self.children.iter_mut().flatten() {
            let _ = crate::fault::sigkill(child);
        }
    }
}

/// The `pobp-worker` event loop: connect, handshake, then serve
/// Batch/Sweep/Fold frames until Shutdown. `io_timeout = None` blocks
/// indefinitely between frames (the master controls pacing); a `Some`
/// deadline makes an abandoned worker exit instead of lingering.
pub fn serve_worker(
    addr: impl ToSocketAddrs,
    slot: usize,
    max_threads: usize,
    io_timeout: Option<Duration>,
) -> Result<(), TransportError> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(io_timeout)?;
    stream.set_write_timeout(io_timeout)?;
    write_frame(&mut stream, FrameKind::Hello, &hello_payload(slot))?;
    let welcome = read_frame(&mut stream).map_err(io_to_timeout("welcome"))?;
    if welcome.kind != FrameKind::Welcome {
        return Err(TransportError::Protocol(format!(
            "expected Welcome, got {:?}",
            welcome.kind
        )));
    }
    let (ack_slot, _n) = decode_welcome(&welcome.payload)?;
    if ack_slot != slot {
        return Err(TransportError::Protocol(format!(
            "master acknowledged slot {ack_slot}, we are slot {slot}"
        )));
    }
    let mut ws = WorkerState::new(max_threads);
    loop {
        let frame = read_frame(&mut stream).map_err(io_to_timeout("next frame"))?;
        match frame.kind {
            FrameKind::Batch => ws.on_batch(&frame.payload)?,
            FrameKind::Sweep => {
                let reply = ws.on_sweep(&frame.payload)?;
                write_frame(&mut stream, FrameKind::Gather, &reply)?;
            }
            FrameKind::Fold => {
                let reply = ws.on_fold()?;
                write_frame(&mut stream, FrameKind::FoldPart, &reply)?;
            }
            FrameKind::Shutdown => return Ok(()),
            other => {
                return Err(TransportError::Protocol(format!(
                    "unexpected frame {other:?} in worker loop"
                )));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::PhiShard;

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("inprocess"), Some(TransportKind::InProcess));
        assert_eq!(TransportKind::parse("in-process"), Some(TransportKind::InProcess));
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
        assert_eq!(TransportKind::default().name(), "inprocess");
    }

    #[test]
    fn handshake_payloads_roundtrip() {
        let (v, slot, pid) = decode_hello(&hello_payload(3)).unwrap();
        assert_eq!((v, slot), (PROTO_VERSION, 3));
        assert_eq!(pid, std::process::id());
        assert_eq!(decode_welcome(&welcome_payload(3, 8)).unwrap(), (3, 8));
        assert!(decode_hello(&welcome_payload(3, 8)[..7]).is_err());
    }

    #[test]
    fn sweep_payload_roundtrips_with_and_without_power() {
        let phi = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let tot = vec![9.0f32, 12.0];
        let (iter, p2, t2, pow) = decode_sweep(&sweep_payload(4, &phi, &tot, None)).unwrap();
        assert_eq!((iter, p2, t2), (4, phi.clone(), tot.clone()));
        assert!(pow.is_none());
        let ps = PowerSet { words: vec![0, 2], topics: vec![vec![1], vec![0, 1]] };
        let (_, _, _, pow) = decode_sweep(&sweep_payload(5, &phi, &tot, Some(&ps))).unwrap();
        let pow = pow.unwrap();
        assert_eq!(pow.words, ps.words);
        assert_eq!(pow.topics, ps.topics);
        // a bad power tag is a typed error
        let mut bad = sweep_payload(4, &phi, &tot, None);
        let tag_off = bad.len() - 4;
        bad[tag_off] = 7;
        assert!(matches!(decode_sweep(&bad), Err(WireError::Malformed(_))));
    }

    #[test]
    fn gather_and_fold_payloads_roundtrip() {
        let g = decode_gather(&gather_payload(2, &[1.5, -2.0], &[0.5, 0.25], 0.125)).unwrap();
        assert_eq!((g.iter, g.sweep_secs), (2, 0.125));
        assert_eq!(g.dphi, vec![1.5, -2.0]);
        assert_eq!(g.r, vec![0.5, 0.25]);
        assert_eq!(decode_fold_part(&fold_part_payload(&[7.0, 8.0])).unwrap(), vec![7.0, 8.0]);
        assert!(decode_fold_part(&gather_payload(2, &[1.0], &[1.0], 0.0)).is_err());
    }

    #[test]
    fn batch_payload_roundtrips_and_validates() {
        let (w, k) = (4usize, 2usize);
        let ck = Checkpoint {
            w,
            k,
            n_workers: 2,
            seed: 42,
            next_batch: 1,
            next_doc: 8,
            iter_syncs: 3,
            rng_state: [1, 2, 3, 4],
            phi: PhiShard::Replicated(vec![0.5; w * k]),
            ledger: crate::comm::Ledger::new(crate::comm::NetModel::infiniband_20gbps()),
            history: Vec::new(),
            snapshots: Vec::new(),
        };
        let shard = Csr {
            w,
            row_ptr: vec![0, 2, 3],
            col: vec![0, 3, 1],
            val: vec![1.0, 2.0, 3.0],
        };
        let params = LdaParams::paper(k);
        let payload = batch_payload(&ck, &shard, &params);
        let (ck2, shard2, params2) = decode_batch(&payload).unwrap();
        assert_eq!((ck2.w, ck2.k, ck2.rng_state), (w, k, [1, 2, 3, 4]));
        assert_eq!(shard2.row_ptr, shard.row_ptr);
        assert_eq!(shard2.col, shard.col);
        assert_eq!(shard2.val, shard.val);
        assert_eq!((params2.alpha, params2.beta), (params.alpha, params.beta));
        // a corrupted embedded checkpoint is refused with the typed error
        let mut bad = payload.clone();
        bad[8 + 40] ^= 1; // inside the checkpoint bytes
        assert!(matches!(decode_batch(&bad), Err(WireError::Malformed(_))));
        // truncated CSR tail is refused
        assert!(decode_batch(&payload[..payload.len() - 2]).is_err());
    }

    #[test]
    fn part_source_scatters_plan_order_replies() {
        let mut src = PartSource::new(6);
        let dense = GatherReply {
            iter: 1,
            dphi: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            r: vec![6.0, 5.0, 4.0, 3.0, 2.0, 1.0],
            sweep_secs: 0.0,
        };
        src.load(None, &dense).unwrap();
        assert_eq!(src.dense_parts().0, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let subset = GatherReply {
            iter: 2,
            dphi: vec![10.0, 20.0],
            r: vec![0.5, 0.25],
            sweep_secs: 0.0,
        };
        src.load(Some(&[1, 4]), &subset).unwrap();
        let (d, r) = src.dense_parts();
        assert_eq!(d, &[1.0, 10.0, 3.0, 4.0, 20.0, 6.0]);
        assert_eq!(r, &[6.0, 0.5, 4.0, 3.0, 0.25, 1.0]);
        // mismatched and out-of-range replies are protocol errors
        assert!(src.load(Some(&[1]), &subset).is_err());
        assert!(src.load(Some(&[1, 99]), &subset).is_err());
    }
}
