//! The transport boundary (Contract 8): one worker-side protocol
//! implementation behind two carriers — the in-process pool (the
//! degenerate single-host case) and real TCP worker processes
//! (`bin/master` + `bin/worker`).
//!
//! # Protocol
//!
//! Every message is one `comm::wire` frame. Per mini-batch:
//!
//! ```text
//! master                                  worker n (of N)
//!   Batch  ── checkpoint + doc shard ──▶    ShardBp::init(shard, k, rng_n)
//!   per iteration t:
//!   Sweep  ── φ̂_eff, totals, power ────▶    sweep_parallel(...)
//!          ◀── Gather: plan-order Δφ̂/r ──   (+ measured sweep seconds)
//!   at the batch boundary:
//!   Fold   ─────────────────────────────▶
//!          ◀── FoldPart: dense Δφ̂ ──────
//! ```
//!
//! The [`FrameKind::Batch`] payload *is* a `POBPCKP1` checkpoint (plus
//! the worker's document shard and the LDA params): the worker-join and
//! the state-transfer message are the same bytes a resumed run loads
//! from disk, checksummed and totals-verified by [`Checkpoint::decode`].
//! A worker therefore rejoins after a crash exactly the way a killed
//! run resumes.
//!
//! # Distributed determinism
//!
//! The master draws the same per-worker RNG splits, document ranges and
//! reduce plans as the in-process coordinator and performs the
//! owner-sliced reduction itself over [`PartSource`] mirrors of the
//! workers' gather buffers; workers contribute only [`ShardBp`] sweep
//! results, which are thread-budget-independent (Contract 1). A
//! loopback distributed run is therefore bitwise identical to the
//! in-process run in both storage modes — `rust/tests/dist_equiv.rs`
//! pins it. Wall-clock quantities (sweep seconds, measured wire
//! seconds) are measured, recorded, and never compared.
//!
//! # Supervision over flaky links (Contract 9)
//!
//! Since the chaos PR every master↔worker exchange is *supervised*:
//! requests carry a per-slot monotone sequence number (wire v2), the
//! master classifies failures into transient vs reconnect vs fatal
//! ([`classify`]), retries transient faults in place, and bridges a
//! dead connection by letting the worker rejoin — shard state retained
//! worker-side — then resending under the *same* sequence number. The
//! worker's dedup ([`serve_worker`]) never re-applies a seq it has
//! already folded; it re-serves the cached reply instead, so
//! retransmission is idempotent and any fault schedule that eventually
//! lets frames through ends bitwise identical to the fault-free run —
//! `rust/tests/chaos_equiv.rs` pins it under a deterministic
//! [`ChaosPlan`](crate::fault::ChaosPlan). Retry/reconnect costs land
//! in [`WireStats`] side accumulators (drained into the ledger, never
//! into `total_secs()`); only an exhausted retry budget escalates to
//! [`TransportError::WorkerDead`] and the Contract 6 checkpoint replay.

use std::fmt;
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use crate::comm::allreduce::{GatherBuf, ReduceSource};
use crate::comm::wire::{
    self, read_frame, write_frame, FrameKind, PayloadRd, WireError, PROTO_VERSION,
};
use crate::comm::Cluster;
use crate::corpus::Csr;
use crate::engine::bp::{Selection, ShardBp};
use crate::engine::traits::LdaParams;
use crate::fault::{chaos, ChaosFault, ChaosPlan};
use crate::sched::PowerSet;
use crate::storage::Checkpoint;
use crate::util::rng::Rng;

/// Which transport a run uses (`[run] transport = inprocess|tcp`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    /// logical workers on the in-process pool (the historical behavior)
    #[default]
    InProcess,
    /// real worker processes over TCP (`bin/master` + `bin/worker`)
    Tcp,
}

impl TransportKind {
    /// Parse a config/CLI value.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "inprocess" | "in-process" => Some(TransportKind::InProcess),
            "tcp" => Some(TransportKind::Tcp),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProcess => "inprocess",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Peer/frame context attached to supervised-transport failures
/// (Contract 9): which peer, slot, frame kind and sequence number was
/// in flight when the wire died, so a failed chaos run names the exact
/// frame instead of a bare `&'static str`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FrameCtx {
    /// remote address (empty when unknown, e.g. before any handshake)
    pub peer: String,
    pub slot: usize,
    /// [`FrameKind::name`] of the frame in flight (empty when none was)
    pub kind: &'static str,
    /// sequence number of the exchange (0 for handshake frames)
    pub seq: u64,
}

impl fmt::Display for FrameCtx {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let peer = if self.peer.is_empty() { "?" } else { &self.peer };
        let kind = if self.kind.is_empty() { "?" } else { self.kind };
        write!(f, "slot {} ({peer}) frame {kind} seq {}", self.slot, self.seq)
    }
}

/// Why a transport operation failed.
#[derive(Debug)]
pub enum TransportError {
    /// a frame was refused (corrupt, truncated, wrong layout) with no
    /// peer attribution — the worker-side / payload-decode form
    Wire(WireError),
    /// a frame from a known peer was refused — the attributed form of
    /// `Wire` the supervised master raises (Contract 9)
    Refused { ctx: FrameCtx, err: WireError },
    Io(io::Error),
    /// the peer spoke wrongly (unexpected frame kind, bad slot, shape
    /// mismatch, protocol-version mismatch)
    Protocol(String),
    /// a socket deadline expired — the hung-socket guard
    Timeout { what: &'static str, ctx: FrameCtx },
    /// a specific worker's connection or process is gone (or its retry
    /// budget is exhausted — the escalation point to checkpoint replay)
    WorkerDead { slot: usize, msg: String },
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Wire(e) => write!(f, "transport wire error: {e}"),
            TransportError::Refused { ctx, err } => {
                write!(f, "frame refused [{ctx}]: {err}")
            }
            TransportError::Io(e) => write!(f, "transport I/O: {e}"),
            TransportError::Protocol(s) => write!(f, "transport protocol violation: {s}"),
            TransportError::Timeout { what, ctx } => {
                write!(f, "transport timeout ({what}) [{ctx}]")
            }
            TransportError::WorkerDead { slot, msg } => {
                write!(f, "worker {slot} unreachable: {msg}")
            }
        }
    }
}

impl std::error::Error for TransportError {}

/// Transient-vs-fatal taxonomy of transport failures (Contract 9): what
/// the supervising master does next with a failed exchange.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultClass {
    /// the stream is still usable; resend the request on the same
    /// connection (a clean reply-deadline expiry: frame lost in flight)
    Transient,
    /// the byte stream can no longer be trusted (corrupt frame, reset,
    /// torn read): drop the connection, let the worker rejoin, resend
    /// under the same sequence number
    Reconnect,
    /// a logic/protocol defect no retry can fix; escalate immediately
    Fatal,
}

/// Classify a transport failure. Any *wire-level* refusal demands a
/// reconnect rather than a same-stream retry: a corrupted length field
/// desynchronizes the byte stream, so the connection — not the frame —
/// is the unit of recovery. Only a clean reply deadline (stream
/// aligned, frame absent) is retried in place; shape and protocol
/// violations are beyond retry.
pub fn classify(e: &TransportError) -> FaultClass {
    match e {
        TransportError::Timeout { .. } => FaultClass::Transient,
        TransportError::Wire(err) | TransportError::Refused { err, .. } => match err {
            WireError::Io(io) if is_timeout(io) => FaultClass::Transient,
            WireError::Malformed(_) => FaultClass::Fatal,
            _ => FaultClass::Reconnect,
        },
        TransportError::Io(io) if is_timeout(io) => FaultClass::Transient,
        TransportError::Io(_) => FaultClass::Reconnect,
        TransportError::WorkerDead { .. } => FaultClass::Reconnect,
        TransportError::Protocol(_) => FaultClass::Fatal,
    }
}

/// Retry/reconnect side counters (Contract 9). Drained into the
/// [`Ledger`](crate::comm::Ledger)'s side accumulators via
/// [`Transport::take_wire_stats`]; they never enter `total_secs()` or
/// the serialized checkpoint bytes, mirroring the `measured_*` fields.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WireStats {
    /// frames transmitted beyond the first attempt (resends and chaos
    /// duplicates)
    pub retrans_frames: u64,
    /// encoded bytes of those extra transmissions
    pub retrans_bytes: u64,
    /// worker rejoin cycles after a dropped connection
    pub reconnects: u64,
    /// wall seconds slept in capped-exponential rejoin backoff
    pub backoff_wait_secs: f64,
    /// chaos verdicts that fired ([`ChaosPlan`] injections)
    pub chaos_faults: u64,
}

impl WireStats {
    /// Fold another stats bundle into this one.
    pub fn merge(&mut self, o: &WireStats) {
        self.retrans_frames += o.retrans_frames;
        self.retrans_bytes += o.retrans_bytes;
        self.reconnects += o.reconnects;
        self.backoff_wait_secs += o.backoff_wait_secs;
        self.chaos_faults += o.chaos_faults;
    }

    /// Drain: return the accumulated counters, resetting to zero.
    pub fn take(&mut self) -> WireStats {
        std::mem::take(self)
    }
}

/// Worker-side connect/reconnect policy (Contract 9): bounded
/// capped-exponential backoff, used both for the initial join — so a
/// worker that races the master's listener waits instead of dying and
/// spawn order no longer matters — and for every mid-run reconnect
/// after a wire fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConnectCfg {
    /// extra connect attempts after the first (0 = a single try)
    pub retries: usize,
    /// initial backoff; doubles per attempt up to [`ConnectCfg::BACKOFF_CAP`]
    pub backoff_ms: u64,
}

impl ConnectCfg {
    /// Ceiling of the exponential backoff growth.
    pub const BACKOFF_CAP: Duration = Duration::from_secs(2);

    /// The wait before retry `attempt` (0-based): `backoff_ms << attempt`,
    /// capped.
    pub fn backoff(&self, attempt: usize) -> Duration {
        let ms = self.backoff_ms.saturating_mul(1u64 << attempt.min(6) as u32);
        Duration::from_millis(ms).min(Self::BACKOFF_CAP)
    }
}

impl Default for ConnectCfg {
    fn default() -> ConnectCfg {
        ConnectCfg { retries: 10, backoff_ms: 50 }
    }
}

impl From<WireError> for TransportError {
    fn from(e: WireError) -> TransportError {
        TransportError::Wire(e)
    }
}

impl From<io::Error> for TransportError {
    fn from(e: io::Error) -> TransportError {
        TransportError::Io(e)
    }
}

// ---- protocol payloads (wire-format conventions of the checkpoint) ----

fn hello_payload(slot: usize) -> Vec<u8> {
    let mut p = Vec::new();
    wire::put_u32(&mut p, PROTO_VERSION);
    wire::put_u64(&mut p, slot as u64);
    wire::put_u64(&mut p, std::process::id() as u64);
    p
}

fn decode_hello(payload: &[u8]) -> Result<(u32, usize, u32), WireError> {
    let mut rd = PayloadRd::new(payload, "hello");
    let version = rd.u32()?;
    let slot = rd.usize()?;
    let pid = rd.u64()? as u32;
    rd.done()?;
    Ok((version, slot, pid))
}

fn welcome_payload(slot: usize, n_workers: usize) -> Vec<u8> {
    let mut p = Vec::new();
    wire::put_u64(&mut p, slot as u64);
    wire::put_u64(&mut p, n_workers as u64);
    p
}

fn decode_welcome(payload: &[u8]) -> Result<(usize, usize), WireError> {
    let mut rd = PayloadRd::new(payload, "welcome");
    let slot = rd.usize()?;
    let n = rd.usize()?;
    rd.done()?;
    Ok((slot, n))
}

/// Build a [`FrameKind::Batch`] payload: the `POBPCKP1` join/state
/// checkpoint, the LDA smoothing params, and the worker's document
/// shard (a re-based CSR slice).
pub fn batch_payload(ck: &Checkpoint, shard: &Csr, params: &LdaParams) -> Vec<u8> {
    let ck_bytes = ck.encode();
    let mut p = Vec::with_capacity(ck_bytes.len() + 64 + 4 * (shard.row_ptr.len() + 2 * shard.col.len()));
    wire::put_u64(&mut p, ck_bytes.len() as u64);
    p.extend_from_slice(&ck_bytes);
    wire::put_u32(&mut p, params.alpha.to_bits());
    wire::put_u32(&mut p, params.beta.to_bits());
    wire::put_u64(&mut p, shard.w as u64);
    wire::put_u64(&mut p, shard.row_ptr.len() as u64);
    wire::put_u32s(&mut p, &shard.row_ptr);
    wire::put_u64(&mut p, shard.col.len() as u64);
    wire::put_u32s(&mut p, &shard.col);
    wire::put_f32s(&mut p, &shard.val);
    p
}

/// Decode a Batch payload. The embedded checkpoint goes through
/// [`Checkpoint::decode`] — per-section checksums plus the bitwise
/// totals check — so a worker refuses a torn state transfer the same
/// way a resuming run refuses a torn checkpoint file.
pub fn decode_batch(payload: &[u8]) -> Result<(Checkpoint, Csr, LdaParams), WireError> {
    let mut rd = PayloadRd::new(payload, "batch");
    let ck_len = rd.usize()?;
    let ck = Checkpoint::decode(rd.bytes(ck_len)?)
        .map_err(|e| WireError::Malformed(format!("join checkpoint refused: {e}")))?;
    let alpha = f32::from_bits(rd.u32()?);
    let beta = f32::from_bits(rd.u32()?);
    let w = rd.usize()?;
    let rows = rd.usize()?;
    if rows == 0 {
        return Err(WireError::Malformed("empty CSR row table".into()));
    }
    let row_ptr = rd.u32s(rows)?;
    let nnz = rd.usize()?;
    let col = rd.u32s(nnz)?;
    let val = rd.f32s(nnz)?;
    rd.done()?;
    if w != ck.w {
        return Err(WireError::Malformed(format!(
            "shard vocabulary {w} != checkpoint vocabulary {}",
            ck.w
        )));
    }
    if row_ptr.first() != Some(&0) || *row_ptr.last().unwrap() as usize != nnz {
        return Err(WireError::Malformed("inconsistent CSR row pointers".into()));
    }
    let params = LdaParams { k: ck.k, alpha, beta };
    Ok((ck, Csr { w, row_ptr, col, val }, params))
}

/// Build a [`FrameKind::Sweep`] payload: iteration index, the dense
/// φ̂_eff working set, the k per-topic totals, and the power set (absent
/// on full-schedule iterations).
pub fn sweep_payload(iter: usize, phi: &[f32], tot: &[f32], power: Option<&PowerSet>) -> Vec<u8> {
    let mut p = Vec::with_capacity(32 + 4 * (phi.len() + tot.len()));
    wire::put_u64(&mut p, iter as u64);
    wire::put_u64(&mut p, phi.len() as u64);
    wire::put_f32s(&mut p, phi);
    wire::put_u64(&mut p, tot.len() as u64);
    wire::put_f32s(&mut p, tot);
    match power {
        None => wire::put_u32(&mut p, 0),
        Some(ps) => {
            wire::put_u32(&mut p, 1);
            wire::put_u64(&mut p, ps.words.len() as u64);
            wire::put_u32s(&mut p, &ps.words);
            for topics in &ps.topics {
                wire::put_u64(&mut p, topics.len() as u64);
                wire::put_u32s(&mut p, topics);
            }
        }
    }
    p
}

/// Decode a Sweep payload into `(iter, φ̂, totals, power set)`.
pub fn decode_sweep(
    payload: &[u8],
) -> Result<(usize, Vec<f32>, Vec<f32>, Option<PowerSet>), WireError> {
    let mut rd = PayloadRd::new(payload, "sweep");
    let iter = rd.usize()?;
    let phi_len = rd.usize()?;
    let phi = rd.f32s(phi_len)?;
    let k = rd.usize()?;
    let tot = rd.f32s(k)?;
    let power = match rd.u32()? {
        0 => None,
        1 => {
            let n_words = rd.usize()?;
            let words = rd.u32s(n_words)?;
            let mut topics = Vec::with_capacity(n_words);
            for _ in 0..n_words {
                let len = rd.usize()?;
                topics.push(rd.u32s(len)?);
            }
            Some(PowerSet { words, topics })
        }
        other => {
            return Err(WireError::Malformed(format!("bad power-set tag {other}")));
        }
    };
    rd.done()?;
    Ok((iter, phi, tot, power))
}

/// A worker's reply to one Sweep: the plan-order gather buffer plus the
/// measured sweep seconds (used for the ledger's compute attribution,
/// never for bits).
#[derive(Clone, Debug)]
pub struct GatherReply {
    pub iter: usize,
    pub dphi: Vec<f32>,
    pub r: Vec<f32>,
    pub sweep_secs: f64,
}

fn gather_payload(iter: usize, dphi: &[f32], r: &[f32], sweep_secs: f64) -> Vec<u8> {
    debug_assert_eq!(dphi.len(), r.len());
    let mut p = Vec::with_capacity(24 + 8 * dphi.len());
    wire::put_u64(&mut p, iter as u64);
    wire::put_u64(&mut p, dphi.len() as u64);
    wire::put_f32s(&mut p, dphi);
    wire::put_f32s(&mut p, r);
    wire::put_f64(&mut p, sweep_secs);
    p
}

fn decode_gather(payload: &[u8]) -> Result<GatherReply, WireError> {
    let mut rd = PayloadRd::new(payload, "gather");
    let iter = rd.usize()?;
    let pairs = rd.usize()?;
    let dphi = rd.f32s(pairs)?;
    let r = rd.f32s(pairs)?;
    let sweep_secs = rd.f64()?;
    rd.done()?;
    Ok(GatherReply { iter, dphi, r, sweep_secs })
}

fn fold_part_payload(dphi: &[f32]) -> Vec<u8> {
    let mut p = Vec::with_capacity(8 + 4 * dphi.len());
    wire::put_u64(&mut p, dphi.len() as u64);
    wire::put_f32s(&mut p, dphi);
    p
}

fn decode_fold_part(payload: &[u8]) -> Result<Vec<f32>, WireError> {
    let mut rd = PayloadRd::new(payload, "fold part");
    let len = rd.usize()?;
    let dphi = rd.f32s(len)?;
    rd.done()?;
    Ok(dphi)
}

// ---- the worker-side protocol (one implementation, two carriers) ----

/// A worker's whole protocol state: its document shard's [`ShardBp`]
/// plus the decode/sweep/export handlers. The TCP worker binary wraps
/// this in a socket loop ([`serve_worker`]); [`InProcessTransport`]
/// calls it directly with the *same encoded payloads*, so the two
/// carriers cannot diverge semantically.
pub struct WorkerState {
    cluster: Cluster,
    w: usize,
    k: usize,
    params: LdaParams,
    shard: Option<ShardBp>,
    flat_buf: Vec<u32>,
    gather: GatherBuf,
}

impl WorkerState {
    /// A fresh worker with a local `max_threads`-thread sweep pool
    /// (thread budgets never change bits — Contract 1).
    pub fn new(max_threads: usize) -> WorkerState {
        WorkerState {
            cluster: Cluster::new(1, max_threads),
            w: 0,
            k: 0,
            params: LdaParams::paper(1),
            shard: None,
            flat_buf: Vec::new(),
            gather: GatherBuf::default(),
        }
    }

    /// Handle a Batch frame: adopt the join/state checkpoint and build
    /// this worker's shard from its document slice, seeding from the
    /// master-drawn RNG split carried in the checkpoint — the same
    /// `ShardBp::init` call, on the same bits, the in-process
    /// coordinator makes.
    pub fn on_batch(&mut self, payload: &[u8]) -> Result<(), TransportError> {
        let (ck, shard_csr, params) = decode_batch(payload)?;
        self.w = ck.w;
        self.k = ck.k;
        self.params = params;
        let mut rng = Rng::from_state(ck.rng_state);
        self.shard = Some(ShardBp::init(shard_csr, ck.k, &mut rng));
        Ok(())
    }

    /// Handle a Sweep frame: run the doc-parallel sweep against the
    /// published φ̂/totals under the published power schedule, and
    /// return the Gather payload — the plan-order gather buffer plus
    /// measured sweep seconds.
    pub fn on_sweep(&mut self, payload: &[u8]) -> Result<Vec<u8>, TransportError> {
        let (iter, phi, tot, power) = decode_sweep(payload)?;
        let shard = self
            .shard
            .as_mut()
            .ok_or_else(|| TransportError::Protocol("sweep before batch".into()))?;
        if phi.len() != self.w * self.k || tot.len() != self.k {
            return Err(TransportError::Protocol(format!(
                "sweep shapes {}/{} do not match W·K = {}·{}",
                phi.len(),
                tot.len(),
                self.w,
                self.k
            )));
        }
        let selection = match &power {
            Some(ps) => Selection::from_power(ps, self.w),
            None => Selection::full(self.w),
        };
        let budget = self.cluster.doc_threads_per_worker();
        let (_resid, timing) = shard.sweep_parallel(
            &self.cluster,
            budget,
            &phi,
            &tot,
            &selection,
            &self.params,
            true,
        );
        // the same critical-path attribution the in-process coordinator
        // records — measured, never compared bitwise
        let sweep_secs = timing.critical_path_secs(budget);
        let payload = match &power {
            None => {
                let (dphi, r) = shard.dense_parts();
                gather_payload(iter, dphi, r, sweep_secs)
            }
            Some(ps) => {
                ps.flat_indices_into(self.k, &mut self.flat_buf);
                shard.export_selected_into(&self.flat_buf, &mut self.gather);
                gather_payload(iter, &self.gather.dphi, &self.gather.r, sweep_secs)
            }
        };
        Ok(payload)
    }

    /// Handle a Fold frame: export the dense end-of-batch Δφ̂.
    pub fn on_fold(&mut self) -> Result<Vec<u8>, TransportError> {
        let shard = self
            .shard
            .as_ref()
            .ok_or_else(|| TransportError::Protocol("fold before batch".into()))?;
        let (dphi, _r) = shard.dense_parts();
        Ok(fold_part_payload(dphi))
    }
}

// ---- the master-side stand-in for a remote shard ----

/// A dense W·K mirror of a remote worker's gather buffers. The master
/// scatters each [`GatherReply`] into it and passes it — through the
/// *unchanged* `allreduce_step`/`allreduce_step_sharded` — wherever the
/// in-process coordinator passes the worker's [`ShardBp`]: the reduce
/// plan only ever reads the plan positions, and those carry exactly the
/// bits the remote shard exported, so the reduction is bitwise
/// identical to the in-process one.
pub struct PartSource {
    dphi: Vec<f32>,
    r: Vec<f32>,
}

impl PartSource {
    pub fn new(len: usize) -> PartSource {
        PartSource { dphi: vec![0.0; len], r: vec![0.0; len] }
    }

    /// Scatter a plan-order reply: dense replies replace the mirrors,
    /// subset replies land at the plan indices. Length mismatches are
    /// protocol violations, not panics.
    pub fn load(
        &mut self,
        indices: Option<&[u32]>,
        reply: &GatherReply,
    ) -> Result<(), TransportError> {
        let expect = indices.map_or(self.dphi.len(), |idx| idx.len());
        if reply.dphi.len() != expect || reply.r.len() != expect {
            return Err(TransportError::Protocol(format!(
                "gather reply carries {} pairs, plan has {expect}",
                reply.dphi.len()
            )));
        }
        match indices {
            None => {
                self.dphi.copy_from_slice(&reply.dphi);
                self.r.copy_from_slice(&reply.r);
            }
            Some(idx) => {
                for (s, &i) in idx.iter().enumerate() {
                    let i = i as usize;
                    if i >= self.dphi.len() {
                        return Err(TransportError::Protocol(format!(
                            "plan index {i} outside W·K = {}",
                            self.dphi.len()
                        )));
                    }
                    self.dphi[i] = reply.dphi[s];
                    self.r[i] = reply.r[s];
                }
            }
        }
        Ok(())
    }
}

impl ReduceSource for PartSource {
    fn dense_parts(&self) -> (&[f32], &[f32]) {
        (&self.dphi, &self.r)
    }
}

// ---- the transport trait and its two backends ----

/// One sweep round-trip across all workers: the replies in slot order
/// plus the measured publish/collect wall seconds (the real allgather /
/// reduce-scatter wire segments).
pub struct SweepExchange {
    pub replies: Vec<GatherReply>,
    pub publish_secs: f64,
    pub collect_secs: f64,
}

/// One end-of-batch fold collection: dense Δφ̂ parts in slot order plus
/// the measured collect wall seconds.
pub struct FoldExchange {
    pub parts: Vec<Vec<f32>>,
    pub collect_secs: f64,
}

/// What the distributed coordinator (`coordinator::dist`) needs from a
/// cluster of workers. Object-safe so backends are runtime-selectable.
pub trait Transport {
    fn n_workers(&self) -> usize;

    /// Ship each worker its batch/state-transfer frame (slot order).
    fn start_batch(&mut self, payloads: &[Vec<u8>]) -> Result<(), TransportError>;

    /// Publish per-worker Sweep frames and collect the Gather replies.
    fn sweep_exchange(&mut self, payloads: &[Vec<u8>]) -> Result<SweepExchange, TransportError>;

    /// Collect every worker's dense end-of-batch Δφ̂.
    fn collect_fold(&mut self) -> Result<FoldExchange, TransportError>;

    /// Advance the wire-chaos epoch to `(batch, iter)` (Contract 9):
    /// subsequent exchanges key their deterministic fault draws to this
    /// point. A no-op for transports without an attached
    /// [`ChaosPlan`].
    fn chaos_epoch(&mut self, _batch: usize, _iter: usize) {}

    /// Drain the retry/reconnect/chaos side counters accumulated since
    /// the previous call (the ledger's Contract 9 side accumulators).
    fn take_wire_stats(&mut self) -> WireStats {
        WireStats::default()
    }

    /// Hard-kill worker `slot`'s process (real SIGKILL on the TCP
    /// backend; a no-op for in-process logical workers, whose "death"
    /// is the fault plan's simulation).
    fn kill_worker(&mut self, slot: usize) -> Result<(), TransportError>;

    /// Tear down and re-establish every worker — the crash-recovery
    /// path between a kill and a checkpoint resume.
    fn reset(&mut self) -> Result<(), TransportError>;

    /// Clean shutdown of all workers.
    fn shutdown(&mut self) -> Result<(), TransportError>;
}

/// The degenerate single-host backend: [`WorkerState`]s called
/// directly, but through the frame codec — every payload is encoded and
/// decoded exactly as it would be on a socket, so the in-process path
/// exercises the wire format on every exchange.
pub struct InProcessTransport {
    workers: Vec<WorkerState>,
    chaos: Option<ChaosPlan>,
    epoch: (usize, usize),
    seqs: Vec<u64>,
    stats: WireStats,
}

impl InProcessTransport {
    pub fn new(n_workers: usize, max_threads: usize) -> InProcessTransport {
        InProcessTransport {
            workers: (0..n_workers).map(|_| WorkerState::new(max_threads)).collect(),
            chaos: None,
            epoch: (0, 0),
            seqs: vec![0; n_workers],
            stats: WireStats::default(),
        }
    }

    /// Attach a deterministic chaos plan (Contract 9): faults are
    /// applied to the encoded bytes between encode and decode,
    /// exercising the same refusal/retransmit/dedup accounting as the
    /// TCP carrier, minus the sockets.
    pub fn with_chaos(mut self, plan: ChaosPlan) -> InProcessTransport {
        self.chaos = Some(plan);
        self
    }

    fn next_seq(&mut self, slot: usize) -> u64 {
        self.seqs[slot] += 1;
        self.seqs[slot]
    }

    /// Push one frame through the codec, applying any chaos verdict for
    /// `(epoch, slot, kind, attempt)` to the encoded bytes. A mangled
    /// transmission is refused by `decode_frame` and retransmitted; the
    /// loop terminates because [`ChaosPlan::decide`] passes every
    /// attempt from its `max_attempts` on.
    fn through_codec(
        &mut self,
        slot: usize,
        kind: FrameKind,
        seq: u64,
        payload: &[u8],
    ) -> Result<Vec<u8>, TransportError> {
        let (batch, iter) = self.epoch;
        let mut attempt = 0usize;
        loop {
            let mut bytes = wire::encode_frame(kind, seq, payload);
            let fault = match &self.chaos {
                Some(plan) => plan.decide(batch, iter, slot, kind, attempt),
                None => None,
            };
            let Some(fault) = fault else {
                return Ok(wire::decode_frame(&bytes)?.payload);
            };
            self.stats.chaos_faults += 1;
            let frame_len = bytes.len() as u64;
            match fault {
                ChaosFault::Delay { .. } => {
                    // pure latency: in-process there is no wall clock
                    // to charge, the frame still arrives intact
                    return Ok(wire::decode_frame(&bytes)?.payload);
                }
                ChaosFault::Duplicate => {
                    // the second copy carries the same seq and is
                    // discarded by dedup; apply exactly one
                    let first = wire::decode_frame(&bytes)?.payload;
                    self.stats.retrans_frames += 1;
                    self.stats.retrans_bytes += frame_len;
                    return Ok(first);
                }
                ChaosFault::FlipBit => {
                    chaos::flip_bit(&mut bytes, seq ^ attempt as u64);
                    debug_assert!(wire::decode_frame(&bytes).is_err());
                }
                ChaosFault::Truncate => {
                    let cut = chaos::cut_len(bytes.len(), seq ^ attempt as u64);
                    bytes.truncate(cut);
                    debug_assert!(wire::decode_frame(&bytes).is_err());
                }
                ChaosFault::Drop | ChaosFault::Reset => {
                    // the frame never arrives; the retransmission below
                    // is the whole recovery
                    if matches!(fault, ChaosFault::Reset) {
                        self.stats.reconnects += 1;
                    }
                }
            }
            // the mangled/lost transmission forces a retransmission
            self.stats.retrans_frames += 1;
            self.stats.retrans_bytes += frame_len;
            attempt += 1;
        }
    }
}

impl Transport for InProcessTransport {
    fn n_workers(&self) -> usize {
        self.workers.len()
    }

    fn start_batch(&mut self, payloads: &[Vec<u8>]) -> Result<(), TransportError> {
        debug_assert_eq!(payloads.len(), self.workers.len());
        for slot in 0..self.workers.len() {
            let seq = self.next_seq(slot);
            let p = self.through_codec(slot, FrameKind::Batch, seq, &payloads[slot])?;
            self.workers[slot].on_batch(&p)?;
            // the BatchAck leg of the supervised protocol, through the
            // codec too so its chaos points exist on this carrier
            let _ = self.through_codec(slot, FrameKind::BatchAck, seq, &[])?;
        }
        Ok(())
    }

    fn sweep_exchange(&mut self, payloads: &[Vec<u8>]) -> Result<SweepExchange, TransportError> {
        debug_assert_eq!(payloads.len(), self.workers.len());
        let t0 = Instant::now();
        let mut replies = Vec::with_capacity(self.workers.len());
        for slot in 0..self.workers.len() {
            let seq = self.next_seq(slot);
            let p = self.through_codec(slot, FrameKind::Sweep, seq, &payloads[slot])?;
            let reply = self.workers[slot].on_sweep(&p)?;
            let reply = self.through_codec(slot, FrameKind::Gather, seq, &reply)?;
            replies.push(decode_gather(&reply)?);
        }
        // in-process, publish and collect are the same synchronous pass;
        // charge it all to the collect side
        Ok(SweepExchange { replies, publish_secs: 0.0, collect_secs: t0.elapsed().as_secs_f64() })
    }

    fn collect_fold(&mut self) -> Result<FoldExchange, TransportError> {
        let t0 = Instant::now();
        let mut parts = Vec::with_capacity(self.workers.len());
        for slot in 0..self.workers.len() {
            let seq = self.next_seq(slot);
            // the (empty) Fold request leg, so its chaos points exist
            // on this carrier too
            let _ = self.through_codec(slot, FrameKind::Fold, seq, &[])?;
            let p = self.workers[slot].on_fold()?;
            let p = self.through_codec(slot, FrameKind::FoldPart, seq, &p)?;
            parts.push(decode_fold_part(&p)?);
        }
        Ok(FoldExchange { parts, collect_secs: t0.elapsed().as_secs_f64() })
    }

    fn chaos_epoch(&mut self, batch: usize, iter: usize) {
        self.epoch = (batch, iter);
    }

    fn take_wire_stats(&mut self) -> WireStats {
        self.stats.take()
    }

    fn kill_worker(&mut self, _slot: usize) -> Result<(), TransportError> {
        Ok(())
    }

    fn reset(&mut self) -> Result<(), TransportError> {
        // nothing to rebuild: the next start_batch re-ships full state
        Ok(())
    }

    fn shutdown(&mut self) -> Result<(), TransportError> {
        Ok(())
    }
}

/// How a [`TcpTransport`] (re)spawns its worker processes.
#[derive(Clone, Debug)]
pub struct TcpSpawnSpec {
    /// the `pobp-worker` executable
    pub exe: PathBuf,
    /// sweep threads per worker (`--threads`)
    pub threads: usize,
}

/// The real-process backend: slot-ordered TCP connections to `pobp-worker`
/// processes, every exchange length-prefixed, checksummed and
/// sequence-numbered, every socket under a read/write deadline so a
/// hung peer fails fast instead of wedging the run. Exchanges are
/// supervised (Contract 9): transient faults are retried in place,
/// connection faults ride a rejoin-and-resend cycle, and only an
/// exhausted retry budget surfaces [`TransportError::WorkerDead`].
pub struct TcpTransport {
    listener: TcpListener,
    conns: Vec<Option<TcpStream>>,
    /// peer address per slot, for [`FrameCtx`] attribution
    peers: Vec<String>,
    children: Vec<Option<Child>>,
    spawn: Option<TcpSpawnSpec>,
    n: usize,
    io_timeout: Duration,
    /// per-slot monotone request sequence numbers (never reset across
    /// reconnects, so a rejoined worker's dedup stays sound)
    seqs: Vec<u64>,
    epoch: (usize, usize),
    chaos: Option<ChaosPlan>,
    stats: WireStats,
    max_frame_retries: usize,
    rejoin_backoff: ConnectCfg,
}

impl TcpTransport {
    /// Default socket deadline (join, reply and write waits).
    pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(120);

    /// Default per-exchange retry budget before escalation.
    pub const DEFAULT_FRAME_RETRIES: usize = 5;

    /// Bind a listener and spawn `n` loopback `pobp-worker` processes
    /// that connect back to it (the `--spawn` path and the test-suite
    /// path).
    pub fn spawn(n: usize, spec: TcpSpawnSpec) -> Result<TcpTransport, TransportError> {
        let listener = TcpListener::bind(("127.0.0.1", 0))?;
        let mut t = Self::from_listener(listener, n, Some(spec));
        t.spawn_children()?;
        t.accept_workers()?;
        Ok(t)
    }

    /// Bind `addr` and wait for `n` externally launched workers to
    /// join (the `bin/master` path without `--spawn`). Call
    /// [`TcpTransport::accept_workers`] once they are started.
    pub fn listen(addr: impl ToSocketAddrs, n: usize) -> Result<TcpTransport, TransportError> {
        Ok(Self::from_listener(TcpListener::bind(addr)?, n, None))
    }

    fn from_listener(listener: TcpListener, n: usize, spawn: Option<TcpSpawnSpec>) -> TcpTransport {
        TcpTransport {
            listener,
            conns: (0..n).map(|_| None).collect(),
            peers: vec![String::new(); n],
            children: (0..n).map(|_| None).collect(),
            spawn,
            n,
            io_timeout: Self::DEFAULT_IO_TIMEOUT,
            seqs: vec![0; n],
            epoch: (0, 0),
            chaos: None,
            stats: WireStats::default(),
            max_frame_retries: Self::DEFAULT_FRAME_RETRIES,
            rejoin_backoff: ConnectCfg::default(),
        }
    }

    /// Override the hung-socket deadline.
    pub fn with_io_timeout(mut self, t: Duration) -> TcpTransport {
        self.io_timeout = t;
        self
    }

    /// Attach a deterministic chaos plan (Contract 9): frames to and
    /// from workers are faulted at the master's socket edge.
    pub fn with_chaos(mut self, plan: ChaosPlan) -> TcpTransport {
        self.chaos = Some(plan);
        self
    }

    /// Override the per-exchange retry budget.
    pub fn with_frame_retries(mut self, retries: usize) -> TcpTransport {
        self.max_frame_retries = retries;
        self
    }

    /// The bound listen address (what workers `--connect` to).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    fn spawn_children(&mut self) -> Result<(), TransportError> {
        let spec = self
            .spawn
            .clone()
            .ok_or_else(|| TransportError::Protocol("no spawn spec for this transport".into()))?;
        let addr = self.listener.local_addr()?;
        for slot in 0..self.n {
            let child = Command::new(&spec.exe)
                .arg("--connect")
                .arg(addr.to_string())
                .arg("--slot")
                .arg(slot.to_string())
                .arg("--threads")
                .arg(spec.threads.to_string())
                .stdin(Stdio::null())
                .spawn()
                .map_err(|e| TransportError::WorkerDead {
                    slot,
                    msg: format!("spawn {}: {e}", spec.exe.display()),
                })?;
            self.children[slot] = Some(child);
        }
        Ok(())
    }

    /// Accept and handshake all `n` workers: each sends Hello
    /// (version, slot, pid), the master validates and replies Welcome.
    /// Connections are stored slot-ordered, so arrival order never
    /// matters. Deadlined end to end.
    pub fn accept_workers(&mut self) -> Result<(), TransportError> {
        let deadline = Instant::now() + self.io_timeout;
        let mut joined = 0usize;
        while joined < self.n {
            self.accept_and_handshake(deadline, true)?;
            joined += 1;
        }
        Ok(())
    }

    /// Accept one worker and run the Hello/Welcome handshake, storing
    /// the connection at the worker's *declared* slot. `initial` joins
    /// refuse duplicate slots; rejoins replace the dead connection.
    fn accept_and_handshake(
        &mut self,
        deadline: Instant,
        initial: bool,
    ) -> Result<usize, TransportError> {
        let mut stream = self.accept_one(deadline)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(self.io_timeout))?;
        stream.set_write_timeout(Some(self.io_timeout))?;
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
        let hello = read_frame(&mut stream).map_err(io_to_timeout("worker hello"))?;
        if hello.kind != FrameKind::Hello {
            return Err(TransportError::Protocol(format!(
                "expected Hello, got {:?}",
                hello.kind
            )));
        }
        let (version, slot, _pid) = decode_hello(&hello.payload)?;
        if version != PROTO_VERSION {
            return Err(TransportError::Protocol(format!(
                "worker speaks protocol v{version}, master v{PROTO_VERSION}"
            )));
        }
        if slot >= self.n {
            return Err(TransportError::Protocol(format!(
                "worker slot {slot} outside 0..{}",
                self.n
            )));
        }
        if initial && self.conns[slot].is_some() {
            return Err(TransportError::Protocol(format!("duplicate worker slot {slot}")));
        }
        write_frame(&mut stream, FrameKind::Welcome, 0, &welcome_payload(slot, self.n))
            .map_err(io_to_timeout("worker welcome"))?;
        self.conns[slot] = Some(stream);
        self.peers[slot] = peer;
        Ok(slot)
    }

    fn accept_one(&self, deadline: Instant) -> Result<TcpStream, TransportError> {
        self.listener.set_nonblocking(true)?;
        let out = loop {
            match self.listener.accept() {
                Ok((s, _)) => break Ok(s),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        break Err(TransportError::Timeout {
                            what: "worker join",
                            ctx: FrameCtx::default(),
                        });
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => break Err(e.into()),
            }
        };
        self.listener.set_nonblocking(false)?;
        let s = out?;
        s.set_nonblocking(false)?;
        Ok(s)
    }

    fn next_seq(&mut self, slot: usize) -> u64 {
        self.seqs[slot] += 1;
        self.seqs[slot]
    }

    fn ctx(&self, slot: usize, kind: FrameKind, seq: u64) -> FrameCtx {
        FrameCtx { peer: self.peers[slot].clone(), slot, kind: kind.name(), seq }
    }

    /// Write raw bytes to `slot`'s connection, attributing failures to
    /// the frame in flight.
    fn send_raw(&mut self, slot: usize, bytes: &[u8], ctx: FrameCtx) -> Result<(), TransportError> {
        use io::Write;
        let stream = match self.conns[slot].as_mut() {
            Some(s) => s,
            None => {
                return Err(TransportError::WorkerDead { slot, msg: "no connection".into() });
            }
        };
        stream.write_all(bytes).map_err(|e| refusal(ctx, WireError::Io(e)))
    }

    /// Write one request frame, applying the chaos verdict for
    /// `(epoch, slot, kind, attempt)` at the socket edge (Contract 9).
    fn chaos_send(
        &mut self,
        slot: usize,
        kind: FrameKind,
        seq: u64,
        payload: &[u8],
        attempt: usize,
    ) -> Result<(), TransportError> {
        let (batch, iter) = self.epoch;
        let fault = match &self.chaos {
            Some(plan) => plan.decide(batch, iter, slot, kind, attempt),
            None => None,
        };
        let bytes = wire::encode_frame(kind, seq, payload);
        let ctx = self.ctx(slot, kind, seq);
        let Some(fault) = fault else {
            return self.send_raw(slot, &bytes, ctx);
        };
        self.stats.chaos_faults += 1;
        match fault {
            ChaosFault::Delay { ms } => {
                std::thread::sleep(Duration::from_millis(ms));
                self.send_raw(slot, &bytes, ctx)
            }
            ChaosFault::Duplicate => {
                // two identical transmissions: the worker's seq dedup
                // must apply exactly one and re-serve the cached reply
                self.send_raw(slot, &bytes, ctx.clone())?;
                self.stats.retrans_frames += 1;
                self.stats.retrans_bytes += bytes.len() as u64;
                self.send_raw(slot, &bytes, ctx)
            }
            ChaosFault::FlipBit => {
                // the worker refuses the mangled frame and reconnects;
                // this side notices at the reply read
                let mut bad = bytes.clone();
                chaos::flip_bit(&mut bad, seq ^ attempt as u64);
                self.send_raw(slot, &bad, ctx)
            }
            ChaosFault::Truncate => {
                // mid-frame reset: a strict prefix of the frame, then
                // the connection dies under the worker's read
                let cut = chaos::cut_len(bytes.len(), seq ^ attempt as u64);
                let res = self.send_raw(slot, &bytes[..cut], ctx);
                self.conns[slot] = None;
                res
            }
            ChaosFault::Reset => {
                // the connection dies before anything is written
                self.conns[slot] = None;
                Ok(())
            }
            ChaosFault::Drop => {
                // half-open hang: the link stays up, the frame never
                // arrives; recovered by the reply deadline
                Ok(())
            }
        }
    }

    /// Best-effort pipelined publish of one request (the broadcast
    /// phase). Returns whether the frame is believed in flight; a
    /// failed write just marks the connection down — the supervised
    /// collect phase recovers.
    fn try_send(&mut self, slot: usize, kind: FrameKind, seq: u64, payload: &[u8]) -> bool {
        match self.chaos_send(slot, kind, seq, payload, 0) {
            Ok(()) => self.conns[slot].is_some(),
            Err(_) => {
                self.conns[slot] = None;
                false
            }
        }
    }

    /// Read the reply to `(reply_kind, seq)`, discarding stale
    /// duplicates of earlier exchanges (a chaos Duplicate's second
    /// reply) and applying any recv-direction chaos verdict to the
    /// freshly read frame.
    fn read_reply(
        &mut self,
        slot: usize,
        reply_kind: FrameKind,
        seq: u64,
        attempt: usize,
    ) -> Result<Vec<u8>, TransportError> {
        loop {
            let ctx = self.ctx(slot, reply_kind, seq);
            let frame = match self.conns[slot].as_mut() {
                None => {
                    return Err(TransportError::WorkerDead {
                        slot,
                        msg: "no connection".into(),
                    });
                }
                Some(stream) => match read_frame(stream) {
                    Ok(f) => f,
                    Err(e) => return Err(refusal(ctx, e)),
                },
            };
            if frame.seq < seq {
                // a stale duplicate from an earlier retransmission:
                // discard without applying, keep reading
                continue;
            }
            if frame.seq > seq || frame.kind != reply_kind {
                return Err(TransportError::Protocol(format!(
                    "worker {slot}: expected {} seq {seq}, got {:?} seq {}",
                    reply_kind.name(),
                    frame.kind,
                    frame.seq
                )));
            }
            let (batch, iter) = self.epoch;
            let fault = match &self.chaos {
                Some(plan) => plan.decide(batch, iter, slot, reply_kind, attempt),
                None => None,
            };
            let Some(fault) = fault else { return Ok(frame.payload) };
            self.stats.chaos_faults += 1;
            match fault {
                ChaosFault::Delay { ms } => {
                    std::thread::sleep(Duration::from_millis(ms));
                    return Ok(frame.payload);
                }
                ChaosFault::Duplicate => {
                    // an edge-duplicated reply: the dedup above discards
                    // the replay; accept the first copy and account it
                    self.stats.retrans_frames += 1;
                    self.stats.retrans_bytes += (wire::HEADER_LEN + frame.payload.len()) as u64;
                    return Ok(frame.payload);
                }
                ChaosFault::FlipBit => {
                    // the reply arrived corrupt: a checksum refusal
                    return Err(refusal(ctx, WireError::Checksum));
                }
                ChaosFault::Drop => {
                    // the reply never arrived: a clean deadline expiry
                    return Err(TransportError::Timeout { what: "reply (chaos drop)", ctx });
                }
                ChaosFault::Truncate | ChaosFault::Reset => {
                    // the reply died mid-frame / the connection reset
                    self.conns[slot] = None;
                    return Err(refusal(ctx, WireError::Truncated("chaos reset")));
                }
            }
        }
    }

    /// Wait for worker `slot` to reconnect after its connection died
    /// (Contract 9): capped-exponential backoff, then accept arrivals —
    /// each stored at its *declared* slot, so concurrently rejoining
    /// workers cannot steal each other's place — until `slot` is back.
    fn rejoin(&mut self, slot: usize, attempt: usize) -> Result<(), TransportError> {
        let wait = self.rejoin_backoff.backoff(attempt);
        if !wait.is_zero() {
            std::thread::sleep(wait);
            self.stats.backoff_wait_secs += wait.as_secs_f64();
        }
        let deadline = Instant::now() + self.io_timeout;
        while self.conns[slot].is_none() {
            self.accept_and_handshake(deadline, false)?;
        }
        self.stats.reconnects += 1;
        Ok(())
    }

    /// One supervised request/reply exchange (Contract 9): retry
    /// transient faults in place, bridge connection faults with a
    /// rejoin, resend under the same sequence number — the worker's
    /// dedup makes resends idempotent — and escalate to `WorkerDead`
    /// (and from there to checkpoint replay) once the retry budget is
    /// spent.
    fn exchange(
        &mut self,
        slot: usize,
        req_kind: FrameKind,
        reply_kind: FrameKind,
        seq: u64,
        payload: &[u8],
        already_sent: bool,
    ) -> Result<Vec<u8>, TransportError> {
        let mut need_send = !already_sent;
        let mut transmissions = usize::from(already_sent);
        let mut attempt = 0usize;
        let mut last = String::new();
        while attempt <= self.max_frame_retries {
            let step = self.exchange_once(
                slot,
                req_kind,
                reply_kind,
                seq,
                payload,
                attempt,
                need_send,
                &mut transmissions,
            );
            let err = match step {
                Ok(reply) => return Ok(reply),
                Err(e) => e,
            };
            match classify(&err) {
                FaultClass::Fatal => return Err(err),
                FaultClass::Transient => need_send = true,
                FaultClass::Reconnect => {
                    self.conns[slot] = None;
                    need_send = true;
                }
            }
            last = err.to_string();
            attempt += 1;
        }
        Err(TransportError::WorkerDead {
            slot,
            msg: format!(
                "retry budget ({}) exhausted on {} seq {seq}: {last}",
                self.max_frame_retries,
                req_kind.name()
            ),
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn exchange_once(
        &mut self,
        slot: usize,
        req_kind: FrameKind,
        reply_kind: FrameKind,
        seq: u64,
        payload: &[u8],
        attempt: usize,
        need_send: bool,
        transmissions: &mut usize,
    ) -> Result<Vec<u8>, TransportError> {
        let mut send = need_send;
        if self.conns[slot].is_none() {
            self.rejoin(slot, attempt)?;
            send = true;
        }
        if send {
            if *transmissions > 0 {
                self.stats.retrans_frames += 1;
                self.stats.retrans_bytes += (wire::HEADER_LEN + payload.len()) as u64;
            }
            *transmissions += 1;
            self.chaos_send(slot, req_kind, seq, payload, attempt)?;
        }
        self.read_reply(slot, reply_kind, seq, attempt)
    }
}

fn io_to_timeout(what: &'static str) -> impl Fn(WireError) -> TransportError {
    move |e| match e {
        WireError::Io(ref io) if is_timeout(io) => {
            TransportError::Timeout { what, ctx: FrameCtx::default() }
        }
        other => TransportError::Wire(other),
    }
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Attribute a wire-level failure to the frame exchange it killed:
/// deadline expiries become [`TransportError::Timeout`], everything
/// else the attributed [`TransportError::Refused`].
fn refusal(ctx: FrameCtx, e: WireError) -> TransportError {
    match e {
        WireError::Io(ref io) if is_timeout(io) => {
            TransportError::Timeout { what: "frame exchange", ctx }
        }
        other => TransportError::Refused { ctx, err: other },
    }
}

impl Transport for TcpTransport {
    fn n_workers(&self) -> usize {
        self.n
    }

    fn start_batch(&mut self, payloads: &[Vec<u8>]) -> Result<(), TransportError> {
        debug_assert_eq!(payloads.len(), self.n);
        let n = self.n;
        let seqs: Vec<u64> = (0..n).map(|s| self.next_seq(s)).collect();
        let mut sent = vec![false; n];
        for (slot, p) in payloads.iter().enumerate() {
            sent[slot] = self.try_send(slot, FrameKind::Batch, seqs[slot], p);
        }
        for (slot, p) in payloads.iter().enumerate() {
            self.exchange(slot, FrameKind::Batch, FrameKind::BatchAck, seqs[slot], p, sent[slot])?;
        }
        Ok(())
    }

    fn sweep_exchange(&mut self, payloads: &[Vec<u8>]) -> Result<SweepExchange, TransportError> {
        debug_assert_eq!(payloads.len(), self.n);
        let n = self.n;
        let t0 = Instant::now();
        let seqs: Vec<u64> = (0..n).map(|s| self.next_seq(s)).collect();
        let mut sent = vec![false; n];
        for (slot, p) in payloads.iter().enumerate() {
            sent[slot] = self.try_send(slot, FrameKind::Sweep, seqs[slot], p);
        }
        let publish_secs = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let mut replies = Vec::with_capacity(n);
        for (slot, p) in payloads.iter().enumerate() {
            let payload =
                self.exchange(slot, FrameKind::Sweep, FrameKind::Gather, seqs[slot], p, sent[slot])?;
            replies.push(decode_gather(&payload)?);
        }
        Ok(SweepExchange { replies, publish_secs, collect_secs: t1.elapsed().as_secs_f64() })
    }

    fn collect_fold(&mut self) -> Result<FoldExchange, TransportError> {
        let n = self.n;
        let t0 = Instant::now();
        let seqs: Vec<u64> = (0..n).map(|s| self.next_seq(s)).collect();
        let mut sent = vec![false; n];
        for (slot, seq) in seqs.iter().enumerate() {
            sent[slot] = self.try_send(slot, FrameKind::Fold, *seq, &[]);
        }
        let mut parts = Vec::with_capacity(n);
        for slot in 0..n {
            let payload = self.exchange(
                slot,
                FrameKind::Fold,
                FrameKind::FoldPart,
                seqs[slot],
                &[],
                sent[slot],
            )?;
            parts.push(decode_fold_part(&payload)?);
        }
        Ok(FoldExchange { parts, collect_secs: t0.elapsed().as_secs_f64() })
    }

    fn chaos_epoch(&mut self, batch: usize, iter: usize) {
        self.epoch = (batch, iter);
    }

    fn take_wire_stats(&mut self) -> WireStats {
        self.stats.take()
    }

    fn kill_worker(&mut self, slot: usize) -> Result<(), TransportError> {
        self.conns[slot] = None;
        match self.children[slot].as_mut() {
            Some(child) => {
                crate::fault::sigkill(child).map_err(|e| TransportError::WorkerDead {
                    slot,
                    msg: format!("sigkill: {e}"),
                })?;
                self.children[slot] = None;
                Ok(())
            }
            None => Err(TransportError::Protocol(format!(
                "worker {slot} was not spawned by this master; cannot kill it"
            ))),
        }
    }

    fn reset(&mut self) -> Result<(), TransportError> {
        for slot in 0..self.n {
            self.conns[slot] = None;
            if let Some(child) = self.children[slot].as_mut() {
                let _ = crate::fault::sigkill(child);
            }
            self.children[slot] = None;
        }
        self.spawn_children()?;
        self.accept_workers()
    }

    fn shutdown(&mut self) -> Result<(), TransportError> {
        for slot in 0..self.n {
            if self.conns[slot].is_some() {
                let ctx = self.ctx(slot, FrameKind::Shutdown, 0);
                let bytes = wire::encode_frame(FrameKind::Shutdown, 0, &[]);
                let _ = self.send_raw(slot, &bytes, ctx);
            }
            self.conns[slot] = None;
            if let Some(child) = self.children[slot].as_mut() {
                // workers exit on Shutdown (or on the socket closing);
                // wait() reaps them either way
                let _ = child.wait();
            }
            self.children[slot] = None;
        }
        Ok(())
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        for child in self.children.iter_mut().flatten() {
            let _ = crate::fault::sigkill(child);
        }
    }
}

/// The `pobp-worker` event loop (supervised, Contract 9): connect with
/// bounded backoff — so racing the master's listener waits instead of
/// dying and spawn order no longer matters — handshake, then serve
/// Batch/Sweep/Fold frames until Shutdown.
///
/// Recoverable wire faults (a corrupt frame, a reset socket, a torn
/// read) drop the *session* and reconnect with the worker's shard
/// state **retained**: `ShardBp` accumulates Δφ̂ within a batch, so a
/// mid-batch reconnect must resume exactly where the wire died, and the
/// master's same-seq resends bridge the gap. Requests whose sequence
/// number was already applied are never re-applied — the cached reply
/// is re-served — which is what makes retransmission idempotent and the
/// recovered run bitwise identical (`chaos_equiv.rs`).
///
/// `io_timeout = None` blocks indefinitely between frames (the master
/// controls pacing); a `Some` deadline makes an abandoned worker exit
/// instead of lingering.
pub fn serve_worker(
    addr: impl ToSocketAddrs,
    slot: usize,
    max_threads: usize,
    io_timeout: Option<Duration>,
    connect: ConnectCfg,
) -> Result<(), TransportError> {
    let mut ws = WorkerState::new(max_threads);
    let mut last_applied = 0u64;
    let mut reply_cache: Option<(u64, FrameKind, Vec<u8>)> = None;
    loop {
        let mut stream = connect_with_backoff(&addr, slot, io_timeout, connect)?;
        match serve_session(&mut stream, &mut ws, &mut last_applied, &mut reply_cache) {
            Ok(()) => return Ok(()),
            Err(e) if session_recoverable(&e) => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Dial the master with capped-exponential backoff and run the
/// Hello/Welcome handshake — the initial join and every mid-run
/// reconnect go through here. Protocol violations (version/slot
/// mismatch) abort immediately; liveness failures burn a retry.
fn connect_with_backoff(
    addr: &impl ToSocketAddrs,
    slot: usize,
    io_timeout: Option<Duration>,
    cfg: ConnectCfg,
) -> Result<TcpStream, TransportError> {
    let mut last: Option<TransportError> = None;
    for attempt in 0..=cfg.retries {
        if attempt > 0 {
            std::thread::sleep(cfg.backoff(attempt - 1));
        }
        match try_connect(addr, slot, io_timeout) {
            Ok(stream) => return Ok(stream),
            Err(e) if matches!(classify(&e), FaultClass::Fatal) => return Err(e),
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| TransportError::Protocol("no connect attempts made".into())))
}

fn try_connect(
    addr: &impl ToSocketAddrs,
    slot: usize,
    io_timeout: Option<Duration>,
) -> Result<TcpStream, TransportError> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(io_timeout)?;
    stream.set_write_timeout(io_timeout)?;
    write_frame(&mut stream, FrameKind::Hello, 0, &hello_payload(slot))?;
    let welcome = read_frame(&mut stream).map_err(io_to_timeout("welcome"))?;
    if welcome.kind != FrameKind::Welcome {
        return Err(TransportError::Protocol(format!(
            "expected Welcome, got {:?}",
            welcome.kind
        )));
    }
    let (ack_slot, _n) = decode_welcome(&welcome.payload)?;
    if ack_slot != slot {
        return Err(TransportError::Protocol(format!(
            "master acknowledged slot {ack_slot}, we are slot {slot}"
        )));
    }
    Ok(stream)
}

/// One connected session: serve frames until Shutdown or a wire fault.
/// Duplicate requests (`seq <= last_applied`) are never re-applied —
/// the cached reply is re-served when the seq matches — so the
/// master's retransmissions are idempotent (Contract 9).
fn serve_session(
    stream: &mut TcpStream,
    ws: &mut WorkerState,
    last_applied: &mut u64,
    reply_cache: &mut Option<(u64, FrameKind, Vec<u8>)>,
) -> Result<(), TransportError> {
    loop {
        let frame = read_frame(stream).map_err(io_to_timeout("next frame"))?;
        if frame.seq != 0 && frame.seq <= *last_applied {
            if let Some((seq, kind, payload)) = reply_cache.as_ref() {
                if *seq == frame.seq {
                    write_frame(stream, *kind, *seq, payload)?;
                }
            }
            continue;
        }
        match frame.kind {
            FrameKind::Batch => {
                ws.on_batch(&frame.payload)?;
                send_reply(stream, last_applied, reply_cache, frame.seq, FrameKind::BatchAck, Vec::new())?;
            }
            FrameKind::Sweep => {
                let reply = ws.on_sweep(&frame.payload)?;
                send_reply(stream, last_applied, reply_cache, frame.seq, FrameKind::Gather, reply)?;
            }
            FrameKind::Fold => {
                let reply = ws.on_fold()?;
                send_reply(stream, last_applied, reply_cache, frame.seq, FrameKind::FoldPart, reply)?;
            }
            FrameKind::Shutdown => return Ok(()),
            other => {
                return Err(TransportError::Protocol(format!(
                    "unexpected frame {other:?} in worker loop"
                )));
            }
        }
    }
}

/// Apply-and-reply: record the seq as applied and cache the reply
/// *before* writing it, so a reply lost to a dying socket is re-served
/// — not recomputed, never re-applied — when the master resends.
fn send_reply(
    stream: &mut TcpStream,
    last_applied: &mut u64,
    reply_cache: &mut Option<(u64, FrameKind, Vec<u8>)>,
    seq: u64,
    kind: FrameKind,
    payload: Vec<u8>,
) -> Result<(), TransportError> {
    if seq != 0 {
        *last_applied = seq;
    }
    *reply_cache = Some((seq, kind, payload));
    let (s, k, p) = reply_cache.as_ref().expect("reply cache just filled");
    write_frame(stream, *k, *s, p)?;
    Ok(())
}

/// Which session errors reconnect instead of exiting: every wire-level
/// corruption class (a corrupted length field desynchronizes the byte
/// stream, so the connection is the recovery unit) and every
/// liveness-class socket error. Deadline expiries exit — that is the
/// abandoned-worker guard — and protocol violations are fatal.
fn session_recoverable(e: &TransportError) -> bool {
    fn recoverable_io(k: io::ErrorKind) -> bool {
        matches!(
            k,
            io::ErrorKind::ConnectionReset
                | io::ErrorKind::ConnectionAborted
                | io::ErrorKind::BrokenPipe
                | io::ErrorKind::UnexpectedEof
                | io::ErrorKind::NotConnected
        )
    }
    match e {
        TransportError::Wire(WireError::Io(io)) | TransportError::Io(io) => {
            recoverable_io(io.kind())
        }
        TransportError::Wire(
            WireError::BadMagic
            | WireError::BadKind(_)
            | WireError::Checksum
            | WireError::Oversized { .. }
            | WireError::Truncated(_),
        ) => true,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::PhiShard;

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("inprocess"), Some(TransportKind::InProcess));
        assert_eq!(TransportKind::parse("in-process"), Some(TransportKind::InProcess));
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
        assert_eq!(TransportKind::default().name(), "inprocess");
    }

    #[test]
    fn handshake_payloads_roundtrip() {
        let (v, slot, pid) = decode_hello(&hello_payload(3)).unwrap();
        assert_eq!((v, slot), (PROTO_VERSION, 3));
        assert_eq!(pid, std::process::id());
        assert_eq!(decode_welcome(&welcome_payload(3, 8)).unwrap(), (3, 8));
        assert!(decode_hello(&welcome_payload(3, 8)[..7]).is_err());
    }

    #[test]
    fn sweep_payload_roundtrips_with_and_without_power() {
        let phi = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let tot = vec![9.0f32, 12.0];
        let (iter, p2, t2, pow) = decode_sweep(&sweep_payload(4, &phi, &tot, None)).unwrap();
        assert_eq!((iter, p2, t2), (4, phi.clone(), tot.clone()));
        assert!(pow.is_none());
        let ps = PowerSet { words: vec![0, 2], topics: vec![vec![1], vec![0, 1]] };
        let (_, _, _, pow) = decode_sweep(&sweep_payload(5, &phi, &tot, Some(&ps))).unwrap();
        let pow = pow.unwrap();
        assert_eq!(pow.words, ps.words);
        assert_eq!(pow.topics, ps.topics);
        // a bad power tag is a typed error
        let mut bad = sweep_payload(4, &phi, &tot, None);
        let tag_off = bad.len() - 4;
        bad[tag_off] = 7;
        assert!(matches!(decode_sweep(&bad), Err(WireError::Malformed(_))));
    }

    #[test]
    fn gather_and_fold_payloads_roundtrip() {
        let g = decode_gather(&gather_payload(2, &[1.5, -2.0], &[0.5, 0.25], 0.125)).unwrap();
        assert_eq!((g.iter, g.sweep_secs), (2, 0.125));
        assert_eq!(g.dphi, vec![1.5, -2.0]);
        assert_eq!(g.r, vec![0.5, 0.25]);
        assert_eq!(decode_fold_part(&fold_part_payload(&[7.0, 8.0])).unwrap(), vec![7.0, 8.0]);
        assert!(decode_fold_part(&gather_payload(2, &[1.0], &[1.0], 0.0)).is_err());
    }

    #[test]
    fn batch_payload_roundtrips_and_validates() {
        let (w, k) = (4usize, 2usize);
        let ck = Checkpoint {
            w,
            k,
            n_workers: 2,
            seed: 42,
            next_batch: 1,
            next_doc: 8,
            iter_syncs: 3,
            rng_state: [1, 2, 3, 4],
            phi: PhiShard::Replicated(vec![0.5; w * k]),
            ledger: crate::comm::Ledger::new(crate::comm::NetModel::infiniband_20gbps()),
            history: Vec::new(),
            snapshots: Vec::new(),
        };
        let shard = Csr {
            w,
            row_ptr: vec![0, 2, 3],
            col: vec![0, 3, 1],
            val: vec![1.0, 2.0, 3.0],
        };
        let params = LdaParams::paper(k);
        let payload = batch_payload(&ck, &shard, &params);
        let (ck2, shard2, params2) = decode_batch(&payload).unwrap();
        assert_eq!((ck2.w, ck2.k, ck2.rng_state), (w, k, [1, 2, 3, 4]));
        assert_eq!(shard2.row_ptr, shard.row_ptr);
        assert_eq!(shard2.col, shard.col);
        assert_eq!(shard2.val, shard.val);
        assert_eq!((params2.alpha, params2.beta), (params.alpha, params.beta));
        // a corrupted embedded checkpoint is refused with the typed error
        let mut bad = payload.clone();
        bad[8 + 40] ^= 1; // inside the checkpoint bytes
        assert!(matches!(decode_batch(&bad), Err(WireError::Malformed(_))));
        // truncated CSR tail is refused
        assert!(decode_batch(&payload[..payload.len() - 2]).is_err());
    }

    #[test]
    fn fault_taxonomy_classifies_each_error() {
        use io::ErrorKind;
        let ctx = FrameCtx { peer: "127.0.0.1:9".into(), slot: 1, kind: "Sweep", seq: 3 };
        // a clean reply-deadline expiry retries in place
        assert_eq!(
            classify(&TransportError::Timeout { what: "reply", ctx: ctx.clone() }),
            FaultClass::Transient
        );
        // every wire refusal demands a reconnect: the stream may be
        // desynchronized past the corrupt frame
        for err in [
            WireError::Checksum,
            WireError::BadMagic,
            WireError::BadKind(99),
            WireError::Oversized { len: 1 << 40 },
            WireError::Truncated("eof"),
        ] {
            assert_eq!(
                classify(&TransportError::Refused { ctx: ctx.clone(), err }),
                FaultClass::Reconnect
            );
        }
        assert_eq!(
            classify(&TransportError::Io(io::Error::from(ErrorKind::ConnectionReset))),
            FaultClass::Reconnect
        );
        assert_eq!(
            classify(&TransportError::Io(io::Error::from(ErrorKind::TimedOut))),
            FaultClass::Transient
        );
        assert_eq!(
            classify(&TransportError::WorkerDead { slot: 0, msg: "gone".into() }),
            FaultClass::Reconnect
        );
        // shape/protocol defects are beyond retry
        assert_eq!(classify(&TransportError::Protocol("bad slot".into())), FaultClass::Fatal);
        assert_eq!(
            classify(&TransportError::Wire(WireError::Malformed("shape".into()))),
            FaultClass::Fatal
        );
        // the attached context names the exact frame that died
        let msg = TransportError::Timeout { what: "reply", ctx }.to_string();
        assert!(msg.contains("slot 1"), "{msg}");
        assert!(msg.contains("Sweep"), "{msg}");
        assert!(msg.contains("seq 3"), "{msg}");
        assert!(msg.contains("127.0.0.1:9"), "{msg}");
    }

    #[test]
    fn connect_backoff_doubles_and_caps() {
        let cfg = ConnectCfg { retries: 8, backoff_ms: 50 };
        assert_eq!(cfg.backoff(0), Duration::from_millis(50));
        assert_eq!(cfg.backoff(2), Duration::from_millis(200));
        assert_eq!(cfg.backoff(20), ConnectCfg::BACKOFF_CAP);
        assert_eq!(ConnectCfg::default(), ConnectCfg { retries: 10, backoff_ms: 50 });
    }

    #[test]
    fn wire_stats_merge_and_take() {
        let mut a = WireStats {
            retrans_frames: 2,
            retrans_bytes: 100,
            reconnects: 1,
            backoff_wait_secs: 0.5,
            chaos_faults: 3,
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.retrans_frames, 4);
        assert_eq!(a.retrans_bytes, 200);
        assert_eq!(a.reconnects, 2);
        assert_eq!(a.chaos_faults, 6);
        assert!((a.backoff_wait_secs - 1.0).abs() < 1e-12);
        let drained = a.take();
        assert_eq!(drained.retrans_frames, 4);
        assert_eq!(a, WireStats::default());
    }

    #[test]
    fn session_recoverability_matches_the_taxonomy() {
        // corruption classes reconnect (the stream is the recovery unit)
        assert!(session_recoverable(&TransportError::Wire(WireError::Checksum)));
        assert!(session_recoverable(&TransportError::Wire(WireError::BadMagic)));
        assert!(session_recoverable(&TransportError::Wire(WireError::Truncated("t"))));
        assert!(session_recoverable(&TransportError::Io(io::Error::from(
            io::ErrorKind::ConnectionReset
        ))));
        assert!(session_recoverable(&TransportError::Wire(WireError::Io(io::Error::from(
            io::ErrorKind::UnexpectedEof
        )))));
        // deadline expiries exit (abandoned-worker guard), protocol
        // violations and payload-shape defects are fatal
        assert!(!session_recoverable(&TransportError::Timeout {
            what: "next frame",
            ctx: FrameCtx::default(),
        }));
        assert!(!session_recoverable(&TransportError::Protocol("nope".into())));
        assert!(!session_recoverable(&TransportError::Wire(WireError::Malformed("m".into()))));
    }

    #[test]
    fn part_source_scatters_plan_order_replies() {
        let mut src = PartSource::new(6);
        let dense = GatherReply {
            iter: 1,
            dphi: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            r: vec![6.0, 5.0, 4.0, 3.0, 2.0, 1.0],
            sweep_secs: 0.0,
        };
        src.load(None, &dense).unwrap();
        assert_eq!(src.dense_parts().0, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let subset = GatherReply {
            iter: 2,
            dphi: vec![10.0, 20.0],
            r: vec![0.5, 0.25],
            sweep_secs: 0.0,
        };
        src.load(Some(&[1, 4]), &subset).unwrap();
        let (d, r) = src.dense_parts();
        assert_eq!(d, &[1.0, 10.0, 3.0, 4.0, 20.0, 6.0]);
        assert_eq!(r, &[6.0, 0.5, 4.0, 3.0, 0.25, 1.0]);
        // mismatched and out-of-range replies are protocol errors
        assert!(src.load(Some(&[1]), &subset).is_err());
        assert!(src.load(Some(&[1, 99]), &subset).is_err());
    }
}
