//! Length-prefixed, checksummed frame codec for the distributed
//! transport (Contract 8).
//!
//! One frame on the socket:
//!
//! ```text
//! "POBPWIR1" | kind u32 | payload_len u64 | seq u64 | fnv1a64(kind|len|seq|payload) u64 | payload
//! ```
//!
//! All integers little-endian; f64/f32 payload fields as raw IEEE bits —
//! the same conventions as the `POBPCKP1` checkpoint format
//! (`storage::checkpoint`), whose FNV-1a-64 checksum this module reuses.
//! The checksum covers the `kind` and `len` header fields *and* the
//! payload, so every single-bit corruption of a frame is refused: a
//! magic flip fails [`WireError::BadMagic`], a kind/len/payload/checksum
//! flip fails [`WireError::BadKind`], [`WireError::Oversized`],
//! [`WireError::Truncated`] or [`WireError::Checksum`]
//! (`mod tests` pins the full corruption matrix, mirroring the
//! checkpoint suite's style).
//!
//! Frames are deliberately dumb: framing and integrity only. What the
//! payload *means* per [`FrameKind`] is the transport protocol
//! (`comm::transport`); decoding those payloads uses [`PayloadRd`],
//! which surfaces shape defects as typed [`WireError`]s too.

use std::fmt;
use std::io::{self, Read, Write};

use crate::storage::checkpoint::fnv1a64;

/// Frame magic: "POBPWIR1" (POBP wire format, version 1).
pub const MAGIC: &[u8; 8] = b"POBPWIR1";
/// Protocol version carried in Hello/Welcome payloads; bumped on any
/// frame- or payload-layout change (v2 added the per-frame sequence
/// number for idempotent retransmission, Contract 9).
pub const PROTO_VERSION: u32 = 2;
/// Frame header bytes: magic + kind + len + seq + checksum.
pub const HEADER_LEN: usize = 8 + 4 + 8 + 8 + 8;
/// Largest accepted payload (1 GiB) — refuses absurd length fields
/// before any allocation happens.
pub const MAX_FRAME: u64 = 1 << 30;

/// What a frame carries; the transport protocol's message vocabulary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// worker → master: join handshake (proto version, slot, pid)
    Hello = 1,
    /// master → worker: handshake accept (slot, cluster size)
    Welcome = 2,
    /// master → worker: batch/state transfer — a full `POBPCKP1`
    /// checkpoint plus the worker's document shard and LDA params
    Batch = 3,
    /// master → worker: publish φ̂_eff + totals + power set; sweep
    Sweep = 4,
    /// worker → master: plan-order gather buffer + measured sweep secs
    Gather = 5,
    /// master → worker: request the end-of-batch dense Δφ̂
    Fold = 6,
    /// worker → master: the dense Δφ̂ part
    FoldPart = 7,
    /// master → worker: clean exit
    Shutdown = 8,
    /// worker → master: the batch/state transfer was applied (empty
    /// payload; the header's sequence number echoes the Batch request).
    /// Gives the Batch exchange a reply so the retry/reconnect
    /// supervision (Contract 9) covers it like Sweep and Fold.
    BatchAck = 9,
}

impl FrameKind {
    fn from_u32(v: u32) -> Option<FrameKind> {
        Some(match v {
            1 => FrameKind::Hello,
            2 => FrameKind::Welcome,
            3 => FrameKind::Batch,
            4 => FrameKind::Sweep,
            5 => FrameKind::Gather,
            6 => FrameKind::Fold,
            7 => FrameKind::FoldPart,
            8 => FrameKind::Shutdown,
            9 => FrameKind::BatchAck,
            _ => return None,
        })
    }

    /// Human-readable name — the frame-context label error reports use.
    pub fn name(&self) -> &'static str {
        match self {
            FrameKind::Hello => "Hello",
            FrameKind::Welcome => "Welcome",
            FrameKind::Batch => "Batch",
            FrameKind::Sweep => "Sweep",
            FrameKind::Gather => "Gather",
            FrameKind::Fold => "Fold",
            FrameKind::FoldPart => "FoldPart",
            FrameKind::Shutdown => "Shutdown",
            FrameKind::BatchAck => "BatchAck",
        }
    }
}

/// Why a frame (or a payload field) was refused.
#[derive(Debug)]
pub enum WireError {
    Io(io::Error),
    /// not a POBP wire frame
    BadMagic,
    /// an unknown frame kind tag
    BadKind(u32),
    /// length field beyond [`MAX_FRAME`]
    Oversized {
        len: u64,
    },
    /// the header, payload or a payload field ended early (or a buffer
    /// carried trailing garbage)
    Truncated(&'static str),
    /// header+payload checksum mismatch
    Checksum,
    /// the payload decoded but is internally inconsistent (bad shape,
    /// bad enum tag, refused sub-payload)
    Malformed(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire I/O: {e}"),
            WireError::BadMagic => write!(f, "not a POBP wire frame (bad magic)"),
            WireError::BadKind(v) => write!(f, "unknown frame kind {v}"),
            WireError::Oversized { len } => {
                write!(f, "frame payload of {len} bytes exceeds the {MAX_FRAME}-byte cap")
            }
            WireError::Truncated(what) => write!(f, "truncated frame ({what})"),
            WireError::Checksum => write!(f, "frame checksum mismatch"),
            WireError::Malformed(s) => write!(f, "malformed payload: {s}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// A decoded frame: kind, sequence number, and raw payload bytes.
///
/// The sequence number (v2) makes retransmission idempotent: the master
/// stamps every request with a per-slot monotone counter, replies echo
/// it, and a worker that already applied `seq` re-serves its cached
/// reply instead of re-applying the fold (Contract 9). Handshake and
/// Shutdown frames use `seq = 0`, which is never deduplicated.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub kind: FrameKind,
    pub seq: u64,
    pub payload: Vec<u8>,
}

/// The checksum input: the mutable header fields then the payload, so a
/// flipped bit anywhere outside the magic lands in the digest.
fn frame_digest(kind: u32, len: u64, seq: u64, payload: &[u8]) -> u64 {
    let mut head = [0u8; 20];
    head[..4].copy_from_slice(&kind.to_le_bytes());
    head[4..12].copy_from_slice(&len.to_le_bytes());
    head[12..].copy_from_slice(&seq.to_le_bytes());
    let mut h = fnv1a64(&head);
    // continue the same FNV-1a stream over the payload
    for &b in payload {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Encode one frame into a fresh buffer.
pub fn encode_frame(kind: FrameKind, seq: u64, payload: &[u8]) -> Vec<u8> {
    let len = payload.len() as u64;
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(kind as u32).to_le_bytes());
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(&frame_digest(kind as u32, len, seq, payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Decode exactly one frame from a complete buffer; trailing bytes are
/// refused (a socket reader uses [`read_frame`] instead).
pub fn decode_frame(bytes: &[u8]) -> Result<Frame, WireError> {
    if bytes.len() < HEADER_LEN {
        return Err(WireError::Truncated("frame header"));
    }
    if &bytes[..8] != MAGIC {
        return Err(WireError::BadMagic);
    }
    let kind_raw = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let len = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
    let seq = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
    let sum = u64::from_le_bytes(bytes[28..36].try_into().unwrap());
    if len > MAX_FRAME {
        return Err(WireError::Oversized { len });
    }
    let payload = &bytes[HEADER_LEN..];
    if payload.len() as u64 != len {
        return Err(WireError::Truncated("frame payload"));
    }
    if frame_digest(kind_raw, len, seq, payload) != sum {
        return Err(WireError::Checksum);
    }
    let kind = FrameKind::from_u32(kind_raw).ok_or(WireError::BadKind(kind_raw))?;
    Ok(Frame { kind, seq, payload: payload.to_vec() })
}

/// Write one frame to a stream (single `write_all` — one syscall per
/// frame on an unbuffered socket).
pub fn write_frame(
    w: &mut impl Write,
    kind: FrameKind,
    seq: u64,
    payload: &[u8],
) -> Result<(), WireError> {
    w.write_all(&encode_frame(kind, seq, payload))?;
    Ok(())
}

/// Read exactly one frame from a stream, validating magic, kind, length
/// cap and checksum before returning. An EOF inside the header or
/// payload surfaces as [`WireError::Truncated`] so a half-closed socket
/// is distinguishable from ordinary I/O failure.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, WireError> {
    let mut head = [0u8; HEADER_LEN];
    read_exact_or(r, &mut head, "frame header")?;
    if &head[..8] != MAGIC {
        return Err(WireError::BadMagic);
    }
    let kind_raw = u32::from_le_bytes(head[8..12].try_into().unwrap());
    let len = u64::from_le_bytes(head[12..20].try_into().unwrap());
    let seq = u64::from_le_bytes(head[20..28].try_into().unwrap());
    let sum = u64::from_le_bytes(head[28..36].try_into().unwrap());
    if len > MAX_FRAME {
        return Err(WireError::Oversized { len });
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_or(r, &mut payload, "frame payload")?;
    if frame_digest(kind_raw, len, seq, &payload) != sum {
        return Err(WireError::Checksum);
    }
    let kind = FrameKind::from_u32(kind_raw).ok_or(WireError::BadKind(kind_raw))?;
    Ok(Frame { kind, seq, payload })
}

fn read_exact_or(r: &mut impl Read, buf: &mut [u8], what: &'static str) -> Result<(), WireError> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            WireError::Truncated(what)
        } else {
            WireError::Io(e)
        }
    })
}

// ---- payload field helpers (checkpoint-format conventions) ----

/// Append a little-endian u64.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a little-endian u32.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append an f64 as raw IEEE bits.
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

/// Append f32s as raw IEEE bits.
pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(4 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Append u32s.
pub fn put_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    out.reserve(4 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Sequential payload reader with typed truncation errors — the wire
/// twin of the checkpoint decoder's section reader.
pub struct PayloadRd<'a> {
    b: &'a [u8],
    pos: usize,
    what: &'static str,
}

impl<'a> PayloadRd<'a> {
    pub fn new(b: &'a [u8], what: &'static str) -> PayloadRd<'a> {
        PayloadRd { b, pos: 0, what }
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::Truncated(self.what))?;
        let s = self.b.get(self.pos..end).ok_or(WireError::Truncated(self.what))?;
        self.pos = end;
        Ok(s)
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize, WireError> {
        Ok(self.u64()? as usize)
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        let raw = self.bytes(4usize.checked_mul(n).ok_or(WireError::Truncated(self.what))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    pub fn u32s(&mut self, n: usize) -> Result<Vec<u32>, WireError> {
        let raw = self.bytes(4usize.checked_mul(n).ok_or(WireError::Truncated(self.what))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn done(&self) -> Result<(), WireError> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(WireError::Truncated(self.what))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        let mut payload = Vec::new();
        put_u64(&mut payload, 7);
        put_f64(&mut payload, 0.25);
        put_f32s(&mut payload, &[1.0, -2.5, 3e-7]);
        put_u32s(&mut payload, &[0, 9, 4096]);
        encode_frame(FrameKind::Gather, 7, &payload)
    }

    #[test]
    fn roundtrip_encode_decode_reencode() {
        let bytes = sample();
        let frame = decode_frame(&bytes).unwrap();
        assert_eq!((frame.kind, frame.seq), (FrameKind::Gather, 7));
        assert_eq!(encode_frame(frame.kind, frame.seq, &frame.payload), bytes);
        // the stream reader agrees with the buffer decoder
        let mut cursor = io::Cursor::new(bytes.clone());
        assert_eq!(read_frame(&mut cursor).unwrap(), frame);
        // empty payloads roundtrip too, and seq 0 is representable
        let empty = encode_frame(FrameKind::Fold, 0, &[]);
        let f = decode_frame(&empty).unwrap();
        assert_eq!((f.kind, f.seq, f.payload.len()), (FrameKind::Fold, 0, 0));
    }

    #[test]
    fn every_single_bit_corruption_is_refused() {
        // the corruption matrix, mirroring the checkpoint suite: flip
        // each bit of the encoded frame in turn; every flip must be
        // refused with a typed error, and the error class must match
        // the corrupted region
        let clean = sample();
        for byte in 0..clean.len() {
            for bit in 0..8 {
                let mut bad = clean.clone();
                bad[byte] ^= 1 << bit;
                let err = decode_frame(&bad)
                    .expect_err(&format!("bit {bit} of byte {byte} accepted"));
                match byte {
                    0..=7 => assert!(
                        matches!(err, WireError::BadMagic),
                        "magic byte {byte}: {err}"
                    ),
                    8..=11 => assert!(
                        matches!(err, WireError::Checksum | WireError::BadKind(_)),
                        "kind byte {byte}: {err}"
                    ),
                    12..=19 => assert!(
                        matches!(
                            err,
                            WireError::Checksum
                                | WireError::Oversized { .. }
                                | WireError::Truncated(_)
                        ),
                        "len byte {byte}: {err}"
                    ),
                    // the sequence-number field is covered by the digest
                    // alone: any flip there is a checksum refusal
                    20..=27 => assert!(
                        matches!(err, WireError::Checksum),
                        "seq byte {byte}: {err}"
                    ),
                    _ => assert!(
                        matches!(err, WireError::Checksum),
                        "checksum/payload byte {byte}: {err}"
                    ),
                }
                // the stream path refuses the same flip (any typed error)
                assert!(read_frame(&mut io::Cursor::new(bad)).is_err());
            }
        }
    }

    #[test]
    fn truncated_frames_refused_at_every_cut() {
        let clean = sample();
        for cut in 0..clean.len() {
            let err = decode_frame(&clean[..cut]).expect_err("truncation accepted");
            assert!(
                matches!(err, WireError::Truncated(_)),
                "cut {cut}: {err}"
            );
            let err = read_frame(&mut io::Cursor::new(clean[..cut].to_vec()))
                .expect_err("stream truncation accepted");
            assert!(
                matches!(err, WireError::Truncated(_)),
                "stream cut {cut}: {err}"
            );
        }
        // trailing garbage after a complete frame is refused by the
        // buffer decoder (the stream reader leaves it for the next read)
        let mut extra = clean.clone();
        extra.push(0);
        assert!(matches!(decode_frame(&extra), Err(WireError::Truncated(_))));
    }

    #[test]
    fn foreign_and_oversized_frames_refused() {
        // a checkpoint file is not a wire frame
        let mut foreign = sample();
        foreign[..8].copy_from_slice(b"POBPCKP1");
        assert!(matches!(decode_frame(&foreign), Err(WireError::BadMagic)));
        // an unknown kind tag is refused even with a valid checksum
        let mut payload = Vec::new();
        put_u64(&mut payload, 1);
        let mut bad_kind = Vec::new();
        bad_kind.extend_from_slice(MAGIC);
        put_u32(&mut bad_kind, 99);
        put_u64(&mut bad_kind, payload.len() as u64);
        put_u64(&mut bad_kind, 5);
        put_u64(&mut bad_kind, frame_digest(99, payload.len() as u64, 5, &payload));
        bad_kind.extend_from_slice(&payload);
        assert!(matches!(decode_frame(&bad_kind), Err(WireError::BadKind(99))));
        // a length field past the cap is refused before allocation,
        // regardless of checksum validity
        let mut huge = Vec::new();
        huge.extend_from_slice(MAGIC);
        put_u32(&mut huge, FrameKind::Batch as u32);
        put_u64(&mut huge, MAX_FRAME + 1);
        put_u64(&mut huge, 0);
        put_u64(&mut huge, frame_digest(FrameKind::Batch as u32, MAX_FRAME + 1, 0, &[]));
        assert!(matches!(
            decode_frame(&huge),
            Err(WireError::Oversized { len }) if len == MAX_FRAME + 1
        ));
        assert!(matches!(
            read_frame(&mut io::Cursor::new(huge)),
            Err(WireError::Oversized { .. })
        ));
    }

    #[test]
    fn payload_reader_types_and_truncation() {
        let mut p = Vec::new();
        put_u64(&mut p, 42);
        put_f64(&mut p, -1.5);
        put_f32s(&mut p, &[7.0, 8.0]);
        put_u32s(&mut p, &[3]);
        let mut rd = PayloadRd::new(&p, "test payload");
        assert_eq!(rd.u64().unwrap(), 42);
        assert_eq!(rd.f64().unwrap(), -1.5);
        assert_eq!(rd.f32s(2).unwrap(), vec![7.0, 8.0]);
        assert_eq!(rd.u32s(1).unwrap(), vec![3]);
        rd.done().unwrap();
        // over-read and under-consume both surface as Truncated
        let mut rd = PayloadRd::new(&p, "test payload");
        assert!(matches!(rd.f32s(1 << 20), Err(WireError::Truncated(_))));
        let mut rd = PayloadRd::new(&p, "test payload");
        let _ = rd.u64().unwrap();
        assert!(matches!(rd.done(), Err(WireError::Truncated(_))));
    }
}
