//! Communication/computation ledger: the measurement substrate behind
//! Figs. 10–12 and the scalability analysis of §3.2.2.
//!
//! Every synchronization the coordinator performs is recorded with its
//! exact per-processor payload bytes; simulated communication time comes
//! from the [`NetModel`], simulated computation time is the max of the
//! measured per-worker shard times (the barrier semantics of Fig. 1).
//!
//! # Overlap mode
//!
//! Pipelined iterations (the POBP coordinator's overlap mode and the
//! YLDA parameter-server baseline) charge `max(compute, comm)` per
//! iteration instead of the serialized sum —
//! [`Ledger::record_overlapped_iter`]. Bytes, sync counts and the
//! per-segment reduce-scatter/allgather attribution stay exact; the
//! hidden fraction `min(compute, comm)` accumulates in
//! [`Ledger::overlap_saved_secs`] and is subtracted from
//! [`Ledger::total_secs`], so `total = Σ max(compute, comm)` over
//! overlapped iterations plus the serialized cost of everything else.
//!
//! A sync recorded with [`Ledger::record_sync_deferred`] (the
//! end-of-batch fold in overlap mode) keeps its bytes, count and segment
//! attribution exact at record time, but its comm seconds join the
//! *next* overlapped iteration's window: that iteration charges
//! `max(compute, comm + deferred)` — the fold's transfer hides behind
//! the next batch's t = 1 sweep. If no overlapped iteration follows
//! (the run's last fold), the deferred comm stays fully serialized in
//! the total.
//!
//! # Exactness invariants (both modes)
//!
//! Overlap changes *time* accounting only; the measured quantities the
//! figures depend on never degrade:
//!
//! * payload bytes per sync are exact (`2 · 4 · pairs` for iteration
//!   syncs, `4 · W · K` for the end-of-batch fold);
//! * sync counts are exact: every mini-batch charges its iterations
//!   plus one final fold, `sync_count = Σ_batches (iters + 1)`;
//! * per-segment attribution covers comm exactly:
//!   `reduce_scatter_secs + allgather_secs = comm_secs` per event;
//! * the decomposition `total = compute + exposed_comm` holds, with
//!   [`Ledger::exposed_comm_secs`] `= comm − overlap_saved` — the
//!   communication an overlapped algorithm could not hide.

use crate::comm::net::NetModel;

/// One synchronization event.
#[derive(Clone, Copy, Debug)]
pub struct SyncEvent {
    /// mini-batch index m (0 for batch algorithms)
    pub batch: usize,
    /// iteration t within the batch
    pub iter: usize,
    /// payload bytes each processor contributes (the sub-matrix size)
    pub payload_bytes: usize,
    /// processors participating
    pub n: usize,
    /// simulated seconds for this allreduce (= reduce-scatter + allgather)
    pub comm_secs: f64,
    /// reduce-scatter segment of `comm_secs` (Rabenseifner first half)
    pub reduce_scatter_secs: f64,
    /// allgather segment of `comm_secs` (Rabenseifner second half)
    pub allgather_secs: f64,
}

/// Accumulates the simulated cost decomposition of a training run.
#[derive(Clone, Debug)]
pub struct Ledger {
    pub net: NetModel,
    pub events: Vec<SyncEvent>,
    /// simulated compute seconds (sum over iterations of max-over-workers)
    pub compute_secs: f64,
    /// total wire bytes moved (all links)
    pub wire_bytes: u64,
    /// total simulated communication seconds
    pub comm_secs: f64,
    /// communication seconds hidden behind computation by overlap-mode
    /// iterations (Σ min(compute, comm)); subtracted from the
    /// serialized total
    pub overlap_saved_secs: f64,
    /// comm seconds of deferred syncs (the overlap-mode end-of-batch
    /// fold) awaiting the next overlapped iteration's window; drained by
    /// [`Ledger::record_overlapped_iter`], harmlessly serialized if the
    /// run ends first
    deferred_comm_secs: f64,
}

impl Ledger {
    pub fn new(net: NetModel) -> Ledger {
        Ledger {
            net,
            events: Vec::new(),
            compute_secs: 0.0,
            wire_bytes: 0,
            comm_secs: 0.0,
            overlap_saved_secs: 0.0,
            deferred_comm_secs: 0.0,
        }
    }

    /// Record an allreduce of `payload_bytes` per processor across `n`,
    /// attributing time to the reduce-scatter and allgather segments.
    /// Returns the simulated seconds charged.
    pub fn record_sync(
        &mut self,
        batch: usize,
        iter: usize,
        payload_bytes: usize,
        n: usize,
    ) -> f64 {
        let reduce_scatter_secs = self.net.reduce_scatter_secs(payload_bytes, n);
        let allgather_secs = self.net.allgather_secs(payload_bytes, n);
        let comm_secs = reduce_scatter_secs + allgather_secs;
        self.wire_bytes += self.net.allreduce_wire_bytes(payload_bytes, n) as u64;
        self.comm_secs += comm_secs;
        self.events.push(SyncEvent {
            batch,
            iter,
            payload_bytes,
            n,
            comm_secs,
            reduce_scatter_secs,
            allgather_secs,
        });
        comm_secs
    }

    /// Record a synchronization whose reduce-scatter and allgather move
    /// **different byte counts** — the sharded storage mode, where the
    /// reduce half ships this sync's reduced pairs while the allgather
    /// half republishes only the *next working set's* slices (zero when
    /// the batch is stopping). `payload_bytes` records the reduce
    /// payload (the Eq. 6 per-processor quantity, comparable across
    /// modes); wire bytes count both halves. The per-event invariant
    /// `reduce_scatter_secs + allgather_secs = comm_secs` is preserved,
    /// and a split with equal halves is byte- and second-identical to
    /// [`Ledger::record_sync`]. Returns the simulated seconds charged.
    pub fn record_sync_split(
        &mut self,
        batch: usize,
        iter: usize,
        reduce_bytes: usize,
        gather_bytes: usize,
        n: usize,
    ) -> f64 {
        let reduce_scatter_secs = self.net.reduce_scatter_secs(reduce_bytes, n);
        // zero gather bytes means the allgather is *skipped* (a stopping
        // iteration republishes nothing), not a zero-byte collective —
        // no latency steps either
        let allgather_secs = if gather_bytes == 0 {
            0.0
        } else {
            self.net.allgather_secs(gather_bytes, n)
        };
        let comm_secs = reduce_scatter_secs + allgather_secs;
        // each half moves its own bytes over the N−1 ring links
        self.wire_bytes +=
            ((reduce_bytes + gather_bytes) * n.saturating_sub(1)) as u64;
        self.comm_secs += comm_secs;
        self.events.push(SyncEvent {
            batch,
            iter,
            payload_bytes: reduce_bytes,
            n,
            comm_secs,
            reduce_scatter_secs,
            allgather_secs,
        });
        comm_secs
    }

    /// Record one iteration's computation: barrier semantics charge the
    /// slowest worker's measured seconds.
    pub fn record_compute(&mut self, per_worker_secs: &[f64]) -> f64 {
        let secs = per_worker_secs.iter().cloned().fold(0.0, f64::max);
        self.compute_secs += secs;
        secs
    }

    /// Record a synchronization whose communication is *deferred* into
    /// the next overlapped iteration's window — the end-of-batch fold in
    /// overlap mode: the leader must fold before freeing the batch, but
    /// the fold's full-matrix *transfer* can hide behind the next
    /// batch's t = 1 sweep. Bytes, the sync count and the per-segment
    /// attribution are recorded exactly now; the comm seconds join the
    /// next [`Ledger::record_overlapped_iter`] (or stay serialized if
    /// none follows). Returns the simulated comm seconds of the sync.
    pub fn record_sync_deferred(
        &mut self,
        batch: usize,
        iter: usize,
        payload_bytes: usize,
        n: usize,
    ) -> f64 {
        let secs = self.record_sync(batch, iter, payload_bytes, n);
        self.deferred_comm_secs += secs;
        secs
    }

    /// Record one *pipelined* iteration — computation and the allreduce
    /// overlapped (the coordinator's pipelined allreduce / the YLDA
    /// parameter-server semantics): the iteration contributes
    /// `max(compute, comm + deferred)` to the total — its own allreduce
    /// plus any deferred fold comm hide behind the sweep — while bytes,
    /// the sync count and the per-segment reduce-scatter/allgather
    /// attribution stay exact. Returns the seconds charged.
    pub fn record_overlapped_iter(
        &mut self,
        batch: usize,
        iter: usize,
        payload_bytes: usize,
        n: usize,
        per_worker_secs: &[f64],
    ) -> f64 {
        let compute = self.record_compute(per_worker_secs);
        let comm = self.record_sync(batch, iter, payload_bytes, n);
        let deferred = std::mem::take(&mut self.deferred_comm_secs);
        // the charging rule lives in one place: the network model's
        // overlapped-iteration time, max(compute, comm + deferred)
        let iter_secs = self.net.overlapped_iter_secs(compute, payload_bytes, n, deferred);
        self.overlap_saved_secs += compute + comm + deferred - iter_secs;
        iter_secs
    }

    /// Total simulated elapsed seconds: compute + comm serialized as in
    /// the synchronous MPA of Fig. 1, minus the fraction hidden by
    /// overlap-mode iterations (zero unless
    /// [`Ledger::record_overlapped_iter`] was used).
    pub fn total_secs(&self) -> f64 {
        self.compute_secs + self.comm_secs - self.overlap_saved_secs
    }

    /// Communication seconds left *exposed* on the critical path:
    /// `comm − overlap_saved` = Σ (comm − compute)⁺ over overlapped
    /// iterations plus the full comm of serialized syncs. This is the
    /// "communication time" the figure benches plot — an overlapped
    /// algorithm (YLDA, pipelined POBP) only pays for the part its
    /// computation cannot hide.
    pub fn exposed_comm_secs(&self) -> f64 {
        self.comm_secs - self.overlap_saved_secs
    }

    /// Fraction of the serialized cost hidden by overlap:
    /// `1 − total / (compute + comm)`. Zero for fully serialized runs;
    /// approaches 0.5 when compute and comm are balanced and every
    /// iteration overlaps.
    pub fn overlap_efficiency(&self) -> f64 {
        let serial = self.compute_secs + self.comm_secs;
        if serial > 0.0 {
            self.overlap_saved_secs / serial
        } else {
            0.0
        }
    }

    /// Number of synchronizations performed.
    pub fn sync_count(&self) -> usize {
        self.events.len()
    }

    /// Seconds spent in the reduce-scatter segments of all allreduces.
    pub fn reduce_scatter_secs_total(&self) -> f64 {
        self.events.iter().map(|e| e.reduce_scatter_secs).sum()
    }

    /// Seconds spent in the allgather segments of all allreduces.
    pub fn allgather_secs_total(&self) -> f64 {
        self.events.iter().map(|e| e.allgather_secs).sum()
    }

    /// Payload bytes summed over events (per-processor view; the paper's
    /// Eq. 5/6 quantity divided by N).
    pub fn payload_bytes_total(&self) -> u64 {
        self.events.iter().map(|e| e.payload_bytes as u64).sum()
    }

    pub fn merge(&mut self, other: &Ledger) {
        self.events.extend_from_slice(&other.events);
        self.compute_secs += other.compute_secs;
        self.wire_bytes += other.wire_bytes;
        self.comm_secs += other.comm_secs;
        self.overlap_saved_secs += other.overlap_saved_secs;
        self.deferred_comm_secs += other.deferred_comm_secs;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_accumulates() {
        let mut l = Ledger::new(NetModel::infiniband_20gbps());
        let t1 = l.record_sync(0, 1, 1 << 20, 8);
        let t2 = l.record_sync(0, 2, 1 << 10, 8);
        assert!(t1 > t2);
        assert_eq!(l.sync_count(), 2);
        assert!((l.comm_secs - (t1 + t2)).abs() < 1e-15);
        assert_eq!(l.payload_bytes_total(), (1 << 20) + (1 << 10));
        assert_eq!(
            l.wire_bytes,
            (2 * ((1u64 << 20) + (1 << 10)) * 7) as u64
        );
    }

    #[test]
    fn per_segment_attribution_covers_comm_time() {
        let mut l = Ledger::new(NetModel::infiniband_20gbps());
        l.record_sync(0, 1, 1 << 16, 8);
        l.record_sync(0, 2, 1 << 12, 8);
        let rs = l.reduce_scatter_secs_total();
        let ag = l.allgather_secs_total();
        assert!(rs > 0.0 && ag > 0.0);
        assert!((rs + ag - l.comm_secs).abs() < 1e-15);
        for e in &l.events {
            let gap = (e.reduce_scatter_secs + e.allgather_secs - e.comm_secs).abs();
            assert!(gap < 1e-18);
        }
    }

    #[test]
    fn split_sync_attribution_is_exact() {
        let net = NetModel::infiniband_20gbps();
        // equal halves degenerate to record_sync exactly
        let mut a = Ledger::new(net);
        let mut b = Ledger::new(net);
        let ta = a.record_sync(0, 1, 1 << 16, 8);
        let tb = b.record_sync_split(0, 1, 1 << 16, 1 << 16, 8);
        assert_eq!(ta.to_bits(), tb.to_bits());
        assert_eq!(a.wire_bytes, b.wire_bytes);
        assert_eq!(a.payload_bytes_total(), b.payload_bytes_total());
        // asymmetric halves: segments cover comm, wire counts both
        let mut l = Ledger::new(net);
        let t = l.record_sync_split(0, 2, 1 << 14, 1 << 18, 8);
        let e = l.events[0];
        assert!((e.reduce_scatter_secs + e.allgather_secs - e.comm_secs).abs() < 1e-18);
        assert!((t - e.comm_secs).abs() < 1e-18);
        assert_eq!(e.payload_bytes, 1 << 14);
        assert_eq!(l.wire_bytes, (((1u64 << 14) + (1 << 18)) * 7) as u64);
        // a zero-byte allgather (stopping iteration) charges no gather time
        let mut z = Ledger::new(net);
        z.record_sync_split(0, 3, 1 << 14, 0, 8);
        assert_eq!(z.events[0].allgather_secs, 0.0);
        assert!(z.events[0].reduce_scatter_secs > 0.0);
    }

    #[test]
    fn compute_is_max_over_workers() {
        let mut l = Ledger::new(NetModel::infiniband_20gbps());
        let secs = l.record_compute(&[0.1, 0.5, 0.2]);
        assert_eq!(secs, 0.5);
        assert_eq!(l.compute_secs, 0.5);
        assert_eq!(l.total_secs(), 0.5);
    }

    #[test]
    fn overlap_mode_totals_are_sum_of_maxes() {
        let net = NetModel::infiniband_20gbps();
        let mut l = Ledger::new(net);
        let mut expect = 0.0;
        // one comm-bound, one compute-bound, one balanced-ish iteration
        for (it, &(c, bytes)) in
            [(1e-6f64, 1usize << 22), (0.5, 1 << 10), (2e-4, 1 << 20)].iter().enumerate()
        {
            let m = net.allreduce_secs(bytes, 8);
            let charged = l.record_overlapped_iter(0, it + 1, bytes, 8, &[c]);
            assert!((charged - c.max(m)).abs() < 1e-15, "iter {it}");
            expect += c.max(m);
        }
        assert!(
            (l.total_secs() - expect).abs() < 1e-12,
            "total {} vs sum-of-maxes {expect}",
            l.total_secs()
        );
        // attribution stays exact: segments cover comm, bytes counted
        assert!((l.reduce_scatter_secs_total() + l.allgather_secs_total()
            - l.comm_secs)
            .abs()
            < 1e-15);
        assert_eq!(l.sync_count(), 3);
        assert!(l.overlap_saved_secs > 0.0);
        assert!(l.overlap_efficiency() > 0.0 && l.overlap_efficiency() < 0.5);
        // total decomposes as compute + exposed comm
        assert!(
            (l.total_secs() - (l.compute_secs + l.exposed_comm_secs())).abs() < 1e-15
        );
        // a serialized sync afterwards is charged in full
        let before = l.total_secs();
        let t = l.record_sync(0, 9, 1 << 16, 8);
        assert!((l.total_secs() - before - t).abs() < 1e-15);
    }

    #[test]
    fn deferred_fold_comm_hides_behind_next_overlapped_iter() {
        let net = NetModel::infiniband_20gbps();
        let mut l = Ledger::new(net);
        // the fold: bytes/segments exact now, comm deferred
        let fold_bytes = 1usize << 20;
        let fold_comm = l.record_sync_deferred(0, 5, fold_bytes, 8);
        assert!(fold_comm > 0.0);
        assert_eq!(l.sync_count(), 1);
        assert_eq!(l.payload_bytes_total(), fold_bytes as u64);
        // a compute-bound t = 1 iteration follows: the fold's comm (and
        // the iteration's own allreduce) hide entirely behind the sweep
        let iter_bytes = 1usize << 10;
        let iter_comm = net.allreduce_secs(iter_bytes, 8);
        let compute = (fold_comm + iter_comm) * 10.0;
        let charged = l.record_overlapped_iter(0, 1, iter_bytes, 8, &[compute]);
        assert!((charged - compute).abs() < 1e-15, "fold comm not hidden");
        assert!(
            (l.overlap_saved_secs - (fold_comm + iter_comm)).abs() < 1e-15,
            "saved {} vs fold {} + iter {}",
            l.overlap_saved_secs,
            fold_comm,
            iter_comm
        );
        assert!((l.total_secs() - compute).abs() < 1e-12);
        // a comm-bound iteration after a second fold: charged the comm
        // side, max(compute, comm + deferred)
        let before = l.total_secs();
        let fold2 = l.record_sync_deferred(1, 5, fold_bytes, 8);
        let tiny = 1e-9;
        let charged2 = l.record_overlapped_iter(1, 1, iter_bytes, 8, &[tiny]);
        assert!((charged2 - (fold2 + iter_comm)).abs() < 1e-15);
        // fold + iteration together cost exactly the overlapped window
        assert!((l.total_secs() - before - charged2).abs() < 1e-12);
        // a trailing deferred fold with no iteration after it stays
        // fully serialized in the total
        let before = l.total_secs();
        let fold3 = l.record_sync_deferred(2, 5, fold_bytes, 8);
        assert!((l.total_secs() - before - fold3).abs() < 1e-12);
    }

    #[test]
    fn merge_combines() {
        let mut a = Ledger::new(NetModel::infiniband_20gbps());
        a.record_sync(0, 1, 100, 4);
        let mut b = Ledger::new(NetModel::infiniband_20gbps());
        b.record_sync(1, 1, 200, 4);
        b.record_compute(&[0.3]);
        a.merge(&b);
        assert_eq!(a.sync_count(), 2);
        assert_eq!(a.payload_bytes_total(), 300);
        assert_eq!(a.compute_secs, 0.3);
    }
}
