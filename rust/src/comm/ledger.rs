//! Communication/computation ledger: the measurement substrate behind
//! Figs. 10–12 and the scalability analysis of §3.2.2.
//!
//! Every synchronization the coordinator performs is recorded with its
//! exact per-processor payload bytes; simulated communication time comes
//! from the [`NetModel`], simulated computation time is the max of the
//! measured per-worker shard times (the barrier semantics of Fig. 1).
//!
//! # Overlap mode
//!
//! Pipelined iterations (the POBP coordinator's overlap mode and the
//! YLDA parameter-server baseline) charge `max(compute, comm)` per
//! iteration instead of the serialized sum —
//! [`Ledger::record_overlapped_iter`]. Bytes, sync counts and the
//! per-segment reduce-scatter/allgather attribution stay exact; the
//! hidden fraction `min(compute, comm)` accumulates in
//! [`Ledger::overlap_saved_secs`] and is subtracted from
//! [`Ledger::total_secs`], so `total = Σ max(compute, comm)` over
//! overlapped iterations plus the serialized cost of everything else.
//!
//! A sync recorded with [`Ledger::record_sync_deferred`] (the
//! end-of-batch fold in overlap mode) keeps its bytes, count and segment
//! attribution exact at record time, but its comm seconds join the
//! *next* overlapped iteration's window: that iteration charges
//! `max(compute, comm + deferred)` — the fold's transfer hides behind
//! the next batch's t = 1 sweep. If no overlapped iteration follows
//! (the run's last fold), the deferred comm stays fully serialized in
//! the total.
//!
//! # Exactness invariants (both modes)
//!
//! Overlap changes *time* accounting only; the measured quantities the
//! figures depend on never degrade:
//!
//! * payload bytes per sync are exact (`2 · 4 · pairs` for iteration
//!   syncs, `4 · W · K` for the end-of-batch fold);
//! * sync counts are exact: every mini-batch charges its iterations
//!   plus one final fold, `sync_count = Σ_batches (iters + 1)`;
//! * per-segment attribution covers comm exactly:
//!   `reduce_scatter_secs + allgather_secs = comm_secs` per event;
//! * the decomposition `total = compute + exposed_comm` holds, with
//!   [`Ledger::exposed_comm_secs`] `= comm − overlap_saved` — the
//!   communication an overlapped algorithm could not hide.

use crate::comm::net::NetModel;
use crate::comm::transport::WireStats;

/// One synchronization event.
#[derive(Clone, Copy, Debug)]
pub struct SyncEvent {
    /// mini-batch index m (0 for batch algorithms)
    pub batch: usize,
    /// iteration t within the batch
    pub iter: usize,
    /// payload bytes each processor contributes (the sub-matrix size)
    pub payload_bytes: usize,
    /// processors participating
    pub n: usize,
    /// simulated seconds for this allreduce (= reduce-scatter + allgather)
    pub comm_secs: f64,
    /// reduce-scatter segment of `comm_secs` (Rabenseifner first half)
    pub reduce_scatter_secs: f64,
    /// allgather segment of `comm_secs` (Rabenseifner second half)
    pub allgather_secs: f64,
}

/// One synchronization's *measured* wire seconds next to the α–β
/// estimate it would replace — the calibration record the distributed
/// transport emits (Contract 8). The modeled fields are copied from the
/// paired [`SyncEvent`] so the bench JSON can report model error per
/// segment without re-joining the two lists.
#[derive(Clone, Copy, Debug)]
pub struct MeasuredSeg {
    pub batch: usize,
    pub iter: usize,
    /// α–β estimate of the reduce-scatter segment
    pub modeled_reduce_secs: f64,
    /// α–β estimate of the allgather segment
    pub modeled_gather_secs: f64,
    /// measured wall seconds collecting the gather buffers (the real
    /// reduce-scatter wire segment, worker compute excluded)
    pub measured_reduce_secs: f64,
    /// measured wall seconds publishing the working set (the real
    /// allgather wire segment)
    pub measured_gather_secs: f64,
}

/// Accumulates the simulated cost decomposition of a training run.
#[derive(Clone, Debug)]
pub struct Ledger {
    pub net: NetModel,
    pub events: Vec<SyncEvent>,
    /// simulated compute seconds (sum over iterations of max-over-workers)
    pub compute_secs: f64,
    /// total wire bytes moved (all links)
    pub wire_bytes: u64,
    /// total simulated communication seconds
    pub comm_secs: f64,
    /// communication seconds hidden behind computation by overlap-mode
    /// iterations (Σ min(compute, comm)); subtracted from the
    /// serialized total
    pub overlap_saved_secs: f64,
    /// comm seconds of deferred syncs (the overlap-mode end-of-batch
    /// fold) awaiting the next overlapped iteration's window; drained by
    /// [`Ledger::record_overlapped_iter`], harmlessly serialized if the
    /// run ends first
    deferred_comm_secs: f64,
    /// simulated seconds the barrier waited on injected stragglers
    /// beyond the healthy critical path (Σ over iterations of
    /// `max(base + delay) − max(base)`); degraded-run attribution only
    /// — never enters [`Ledger::total_secs`]
    pub straggler_wait_secs: f64,
    /// straggler-timeout polls issued while waiting (exponential
    /// backoff against the α–β timeout,
    /// [`NetModel::straggler_timeout_secs`])
    pub straggler_polls: u64,
    /// measured wall seconds spent writing checkpoints (I/O, not
    /// simulated; excluded from [`Ledger::total_secs`])
    pub checkpoint_secs: f64,
    /// bytes of checkpoint files written
    pub checkpoint_bytes: u64,
    /// checkpoints written
    pub checkpoint_count: u64,
    /// simulated seconds of training replayed after recoveries (work
    /// past the restored checkpoint that the killed attempt had already
    /// paid for); degraded-run attribution only
    pub recovery_replay_secs: f64,
    /// recoveries performed (restore-and-replay cycles)
    pub recovery_count: u64,
    /// measured-vs-modeled wire seconds per sync, recorded by the
    /// distributed transport (empty on simulated runs). Measured wall
    /// time: excluded from [`Ledger::total_secs`] *and* from
    /// checkpoint serialization — like per-worker compute seconds, it
    /// is re-measured and never compared bitwise
    pub measured: Vec<MeasuredSeg>,
    /// Σ measured reduce-scatter (gather-collect) wire seconds
    pub measured_reduce_secs: f64,
    /// Σ measured allgather (publish) wire seconds
    pub measured_gather_secs: f64,
    /// frames the supervised transport retransmitted after a wire fault
    /// (Contract 9). Like the `measured_*` fields these are recovery
    /// *effort* accumulators — they never enter [`Ledger::total_secs`]
    /// and are never serialized into checkpoints (re-measured on
    /// resume, never compared bitwise)
    pub retrans_frames: u64,
    /// bytes of retransmitted frames (header + payload, per resend)
    pub retrans_bytes: u64,
    /// worker connections re-established mid-run (rejoin handshakes)
    pub reconnects: u64,
    /// measured wall seconds slept in reconnect backoff
    pub backoff_wait_secs: f64,
    /// wire faults the chaos plan injected (0 on chaos-free runs)
    pub chaos_faults: u64,
}

impl Ledger {
    pub fn new(net: NetModel) -> Ledger {
        Ledger {
            net,
            events: Vec::new(),
            compute_secs: 0.0,
            wire_bytes: 0,
            comm_secs: 0.0,
            overlap_saved_secs: 0.0,
            deferred_comm_secs: 0.0,
            straggler_wait_secs: 0.0,
            straggler_polls: 0,
            checkpoint_secs: 0.0,
            checkpoint_bytes: 0,
            checkpoint_count: 0,
            recovery_replay_secs: 0.0,
            recovery_count: 0,
            measured: Vec::new(),
            measured_reduce_secs: 0.0,
            measured_gather_secs: 0.0,
            retrans_frames: 0,
            retrans_bytes: 0,
            reconnects: 0,
            backoff_wait_secs: 0.0,
            chaos_faults: 0,
        }
    }

    /// Record an allreduce of `payload_bytes` per processor across `n`,
    /// attributing time to the reduce-scatter and allgather segments.
    /// Returns the simulated seconds charged.
    pub fn record_sync(
        &mut self,
        batch: usize,
        iter: usize,
        payload_bytes: usize,
        n: usize,
    ) -> f64 {
        let reduce_scatter_secs = self.net.reduce_scatter_secs(payload_bytes, n);
        let allgather_secs = self.net.allgather_secs(payload_bytes, n);
        let comm_secs = reduce_scatter_secs + allgather_secs;
        self.wire_bytes += self.net.allreduce_wire_bytes(payload_bytes, n) as u64;
        self.comm_secs += comm_secs;
        self.events.push(SyncEvent {
            batch,
            iter,
            payload_bytes,
            n,
            comm_secs,
            reduce_scatter_secs,
            allgather_secs,
        });
        comm_secs
    }

    /// Record a synchronization whose reduce-scatter and allgather move
    /// **different byte counts** — the sharded storage mode, where the
    /// reduce half ships this sync's reduced pairs while the allgather
    /// half republishes only the *next working set's* slices (zero when
    /// the batch is stopping). `payload_bytes` records the reduce
    /// payload (the Eq. 6 per-processor quantity, comparable across
    /// modes); wire bytes count both halves. The per-event invariant
    /// `reduce_scatter_secs + allgather_secs = comm_secs` is preserved,
    /// and a split with equal halves is byte- and second-identical to
    /// [`Ledger::record_sync`]. Returns the simulated seconds charged.
    pub fn record_sync_split(
        &mut self,
        batch: usize,
        iter: usize,
        reduce_bytes: usize,
        gather_bytes: usize,
        n: usize,
    ) -> f64 {
        let reduce_scatter_secs = self.net.reduce_scatter_secs(reduce_bytes, n);
        // zero gather bytes means the allgather is *skipped* (a stopping
        // iteration republishes nothing), not a zero-byte collective —
        // no latency steps either
        let allgather_secs = if gather_bytes == 0 {
            0.0
        } else {
            self.net.allgather_secs(gather_bytes, n)
        };
        let comm_secs = reduce_scatter_secs + allgather_secs;
        // each half moves its own bytes over the N−1 ring links
        self.wire_bytes +=
            ((reduce_bytes + gather_bytes) * n.saturating_sub(1)) as u64;
        self.comm_secs += comm_secs;
        self.events.push(SyncEvent {
            batch,
            iter,
            payload_bytes: reduce_bytes,
            n,
            comm_secs,
            reduce_scatter_secs,
            allgather_secs,
        });
        comm_secs
    }

    /// Record one iteration's computation: barrier semantics charge the
    /// slowest worker's measured seconds.
    pub fn record_compute(&mut self, per_worker_secs: &[f64]) -> f64 {
        let secs = per_worker_secs.iter().cloned().fold(0.0, f64::max);
        self.compute_secs += secs;
        secs
    }

    /// Record a synchronization whose communication is *deferred* into
    /// the next overlapped iteration's window — the end-of-batch fold in
    /// overlap mode: the leader must fold before freeing the batch, but
    /// the fold's full-matrix *transfer* can hide behind the next
    /// batch's t = 1 sweep. Bytes, the sync count and the per-segment
    /// attribution are recorded exactly now; the comm seconds join the
    /// next [`Ledger::record_overlapped_iter`] (or stay serialized if
    /// none follows). Returns the simulated comm seconds of the sync.
    pub fn record_sync_deferred(
        &mut self,
        batch: usize,
        iter: usize,
        payload_bytes: usize,
        n: usize,
    ) -> f64 {
        let secs = self.record_sync(batch, iter, payload_bytes, n);
        self.deferred_comm_secs += secs;
        secs
    }

    /// Record one *pipelined* iteration — computation and the allreduce
    /// overlapped (the coordinator's pipelined allreduce / the YLDA
    /// parameter-server semantics): the iteration contributes
    /// `max(compute, comm + deferred)` to the total — its own allreduce
    /// plus any deferred fold comm hide behind the sweep — while bytes,
    /// the sync count and the per-segment reduce-scatter/allgather
    /// attribution stay exact. Returns the seconds charged.
    pub fn record_overlapped_iter(
        &mut self,
        batch: usize,
        iter: usize,
        payload_bytes: usize,
        n: usize,
        per_worker_secs: &[f64],
    ) -> f64 {
        let compute = self.record_compute(per_worker_secs);
        let comm = self.record_sync(batch, iter, payload_bytes, n);
        let deferred = std::mem::take(&mut self.deferred_comm_secs);
        // the charging rule lives in one place: the network model's
        // overlapped-iteration time, max(compute, comm + deferred)
        let iter_secs = self.net.overlapped_iter_secs(compute, payload_bytes, n, deferred);
        self.overlap_saved_secs += compute + comm + deferred - iter_secs;
        iter_secs
    }

    /// Record one iteration's straggler wait: `base_secs` are the
    /// healthy per-worker sweep times (already charged through
    /// [`Ledger::record_compute`]), `delay_secs` the injected per-worker
    /// straggle. The barrier pays `max(base + delay) − max(base)` —
    /// exactly the Σmax bookkeeping [`Ledger::record_compute`] uses, so
    /// the invariant `compute_secs + straggler_wait_secs =
    /// Σ_iters max(base + delay)` holds to f64 addition order. The
    /// leader polls the straggler with exponential backoff starting at
    /// `timeout_secs` (the α–β-model timeout), doubling until the wait
    /// is covered; polls accumulate in [`Ledger::straggler_polls`].
    /// Nothing here perturbs [`Ledger::total_secs`] — degraded time is
    /// reported through [`Ledger::degraded_total_secs`]. Returns the
    /// wait charged.
    pub fn record_straggler(
        &mut self,
        base_secs: &[f64],
        delay_secs: &[f64],
        timeout_secs: f64,
    ) -> f64 {
        debug_assert_eq!(base_secs.len(), delay_secs.len());
        let base = base_secs.iter().cloned().fold(0.0, f64::max);
        let delayed = base_secs
            .iter()
            .zip(delay_secs)
            .map(|(b, d)| b + d)
            .fold(0.0, f64::max);
        let wait = (delayed - base).max(0.0);
        if wait > 0.0 {
            self.straggler_wait_secs += wait;
            let mut t = timeout_secs.max(1e-12);
            let mut covered = 0.0;
            while covered < wait && self.straggler_polls < u64::MAX {
                covered += t;
                t *= 2.0;
                self.straggler_polls += 1;
            }
        }
        wait
    }

    /// Record one checkpoint write: `bytes` of file emitted in `secs`
    /// of measured wall-clock I/O. Checkpoint I/O is real time, not
    /// simulated time — it accumulates in the side counters and
    /// [`Ledger::degraded_total_secs`], never in [`Ledger::total_secs`].
    pub fn record_checkpoint(&mut self, bytes: usize, secs: f64) {
        self.checkpoint_count += 1;
        self.checkpoint_bytes += bytes as u64;
        self.checkpoint_secs += secs;
    }

    /// Record the *measured* wire seconds of the most recent sync next
    /// to its α–β estimate — what the distributed transport calls right
    /// after `record_sync`/`record_sync_split` with the wall time of
    /// its publish and collect passes ([`MeasuredSeg`] pairs the two so
    /// [`NetModel::calibration_error_secs`](crate::comm::NetModel::calibration_error_secs)
    /// can score the model). No-op before the first sync. Measured time
    /// never enters [`Ledger::total_secs`].
    pub fn record_measured(&mut self, reduce_secs: f64, gather_secs: f64) {
        let ev = match self.events.last() {
            Some(ev) => ev,
            None => return,
        };
        self.measured.push(MeasuredSeg {
            batch: ev.batch,
            iter: ev.iter,
            modeled_reduce_secs: ev.reduce_scatter_secs,
            modeled_gather_secs: ev.allgather_secs,
            measured_reduce_secs: reduce_secs,
            measured_gather_secs: gather_secs,
        });
        self.measured_reduce_secs += reduce_secs;
        self.measured_gather_secs += gather_secs;
    }

    /// Fold the supervised transport's drained [`WireStats`] into the
    /// Contract 9 side accumulators — retransmitted frames/bytes,
    /// reconnect handshakes, backoff sleep, injected faults. Recovery
    /// effort, like the `measured_*` seconds: it never enters
    /// [`Ledger::total_secs`] and is never serialized into checkpoints,
    /// so a chaos run's cost model stays bitwise equal to the fault-free
    /// oracle's while the recovery work remains observable.
    pub fn record_wire_faults(&mut self, s: &WireStats) {
        self.retrans_frames += s.retrans_frames;
        self.retrans_bytes += s.retrans_bytes;
        self.reconnects += s.reconnects;
        self.backoff_wait_secs += s.backoff_wait_secs;
        self.chaos_faults += s.chaos_faults;
    }

    /// Record one recovery's replay cost: the simulated seconds the
    /// killed attempt had progressed past the checkpoint the new
    /// attempt restores from — training work paid twice. Degraded-run
    /// attribution only.
    pub fn record_recovery_replay(&mut self, secs: f64) {
        if secs > 0.0 {
            self.recovery_count += 1;
            self.recovery_replay_secs += secs;
        }
    }

    /// Total simulated elapsed seconds: compute + comm serialized as in
    /// the synchronous MPA of Fig. 1, minus the fraction hidden by
    /// overlap-mode iterations (zero unless
    /// [`Ledger::record_overlapped_iter`] was used).
    pub fn total_secs(&self) -> f64 {
        self.compute_secs + self.comm_secs - self.overlap_saved_secs
    }

    /// What a degraded run actually cost: the healthy total plus
    /// straggler waits, checkpoint I/O and recovery replay. Equals
    /// [`Ledger::total_secs`] exactly on a fault-free run with
    /// checkpointing disabled.
    pub fn degraded_total_secs(&self) -> f64 {
        self.total_secs()
            + self.straggler_wait_secs
            + self.checkpoint_secs
            + self.recovery_replay_secs
    }

    /// Communication seconds left *exposed* on the critical path:
    /// `comm − overlap_saved` = Σ (comm − compute)⁺ over overlapped
    /// iterations plus the full comm of serialized syncs. This is the
    /// "communication time" the figure benches plot — an overlapped
    /// algorithm (YLDA, pipelined POBP) only pays for the part its
    /// computation cannot hide.
    pub fn exposed_comm_secs(&self) -> f64 {
        self.comm_secs - self.overlap_saved_secs
    }

    /// Fraction of the serialized cost hidden by overlap:
    /// `1 − total / (compute + comm)`. Zero for fully serialized runs;
    /// approaches 0.5 when compute and comm are balanced and every
    /// iteration overlaps.
    pub fn overlap_efficiency(&self) -> f64 {
        let serial = self.compute_secs + self.comm_secs;
        if serial > 0.0 {
            self.overlap_saved_secs / serial
        } else {
            0.0
        }
    }

    /// Number of synchronizations performed.
    pub fn sync_count(&self) -> usize {
        self.events.len()
    }

    /// Seconds spent in the reduce-scatter segments of all allreduces.
    pub fn reduce_scatter_secs_total(&self) -> f64 {
        self.events.iter().map(|e| e.reduce_scatter_secs).sum()
    }

    /// Seconds spent in the allgather segments of all allreduces.
    pub fn allgather_secs_total(&self) -> f64 {
        self.events.iter().map(|e| e.allgather_secs).sum()
    }

    /// Payload bytes summed over events (per-processor view; the paper's
    /// Eq. 5/6 quantity divided by N).
    pub fn payload_bytes_total(&self) -> u64 {
        self.events.iter().map(|e| e.payload_bytes as u64).sum()
    }

    pub fn merge(&mut self, other: &Ledger) {
        self.events.extend_from_slice(&other.events);
        self.compute_secs += other.compute_secs;
        self.wire_bytes += other.wire_bytes;
        self.comm_secs += other.comm_secs;
        self.overlap_saved_secs += other.overlap_saved_secs;
        self.deferred_comm_secs += other.deferred_comm_secs;
        self.straggler_wait_secs += other.straggler_wait_secs;
        self.straggler_polls += other.straggler_polls;
        self.checkpoint_secs += other.checkpoint_secs;
        self.checkpoint_bytes += other.checkpoint_bytes;
        self.checkpoint_count += other.checkpoint_count;
        self.recovery_replay_secs += other.recovery_replay_secs;
        self.recovery_count += other.recovery_count;
        self.measured.extend_from_slice(&other.measured);
        self.measured_reduce_secs += other.measured_reduce_secs;
        self.measured_gather_secs += other.measured_gather_secs;
        self.retrans_frames += other.retrans_frames;
        self.retrans_bytes += other.retrans_bytes;
        self.reconnects += other.reconnects;
        self.backoff_wait_secs += other.backoff_wait_secs;
        self.chaos_faults += other.chaos_faults;
    }

    /// Append the ledger's full state — the [`NetModel`], every
    /// accumulator including the private deferred-comm carry, and the
    /// event list — to `out` as little-endian bytes (f64s as raw IEEE
    /// bits). This is the checkpoint engine's LEDGER section payload
    /// (`storage::checkpoint`, Contract 6): a restored ledger resumes
    /// accumulating from bitwise-identical f64 sums, which is what
    /// makes a recovered run's cost accounting equal an uninterrupted
    /// run's. The measured-segment calibration records are deliberately
    /// *not* serialized — they are wall-clock measurements, re-measured
    /// after a resume and never compared (same rule as per-worker
    /// compute seconds).
    pub fn serialize_into(&self, out: &mut Vec<u8>) {
        fn pu(out: &mut Vec<u8>, v: u64) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        fn pf(out: &mut Vec<u8>, v: f64) {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        pf(out, self.net.latency_s);
        pf(out, self.net.bandwidth_bps);
        pf(out, self.compute_secs);
        pu(out, self.wire_bytes);
        pf(out, self.comm_secs);
        pf(out, self.overlap_saved_secs);
        pf(out, self.deferred_comm_secs);
        pf(out, self.straggler_wait_secs);
        pu(out, self.straggler_polls);
        pf(out, self.checkpoint_secs);
        pu(out, self.checkpoint_bytes);
        pu(out, self.checkpoint_count);
        pf(out, self.recovery_replay_secs);
        pu(out, self.recovery_count);
        pu(out, self.events.len() as u64);
        for e in &self.events {
            pu(out, e.batch as u64);
            pu(out, e.iter as u64);
            pu(out, e.payload_bytes as u64);
            pu(out, e.n as u64);
            pf(out, e.comm_secs);
            pf(out, e.reduce_scatter_secs);
            pf(out, e.allgather_secs);
        }
    }

    /// Inverse of [`Ledger::serialize_into`]. `None` if the payload is
    /// truncated or malformed (the checkpoint loader treats that as
    /// corruption and refuses the file).
    pub fn deserialize(bytes: &[u8]) -> Option<Ledger> {
        struct Rd<'a> {
            b: &'a [u8],
            pos: usize,
        }
        impl Rd<'_> {
            fn u64(&mut self) -> Option<u64> {
                let s = self.b.get(self.pos..self.pos + 8)?;
                self.pos += 8;
                Some(u64::from_le_bytes(s.try_into().ok()?))
            }
            fn f64(&mut self) -> Option<f64> {
                Some(f64::from_bits(self.u64()?))
            }
        }
        let mut r = Rd { b: bytes, pos: 0 };
        let net = NetModel { latency_s: r.f64()?, bandwidth_bps: r.f64()? };
        let mut l = Ledger::new(net);
        l.compute_secs = r.f64()?;
        l.wire_bytes = r.u64()?;
        l.comm_secs = r.f64()?;
        l.overlap_saved_secs = r.f64()?;
        l.deferred_comm_secs = r.f64()?;
        l.straggler_wait_secs = r.f64()?;
        l.straggler_polls = r.u64()?;
        l.checkpoint_secs = r.f64()?;
        l.checkpoint_bytes = r.u64()?;
        l.checkpoint_count = r.u64()?;
        l.recovery_replay_secs = r.f64()?;
        l.recovery_count = r.u64()?;
        let n_events = r.u64()? as usize;
        // sanity bound: each event is 7 fields of 8 bytes
        if bytes.len().saturating_sub(r.pos) < n_events.checked_mul(56)? {
            return None;
        }
        l.events.reserve(n_events);
        for _ in 0..n_events {
            l.events.push(SyncEvent {
                batch: r.u64()? as usize,
                iter: r.u64()? as usize,
                payload_bytes: r.u64()? as usize,
                n: r.u64()? as usize,
                comm_secs: r.f64()?,
                reduce_scatter_secs: r.f64()?,
                allgather_secs: r.f64()?,
            });
        }
        if r.pos != bytes.len() {
            return None;
        }
        Some(l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_accumulates() {
        let mut l = Ledger::new(NetModel::infiniband_20gbps());
        let t1 = l.record_sync(0, 1, 1 << 20, 8);
        let t2 = l.record_sync(0, 2, 1 << 10, 8);
        assert!(t1 > t2);
        assert_eq!(l.sync_count(), 2);
        assert!((l.comm_secs - (t1 + t2)).abs() < 1e-15);
        assert_eq!(l.payload_bytes_total(), (1 << 20) + (1 << 10));
        assert_eq!(
            l.wire_bytes,
            (2 * ((1u64 << 20) + (1 << 10)) * 7) as u64
        );
    }

    #[test]
    fn per_segment_attribution_covers_comm_time() {
        let mut l = Ledger::new(NetModel::infiniband_20gbps());
        l.record_sync(0, 1, 1 << 16, 8);
        l.record_sync(0, 2, 1 << 12, 8);
        let rs = l.reduce_scatter_secs_total();
        let ag = l.allgather_secs_total();
        assert!(rs > 0.0 && ag > 0.0);
        assert!((rs + ag - l.comm_secs).abs() < 1e-15);
        for e in &l.events {
            let gap = (e.reduce_scatter_secs + e.allgather_secs - e.comm_secs).abs();
            assert!(gap < 1e-18);
        }
    }

    #[test]
    fn split_sync_attribution_is_exact() {
        let net = NetModel::infiniband_20gbps();
        // equal halves degenerate to record_sync exactly
        let mut a = Ledger::new(net);
        let mut b = Ledger::new(net);
        let ta = a.record_sync(0, 1, 1 << 16, 8);
        let tb = b.record_sync_split(0, 1, 1 << 16, 1 << 16, 8);
        assert_eq!(ta.to_bits(), tb.to_bits());
        assert_eq!(a.wire_bytes, b.wire_bytes);
        assert_eq!(a.payload_bytes_total(), b.payload_bytes_total());
        // asymmetric halves: segments cover comm, wire counts both
        let mut l = Ledger::new(net);
        let t = l.record_sync_split(0, 2, 1 << 14, 1 << 18, 8);
        let e = l.events[0];
        assert!((e.reduce_scatter_secs + e.allgather_secs - e.comm_secs).abs() < 1e-18);
        assert!((t - e.comm_secs).abs() < 1e-18);
        assert_eq!(e.payload_bytes, 1 << 14);
        assert_eq!(l.wire_bytes, (((1u64 << 14) + (1 << 18)) * 7) as u64);
        // a zero-byte allgather (stopping iteration) charges no gather time
        let mut z = Ledger::new(net);
        z.record_sync_split(0, 3, 1 << 14, 0, 8);
        assert_eq!(z.events[0].allgather_secs, 0.0);
        assert!(z.events[0].reduce_scatter_secs > 0.0);
    }

    #[test]
    fn compute_is_max_over_workers() {
        let mut l = Ledger::new(NetModel::infiniband_20gbps());
        let secs = l.record_compute(&[0.1, 0.5, 0.2]);
        assert_eq!(secs, 0.5);
        assert_eq!(l.compute_secs, 0.5);
        assert_eq!(l.total_secs(), 0.5);
    }

    #[test]
    fn overlap_mode_totals_are_sum_of_maxes() {
        let net = NetModel::infiniband_20gbps();
        let mut l = Ledger::new(net);
        let mut expect = 0.0;
        // one comm-bound, one compute-bound, one balanced-ish iteration
        for (it, &(c, bytes)) in
            [(1e-6f64, 1usize << 22), (0.5, 1 << 10), (2e-4, 1 << 20)].iter().enumerate()
        {
            let m = net.allreduce_secs(bytes, 8);
            let charged = l.record_overlapped_iter(0, it + 1, bytes, 8, &[c]);
            assert!((charged - c.max(m)).abs() < 1e-15, "iter {it}");
            expect += c.max(m);
        }
        assert!(
            (l.total_secs() - expect).abs() < 1e-12,
            "total {} vs sum-of-maxes {expect}",
            l.total_secs()
        );
        // attribution stays exact: segments cover comm, bytes counted
        assert!((l.reduce_scatter_secs_total() + l.allgather_secs_total()
            - l.comm_secs)
            .abs()
            < 1e-15);
        assert_eq!(l.sync_count(), 3);
        assert!(l.overlap_saved_secs > 0.0);
        assert!(l.overlap_efficiency() > 0.0 && l.overlap_efficiency() < 0.5);
        // total decomposes as compute + exposed comm
        assert!(
            (l.total_secs() - (l.compute_secs + l.exposed_comm_secs())).abs() < 1e-15
        );
        // a serialized sync afterwards is charged in full
        let before = l.total_secs();
        let t = l.record_sync(0, 9, 1 << 16, 8);
        assert!((l.total_secs() - before - t).abs() < 1e-15);
    }

    #[test]
    fn deferred_fold_comm_hides_behind_next_overlapped_iter() {
        let net = NetModel::infiniband_20gbps();
        let mut l = Ledger::new(net);
        // the fold: bytes/segments exact now, comm deferred
        let fold_bytes = 1usize << 20;
        let fold_comm = l.record_sync_deferred(0, 5, fold_bytes, 8);
        assert!(fold_comm > 0.0);
        assert_eq!(l.sync_count(), 1);
        assert_eq!(l.payload_bytes_total(), fold_bytes as u64);
        // a compute-bound t = 1 iteration follows: the fold's comm (and
        // the iteration's own allreduce) hide entirely behind the sweep
        let iter_bytes = 1usize << 10;
        let iter_comm = net.allreduce_secs(iter_bytes, 8);
        let compute = (fold_comm + iter_comm) * 10.0;
        let charged = l.record_overlapped_iter(0, 1, iter_bytes, 8, &[compute]);
        assert!((charged - compute).abs() < 1e-15, "fold comm not hidden");
        assert!(
            (l.overlap_saved_secs - (fold_comm + iter_comm)).abs() < 1e-15,
            "saved {} vs fold {} + iter {}",
            l.overlap_saved_secs,
            fold_comm,
            iter_comm
        );
        assert!((l.total_secs() - compute).abs() < 1e-12);
        // a comm-bound iteration after a second fold: charged the comm
        // side, max(compute, comm + deferred)
        let before = l.total_secs();
        let fold2 = l.record_sync_deferred(1, 5, fold_bytes, 8);
        let tiny = 1e-9;
        let charged2 = l.record_overlapped_iter(1, 1, iter_bytes, 8, &[tiny]);
        assert!((charged2 - (fold2 + iter_comm)).abs() < 1e-15);
        // fold + iteration together cost exactly the overlapped window
        assert!((l.total_secs() - before - charged2).abs() < 1e-12);
        // a trailing deferred fold with no iteration after it stays
        // fully serialized in the total
        let before = l.total_secs();
        let fold3 = l.record_sync_deferred(2, 5, fold_bytes, 8);
        assert!((l.total_secs() - before - fold3).abs() < 1e-12);
    }

    #[test]
    fn straggler_wait_obeys_sigma_max_bookkeeping() {
        // Σmax invariant: per iteration record_compute charges
        // max(base) and record_straggler charges max(base + delay) −
        // max(base), so compute + straggler_wait = Σ max(base + delay).
        let net = NetModel::infiniband_20gbps();
        let mut l = Ledger::new(net);
        let timeout = net.straggler_timeout_secs(1 << 16, 4, 4.0);
        let iters: &[(&[f64], &[f64])] = &[
            (&[0.2, 0.5, 0.3], &[0.0, 0.0, 0.7]),   // straggler shifts the max
            (&[0.4, 0.1, 0.2], &[0.05, 0.0, 0.0]),  // delay hides under the max
            (&[0.3, 0.3, 0.3], &[0.0, 0.0, 0.0]),   // healthy iteration
        ];
        let mut expect = 0.0;
        for (base, delay) in iters {
            l.record_compute(base);
            l.record_straggler(base, delay, timeout);
            expect += base
                .iter()
                .zip(delay.iter())
                .map(|(b, d)| b + d)
                .fold(0.0, f64::max);
        }
        assert!(
            (l.compute_secs + l.straggler_wait_secs - expect).abs() < 1e-12,
            "Σmax broken: {} + {} vs {expect}",
            l.compute_secs,
            l.straggler_wait_secs
        );
        // the second iteration's delay hid under the healthy max
        assert!((l.straggler_wait_secs - 0.5).abs() < 1e-12);
        // backoff polls: first poll at the timeout, doubling — a 0.5 s
        // wait against a micro-scale timeout needs several polls
        assert!(l.straggler_polls > 1);
        // degraded attribution never leaks into the healthy total
        assert!((l.total_secs() - l.compute_secs).abs() < 1e-15);
        assert!(
            (l.degraded_total_secs() - (l.total_secs() + l.straggler_wait_secs)).abs()
                < 1e-15
        );
    }

    #[test]
    fn checkpoint_and_replay_accounting_stay_out_of_total() {
        let mut l = Ledger::new(NetModel::infiniband_20gbps());
        l.record_sync(0, 1, 1 << 16, 8);
        l.record_compute(&[0.25]);
        let healthy = l.total_secs();
        l.record_checkpoint(4096, 0.002);
        l.record_checkpoint(4096, 0.003);
        l.record_recovery_replay(0.5);
        l.record_recovery_replay(0.0); // no-op: nothing was replayed
        assert_eq!(l.checkpoint_count, 2);
        assert_eq!(l.checkpoint_bytes, 8192);
        assert_eq!(l.recovery_count, 1);
        assert_eq!(l.total_secs().to_bits(), healthy.to_bits());
        assert!(
            (l.degraded_total_secs() - (healthy + 0.005 + 0.5)).abs() < 1e-15
        );
    }

    #[test]
    fn ledger_serialization_round_trips_bitwise() {
        let mut l = Ledger::new(NetModel::gige());
        l.record_sync(0, 1, 1 << 14, 4);
        l.record_sync_split(0, 2, 1 << 10, 1 << 12, 4);
        l.record_compute(&[0.125, 0.5]);
        l.record_sync_deferred(1, 3, 1 << 12, 4);
        l.record_overlapped_iter(1, 1, 1 << 10, 4, &[0.25]);
        l.record_straggler(&[0.1, 0.2], &[0.4, 0.0], 1e-4);
        l.record_checkpoint(1000, 0.001);
        l.record_recovery_replay(0.25);
        let mut buf = Vec::new();
        l.serialize_into(&mut buf);
        let r = Ledger::deserialize(&buf).expect("round trip");
        assert_eq!(r.events.len(), l.events.len());
        for (a, b) in r.events.iter().zip(&l.events) {
            assert_eq!(a.batch, b.batch);
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.payload_bytes, b.payload_bytes);
            assert_eq!(a.n, b.n);
            assert_eq!(a.comm_secs.to_bits(), b.comm_secs.to_bits());
            assert_eq!(
                a.reduce_scatter_secs.to_bits(),
                b.reduce_scatter_secs.to_bits()
            );
            assert_eq!(a.allgather_secs.to_bits(), b.allgather_secs.to_bits());
        }
        assert_eq!(r.compute_secs.to_bits(), l.compute_secs.to_bits());
        assert_eq!(r.comm_secs.to_bits(), l.comm_secs.to_bits());
        assert_eq!(r.overlap_saved_secs.to_bits(), l.overlap_saved_secs.to_bits());
        assert_eq!(r.deferred_comm_secs.to_bits(), l.deferred_comm_secs.to_bits());
        assert_eq!(r.wire_bytes, l.wire_bytes);
        assert_eq!(
            r.straggler_wait_secs.to_bits(),
            l.straggler_wait_secs.to_bits()
        );
        assert_eq!(r.straggler_polls, l.straggler_polls);
        assert_eq!(r.checkpoint_secs.to_bits(), l.checkpoint_secs.to_bits());
        assert_eq!(r.checkpoint_bytes, l.checkpoint_bytes);
        assert_eq!(r.checkpoint_count, l.checkpoint_count);
        assert_eq!(
            r.recovery_replay_secs.to_bits(),
            l.recovery_replay_secs.to_bits()
        );
        assert_eq!(r.recovery_count, l.recovery_count);
        assert_eq!(r.total_secs().to_bits(), l.total_secs().to_bits());
        assert_eq!(
            r.degraded_total_secs().to_bits(),
            l.degraded_total_secs().to_bits()
        );
        // truncation is detected, front and back
        assert!(Ledger::deserialize(&buf[..buf.len() - 1]).is_none());
        assert!(Ledger::deserialize(&buf[..16]).is_none());
        let mut longer = buf.clone();
        longer.push(0);
        assert!(Ledger::deserialize(&longer).is_none());
    }

    #[test]
    fn wire_fault_accumulators_stay_out_of_total_and_checkpoints() {
        let mut l = Ledger::new(NetModel::infiniband_20gbps());
        l.record_sync(0, 1, 1 << 16, 8);
        l.record_compute(&[0.25]);
        let healthy = l.total_secs();
        let mut clean = Vec::new();
        l.serialize_into(&mut clean);
        l.record_wire_faults(&WireStats {
            retrans_frames: 3,
            retrans_bytes: 4096,
            reconnects: 1,
            backoff_wait_secs: 0.05,
            chaos_faults: 4,
        });
        l.record_wire_faults(&WireStats::default()); // no-op fold
        assert_eq!(l.retrans_frames, 3);
        assert_eq!(l.retrans_bytes, 4096);
        assert_eq!(l.reconnects, 1);
        assert_eq!(l.chaos_faults, 4);
        assert!((l.backoff_wait_secs - 0.05).abs() < 1e-15);
        // never in the simulated total, never in degraded attribution
        assert_eq!(l.total_secs().to_bits(), healthy.to_bits());
        assert_eq!(l.degraded_total_secs().to_bits(), healthy.to_bits());
        // never serialized: the checkpoint payload is byte-identical
        let mut after = Vec::new();
        l.serialize_into(&mut after);
        assert_eq!(clean, after);
        // merge carries the side accumulators
        let mut m = Ledger::new(NetModel::infiniband_20gbps());
        m.merge(&l);
        assert_eq!(m.retrans_frames, 3);
        assert_eq!(m.reconnects, 1);
        assert_eq!(m.chaos_faults, 4);
    }

    #[test]
    fn merge_combines() {
        let mut a = Ledger::new(NetModel::infiniband_20gbps());
        a.record_sync(0, 1, 100, 4);
        let mut b = Ledger::new(NetModel::infiniband_20gbps());
        b.record_sync(1, 1, 200, 4);
        b.record_compute(&[0.3]);
        a.merge(&b);
        assert_eq!(a.sync_count(), 2);
        assert_eq!(a.payload_bytes_total(), 300);
        assert_eq!(a.compute_secs, 0.3);
    }
}
