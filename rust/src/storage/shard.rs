//! Storage-mode abstraction for the accumulated topic–word matrix φ̂:
//! the coordinator's big-K "model-parallel" switch (ISSUE 6 / ROADMAP
//! open item 2, after *Model-Parallel Inference for Big Topic Models*,
//! Zheng et al.).
//!
//! * [`PhiShard::Replicated`] — the classic dense `W·K` replica every
//!   processor holds; retained as the default mode and the bitwise
//!   oracle.
//! * [`PhiShard::Sharded`] — each logical worker persistently stores
//!   only its **row-aligned owner slice** of φ̂
//!   ([`OwnerSlices::row_aligned`]), so per-worker φ̂ memory is
//!   O(W·K/N) and a K·W that cannot fit as a dense replica still
//!   trains. Sweeps read rows through `engine::bp::PhiView::Slices`;
//!   nothing on the training path ever concatenates the slices.
//!
//! Contract 5 (docs/ARCHITECTURE.md) pins the interchangeability: with
//! identical inputs the two modes produce bitwise-identical models,
//! totals and residual histories (`rust/tests/shard_equiv.rs`).

use crate::comm::OwnerSlices;

/// Which φ̂ storage layout the coordinator trains under.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PhiStorageMode {
    /// dense `W·K` replica on every processor (the oracle)
    #[default]
    Replicated,
    /// row-aligned owner slices, one per logical worker — O(W·K/N)
    /// per-worker φ̂ memory
    Sharded,
}

/// The accumulated φ̂ matrix under either storage mode.
#[derive(Clone, Debug)]
pub enum PhiShard {
    /// the dense row-major `W·K` matrix
    Replicated(Vec<f32>),
    /// per-owner row-aligned slices, owner order; `parts[n]` covers
    /// `os.range(n)` of the flat row-major space
    Sharded {
        /// the row-aligned owner partition
        os: OwnerSlices,
        /// topics per word (row width)
        k: usize,
        /// per-owner slices
        parts: Vec<Vec<f32>>,
    },
}

impl PhiShard {
    /// Zeroed dense replica.
    pub fn replicated(w: usize, k: usize) -> PhiShard {
        PhiShard::Replicated(vec![0.0; w * k])
    }

    /// Zeroed sharded accumulator: `owners` row-aligned slices of a
    /// `W·K` flat space.
    pub fn sharded(w: usize, k: usize, owners: usize) -> PhiShard {
        let os = OwnerSlices::row_aligned(w * k, k, owners);
        let parts = (0..owners).map(|n| vec![0.0; os.range(n).len()]).collect();
        PhiShard::Sharded { os, k, parts }
    }

    /// The storage mode this matrix is held under.
    pub fn mode(&self) -> PhiStorageMode {
        match self {
            PhiShard::Replicated(_) => PhiStorageMode::Replicated,
            PhiShard::Sharded { .. } => PhiStorageMode::Sharded,
        }
    }

    /// The owner slices (sharded mode only).
    ///
    /// # Panics
    /// On a replicated matrix, which has no owner partition.
    pub fn owner_slices(&self) -> OwnerSlices {
        match self {
            PhiShard::Replicated(_) => panic!("replicated φ̂ has no owner slices"),
            PhiShard::Sharded { os, .. } => *os,
        }
    }

    /// Borrowed per-owner slices (sharded mode only) — the
    /// `ShardedState` / `PhiView::Slices` input.
    ///
    /// # Panics
    /// On a replicated matrix.
    pub fn parts(&self) -> &[Vec<f32>] {
        match self {
            PhiShard::Replicated(_) => panic!("replicated φ̂ has no slice parts"),
            PhiShard::Sharded { parts, .. } => parts,
        }
    }

    /// Mutable per-owner slices (sharded mode only) — the end-of-batch
    /// accumulator fold target.
    ///
    /// # Panics
    /// On a replicated matrix.
    pub fn parts_mut(&mut self) -> &mut [Vec<f32>] {
        match self {
            PhiShard::Replicated(_) => panic!("replicated φ̂ has no slice parts"),
            PhiShard::Sharded { parts, .. } => parts,
        }
    }

    /// φ̂ rows per owner slice (sharded mode only) — the `PhiView`
    /// stride.
    ///
    /// # Panics
    /// On a replicated matrix.
    pub fn rows_per(&self) -> usize {
        match self {
            PhiShard::Replicated(_) => panic!("replicated φ̂ has no slice stride"),
            PhiShard::Sharded { os, k, .. } => os.per() / k,
        }
    }

    /// Bytes of φ̂ one worker keeps resident: the full matrix when
    /// replicated, the largest owner slice when sharded.
    pub fn resident_bytes_per_worker(&self) -> usize {
        match self {
            PhiShard::Replicated(d) => 4 * d.len(),
            PhiShard::Sharded { parts, .. } => {
                parts.iter().map(|p| 4 * p.len()).max().unwrap_or(0)
            }
        }
    }

    /// Materialize the dense row-major matrix (model export /
    /// evaluation; the sharded training path never calls this
    /// mid-batch).
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            PhiShard::Replicated(d) => d.clone(),
            PhiShard::Sharded { parts, .. } => parts.concat(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharded_partition_is_row_aligned_and_complete() {
        let (w, k, n) = (37, 5, 4);
        let s = PhiShard::sharded(w, k, n);
        let os = s.owner_slices();
        assert_eq!(os.owners(), n);
        assert_eq!(s.rows_per(), w.div_ceil(n));
        let total: usize = s.parts().iter().map(|p| p.len()).sum();
        assert_eq!(total, w * k);
        for (i, p) in s.parts().iter().enumerate() {
            assert_eq!(p.len(), os.range(i).len());
            assert_eq!(p.len() % k, 0, "slice {i} holds partial rows");
        }
        assert_eq!(s.mode(), PhiStorageMode::Sharded);
    }

    #[test]
    fn to_dense_round_trips_slice_writes() {
        let (w, k, n) = (10, 3, 3);
        let mut s = PhiShard::sharded(w, k, n);
        // write a distinct value into each word's row through the parts
        let rows_per = s.rows_per();
        for (part_i, part) in s.parts_mut().iter_mut().enumerate() {
            for (j, v) in part.iter_mut().enumerate() {
                let wi = part_i * rows_per + j / k;
                *v = wi as f32;
            }
        }
        let dense = s.to_dense();
        assert_eq!(dense.len(), w * k);
        for wi in 0..w {
            for t in 0..k {
                assert_eq!(dense[wi * k + t], wi as f32);
            }
        }
        // replicated round trip for parity
        let r = PhiShard::Replicated(dense.clone());
        assert_eq!(r.to_dense(), dense);
    }

    #[test]
    fn resident_bytes_shrink_with_owners() {
        let (w, k) = (2000, 50);
        let rep = PhiShard::replicated(w, k);
        assert_eq!(rep.resident_bytes_per_worker(), 4 * w * k);
        let mut prev = usize::MAX;
        for n in [1usize, 2, 4, 8] {
            let s = PhiShard::sharded(w, k, n);
            let b = s.resident_bytes_per_worker();
            assert_eq!(b, 4 * w.div_ceil(n) * k);
            assert!(b <= prev);
            prev = b;
        }
        // ≈ W·K/N: within one row of the even split
        let s8 = PhiShard::sharded(w, k, 8);
        assert!(s8.resident_bytes_per_worker() <= 4 * (w / 8 + 1) * k);
    }

    #[test]
    fn default_mode_is_replicated() {
        assert_eq!(PhiStorageMode::default(), PhiStorageMode::Replicated);
    }
}
