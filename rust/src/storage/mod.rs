//! Disk-backed topic–word matrix (§2.1, §4.5): "we may also store the
//! entire matrix in hard disk and load the partial matrix in memory for
//! computation" — the memory extension that lets OBP/POBP handle K·W far
//! beyond RAM.
//!
//! `PhiStore` is a row-banked f32 matrix: rows (words) are grouped into
//! fixed-size bands; bands are materialized in memory on access, spilled
//! to a backing file under LRU pressure, and written back when dirty.
//! The POBP access pattern is ideal for it: one iteration touches only
//! the power words' rows, so the working set is λ_W·W bands.

use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub mod checkpoint;
pub mod shard;

pub use checkpoint::{Checkpoint, CkptError, CkptExpect};
pub use shard::{PhiShard, PhiStorageMode};

/// Rows per band. Bands are the spill granularity.
const BAND_ROWS: usize = 64;

struct Band {
    /// first row of the band
    base: usize,
    data: Vec<f32>,
    dirty: bool,
}

/// A W×K f32 matrix with at most `max_resident` bands in memory; the
/// rest live in a backing file.
pub struct PhiStore {
    pub w: usize,
    pub k: usize,
    path: PathBuf,
    file: File,
    /// band index -> resident slot (usize::MAX = on disk)
    slot_of: Vec<usize>,
    resident: Vec<Band>,
    lru: VecDeque<usize>, // band indices, most-recent at back
    max_resident: usize,
    /// spill/load counters (observability + tests)
    pub loads: u64,
    pub spills: u64,
}

impl PhiStore {
    /// Create a zeroed store backed by `path`. `max_resident_bytes`
    /// bounds the in-memory footprint (min one band).
    pub fn create(path: &Path, w: usize, k: usize, max_resident_bytes: usize) -> Result<PhiStore> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("create {}", path.display()))?;
        file.set_len((w * k * 4) as u64)?;
        let bands = w.div_ceil(BAND_ROWS);
        let band_bytes = BAND_ROWS * k * 4;
        let max_resident = (max_resident_bytes / band_bytes).max(1);
        Ok(PhiStore {
            w,
            k,
            path: path.to_path_buf(),
            file,
            slot_of: vec![usize::MAX; bands],
            resident: Vec::new(),
            lru: VecDeque::new(),
            max_resident,
            loads: 0,
            spills: 0,
        })
    }

    pub fn backing_path(&self) -> &Path {
        &self.path
    }

    pub fn resident_bands(&self) -> usize {
        self.resident.len()
    }

    fn band_rows(&self, band: usize) -> (usize, usize) {
        let lo = band * BAND_ROWS;
        (lo, (lo + BAND_ROWS).min(self.w))
    }

    fn ensure_resident(&mut self, band: usize) -> Result<usize> {
        if self.slot_of[band] != usize::MAX {
            // refresh LRU position
            if let Some(pos) = self.lru.iter().position(|&b| b == band) {
                self.lru.remove(pos);
            }
            self.lru.push_back(band);
            return Ok(self.slot_of[band]);
        }
        // evict if at capacity
        while self.resident.len() >= self.max_resident {
            let victim = self.lru.pop_front().expect("lru empty at capacity");
            let slot = self.slot_of[victim];
            if self.resident[slot].dirty {
                self.write_band(victim, slot)?;
                self.spills += 1;
            }
            // move the last resident band into the victim's slot
            let last = self.resident.len() - 1;
            self.resident.swap(slot, last);
            let moved = self.resident[slot].base / BAND_ROWS;
            if slot != last {
                self.slot_of[moved] = slot;
            }
            self.resident.pop();
            self.slot_of[victim] = usize::MAX;
        }
        // load
        let (lo, hi) = self.band_rows(band);
        let mut data = vec![0f32; (hi - lo) * self.k];
        self.file.seek(SeekFrom::Start((lo * self.k * 4) as u64))?;
        let mut buf = vec![0u8; data.len() * 4];
        self.file.read_exact(&mut buf)?;
        for (v, b) in data.iter_mut().zip(buf.chunks_exact(4)) {
            *v = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
        self.loads += 1;
        let slot = self.resident.len();
        self.resident.push(Band { base: lo, data, dirty: false });
        self.slot_of[band] = slot;
        self.lru.push_back(band);
        Ok(slot)
    }

    fn write_band(&mut self, band: usize, slot: usize) -> Result<()> {
        let (lo, _) = self.band_rows(band);
        let data = &self.resident[slot].data;
        let mut buf = Vec::with_capacity(data.len() * 4);
        for &v in data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.file.seek(SeekFrom::Start((lo * self.k * 4) as u64))?;
        self.file.write_all(&buf)?;
        Ok(())
    }

    /// Read row `w` into `out` (len K).
    pub fn read_row(&mut self, w: usize, out: &mut [f32]) -> Result<()> {
        assert!(w < self.w && out.len() == self.k);
        let band = w / BAND_ROWS;
        let slot = self.ensure_resident(band)?;
        let b = &self.resident[slot];
        let off = (w - b.base) * self.k;
        out.copy_from_slice(&b.data[off..off + self.k]);
        Ok(())
    }

    /// Add `delta` (len K) into row `w` — the Δφ̂ accumulation of Eq. 11.
    pub fn add_row(&mut self, w: usize, delta: &[f32]) -> Result<()> {
        assert!(w < self.w && delta.len() == self.k);
        let band = w / BAND_ROWS;
        let slot = self.ensure_resident(band)?;
        let b = &mut self.resident[slot];
        let off = (w - b.base) * self.k;
        for (x, &d) in b.data[off..off + self.k].iter_mut().zip(delta) {
            *x += d;
        }
        b.dirty = true;
        Ok(())
    }

    /// Flush all dirty bands to disk.
    pub fn flush(&mut self) -> Result<()> {
        for i in 0..self.resident.len() {
            if self.resident[i].dirty {
                let band = self.resident[i].base / BAND_ROWS;
                self.write_band(band, i)?;
                self.resident[i].dirty = false;
            }
        }
        self.file.flush()?;
        Ok(())
    }

    /// Materialize the full matrix (for evaluation / export).
    pub fn to_dense(&mut self) -> Result<Vec<f32>> {
        self.flush()?;
        let mut out = vec![0u8; self.w * self.k * 4];
        self.file.seek(SeekFrom::Start(0))?;
        self.file.read_exact(&mut out)?;
        Ok(out
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("pobp_phistore_{name}.bin"))
    }

    #[test]
    fn roundtrip_within_memory() {
        let path = tmp("mem");
        let mut s = PhiStore::create(&path, 100, 8, usize::MAX).unwrap();
        s.add_row(3, &[1.0; 8]).unwrap();
        s.add_row(3, &[0.5; 8]).unwrap();
        let mut row = [0f32; 8];
        s.read_row(3, &mut row).unwrap();
        assert_eq!(row, [1.5; 8]);
        s.read_row(99, &mut row).unwrap();
        assert_eq!(row, [0.0; 8]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn spills_and_reloads_under_pressure() {
        let path = tmp("spill");
        // capacity: exactly one band resident
        let k = 4;
        let one_band = BAND_ROWS * k * 4;
        let mut s = PhiStore::create(&path, BAND_ROWS * 4, k, one_band).unwrap();
        // touch all four bands with distinct values
        for band in 0..4 {
            let w = band * BAND_ROWS + 1;
            s.add_row(w, &[band as f32 + 1.0; 4]).unwrap();
        }
        assert!(s.spills >= 3, "expected spills, got {}", s.spills);
        assert_eq!(s.resident_bands(), 1);
        // read everything back correctly through reloads
        let mut row = [0f32; 4];
        for band in 0..4 {
            let w = band * BAND_ROWS + 1;
            s.read_row(w, &mut row).unwrap();
            assert_eq!(row, [band as f32 + 1.0; 4], "band {band}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dense_export_matches_random_updates() {
        let path = tmp("dense");
        let (w, k) = (200usize, 6usize);
        let mut s = PhiStore::create(&path, w, k, 2 * BAND_ROWS * k * 4).unwrap();
        let mut shadow = vec![0f32; w * k];
        let mut rng = Rng::new(8);
        for _ in 0..500 {
            let wi = rng.below(w);
            let delta: Vec<f32> = (0..k).map(|_| rng.f32()).collect();
            s.add_row(wi, &delta).unwrap();
            for (t, &d) in delta.iter().enumerate() {
                shadow[wi * k + t] += d;
            }
        }
        let dense = s.to_dense().unwrap();
        for (i, (&a, &b)) in dense.iter().zip(&shadow).enumerate() {
            assert!((a - b).abs() < 1e-5, "mismatch at {i}: {a} vs {b}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn band_boundary_rows_round_trip() {
        // rows on either side of every band boundary, including a final
        // partial band (w not a multiple of BAND_ROWS)
        let path = tmp("boundary");
        let k = 3;
        let w = 3 * BAND_ROWS + 7;
        let mut s = PhiStore::create(&path, w, k, usize::MAX).unwrap();
        let probe: Vec<usize> = (1..=3)
            .flat_map(|b| [b * BAND_ROWS - 1, b * BAND_ROWS])
            .chain([0, w - 1])
            .collect();
        for &wi in &probe {
            s.add_row(wi, &[wi as f32; 3]).unwrap();
        }
        let mut row = [0f32; 3];
        for &wi in &probe {
            s.read_row(wi, &mut row).unwrap();
            assert_eq!(row, [wi as f32; 3], "row {wi}");
        }
        // untouched neighbors stay zero
        s.read_row(1, &mut row).unwrap();
        assert_eq!(row, [0.0; 3]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn eviction_stays_correct_across_many_bands() {
        // 2-band capacity over 8 bands, interleaved adds and reads that
        // force repeated spill/reload of dirty bands; a shadow matrix is
        // the oracle
        let path = tmp("churn");
        let k = 4;
        let nbands = 8;
        let w = nbands * BAND_ROWS;
        let two_bands = 2 * BAND_ROWS * k * 4;
        let mut s = PhiStore::create(&path, w, k, two_bands).unwrap();
        let mut shadow = vec![0f32; w * k];
        let mut rng = Rng::new(31);
        let mut row = [0f32; 4];
        for step in 0..2000 {
            let wi = rng.below(w);
            if step % 3 == 0 {
                s.read_row(wi, &mut row).unwrap();
                for t in 0..k {
                    assert_eq!(row[t], shadow[wi * k + t], "step {step} row {wi}");
                }
            } else {
                let delta: Vec<f32> = (0..k).map(|_| rng.f32() - 0.5).collect();
                s.add_row(wi, &delta).unwrap();
                for (t, &d) in delta.iter().enumerate() {
                    shadow[wi * k + t] += d;
                }
            }
        }
        assert!(s.spills > 0, "pressure never triggered a spill");
        assert!(s.resident_bands() <= 2);
        // full export agrees with the shadow exactly (adds were exact
        // f32 ops in both, same order)
        let dense = s.to_dense().unwrap();
        assert_eq!(dense, shadow);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn add_rows_across_bands_then_dense_export() {
        // every row touched exactly once under minimal (one-band)
        // residency, then exported — the add_row → to_dense path the
        // out-of-core sweep will lean on
        let path = tmp("addall");
        let k = 5;
        let w = 4 * BAND_ROWS + 9;
        let one_band = BAND_ROWS * k * 4;
        let mut s = PhiStore::create(&path, w, k, one_band).unwrap();
        for wi in 0..w {
            let delta: Vec<f32> = (0..k).map(|t| (wi * k + t) as f32).collect();
            s.add_row(wi, &delta).unwrap();
        }
        let dense = s.to_dense().unwrap();
        for (i, &v) in dense.iter().enumerate() {
            assert_eq!(v, i as f32, "flat {i}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn flush_persists_across_reopen() {
        let path = tmp("reopen");
        {
            let mut s = PhiStore::create(&path, 80, 4, usize::MAX).unwrap();
            s.add_row(70, &[7.0; 4]).unwrap();
            s.flush().unwrap();
        }
        // re-open the raw file and check bytes directly
        let bytes = std::fs::read(&path).unwrap();
        let off = 70 * 4 * 4;
        let v = f32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]]);
        assert_eq!(v, 7.0);
        std::fs::remove_file(&path).ok();
    }
}
