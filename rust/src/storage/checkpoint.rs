//! Crash-consistent checkpoint engine (Contract 6).
//!
//! A [`Checkpoint`] atomically serializes the full training state at a
//! mini-batch boundary — the accumulated φ̂ in either
//! [`PhiStorageMode`], the RNG stream position, the batch cursor, the
//! ledger (every f64 accumulator bit-preserved) and the run's history
//! and snapshots — into one file:
//!
//! ```text
//! "POBPCKP1" | version u32 | n_sections u32
//!   then per section:
//! tag u32 | payload_len u64 | fnv1a64(payload) u64 | payload
//! ```
//!
//! All integers little-endian; f64/f32 as raw IEEE bits. Sections:
//! META (shapes + cursors), RNG, PHI (mode-tagged), TOTALS (k per-topic
//! f64 sums of φ̂ plus the grand total, recomputed on load and compared
//! **bitwise** as a semantic integrity check on top of the checksums),
//! LEDGER ([`Ledger::serialize_into`]), HISTORY, SNAPSHOTS.
//!
//! # Crash consistency and corruption
//!
//! [`Checkpoint::write`] serializes to a buffer, writes a tmp file,
//! `sync_all`s and renames — a crash mid-write leaves at most a stale
//! tmp file, never a torn checkpoint. [`Checkpoint::load`] refuses the
//! file on any defect (bad magic/version, truncated section, checksum
//! mismatch, shape inconsistency, totals drift);
//! [`Checkpoint::load_latest_good`] walks the directory newest-first
//! and falls back past refused files to the previous good checkpoint
//! (`rust/tests/fault_equiv.rs` pins the flip-one-byte case).
//!
//! # Determinism contract
//!
//! Everything a resumed run needs to reproduce the uninterrupted run
//! bitwise is in here; everything that is *measured* (wall clock,
//! per-worker compute seconds) is either carried verbatim (history) or
//! re-measured and never compared. The wire format is deliberately
//! self-contained and position-independent — it doubles as the
//! worker-join/state-transfer payload of the distributed transport
//! (Contract 8): `comm::transport` ships a [`Checkpoint`] inside every
//! batch frame, so a worker joins — or *re*joins after a crash — by
//! decoding exactly the state a resumed run would load from disk.

use std::fmt;
use std::fs;
use std::io::{self, Write};
use std::path::{Path, PathBuf};

use crate::comm::Ledger;
use crate::engine::traits::{IterStat, Model};
use crate::storage::shard::{PhiShard, PhiStorageMode};

/// File magic: "POBPCKP1".
pub const MAGIC: &[u8; 8] = b"POBPCKP1";
/// Wire-format version; bumped on any layout change.
pub const VERSION: u32 = 1;
/// Checkpoint file extension.
pub const EXTENSION: &str = "pobpckpt";

const SEC_META: u32 = 1;
const SEC_RNG: u32 = 2;
const SEC_PHI: u32 = 3;
const SEC_TOTALS: u32 = 4;
const SEC_LEDGER: u32 = 5;
const SEC_HISTORY: u32 = 6;
const SEC_SNAPSHOTS: u32 = 7;
const N_SECTIONS: u32 = 7;

/// Why a checkpoint file was refused.
#[derive(Debug)]
pub enum CkptError {
    Io(io::Error),
    /// not a checkpoint file
    BadMagic,
    /// a future (or garbage) wire-format version
    BadVersion(u32),
    /// a section or the header ended early
    Truncated(&'static str),
    /// a section's FNV-1a checksum did not match its payload
    Checksum(u32),
    /// internally inconsistent shapes (e.g. φ̂ length ≠ W·K)
    Shape(String),
    /// the recomputed f64 per-topic totals differ bitwise from the
    /// TOTALS section — the payload decoded but does not mean what it
    /// said it meant
    TotalsMismatch,
}

impl fmt::Display for CkptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint I/O: {e}"),
            CkptError::BadMagic => write!(f, "not a POBP checkpoint (bad magic)"),
            CkptError::BadVersion(v) => write!(f, "unsupported checkpoint version {v}"),
            CkptError::Truncated(what) => write!(f, "truncated checkpoint ({what})"),
            CkptError::Checksum(tag) => {
                write!(f, "checksum mismatch in checkpoint section {tag}")
            }
            CkptError::Shape(s) => write!(f, "inconsistent checkpoint shapes: {s}"),
            CkptError::TotalsMismatch => {
                write!(f, "checkpoint φ̂ totals do not match their section")
            }
        }
    }
}

impl std::error::Error for CkptError {}

impl From<io::Error> for CkptError {
    fn from(e: io::Error) -> CkptError {
        CkptError::Io(e)
    }
}

/// What a loaded checkpoint must match to be usable for a given run
/// configuration; mismatching files (another corpus, another seed,
/// another worker count) are skipped by [`Checkpoint::load_latest_good`]
/// rather than resumed into the wrong run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CkptExpect {
    pub w: usize,
    pub k: usize,
    pub n_workers: usize,
    pub seed: u64,
    pub mode: PhiStorageMode,
}

/// The full training state at a mini-batch boundary.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// vocabulary size (φ̂ rows)
    pub w: usize,
    /// topics (φ̂ row width)
    pub k: usize,
    /// logical worker count the run was configured with
    pub n_workers: usize,
    /// the run's master seed (resume sanity check, not re-applied)
    pub seed: u64,
    /// index of the first batch the resumed run must train
    pub next_batch: usize,
    /// first document of that batch (the stream cursor)
    pub next_doc: usize,
    /// iteration-sync counter (snapshot cadence state)
    pub iter_syncs: usize,
    /// master RNG stream position, captured at the batch boundary
    /// *before* the next batch's worker splits are drawn
    pub rng_state: [u64; 4],
    /// accumulated φ̂ in the run's storage mode
    pub phi: PhiShard,
    pub ledger: Ledger,
    pub history: Vec<IterStat>,
    pub snapshots: Vec<(f64, Model)>,
}

/// FNV-1a-64 — the per-section checksum of this file format, shared with
/// the transport's frame format (`comm::wire`), which reuses the
/// `POBPCKP1` sectioned-format conventions on the socket.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(4 * xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

fn push_section(out: &mut Vec<u8>, tag: u32, payload: &[u8]) {
    out.extend_from_slice(&tag.to_le_bytes());
    put_u64(out, payload.len() as u64);
    put_u64(out, fnv1a64(payload));
    out.extend_from_slice(payload);
}

struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
    what: &'static str,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8], what: &'static str) -> Rd<'a> {
        Rd { b, pos: 0, what }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], CkptError> {
        let s = self
            .b
            .get(self.pos..self.pos.checked_add(n).ok_or(CkptError::Truncated(self.what))?)
            .ok_or(CkptError::Truncated(self.what))?;
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, CkptError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, CkptError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> Result<usize, CkptError> {
        Ok(self.u64()? as usize)
    }

    fn f64(&mut self) -> Result<f64, CkptError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, CkptError> {
        let raw = self.bytes(4usize.checked_mul(n).ok_or(CkptError::Truncated(self.what))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    fn done(&self) -> Result<(), CkptError> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(CkptError::Truncated(self.what))
        }
    }
}

/// Per-topic f64 sums of φ̂ plus the grand total, in one fixed
/// sequential order (dense row order — the sharded parts concatenate to
/// exactly that order, Contract 5's row alignment). Recomputed on load
/// and compared bitwise against the TOTALS section.
fn phi_topic_totals(phi: &PhiShard, k: usize) -> Vec<f64> {
    let mut tot = vec![0f64; k + 1];
    let mut fold = |slice: &[f32]| {
        for row in slice.chunks_exact(k) {
            for (t, &v) in row.iter().enumerate() {
                tot[t] += v as f64;
            }
        }
    };
    match phi {
        PhiShard::Replicated(d) => fold(d),
        PhiShard::Sharded { parts, .. } => {
            for p in parts {
                fold(p);
            }
        }
    }
    let grand: f64 = tot[..k].iter().sum();
    tot[k] = grand;
    tot
}

impl Checkpoint {
    /// Serialize to the full wire format (header + all sections).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&N_SECTIONS.to_le_bytes());

        let mut meta = Vec::new();
        put_u64(&mut meta, self.w as u64);
        put_u64(&mut meta, self.k as u64);
        put_u64(&mut meta, self.n_workers as u64);
        put_u64(&mut meta, self.seed);
        put_u64(&mut meta, self.next_batch as u64);
        put_u64(&mut meta, self.next_doc as u64);
        put_u64(&mut meta, self.iter_syncs as u64);
        put_u64(
            &mut meta,
            match self.phi.mode() {
                PhiStorageMode::Replicated => 0,
                PhiStorageMode::Sharded => 1,
            },
        );
        push_section(&mut out, SEC_META, &meta);

        let mut rng = Vec::new();
        for s in self.rng_state {
            put_u64(&mut rng, s);
        }
        push_section(&mut out, SEC_RNG, &rng);

        let mut phi = Vec::new();
        match &self.phi {
            PhiShard::Replicated(d) => {
                put_u64(&mut phi, 0);
                put_u64(&mut phi, d.len() as u64);
                put_f32s(&mut phi, d);
            }
            PhiShard::Sharded { parts, .. } => {
                put_u64(&mut phi, 1);
                put_u64(&mut phi, parts.len() as u64);
                for p in parts {
                    put_u64(&mut phi, p.len() as u64);
                    put_f32s(&mut phi, p);
                }
            }
        }
        push_section(&mut out, SEC_PHI, &phi);

        let mut totals = Vec::new();
        for t in phi_topic_totals(&self.phi, self.k) {
            put_f64(&mut totals, t);
        }
        push_section(&mut out, SEC_TOTALS, &totals);

        let mut ledger = Vec::new();
        self.ledger.serialize_into(&mut ledger);
        push_section(&mut out, SEC_LEDGER, &ledger);

        let mut hist = Vec::new();
        put_u64(&mut hist, self.history.len() as u64);
        for s in &self.history {
            put_u64(&mut hist, s.batch as u64);
            put_u64(&mut hist, s.iter as u64);
            put_f64(&mut hist, s.residual_per_token);
            put_u64(&mut hist, s.synced_pairs as u64);
            put_f64(&mut hist, s.sim_elapsed);
            put_f64(&mut hist, s.wall_elapsed);
        }
        push_section(&mut out, SEC_HISTORY, &hist);

        let mut snaps = Vec::new();
        put_u64(&mut snaps, self.snapshots.len() as u64);
        for (t, m) in &self.snapshots {
            put_f64(&mut snaps, *t);
            put_u64(&mut snaps, m.w as u64);
            put_u64(&mut snaps, m.k as u64);
            put_f32s(&mut snaps, &m.phi_wk);
        }
        push_section(&mut out, SEC_SNAPSHOTS, &snaps);

        out
    }

    /// Decode and fully validate a serialized checkpoint: header,
    /// per-section checksums, shape consistency, and the bitwise
    /// totals recomputation.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint, CkptError> {
        let mut hdr = Rd::new(bytes, "header");
        if hdr.bytes(8)? != MAGIC {
            return Err(CkptError::BadMagic);
        }
        let version = hdr.u32()?;
        if version != VERSION {
            return Err(CkptError::BadVersion(version));
        }
        let n_sections = hdr.u32()?;
        if n_sections != N_SECTIONS {
            return Err(CkptError::Shape(format!(
                "{n_sections} sections, expected {N_SECTIONS}"
            )));
        }
        let mut sections: Vec<(u32, &[u8])> = Vec::with_capacity(n_sections as usize);
        for _ in 0..n_sections {
            let tag = hdr.u32()?;
            let len = hdr.usize()?;
            let sum = hdr.u64()?;
            let payload = hdr.bytes(len)?;
            if fnv1a64(payload) != sum {
                return Err(CkptError::Checksum(tag));
            }
            sections.push((tag, payload));
        }
        hdr.done()?;
        let section = |tag: u32, what: &'static str| -> Result<&[u8], CkptError> {
            sections
                .iter()
                .find(|(t, _)| *t == tag)
                .map(|(_, p)| *p)
                .ok_or(CkptError::Truncated(what))
        };

        let mut meta = Rd::new(section(SEC_META, "meta")?, "meta");
        let w = meta.usize()?;
        let k = meta.usize()?;
        let n_workers = meta.usize()?;
        let seed = meta.u64()?;
        let next_batch = meta.usize()?;
        let next_doc = meta.usize()?;
        let iter_syncs = meta.usize()?;
        let mode_tag = meta.u64()?;
        meta.done()?;
        if k == 0 || n_workers == 0 {
            return Err(CkptError::Shape(format!("k = {k}, n_workers = {n_workers}")));
        }

        let mut rng = Rd::new(section(SEC_RNG, "rng")?, "rng");
        let rng_state = [rng.u64()?, rng.u64()?, rng.u64()?, rng.u64()?];
        rng.done()?;

        let mut pr = Rd::new(section(SEC_PHI, "phi")?, "phi");
        let phi_tag = pr.u64()?;
        if phi_tag != mode_tag {
            return Err(CkptError::Shape(format!(
                "φ̂ section mode {phi_tag} vs meta mode {mode_tag}"
            )));
        }
        let phi = match phi_tag {
            0 => {
                let len = pr.usize()?;
                if len != w * k {
                    return Err(CkptError::Shape(format!(
                        "dense φ̂ len {len} vs W·K = {}",
                        w * k
                    )));
                }
                PhiShard::Replicated(pr.f32s(len)?)
            }
            1 => {
                // rebuild the canonical row-aligned partition and demand
                // the stored parts match it exactly — owner boundaries
                // are shape, not data
                let mut shard = PhiShard::sharded(w, k, n_workers);
                let n_parts = pr.usize()?;
                if n_parts != shard.parts().len() {
                    return Err(CkptError::Shape(format!(
                        "{n_parts} φ̂ parts vs {} owners",
                        shard.parts().len()
                    )));
                }
                for (i, part) in shard.parts_mut().iter_mut().enumerate() {
                    let len = pr.usize()?;
                    if len != part.len() {
                        return Err(CkptError::Shape(format!(
                            "φ̂ part {i} len {len} vs owner slice {}",
                            part.len()
                        )));
                    }
                    part.copy_from_slice(&pr.f32s(len)?);
                }
                shard
            }
            other => {
                return Err(CkptError::Shape(format!("unknown φ̂ mode tag {other}")))
            }
        };
        pr.done()?;

        let mut tr = Rd::new(section(SEC_TOTALS, "totals")?, "totals");
        let stored: Vec<f64> =
            (0..k + 1).map(|_| tr.f64()).collect::<Result<_, _>>()?;
        tr.done()?;
        let recomputed = phi_topic_totals(&phi, k);
        if stored
            .iter()
            .zip(&recomputed)
            .any(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err(CkptError::TotalsMismatch);
        }

        let ledger = Ledger::deserialize(section(SEC_LEDGER, "ledger")?)
            .ok_or(CkptError::Truncated("ledger"))?;

        let mut hr = Rd::new(section(SEC_HISTORY, "history")?, "history");
        let n_hist = hr.usize()?;
        let mut history = Vec::with_capacity(n_hist.min(1 << 20));
        for _ in 0..n_hist {
            history.push(IterStat {
                batch: hr.usize()?,
                iter: hr.usize()?,
                residual_per_token: hr.f64()?,
                synced_pairs: hr.usize()?,
                sim_elapsed: hr.f64()?,
                wall_elapsed: hr.f64()?,
            });
        }
        hr.done()?;

        let mut sr = Rd::new(section(SEC_SNAPSHOTS, "snapshots")?, "snapshots");
        let n_snaps = sr.usize()?;
        let mut snapshots = Vec::with_capacity(n_snaps.min(1 << 12));
        for _ in 0..n_snaps {
            let t = sr.f64()?;
            let mw = sr.usize()?;
            let mk = sr.usize()?;
            if mw != w || mk != k {
                return Err(CkptError::Shape(format!(
                    "snapshot model {mw}×{mk} vs run {w}×{k}"
                )));
            }
            let phi_wk = sr.f32s(mw * mk)?;
            snapshots.push((t, Model { k: mk, w: mw, phi_wk }));
        }
        sr.done()?;

        Ok(Checkpoint {
            w,
            k,
            n_workers,
            seed,
            next_batch,
            next_doc,
            iter_syncs,
            rng_state,
            phi,
            ledger,
            history,
            snapshots,
        })
    }

    /// The expectation signature of this checkpoint.
    pub fn expectation(&self) -> CkptExpect {
        CkptExpect {
            w: self.w,
            k: self.k,
            n_workers: self.n_workers,
            seed: self.seed,
            mode: self.phi.mode(),
        }
    }

    /// Atomically write the checkpoint into `dir` as
    /// `ckpt-<next_batch>.pobpckpt` (tmp file + `sync_all` + rename),
    /// then prune all but the newest `keep` checkpoints. Returns the
    /// final path and the bytes written.
    pub fn write(&self, dir: &Path, keep: usize) -> io::Result<(PathBuf, usize)> {
        fs::create_dir_all(dir)?;
        let bytes = self.encode();
        let name = format!("ckpt-{:08}.{EXTENSION}", self.next_batch);
        let final_path = dir.join(&name);
        let tmp_path = dir.join(format!(".{name}.tmp"));
        {
            let mut f = fs::File::create(&tmp_path)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        fs::rename(&tmp_path, &final_path)?;
        // retention: the name embeds the zero-padded batch index, so
        // lexicographic order is batch order
        let mut existing = list_checkpoints(dir)?;
        while existing.len() > keep.max(1) {
            let oldest = existing.remove(0);
            if oldest != final_path {
                let _ = fs::remove_file(&oldest);
            }
        }
        Ok((final_path, bytes.len()))
    }

    /// Load and validate one checkpoint file.
    pub fn load(path: &Path) -> Result<Checkpoint, CkptError> {
        Checkpoint::decode(&fs::read(path)?)
    }

    /// The newest loadable checkpoint in `dir` that matches `expect`
    /// (if given), skipping — not failing on — corrupt, truncated or
    /// mismatching files: that is the fallback-to-previous-good
    /// behavior Contract 6 requires. `None` when the directory has no
    /// usable checkpoint.
    pub fn load_latest_good(
        dir: &Path,
        expect: Option<&CkptExpect>,
    ) -> Option<(Checkpoint, PathBuf)> {
        let paths = list_checkpoints(dir).ok()?;
        for path in paths.into_iter().rev() {
            if let Ok(ck) = Checkpoint::load(&path) {
                if expect.is_none_or(|e| *e == ck.expectation()) {
                    return Some((ck, path));
                }
            }
        }
        None
    }
}

/// All checkpoint files in `dir`, sorted oldest-first (the zero-padded
/// name embeds the batch index).
pub fn list_checkpoints(dir: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let rd = match fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e),
    };
    for entry in rd {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) == Some(EXTENSION) {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;

    fn tempdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pobp-ckpt-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample(mode: PhiStorageMode) -> Checkpoint {
        let (w, k, n) = (10, 4, 3);
        let mut phi = match mode {
            PhiStorageMode::Replicated => PhiShard::replicated(w, k),
            PhiStorageMode::Sharded => PhiShard::sharded(w, k, n),
        };
        match &mut phi {
            PhiShard::Replicated(d) => {
                for (i, v) in d.iter_mut().enumerate() {
                    *v = (i as f32).sin();
                }
            }
            PhiShard::Sharded { parts, .. } => {
                let mut i = 0;
                for p in parts {
                    for v in p.iter_mut() {
                        *v = (i as f32).sin();
                        i += 1;
                    }
                }
            }
        }
        let mut ledger = Ledger::new(NetModel::infiniband_20gbps());
        ledger.record_sync(0, 1, 1 << 12, n);
        ledger.record_compute(&[0.1, 0.3, 0.2]);
        Checkpoint {
            w,
            k,
            n_workers: n,
            seed: 99,
            next_batch: 2,
            next_doc: 17,
            iter_syncs: 9,
            rng_state: [1, 2, 3, u64::MAX],
            phi,
            ledger,
            history: vec![IterStat {
                batch: 1,
                iter: 3,
                residual_per_token: 0.25,
                synced_pairs: 40,
                sim_elapsed: 1.5,
                wall_elapsed: 0.1,
            }],
            snapshots: vec![(1.25, Model { k, w, phi_wk: vec![0.5; w * k] })],
        }
    }

    fn assert_equal(a: &Checkpoint, b: &Checkpoint) {
        assert_eq!(a.w, b.w);
        assert_eq!(a.k, b.k);
        assert_eq!(a.n_workers, b.n_workers);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.next_batch, b.next_batch);
        assert_eq!(a.next_doc, b.next_doc);
        assert_eq!(a.iter_syncs, b.iter_syncs);
        assert_eq!(a.rng_state, b.rng_state);
        assert_eq!(a.phi.mode(), b.phi.mode());
        assert_eq!(a.phi.to_dense(), b.phi.to_dense());
        assert_eq!(a.ledger.sync_count(), b.ledger.sync_count());
        assert_eq!(
            a.ledger.total_secs().to_bits(),
            b.ledger.total_secs().to_bits()
        );
        assert_eq!(a.history.len(), b.history.len());
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.batch, y.batch);
            assert_eq!(x.iter, y.iter);
            assert_eq!(
                x.residual_per_token.to_bits(),
                y.residual_per_token.to_bits()
            );
            assert_eq!(x.synced_pairs, y.synced_pairs);
        }
        assert_eq!(a.snapshots.len(), b.snapshots.len());
        for ((ta, ma), (tb, mb)) in a.snapshots.iter().zip(&b.snapshots) {
            assert_eq!(ta.to_bits(), tb.to_bits());
            assert_eq!(ma.phi_wk, mb.phi_wk);
        }
    }

    #[test]
    fn roundtrip_is_bitwise_both_modes() {
        for mode in [PhiStorageMode::Replicated, PhiStorageMode::Sharded] {
            let ck = sample(mode);
            let back = Checkpoint::decode(&ck.encode()).unwrap();
            assert_equal(&ck, &back);
            // encode is deterministic: same state, same bytes
            assert_eq!(ck.encode(), back.encode());
        }
    }

    #[test]
    fn every_flipped_byte_is_refused_or_harmless() {
        // flip each byte of the file in turn: the loader must never
        // return state that differs from the original (it either
        // refuses, or the flip was in a length/padding position whose
        // decode still reproduces the exact state — which cannot happen
        // with checksummed sections, so: always refused)
        let ck = sample(PhiStorageMode::Sharded);
        let bytes = ck.encode();
        let stride = (bytes.len() / 97).max(1);
        for i in (0..bytes.len()).step_by(stride) {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(
                Checkpoint::decode(&bad).is_err(),
                "flipped byte {i} was accepted"
            );
        }
        // truncation at any prefix is refused too
        for cut in [0, 7, 8, 20, bytes.len() / 2, bytes.len() - 1] {
            assert!(Checkpoint::decode(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn write_load_and_retention() {
        let dir = tempdir("retention");
        let mut ck = sample(PhiStorageMode::Replicated);
        for b in 1..=4 {
            ck.next_batch = b;
            ck.write(&dir, 2).unwrap();
        }
        let files = list_checkpoints(&dir).unwrap();
        assert_eq!(files.len(), 2, "retention must keep the newest 2");
        let (latest, path) = Checkpoint::load_latest_good(&dir, None).unwrap();
        assert_eq!(latest.next_batch, 4);
        assert!(path.to_string_lossy().contains("ckpt-00000004"));
        // no stale tmp files
        assert!(fs::read_dir(&dir)
            .unwrap()
            .all(|e| !e.unwrap().path().to_string_lossy().ends_with(".tmp")));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_latest_falls_back_to_previous_good() {
        let dir = tempdir("fallback");
        let mut ck = sample(PhiStorageMode::Replicated);
        ck.next_batch = 1;
        ck.write(&dir, 4).unwrap();
        ck.next_batch = 2;
        let (newest, _) = ck.write(&dir, 4).unwrap();
        // flip one byte in the middle of the newest file
        let mut raw = fs::read(&newest).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 0xFF;
        fs::write(&newest, &raw).unwrap();
        assert!(Checkpoint::load(&newest).is_err(), "corrupt load must refuse");
        let (good, path) = Checkpoint::load_latest_good(&dir, None).unwrap();
        assert_eq!(good.next_batch, 1, "must fall back past the corrupt file");
        assert!(path.to_string_lossy().contains("ckpt-00000001"));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn expectation_filter_skips_foreign_checkpoints() {
        let dir = tempdir("expect");
        let ck = sample(PhiStorageMode::Replicated);
        ck.write(&dir, 2).unwrap();
        let mut expect = ck.expectation();
        assert!(Checkpoint::load_latest_good(&dir, Some(&expect)).is_some());
        expect.seed ^= 1;
        assert!(
            Checkpoint::load_latest_good(&dir, Some(&expect)).is_none(),
            "foreign seed must not resume"
        );
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_or_missing_dir_is_no_checkpoint() {
        let dir = tempdir("empty");
        assert!(Checkpoint::load_latest_good(&dir, None).is_none());
        fs::remove_dir_all(&dir).unwrap();
        assert!(Checkpoint::load_latest_good(&dir, None).is_none());
        assert!(list_checkpoints(&dir).unwrap().is_empty());
    }
}
