//! The POBP coordinator — the paper's system contribution (Fig. 4).
//!
//! The leader streams mini-batches, shards each over N (simulated)
//! processors, and runs the bulk-synchronous loop:
//!
//! * **t = 1**: workers random-initialize messages, sweep everything, and
//!   the leader allreduces the *complete* Δφ̂ and residual matrices
//!   (Fig. 4 lines 3–10).
//! * **t ≥ 2**: the leader two-step-selects power words/topics from the
//!   synchronized residual matrix (§3.1), workers sweep only that subset,
//!   and only the `λ_W·W × λ_K·K` sub-matrices are allreduced
//!   (lines 12–28, Eqs. 6, 9, 15).
//! * The batch ends when the mean residual per token drops below the
//!   threshold (line 26) or `max_iters` is hit; the accumulated gradient
//!   joins the global φ̂ with the 1/(m−1)-style SGD semantics of Eq. 11.
//!
//! Special cases the paper calls out: N = 1 reduces to OBP; one mini-batch
//! (`nnz_budget = usize::MAX`) reduces to (parallel) batch BP; full
//! `PowerParams` disables selection entirely.
//!
//! # Overlap pipeline (`PobpConfig::overlap`)
//!
//! The serialized loop charges compute + comm per iteration (the BSP
//! semantics of Fig. 1). Overlap mode runs the same arithmetic through
//! the pipelined synchronization stack instead:
//!
//! * the allreduce is the slice-granular pipelined
//!   [`allreduce_step_overlap`]: per-owner-slice gather chunks, each
//!   owner folding its slice as soon as every worker has packed *that
//!   slice* (per-slice ready counters — no per-worker rounds);
//! * the next mini-batch's shard construction runs concurrently with the
//!   current batch's end-of-batch fold (both leader-side, disjoint
//!   state);
//! * the ledger charges `max(compute, comm)` per iteration
//!   ([`Ledger::record_overlapped_iter`], the YLDA parameter-server
//!   semantics of `engine::mpa`), keeping byte counts and per-segment
//!   reduce-scatter/allgather attribution exact. The end-of-batch fold's
//!   leader-side *work* stays serialized — the leader must finish
//!   folding before freeing the batch (Fig. 4 line 30) — but its
//!   simulated full-matrix *transfer* is deferred into the next batch's
//!   t = 1 window ([`Ledger::record_sync_deferred`]): that iteration
//!   charges `max(compute, comm + fold comm)`, with bytes and sync
//!   counts exact. The run's last fold has no following iteration and
//!   stays fully serialized.
//!
//! Numerical results are **bitwise identical** between the two modes at
//! any thread budget (`rust/tests/allreduce_equiv.rs` pins this): both
//! run the same per-element left folds and the same per-owner f64
//! totals sequence; only scheduling and time accounting differ.
//!
//! # Storage modes (`PobpConfig::storage`)
//!
//! The φ̂ accumulator and the per-batch working state come in two
//! layouts (Contract 5, docs/ARCHITECTURE.md):
//!
//! * [`PhiStorageMode::Replicated`] (default) — every processor holds
//!   the dense `W·K` replica; the bitwise oracle.
//! * [`PhiStorageMode::Sharded`] — each logical worker persistently
//!   stores only its row-aligned owner slice of φ̂ and r
//!   (O(W·K/N) per-worker model memory, the big-K mode). Sweeps read
//!   rows in place through [`PhiView::Slices`]; the allreduce folds
//!   into the stored slices; the ledger attributes the reduce-scatter
//!   and the next iteration's working-set allgather separately.
//!
//! Model, totals and residual history are **bitwise identical** across
//! the two modes at any thread budget (`rust/tests/shard_equiv.rs`).
//!
//! Simulation note (DESIGN.md §Substitutions): worker compute is measured
//! per shard; communication time comes from the byte-exact ledger +
//! network model. Numerical results are *identical* to a real N-process
//! deployment because the allreduce is a deterministic leader-side sum.
//!
//! # Distributed transport (`coordinator::dist`)
//!
//! Since the transport PR that identity claim is *tested*, not argued:
//! [`dist::fit_dist`] runs the same two loops against workers behind a
//! [`crate::comm::Transport`] — real processes over TCP or the
//! in-process degenerate backend — bitwise-equal to [`fit`] in both
//! storage modes (Contract 8, `rust/tests/dist_equiv.rs`).

use std::fmt;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::comm::allreduce::{
    allreduce_step, allreduce_step_injected, allreduce_step_overlap,
    allreduce_step_overlap_injected, allreduce_step_sharded,
    allreduce_step_sharded_injected, reduce_chunked, GlobalState, ReducePlan,
    ShardedState, SyncScratch,
};
use crate::comm::{Cluster, Ledger, NetModel};
use crate::corpus::{shard_ranges, Csr, MiniBatch, MiniBatchStream};
use crate::engine::bp::{PhiView, Selection, ShardBp};
use crate::engine::traits::{IterStat, LdaParams, Model, TrainResult};
use crate::fault::{FaultEvent, FaultPlan, SyncPhase};
use crate::sched::{select_power, select_power_sharded, PowerParams, PowerSet};
use crate::storage::{Checkpoint, CkptExpect, PhiShard, PhiStorageMode};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

pub mod dist;

pub use dist::{fit_dist, fit_dist_resilient};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct PobpConfig {
    /// number of (simulated) processors N
    pub n_workers: usize,
    /// OS-thread cap for the simulation (0 = all cores)
    pub max_threads: usize,
    /// Pin pool threads to cores (best-effort, `comm::affinity`): a pure
    /// performance hint — results are bitwise identical pinned or
    /// floating, and where the OS refuses affinity the run logs once and
    /// continues unpinned. CLI `--pin-cores`, TOML `[run] pin_cores`.
    pub pin_cores: bool,
    /// non-zero entries **per processor** per mini-batch (paper §4:
    /// "NNZ ≈ 45,000 in each mini-batch ... fit into 2 GB memory of each
    /// processor"): the global mini-batch holds `nnz_budget × N` entries,
    /// which is what makes PUBMED's M = 19 at N = 256.
    /// `usize::MAX` = single batch (batch BP mode)
    pub nnz_budget: usize,
    /// power word/topic ratios (λ_W, λ_K·K)
    pub power: PowerParams,
    /// max iterations per mini-batch T_m
    pub max_iters: usize,
    /// minimum iterations before the convergence check may fire. BP from
    /// random init has a residual *dip* before topic symmetry breaks (the
    /// messages barely move while φ̂ is still near-uniform), so line 26's
    /// threshold would otherwise fire spuriously at t = 2.
    pub min_iters: usize,
    /// convergence threshold on mean residual per token (line 26; 0.1)
    pub converge_thresh: f64,
    /// additional *relative* convergence condition: the residual must
    /// also fall below this fraction of the first iteration's residual.
    /// Under power selection the absolute threshold alone fires too
    /// early — the power-law concentration (§3.3) means the un-selected
    /// tail's stale residual is small even though those words have
    /// barely been updated.
    pub converge_rel: f64,
    pub net: NetModel,
    pub seed: u64,
    /// record a model snapshot every this many synchronizations
    /// (0 = never); used for perplexity-vs-time curves
    pub snapshot_every: usize,
    /// run the overlap pipeline: slice-granular gather/fold allreduce,
    /// next-batch shard construction overlapped with the fold, the
    /// fold's transfer deferred into the next batch's t = 1 window, and
    /// `max(compute, comm)` ledger accounting per iteration. Bitwise
    /// identical results to the serialized mode (see module doc);
    /// default `false` = the paper's serialized BSP accounting.
    pub overlap: bool,
    /// φ̂ storage layout: `Replicated` keeps the classic dense `W·K`
    /// replica on every processor (the bitwise oracle); `Sharded`
    /// stores only a row-aligned owner slice per logical worker —
    /// O(W·K/N) per-worker φ̂ memory with bitwise-identical results
    /// (Contract 5, `rust/tests/shard_equiv.rs`). Sharded mode does
    /// not support the overlap pipeline yet.
    pub storage: PhiStorageMode,
}

impl Default for PobpConfig {
    fn default() -> Self {
        PobpConfig {
            n_workers: 4,
            max_threads: 0,
            pin_cores: false,
            nnz_budget: 45_000,
            power: PowerParams::paper_default(),
            max_iters: 50,
            min_iters: 5,
            converge_thresh: 0.1,
            converge_rel: 0.01,
            net: NetModel::infiniband_20gbps(),
            seed: 42,
            snapshot_every: 0,
            overlap: false,
            storage: PhiStorageMode::Replicated,
        }
    }
}

impl PobpConfig {
    /// Single-processor online BP (the paper: "If N = 1, POBP reduces to
    /// the OBP algorithm").
    pub fn obp(seed: u64) -> PobpConfig {
        PobpConfig { n_workers: 1, power: PowerParams::full(), seed, ..Default::default() }
    }

    /// Single-processor batch BP ("If M = 1, POBP reduces to the parallel
    /// batch BP algorithm" — with N = 1 it is plain batch BP).
    pub fn batch_bp(seed: u64) -> PobpConfig {
        PobpConfig {
            n_workers: 1,
            nnz_budget: usize::MAX,
            power: PowerParams::full(),
            seed,
            ..Default::default()
        }
    }

    /// Check for unsupported or degenerate combinations. Every `fit_*`
    /// entry point calls this before touching the corpus, so invalid
    /// configurations surface as typed [`ConfigError`]s at the front
    /// door instead of panics deep inside a training loop.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.n_workers == 0 {
            return Err(ConfigError::ZeroWorkers);
        }
        if self.max_iters == 0 {
            return Err(ConfigError::ZeroMaxIters);
        }
        if self.nnz_budget == 0 {
            return Err(ConfigError::ZeroNnzBudget);
        }
        if self.overlap && self.storage == PhiStorageMode::Sharded {
            return Err(ConfigError::OverlapShardedUnsupported);
        }
        Ok(())
    }
}

/// A rejected configuration. Every unsupported combination that used to
/// be an `assert!` inside a fit loop is a typed variant here, so front
/// ends (TOML configs, CLI flags) can report it before any work starts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ConfigError {
    /// `storage = sharded` with `overlap = true`: the overlap pipeline
    /// is not wired through sharded storage yet.
    OverlapShardedUnsupported,
    /// `overlap = true` through a distributed transport: the pipelined
    /// allreduce is not wired through the wire protocol yet.
    OverlapDistUnsupported,
    /// `n_workers == 0`
    ZeroWorkers,
    /// `max_iters == 0`
    ZeroMaxIters,
    /// `nnz_budget == 0`
    ZeroNnzBudget,
    /// checkpointing or resume requested without a checkpoint directory
    CheckpointDirMissing,
    /// `keep_checkpoints == 0` would prune a checkpoint the moment it
    /// is written, leaving nothing to recover from
    ZeroKeepCheckpoints,
    /// the straggler timeout must be a positive finite multiple of the
    /// modeled sync time
    BadStragglerFactor(f64),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::OverlapShardedUnsupported => write!(
                f,
                "sharded storage does not support the overlap pipeline yet \
                 (set overlap = false or storage = replicated)"
            ),
            ConfigError::OverlapDistUnsupported => write!(
                f,
                "the overlap pipeline does not run over a distributed \
                 transport yet (set overlap = false or fit in-process)"
            ),
            ConfigError::ZeroWorkers => write!(f, "n_workers must be at least 1"),
            ConfigError::ZeroMaxIters => write!(f, "max_iters must be at least 1"),
            ConfigError::ZeroNnzBudget => write!(f, "nnz_budget must be positive"),
            ConfigError::CheckpointDirMissing => {
                write!(f, "checkpointing is enabled but checkpoint_dir is empty")
            }
            ConfigError::ZeroKeepCheckpoints => {
                write!(f, "keep_checkpoints must be at least 1")
            }
            ConfigError::BadStragglerFactor(x) => write!(
                f,
                "straggler_timeout_factor must be positive and finite, got {x}"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Why a training attempt did not produce a [`TrainResult`].
#[derive(Debug)]
pub enum TrainError {
    /// rejected by [`PobpConfig::validate`] / [`ResilienceConfig::validate`]
    Config(ConfigError),
    /// an injected kill fired; `sim_secs_at_death` is the simulated
    /// clock at the kill point, which [`fit_resilient`] uses to charge
    /// the recovery replay exactly
    Killed { fault: FaultEvent, sim_secs_at_death: f64 },
    /// [`fit_resilient`] gave up: kills kept firing past `max_retries`
    RetriesExhausted { fault: FaultEvent, retries: usize },
    /// checkpoint I/O or state-restore failure
    Checkpoint(String),
    /// distributed transport failure: a worker connection died, a frame
    /// was refused, or a peer broke protocol
    /// ([`crate::comm::TransportError`])
    Transport(String),
}

impl TrainError {
    fn killed(fault: FaultEvent, ledger: &Ledger) -> TrainError {
        TrainError::Killed { fault, sim_secs_at_death: ledger.total_secs() }
    }
}

impl fmt::Display for TrainError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrainError::Config(e) => write!(f, "invalid configuration: {e}"),
            TrainError::Killed { fault, sim_secs_at_death } => {
                write!(f, "{fault} at simulated t={sim_secs_at_death:.3}s")
            }
            TrainError::RetriesExhausted { fault, retries } => write!(
                f,
                "gave up after {retries} retries; last fault: {fault}"
            ),
            TrainError::Checkpoint(msg) => write!(f, "checkpoint failure: {msg}"),
            TrainError::Transport(msg) => write!(f, "transport failure: {msg}"),
        }
    }
}

impl std::error::Error for TrainError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TrainError::Config(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ConfigError> for TrainError {
    fn from(e: ConfigError) -> TrainError {
        TrainError::Config(e)
    }
}

/// Fault-tolerance knobs for [`fit_resilient`] (Contract 6,
/// docs/ARCHITECTURE.md).
#[derive(Clone, Debug)]
pub struct ResilienceConfig {
    /// write a checkpoint after every this many completed mini-batches
    /// (0 = never checkpoint; recovery then replays from scratch)
    pub checkpoint_every: usize,
    /// where checkpoint files live (created on first write)
    pub checkpoint_dir: PathBuf,
    /// how many recent checkpoints to retain (≥ 1); older files are
    /// pruned after each successful write
    pub keep_checkpoints: usize,
    /// how many kills [`fit_resilient`] absorbs before giving up
    pub max_retries: usize,
    /// straggler timeout = this factor × the modeled allreduce time for
    /// the iteration's payload, floored at one network latency
    /// ([`NetModel::straggler_timeout_secs`])
    pub straggler_timeout_factor: f64,
    /// start by loading the newest matching checkpoint from
    /// `checkpoint_dir` (resume a previously interrupted process)
    pub resume: bool,
}

impl ResilienceConfig {
    /// Checkpoint every batch into `dir`, keep two, absorb three kills.
    pub fn in_dir(dir: impl Into<PathBuf>) -> ResilienceConfig {
        ResilienceConfig {
            checkpoint_every: 1,
            checkpoint_dir: dir.into(),
            keep_checkpoints: 2,
            max_retries: 3,
            straggler_timeout_factor: 4.0,
            resume: false,
        }
    }

    /// Typed validation, same contract as [`PobpConfig::validate`].
    pub fn validate(&self) -> Result<(), ConfigError> {
        if (self.checkpoint_every > 0 || self.resume)
            && self.checkpoint_dir.as_os_str().is_empty()
        {
            return Err(ConfigError::CheckpointDirMissing);
        }
        if self.keep_checkpoints == 0 {
            return Err(ConfigError::ZeroKeepCheckpoints);
        }
        if !self.straggler_timeout_factor.is_finite()
            || self.straggler_timeout_factor <= 0.0
        {
            return Err(ConfigError::BadStragglerFactor(self.straggler_timeout_factor));
        }
        Ok(())
    }
}

/// Per-attempt harness state threaded into the storage-specific run
/// loops: resilience knobs, the fault plan, and — on recovery — the
/// checkpoint to restore plus the replay time to charge.
struct RunCtx<'a> {
    res: Option<&'a ResilienceConfig>,
    faults: Option<&'a FaultPlan>,
    resume: Option<Checkpoint>,
    replay_secs: f64,
}

impl RunCtx<'_> {
    /// A plain, unfaulted, checkpoint-free run.
    fn bare() -> RunCtx<'static> {
        RunCtx { res: None, faults: None, resume: None, replay_secs: 0.0 }
    }
}

/// Restore-time sanity: a checkpoint handed to a run loop must describe
/// the same problem and configuration. [`fit_resilient`] already
/// filters candidates through [`CkptExpect`]; this guards direct misuse.
fn check_resume(
    ck: &Checkpoint,
    w: usize,
    k: usize,
    cfg: &PobpConfig,
) -> Result<(), TrainError> {
    let ok = ck.w == w
        && ck.k == k
        && ck.n_workers == cfg.n_workers
        && ck.seed == cfg.seed
        && ck.phi.mode() == cfg.storage;
    if ok {
        Ok(())
    } else {
        Err(TrainError::Checkpoint(format!(
            "checkpoint ({}x{}, n={}, seed={}, {:?}) does not match the run \
             ({}x{}, n={}, seed={}, {:?})",
            ck.w,
            ck.k,
            ck.n_workers,
            ck.seed,
            ck.phi.mode(),
            w,
            k,
            cfg.n_workers,
            cfg.seed,
            cfg.storage,
        )))
    }
}

/// Write `ck` atomically under the resilience config's directory
/// (tmp-file + rename, retention pruning) and charge the measured I/O
/// to the live ledger's side accumulators — never to `total_secs()`.
fn write_checkpoint(
    res: &ResilienceConfig,
    ck: &Checkpoint,
    ledger: &mut Ledger,
) -> Result<(), TrainError> {
    let t0 = std::time::Instant::now();
    let (_, bytes) = ck
        .write(&res.checkpoint_dir, res.keep_checkpoints)
        .map_err(|e| TrainError::Checkpoint(e.to_string()))?;
    ledger.record_checkpoint(bytes, t0.elapsed().as_secs_f64());
    Ok(())
}

/// Build one mini-batch's worker shards (Fig. 4 lines 3-5). The worker
/// RNG streams split off `rng` in worker order, once per batch — the
/// overlap pipeline calls this concurrently with the previous batch's
/// fold, and draws the splits at the same point of the stream either
/// way, so both modes see identical randomness.
fn build_shards(
    mb: &MiniBatch,
    k: usize,
    n_workers: usize,
    rng: &mut Rng,
) -> Vec<Mutex<ShardBp>> {
    let ranges = shard_ranges(mb.data.docs(), n_workers);
    let mut worker_rngs: Vec<Rng> =
        (0..n_workers).map(|n| rng.split(n as u64)).collect();
    ranges
        .iter()
        .zip(worker_rngs.iter_mut())
        .map(|(rg, wrng)| {
            Mutex::new(ShardBp::init(mb.data.slice_docs(rg.start, rg.end), k, wrng))
        })
        .collect()
}

/// Trains LDA with POBP over `corpus` and returns the learned model plus
/// the full cost decomposition. Dispatches on [`PobpConfig::storage`];
/// both modes produce bitwise-identical models, totals and residual
/// histories (Contract 5).
///
/// Panics on an invalid configuration; use [`fit_checked`] for the typed
/// error.
pub fn fit(corpus: &Csr, params: &LdaParams, cfg: &PobpConfig) -> TrainResult {
    match fit_checked(corpus, params, cfg) {
        Ok(r) => r,
        Err(e) => panic!("{e}"),
    }
}

/// [`fit`] with typed configuration errors instead of panics.
pub fn fit_checked(
    corpus: &Csr,
    params: &LdaParams,
    cfg: &PobpConfig,
) -> Result<TrainResult, TrainError> {
    cfg.validate()?;
    match cfg.storage {
        PhiStorageMode::Replicated => {
            fit_replicated(corpus, params, cfg, RunCtx::bare())
        }
        PhiStorageMode::Sharded => fit_sharded(corpus, params, cfg, RunCtx::bare()),
    }
}

/// Fault-tolerant [`fit`] (Contract 6): writes a crash-consistent
/// checkpoint every `res.checkpoint_every` completed mini-batches, and
/// when a (possibly injected) kill fires, resumes from the newest good
/// checkpoint — deterministically replaying the interrupted batch —
/// until the run completes or `res.max_retries` kills have been
/// absorbed.
///
/// The recovered result is **bitwise identical** to an uninterrupted
/// run at any thread budget and in both storage modes
/// (`rust/tests/fault_equiv.rs`); only the ledger's side accumulators
/// (checkpoint I/O, straggler wait, recovery replay) record that the
/// road was bumpy. Corrupt or mismatching checkpoint files are skipped
/// in favor of the previous good one; with none left, recovery replays
/// from scratch.
pub fn fit_resilient(
    corpus: &Csr,
    params: &LdaParams,
    cfg: &PobpConfig,
    res: &ResilienceConfig,
    faults: Option<&FaultPlan>,
) -> Result<TrainResult, TrainError> {
    cfg.validate()?;
    res.validate()?;
    let expect = CkptExpect {
        w: corpus.w,
        k: params.k,
        n_workers: cfg.n_workers,
        seed: cfg.seed,
        mode: cfg.storage,
    };
    let mut allow_resume = res.resume;
    let mut last_death: Option<f64> = None;
    let mut retries = 0usize;
    loop {
        let resume = if allow_resume {
            Checkpoint::load_latest_good(&res.checkpoint_dir, Some(&expect))
                .map(|(ck, _)| ck)
        } else {
            None
        };
        // Replay cost: the simulated time the dead attempt had covered
        // past the restore point (or past t = 0 with no checkpoint).
        let resumed_secs = resume.as_ref().map_or(0.0, |ck| ck.ledger.total_secs());
        let replay_secs = last_death.map_or(0.0, |d| (d - resumed_secs).max(0.0));
        let ctx = RunCtx { res: Some(res), faults, resume, replay_secs };
        let attempt = match cfg.storage {
            PhiStorageMode::Replicated => fit_replicated(corpus, params, cfg, ctx),
            PhiStorageMode::Sharded => fit_sharded(corpus, params, cfg, ctx),
        };
        match attempt {
            Err(TrainError::Killed { fault, sim_secs_at_death }) => {
                retries += 1;
                if retries > res.max_retries {
                    return Err(TrainError::RetriesExhausted {
                        fault,
                        retries: res.max_retries,
                    });
                }
                last_death = Some(sim_secs_at_death);
                allow_resume = true;
            }
            other => return other,
        }
    }
}

/// [`fit`] in replicated storage mode: the dense `W·K` φ̂ replica, the
/// paper's layout and the bitwise oracle for the sharded mode.
fn fit_replicated(
    corpus: &Csr,
    params: &LdaParams,
    cfg: &PobpConfig,
    ctx: RunCtx<'_>,
) -> Result<TrainResult, TrainError> {
    let RunCtx { res, faults, resume, replay_secs } = ctx;
    let mut wall = Stopwatch::new();
    let (w, k) = (corpus.w, params.k);
    let cluster = Cluster::new(cfg.n_workers, cfg.max_threads).with_pinning(cfg.pin_cores);
    let mut ledger = Ledger::new(cfg.net);
    let mut history = Vec::new();
    let mut snapshots: Vec<(f64, Model)> = Vec::new();
    let mut rng = Rng::new(cfg.seed);

    // Global accumulated sufficient statistics φ̂ (Eq. 11's phi^{m}).
    let mut phi_acc = vec![0f32; w * k];
    // Snapshot cadence counts *iteration* syncs only: the end-of-batch
    // fold also bumps `ledger.sync_count()`, which would skip/shift
    // snapshots whose multiple lands on a fold.
    let mut iter_syncs = 0usize;
    // Stream cursor of a resumed run: (next_doc, next_batch).
    let mut cursor: Option<(usize, usize)> = None;
    if let Some(ck) = resume {
        // Contract 6 restore: every piece of training state the loop
        // below reads comes off the checkpoint, so the continuation is
        // the same deterministic program an uninterrupted run executes.
        check_resume(&ck, w, k, cfg)?;
        phi_acc = ck.phi.to_dense();
        rng = Rng::from_state(ck.rng_state);
        iter_syncs = ck.iter_syncs;
        ledger = ck.ledger;
        history = ck.history;
        snapshots = ck.snapshots;
        cursor = Some((ck.next_doc, ck.next_batch));
    }
    // Simulated time the dead attempt covered past the restore point —
    // a side accumulator, never part of `total_secs()` (Contract 6).
    ledger.record_recovery_replay(replay_secs);
    // Reusable synchronization buffers (gather exports, owner-slot
    // permutation, totals deltas) and the plan-index buffer — held for
    // the whole run so the O(pairs) gather/reduction storage never
    // reallocates across syncs (small per-dispatch task vectors remain).
    let mut scratch = SyncScratch::default();
    let mut flat_buf: Vec<u32> = Vec::new();

    let global_budget = cfg.nnz_budget.saturating_mul(cfg.n_workers);
    let mut stream = match cursor {
        Some((doc, batch)) => {
            MiniBatchStream::resume(corpus, global_budget, doc, batch)
        }
        None => MiniBatchStream::new(corpus, global_budget),
    };
    let mut pending = stream.next();
    // Shards of the upcoming batch, possibly prebuilt by the overlap
    // pipeline during the previous batch's fold.
    let mut prebuilt: Option<Vec<Mutex<ShardBp>>> = None;
    while let Some(mb) = pending.take() {
        let tokens = mb.data.tokens().max(1.0);

        let shards: Vec<Mutex<ShardBp>> = match prebuilt.take() {
            Some(s) => s,
            None => build_shards(&mb, k, cfg.n_workers, &mut rng),
        };

        // Working global state for this batch: φ̂ = phi_acc + Σ_n Δφ̂_n,
        // plus the synchronized residual matrix — totals f64-backed
        // against incremental drift (comm::allreduce::GlobalState).
        let mut state = GlobalState::new(&phi_acc, k);
        let mut selection = Selection::full(w);
        // None = full sync; the full schedule stays implicit — there is
        // deliberately no way to materialize an all-pairs PowerSet
        // (O(W·K) heap at PUBMED scale).
        let mut power: Option<PowerSet> = None;
        let mut prev_resid = f64::INFINITY;
        let mut first_resid = f64::INFINITY;
        let mut iters_run = 0;

        for t in 1..=cfg.max_iters {
            iters_run = t;
            // --- fault injection (Contract 6): a planned sweep-phase
            //     kill fires before any work on this iteration ---
            if let Some(f) = faults {
                f.trip(mb.index, t, SyncPhase::Sweep)
                    .map_err(|e| TrainError::killed(e, &ledger))?;
            }
            // --- doc-parallel sweep (lines 6-8 / 15-20): each worker
            //     fans its shard's fixed NNZ-derived doc blocks over its
            //     share of the OS-thread pool, so an N = 1 (OBP) run
            //     saturates the whole machine instead of one core.
            //     Residual clearing is folded into the sweep's merge. ---
            let budget = cluster.doc_threads_per_worker();
            let phi_ref: &[f32] = &state.phi_eff;
            let tot_ref: &[f32] = state.phi_tot();
            let sel_ref = &selection;
            let (reports, _wall) = cluster.run(|n| {
                let mut shard = shards[n].lock().unwrap();
                shard.sweep_parallel(
                    &cluster, budget, phi_ref, tot_ref, sel_ref, params, true,
                )
            });
            // per-worker compute from the per-block timings: the worker's
            // own critical path on its thread budget, robust to the pool
            // contention the raw closure wall clock would over-count when
            // logical workers are multiplexed over fewer cores
            let secs: Vec<f64> = reports
                .iter()
                .map(|(_, timing)| timing.critical_path_secs(budget))
                .collect();

            // --- synchronize Δφ̂ and r on the scheduled pairs (lines
            //     9-10 / 23-24, Eqs. 9 & 15): owner-sliced
            //     reduce-scatter, one call for both the full and the
            //     power schedule; overlap mode runs the double-buffered
            //     pipelined variant (bitwise-identical results) ---
            let plan = match &power {
                None => ReducePlan::Dense { len: w * k },
                Some(ps) => {
                    ps.flat_indices_into(k, &mut flat_buf);
                    ReducePlan::Subset { indices: &flat_buf }
                }
            };
            let pairs = match (cfg.overlap, faults) {
                (true, None) => allreduce_step_overlap(
                    &cluster, &plan, &phi_acc, &shards, &mut state, &mut scratch,
                ),
                (false, None) => {
                    allreduce_step(&cluster, &plan, &phi_acc, &shards, &mut state, &mut scratch)
                }
                // fault-aware variants: the step runs, then a planned
                // mid-reduce kill fires inside the sync boundary (the
                // partial republish is discarded by the batch replay)
                (true, Some(f)) => allreduce_step_overlap_injected(
                    &cluster, &plan, &phi_acc, &shards, &mut state, &mut scratch, f,
                    mb.index, t,
                )
                .map_err(|e| TrainError::killed(e, &ledger))?,
                (false, Some(f)) => allreduce_step_injected(
                    &cluster, &plan, &phi_acc, &shards, &mut state, &mut scratch, f,
                    mb.index, t,
                )
                .map_err(|e| TrainError::killed(e, &ledger))?,
            };
            // two f32 matrices (φ̂ and r) restricted to the selection
            let payload = 2 * 4 * pairs;
            if cfg.overlap {
                // pipelined iteration: comm hides behind compute
                ledger.record_overlapped_iter(mb.index, t, payload, cfg.n_workers, &secs);
            } else {
                ledger.record_compute(&secs);
                ledger.record_sync(mb.index, t, payload, cfg.n_workers);
            }
            // --- injected straggler delays: the slow workers finish
            //     late, and the leader's timeout/backoff wait lands in a
            //     side accumulator under the Σmax invariant
            //     ([`Ledger::record_straggler`]) — `total_secs()` keeps
            //     the fault-free bits ---
            if let Some(delays) =
                faults.and_then(|f| f.delays_at(mb.index, t, cfg.n_workers))
            {
                let factor = res.map_or(4.0, |r| r.straggler_timeout_factor);
                let timeout =
                    cfg.net.straggler_timeout_secs(payload, cfg.n_workers, factor);
                ledger.record_straggler(&secs, &delays, timeout);
            }

            iter_syncs += 1;
            let resid_per_token = state.r_total() / tokens;
            if cfg.snapshot_every > 0 && iter_syncs % cfg.snapshot_every == 0 {
                snapshots.push((
                    ledger.total_secs(),
                    Model { k, w, phi_wk: state.phi_eff.clone() },
                ));
            }
            history.push(IterStat {
                batch: mb.index,
                iter: t,
                residual_per_token: resid_per_token,
                synced_pairs: pairs,
                sim_elapsed: ledger.total_secs(),
                wall_elapsed: wall.total_secs(),
            });

            // --- convergence check (line 26) ---
            // Fire only on the decaying side of the residual curve: BP
            // from random init dips before topic symmetry breaks, then
            // humps; a plain threshold would stop inside the dip.
            if t == 1 {
                first_resid = resid_per_token.max(1e-12);
            }
            if t >= cfg.min_iters
                && resid_per_token <= cfg.converge_thresh
                && resid_per_token <= cfg.converge_rel * first_resid
                && resid_per_token <= prev_resid
            {
                break;
            }
            prev_resid = resid_per_token;

            // --- dynamic power selection for the next iteration
            //     (lines 12-13 / 27-28) ---
            if cfg.power.lambda_w < 1.0 || cfg.power.lambda_k_times_k < k {
                let ps = select_power(&state.r_global, w, k, &cfg.power);
                selection = Selection::from_power(&ps, w);
                power = Some(ps);
            }
        }

        // --- fold the batch gradient into the global model (Eq. 11) ---
        // phi_eff already equals phi_acc + Σ_n Δφ̂_n on every pair that was
        // last synchronized; un-synced pairs differ only by worker-local
        // updates not yet communicated, so the fold ships one final full
        // φ̂ matrix (the paper frees the batch keeping the global matrix,
        // line 30) — and charges it: one sync per batch on top of the
        // per-iteration ones, so sync_count = Σ_batches (iters + 1). In
        // overlap mode the fold's *transfer* is deferred into the next
        // batch's t = 1 window (`record_sync_deferred`: bytes and count
        // exact now, comm hidden behind the next sweep's max(compute,
        // comm)); the leader-side folding work itself stays serialized.
        // Overlap mode also builds the *next* batch's shards concurrently
        // with the fold — both leader-side, disjoint state, and the RNG
        // splits happen at the same stream position either way.
        let next_mb = stream.next();
        // Contract 6: the checkpointed RNG position is the batch
        // boundary — after this batch's worker splits, before the next
        // batch's (which the fold block below draws).
        let rng_boundary = rng.state();
        // A planned fold-phase kill fires before the fold mutates
        // φ̂_acc, so the checkpointed state stays batch-consistent.
        if let Some(f) = faults {
            f.trip(mb.index, iters_run + 1, SyncPhase::Fold)
                .map_err(|e| TrainError::killed(e, &ledger))?;
        }
        {
            let guards: Vec<_> = shards.iter().map(|s| s.lock().unwrap()).collect();
            let dphi_parts: Vec<&[f32]> =
                guards.iter().map(|g| g.dphi.as_slice()).collect();
            if cfg.overlap {
                let rng_ref = &mut rng;
                prebuilt = std::thread::scope(|scope| {
                    let prefetch = next_mb.as_ref().map(|nmb| {
                        scope.spawn(move || build_shards(nmb, k, cfg.n_workers, rng_ref))
                    });
                    reduce_chunked(&cluster, Some(&phi_acc), &dphi_parts, &mut state.phi_eff);
                    prefetch.map(|h| h.join().expect("shard prefetch thread"))
                });
            } else {
                reduce_chunked(&cluster, Some(&phi_acc), &dphi_parts, &mut state.phi_eff);
                prebuilt =
                    next_mb.as_ref().map(|nmb| build_shards(nmb, k, cfg.n_workers, &mut rng));
            }
            drop(guards);
            phi_acc.copy_from_slice(&state.phi_eff);
            if cfg.overlap {
                ledger.record_sync_deferred(mb.index, iters_run + 1, 4 * w * k, cfg.n_workers);
            } else {
                ledger.record_sync(mb.index, iters_run + 1, 4 * w * k, cfg.n_workers);
            }
        }
        // --- checkpoint cadence (Contract 6): after the fold, φ̂ and
        //     the ledger are batch-consistent; the cursor names the
        //     batch the restored run starts from ---
        if let (Some(r), Some(nmb)) = (res, next_mb.as_ref()) {
            if r.checkpoint_every > 0 && (mb.index + 1) % r.checkpoint_every == 0 {
                let ck = Checkpoint {
                    w,
                    k,
                    n_workers: cfg.n_workers,
                    seed: cfg.seed,
                    next_batch: nmb.index,
                    next_doc: nmb.doc_range.start,
                    iter_syncs,
                    rng_state: rng_boundary,
                    phi: PhiShard::Replicated(phi_acc.clone()),
                    ledger: ledger.clone(),
                    history: history.clone(),
                    snapshots: snapshots.clone(),
                };
                write_checkpoint(r, &ck, &mut ledger)?;
            }
        }
        pending = next_mb;
        let _ = wall.lap_secs();
    }

    Ok(TrainResult {
        model: Model { k, w, phi_wk: phi_acc },
        history,
        ledger,
        wall_secs: wall.total_secs(),
        snapshots,
    })
}

/// [`fit`] in **sharded** storage mode: each logical worker persistently
/// holds only its row-aligned owner slice of φ̂ and the synchronized
/// residual matrix ([`PhiShard::Sharded`] / [`ShardedState`]) — per-worker
/// model memory O(W·K/N) — while every number (model, totals, residual
/// history) stays bitwise equal to [`fit_replicated`]. The differences
/// are pure reorderings of identical arithmetic:
///
/// * sweeps read φ̂ rows in place through [`PhiView::Slices`] — the same
///   bits [`fit_replicated`]'s dense rows hand the kernels;
/// * the allreduce folds into the stored slices
///   ([`allreduce_step_sharded`]), per-element left folds and per-owner
///   f64 totals in the replicated op order;
/// * power selection reads the sharded residual slices
///   ([`select_power_sharded`], bitwise-equal schedule);
/// * the ledger charges the reduce-scatter and the allgather halves
///   separately ([`Ledger::record_sync_split`]): the reduce ships the
///   synchronized pairs, the gather ships the **next** iteration's φ̂
///   working set (the full matrix before a dense sweep, the selected
///   rows before a power sweep, nothing when the batch stops here).
///
/// The overlap pipeline is not wired through sharded storage yet;
/// `cfg.overlap` is rejected up front by [`PobpConfig::validate`]
/// ([`ConfigError::OverlapShardedUnsupported`]).
fn fit_sharded(
    corpus: &Csr,
    params: &LdaParams,
    cfg: &PobpConfig,
    ctx: RunCtx<'_>,
) -> Result<TrainResult, TrainError> {
    let RunCtx { res, faults, resume, replay_secs } = ctx;
    let mut wall = Stopwatch::new();
    let (w, k) = (corpus.w, params.k);
    let cluster = Cluster::new(cfg.n_workers, cfg.max_threads).with_pinning(cfg.pin_cores);
    let mut ledger = Ledger::new(cfg.net);
    let mut history = Vec::new();
    let mut snapshots: Vec<(f64, Model)> = Vec::new();
    let mut rng = Rng::new(cfg.seed);

    // Global accumulated φ̂ (Eq. 11's phi^{m}), stored as row-aligned
    // owner slices — no worker ever holds the dense matrix.
    let mut phi_acc = PhiShard::sharded(w, k, cfg.n_workers);
    // iteration-sync counter for the snapshot cadence (see
    // fit_replicated: the end-of-batch fold must not shift snapshots)
    let mut iter_syncs = 0usize;
    // Stream cursor of a resumed run: (next_doc, next_batch).
    let mut cursor: Option<(usize, usize)> = None;
    if let Some(ck) = resume {
        // Contract 6 restore, sharded flavor: the decoded checkpoint's
        // owner partition is the canonical row-aligned split for
        // (W, K, N), i.e. exactly what `PhiShard::sharded` above built.
        check_resume(&ck, w, k, cfg)?;
        phi_acc = ck.phi;
        rng = Rng::from_state(ck.rng_state);
        iter_syncs = ck.iter_syncs;
        ledger = ck.ledger;
        history = ck.history;
        snapshots = ck.snapshots;
        cursor = Some((ck.next_doc, ck.next_batch));
    }
    ledger.record_recovery_replay(replay_secs);
    let os = phi_acc.owner_slices();
    let rows_per = phi_acc.rows_per();
    let mut scratch = SyncScratch::default();
    let mut flat_buf: Vec<u32> = Vec::new();

    let global_budget = cfg.nnz_budget.saturating_mul(cfg.n_workers);
    let mut stream = match cursor {
        Some((doc, batch)) => {
            MiniBatchStream::resume(corpus, global_budget, doc, batch)
        }
        None => MiniBatchStream::new(corpus, global_budget),
    };
    let mut pending = stream.next();
    while let Some(mb) = pending.take() {
        let tokens = mb.data.tokens().max(1.0);
        // worker RNG splits drawn at the same stream position as the
        // replicated path (once per batch, batch order), so both modes
        // see identical shard initialization
        let shards: Vec<Mutex<ShardBp>> = build_shards(&mb, k, cfg.n_workers, &mut rng);

        // Per-batch working state: φ̂_eff and r as per-owner stored
        // slices, f64-backed totals (comm::allreduce::ShardedState).
        let mut state = ShardedState::new(phi_acc.parts(), k, os);
        let mut selection = Selection::full(w);
        let mut power: Option<PowerSet> = None;
        let mut prev_resid = f64::INFINITY;
        let mut first_resid = f64::INFINITY;
        let mut iters_run = 0;

        for t in 1..=cfg.max_iters {
            iters_run = t;
            // --- fault injection (Contract 6): a planned sweep-phase
            //     kill fires before any work on this iteration ---
            if let Some(f) = faults {
                f.trip(mb.index, t, SyncPhase::Sweep)
                    .map_err(|e| TrainError::killed(e, &ledger))?;
            }
            // --- doc-parallel sweep, φ̂ rows read in place from the
            //     owner slices (no gather materialization leader-side;
            //     the simulated transfer is charged below) ---
            let budget = cluster.doc_threads_per_worker();
            let (reports, _wall) = {
                let phi_parts = state.phi_parts();
                let view = PhiView::Slices { parts: &phi_parts, rows_per };
                let tot_ref: &[f32] = state.phi_tot();
                let sel_ref = &selection;
                cluster.run(|n| {
                    let mut shard = shards[n].lock().unwrap();
                    shard.sweep_parallel_view(
                        &cluster, budget, view, tot_ref, sel_ref, params, true,
                    )
                })
            };
            let secs: Vec<f64> = reports
                .iter()
                .map(|(_, timing)| timing.critical_path_secs(budget))
                .collect();

            // --- owner-sliced reduce-scatter into the stored slices ---
            let plan = match &power {
                None => ReducePlan::Dense { len: w * k },
                Some(ps) => {
                    ps.flat_indices_into(k, &mut flat_buf);
                    ReducePlan::Subset { indices: &flat_buf }
                }
            };
            let pairs = match faults {
                None => allreduce_step_sharded(
                    &cluster, &plan, phi_acc.parts(), &shards, &mut state, &mut scratch,
                ),
                // fault-aware variant: the step runs, then a planned
                // mid-reduce kill fires inside the sync boundary
                Some(f) => allreduce_step_sharded_injected(
                    &cluster, &plan, phi_acc.parts(), &shards, &mut state,
                    &mut scratch, f, mb.index, t,
                )
                .map_err(|e| TrainError::killed(e, &ledger))?,
            };

            // --- convergence decision first (line 26), so the ledger's
            //     allgather half can charge exactly the next sweep's
            //     working set — nothing when the batch stops here ---
            let resid_per_token = state.r_total() / tokens;
            if t == 1 {
                first_resid = resid_per_token.max(1e-12);
            }
            let converged = t >= cfg.min_iters
                && resid_per_token <= cfg.converge_thresh
                && resid_per_token <= cfg.converge_rel * first_resid
                && resid_per_token <= prev_resid;
            let stopping = converged || t == cfg.max_iters;

            // --- dynamic power selection for the next iteration, from
            //     the sharded residual slices (bitwise-equal schedule) ---
            let next: Option<PowerSet> = if !stopping
                && (cfg.power.lambda_w < 1.0 || cfg.power.lambda_k_times_k < k)
            {
                Some(select_power_sharded(&state.r_parts(), rows_per, w, k, &cfg.power))
            } else {
                None
            };

            // reduce half: the synchronized Δφ̂ + r pairs; gather half:
            // the φ̂ working set the next sweep reads (full matrix when
            // the next sweep is dense)
            let reduce_bytes = 2 * 4 * pairs;
            let gather_bytes = if stopping {
                0
            } else {
                4 * next.as_ref().map_or(w * k, |ps| ps.pairs())
            };
            ledger.record_compute(&secs);
            ledger.record_sync_split(mb.index, t, reduce_bytes, gather_bytes, cfg.n_workers);
            // --- injected straggler delays (see fit_replicated): the
            //     leader's wait goes to a side accumulator under the
            //     Σmax invariant; `total_secs()` keeps fault-free bits ---
            if let Some(delays) =
                faults.and_then(|f| f.delays_at(mb.index, t, cfg.n_workers))
            {
                let factor = res.map_or(4.0, |r| r.straggler_timeout_factor);
                let timeout = cfg.net.straggler_timeout_secs(
                    reduce_bytes + gather_bytes,
                    cfg.n_workers,
                    factor,
                );
                ledger.record_straggler(&secs, &delays, timeout);
            }

            iter_syncs += 1;
            if cfg.snapshot_every > 0 && iter_syncs % cfg.snapshot_every == 0 {
                snapshots.push((
                    ledger.total_secs(),
                    Model { k, w, phi_wk: state.render_dense() },
                ));
            }
            history.push(IterStat {
                batch: mb.index,
                iter: t,
                residual_per_token: resid_per_token,
                synced_pairs: pairs,
                sim_elapsed: ledger.total_secs(),
                wall_elapsed: wall.total_secs(),
            });

            if converged {
                break;
            }
            prev_resid = resid_per_token;
            if let Some(ps) = next {
                selection = Selection::from_power(&ps, w);
                power = Some(ps);
            }
        }

        // --- fold the batch gradient into the sharded accumulator
        //     (Eq. 11): each owner folds every worker's Δφ̂ over its own
        //     slice — reduce_chunked's per-element left fold, fused with
        //     the copy-back. The simulated transfer is the replicated
        //     fold's: one full φ̂ matrix reduced and re-gathered
        //     (identical payload and wire bytes to `record_sync`). ---
        let next_mb = stream.next();
        // Contract 6: the batch-boundary RNG position — this batch's
        // splits were drawn at the loop top, the next batch's have not
        // been (the sharded path draws them at the next loop top).
        let rng_boundary = rng.state();
        // A planned fold-phase kill fires before the fold mutates the
        // sharded accumulator, keeping checkpoint state batch-consistent.
        if let Some(f) = faults {
            f.trip(mb.index, iters_run + 1, SyncPhase::Fold)
                .map_err(|e| TrainError::killed(e, &ledger))?;
        }
        {
            let guards: Vec<_> = shards.iter().map(|s| s.lock().unwrap()).collect();
            let dphi_parts: Vec<&[f32]> =
                guards.iter().map(|g| g.dphi.as_slice()).collect();
            state.fold_batch(&cluster, phi_acc.parts_mut(), &dphi_parts);
            drop(guards);
            ledger.record_sync_split(
                mb.index,
                iters_run + 1,
                4 * w * k,
                4 * w * k,
                cfg.n_workers,
            );
        }
        // --- checkpoint cadence (Contract 6): the sharded checkpoint
        //     stores the owner slices as-is; no densification ---
        if let (Some(r), Some(nmb)) = (res, next_mb.as_ref()) {
            if r.checkpoint_every > 0 && (mb.index + 1) % r.checkpoint_every == 0 {
                let ck = Checkpoint {
                    w,
                    k,
                    n_workers: cfg.n_workers,
                    seed: cfg.seed,
                    next_batch: nmb.index,
                    next_doc: nmb.doc_range.start,
                    iter_syncs,
                    rng_state: rng_boundary,
                    phi: phi_acc.clone(),
                    ledger: ledger.clone(),
                    history: history.clone(),
                    snapshots: snapshots.clone(),
                };
                write_checkpoint(r, &ck, &mut ledger)?;
            }
        }
        pending = next_mb;
        let _ = wall.lap_secs();
    }

    Ok(TrainResult {
        model: Model { k, w, phi_wk: phi_acc.to_dense() },
        history,
        ledger,
        wall_secs: wall.total_secs(),
        snapshots,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::{generate, SynthSpec};

    fn tiny() -> Csr {
        generate(&SynthSpec::tiny(17)).corpus
    }

    #[test]
    fn model_mass_equals_corpus_tokens() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = PobpConfig {
            n_workers: 3,
            nnz_budget: 800,
            max_iters: 12,
            ..Default::default()
        };
        let r = fit(&c, &params, &cfg);
        assert!(
            (r.model.mass() - c.tokens()).abs() < c.tokens() * 1e-3,
            "mass {} vs tokens {}",
            r.model.mass(),
            c.tokens()
        );
    }

    #[test]
    fn residual_converges_within_batches() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = PobpConfig { n_workers: 2, nnz_budget: 1500, max_iters: 60, ..Default::default() };
        let r = fit(&c, &params, &cfg);
        // the last iteration of each batch must be at/near the threshold
        let mut per_batch_last: std::collections::BTreeMap<usize, f64> =
            Default::default();
        for st in &r.history {
            per_batch_last.insert(st.batch, st.residual_per_token);
        }
        for (b, resid) in per_batch_last {
            assert!(resid <= 0.25, "batch {b} ended at residual {resid}");
        }
    }

    #[test]
    fn n_workers_does_not_change_result_much() {
        // The allreduce is a deterministic sum; with the same seed the
        // worker split changes init RNG streams, so results are not
        // bitwise equal — but model quality must match closely.
        let c = tiny();
        let params = LdaParams::paper(8);
        let base = PobpConfig { nnz_budget: usize::MAX, max_iters: 30, ..Default::default() };
        let r1 = fit(&c, &params, &PobpConfig { n_workers: 1, ..base.clone() });
        let r4 = fit(&c, &params, &PobpConfig { n_workers: 4, ..base });
        let m1 = r1.model.mass();
        let m4 = r4.model.mass();
        assert!((m1 - m4).abs() < m1 * 1e-3);
        let p1 = crate::eval::perplexity::heldin_perplexity(&r1.model, &c, &params);
        let p4 = crate::eval::perplexity::heldin_perplexity(&r4.model, &c, &params);
        assert!(
            (p1.ln() - p4.ln()).abs() < 0.12,
            "perplexities diverge: {p1} vs {p4}"
        );
    }

    #[test]
    fn power_selection_reduces_payload() {
        let c = tiny();
        let params = LdaParams::paper(8);
        // converge_thresh 0 pins both runs to exactly max_iters syncs so
        // the payload comparison is like-for-like
        let full = fit(&c, &params, &PobpConfig {
            n_workers: 2,
            power: PowerParams::full(),
            max_iters: 15,
            converge_thresh: 0.0,
            ..Default::default()
        });
        let powered = fit(&c, &params, &PobpConfig {
            n_workers: 2,
            power: PowerParams { lambda_w: 0.1, lambda_k_times_k: 4 },
            max_iters: 15,
            converge_thresh: 0.0,
            ..Default::default()
        });
        assert!(
            powered.ledger.payload_bytes_total()
                < full.ledger.payload_bytes_total() / 2,
            "power sync not smaller: {} vs {}",
            powered.ledger.payload_bytes_total(),
            full.ledger.payload_bytes_total()
        );
    }

    #[test]
    fn ledger_charges_final_fold_sync() {
        // converge_thresh 0 pins every batch to exactly max_iters
        // iteration syncs; the end-of-batch fold must add one more.
        let c = tiny();
        let params = LdaParams::paper(8);
        let max_iters = 7;
        let cfg = PobpConfig {
            n_workers: 2,
            nnz_budget: 600,
            max_iters,
            converge_thresh: 0.0,
            ..Default::default()
        };
        let r = fit(&c, &params, &cfg);
        let batches = r.history.iter().map(|s| s.batch).max().unwrap() + 1;
        assert!(batches >= 2, "want a multi-batch run, got {batches}");
        assert_eq!(
            r.ledger.sync_count(),
            batches * (max_iters + 1),
            "every batch must charge its iterations plus one final fold"
        );
        // the fold ships one full W×K φ̂ matrix, recorded past the last
        // iteration index
        let folds = r
            .ledger
            .events
            .iter()
            .filter(|e| e.iter == max_iters + 1)
            .collect::<Vec<_>>();
        assert_eq!(folds.len(), batches);
        for e in &folds {
            assert_eq!(e.payload_bytes, 4 * c.w * 8);
        }
    }

    #[test]
    fn single_worker_obp_mode_runs() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let r = fit(&c, &params, &PobpConfig { nnz_budget: 700, ..PobpConfig::obp(5) });
        assert!(r.ledger.comm_secs == 0.0, "N=1 must not pay comm time");
        assert!(r.model.mass() > 0.0);
    }

    #[test]
    fn sharded_storage_matches_replicated_oracle() {
        // The deep bitwise pins (thread budgets 1/2/8, full + power
        // configs) live in rust/tests/shard_equiv.rs; this is the
        // smoke-level contract: same model bits, same residual
        // trajectory, same pair/byte accounting, smaller resident φ̂.
        let c = tiny();
        let params = LdaParams::paper(8);
        let base = PobpConfig {
            n_workers: 3,
            nnz_budget: 900,
            max_iters: 12,
            ..Default::default()
        };
        let rep = fit(&c, &params, &base);
        let sh = fit(
            &c,
            &params,
            &PobpConfig { storage: PhiStorageMode::Sharded, ..base },
        );
        assert_eq!(sh.model.phi_wk, rep.model.phi_wk);
        assert_eq!(sh.history.len(), rep.history.len());
        for (a, b) in sh.history.iter().zip(&rep.history) {
            assert_eq!(
                a.residual_per_token.to_bits(),
                b.residual_per_token.to_bits()
            );
            assert_eq!(a.synced_pairs, b.synced_pairs);
        }
        assert_eq!(sh.ledger.sync_count(), rep.ledger.sync_count());
        assert_eq!(
            sh.ledger.payload_bytes_total(),
            rep.ledger.payload_bytes_total()
        );
    }

    #[test]
    fn sharded_storage_rejects_overlap() {
        // the combination fails closed with a typed error — both at
        // validation time and through the checked front door
        let cfg = PobpConfig {
            storage: PhiStorageMode::Sharded,
            overlap: true,
            ..Default::default()
        };
        assert_eq!(cfg.validate(), Err(ConfigError::OverlapShardedUnsupported));
        let c = tiny();
        let params = LdaParams::paper(8);
        match fit_checked(&c, &params, &cfg) {
            Err(TrainError::Config(e)) => {
                assert_eq!(e, ConfigError::OverlapShardedUnsupported);
                assert!(e.to_string().contains("overlap pipeline"));
            }
            Err(other) => panic!("unexpected error: {other}"),
            Ok(_) => panic!("invalid config must be rejected"),
        }
    }

    #[test]
    fn validate_catches_degenerate_configs() {
        assert_eq!(PobpConfig::default().validate(), Ok(()));
        assert_eq!(
            PobpConfig { n_workers: 0, ..Default::default() }.validate(),
            Err(ConfigError::ZeroWorkers)
        );
        assert_eq!(
            PobpConfig { max_iters: 0, ..Default::default() }.validate(),
            Err(ConfigError::ZeroMaxIters)
        );
        assert_eq!(
            PobpConfig { nnz_budget: 0, ..Default::default() }.validate(),
            Err(ConfigError::ZeroNnzBudget)
        );
        let mut res = ResilienceConfig::in_dir("");
        assert_eq!(res.validate(), Err(ConfigError::CheckpointDirMissing));
        res.checkpoint_dir = "ckpts".into();
        res.keep_checkpoints = 0;
        assert_eq!(res.validate(), Err(ConfigError::ZeroKeepCheckpoints));
        res.keep_checkpoints = 1;
        res.straggler_timeout_factor = -1.0;
        assert!(matches!(
            res.validate(),
            Err(ConfigError::BadStragglerFactor(_))
        ));
        res.straggler_timeout_factor = 4.0;
        assert_eq!(res.validate(), Ok(()));
    }

    #[test]
    fn resilient_run_without_faults_matches_fit_and_writes_checkpoints() {
        // the deep kill/recover pins live in rust/tests/fault_equiv.rs;
        // this is the smoke-level contract: the resilient wrapper is a
        // bitwise no-op on a healthy run, and the checkpoint I/O lands
        // only in the ledger's side accumulators
        let dir = std::env::temp_dir()
            .join(format!("pobp-coord-res-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = PobpConfig {
            n_workers: 2,
            nnz_budget: 600,
            max_iters: 7,
            converge_thresh: 0.0,
            ..Default::default()
        };
        let oracle = fit(&c, &params, &cfg);
        let res = ResilienceConfig::in_dir(&dir);
        let r = fit_resilient(&c, &params, &cfg, &res, None).expect("resilient run");
        assert_eq!(r.model.phi_wk, oracle.model.phi_wk);
        assert_eq!(r.ledger.sync_count(), oracle.ledger.sync_count());
        assert!(r.ledger.checkpoint_count >= 1, "no checkpoint was written");
        assert_eq!(r.ledger.recovery_count, 0);
        assert_eq!(
            r.ledger.total_secs().to_bits(),
            oracle.ledger.total_secs().to_bits(),
            "checkpoint I/O must never leak into total_secs"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn overlap_mode_matches_serialized_and_hides_comm() {
        // The deep bitwise pins (all thread budgets, history residuals)
        // live in rust/tests/allreduce_equiv.rs; this is the smoke-level
        // contract: same model bits, same bytes, max(compute, comm)
        // accounting actually hides something.
        let c = tiny();
        let params = LdaParams::paper(8);
        let base = PobpConfig {
            n_workers: 3,
            nnz_budget: 900,
            max_iters: 12,
            ..Default::default()
        };
        let ser = fit(&c, &params, &PobpConfig { overlap: false, ..base.clone() });
        let ov = fit(&c, &params, &PobpConfig { overlap: true, ..base });
        assert_eq!(ov.model.phi_wk, ser.model.phi_wk);
        assert_eq!(ov.history.len(), ser.history.len());
        assert_eq!(ov.ledger.payload_bytes_total(), ser.ledger.payload_bytes_total());
        assert_eq!(ov.ledger.sync_count(), ser.ledger.sync_count());
        let l = &ov.ledger;
        assert!(l.overlap_saved_secs > 0.0, "pipeline hid no communication");
        assert!(l.total_secs() < l.compute_secs + l.comm_secs);
        assert!(l.total_secs() + 1e-12 >= l.compute_secs.max(l.comm_secs));
        assert_eq!(ser.ledger.overlap_saved_secs, 0.0);
    }
}
