//! The POBP coordinator — the paper's system contribution (Fig. 4).
//!
//! The leader streams mini-batches, shards each over N (simulated)
//! processors, and runs the bulk-synchronous loop:
//!
//! * **t = 1**: workers random-initialize messages, sweep everything, and
//!   the leader allreduces the *complete* Δφ̂ and residual matrices
//!   (Fig. 4 lines 3–10).
//! * **t ≥ 2**: the leader two-step-selects power words/topics from the
//!   synchronized residual matrix (§3.1), workers sweep only that subset,
//!   and only the `λ_W·W × λ_K·K` sub-matrices are allreduced
//!   (lines 12–28, Eqs. 6, 9, 15).
//! * The batch ends when the mean residual per token drops below the
//!   threshold (line 26) or `max_iters` is hit; the accumulated gradient
//!   joins the global φ̂ with the 1/(m−1)-style SGD semantics of Eq. 11.
//!
//! Special cases the paper calls out: N = 1 reduces to OBP; one mini-batch
//! (`nnz_budget = usize::MAX`) reduces to (parallel) batch BP; full
//! `PowerParams` disables selection entirely.
//!
//! # Overlap pipeline (`PobpConfig::overlap`)
//!
//! The serialized loop charges compute + comm per iteration (the BSP
//! semantics of Fig. 1). Overlap mode runs the same arithmetic through
//! the pipelined synchronization stack instead:
//!
//! * the allreduce is the slice-granular pipelined
//!   [`allreduce_step_overlap`]: per-owner-slice gather chunks, each
//!   owner folding its slice as soon as every worker has packed *that
//!   slice* (per-slice ready counters — no per-worker rounds);
//! * the next mini-batch's shard construction runs concurrently with the
//!   current batch's end-of-batch fold (both leader-side, disjoint
//!   state);
//! * the ledger charges `max(compute, comm)` per iteration
//!   ([`Ledger::record_overlapped_iter`], the YLDA parameter-server
//!   semantics of `engine::mpa`), keeping byte counts and per-segment
//!   reduce-scatter/allgather attribution exact. The end-of-batch fold's
//!   leader-side *work* stays serialized — the leader must finish
//!   folding before freeing the batch (Fig. 4 line 30) — but its
//!   simulated full-matrix *transfer* is deferred into the next batch's
//!   t = 1 window ([`Ledger::record_sync_deferred`]): that iteration
//!   charges `max(compute, comm + fold comm)`, with bytes and sync
//!   counts exact. The run's last fold has no following iteration and
//!   stays fully serialized.
//!
//! Numerical results are **bitwise identical** between the two modes at
//! any thread budget (`rust/tests/allreduce_equiv.rs` pins this): both
//! run the same per-element left folds and the same per-owner f64
//! totals sequence; only scheduling and time accounting differ.
//!
//! # Storage modes (`PobpConfig::storage`)
//!
//! The φ̂ accumulator and the per-batch working state come in two
//! layouts (Contract 5, docs/ARCHITECTURE.md):
//!
//! * [`PhiStorageMode::Replicated`] (default) — every processor holds
//!   the dense `W·K` replica; the bitwise oracle.
//! * [`PhiStorageMode::Sharded`] — each logical worker persistently
//!   stores only its row-aligned owner slice of φ̂ and r
//!   (O(W·K/N) per-worker model memory, the big-K mode). Sweeps read
//!   rows in place through [`PhiView::Slices`]; the allreduce folds
//!   into the stored slices; the ledger attributes the reduce-scatter
//!   and the next iteration's working-set allgather separately.
//!
//! Model, totals and residual history are **bitwise identical** across
//! the two modes at any thread budget (`rust/tests/shard_equiv.rs`).
//!
//! Simulation note (DESIGN.md §Substitutions): worker compute is measured
//! per shard; communication time comes from the byte-exact ledger +
//! network model. Numerical results are *identical* to a real N-process
//! deployment because the allreduce is a deterministic leader-side sum.

use std::sync::Mutex;

use crate::comm::allreduce::{
    allreduce_step, allreduce_step_overlap, allreduce_step_sharded, reduce_chunked,
    GlobalState, ReducePlan, ShardedState, SyncScratch,
};
use crate::comm::{Cluster, Ledger, NetModel};
use crate::corpus::{shard_ranges, Csr, MiniBatch, MiniBatchStream};
use crate::engine::bp::{PhiView, Selection, ShardBp};
use crate::engine::traits::{IterStat, LdaParams, Model, TrainResult};
use crate::sched::{select_power, select_power_sharded, PowerParams, PowerSet};
use crate::storage::{PhiShard, PhiStorageMode};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct PobpConfig {
    /// number of (simulated) processors N
    pub n_workers: usize,
    /// OS-thread cap for the simulation (0 = all cores)
    pub max_threads: usize,
    /// non-zero entries **per processor** per mini-batch (paper §4:
    /// "NNZ ≈ 45,000 in each mini-batch ... fit into 2 GB memory of each
    /// processor"): the global mini-batch holds `nnz_budget × N` entries,
    /// which is what makes PUBMED's M = 19 at N = 256.
    /// `usize::MAX` = single batch (batch BP mode)
    pub nnz_budget: usize,
    /// power word/topic ratios (λ_W, λ_K·K)
    pub power: PowerParams,
    /// max iterations per mini-batch T_m
    pub max_iters: usize,
    /// minimum iterations before the convergence check may fire. BP from
    /// random init has a residual *dip* before topic symmetry breaks (the
    /// messages barely move while φ̂ is still near-uniform), so line 26's
    /// threshold would otherwise fire spuriously at t = 2.
    pub min_iters: usize,
    /// convergence threshold on mean residual per token (line 26; 0.1)
    pub converge_thresh: f64,
    /// additional *relative* convergence condition: the residual must
    /// also fall below this fraction of the first iteration's residual.
    /// Under power selection the absolute threshold alone fires too
    /// early — the power-law concentration (§3.3) means the un-selected
    /// tail's stale residual is small even though those words have
    /// barely been updated.
    pub converge_rel: f64,
    pub net: NetModel,
    pub seed: u64,
    /// record a model snapshot every this many synchronizations
    /// (0 = never); used for perplexity-vs-time curves
    pub snapshot_every: usize,
    /// run the overlap pipeline: slice-granular gather/fold allreduce,
    /// next-batch shard construction overlapped with the fold, the
    /// fold's transfer deferred into the next batch's t = 1 window, and
    /// `max(compute, comm)` ledger accounting per iteration. Bitwise
    /// identical results to the serialized mode (see module doc);
    /// default `false` = the paper's serialized BSP accounting.
    pub overlap: bool,
    /// φ̂ storage layout: `Replicated` keeps the classic dense `W·K`
    /// replica on every processor (the bitwise oracle); `Sharded`
    /// stores only a row-aligned owner slice per logical worker —
    /// O(W·K/N) per-worker φ̂ memory with bitwise-identical results
    /// (Contract 5, `rust/tests/shard_equiv.rs`). Sharded mode does
    /// not support the overlap pipeline yet.
    pub storage: PhiStorageMode,
}

impl Default for PobpConfig {
    fn default() -> Self {
        PobpConfig {
            n_workers: 4,
            max_threads: 0,
            nnz_budget: 45_000,
            power: PowerParams::paper_default(),
            max_iters: 50,
            min_iters: 5,
            converge_thresh: 0.1,
            converge_rel: 0.01,
            net: NetModel::infiniband_20gbps(),
            seed: 42,
            snapshot_every: 0,
            overlap: false,
            storage: PhiStorageMode::Replicated,
        }
    }
}

impl PobpConfig {
    /// Single-processor online BP (the paper: "If N = 1, POBP reduces to
    /// the OBP algorithm").
    pub fn obp(seed: u64) -> PobpConfig {
        PobpConfig { n_workers: 1, power: PowerParams::full(), seed, ..Default::default() }
    }

    /// Single-processor batch BP ("If M = 1, POBP reduces to the parallel
    /// batch BP algorithm" — with N = 1 it is plain batch BP).
    pub fn batch_bp(seed: u64) -> PobpConfig {
        PobpConfig {
            n_workers: 1,
            nnz_budget: usize::MAX,
            power: PowerParams::full(),
            seed,
            ..Default::default()
        }
    }
}

/// Build one mini-batch's worker shards (Fig. 4 lines 3-5). The worker
/// RNG streams split off `rng` in worker order, once per batch — the
/// overlap pipeline calls this concurrently with the previous batch's
/// fold, and draws the splits at the same point of the stream either
/// way, so both modes see identical randomness.
fn build_shards(
    mb: &MiniBatch,
    k: usize,
    n_workers: usize,
    rng: &mut Rng,
) -> Vec<Mutex<ShardBp>> {
    let ranges = shard_ranges(mb.data.docs(), n_workers);
    let mut worker_rngs: Vec<Rng> =
        (0..n_workers).map(|n| rng.split(n as u64)).collect();
    ranges
        .iter()
        .zip(worker_rngs.iter_mut())
        .map(|(rg, wrng)| {
            Mutex::new(ShardBp::init(mb.data.slice_docs(rg.start, rg.end), k, wrng))
        })
        .collect()
}

/// Trains LDA with POBP over `corpus` and returns the learned model plus
/// the full cost decomposition. Dispatches on [`PobpConfig::storage`];
/// both modes produce bitwise-identical models, totals and residual
/// histories (Contract 5).
pub fn fit(corpus: &Csr, params: &LdaParams, cfg: &PobpConfig) -> TrainResult {
    match cfg.storage {
        PhiStorageMode::Replicated => fit_replicated(corpus, params, cfg),
        PhiStorageMode::Sharded => fit_sharded(corpus, params, cfg),
    }
}

/// [`fit`] in replicated storage mode: the dense `W·K` φ̂ replica, the
/// paper's layout and the bitwise oracle for the sharded mode.
fn fit_replicated(corpus: &Csr, params: &LdaParams, cfg: &PobpConfig) -> TrainResult {
    let mut wall = Stopwatch::new();
    let (w, k) = (corpus.w, params.k);
    let cluster = Cluster::new(cfg.n_workers, cfg.max_threads);
    let mut ledger = Ledger::new(cfg.net);
    let mut history = Vec::new();
    let mut snapshots: Vec<(f64, Model)> = Vec::new();
    let mut rng = Rng::new(cfg.seed);

    // Global accumulated sufficient statistics φ̂ (Eq. 11's phi^{m}).
    let mut phi_acc = vec![0f32; w * k];
    // Snapshot cadence counts *iteration* syncs only: the end-of-batch
    // fold also bumps `ledger.sync_count()`, which would skip/shift
    // snapshots whose multiple lands on a fold.
    let mut iter_syncs = 0usize;
    // Reusable synchronization buffers (gather exports, owner-slot
    // permutation, totals deltas) and the plan-index buffer — held for
    // the whole run so the O(pairs) gather/reduction storage never
    // reallocates across syncs (small per-dispatch task vectors remain).
    let mut scratch = SyncScratch::default();
    let mut flat_buf: Vec<u32> = Vec::new();

    let global_budget = cfg.nnz_budget.saturating_mul(cfg.n_workers);
    let mut stream = MiniBatchStream::new(corpus, global_budget);
    let mut pending = stream.next();
    // Shards of the upcoming batch, possibly prebuilt by the overlap
    // pipeline during the previous batch's fold.
    let mut prebuilt: Option<Vec<Mutex<ShardBp>>> = None;
    while let Some(mb) = pending.take() {
        let tokens = mb.data.tokens().max(1.0);

        let shards: Vec<Mutex<ShardBp>> = match prebuilt.take() {
            Some(s) => s,
            None => build_shards(&mb, k, cfg.n_workers, &mut rng),
        };

        // Working global state for this batch: φ̂ = phi_acc + Σ_n Δφ̂_n,
        // plus the synchronized residual matrix — totals f64-backed
        // against incremental drift (comm::allreduce::GlobalState).
        let mut state = GlobalState::new(&phi_acc, k);
        let mut selection = Selection::full(w);
        // None = full sync; the full schedule stays implicit — there is
        // deliberately no way to materialize an all-pairs PowerSet
        // (O(W·K) heap at PUBMED scale).
        let mut power: Option<PowerSet> = None;
        let mut prev_resid = f64::INFINITY;
        let mut first_resid = f64::INFINITY;
        let mut iters_run = 0;

        for t in 1..=cfg.max_iters {
            iters_run = t;
            // --- doc-parallel sweep (lines 6-8 / 15-20): each worker
            //     fans its shard's fixed NNZ-derived doc blocks over its
            //     share of the OS-thread pool, so an N = 1 (OBP) run
            //     saturates the whole machine instead of one core.
            //     Residual clearing is folded into the sweep's merge. ---
            let budget = cluster.doc_threads_per_worker();
            let phi_ref: &[f32] = &state.phi_eff;
            let tot_ref: &[f32] = state.phi_tot();
            let sel_ref = &selection;
            let (reports, _wall) = cluster.run(|n| {
                let mut shard = shards[n].lock().unwrap();
                shard.sweep_parallel(
                    &cluster, budget, phi_ref, tot_ref, sel_ref, params, true,
                )
            });
            // per-worker compute from the per-block timings: the worker's
            // own critical path on its thread budget, robust to the pool
            // contention the raw closure wall clock would over-count when
            // logical workers are multiplexed over fewer cores
            let secs: Vec<f64> = reports
                .iter()
                .map(|(_, timing)| timing.critical_path_secs(budget))
                .collect();

            // --- synchronize Δφ̂ and r on the scheduled pairs (lines
            //     9-10 / 23-24, Eqs. 9 & 15): owner-sliced
            //     reduce-scatter, one call for both the full and the
            //     power schedule; overlap mode runs the double-buffered
            //     pipelined variant (bitwise-identical results) ---
            let plan = match &power {
                None => ReducePlan::Dense { len: w * k },
                Some(ps) => {
                    ps.flat_indices_into(k, &mut flat_buf);
                    ReducePlan::Subset { indices: &flat_buf }
                }
            };
            let pairs = if cfg.overlap {
                allreduce_step_overlap(
                    &cluster, &plan, &phi_acc, &shards, &mut state, &mut scratch,
                )
            } else {
                allreduce_step(&cluster, &plan, &phi_acc, &shards, &mut state, &mut scratch)
            };
            // two f32 matrices (φ̂ and r) restricted to the selection
            let payload = 2 * 4 * pairs;
            if cfg.overlap {
                // pipelined iteration: comm hides behind compute
                ledger.record_overlapped_iter(mb.index, t, payload, cfg.n_workers, &secs);
            } else {
                ledger.record_compute(&secs);
                ledger.record_sync(mb.index, t, payload, cfg.n_workers);
            }

            iter_syncs += 1;
            let resid_per_token = state.r_total() / tokens;
            if cfg.snapshot_every > 0 && iter_syncs % cfg.snapshot_every == 0 {
                snapshots.push((
                    ledger.total_secs(),
                    Model { k, w, phi_wk: state.phi_eff.clone() },
                ));
            }
            history.push(IterStat {
                batch: mb.index,
                iter: t,
                residual_per_token: resid_per_token,
                synced_pairs: pairs,
                sim_elapsed: ledger.total_secs(),
                wall_elapsed: wall.total_secs(),
            });

            // --- convergence check (line 26) ---
            // Fire only on the decaying side of the residual curve: BP
            // from random init dips before topic symmetry breaks, then
            // humps; a plain threshold would stop inside the dip.
            if t == 1 {
                first_resid = resid_per_token.max(1e-12);
            }
            if t >= cfg.min_iters
                && resid_per_token <= cfg.converge_thresh
                && resid_per_token <= cfg.converge_rel * first_resid
                && resid_per_token <= prev_resid
            {
                break;
            }
            prev_resid = resid_per_token;

            // --- dynamic power selection for the next iteration
            //     (lines 12-13 / 27-28) ---
            if cfg.power.lambda_w < 1.0 || cfg.power.lambda_k_times_k < k {
                let ps = select_power(&state.r_global, w, k, &cfg.power);
                selection = Selection::from_power(&ps, w);
                power = Some(ps);
            }
        }

        // --- fold the batch gradient into the global model (Eq. 11) ---
        // phi_eff already equals phi_acc + Σ_n Δφ̂_n on every pair that was
        // last synchronized; un-synced pairs differ only by worker-local
        // updates not yet communicated, so the fold ships one final full
        // φ̂ matrix (the paper frees the batch keeping the global matrix,
        // line 30) — and charges it: one sync per batch on top of the
        // per-iteration ones, so sync_count = Σ_batches (iters + 1). In
        // overlap mode the fold's *transfer* is deferred into the next
        // batch's t = 1 window (`record_sync_deferred`: bytes and count
        // exact now, comm hidden behind the next sweep's max(compute,
        // comm)); the leader-side folding work itself stays serialized.
        // Overlap mode also builds the *next* batch's shards concurrently
        // with the fold — both leader-side, disjoint state, and the RNG
        // splits happen at the same stream position either way.
        let next_mb = stream.next();
        {
            let guards: Vec<_> = shards.iter().map(|s| s.lock().unwrap()).collect();
            let dphi_parts: Vec<&[f32]> =
                guards.iter().map(|g| g.dphi.as_slice()).collect();
            if cfg.overlap {
                let rng_ref = &mut rng;
                prebuilt = std::thread::scope(|scope| {
                    let prefetch = next_mb.as_ref().map(|nmb| {
                        scope.spawn(move || build_shards(nmb, k, cfg.n_workers, rng_ref))
                    });
                    reduce_chunked(&cluster, Some(&phi_acc), &dphi_parts, &mut state.phi_eff);
                    prefetch.map(|h| h.join().expect("shard prefetch thread"))
                });
            } else {
                reduce_chunked(&cluster, Some(&phi_acc), &dphi_parts, &mut state.phi_eff);
                prebuilt =
                    next_mb.as_ref().map(|nmb| build_shards(nmb, k, cfg.n_workers, &mut rng));
            }
            drop(guards);
            phi_acc.copy_from_slice(&state.phi_eff);
            if cfg.overlap {
                ledger.record_sync_deferred(mb.index, iters_run + 1, 4 * w * k, cfg.n_workers);
            } else {
                ledger.record_sync(mb.index, iters_run + 1, 4 * w * k, cfg.n_workers);
            }
        }
        pending = next_mb;
        let _ = wall.lap_secs();
    }

    TrainResult {
        model: Model { k, w, phi_wk: phi_acc },
        history,
        ledger,
        wall_secs: wall.total_secs(),
        snapshots,
    }
}

/// [`fit`] in **sharded** storage mode: each logical worker persistently
/// holds only its row-aligned owner slice of φ̂ and the synchronized
/// residual matrix ([`PhiShard::Sharded`] / [`ShardedState`]) — per-worker
/// model memory O(W·K/N) — while every number (model, totals, residual
/// history) stays bitwise equal to [`fit_replicated`]. The differences
/// are pure reorderings of identical arithmetic:
///
/// * sweeps read φ̂ rows in place through [`PhiView::Slices`] — the same
///   bits [`fit_replicated`]'s dense rows hand the kernels;
/// * the allreduce folds into the stored slices
///   ([`allreduce_step_sharded`]), per-element left folds and per-owner
///   f64 totals in the replicated op order;
/// * power selection reads the sharded residual slices
///   ([`select_power_sharded`], bitwise-equal schedule);
/// * the ledger charges the reduce-scatter and the allgather halves
///   separately ([`Ledger::record_sync_split`]): the reduce ships the
///   synchronized pairs, the gather ships the **next** iteration's φ̂
///   working set (the full matrix before a dense sweep, the selected
///   rows before a power sweep, nothing when the batch stops here).
///
/// The overlap pipeline is not wired through sharded storage yet;
/// `cfg.overlap` is rejected.
fn fit_sharded(corpus: &Csr, params: &LdaParams, cfg: &PobpConfig) -> TrainResult {
    assert!(!cfg.overlap, "sharded storage does not support the overlap pipeline yet");
    let mut wall = Stopwatch::new();
    let (w, k) = (corpus.w, params.k);
    let cluster = Cluster::new(cfg.n_workers, cfg.max_threads);
    let mut ledger = Ledger::new(cfg.net);
    let mut history = Vec::new();
    let mut snapshots: Vec<(f64, Model)> = Vec::new();
    let mut rng = Rng::new(cfg.seed);

    // Global accumulated φ̂ (Eq. 11's phi^{m}), stored as row-aligned
    // owner slices — no worker ever holds the dense matrix.
    let mut phi_acc = PhiShard::sharded(w, k, cfg.n_workers);
    let os = phi_acc.owner_slices();
    let rows_per = phi_acc.rows_per();
    // iteration-sync counter for the snapshot cadence (see
    // fit_replicated: the end-of-batch fold must not shift snapshots)
    let mut iter_syncs = 0usize;
    let mut scratch = SyncScratch::default();
    let mut flat_buf: Vec<u32> = Vec::new();

    let global_budget = cfg.nnz_budget.saturating_mul(cfg.n_workers);
    let mut stream = MiniBatchStream::new(corpus, global_budget);
    let mut pending = stream.next();
    while let Some(mb) = pending.take() {
        let tokens = mb.data.tokens().max(1.0);
        // worker RNG splits drawn at the same stream position as the
        // replicated path (once per batch, batch order), so both modes
        // see identical shard initialization
        let shards: Vec<Mutex<ShardBp>> = build_shards(&mb, k, cfg.n_workers, &mut rng);

        // Per-batch working state: φ̂_eff and r as per-owner stored
        // slices, f64-backed totals (comm::allreduce::ShardedState).
        let mut state = ShardedState::new(phi_acc.parts(), k, os);
        let mut selection = Selection::full(w);
        let mut power: Option<PowerSet> = None;
        let mut prev_resid = f64::INFINITY;
        let mut first_resid = f64::INFINITY;
        let mut iters_run = 0;

        for t in 1..=cfg.max_iters {
            iters_run = t;
            // --- doc-parallel sweep, φ̂ rows read in place from the
            //     owner slices (no gather materialization leader-side;
            //     the simulated transfer is charged below) ---
            let budget = cluster.doc_threads_per_worker();
            let (reports, _wall) = {
                let phi_parts = state.phi_parts();
                let view = PhiView::Slices { parts: &phi_parts, rows_per };
                let tot_ref: &[f32] = state.phi_tot();
                let sel_ref = &selection;
                cluster.run(|n| {
                    let mut shard = shards[n].lock().unwrap();
                    shard.sweep_parallel_view(
                        &cluster, budget, view, tot_ref, sel_ref, params, true,
                    )
                })
            };
            let secs: Vec<f64> = reports
                .iter()
                .map(|(_, timing)| timing.critical_path_secs(budget))
                .collect();

            // --- owner-sliced reduce-scatter into the stored slices ---
            let plan = match &power {
                None => ReducePlan::Dense { len: w * k },
                Some(ps) => {
                    ps.flat_indices_into(k, &mut flat_buf);
                    ReducePlan::Subset { indices: &flat_buf }
                }
            };
            let pairs = allreduce_step_sharded(
                &cluster, &plan, phi_acc.parts(), &shards, &mut state, &mut scratch,
            );

            // --- convergence decision first (line 26), so the ledger's
            //     allgather half can charge exactly the next sweep's
            //     working set — nothing when the batch stops here ---
            let resid_per_token = state.r_total() / tokens;
            if t == 1 {
                first_resid = resid_per_token.max(1e-12);
            }
            let converged = t >= cfg.min_iters
                && resid_per_token <= cfg.converge_thresh
                && resid_per_token <= cfg.converge_rel * first_resid
                && resid_per_token <= prev_resid;
            let stopping = converged || t == cfg.max_iters;

            // --- dynamic power selection for the next iteration, from
            //     the sharded residual slices (bitwise-equal schedule) ---
            let next: Option<PowerSet> = if !stopping
                && (cfg.power.lambda_w < 1.0 || cfg.power.lambda_k_times_k < k)
            {
                Some(select_power_sharded(&state.r_parts(), rows_per, w, k, &cfg.power))
            } else {
                None
            };

            // reduce half: the synchronized Δφ̂ + r pairs; gather half:
            // the φ̂ working set the next sweep reads (full matrix when
            // the next sweep is dense)
            let reduce_bytes = 2 * 4 * pairs;
            let gather_bytes = if stopping {
                0
            } else {
                4 * next.as_ref().map_or(w * k, |ps| ps.pairs())
            };
            ledger.record_compute(&secs);
            ledger.record_sync_split(mb.index, t, reduce_bytes, gather_bytes, cfg.n_workers);

            iter_syncs += 1;
            if cfg.snapshot_every > 0 && iter_syncs % cfg.snapshot_every == 0 {
                snapshots.push((
                    ledger.total_secs(),
                    Model { k, w, phi_wk: state.render_dense() },
                ));
            }
            history.push(IterStat {
                batch: mb.index,
                iter: t,
                residual_per_token: resid_per_token,
                synced_pairs: pairs,
                sim_elapsed: ledger.total_secs(),
                wall_elapsed: wall.total_secs(),
            });

            if converged {
                break;
            }
            prev_resid = resid_per_token;
            if let Some(ps) = next {
                selection = Selection::from_power(&ps, w);
                power = Some(ps);
            }
        }

        // --- fold the batch gradient into the sharded accumulator
        //     (Eq. 11): each owner folds every worker's Δφ̂ over its own
        //     slice — reduce_chunked's per-element left fold, fused with
        //     the copy-back. The simulated transfer is the replicated
        //     fold's: one full φ̂ matrix reduced and re-gathered
        //     (identical payload and wire bytes to `record_sync`). ---
        let next_mb = stream.next();
        {
            let guards: Vec<_> = shards.iter().map(|s| s.lock().unwrap()).collect();
            let dphi_parts: Vec<&[f32]> =
                guards.iter().map(|g| g.dphi.as_slice()).collect();
            state.fold_batch(&cluster, phi_acc.parts_mut(), &dphi_parts);
            drop(guards);
            ledger.record_sync_split(
                mb.index,
                iters_run + 1,
                4 * w * k,
                4 * w * k,
                cfg.n_workers,
            );
        }
        pending = next_mb;
        let _ = wall.lap_secs();
    }

    TrainResult {
        model: Model { k, w, phi_wk: phi_acc.to_dense() },
        history,
        ledger,
        wall_secs: wall.total_secs(),
        snapshots,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::{generate, SynthSpec};

    fn tiny() -> Csr {
        generate(&SynthSpec::tiny(17)).corpus
    }

    #[test]
    fn model_mass_equals_corpus_tokens() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = PobpConfig {
            n_workers: 3,
            nnz_budget: 800,
            max_iters: 12,
            ..Default::default()
        };
        let r = fit(&c, &params, &cfg);
        assert!(
            (r.model.mass() - c.tokens()).abs() < c.tokens() * 1e-3,
            "mass {} vs tokens {}",
            r.model.mass(),
            c.tokens()
        );
    }

    #[test]
    fn residual_converges_within_batches() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let cfg = PobpConfig { n_workers: 2, nnz_budget: 1500, max_iters: 60, ..Default::default() };
        let r = fit(&c, &params, &cfg);
        // the last iteration of each batch must be at/near the threshold
        let mut per_batch_last: std::collections::BTreeMap<usize, f64> =
            Default::default();
        for st in &r.history {
            per_batch_last.insert(st.batch, st.residual_per_token);
        }
        for (b, resid) in per_batch_last {
            assert!(resid <= 0.25, "batch {b} ended at residual {resid}");
        }
    }

    #[test]
    fn n_workers_does_not_change_result_much() {
        // The allreduce is a deterministic sum; with the same seed the
        // worker split changes init RNG streams, so results are not
        // bitwise equal — but model quality must match closely.
        let c = tiny();
        let params = LdaParams::paper(8);
        let base = PobpConfig { nnz_budget: usize::MAX, max_iters: 30, ..Default::default() };
        let r1 = fit(&c, &params, &PobpConfig { n_workers: 1, ..base.clone() });
        let r4 = fit(&c, &params, &PobpConfig { n_workers: 4, ..base });
        let m1 = r1.model.mass();
        let m4 = r4.model.mass();
        assert!((m1 - m4).abs() < m1 * 1e-3);
        let p1 = crate::eval::perplexity::heldin_perplexity(&r1.model, &c, &params);
        let p4 = crate::eval::perplexity::heldin_perplexity(&r4.model, &c, &params);
        assert!(
            (p1.ln() - p4.ln()).abs() < 0.12,
            "perplexities diverge: {p1} vs {p4}"
        );
    }

    #[test]
    fn power_selection_reduces_payload() {
        let c = tiny();
        let params = LdaParams::paper(8);
        // converge_thresh 0 pins both runs to exactly max_iters syncs so
        // the payload comparison is like-for-like
        let full = fit(&c, &params, &PobpConfig {
            n_workers: 2,
            power: PowerParams::full(),
            max_iters: 15,
            converge_thresh: 0.0,
            ..Default::default()
        });
        let powered = fit(&c, &params, &PobpConfig {
            n_workers: 2,
            power: PowerParams { lambda_w: 0.1, lambda_k_times_k: 4 },
            max_iters: 15,
            converge_thresh: 0.0,
            ..Default::default()
        });
        assert!(
            powered.ledger.payload_bytes_total()
                < full.ledger.payload_bytes_total() / 2,
            "power sync not smaller: {} vs {}",
            powered.ledger.payload_bytes_total(),
            full.ledger.payload_bytes_total()
        );
    }

    #[test]
    fn ledger_charges_final_fold_sync() {
        // converge_thresh 0 pins every batch to exactly max_iters
        // iteration syncs; the end-of-batch fold must add one more.
        let c = tiny();
        let params = LdaParams::paper(8);
        let max_iters = 7;
        let cfg = PobpConfig {
            n_workers: 2,
            nnz_budget: 600,
            max_iters,
            converge_thresh: 0.0,
            ..Default::default()
        };
        let r = fit(&c, &params, &cfg);
        let batches = r.history.iter().map(|s| s.batch).max().unwrap() + 1;
        assert!(batches >= 2, "want a multi-batch run, got {batches}");
        assert_eq!(
            r.ledger.sync_count(),
            batches * (max_iters + 1),
            "every batch must charge its iterations plus one final fold"
        );
        // the fold ships one full W×K φ̂ matrix, recorded past the last
        // iteration index
        let folds = r
            .ledger
            .events
            .iter()
            .filter(|e| e.iter == max_iters + 1)
            .collect::<Vec<_>>();
        assert_eq!(folds.len(), batches);
        for e in &folds {
            assert_eq!(e.payload_bytes, 4 * c.w * 8);
        }
    }

    #[test]
    fn single_worker_obp_mode_runs() {
        let c = tiny();
        let params = LdaParams::paper(8);
        let r = fit(&c, &params, &PobpConfig { nnz_budget: 700, ..PobpConfig::obp(5) });
        assert!(r.ledger.comm_secs == 0.0, "N=1 must not pay comm time");
        assert!(r.model.mass() > 0.0);
    }

    #[test]
    fn sharded_storage_matches_replicated_oracle() {
        // The deep bitwise pins (thread budgets 1/2/8, full + power
        // configs) live in rust/tests/shard_equiv.rs; this is the
        // smoke-level contract: same model bits, same residual
        // trajectory, same pair/byte accounting, smaller resident φ̂.
        let c = tiny();
        let params = LdaParams::paper(8);
        let base = PobpConfig {
            n_workers: 3,
            nnz_budget: 900,
            max_iters: 12,
            ..Default::default()
        };
        let rep = fit(&c, &params, &base);
        let sh = fit(
            &c,
            &params,
            &PobpConfig { storage: PhiStorageMode::Sharded, ..base },
        );
        assert_eq!(sh.model.phi_wk, rep.model.phi_wk);
        assert_eq!(sh.history.len(), rep.history.len());
        for (a, b) in sh.history.iter().zip(&rep.history) {
            assert_eq!(
                a.residual_per_token.to_bits(),
                b.residual_per_token.to_bits()
            );
            assert_eq!(a.synced_pairs, b.synced_pairs);
        }
        assert_eq!(sh.ledger.sync_count(), rep.ledger.sync_count());
        assert_eq!(
            sh.ledger.payload_bytes_total(),
            rep.ledger.payload_bytes_total()
        );
    }

    #[test]
    #[should_panic(expected = "overlap pipeline")]
    fn sharded_storage_rejects_overlap() {
        let c = tiny();
        let params = LdaParams::paper(8);
        fit(&c, &params, &PobpConfig {
            storage: PhiStorageMode::Sharded,
            overlap: true,
            ..Default::default()
        });
    }

    #[test]
    fn overlap_mode_matches_serialized_and_hides_comm() {
        // The deep bitwise pins (all thread budgets, history residuals)
        // live in rust/tests/allreduce_equiv.rs; this is the smoke-level
        // contract: same model bits, same bytes, max(compute, comm)
        // accounting actually hides something.
        let c = tiny();
        let params = LdaParams::paper(8);
        let base = PobpConfig {
            n_workers: 3,
            nnz_budget: 900,
            max_iters: 12,
            ..Default::default()
        };
        let ser = fit(&c, &params, &PobpConfig { overlap: false, ..base.clone() });
        let ov = fit(&c, &params, &PobpConfig { overlap: true, ..base });
        assert_eq!(ov.model.phi_wk, ser.model.phi_wk);
        assert_eq!(ov.history.len(), ser.history.len());
        assert_eq!(ov.ledger.payload_bytes_total(), ser.ledger.payload_bytes_total());
        assert_eq!(ov.ledger.sync_count(), ser.ledger.sync_count());
        let l = &ov.ledger;
        assert!(l.overlap_saved_secs > 0.0, "pipeline hid no communication");
        assert!(l.total_secs() < l.compute_secs + l.comm_secs);
        assert!(l.total_secs() + 1e-12 >= l.compute_secs.max(l.comm_secs));
        assert_eq!(ser.ledger.overlap_saved_secs, 0.0);
    }
}
