//! The distributed coordinator: [`fit`](super::fit)'s two training
//! loops driven through a [`Transport`] instead of direct calls into
//! leader-owned [`ShardBp`](crate::engine::bp::ShardBp)s (Contract 8,
//! docs/ARCHITECTURE.md).
//!
//! The remote worker contributes to training through exactly three
//! channels, and each one crosses the wire as a typed frame:
//!
//! * **shard construction** — the Batch frame carries a `POBPCKP1`
//!   checkpoint (the worker's RNG split drawn from the leader's stream
//!   at the same position `build_shards` draws it) plus the worker's
//!   re-based CSR doc slice and the LDA priors; the worker rebuilds the
//!   same `ShardBp::init` the in-process loops build. Because the frame
//!   is a full state transfer, worker (re)join after a crash is the
//!   same message as a normal batch start.
//! * **sweeps** — the Sweep frame publishes φ̂_eff, the topic totals and
//!   the power schedule; the Gather reply returns the plan-order
//!   (Δφ̂, r) export. Sweeps are bitwise budget-independent (Contract 1)
//!   and dense-vs-sliced-view independent (Contract 5), so a remote
//!   worker sweeping a dense render of sharded φ̂ produces the bits the
//!   in-process `PhiView::Slices` sweep produces.
//! * **the end-of-batch fold** — the FoldPart reply ships the dense
//!   Δφ̂ accumulated over the batch (Eq. 11's per-worker term).
//!
//! Leader-side, each reply lands in a [`PartSource`] — a dense mirror of
//! the worker's (Δφ̂, r) — and the **unchanged** `allreduce_step` /
//! `allreduce_step_sharded` run on top of those mirrors: the same
//! per-element left folds in the same owner order, hence bitwise
//! equality with [`fit`](super::fit) (`rust/tests/dist_equiv.rs` pins
//! it across worker counts, storage modes, thread budgets, and real
//! TCP worker processes).
//!
//! Time accounting: the modeled α–β charges are recorded exactly as
//! in-process ([`Ledger::record_sync`] / `record_sync_split`), and the
//! *measured* wire seconds of every exchange land next to them through
//! [`Ledger::record_measured`] — the publish pass is the (all)gather
//! leg, the collect pass minus the slowest worker's sweep is the
//! reduce leg. Measured seconds never enter `total_secs()`; they exist
//! to calibrate the model ([`NetModel::calibration_error_secs`]).
//!
//! [`Ledger::record_sync`]: crate::comm::Ledger::record_sync
//! [`Ledger::record_measured`]: crate::comm::Ledger::record_measured
//! [`NetModel::calibration_error_secs`]: crate::comm::NetModel::calibration_error_secs

use std::sync::Mutex;

use crate::comm::allreduce::{
    allreduce_step, allreduce_step_injected, allreduce_step_sharded,
    allreduce_step_sharded_injected, reduce_chunked, GlobalState, ReducePlan,
    ShardedState, SyncScratch,
};
use crate::comm::transport::{
    batch_payload, sweep_payload, PartSource, SweepExchange, Transport, TransportError,
};
use crate::comm::{Cluster, Ledger};
use crate::corpus::{shard_ranges, Csr, MiniBatch, MiniBatchStream};
use crate::engine::traits::{IterStat, LdaParams, Model, TrainResult};
use crate::fault::{FaultPlan, SyncPhase};
use crate::sched::{select_power, select_power_sharded, PowerSet};
use crate::storage::{Checkpoint, CkptExpect, PhiShard, PhiStorageMode};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use super::{
    check_resume, write_checkpoint, ConfigError, PobpConfig, ResilienceConfig,
    RunCtx, TrainError,
};

fn transport_err(e: TransportError) -> TrainError {
    TrainError::Transport(e.to_string())
}

/// Front-door checks shared by [`fit_dist`] and [`fit_dist_resilient`]:
/// the usual config validation, the unsupported overlap pipeline, and
/// the transport actually holding `n_workers` workers.
fn validate_dist(cfg: &PobpConfig, transport: &dyn Transport) -> Result<(), TrainError> {
    cfg.validate()?;
    if cfg.overlap {
        return Err(ConfigError::OverlapDistUnsupported.into());
    }
    if transport.n_workers() != cfg.n_workers {
        return Err(TrainError::Transport(format!(
            "transport holds {} workers, config wants n_workers = {}",
            transport.n_workers(),
            cfg.n_workers
        )));
    }
    Ok(())
}

/// Build one mini-batch's Batch frames, slot order — the distributed
/// twin of [`build_shards`](super::build_shards): the same
/// `shard_ranges` partition and the same `rng.split(n)` draws at the
/// same stream position, so the worker's `Rng::from_state` rebuild is
/// the RNG `build_shards` hands `ShardBp::init`. The embedded
/// checkpoint's φ̂ is a zeroed placeholder (the decoder demands the
/// W·K shape; workers never read it — φ̂ arrives with every Sweep).
fn batch_payloads(
    mb: &MiniBatch,
    w: usize,
    k: usize,
    params: &LdaParams,
    cfg: &PobpConfig,
    rng: &mut Rng,
) -> Vec<Vec<u8>> {
    let ranges = shard_ranges(mb.data.docs(), cfg.n_workers);
    ranges
        .iter()
        .enumerate()
        .map(|(n, rg)| {
            let wrng = rng.split(n as u64);
            let slice = mb.data.slice_docs(rg.start, rg.end);
            let ck = Checkpoint {
                w,
                k,
                n_workers: cfg.n_workers,
                seed: cfg.seed,
                next_batch: mb.index,
                next_doc: mb.doc_range.start,
                iter_syncs: 0,
                rng_state: wrng.state(),
                phi: PhiShard::Replicated(vec![0.0; w * k]),
                ledger: Ledger::new(cfg.net),
                history: Vec::new(),
                snapshots: Vec::new(),
            };
            batch_payload(&ck, &slice, params)
        })
        .collect()
}

/// Protocol sanity on a sweep round-trip: one reply per worker, every
/// reply echoing the published iteration.
fn check_replies(ex: &SweepExchange, t: usize, n: usize) -> Result<(), TrainError> {
    if ex.replies.len() != n {
        return Err(TrainError::Transport(format!(
            "{} gather replies for {n} workers",
            ex.replies.len()
        )));
    }
    for (slot, r) in ex.replies.iter().enumerate() {
        if r.iter != t {
            return Err(TrainError::Transport(format!(
                "worker {slot} answered iteration {} during iteration {t}",
                r.iter
            )));
        }
    }
    Ok(())
}

/// Shape-check the end-of-batch fold parts (dense `W·K` each, slot
/// order) before they touch the accumulator.
fn check_fold_parts(parts: &[Vec<f32>], n: usize, len: usize) -> Result<(), TrainError> {
    if parts.len() != n {
        return Err(TrainError::Transport(format!(
            "{} fold parts for {n} workers",
            parts.len()
        )));
    }
    for (slot, p) in parts.iter().enumerate() {
        if p.len() != len {
            return Err(TrainError::Transport(format!(
                "fold part {slot} carries {} elements, want W·K = {len}",
                p.len()
            )));
        }
    }
    Ok(())
}

/// [`fit_checked`](super::fit_checked) through a [`Transport`]:
/// the same training program, with sweeps and gathers crossing the
/// transport as wire frames. Bitwise-equal to the in-process fit in
/// both storage modes (Contract 8, `rust/tests/dist_equiv.rs`).
///
/// The caller owns the transport's lifecycle — workers stay connected
/// after the run so several fits can share one cluster; call
/// [`Transport::shutdown`] when done.
pub fn fit_dist(
    corpus: &Csr,
    params: &LdaParams,
    cfg: &PobpConfig,
    transport: &mut dyn Transport,
) -> Result<TrainResult, TrainError> {
    validate_dist(cfg, transport)?;
    match cfg.storage {
        PhiStorageMode::Replicated => {
            dist_replicated(corpus, params, cfg, RunCtx::bare(), transport)
        }
        PhiStorageMode::Sharded => {
            dist_sharded(corpus, params, cfg, RunCtx::bare(), transport)
        }
    }
}

/// [`fit_resilient`](super::fit_resilient) through a [`Transport`]
/// (Contracts 6 + 8): same checkpoint cadence and retry loop, except
/// that a planned kill now SIGKILLs the real worker process
/// ([`Transport::kill_worker`]) and each retry re-establishes the whole
/// cluster ([`Transport::reset`]) before resuming from the newest good
/// checkpoint. The recovered result is bitwise identical to an
/// uninterrupted run (`rust/tests/dist_equiv.rs`).
pub fn fit_dist_resilient(
    corpus: &Csr,
    params: &LdaParams,
    cfg: &PobpConfig,
    res: &ResilienceConfig,
    faults: Option<&FaultPlan>,
    transport: &mut dyn Transport,
) -> Result<TrainResult, TrainError> {
    validate_dist(cfg, transport)?;
    res.validate()?;
    let expect = CkptExpect {
        w: corpus.w,
        k: params.k,
        n_workers: cfg.n_workers,
        seed: cfg.seed,
        mode: cfg.storage,
    };
    let mut allow_resume = res.resume;
    let mut last_death: Option<f64> = None;
    let mut retries = 0usize;
    let mut need_reset = false;
    loop {
        if need_reset {
            // a kill left a worker dead (and, on TCP, a real corpse):
            // tear the cluster down and respawn/reaccept everyone —
            // the next attempt's Batch frames re-ship all worker state
            transport.reset().map_err(transport_err)?;
            need_reset = false;
        }
        let resume = if allow_resume {
            Checkpoint::load_latest_good(&res.checkpoint_dir, Some(&expect))
                .map(|(ck, _)| ck)
        } else {
            None
        };
        let resumed_secs = resume.as_ref().map_or(0.0, |ck| ck.ledger.total_secs());
        let replay_secs = last_death.map_or(0.0, |d| (d - resumed_secs).max(0.0));
        let ctx = RunCtx { res: Some(res), faults, resume, replay_secs };
        let attempt = match cfg.storage {
            PhiStorageMode::Replicated => {
                dist_replicated(corpus, params, cfg, ctx, transport)
            }
            PhiStorageMode::Sharded => dist_sharded(corpus, params, cfg, ctx, transport),
        };
        match attempt {
            Err(TrainError::Killed { fault, sim_secs_at_death }) => {
                retries += 1;
                if retries > res.max_retries {
                    return Err(TrainError::RetriesExhausted {
                        fault,
                        retries: res.max_retries,
                    });
                }
                last_death = Some(sim_secs_at_death);
                allow_resume = true;
                need_reset = true;
            }
            other => return other,
        }
    }
}

/// [`fit_replicated`](super::fit) over a transport. The loop body
/// mirrors the in-process one statement for statement; the differences
/// are exactly the three wire exchanges and the [`PartSource`] mirrors
/// the allreduce reads instead of leader-owned shards.
fn dist_replicated(
    corpus: &Csr,
    params: &LdaParams,
    cfg: &PobpConfig,
    ctx: RunCtx<'_>,
    transport: &mut dyn Transport,
) -> Result<TrainResult, TrainError> {
    let RunCtx { res, faults, resume, replay_secs } = ctx;
    let mut wall = Stopwatch::new();
    let (w, k) = (corpus.w, params.k);
    let cluster = Cluster::new(cfg.n_workers, cfg.max_threads).with_pinning(cfg.pin_cores);
    let mut ledger = Ledger::new(cfg.net);
    let mut history = Vec::new();
    let mut snapshots: Vec<(f64, Model)> = Vec::new();
    let mut rng = Rng::new(cfg.seed);

    let mut phi_acc = vec![0f32; w * k];
    let mut iter_syncs = 0usize;
    let mut cursor: Option<(usize, usize)> = None;
    if let Some(ck) = resume {
        // Contract 6 restore — identical to the in-process path; the
        // workers need no restore of their own because the next Batch
        // frame re-ships their entire state.
        check_resume(&ck, w, k, cfg)?;
        phi_acc = ck.phi.to_dense();
        rng = Rng::from_state(ck.rng_state);
        iter_syncs = ck.iter_syncs;
        ledger = ck.ledger;
        history = ck.history;
        snapshots = ck.snapshots;
        cursor = Some((ck.next_doc, ck.next_batch));
    }
    ledger.record_recovery_replay(replay_secs);
    let mut scratch = SyncScratch::default();
    let mut flat_buf: Vec<u32> = Vec::new();

    let global_budget = cfg.nnz_budget.saturating_mul(cfg.n_workers);
    let mut stream = match cursor {
        Some((doc, batch)) => {
            MiniBatchStream::resume(corpus, global_budget, doc, batch)
        }
        None => MiniBatchStream::new(corpus, global_budget),
    };
    let mut pending = stream.next();
    while let Some(mb) = pending.take() {
        let tokens = mb.data.tokens().max(1.0);

        // Fig. 4 lines 3-5 over the wire: each worker receives its doc
        // slice + RNG split and rebuilds its shard remotely. The RNG
        // draws happen at the same stream position as build_shards'.
        let payloads = batch_payloads(&mb, w, k, params, cfg, &mut rng);
        // Contract 9: key the chaos schedule on (batch, iter) — Batch
        // frames are iteration 0 — and fold the transport's recovery
        // effort (retransmits, reconnects, backoff) into the ledger's
        // side accumulators after every exchange.
        transport.chaos_epoch(mb.index, 0);
        transport.start_batch(&payloads).map_err(transport_err)?;
        ledger.record_wire_faults(&transport.take_wire_stats());
        // Leader-side dense mirrors of each worker's (Δφ̂, r): gather
        // replies scatter into these, and the unchanged allreduce pulls
        // from them exactly as it pulls from in-process shards.
        let sources: Vec<Mutex<PartSource>> = (0..cfg.n_workers)
            .map(|_| Mutex::new(PartSource::new(w * k)))
            .collect();

        let mut state = GlobalState::new(&phi_acc, k);
        let mut power: Option<PowerSet> = None;
        let mut prev_resid = f64::INFINITY;
        let mut first_resid = f64::INFINITY;
        let mut iters_run = 0;

        for t in 1..=cfg.max_iters {
            iters_run = t;
            // --- fault injection (Contract 6): a planned sweep-phase
            //     kill SIGKILLs the real worker before any work ---
            if let Some(f) = faults {
                if let Err(e) = f.trip(mb.index, t, SyncPhase::Sweep) {
                    let _ = transport.kill_worker(e.worker);
                    return Err(TrainError::killed(e, &ledger));
                }
            }
            // --- remote sweep (lines 6-8 / 15-20): publish φ̂ + totals
            //     + the power schedule, collect plan-order exports ---
            let sweep = sweep_payload(t, &state.phi_eff, state.phi_tot(), power.as_ref());
            let frames: Vec<Vec<u8>> = vec![sweep; cfg.n_workers];
            transport.chaos_epoch(mb.index, t);
            let ex = transport.sweep_exchange(&frames).map_err(transport_err)?;
            ledger.record_wire_faults(&transport.take_wire_stats());
            check_replies(&ex, t, cfg.n_workers)?;
            let secs: Vec<f64> = ex.replies.iter().map(|r| r.sweep_secs).collect();

            // --- synchronize on the scheduled pairs (lines 9-10 /
            //     23-24): scatter the replies into the mirrors, then
            //     the same owner-sliced reduce as in-process ---
            let plan = match &power {
                None => ReducePlan::Dense { len: w * k },
                Some(ps) => {
                    ps.flat_indices_into(k, &mut flat_buf);
                    ReducePlan::Subset { indices: &flat_buf }
                }
            };
            let indices = match &plan {
                ReducePlan::Dense { .. } => None,
                ReducePlan::Subset { indices } => Some(*indices),
            };
            for (src, reply) in sources.iter().zip(&ex.replies) {
                src.lock().unwrap().load(indices, reply).map_err(transport_err)?;
            }
            let pairs = match faults {
                None => allreduce_step(
                    &cluster, &plan, &phi_acc, &sources, &mut state, &mut scratch,
                ),
                Some(f) => match allreduce_step_injected(
                    &cluster, &plan, &phi_acc, &sources, &mut state, &mut scratch, f,
                    mb.index, t,
                ) {
                    Ok(p) => p,
                    Err(e) => {
                        let _ = transport.kill_worker(e.worker);
                        return Err(TrainError::killed(e, &ledger));
                    }
                },
            };
            let payload = 2 * 4 * pairs;
            ledger.record_compute(&secs);
            ledger.record_sync(mb.index, t, payload, cfg.n_workers);
            // measured wire seconds beside the α–β estimate: publish
            // is the gather leg; collect minus the slowest worker's
            // sweep is the reduce leg (never part of total_secs)
            let sweep_max = secs.iter().cloned().fold(0.0, f64::max);
            ledger.record_measured((ex.collect_secs - sweep_max).max(0.0), ex.publish_secs);
            if let Some(delays) =
                faults.and_then(|f| f.delays_at(mb.index, t, cfg.n_workers))
            {
                let factor = res.map_or(4.0, |r| r.straggler_timeout_factor);
                let timeout =
                    cfg.net.straggler_timeout_secs(payload, cfg.n_workers, factor);
                ledger.record_straggler(&secs, &delays, timeout);
            }

            iter_syncs += 1;
            let resid_per_token = state.r_total() / tokens;
            if cfg.snapshot_every > 0 && iter_syncs % cfg.snapshot_every == 0 {
                snapshots.push((
                    ledger.total_secs(),
                    Model { k, w, phi_wk: state.phi_eff.clone() },
                ));
            }
            history.push(IterStat {
                batch: mb.index,
                iter: t,
                residual_per_token: resid_per_token,
                synced_pairs: pairs,
                sim_elapsed: ledger.total_secs(),
                wall_elapsed: wall.total_secs(),
            });

            // --- convergence check (line 26), verbatim in-process ---
            if t == 1 {
                first_resid = resid_per_token.max(1e-12);
            }
            if t >= cfg.min_iters
                && resid_per_token <= cfg.converge_thresh
                && resid_per_token <= cfg.converge_rel * first_resid
                && resid_per_token <= prev_resid
            {
                break;
            }
            prev_resid = resid_per_token;

            // --- dynamic power selection (lines 12-13 / 27-28): the
            //     schedule travels to the workers in the next Sweep ---
            if cfg.power.lambda_w < 1.0 || cfg.power.lambda_k_times_k < k {
                power = Some(select_power(&state.r_global, w, k, &cfg.power));
            }
        }

        // --- fold the batch gradient into the global model (Eq. 11):
        //     collect every worker's dense Δφ̂ and run the in-process
        //     fold reduction over the received parts ---
        let next_mb = stream.next();
        // Contract 6: the batch-boundary RNG position — this batch's
        // splits drawn, the next batch's not yet (drawn at the next
        // loop top, the same stream position the in-process prebuild
        // draws them at).
        let rng_boundary = rng.state();
        if let Some(f) = faults {
            if let Err(e) = f.trip(mb.index, iters_run + 1, SyncPhase::Fold) {
                let _ = transport.kill_worker(e.worker);
                return Err(TrainError::killed(e, &ledger));
            }
        }
        {
            transport.chaos_epoch(mb.index, iters_run + 1);
            let fx = transport.collect_fold().map_err(transport_err)?;
            ledger.record_wire_faults(&transport.take_wire_stats());
            check_fold_parts(&fx.parts, cfg.n_workers, w * k)?;
            let dphi_parts: Vec<&[f32]> =
                fx.parts.iter().map(|p| p.as_slice()).collect();
            reduce_chunked(&cluster, Some(&phi_acc), &dphi_parts, &mut state.phi_eff);
            phi_acc.copy_from_slice(&state.phi_eff);
            ledger.record_sync(mb.index, iters_run + 1, 4 * w * k, cfg.n_workers);
            ledger.record_measured(fx.collect_secs, 0.0);
        }
        // --- checkpoint cadence (Contract 6), verbatim in-process ---
        if let (Some(r), Some(nmb)) = (res, next_mb.as_ref()) {
            if r.checkpoint_every > 0 && (mb.index + 1) % r.checkpoint_every == 0 {
                let ck = Checkpoint {
                    w,
                    k,
                    n_workers: cfg.n_workers,
                    seed: cfg.seed,
                    next_batch: nmb.index,
                    next_doc: nmb.doc_range.start,
                    iter_syncs,
                    rng_state: rng_boundary,
                    phi: PhiShard::Replicated(phi_acc.clone()),
                    ledger: ledger.clone(),
                    history: history.clone(),
                    snapshots: snapshots.clone(),
                };
                write_checkpoint(r, &ck, &mut ledger)?;
            }
        }
        pending = next_mb;
        let _ = wall.lap_secs();
    }

    Ok(TrainResult {
        model: Model { k, w, phi_wk: phi_acc },
        history,
        ledger,
        wall_secs: wall.total_secs(),
        snapshots,
    })
}

/// [`fit_sharded`](super::fit) over a transport: the leader keeps only
/// the row-aligned owner slices; workers sweep a dense render of them
/// (bit-equal to the sliced view, Contract 5) and the sharded allreduce
/// folds the mirrored replies into the stored slices.
fn dist_sharded(
    corpus: &Csr,
    params: &LdaParams,
    cfg: &PobpConfig,
    ctx: RunCtx<'_>,
    transport: &mut dyn Transport,
) -> Result<TrainResult, TrainError> {
    let RunCtx { res, faults, resume, replay_secs } = ctx;
    let mut wall = Stopwatch::new();
    let (w, k) = (corpus.w, params.k);
    let cluster = Cluster::new(cfg.n_workers, cfg.max_threads).with_pinning(cfg.pin_cores);
    let mut ledger = Ledger::new(cfg.net);
    let mut history = Vec::new();
    let mut snapshots: Vec<(f64, Model)> = Vec::new();
    let mut rng = Rng::new(cfg.seed);

    let mut phi_acc = PhiShard::sharded(w, k, cfg.n_workers);
    let mut iter_syncs = 0usize;
    let mut cursor: Option<(usize, usize)> = None;
    if let Some(ck) = resume {
        check_resume(&ck, w, k, cfg)?;
        phi_acc = ck.phi;
        rng = Rng::from_state(ck.rng_state);
        iter_syncs = ck.iter_syncs;
        ledger = ck.ledger;
        history = ck.history;
        snapshots = ck.snapshots;
        cursor = Some((ck.next_doc, ck.next_batch));
    }
    ledger.record_recovery_replay(replay_secs);
    let os = phi_acc.owner_slices();
    let rows_per = phi_acc.rows_per();
    let mut scratch = SyncScratch::default();
    let mut flat_buf: Vec<u32> = Vec::new();

    let global_budget = cfg.nnz_budget.saturating_mul(cfg.n_workers);
    let mut stream = match cursor {
        Some((doc, batch)) => {
            MiniBatchStream::resume(corpus, global_budget, doc, batch)
        }
        None => MiniBatchStream::new(corpus, global_budget),
    };
    let mut pending = stream.next();
    while let Some(mb) = pending.take() {
        let tokens = mb.data.tokens().max(1.0);
        let payloads = batch_payloads(&mb, w, k, params, cfg, &mut rng);
        // Contract 9: same chaos keying and recovery-effort accounting
        // as the replicated loop
        transport.chaos_epoch(mb.index, 0);
        transport.start_batch(&payloads).map_err(transport_err)?;
        ledger.record_wire_faults(&transport.take_wire_stats());
        let sources: Vec<Mutex<PartSource>> = (0..cfg.n_workers)
            .map(|_| Mutex::new(PartSource::new(w * k)))
            .collect();

        let mut state = ShardedState::new(phi_acc.parts(), k, os);
        let mut power: Option<PowerSet> = None;
        let mut prev_resid = f64::INFINITY;
        let mut first_resid = f64::INFINITY;
        let mut iters_run = 0;

        for t in 1..=cfg.max_iters {
            iters_run = t;
            if let Some(f) = faults {
                if let Err(e) = f.trip(mb.index, t, SyncPhase::Sweep) {
                    let _ = transport.kill_worker(e.worker);
                    return Err(TrainError::killed(e, &ledger));
                }
            }
            // --- remote sweep over a dense render of the owner slices
            //     (the wire format ships one contiguous φ̂; Contract 5
            //     makes the dense sweep bit-equal to the sliced one) ---
            let phi_dense = state.render_dense();
            let sweep = sweep_payload(t, &phi_dense, state.phi_tot(), power.as_ref());
            let frames: Vec<Vec<u8>> = vec![sweep; cfg.n_workers];
            transport.chaos_epoch(mb.index, t);
            let ex = transport.sweep_exchange(&frames).map_err(transport_err)?;
            ledger.record_wire_faults(&transport.take_wire_stats());
            check_replies(&ex, t, cfg.n_workers)?;
            let secs: Vec<f64> = ex.replies.iter().map(|r| r.sweep_secs).collect();

            // --- owner-sliced reduce-scatter into the stored slices ---
            let plan = match &power {
                None => ReducePlan::Dense { len: w * k },
                Some(ps) => {
                    ps.flat_indices_into(k, &mut flat_buf);
                    ReducePlan::Subset { indices: &flat_buf }
                }
            };
            let indices = match &plan {
                ReducePlan::Dense { .. } => None,
                ReducePlan::Subset { indices } => Some(*indices),
            };
            for (src, reply) in sources.iter().zip(&ex.replies) {
                src.lock().unwrap().load(indices, reply).map_err(transport_err)?;
            }
            let pairs = match faults {
                None => allreduce_step_sharded(
                    &cluster, &plan, phi_acc.parts(), &sources, &mut state, &mut scratch,
                ),
                Some(f) => match allreduce_step_sharded_injected(
                    &cluster, &plan, phi_acc.parts(), &sources, &mut state,
                    &mut scratch, f, mb.index, t,
                ) {
                    Ok(p) => p,
                    Err(e) => {
                        let _ = transport.kill_worker(e.worker);
                        return Err(TrainError::killed(e, &ledger));
                    }
                },
            };

            // --- convergence decision first, so the gather half can
            //     charge exactly the next sweep's working set (verbatim
            //     the in-process sharded accounting) ---
            let resid_per_token = state.r_total() / tokens;
            if t == 1 {
                first_resid = resid_per_token.max(1e-12);
            }
            let converged = t >= cfg.min_iters
                && resid_per_token <= cfg.converge_thresh
                && resid_per_token <= cfg.converge_rel * first_resid
                && resid_per_token <= prev_resid;
            let stopping = converged || t == cfg.max_iters;

            let next: Option<PowerSet> = if !stopping
                && (cfg.power.lambda_w < 1.0 || cfg.power.lambda_k_times_k < k)
            {
                Some(select_power_sharded(&state.r_parts(), rows_per, w, k, &cfg.power))
            } else {
                None
            };

            let reduce_bytes = 2 * 4 * pairs;
            let gather_bytes = if stopping {
                0
            } else {
                4 * next.as_ref().map_or(w * k, |ps| ps.pairs())
            };
            ledger.record_compute(&secs);
            ledger.record_sync_split(mb.index, t, reduce_bytes, gather_bytes, cfg.n_workers);
            let sweep_max = secs.iter().cloned().fold(0.0, f64::max);
            ledger.record_measured((ex.collect_secs - sweep_max).max(0.0), ex.publish_secs);
            if let Some(delays) =
                faults.and_then(|f| f.delays_at(mb.index, t, cfg.n_workers))
            {
                let factor = res.map_or(4.0, |r| r.straggler_timeout_factor);
                let timeout = cfg.net.straggler_timeout_secs(
                    reduce_bytes + gather_bytes,
                    cfg.n_workers,
                    factor,
                );
                ledger.record_straggler(&secs, &delays, timeout);
            }

            iter_syncs += 1;
            if cfg.snapshot_every > 0 && iter_syncs % cfg.snapshot_every == 0 {
                snapshots.push((
                    ledger.total_secs(),
                    Model { k, w, phi_wk: state.render_dense() },
                ));
            }
            history.push(IterStat {
                batch: mb.index,
                iter: t,
                residual_per_token: resid_per_token,
                synced_pairs: pairs,
                sim_elapsed: ledger.total_secs(),
                wall_elapsed: wall.total_secs(),
            });

            if converged {
                break;
            }
            prev_resid = resid_per_token;
            if let Some(ps) = next {
                power = Some(ps);
            }
        }

        // --- fold into the sharded accumulator (Eq. 11): each owner
        //     folds every received dense Δφ̂ over its own slice ---
        let next_mb = stream.next();
        let rng_boundary = rng.state();
        if let Some(f) = faults {
            if let Err(e) = f.trip(mb.index, iters_run + 1, SyncPhase::Fold) {
                let _ = transport.kill_worker(e.worker);
                return Err(TrainError::killed(e, &ledger));
            }
        }
        {
            transport.chaos_epoch(mb.index, iters_run + 1);
            let fx = transport.collect_fold().map_err(transport_err)?;
            ledger.record_wire_faults(&transport.take_wire_stats());
            check_fold_parts(&fx.parts, cfg.n_workers, w * k)?;
            let dphi_parts: Vec<&[f32]> =
                fx.parts.iter().map(|p| p.as_slice()).collect();
            state.fold_batch(&cluster, phi_acc.parts_mut(), &dphi_parts);
            ledger.record_sync_split(
                mb.index,
                iters_run + 1,
                4 * w * k,
                4 * w * k,
                cfg.n_workers,
            );
            ledger.record_measured(fx.collect_secs, 0.0);
        }
        if let (Some(r), Some(nmb)) = (res, next_mb.as_ref()) {
            if r.checkpoint_every > 0 && (mb.index + 1) % r.checkpoint_every == 0 {
                let ck = Checkpoint {
                    w,
                    k,
                    n_workers: cfg.n_workers,
                    seed: cfg.seed,
                    next_batch: nmb.index,
                    next_doc: nmb.doc_range.start,
                    iter_syncs,
                    rng_state: rng_boundary,
                    phi: phi_acc.clone(),
                    ledger: ledger.clone(),
                    history: history.clone(),
                    snapshots: snapshots.clone(),
                };
                write_checkpoint(r, &ck, &mut ledger)?;
            }
        }
        pending = next_mb;
        let _ = wall.lap_secs();
    }

    Ok(TrainResult {
        model: Model { k, w, phi_wk: phi_acc.to_dense() },
        history,
        ledger,
        wall_secs: wall.total_secs(),
        snapshots,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::InProcessTransport;
    use crate::coordinator::fit;
    use crate::synth::{generate, SynthSpec};

    // The deep pins — worker counts × storage modes × thread budgets,
    // real TCP processes, SIGKILL + rejoin — live in
    // rust/tests/dist_equiv.rs; these are the smoke-level contracts.

    #[test]
    fn inprocess_transport_matches_fit_oracle() {
        let c = generate(&SynthSpec::tiny(17)).corpus;
        let params = LdaParams::paper(8);
        let cfg = PobpConfig {
            n_workers: 2,
            nnz_budget: 700,
            max_iters: 8,
            ..Default::default()
        };
        let oracle = fit(&c, &params, &cfg);
        let mut tp = InProcessTransport::new(cfg.n_workers, cfg.max_threads);
        let r = fit_dist(&c, &params, &cfg, &mut tp).expect("dist fit");
        assert_eq!(r.model.phi_wk, oracle.model.phi_wk);
        assert_eq!(r.history.len(), oracle.history.len());
        for (a, b) in r.history.iter().zip(&oracle.history) {
            assert_eq!(
                a.residual_per_token.to_bits(),
                b.residual_per_token.to_bits()
            );
            assert_eq!(a.synced_pairs, b.synced_pairs);
        }
        assert_eq!(r.ledger.sync_count(), oracle.ledger.sync_count());
        assert_eq!(
            r.ledger.payload_bytes_total(),
            oracle.ledger.payload_bytes_total()
        );
        // every sync recorded a measured wire segment beside the model
        assert_eq!(r.ledger.measured.len(), r.ledger.sync_count());
    }

    #[test]
    fn dist_rejects_overlap_and_mismatched_transport() {
        let c = generate(&SynthSpec::tiny(3)).corpus;
        let params = LdaParams::paper(4);
        let mut tp = InProcessTransport::new(2, 1);
        let cfg = PobpConfig { n_workers: 2, overlap: true, ..Default::default() };
        match fit_dist(&c, &params, &cfg, &mut tp) {
            Err(TrainError::Config(ConfigError::OverlapDistUnsupported)) => {}
            Err(other) => panic!("unexpected error: {other}"),
            Ok(_) => panic!("overlap over a transport must be rejected"),
        }
        let cfg = PobpConfig { n_workers: 3, ..Default::default() };
        match fit_dist(&c, &params, &cfg, &mut tp) {
            Err(TrainError::Transport(msg)) => {
                assert!(msg.contains("workers"), "odd message: {msg}")
            }
            Err(other) => panic!("unexpected error: {other}"),
            Ok(_) => panic!("worker-count mismatch must be rejected"),
        }
    }
}
