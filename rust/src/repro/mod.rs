//! The experiment harness: dataset presets, a uniform algorithm runner,
//! and perplexity-curve helpers shared by the CLI, the examples and every
//! `benches/` target. One function per concept so each bench file maps
//! 1:1 onto a paper table/figure (DESIGN.md §5).

use crate::comm::{NetModel, TransportKind};
use crate::coordinator::{
    fit_checked, fit_resilient, PobpConfig, ResilienceConfig, TrainError,
};
use crate::corpus::{split_tokens, Csr, Split};
use crate::engine::mpa::{fit_gibbs, GsVariant, MpaConfig};
use crate::engine::traits::{LdaParams, Model, TrainResult};
use crate::engine::vb::fit_vb;
use crate::eval::perplexity::predictive_perplexity;
use crate::sched::PowerParams;
use crate::storage::PhiStorageMode;
use crate::synth::{generate, SynthSpec, TABLE3};

/// Every algorithm the paper evaluates (Figs. 8–12, Tables 4–5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// the paper's system
    Pobp,
    /// parallel OBP without power selection (ablation)
    PobpFull,
    /// single-processor online BP
    Obp,
    /// single-processor batch BP
    BatchBp,
    Pgs,
    Pfgs,
    Psgs,
    Ylda,
    Pvb,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Pobp => "pobp",
            Algo::PobpFull => "pobp-full",
            Algo::Obp => "obp",
            Algo::BatchBp => "bp",
            Algo::Pgs => "pgs",
            Algo::Pfgs => "pfgs",
            Algo::Psgs => "psgs",
            Algo::Ylda => "ylda",
            Algo::Pvb => "pvb",
        }
    }

    pub fn parse(s: &str) -> Option<Algo> {
        Some(match s {
            "pobp" => Algo::Pobp,
            "pobp-full" => Algo::PobpFull,
            "obp" => Algo::Obp,
            "bp" => Algo::BatchBp,
            "pgs" => Algo::Pgs,
            "pfgs" => Algo::Pfgs,
            "psgs" => Algo::Psgs,
            "ylda" => Algo::Ylda,
            "pvb" => Algo::Pvb,
            _ => return None,
        })
    }

    /// The comparison set of the paper's Figs. 8–11.
    pub fn paper_set() -> [Algo; 5] {
        [Algo::Pobp, Algo::Pfgs, Algo::Psgs, Algo::Ylda, Algo::Pvb]
    }
}

/// Uniform knobs for one experiment run.
#[derive(Clone, Debug)]
pub struct RunOpts {
    pub n_workers: usize,
    pub max_threads: usize,
    /// Pin pool threads to cores (`PobpConfig::pin_cores`): best-effort
    /// cache-warmth hint, bitwise-identical results pinned or floating.
    /// Honored by the POBP family; the Gibbs/VB baselines ignore it.
    pub pin_cores: bool,
    /// batch iterations for the batch algorithms (paper: 500)
    pub iters: usize,
    /// per-mini-batch iteration cap for the online algorithms
    pub max_batch_iters: usize,
    pub nnz_budget: usize,
    pub power: PowerParams,
    pub net: NetModel,
    pub seed: u64,
    pub snapshot_every: usize,
    /// Run the POBP family through the overlap pipeline
    /// (`PobpConfig::overlap`): double-buffered gather/fold allreduce,
    /// next-batch shard construction hidden behind the end-of-batch
    /// fold, and `max(compute, comm)` ledger accounting per iteration.
    /// Numerical results are bitwise identical to the serialized mode —
    /// only the time accounting changes — so figure benches can ablate
    /// pipelined POBP against the overlapped YLDA baseline
    /// (`benches/fig11_training_time.rs`). Ignored by the Gibbs/VB
    /// algorithms (YLDA always overlaps; the others are serialized BSP
    /// by construction). Default `false`: the paper charges POBP the
    /// serialized BSP cost of Fig. 1.
    pub overlap: bool,
    /// φ̂ storage layout for the POBP family (`PobpConfig::storage`):
    /// `Replicated` (default) keeps the dense per-processor replica,
    /// `Sharded` stores row-aligned owner slices — O(W·K/N) per-worker
    /// φ̂ memory, bitwise-identical results. Ignored by the Gibbs/VB
    /// algorithms.
    pub storage: PhiStorageMode,
    /// Fault tolerance for the POBP family (Contract 6): write a
    /// crash-consistent checkpoint every this many completed
    /// mini-batches (0 = never). With checkpointing or `resume` on, the
    /// run goes through `coordinator::fit_resilient` — recovery from a
    /// kill is bitwise identical to the uninterrupted run. Ignored by
    /// the Gibbs/VB algorithms.
    pub checkpoint_every: usize,
    /// checkpoint directory (empty = default `pobp-checkpoints`)
    pub checkpoint_dir: String,
    /// kills absorbed before the run gives up
    pub max_retries: usize,
    /// straggler timeout factor (× the modeled per-iteration sync time)
    pub straggler_timeout_factor: f64,
    /// resume from the newest matching checkpoint in `checkpoint_dir`
    pub resume: bool,
    /// Synchronization carrier for the POBP family (Contract 8):
    /// `InProcess` (default) runs logical workers on the in-process
    /// pool inside this process; `Tcp` is the real master/worker
    /// cluster, which runs under the dedicated `pobp-master` /
    /// `pobp-worker` binaries — `run_algo` itself never opens sockets,
    /// so resolving a `transport = tcp` config here is a typed error at
    /// the CLI layer, not a silent fallback. Ignored by the Gibbs/VB
    /// algorithms.
    pub transport: TransportKind,
    /// Worker startup connect attempts after the first (Contract 9):
    /// `pobp-worker` retries its initial connect this many times with
    /// capped exponential backoff, so spawn order against the master's
    /// listener does not matter. Mirrored by the worker binary's
    /// `--connect-retries` flag.
    pub connect_retries: usize,
    /// Initial connect/rejoin backoff in milliseconds, doubling per
    /// attempt and capped at 2 s (`--connect-backoff-ms`).
    pub connect_backoff_ms: u64,
    /// Seed of the deterministic wire-fault schedule (Contract 9);
    /// meaningful only when `chaos_permille > 0`.
    pub chaos_seed: u64,
    /// Per-frame wire-fault probability out of 1000 (0 = chaos off,
    /// the default; at most 1000). Faults are injected at the master's
    /// TCP edge and recovered by the supervised retry/reconnect layer —
    /// results stay bitwise identical to the fault-free run.
    pub chaos_permille: u32,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            n_workers: 4,
            max_threads: 0,
            pin_cores: false,
            iters: 100,
            // power-subset iterations are ~λ_W·λ_K cheap, so the BP family
            // gets a deep budget (the paper's T ≈ 200); the residual
            // threshold stops full-selection runs much earlier
            max_batch_iters: 200,
            nnz_budget: 45_000,
            power: PowerParams::paper_default(),
            net: NetModel::infiniband_20gbps(),
            seed: 42,
            snapshot_every: 0,
            overlap: false,
            storage: PhiStorageMode::Replicated,
            checkpoint_every: 0,
            checkpoint_dir: String::new(),
            max_retries: 3,
            straggler_timeout_factor: 4.0,
            resume: false,
            transport: TransportKind::InProcess,
            connect_retries: 10,
            connect_backoff_ms: 50,
            chaos_seed: 0,
            chaos_permille: 0,
        }
    }
}

impl RunOpts {
    /// Whether the POBP family should run through the fault-tolerant
    /// entry point (`coordinator::fit_resilient`).
    pub fn wants_resilience(&self) -> bool {
        self.checkpoint_every > 0 || self.resume
    }

    /// The resilience knobs these options describe.
    pub fn resilience(&self) -> ResilienceConfig {
        let dir = if self.checkpoint_dir.is_empty() {
            "pobp-checkpoints"
        } else {
            &self.checkpoint_dir
        };
        ResilienceConfig {
            checkpoint_every: self.checkpoint_every,
            max_retries: self.max_retries,
            straggler_timeout_factor: self.straggler_timeout_factor,
            resume: self.resume,
            ..ResilienceConfig::in_dir(dir)
        }
    }
}

/// The `PobpConfig` that `run_algo` hands the coordinator for a BP-family
/// algorithm under the shared options.
pub fn pobp_config(algo: Algo, params: &LdaParams, o: &RunOpts) -> PobpConfig {
    // clamp the per-word power-topic count to K
    let power = PowerParams {
        lambda_w: o.power.lambda_w,
        lambda_k_times_k: o.power.lambda_k_times_k.min(params.k),
    };
    PobpConfig {
        n_workers: match algo {
            Algo::Obp | Algo::BatchBp => 1,
            _ => o.n_workers,
        },
        max_threads: o.max_threads,
        pin_cores: o.pin_cores,
        nnz_budget: if algo == Algo::BatchBp { usize::MAX } else { o.nnz_budget },
        power: match algo {
            Algo::Pobp => power,
            _ => PowerParams::full(),
        },
        max_iters: o.max_batch_iters,
        min_iters: 5,
        converge_thresh: 0.1,
        converge_rel: 0.01,
        net: o.net,
        seed: o.seed,
        snapshot_every: o.snapshot_every,
        // default false: the paper charges POBP the serialized
        // BSP cost (Fig. 1); the overlap ablation flips this to
        // compare pipelined POBP against the overlapped YLDA
        overlap: o.overlap,
        storage: o.storage,
    }
}

/// Run `algo` on `corpus` under the shared options, surfacing invalid
/// configurations and terminal faults as typed errors instead of panics.
pub fn run_algo_checked(
    algo: Algo,
    corpus: &Csr,
    params: &LdaParams,
    o: &RunOpts,
) -> Result<TrainResult, TrainError> {
    match algo {
        Algo::Pobp | Algo::PobpFull | Algo::Obp | Algo::BatchBp => {
            let cfg = pobp_config(algo, params, o);
            if o.wants_resilience() {
                fit_resilient(corpus, params, &cfg, &o.resilience(), None)
            } else {
                fit_checked(corpus, params, &cfg)
            }
        }
        Algo::Pgs | Algo::Pfgs | Algo::Psgs | Algo::Ylda => {
            let cfg = MpaConfig {
                n_workers: o.n_workers,
                max_threads: o.max_threads,
                iters: o.iters,
                net: o.net,
                seed: o.seed,
                snapshot_every: o.snapshot_every,
            };
            let variant = match algo {
                Algo::Pgs => GsVariant::Plain,
                Algo::Pfgs => GsVariant::Fast,
                Algo::Psgs => GsVariant::Sparse,
                _ => GsVariant::Ylda,
            };
            Ok(fit_gibbs(corpus, params, &cfg, variant))
        }
        Algo::Pvb => {
            let cfg = MpaConfig {
                n_workers: o.n_workers,
                max_threads: o.max_threads,
                // VB iterations are ~INNER_ITERS× heavier; match the GS
                // budget in sweeps, the paper runs all batch algorithms
                // the same 500 iterations
                iters: o.iters,
                net: o.net,
                seed: o.seed,
                snapshot_every: o.snapshot_every,
            };
            Ok(fit_vb(corpus, params, &cfg))
        }
    }
}

/// Run `algo` on `corpus` under the shared options. Panics on an invalid
/// configuration or a terminal fault; [`run_algo_checked`] is the typed
/// variant.
pub fn run_algo(algo: Algo, corpus: &Csr, params: &LdaParams, o: &RunOpts) -> TrainResult {
    match run_algo_checked(algo, corpus, params, o) {
        Ok(r) => r,
        Err(e) => panic!("{e}"),
    }
}

/// The paper's corpora, scaled (DESIGN.md §Substitutions). `scale` divides
/// the document count; vocabulary is capped at 2000.
pub fn dataset(name: &str, scale: usize, topics: usize, seed: u64) -> Csr {
    if name == "tiny" {
        return generate(&SynthSpec::tiny(seed)).corpus;
    }
    let row = TABLE3
        .iter()
        .find(|r| r.name.eq_ignore_ascii_case(name.trim_end_matches("-sim")))
        .unwrap_or_else(|| panic!("unknown dataset {name}"));
    generate(&SynthSpec::from_table(row, scale, topics, seed)).corpus
}

/// 80/20 split + predictive perplexity of a trained model (Eq. 20).
pub fn eval_model(model: &Model, corpus: &Csr, params: &LdaParams, seed: u64) -> f64 {
    let split = split_tokens(corpus, 0.2, seed);
    predictive_perplexity(model, &split, params, 20, seed)
}

/// Perplexity at every snapshot → (sim_secs, perplexity) series (Fig. 8).
pub fn perplexity_curve(
    result: &TrainResult,
    split: &Split,
    params: &LdaParams,
    seed: u64,
) -> Vec<(f64, f64)> {
    result
        .snapshots
        .iter()
        .map(|(t, m)| (*t, predictive_perplexity(m, split, params, 20, seed)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_algos_run_on_tiny() {
        let c = dataset("tiny", 1, 8, 3);
        let params = LdaParams::paper(8);
        let o = RunOpts {
            n_workers: 2,
            iters: 5,
            max_batch_iters: 8,
            nnz_budget: 1000,
            ..Default::default()
        };
        for algo in [
            Algo::Pobp, Algo::PobpFull, Algo::Obp, Algo::BatchBp,
            Algo::Pgs, Algo::Pfgs, Algo::Psgs, Algo::Ylda, Algo::Pvb,
        ] {
            let r = run_algo(algo, &c, &params, &o);
            assert!(
                (r.model.mass() - c.tokens()).abs() < c.tokens() * 1e-3,
                "{} mass {} vs {}",
                algo.name(),
                r.model.mass(),
                c.tokens()
            );
        }
    }

    #[test]
    fn overlap_flag_matches_serialized_bitwise() {
        // RunOpts::overlap is pure time accounting: the pipelined run
        // must reproduce the serialized model bit-for-bit while hiding
        // some communication.
        let c = dataset("tiny", 1, 8, 3);
        let params = LdaParams::paper(8);
        let o = RunOpts {
            n_workers: 3,
            max_batch_iters: 10,
            nnz_budget: 900,
            ..Default::default()
        };
        let ser = run_algo(Algo::Pobp, &c, &params, &o);
        let ov = run_algo(Algo::Pobp, &c, &params, &RunOpts { overlap: true, ..o });
        assert_eq!(ser.model.phi_wk, ov.model.phi_wk);
        assert_eq!(ser.ledger.payload_bytes_total(), ov.ledger.payload_bytes_total());
        assert_eq!(ser.ledger.overlap_saved_secs, 0.0);
        assert!(ov.ledger.overlap_saved_secs > 0.0, "pipeline hid no communication");
    }

    #[test]
    fn resilient_opts_match_plain_run_bitwise() {
        // checkpoint_every routes the POBP family through
        // fit_resilient; a healthy run must stay bitwise identical and
        // only pick up side-accumulator checkpoint charges.
        let dir = std::env::temp_dir()
            .join(format!("pobp-repro-res-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = dataset("tiny", 1, 8, 3);
        let params = LdaParams::paper(8);
        let o = RunOpts {
            n_workers: 2,
            max_batch_iters: 8,
            nnz_budget: 500,
            ..Default::default()
        };
        let plain = run_algo(Algo::Pobp, &c, &params, &o);
        let resilient = run_algo(
            Algo::Pobp,
            &c,
            &params,
            &RunOpts {
                checkpoint_every: 1,
                checkpoint_dir: dir.to_string_lossy().into_owned(),
                ..o
            },
        );
        assert_eq!(plain.model.phi_wk, resilient.model.phi_wk);
        assert!(resilient.ledger.checkpoint_count >= 1);
        assert_eq!(
            plain.ledger.total_secs().to_bits(),
            resilient.ledger.total_secs().to_bits()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dataset_presets_resolve() {
        let c = dataset("enron", 400, 8, 1);
        assert!(c.docs() >= 50);
        assert!(c.w <= 2000);
    }

    #[test]
    #[should_panic(expected = "unknown dataset")]
    fn unknown_dataset_panics() {
        dataset("nope", 1, 8, 1);
    }

    #[test]
    fn algo_names_roundtrip() {
        for a in [
            Algo::Pobp, Algo::PobpFull, Algo::Obp, Algo::BatchBp,
            Algo::Pgs, Algo::Pfgs, Algo::Psgs, Algo::Ylda, Algo::Pvb,
        ] {
            assert_eq!(Algo::parse(a.name()), Some(a));
        }
        assert_eq!(Algo::parse("bogus"), None);
    }
}
