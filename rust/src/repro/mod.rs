//! The experiment harness: dataset presets, a uniform algorithm runner,
//! and perplexity-curve helpers shared by the CLI, the examples and every
//! `benches/` target. One function per concept so each bench file maps
//! 1:1 onto a paper table/figure (DESIGN.md §5).

use crate::comm::NetModel;
use crate::coordinator::{fit as fit_pobp, PobpConfig};
use crate::corpus::{split_tokens, Csr, Split};
use crate::engine::mpa::{fit_gibbs, GsVariant, MpaConfig};
use crate::engine::traits::{LdaParams, Model, TrainResult};
use crate::engine::vb::fit_vb;
use crate::eval::perplexity::predictive_perplexity;
use crate::sched::PowerParams;
use crate::storage::PhiStorageMode;
use crate::synth::{generate, SynthSpec, TABLE3};

/// Every algorithm the paper evaluates (Figs. 8–12, Tables 4–5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// the paper's system
    Pobp,
    /// parallel OBP without power selection (ablation)
    PobpFull,
    /// single-processor online BP
    Obp,
    /// single-processor batch BP
    BatchBp,
    Pgs,
    Pfgs,
    Psgs,
    Ylda,
    Pvb,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Pobp => "pobp",
            Algo::PobpFull => "pobp-full",
            Algo::Obp => "obp",
            Algo::BatchBp => "bp",
            Algo::Pgs => "pgs",
            Algo::Pfgs => "pfgs",
            Algo::Psgs => "psgs",
            Algo::Ylda => "ylda",
            Algo::Pvb => "pvb",
        }
    }

    pub fn parse(s: &str) -> Option<Algo> {
        Some(match s {
            "pobp" => Algo::Pobp,
            "pobp-full" => Algo::PobpFull,
            "obp" => Algo::Obp,
            "bp" => Algo::BatchBp,
            "pgs" => Algo::Pgs,
            "pfgs" => Algo::Pfgs,
            "psgs" => Algo::Psgs,
            "ylda" => Algo::Ylda,
            "pvb" => Algo::Pvb,
            _ => return None,
        })
    }

    /// The comparison set of the paper's Figs. 8–11.
    pub fn paper_set() -> [Algo; 5] {
        [Algo::Pobp, Algo::Pfgs, Algo::Psgs, Algo::Ylda, Algo::Pvb]
    }
}

/// Uniform knobs for one experiment run.
#[derive(Clone, Debug)]
pub struct RunOpts {
    pub n_workers: usize,
    pub max_threads: usize,
    /// batch iterations for the batch algorithms (paper: 500)
    pub iters: usize,
    /// per-mini-batch iteration cap for the online algorithms
    pub max_batch_iters: usize,
    pub nnz_budget: usize,
    pub power: PowerParams,
    pub net: NetModel,
    pub seed: u64,
    pub snapshot_every: usize,
    /// Run the POBP family through the overlap pipeline
    /// (`PobpConfig::overlap`): double-buffered gather/fold allreduce,
    /// next-batch shard construction hidden behind the end-of-batch
    /// fold, and `max(compute, comm)` ledger accounting per iteration.
    /// Numerical results are bitwise identical to the serialized mode —
    /// only the time accounting changes — so figure benches can ablate
    /// pipelined POBP against the overlapped YLDA baseline
    /// (`benches/fig11_training_time.rs`). Ignored by the Gibbs/VB
    /// algorithms (YLDA always overlaps; the others are serialized BSP
    /// by construction). Default `false`: the paper charges POBP the
    /// serialized BSP cost of Fig. 1.
    pub overlap: bool,
    /// φ̂ storage layout for the POBP family (`PobpConfig::storage`):
    /// `Replicated` (default) keeps the dense per-processor replica,
    /// `Sharded` stores row-aligned owner slices — O(W·K/N) per-worker
    /// φ̂ memory, bitwise-identical results. Ignored by the Gibbs/VB
    /// algorithms.
    pub storage: PhiStorageMode,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            n_workers: 4,
            max_threads: 0,
            iters: 100,
            // power-subset iterations are ~λ_W·λ_K cheap, so the BP family
            // gets a deep budget (the paper's T ≈ 200); the residual
            // threshold stops full-selection runs much earlier
            max_batch_iters: 200,
            nnz_budget: 45_000,
            power: PowerParams::paper_default(),
            net: NetModel::infiniband_20gbps(),
            seed: 42,
            snapshot_every: 0,
            overlap: false,
            storage: PhiStorageMode::Replicated,
        }
    }
}

/// Run `algo` on `corpus` under the shared options.
pub fn run_algo(algo: Algo, corpus: &Csr, params: &LdaParams, o: &RunOpts) -> TrainResult {
    // clamp the per-word power-topic count to K
    let power = PowerParams {
        lambda_w: o.power.lambda_w,
        lambda_k_times_k: o.power.lambda_k_times_k.min(params.k),
    };
    match algo {
        Algo::Pobp | Algo::PobpFull | Algo::Obp | Algo::BatchBp => {
            let cfg = PobpConfig {
                n_workers: match algo {
                    Algo::Obp | Algo::BatchBp => 1,
                    _ => o.n_workers,
                },
                max_threads: o.max_threads,
                nnz_budget: if algo == Algo::BatchBp { usize::MAX } else { o.nnz_budget },
                power: match algo {
                    Algo::Pobp => power,
                    _ => PowerParams::full(),
                },
                max_iters: o.max_batch_iters,
                min_iters: 5,
                converge_thresh: 0.1,
                converge_rel: 0.01,
                net: o.net,
                seed: o.seed,
                snapshot_every: o.snapshot_every,
                // default false: the paper charges POBP the serialized
                // BSP cost (Fig. 1); the overlap ablation flips this to
                // compare pipelined POBP against the overlapped YLDA
                overlap: o.overlap,
                storage: o.storage,
            };
            fit_pobp(corpus, params, &cfg)
        }
        Algo::Pgs | Algo::Pfgs | Algo::Psgs | Algo::Ylda => {
            let cfg = MpaConfig {
                n_workers: o.n_workers,
                max_threads: o.max_threads,
                iters: o.iters,
                net: o.net,
                seed: o.seed,
                snapshot_every: o.snapshot_every,
            };
            let variant = match algo {
                Algo::Pgs => GsVariant::Plain,
                Algo::Pfgs => GsVariant::Fast,
                Algo::Psgs => GsVariant::Sparse,
                _ => GsVariant::Ylda,
            };
            fit_gibbs(corpus, params, &cfg, variant)
        }
        Algo::Pvb => {
            let cfg = MpaConfig {
                n_workers: o.n_workers,
                max_threads: o.max_threads,
                // VB iterations are ~INNER_ITERS× heavier; match the GS
                // budget in sweeps, the paper runs all batch algorithms
                // the same 500 iterations
                iters: o.iters,
                net: o.net,
                seed: o.seed,
                snapshot_every: o.snapshot_every,
            };
            fit_vb(corpus, params, &cfg)
        }
    }
}

/// The paper's corpora, scaled (DESIGN.md §Substitutions). `scale` divides
/// the document count; vocabulary is capped at 2000.
pub fn dataset(name: &str, scale: usize, topics: usize, seed: u64) -> Csr {
    if name == "tiny" {
        return generate(&SynthSpec::tiny(seed)).corpus;
    }
    let row = TABLE3
        .iter()
        .find(|r| r.name.eq_ignore_ascii_case(name.trim_end_matches("-sim")))
        .unwrap_or_else(|| panic!("unknown dataset {name}"));
    generate(&SynthSpec::from_table(row, scale, topics, seed)).corpus
}

/// 80/20 split + predictive perplexity of a trained model (Eq. 20).
pub fn eval_model(model: &Model, corpus: &Csr, params: &LdaParams, seed: u64) -> f64 {
    let split = split_tokens(corpus, 0.2, seed);
    predictive_perplexity(model, &split, params, 20, seed)
}

/// Perplexity at every snapshot → (sim_secs, perplexity) series (Fig. 8).
pub fn perplexity_curve(
    result: &TrainResult,
    split: &Split,
    params: &LdaParams,
    seed: u64,
) -> Vec<(f64, f64)> {
    result
        .snapshots
        .iter()
        .map(|(t, m)| (*t, predictive_perplexity(m, split, params, 20, seed)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_algos_run_on_tiny() {
        let c = dataset("tiny", 1, 8, 3);
        let params = LdaParams::paper(8);
        let o = RunOpts {
            n_workers: 2,
            iters: 5,
            max_batch_iters: 8,
            nnz_budget: 1000,
            ..Default::default()
        };
        for algo in [
            Algo::Pobp, Algo::PobpFull, Algo::Obp, Algo::BatchBp,
            Algo::Pgs, Algo::Pfgs, Algo::Psgs, Algo::Ylda, Algo::Pvb,
        ] {
            let r = run_algo(algo, &c, &params, &o);
            assert!(
                (r.model.mass() - c.tokens()).abs() < c.tokens() * 1e-3,
                "{} mass {} vs {}",
                algo.name(),
                r.model.mass(),
                c.tokens()
            );
        }
    }

    #[test]
    fn overlap_flag_matches_serialized_bitwise() {
        // RunOpts::overlap is pure time accounting: the pipelined run
        // must reproduce the serialized model bit-for-bit while hiding
        // some communication.
        let c = dataset("tiny", 1, 8, 3);
        let params = LdaParams::paper(8);
        let o = RunOpts {
            n_workers: 3,
            max_batch_iters: 10,
            nnz_budget: 900,
            ..Default::default()
        };
        let ser = run_algo(Algo::Pobp, &c, &params, &o);
        let ov = run_algo(Algo::Pobp, &c, &params, &RunOpts { overlap: true, ..o });
        assert_eq!(ser.model.phi_wk, ov.model.phi_wk);
        assert_eq!(ser.ledger.payload_bytes_total(), ov.ledger.payload_bytes_total());
        assert_eq!(ser.ledger.overlap_saved_secs, 0.0);
        assert!(ov.ledger.overlap_saved_secs > 0.0, "pipeline hid no communication");
    }

    #[test]
    fn dataset_presets_resolve() {
        let c = dataset("enron", 400, 8, 1);
        assert!(c.docs() >= 50);
        assert!(c.w <= 2000);
    }

    #[test]
    #[should_panic(expected = "unknown dataset")]
    fn unknown_dataset_panics() {
        dataset("nope", 1, 8, 1);
    }

    #[test]
    fn algo_names_roundtrip() {
        for a in [
            Algo::Pobp, Algo::PobpFull, Algo::Obp, Algo::BatchBp,
            Algo::Pgs, Algo::Pfgs, Algo::Psgs, Algo::Ylda, Algo::Pvb,
        ] {
            assert_eq!(Algo::parse(a.name()), Some(a));
        }
        assert_eq!(Algo::parse("bogus"), None);
    }
}
