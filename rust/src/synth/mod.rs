//! Synthetic corpus generation (substitute for the paper's UCI corpora).
//!
//! The paper evaluates on ENRON, NYTIMES, WIKIPEDIA and PUBMED (Table 3).
//! Those dumps are not available offline, so we generate corpora from the
//! LDA generative model itself with the statistics that drive every
//! reported quantity matched to Table 3 (scaled):
//!
//!   * vocabulary word marginals follow a Zipf law (the power-law
//!     structure §3.3 depends on),
//!   * per-document length from a log-normal fitted to tokens/doc,
//!   * sparsity η = NNZ/(W·D) emerges from the above (validated in tests),
//!   * topics drawn from a sparse symmetric Dirichlet, modulated by the
//!     Zipf base measure.
//!
//! `TableRow` records the paper's Table 3 so the benches can print
//! paper-vs-generated statistics side by side.

use crate::corpus::csr::Csr;
use crate::util::rng::Rng;

/// One row of the paper's Table 3 (the real-corpus statistics).
#[derive(Clone, Copy, Debug)]
pub struct TableRow {
    pub name: &'static str,
    pub d: usize,
    pub w: usize,
    pub tokens: u64,
    pub nnz: u64,
}

/// Paper Table 3, verbatim.
pub const TABLE3: [TableRow; 4] = [
    TableRow { name: "ENRON", d: 39_861, w: 6_536, tokens: 6_412_172, nnz: 2_374_385 },
    TableRow { name: "NYTIMES", d: 300_000, w: 7_871, tokens: 99_542_125, nnz: 44_379_275 },
    TableRow { name: "WIKIPEDIA", d: 4_360_095, w: 5_363, tokens: 665_375_061, nnz: 154_934_308 },
    TableRow { name: "PUBMED", d: 8_200_000, w: 6_902, tokens: 737_869_083, nnz: 222_399_377 },
];

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: String,
    pub docs: usize,
    pub vocab: usize,
    pub topics: usize,
    /// mean tokens per document
    pub mean_doc_len: f64,
    /// Zipf exponent of the word marginal (≈1 for natural text)
    pub zipf_s: f64,
    /// Dirichlet concentration for topic-word distributions
    pub beta_gen: f64,
    /// Dirichlet concentration for doc-topic distributions
    pub alpha_gen: f64,
    pub seed: u64,
}

impl SynthSpec {
    /// Scale a Table 3 corpus down by `scale` (docs /= scale), keeping
    /// tokens/doc and the W/D flavour of the original.
    pub fn from_table(row: &TableRow, scale: usize, topics: usize, seed: u64) -> SynthSpec {
        let docs = (row.d / scale).max(50);
        SynthSpec {
            name: format!("{}-sim", row.name.to_lowercase()),
            docs,
            vocab: row.w.min(2000), // truncated further for laptop scale
            topics,
            mean_doc_len: row.tokens as f64 / row.d as f64,
            zipf_s: 1.05,
            beta_gen: 0.02,
            alpha_gen: 0.08,
            seed,
        }
    }

    /// Small preset used across tests and quickstart.
    pub fn tiny(seed: u64) -> SynthSpec {
        SynthSpec {
            name: "tiny".into(),
            docs: 120,
            vocab: 200,
            topics: 8,
            mean_doc_len: 40.0,
            zipf_s: 1.0,
            beta_gen: 0.05,
            alpha_gen: 0.1,
            seed,
        }
    }
}

/// A generated corpus plus its ground-truth parameters (useful for
/// accuracy sanity checks beyond perplexity).
pub struct SynthCorpus {
    pub spec: SynthSpec,
    pub corpus: Csr,
    /// true topic-word distributions, row-major (K, W), rows sum to 1
    pub phi_true: Vec<f64>,
}

/// Draw a corpus from the LDA generative model with a Zipf word base.
pub fn generate(spec: &SynthSpec) -> SynthCorpus {
    let (d, w, k) = (spec.docs, spec.vocab, spec.topics);
    let mut rng = Rng::new(spec.seed);

    // Zipf base measure over the vocabulary.
    let base: Vec<f64> = {
        let mut b: Vec<f64> = (0..w)
            .map(|i| 1.0 / ((i + 1) as f64).powf(spec.zipf_s))
            .collect();
        let s: f64 = b.iter().sum();
        b.iter_mut().for_each(|x| *x /= s);
        b
    };

    // Topic-word distributions: Gamma(beta * W * base_w) draws, normalized.
    // This is Dirichlet(beta * W * base) — sparse topics whose marginal
    // matches the Zipf base, so the corpus-level word frequencies follow
    // the power law that Section 3.3 observes.
    let mut phi_true = vec![0f64; k * w];
    for t in 0..k {
        let row = &mut phi_true[t * w..(t + 1) * w];
        let mut sum = 0.0;
        for (wi, slot) in row.iter_mut().enumerate() {
            let shape = (spec.beta_gen * w as f64 * base[wi]).max(1e-3);
            *slot = rng.gamma(shape);
            sum += *slot;
        }
        row.iter_mut().for_each(|x| *x /= sum.max(1e-300));
    }

    // Documents.
    let sigma: f64 = 0.6; // log-normal spread of doc lengths
    let mu_len = spec.mean_doc_len.ln() - 0.5 * sigma * sigma;
    let mut docs: Vec<Vec<(u32, f32)>> = Vec::with_capacity(d);
    let mut counts = vec![0f32; w];
    for _ in 0..d {
        let len = ((mu_len + sigma * rng.normal()).exp().round() as usize).max(1);
        let theta = rng.dirichlet_sym(spec.alpha_gen, k);
        counts.fill(0.0);
        for _ in 0..len {
            let t = rng.discrete(&theta);
            let wi = rng.discrete(&phi_true[t * w..(t + 1) * w]);
            counts[wi] += 1.0;
        }
        let row: Vec<(u32, f32)> = counts
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0.0)
            .map(|(i, &c)| (i as u32, c))
            .collect();
        docs.push(row);
    }

    SynthCorpus {
        spec: spec.clone(),
        corpus: Csr::from_docs(w, &docs),
        phi_true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_shape() {
        let s = generate(&SynthSpec::tiny(1));
        assert_eq!(s.corpus.docs(), 120);
        assert_eq!(s.corpus.w, 200);
        assert!(s.corpus.nnz() > 0);
    }

    #[test]
    fn doc_length_matches_mean() {
        let spec = SynthSpec { docs: 400, ..SynthSpec::tiny(2) };
        let s = generate(&spec);
        let mean = s.corpus.tokens() / s.corpus.docs() as f64;
        assert!(
            (mean - spec.mean_doc_len).abs() < 0.25 * spec.mean_doc_len,
            "mean doc len {mean} vs {}",
            spec.mean_doc_len
        );
    }

    #[test]
    fn word_marginal_is_heavy_tailed() {
        // top 10% of words should carry well over half the tokens
        // (power-law premise of §3.3)
        let spec = SynthSpec { docs: 300, ..SynthSpec::tiny(3) };
        let s = generate(&spec);
        let mut wt = s.corpus.word_tokens();
        wt.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let total: f64 = wt.iter().sum();
        let top10: f64 = wt.iter().take(wt.len() / 10).sum();
        assert!(top10 / total > 0.5, "top-10% share {}", top10 / total);
    }

    #[test]
    fn phi_rows_are_distributions() {
        let s = generate(&SynthSpec::tiny(4));
        let w = s.spec.vocab;
        for t in 0..s.spec.topics {
            let sum: f64 = s.phi_true[t * w..(t + 1) * w].iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&SynthSpec::tiny(9));
        let b = generate(&SynthSpec::tiny(9));
        assert_eq!(a.corpus.col, b.corpus.col);
        assert_eq!(a.corpus.val, b.corpus.val);
    }

    #[test]
    fn table_presets_scale() {
        let spec = SynthSpec::from_table(&TABLE3[0], 100, 10, 0);
        assert_eq!(spec.name, "enron-sim");
        assert_eq!(spec.docs, 398);
        assert!((spec.mean_doc_len - 160.86).abs() < 1.0);
    }
}
