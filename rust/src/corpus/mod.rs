//! Corpus substrate: sparse doc–word storage, UCI bag-of-words I/O,
//! vocabulary truncation, train/heldout splitting, and mini-batch
//! streaming — everything between raw data and the inference engines.

pub mod bow;
pub mod csr;
pub mod split;
pub mod stream;
pub mod vocab;

pub use csr::Csr;
pub use split::{split_tokens, Split};
pub use stream::{shard_ranges, MiniBatch, MiniBatchStream};
pub use vocab::Vocab;
