//! Mini-batch streaming (§2.1): the online algorithms treat the corpus as
//! a stream of M mini-batches sized by a *non-zero-entry budget* — the
//! paper fixes NNZ ≈ 45,000 per mini-batch so each fits a 2 GB processor.
//!
//! A mini-batch is a contiguous document range (documents arrive in stream
//! order); `MiniBatchStream` yields `Csr` slices plus their provenance so
//! the coordinator can shard them over workers.

use crate::corpus::csr::Csr;

/// One mini-batch: a doc-range slice of the source corpus.
pub struct MiniBatch {
    /// index of this batch (0-based; the paper's m)
    pub index: usize,
    /// [lo, hi) document range in the source corpus
    pub doc_range: std::ops::Range<usize>,
    pub data: Csr,
}

/// Streams a corpus as mini-batches with at most `nnz_budget` non-zeros
/// each (always at least one document per batch).
pub struct MiniBatchStream<'a> {
    corpus: &'a Csr,
    nnz_budget: usize,
    next_doc: usize,
    next_index: usize,
}

impl<'a> MiniBatchStream<'a> {
    pub fn new(corpus: &'a Csr, nnz_budget: usize) -> Self {
        assert!(nnz_budget > 0, "nnz budget must be positive");
        MiniBatchStream { corpus, nnz_budget, next_doc: 0, next_index: 0 }
    }

    /// Resume the deterministic stream at an exact cursor captured from
    /// a checkpoint (Contract 6): the next batch starts at document
    /// `next_doc` and takes index `next_index`. Because batching is a
    /// pure function of the corpus and the budget, the resumed stream
    /// yields exactly the suffix a fresh stream would — without
    /// re-slicing the already-trained prefix.
    pub fn resume(
        corpus: &'a Csr,
        nnz_budget: usize,
        next_doc: usize,
        next_index: usize,
    ) -> Self {
        assert!(nnz_budget > 0, "nnz budget must be positive");
        assert!(next_doc <= corpus.docs(), "resume cursor past the corpus");
        MiniBatchStream { corpus, nnz_budget, next_doc, next_index }
    }

    /// Number of batches this stream will yield (without consuming it).
    pub fn count(corpus: &Csr, nnz_budget: usize) -> usize {
        MiniBatchStream::new(corpus, nnz_budget).map(|_| 1).sum()
    }
}

impl<'a> Iterator for MiniBatchStream<'a> {
    type Item = MiniBatch;

    fn next(&mut self) -> Option<MiniBatch> {
        let d = self.corpus.docs();
        if self.next_doc >= d {
            return None;
        }
        let lo = self.next_doc;
        let base = self.corpus.row_ptr[lo] as usize;
        let mut hi = lo;
        while hi < d {
            let nnz_through = self.corpus.row_ptr[hi + 1] as usize - base;
            if nnz_through > self.nnz_budget && hi > lo {
                break;
            }
            hi += 1;
            if nnz_through > self.nnz_budget {
                break; // single huge doc: take it alone
            }
        }
        self.next_doc = hi;
        let index = self.next_index;
        self.next_index += 1;
        Some(MiniBatch {
            index,
            doc_range: lo..hi,
            data: self.corpus.slice_docs(lo, hi),
        })
    }
}

/// Even contiguous sharding of `docs` documents over `n` workers:
/// returns the `[lo, hi)` ranges (some possibly empty when docs < n).
pub fn shard_ranges(docs: usize, n: usize) -> Vec<std::ops::Range<usize>> {
    assert!(n > 0);
    let base = docs / n;
    let extra = docs % n;
    let mut out = Vec::with_capacity(n);
    let mut lo = 0;
    for i in 0..n {
        let len = base + usize::from(i < extra);
        out.push(lo..lo + len);
        lo += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn corpus(rng: &mut Rng, d: usize, w: usize) -> Csr {
        let docs: Vec<Vec<(u32, f32)>> = (0..d)
            .map(|_| {
                (0..rng.range(1, 8))
                    .map(|_| (rng.below(w) as u32, 1.0))
                    .collect()
            })
            .collect();
        Csr::from_docs(w, &docs)
    }

    #[test]
    fn batches_cover_corpus_in_order() {
        check("stream covers corpus", 30, |rng| {
            let d = rng.range(1, 60);
            let c = corpus(rng, d, 20);
            let budget = rng.range(1, 30);
            let mut next = 0;
            let mut nnz = 0;
            for (i, mb) in MiniBatchStream::new(&c, budget).enumerate() {
                assert_eq!(mb.index, i);
                assert_eq!(mb.doc_range.start, next);
                assert!(mb.doc_range.end > mb.doc_range.start);
                next = mb.doc_range.end;
                nnz += mb.data.nnz();
            }
            assert_eq!(next, c.docs());
            assert_eq!(nnz, c.nnz());
        });
    }

    #[test]
    fn respects_budget_except_single_doc() {
        check("stream respects budget", 30, |rng| {
            let d = rng.range(1, 60);
            let c = corpus(rng, d, 20);
            let budget = rng.range(2, 25);
            for mb in MiniBatchStream::new(&c, budget) {
                if mb.doc_range.len() > 1 {
                    assert!(mb.data.nnz() <= budget);
                }
            }
        });
    }

    #[test]
    fn resumed_stream_yields_the_exact_suffix() {
        check("stream resume suffix", 30, |rng| {
            let d = rng.range(1, 60);
            let c = corpus(rng, d, 20);
            let budget = rng.range(1, 30);
            let all: Vec<MiniBatch> = MiniBatchStream::new(&c, budget).collect();
            let skip = rng.below(all.len() + 1);
            let cursor_doc = all
                .get(skip)
                .map_or(c.docs(), |mb| mb.doc_range.start);
            let resumed: Vec<MiniBatch> =
                MiniBatchStream::resume(&c, budget, cursor_doc, skip).collect();
            assert_eq!(resumed.len(), all.len() - skip);
            for (a, b) in resumed.iter().zip(&all[skip..]) {
                assert_eq!(a.index, b.index);
                assert_eq!(a.doc_range, b.doc_range);
                assert_eq!(a.data.nnz(), b.data.nnz());
            }
        });
    }

    #[test]
    fn count_matches_iteration() {
        let mut rng = Rng::new(11);
        let c = corpus(&mut rng, 40, 20);
        assert_eq!(
            MiniBatchStream::count(&c, 10),
            MiniBatchStream::new(&c, 10).count()
        );
    }

    #[test]
    fn shards_are_even_partition() {
        check("shards partition", 50, |rng| {
            let docs = rng.below(100);
            let n = rng.range(1, 12);
            let rs = shard_ranges(docs, n);
            assert_eq!(rs.len(), n);
            assert_eq!(rs[0].start, 0);
            assert_eq!(rs[n - 1].end, docs);
            for w in rs.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            let (min, max) = rs
                .iter()
                .fold((usize::MAX, 0), |(a, b), r| (a.min(r.len()), b.max(r.len())));
            assert!(max - min <= 1, "imbalanced shards");
        });
    }
}
