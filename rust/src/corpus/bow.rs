//! UCI bag-of-words format reader/writer.
//!
//! The paper's four corpora (ENRON, NYTIMES, PUBMED from the UCI ML
//! repository, plus WIKIPEDIA) ship in this format:
//!
//! ```text
//! D
//! W
//! NNZ
//! docId wordId count      (both ids 1-based)
//! ...
//! ```
//!
//! An optional companion `vocab.<name>.txt` lists one word per line. The
//! loader is tolerant of blank lines and `#` comments so the bundled
//! sample corpora can be annotated.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::corpus::csr::Csr;
use crate::corpus::vocab::Vocab;

/// Load a UCI bag-of-words file into CSR form.
pub fn read_uci(path: &Path) -> Result<Csr> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    read_uci_from(BufReader::new(file))
}

/// Load from any reader (exposed for tests).
pub fn read_uci_from(reader: impl BufRead) -> Result<Csr> {
    let mut lines = reader.lines().enumerate().filter_map(|(ln, l)| {
        let l = match l {
            Ok(l) => l,
            Err(e) => return Some(Err((ln, e))),
        };
        let t = l.trim().to_string();
        if t.is_empty() || t.starts_with('#') {
            None
        } else {
            Some(Ok((ln, t)))
        }
    });

    let mut next_header = |name: &str| -> Result<usize> {
        match lines.next() {
            Some(Ok((ln, t))) => t
                .parse::<usize>()
                .with_context(|| format!("line {}: bad {name} header '{t}'", ln + 1)),
            Some(Err((ln, e))) => bail!("line {}: {e}", ln + 1),
            None => bail!("missing {name} header"),
        }
    };
    let d = next_header("D")?;
    let w = next_header("W")?;
    let nnz = next_header("NNZ")?;

    let mut docs: Vec<Vec<(u32, f32)>> = vec![Vec::new(); d];
    let mut seen = 0usize;
    for item in lines {
        let (ln, t) = match item {
            Ok(v) => v,
            Err((ln, e)) => bail!("line {}: {e}", ln + 1),
        };
        let mut it = t.split_whitespace();
        let (Some(ds), Some(ws), Some(cs)) = (it.next(), it.next(), it.next())
        else {
            bail!("line {}: expected 'doc word count', got '{t}'", ln + 1);
        };
        let doc: usize = ds.parse().with_context(|| format!("line {}", ln + 1))?;
        let word: usize = ws.parse().with_context(|| format!("line {}", ln + 1))?;
        let count: f32 = cs.parse().with_context(|| format!("line {}", ln + 1))?;
        if doc == 0 || doc > d {
            bail!("line {}: doc id {doc} out of 1..={d}", ln + 1);
        }
        if word == 0 || word > w {
            bail!("line {}: word id {word} out of 1..={w}", ln + 1);
        }
        docs[doc - 1].push((word as u32 - 1, count));
        seen += 1;
    }
    if seen != nnz {
        bail!("NNZ header says {nnz} but found {seen} entries");
    }
    Ok(Csr::from_docs(w, &docs))
}

/// Write CSR to UCI bag-of-words format.
pub fn write_uci(corpus: &Csr, mut out: impl Write) -> Result<()> {
    writeln!(out, "{}", corpus.docs())?;
    writeln!(out, "{}", corpus.w)?;
    writeln!(out, "{}", corpus.nnz())?;
    for doc in 0..corpus.docs() {
        let (ws, vs) = corpus.row(doc);
        for (&wid, &c) in ws.iter().zip(vs) {
            writeln!(out, "{} {} {}", doc + 1, wid + 1, c as u64)?;
        }
    }
    Ok(())
}

/// Write corpus + vocab to `<dir>/docword.<name>.txt` and
/// `<dir>/vocab.<name>.txt` (the UCI layout).
pub fn write_uci_pair(dir: &Path, name: &str, corpus: &Csr, vocab: &Vocab) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let dw = std::fs::File::create(dir.join(format!("docword.{name}.txt")))?;
    write_uci(corpus, std::io::BufWriter::new(dw))?;
    let mut vf = std::fs::File::create(dir.join(format!("vocab.{name}.txt")))?;
    for i in 0..vocab.len() {
        writeln!(vf, "{}", vocab.word(i))?;
    }
    Ok(())
}

/// Read a one-word-per-line vocabulary file.
pub fn read_vocab(path: &Path) -> Result<Vocab> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("open {}", path.display()))?;
    Ok(Vocab::new(text.lines().map(|l| l.trim().to_string()).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const SAMPLE: &str = "# tiny corpus\n3\n4\n5\n1 1 2\n1 3 1\n2 2 4\n3 2 1\n3 4 2\n";

    #[test]
    fn parse_roundtrip() {
        let c = read_uci_from(Cursor::new(SAMPLE)).unwrap();
        assert_eq!((c.docs(), c.w, c.nnz()), (3, 4, 5));
        assert_eq!(c.row(0).0, &[0, 2]);
        assert_eq!(c.tokens(), 10.0);

        let mut buf = Vec::new();
        write_uci(&c, &mut buf).unwrap();
        let c2 = read_uci_from(Cursor::new(buf)).unwrap();
        assert_eq!(c2.row_ptr, c.row_ptr);
        assert_eq!(c2.col, c.col);
        assert_eq!(c2.val, c.val);
    }

    #[test]
    fn rejects_bad_nnz() {
        let bad = "1\n2\n99\n1 1 1\n";
        assert!(read_uci_from(Cursor::new(bad)).unwrap_err().to_string().contains("NNZ"));
    }

    #[test]
    fn rejects_out_of_range_ids() {
        let bad = "1\n2\n1\n1 3 1\n";
        assert!(read_uci_from(Cursor::new(bad)).is_err());
        let bad = "1\n2\n1\n2 1 1\n";
        assert!(read_uci_from(Cursor::new(bad)).is_err());
        let bad = "1\n2\n1\n0 1 1\n";
        assert!(read_uci_from(Cursor::new(bad)).is_err());
    }

    #[test]
    fn file_pair_roundtrip() {
        let dir = std::env::temp_dir().join("pobp_bow_test");
        let c = read_uci_from(Cursor::new(SAMPLE)).unwrap();
        let v = Vocab::synthetic(4);
        write_uci_pair(&dir, "tiny", &c, &v).unwrap();
        let c2 = read_uci(&dir.join("docword.tiny.txt")).unwrap();
        assert_eq!(c2.nnz(), c.nnz());
        let v2 = read_vocab(&dir.join("vocab.tiny.txt")).unwrap();
        assert_eq!(v2.len(), 4);
        assert_eq!(v2.word(1), "w0001");
        std::fs::remove_dir_all(&dir).ok();
    }
}
