//! Vocabulary: id ↔ string mapping and frequency-based truncation.
//!
//! The paper (§4, following Hoffman et al.) truncates each corpus to a
//! fixed vocabulary of the most frequent words — e.g. PUBMED from 141,043
//! to 6,902 words — while keeping >40% of tokens. `truncate_by_tokens`
//! reproduces that preprocessing step.

use crate::corpus::csr::Csr;

/// Word id ↔ string table.
#[derive(Clone, Debug, Default)]
pub struct Vocab {
    words: Vec<String>,
}

impl Vocab {
    pub fn new(words: Vec<String>) -> Vocab {
        Vocab { words }
    }

    /// Synthetic vocabulary "w0000", "w0001", ...
    pub fn synthetic(n: usize) -> Vocab {
        Vocab {
            words: (0..n).map(|i| format!("w{i:04}")).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn word(&self, id: usize) -> &str {
        &self.words[id]
    }
}

/// Result of a vocabulary truncation: the remapped corpus, the kept
/// vocabulary, and the token-retention ratio (paper: >40% for PUBMED).
pub struct Truncation {
    pub corpus: Csr,
    pub vocab: Vocab,
    pub kept_words: usize,
    pub token_retention: f64,
    /// old word id -> new id (u32::MAX = dropped)
    pub remap: Vec<u32>,
}

/// Keep the `keep` most frequent words (by token count), remap ids densely
/// and drop all other entries — the paper's fixed-truncated-vocabulary
/// preprocessing (§4).
pub fn truncate_by_tokens(corpus: &Csr, vocab: &Vocab, keep: usize) -> Truncation {
    let wt = corpus.word_tokens();
    let keep = keep.min(corpus.w);
    let order = crate::util::partial_sort::top_k_desc(
        &wt.iter().map(|&t| t as f32).collect::<Vec<_>>(),
        keep,
    );
    let mut remap = vec![u32::MAX; corpus.w];
    let mut words = Vec::with_capacity(keep);
    for (new_id, &old_id) in order.iter().enumerate() {
        remap[old_id as usize] = new_id as u32;
        words.push(if vocab.is_empty() {
            format!("w{old_id:04}")
        } else {
            vocab.word(old_id as usize).to_string()
        });
    }

    let total_tokens = corpus.tokens();
    let mut docs: Vec<Vec<(u32, f32)>> = Vec::with_capacity(corpus.docs());
    let mut kept_tokens = 0f64;
    for d in 0..corpus.docs() {
        let (ws, vs) = corpus.row(d);
        let mut row = Vec::with_capacity(ws.len());
        for (&wid, &c) in ws.iter().zip(vs) {
            let nid = remap[wid as usize];
            if nid != u32::MAX {
                row.push((nid, c));
                kept_tokens += c as f64;
            }
        }
        docs.push(row);
    }
    Truncation {
        corpus: Csr::from_docs(keep, &docs),
        vocab: Vocab::new(words),
        kept_words: keep,
        token_retention: if total_tokens > 0.0 {
            kept_tokens / total_tokens
        } else {
            0.0
        },
        remap,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Csr {
        // word 1 is heavy (9 tokens), word 0 medium (3), words 2,3 light
        Csr::from_docs(
            4,
            &[
                vec![(0, 1.0), (1, 4.0)],
                vec![(1, 5.0), (2, 1.0)],
                vec![(0, 2.0), (3, 1.0)],
                vec![(3, 1.0)],
            ],
        )
    }

    #[test]
    fn keeps_most_frequent() {
        let t = truncate_by_tokens(&corpus(), &Vocab::default(), 2);
        assert_eq!(t.kept_words, 2);
        assert_eq!(t.corpus.w, 2);
        // word 1 -> id 0, word 0 -> id 1
        assert_eq!(t.remap[1], 0);
        assert_eq!(t.remap[0], 1);
        assert_eq!(t.remap[2], u32::MAX);
        // retention = (9 + 3) / 15
        assert!((t.token_retention - 12.0 / 15.0).abs() < 1e-12);
        assert_eq!(t.corpus.tokens(), 12.0);
        // doc 3 had only dropped words -> empty row survives as a doc
        assert_eq!(t.corpus.docs(), 4);
        assert_eq!(t.corpus.row(3).0.len(), 0);
    }

    #[test]
    fn truncate_noop_when_keep_exceeds_w() {
        let c = corpus();
        let t = truncate_by_tokens(&c, &Vocab::default(), 100);
        assert_eq!(t.kept_words, 4);
        assert_eq!(t.corpus.tokens(), c.tokens());
        assert!((t.token_retention - 1.0).abs() < 1e-12);
    }

    #[test]
    fn synthetic_vocab_names() {
        let v = Vocab::synthetic(3);
        assert_eq!(v.len(), 3);
        assert_eq!(v.word(2), "w0002");
    }
}
