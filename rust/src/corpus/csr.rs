//! Document–word matrix in CSR form (documents = rows).
//!
//! The paper's x_{W×D} is extremely sparse (NNZ ≈ η·W·D with η ≪ 1,
//! §3.2.2); every engine in this crate iterates the non-zeros through this
//! structure. Counts are `f32` (the BP/VB family treats them as reals; the
//! Gibbs family reads them back as integers).

/// Sparse doc–word count matrix, rows = documents.
#[derive(Clone, Debug, Default)]
pub struct Csr {
    /// number of vocabulary words (columns)
    pub w: usize,
    /// row offsets, len = docs + 1
    pub row_ptr: Vec<u32>,
    /// word ids, len = nnz
    pub col: Vec<u32>,
    /// counts, len = nnz
    pub val: Vec<f32>,
}

impl Csr {
    /// Build from per-document (word, count) lists. Entries with zero or
    /// negative count are dropped; duplicate words within a doc are merged.
    pub fn from_docs(w: usize, docs: &[Vec<(u32, f32)>]) -> Csr {
        let mut row_ptr = Vec::with_capacity(docs.len() + 1);
        let mut col = Vec::new();
        let mut val = Vec::new();
        row_ptr.push(0u32);
        let mut scratch: Vec<(u32, f32)> = Vec::new();
        for doc in docs {
            scratch.clear();
            scratch.extend(doc.iter().copied().filter(|&(wid, c)| {
                assert!((wid as usize) < w, "word id {wid} out of range {w}");
                c > 0.0
            }));
            scratch.sort_unstable_by_key(|&(wid, _)| wid);
            let mut i = 0;
            while i < scratch.len() {
                let (wid, mut c) = scratch[i];
                let mut j = i + 1;
                while j < scratch.len() && scratch[j].0 == wid {
                    c += scratch[j].1;
                    j += 1;
                }
                col.push(wid);
                val.push(c);
                i = j;
            }
            row_ptr.push(col.len() as u32);
        }
        Csr { w, row_ptr, col, val }
    }

    #[inline]
    pub fn docs(&self) -> usize {
        self.row_ptr.len() - 1
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.col.len()
    }

    /// Total token count (sum of all values).
    pub fn tokens(&self) -> f64 {
        self.val.iter().map(|&v| v as f64).sum()
    }

    /// Sparsity η = NNZ / (W · D) of Table 2's complexity analysis.
    pub fn eta(&self) -> f64 {
        if self.docs() == 0 || self.w == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.w as f64 * self.docs() as f64)
    }

    /// (word ids, counts) of document `d`.
    #[inline]
    pub fn row(&self, d: usize) -> (&[u32], &[f32]) {
        let lo = self.row_ptr[d] as usize;
        let hi = self.row_ptr[d + 1] as usize;
        (&self.col[lo..hi], &self.val[lo..hi])
    }

    /// Half-open nnz index range of document `d`.
    #[inline]
    pub fn row_range(&self, d: usize) -> std::ops::Range<usize> {
        self.row_ptr[d] as usize..self.row_ptr[d + 1] as usize
    }

    /// A new CSR holding documents `[lo, hi)` (columns unchanged).
    pub fn slice_docs(&self, lo: usize, hi: usize) -> Csr {
        assert!(lo <= hi && hi <= self.docs());
        let base = self.row_ptr[lo];
        let row_ptr = self.row_ptr[lo..=hi].iter().map(|&p| p - base).collect();
        let span = self.row_ptr[lo] as usize..self.row_ptr[hi] as usize;
        Csr {
            w: self.w,
            row_ptr,
            col: self.col[span.clone()].to_vec(),
            val: self.val[span].to_vec(),
        }
    }

    /// Per-word document frequency (number of docs containing each word).
    pub fn doc_freq(&self) -> Vec<u32> {
        let mut df = vec![0u32; self.w];
        for &wid in &self.col {
            df[wid as usize] += 1;
        }
        df
    }

    /// Per-word token counts.
    pub fn word_tokens(&self) -> Vec<f64> {
        let mut wt = vec![0f64; self.w];
        for (&wid, &c) in self.col.iter().zip(&self.val) {
            wt[wid as usize] += c as f64;
        }
        wt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        Csr::from_docs(
            5,
            &[
                vec![(0, 2.0), (3, 1.0)],
                vec![],
                vec![(1, 4.0), (1, 1.0), (4, 3.0), (2, 0.0)],
            ],
        )
    }

    #[test]
    fn shape_and_counts() {
        let m = sample();
        assert_eq!(m.docs(), 3);
        assert_eq!(m.nnz(), 4); // dup merged, zero dropped
        assert_eq!(m.tokens(), 11.0); // 2 + 1 + (4+1) + 3
        assert!((m.eta() - 4.0 / 15.0).abs() < 1e-12);
    }

    #[test]
    fn rows_sorted_and_merged() {
        let m = sample();
        let (w, v) = m.row(2);
        assert_eq!(w, &[1, 4]);
        assert_eq!(v, &[5.0, 3.0]);
        assert_eq!(m.row(1).0.len(), 0);
    }

    #[test]
    fn slice_preserves_rows() {
        let m = sample();
        let s = m.slice_docs(1, 3);
        assert_eq!(s.docs(), 2);
        assert_eq!(s.row(1).0, m.row(2).0);
        assert_eq!(s.row(1).1, m.row(2).1);
        assert_eq!(s.nnz(), 2);
        let empty = m.slice_docs(1, 1);
        assert_eq!(empty.docs(), 0);
        assert_eq!(empty.nnz(), 0);
    }

    #[test]
    fn doc_freq_and_word_tokens() {
        let m = sample();
        assert_eq!(m.doc_freq(), vec![1, 1, 0, 1, 1]);
        assert_eq!(m.word_tokens(), vec![2.0, 5.0, 0.0, 1.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_bad_word_id() {
        Csr::from_docs(2, &[vec![(2, 1.0)]]);
    }
}
