//! 80/20 per-document token split for predictive perplexity (Eq. 20).
//!
//! Following the paper (§4): "we randomly partition each document into 80%
//! and 20% subsets"; θ is folded in on the 80% side with φ fixed, and
//! perplexity is computed on the 20% side. The split is at token
//! granularity, deterministic given the seed.

use crate::corpus::csr::Csr;
use crate::util::rng::Rng;

/// A train/heldout pair over the same vocabulary and document set.
pub struct Split {
    pub train: Csr,
    pub heldout: Csr,
}

/// Split each document's tokens into train (`1 - heldout_frac`) and
/// heldout (`heldout_frac`) parts. Counts are integral: each of the
/// `x_{w,d}` tokens is assigned independently, so expectations match the
/// fraction while tiny documents still land somewhere sensible. Documents
/// with a single token keep it on the train side.
pub fn split_tokens(corpus: &Csr, heldout_frac: f64, seed: u64) -> Split {
    assert!((0.0..1.0).contains(&heldout_frac));
    let mut rng = Rng::new(seed);
    let mut train_docs = Vec::with_capacity(corpus.docs());
    let mut held_docs = Vec::with_capacity(corpus.docs());
    for d in 0..corpus.docs() {
        let (ws, vs) = corpus.row(d);
        let doc_tokens: f64 = vs.iter().map(|&v| v as f64).sum();
        let mut tr = Vec::with_capacity(ws.len());
        let mut he = Vec::new();
        for (&wid, &c) in ws.iter().zip(vs) {
            let c = c.round() as u32;
            let mut h = 0u32;
            for _ in 0..c {
                if rng.f64() < heldout_frac {
                    h += 1;
                }
            }
            // keep at least one token in train for one-token docs
            if doc_tokens <= 1.0 {
                h = 0;
            }
            if c > h {
                tr.push((wid, (c - h) as f32));
            }
            if h > 0 {
                he.push((wid, h as f32));
            }
        }
        train_docs.push(tr);
        held_docs.push(he);
    }
    Split {
        train: Csr::from_docs(corpus.w, &train_docs),
        heldout: Csr::from_docs(corpus.w, &held_docs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn random_corpus(rng: &mut Rng) -> Csr {
        let d = rng.range(1, 20);
        let w = rng.range(2, 30);
        let docs: Vec<Vec<(u32, f32)>> = (0..d)
            .map(|_| {
                (0..rng.below(w))
                    .map(|_| (rng.below(w) as u32, rng.range(1, 6) as f32))
                    .collect()
            })
            .collect();
        Csr::from_docs(w, &docs)
    }

    #[test]
    fn token_mass_is_conserved() {
        check("split conserves tokens", 50, |rng| {
            let c = random_corpus(rng);
            let s = split_tokens(&c, 0.2, rng.next_u64());
            assert_eq!(
                (s.train.tokens() + s.heldout.tokens()) as u64,
                c.tokens() as u64
            );
            assert_eq!(s.train.docs(), c.docs());
            assert_eq!(s.heldout.docs(), c.docs());
        });
    }

    #[test]
    fn fraction_approximately_respected() {
        let mut rng = Rng::new(1);
        let docs: Vec<Vec<(u32, f32)>> =
            (0..200).map(|_| vec![(rng.below(50) as u32, 20.0)]).collect();
        let c = Csr::from_docs(50, &docs);
        let s = split_tokens(&c, 0.2, 7);
        let frac = s.heldout.tokens() / c.tokens();
        assert!((frac - 0.2).abs() < 0.03, "got {frac}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(2);
        let c = random_corpus(&mut rng);
        let a = split_tokens(&c, 0.2, 42);
        let b = split_tokens(&c, 0.2, 42);
        assert_eq!(a.train.val, b.train.val);
        assert_eq!(a.heldout.col, b.heldout.col);
    }

    #[test]
    fn single_token_doc_stays_in_train() {
        let c = Csr::from_docs(3, &[vec![(1, 1.0)]]);
        for seed in 0..20 {
            let s = split_tokens(&c, 0.9, seed);
            assert_eq!(s.train.tokens(), 1.0);
            assert_eq!(s.heldout.tokens(), 0.0);
        }
    }
}
