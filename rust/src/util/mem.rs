//! Memory accounting: process RSS (Linux) + analytic per-processor model
//! bytes (Table 5 of the paper).
//!
//! The paper reports the memory each *processor* would use on the cluster.
//! We run N logical workers in one process, so Table 5 is regenerated from
//! the same analytic accounting the paper's Table 2 derives — exact byte
//! counts of the matrices each algorithm keeps resident — while `rss_bytes`
//! provides the real, whole-process sanity check.

use std::fs;

/// Current resident set size of this process in bytes (0 if unavailable).
pub fn rss_bytes() -> usize {
    let Ok(status) = fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: usize = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Analytic per-processor resident bytes for each algorithm family
/// (Table 2's memory column, instantiated).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemModel {
    /// number of documents resident at once (whole corpus / N for batch,
    /// mini-batch shard for online)
    pub docs_resident: usize,
    /// non-zero (doc, word) pairs resident at once
    pub nnz_resident: usize,
    /// tokens resident at once (Gibbs stores one topic label per token)
    pub tokens_resident: usize,
    pub k: usize,
    pub w: usize,
}

impl MemModel {
    /// POBP / OBP: per-nnz messages (K f32) + theta (D_m/N x K f32) +
    /// global phi + residual matrix (both K x W f32) + x (nnz * 8 bytes).
    pub fn pobp_bytes(&self) -> usize {
        4 * self.nnz_resident * self.k          // mu
            + 4 * self.docs_resident * self.k   // theta
            + 2 * 4 * self.k * self.w           // phi + r
            + 8 * self.nnz_resident // CSR (word id + count)
    }

    /// Parallel GS family: token topic labels (u32) + ndk (D/N x K u32) +
    /// global nwk (K x W u32) + nk + tokens (doc,word) u32 pairs.
    pub fn pgs_bytes(&self) -> usize {
        4 * self.tokens_resident                // z labels
            + 4 * self.docs_resident * self.k   // ndk
            + 4 * self.k * self.w               // nwk
            + 4 * self.k                        // nk
            + 8 * self.tokens_resident // token stream
    }

    /// Parallel VB: gamma (D/N x K f32) + lambda (K x W f32) + expElogbeta
    /// (K x W f32) + x (nnz * 8).
    pub fn pvb_bytes(&self) -> usize {
        4 * self.docs_resident * self.k
            + 2 * 4 * self.k * self.w
            + 8 * self.nnz_resident
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive_on_linux() {
        assert!(rss_bytes() > 0);
    }

    #[test]
    fn pobp_memory_constant_in_n() {
        // Table 5's headline: POBP resident bytes do not depend on N
        // because the shard size is fixed by the mini-batch, not by D/N.
        let mk = |_n: usize| MemModel {
            docs_resident: 1000, // mini-batch docs
            nnz_resident: 45_000,
            tokens_resident: 0,
            k: 200,
            w: 5000,
        };
        assert_eq!(mk(128).pobp_bytes(), mk(1024).pobp_bytes());
    }

    #[test]
    fn pgs_memory_shrinks_with_n() {
        let mk = |n: usize| MemModel {
            docs_resident: 8_200_000 / n,
            nnz_resident: 0,
            tokens_resident: 737_869_083 / n,
            k: 200,
            w: 5000,
        };
        assert!(mk(1024).pgs_bytes() < mk(128).pgs_bytes());
    }
}
