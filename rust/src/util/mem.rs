//! Memory accounting: process RSS (Linux) + analytic per-processor model
//! bytes (Table 5 of the paper).
//!
//! The paper reports the memory each *processor* would use on the cluster.
//! We run N logical workers in one process, so Table 5 is regenerated from
//! the same analytic accounting the paper's Table 2 derives — exact byte
//! counts of the matrices each algorithm keeps resident — while `rss_bytes`
//! provides the real, whole-process sanity check.

use std::fs;

/// Current resident set size of this process in bytes (0 if unavailable).
pub fn rss_bytes() -> usize {
    let Ok(status) = fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: usize = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Analytic per-processor resident bytes for each algorithm family
/// (Table 2's memory column, instantiated).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemModel {
    /// number of documents resident at once (whole corpus / N for batch,
    /// mini-batch shard for online)
    pub docs_resident: usize,
    /// non-zero (doc, word) pairs resident at once
    pub nnz_resident: usize,
    /// tokens resident at once (Gibbs stores one topic label per token)
    pub tokens_resident: usize,
    pub k: usize,
    pub w: usize,
}

impl MemModel {
    /// POBP / OBP: per-nnz messages (K f32) + theta (D_m/N x K f32) +
    /// global phi + residual matrix (both K x W f32) + x (nnz * 8 bytes).
    pub fn pobp_bytes(&self) -> usize {
        4 * self.nnz_resident * self.k          // mu
            + 4 * self.docs_resident * self.k   // theta
            + 2 * 4 * self.k * self.w           // phi + r
            + 8 * self.nnz_resident // CSR (word id + count)
    }

    /// Bytes of the global φ̂ + r replica one processor keeps resident in
    /// **replicated** storage mode — the `2·4·K·W` term of
    /// [`MemModel::pobp_bytes`], broken out so the two storage modes can
    /// be compared like-for-like.
    pub fn phi_replica_bytes(&self) -> usize {
        2 * 4 * self.k * self.w
    }

    /// Per-processor resident φ̂ bytes in **sharded** storage mode: the
    /// row-aligned owner slice of φ̂ + r (`2·4·ceil(W/N)·K`, the
    /// `OwnerSlices::row_aligned` split) plus the gathered working set
    /// of the current power selection (`4·working_pairs` packed f32
    /// lanes). O(W·K/N) — the model-parallel big-K claim.
    pub fn phi_sharded_bytes(&self, n: usize, working_pairs: usize) -> usize {
        2 * 4 * self.w.div_ceil(n.max(1)) * self.k + 4 * working_pairs
    }

    /// [`MemModel::pobp_bytes`] with the φ̂ replica swapped for the
    /// sharded per-processor slice — what one worker keeps resident when
    /// the coordinator trains with `PhiStorageMode::Sharded`.
    pub fn pobp_sharded_bytes(&self, n: usize, working_pairs: usize) -> usize {
        self.pobp_bytes() - self.phi_replica_bytes()
            + self.phi_sharded_bytes(n, working_pairs)
    }

    /// Parallel GS family: token topic labels (u32) + ndk (D/N x K u32) +
    /// global nwk (K x W u32) + nk + tokens (doc,word) u32 pairs.
    pub fn pgs_bytes(&self) -> usize {
        4 * self.tokens_resident                // z labels
            + 4 * self.docs_resident * self.k   // ndk
            + 4 * self.k * self.w               // nwk
            + 4 * self.k                        // nk
            + 8 * self.tokens_resident // token stream
    }

    /// Parallel VB: gamma (D/N x K f32) + lambda (K x W f32) + expElogbeta
    /// (K x W f32) + x (nnz * 8).
    pub fn pvb_bytes(&self) -> usize {
        4 * self.docs_resident * self.k
            + 2 * 4 * self.k * self.w
            + 8 * self.nnz_resident
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive_on_linux() {
        assert!(rss_bytes() > 0);
    }

    #[test]
    fn pobp_memory_constant_in_n() {
        // Table 5's headline: POBP resident bytes do not depend on N
        // because the shard size is fixed by the mini-batch, not by D/N.
        let mk = |_n: usize| MemModel {
            docs_resident: 1000, // mini-batch docs
            nnz_resident: 45_000,
            tokens_resident: 0,
            k: 200,
            w: 5000,
        };
        assert_eq!(mk(128).pobp_bytes(), mk(1024).pobp_bytes());
    }

    #[test]
    fn sharded_phi_memory_shrinks_as_w_k_over_n() {
        let m = MemModel {
            docs_resident: 1000,
            nnz_resident: 45_000,
            tokens_resident: 0,
            k: 8000,
            w: 141_043,
        };
        // replicated replica is constant in N; sharded slice shrinks
        let mut prev = usize::MAX;
        for n in [1usize, 2, 8, 64, 256] {
            let b = m.phi_sharded_bytes(n, 0);
            assert!(b < prev, "n={n}");
            prev = b;
            // ≈ W·K/N: exact up to the ceil's one-row slack
            let ideal = 2 * 4 * m.k * m.w / n;
            assert!(b >= ideal, "n={n}");
            assert!(b <= ideal + 2 * 4 * m.k, "n={n}: {b} vs {ideal}");
        }
        // n = 1 degenerates to the replica
        assert_eq!(m.phi_sharded_bytes(1, 0), m.phi_replica_bytes());
        // the working set rides on top
        assert_eq!(
            m.phi_sharded_bytes(8, 1000) - m.phi_sharded_bytes(8, 0),
            4 * 1000
        );
        // whole-worker accounting: sharded strictly below replicated
        assert!(m.pobp_sharded_bytes(8, 45_000) < m.pobp_bytes());
    }

    #[test]
    fn pgs_memory_shrinks_with_n() {
        let mk = |n: usize| MemModel {
            docs_resident: 8_200_000 / n,
            nnz_resident: 0,
            tokens_resident: 737_869_083 / n,
            k: 200,
            w: 5000,
        };
        assert!(mk(1024).pgs_bytes() < mk(128).pgs_bytes());
    }
}
