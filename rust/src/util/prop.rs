//! Tiny property-test driver (offline substitute for `proptest`).
//!
//! Runs a predicate over many seeded random cases; on failure it reports
//! the failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! use pobp::util::prop::check;
//! check("sum is commutative", 200, |rng| {
//!     let (a, b) = (rng.f64(), rng.f64());
//!     assert!((a + b - (b + a)).abs() < 1e-12);
//! });
//! ```
//!
//! There is no shrinking; cases are kept small by construction instead.

use crate::util::rng::Rng;

/// Base seed; override with `POBP_PROP_SEED` to replay a failure.
fn base_seed() -> u64 {
    std::env::var("POBP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `cases` seeded cases of `f`. Panics (with the failing seed) if any
/// case panics.
pub fn check(name: &str, cases: u64, f: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    let base = base_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at seed {seed} \
                 (replay: POBP_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("below stays in range", 100, |rng| {
            let n = rng.range(1, 50);
            assert!(rng.below(n) < n);
        });
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn failing_property_reports_seed() {
        check("always fails", 3, |_| panic!("boom"));
    }
}
