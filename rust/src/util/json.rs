//! Minimal JSON parser + writer (offline substitute for `serde_json`).
//!
//! Used to read `artifacts/manifest.json` and to write run records /
//! results. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (not needed by any of our files).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected , or ] at {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u")?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                }
                Some(_) => {
                    // copy a run of plain utf8 bytes
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"format":"hlo-text","beta":0.01,
            "entries":[{"file":"a.hlo.txt","d":64,"w":512,"k":50,
                        "args":["x[d,w]"],"ok":true,"none":null}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("d").unwrap().as_usize(), Some(64));
        assert_eq!(e.get("ok"), Some(&Json::Bool(true)));
        // reparse the printed form
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_numbers_and_escapes() {
        let v = Json::parse(r#"[-1.5e3, 0.25, "a\nbA"]"#).unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert_eq!(a[1].as_f64(), Some(0.25));
        assert_eq!(a[2].as_str(), Some("a\nbA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn builder_helpers() {
        let j = Json::obj(vec![
            ("k", Json::from(50usize)),
            ("name", Json::from("pobp")),
            ("xs", Json::from(vec![1.0, 2.0])),
        ]);
        assert_eq!(j.get("k").unwrap().as_usize(), Some(50));
        assert_eq!(j.to_string(), r#"{"k":50,"name":"pobp","xs":[1,2]}"#);
    }
}
