//! Wall-clock timing helpers shared by the engines, the bench harness and
//! the metrics layer.

use std::time::Instant;

/// A simple stopwatch accumulating named segments.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn total_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous `lap` (or construction).
    pub fn lap_secs(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Human format for seconds: "123ms", "4.56s", "2m03s".
pub fn fmt_secs(s: f64) -> String {
    if s < 1.0 {
        format!("{:.0}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        format!("{}m{:02.0}s", (s / 60.0) as u64, s % 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value_and_duration() {
        let (v, dt) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn lap_resets() {
        let mut sw = Stopwatch::new();
        let a = sw.lap_secs();
        let b = sw.lap_secs();
        assert!(a >= 0.0 && b >= 0.0);
        assert!(sw.total_secs() >= a);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_secs(0.1234), "123ms");
        assert_eq!(fmt_secs(4.561), "4.56s");
        assert_eq!(fmt_secs(123.0), "2m03s");
    }
}
