//! Partial-sort / top-k selection (Fig. 4 lines 12–13, 27–28).
//!
//! The paper selects power words and power topics with a *partial sort*
//! because the full order of the tail is irrelevant. `top_k_desc` is
//! `O(n + k log k)`: a quickselect partition (`select_nth_unstable_by`)
//! followed by sorting only the head. This is the coordinator's hot
//! selection primitive, called once per (mini-batch, iteration).

/// Indices of the `k` largest values of `vals`, sorted descending by value.
/// Ties broken by lower index for determinism. `k` is clamped to `len`.
pub fn top_k_desc(vals: &[f32], k: usize) -> Vec<u32> {
    let n = vals.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..n as u32).collect();
    let cmp = |&a: &u32, &b: &u32| {
        let (va, vb) = (vals[a as usize], vals[b as usize]);
        vb.partial_cmp(&va)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    };
    if k < n {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_unstable_by(cmp);
    idx
}

/// Like [`top_k_desc`] but over a strided slice: selects among
/// `vals[offset + i*stride]` for `i in 0..count`. Used for per-word topic
/// selection on the row-major `(W, K)` residual matrix without copying.
pub fn top_k_desc_strided(
    vals: &[f32],
    offset: usize,
    stride: usize,
    count: usize,
    k: usize,
) -> Vec<u32> {
    let k = k.min(count);
    if k == 0 {
        return Vec::new();
    }
    let get = |i: u32| vals[offset + i as usize * stride];
    let mut idx: Vec<u32> = (0..count as u32).collect();
    let cmp = |&a: &u32, &b: &u32| {
        get(b)
            .partial_cmp(&get(a))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    };
    if k < count {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_unstable_by(cmp);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_largest() {
        let v = [3.0f32, 9.0, 1.0, 7.0, 5.0];
        assert_eq!(top_k_desc(&v, 3), vec![1, 3, 4]);
    }

    #[test]
    fn k_clamped_and_zero() {
        let v = [1.0f32, 2.0];
        assert_eq!(top_k_desc(&v, 10), vec![1, 0]);
        assert!(top_k_desc(&v, 0).is_empty());
        assert!(top_k_desc(&[], 3).is_empty());
    }

    #[test]
    fn ties_break_by_index() {
        let v = [5.0f32, 5.0, 5.0, 5.0];
        assert_eq!(top_k_desc(&v, 2), vec![0, 1]);
    }

    #[test]
    fn matches_full_sort_on_random_input() {
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..50 {
            let n = rng.range(1, 200);
            let v: Vec<f32> = (0..n).map(|_| rng.f32() * 100.0).collect();
            let k = rng.below(n + 1);
            let got = top_k_desc(&v, k);
            let mut want: Vec<u32> = (0..n as u32).collect();
            want.sort_by(|&a, &b| {
                v[b as usize]
                    .partial_cmp(&v[a as usize])
                    .unwrap()
                    .then(a.cmp(&b))
            });
            want.truncate(k);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn strided_matches_dense_row() {
        // (W=3, K=4) row-major; select topics of word 1
        let m = [
            0.0f32, 1.0, 2.0, 3.0, // w0
            9.0, 2.0, 7.0, 4.0, // w1
            5.0, 5.0, 5.0, 5.0, // w2
        ];
        let got = top_k_desc_strided(&m, 4, 1, 4, 2);
        assert_eq!(got, vec![0, 2]); // 9.0 at k=0, 7.0 at k=2
        // column select: values of topic 2 across words -> [2,7,5]
        let got = top_k_desc_strided(&m, 2, 4, 3, 2);
        assert_eq!(got, vec![1, 2]);
    }
}
