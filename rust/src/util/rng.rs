//! Deterministic pseudo-random generation (offline substitute for `rand`).
//!
//! xoshiro256** seeded via splitmix64. Everything in the repo that needs
//! randomness (synthetic corpora, message init, Gibbs sampling, property
//! tests) goes through this type so runs are reproducible from a single
//! `u64` seed.

/// xoshiro256** PRNG (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-worker / per-doc RNGs).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The raw 256-bit stream position. Together with [`Rng::from_state`]
    /// this is the checkpoint/restore contract: a generator rebuilt from
    /// a captured state produces the exact `u64` sequence the original
    /// would have produced from that point on (Contract 6).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact stream position captured by
    /// [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn discrete(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; boosts shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^{1/a}
            let g = self.gamma(shape + 1.0);
            return g * self.f64().max(1e-300).powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let xn = self.normal();
            let v = 1.0 + c * xn;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * xn.powi(4)
                || u.ln() < 0.5 * xn * xn + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) sample of dimension `dim`.
    pub fn dirichlet_sym(&mut self, alpha: f64, dim: usize) -> Vec<f64> {
        let mut xs: Vec<f64> = (0..dim).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = xs.iter().sum::<f64>().max(1e-300);
        for x in &mut xs {
            *x /= sum;
        }
        xs
    }

    /// Poisson(lambda) via inversion (small lambda) or normal approx.
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0usize;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            (lambda + lambda.sqrt() * self.normal()).round().max(0.0) as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(3);
        for &a in &[0.05, 0.5, 5.0] {
            let d = r.dirichlet_sym(a, 16);
            assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(4);
        for &shape in &[0.3, 1.0, 4.5] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(1.0),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(5);
        for &lam in &[3.0, 80.0] {
            let n = 5_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < 0.1 * lam, "lam {lam} mean {mean}");
        }
    }

    #[test]
    fn discrete_prefers_heavy_weight() {
        let mut r = Rng::new(6);
        let w = [0.05, 0.05, 0.9];
        let hits = (0..5_000).filter(|_| r.discrete(&w) == 2).count();
        assert!(hits > 4_000);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
